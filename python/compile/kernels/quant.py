"""SageAttention-style per-block INT8 quantization in jnp (paper Sec. 3.5,
Alg. 1 lines 3 & 12). Semantics mirror rust/src/tensor/quant.rs: symmetric
int8 with per-block scale delta = absmax/127, K smoothed by its global
per-channel mean before quantization (softmax-invariant shift)."""

import jax.numpy as jnp


def quantize_blockwise(x, block_rows):
    """Quantize (N, d) into int8 blocks of `block_rows` rows.

    Returns (q_int8 (N, d), scales (N/block_rows,)).
    """
    n, d = x.shape
    assert n % block_rows == 0
    nb = n // block_rows
    xb = x.reshape(nb, block_rows, d)
    absmax = jnp.max(jnp.abs(xb), axis=(1, 2))
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0 / 127.0)
    q = jnp.clip(jnp.round(xb / scale[:, None, None]), -127, 127).astype(jnp.int8)
    return q.reshape(n, d), scale


def dequantize_blockwise(q, scale, block_rows):
    """Inverse of quantize_blockwise (for tests)."""
    n, d = q.shape
    nb = n // block_rows
    return (q.reshape(nb, block_rows, d).astype(jnp.float32) * scale[:, None, None]).reshape(n, d)


def smooth_k(k):
    """Subtract K's per-channel mean (over tokens). Returns (k_smoothed,
    mean). Row softmax is invariant to the induced per-row score shift."""
    mean = k.mean(axis=0)
    return k - mean[None, :], mean


def qk_scores_quantized(q, k, bq, bk, *, scale=None):
    """Dequantized QK^T computed through the int8 path:
    S = (Qq @ Kq^T) * dQ_i * dK_j * scale, with K smoothing.

    The int8 matmul accumulates in int32 (exact), so the only error vs f32
    is the quantization rounding — matching the Rust engine bit-for-bit in
    structure if not in float rounding."""
    d = q.shape[-1]
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.float32(d))
    ks, _ = smooth_k(k)
    qq, dq = quantize_blockwise(q, bq)
    kq, dk = quantize_blockwise(ks, bk)
    acc = jnp.matmul(qq.astype(jnp.int32), kq.astype(jnp.int32).T)
    n, m = q.shape[0], k.shape[0]
    row_scale = jnp.repeat(dq, bq)[:n]
    col_scale = jnp.repeat(dk, bk)[:m]
    return acc.astype(jnp.float32) * row_scale[:, None] * col_scale[None, :] * scale
