"""Stage-1 sparse mask prediction in jnp (paper Sec. 3.2-3.3, Alg. 1
lines 4-6). Semantics match the Rust implementation exactly (including the
inclusive TopCdf crossing element — see rust/src/sparge/predict.rs).

Shapes here require N % bq == 0 and N % bk == 0 (the AOT path pads inputs
to block multiples before calling in).
"""

import jax.numpy as jnp


def compress_blocks(x, block_rows):
    """Mean-token compression: (N, d) -> (N/block_rows, d)."""
    n, d = x.shape
    assert n % block_rows == 0, f"N={n} not a multiple of {block_rows}"
    return x.reshape(n // block_rows, block_rows, d).mean(axis=1)


def cos_sim_blocks(x, block_rows):
    """Per-block mean cosine self-similarity: CosSim(X) = mean(XX^T/|max|).

    Rows are L2-normalized first (matching the Rust engine), so Gram
    entries are true cosines; the |max| normalization then guards
    degenerate blocks. Returns (N/block_rows,).
    """
    n, d = x.shape
    nb = n // block_rows
    xb = x.reshape(nb, block_rows, d)
    norms = jnp.linalg.norm(xb, axis=-1, keepdims=True)
    xn = jnp.where(norms > 0, xb / jnp.maximum(norms, 1e-30), 0.0)
    gram = jnp.einsum("bid,bjd->bij", xn, xn)
    mean = gram.mean(axis=(1, 2))
    maxabs = jnp.max(jnp.abs(gram), axis=(1, 2))
    return jnp.where(maxabs > 0, mean / jnp.maximum(maxabs, 1e-30), 1.0)


def top_cdf(p_hat, tau):
    """Row-wise TopCdf: minimal descending prefix whose mass *reaches*
    tau * row-sum, crossing element included (the prose semantics; the
    paper's `cusum <= tau*sum` pseudocode drops the crossing element —
    see the Rust kernel for the full rationale). Returns bool (Tm, Tn).

    Implemented as sort → cumsum → per-row threshold → `p >= threshold`
    (one sort instead of argsort + inverse-argsort scatter: the xla 0.5.1
    CPU backend the Rust runtime binds compiles the scatter form ~10x
    slower). Equivalent to the prefix form except for exact value ties,
    which have measure zero for real attention scores."""
    sorted_p = -jnp.sort(-p_hat, axis=-1)  # descending
    cum = jnp.cumsum(sorted_p, axis=-1)
    budget = tau * jnp.sum(p_hat, axis=-1, keepdims=True)
    # keep ranks up to and including the first position where cum >= budget
    reached_before = jnp.concatenate(
        [jnp.zeros_like(cum[:, :1], dtype=bool), cum[:, :-1] >= budget], axis=-1
    )
    keep_sorted = jnp.logical_not(reached_before)
    count = jnp.sum(keep_sorted, axis=-1, keepdims=True)  # >= 1
    threshold = jnp.take_along_axis(sorted_p, count - 1, axis=-1)
    return p_hat >= threshold


def predict_mask(q, k, bq, bk, tau, theta, *, causal=False, scale=None):
    """Full stage-1 prediction. Returns (mask bool (Tm,Tn), sim_q, sim_k,
    p_hat)."""
    n, d = q.shape
    m = k.shape[0]
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.float32(d))
    qt = compress_blocks(q, bq)
    kt = compress_blocks(k, bk)
    sim_q = cos_sim_blocks(q, bq)
    sim_k = cos_sim_blocks(k, bk)
    tm, tn = qt.shape[0], kt.shape[0]

    s_hat = (qt @ kt.T) * scale
    s_hat = jnp.where((sim_k < theta)[None, :], -jnp.inf, s_hat)
    if causal:
        # block (i,j) outside the causal domain when j*bk > (i+1)*bq - 1
        qi_last = (jnp.arange(tm) + 1) * bq - 1
        kj_first = jnp.arange(tn) * bk
        domain = kj_first[None, :] <= qi_last[:, None]
        s_hat = jnp.where(domain, s_hat, -jnp.inf)

    mx = jnp.max(s_hat, axis=-1, keepdims=True)
    mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
    p = jnp.where(jnp.isfinite(s_hat), jnp.exp(s_hat - mx), 0.0)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    p_hat = jnp.where(denom > 0, p / jnp.maximum(denom, 1e-30), 0.0)

    mask = top_cdf(p_hat, tau)
    # fix blocks are never skipped (Eq. 5)
    mask = jnp.where((sim_q < theta)[:, None], True, mask)
    mask = jnp.where((sim_k < theta)[None, :], True, mask)
    if causal:
        mask = jnp.logical_and(mask, domain)
    return mask, sim_q, sim_k, p_hat
