"""L1: the SpargeAttn sparse FlashAttention kernel in Pallas (Alg. 1).

The kernel runs one query tile per grid step and streams key/value blocks
through an online-softmax loop, consuming the stage-1 block mask M_g
(skip whole blocks) and applying the stage-2 lambda filter (skip the PV
product per row group when max(m_local - m_new) < lambda).

interpret=True is mandatory on this substrate: CPU PJRT cannot execute
Mosaic custom-calls, and interpret mode lowers the kernel to plain HLO so
the exported artifact runs on the Rust runtime. Real block skipping (and
therefore wall-clock speedup) lives in the Rust engine; this kernel's
masked-update semantics are numerically identical to skipping (the
"skipping == masking" invariant, tested both here and in Rust).

TPU adaptation notes (DESIGN.md Hardware-Adaptation): the (bq, d) query
tile + (bk, d) streamed K/V blocks are the VMEM working set; the paper's
c_w CUDA warps become c_w row groups of the query tile.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import predict as predict_mod

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, *, bq, bk, cw, n_kblocks, scale, lam, causal):
    i = pl.program_id(0)
    q = q_ref[...].astype(jnp.float32)  # (bq, d)
    d = q.shape[-1]
    mask_row = mask_ref[...].reshape(-1)  # (n_kblocks,)

    m0 = jnp.full((bq,), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((bq,), dtype=jnp.float32)
    acc0 = jnp.zeros((bq, d), dtype=jnp.float32)

    rows_per_group = bq // cw
    group_id = jax.lax.broadcasted_iota(jnp.int32, (bq,), 0) // rows_per_group

    def body(j, carry):
        m_prev, l_prev, acc_prev = carry
        kj = pl.load(k_ref, (pl.dslice(j * bk, bk), slice(None))).astype(jnp.float32)
        vj = pl.load(v_ref, (pl.dslice(j * bk, bk), slice(None))).astype(jnp.float32)
        s = (q @ kj.T) * scale  # (bq, bk)
        if causal:
            qi = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kjg = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kjg <= qi, s, NEG_INF)

        m_local = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_local)
        rescale = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        # entries at NEG_INF must contribute exactly zero
        p = jnp.where(s <= NEG_INF / 2, 0.0, p)
        l_new = l_prev * rescale + jnp.sum(p, axis=-1)

        # stage-2 lambda filter: per row group, skip PV when
        # max(m_local - m_new) < lambda  (Alg. 1 line 15)
        diff = m_local - m_new
        group_worst = jax.ops.segment_max(diff, group_id, num_segments=cw)
        skip_pv = (group_worst < lam)[group_id]  # (bq,)

        pv = p @ vj
        pv = jnp.where(skip_pv[:, None], 0.0, pv)
        acc_new = acc_prev * rescale[:, None] + pv

        # stage-1 block mask: masked blocks contribute nothing at all
        on = mask_row[j] != 0
        m_out = jnp.where(on, m_new, m_prev)
        l_out = jnp.where(on, l_new, l_prev)
        acc_out = jnp.where(on, acc_new, acc_prev)
        return m_out, l_out, acc_out

    m, l, acc = jax.lax.fori_loop(0, n_kblocks, body, (m0, l0, acc0))
    safe_l = jnp.where(l > 0, l, 1.0)
    out = jnp.where((l > 0)[:, None], acc / safe_l[:, None], 0.0)
    o_ref[...] = out.astype(o_ref.dtype)


def sparge_attention_pallas(q, k, v, mask, *, bq=64, bk=64, cw=4, lam=None,
                            causal=False, scale=None, interpret=True):
    """Sparse flash attention over one head.

    q: (N, d); k, v: (M, d); mask: (N//bq, M//bk) int32/bool (M_g).
    lam: stage-2 threshold (negative float) or None to disable.
    """
    n, d = q.shape
    m = k.shape[0]
    assert n % bq == 0 and m % bk == 0, "pad inputs to block multiples"
    assert bq % cw == 0, "bq must divide into cw row groups"
    if scale is None:
        scale = float(1.0 / (d ** 0.5))
    n_qblocks = n // bq
    n_kblocks = m // bk
    lam_val = float(lam) if lam is not None else -1e30

    kernel = functools.partial(
        _kernel, bq=bq, bk=bk, cw=cw, n_kblocks=n_kblocks,
        scale=scale, lam=lam_val, causal=causal,
    )
    return pl.pallas_call(
        kernel,
        grid=(n_qblocks,),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i: (i, 0)),        # Q tile
            pl.BlockSpec((m, d), lambda i: (0, 0)),          # full K
            pl.BlockSpec((m, d), lambda i: (0, 0)),          # full V
            pl.BlockSpec((1, n_kblocks), lambda i: (i, 0)),  # mask row
        ],
        out_specs=pl.BlockSpec((bq, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), q.dtype),
        interpret=interpret,
    )(q, k, v, mask.astype(jnp.int32))


def sparge_attention(q, k, v, *, tau, theta, lam=None, bq=64, bk=64, cw=4,
                     causal=False, scale=None, interpret=True):
    """End-to-end SpargeAttn: stage-1 prediction (jnp) + the Pallas sparse
    kernel. Returns (out, mask)."""
    mask, _, _, _ = predict_mod.predict_mask(
        q, k, bq, bk, tau, theta, causal=causal, scale=scale
    )
    out = sparge_attention_pallas(
        q, k, v, mask, bq=bq, bk=bk, cw=cw, lam=lam,
        causal=causal, scale=scale, interpret=interpret,
    )
    return out, mask


def sparge_attention_simulated(q, k, v, *, tau, theta, bq=64, bk=64,
                               causal=False, scale=None):
    """Pure-jnp simulated sparge (prediction + block-masked dense attention,
    no Pallas). Used inside the L2 model artifacts where a lean HLO module
    matters more than exercising the kernel; numerics match the kernel with
    lam=None by the skipping==masking invariant."""
    from . import ref

    mask, _, _, _ = predict_mod.predict_mask(
        q, k, bq, bk, tau, theta, causal=causal, scale=scale
    )
    out = ref.attention_block_masked(q, k, v, mask, bq, bk, causal=causal, scale=scale)
    return out, mask
