"""Pure-jnp oracles for the SpargeAttn kernels.

Every Pallas kernel and every exported HLO module is validated against
these reference implementations (pytest + hypothesis sweeps in
``python/tests/``); the Rust engine checks against the same semantics
through golden trace files.
"""

import jax.numpy as jnp


def attention_dense(q, k, v, *, causal=False, scale=None):
    """Full-matrix attention: O = softmax(QK^T*scale [+ causal]) V.

    q, k, v: (N, d) single-head arrays (f32).
    """
    d = q.shape[-1]
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.float32(d))
    s = (q @ k.T) * scale
    if causal:
        n, m = s.shape
        mask = jnp.tril(jnp.ones((n, m), dtype=bool))
        s = jnp.where(mask, s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return p @ v


def attention_block_masked(q, k, v, block_mask, bq, bk, *, causal=False, scale=None):
    """Attention with a *block* mask: score entries whose (i//bq, j//bk)
    block is masked out are set to -inf before softmax.

    Numerically identical to skipping those block matmuls in the sparse
    kernel — this is the oracle for the "skipping == masking" invariant.
    Rows that lose every block produce zeros (matching the kernel).
    """
    d = q.shape[-1]
    n, m = q.shape[0], k.shape[0]
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.float32(d))
    s = (q @ k.T) * scale
    elem_mask = jnp.repeat(jnp.repeat(block_mask.astype(bool), bq, axis=0), bk, axis=1)[:n, :m]
    if causal:
        elem_mask = jnp.logical_and(elem_mask, jnp.tril(jnp.ones((n, m), dtype=bool)))
    s = jnp.where(elem_mask, s, -jnp.inf)
    mx = jnp.max(s, axis=-1, keepdims=True)
    mx = jnp.where(jnp.isfinite(mx), mx, 0.0)  # all-masked rows
    p = jnp.where(jnp.isfinite(s), jnp.exp(s - mx), 0.0)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    p = jnp.where(denom > 0, p / jnp.maximum(denom, 1e-30), 0.0)
    return p @ v


def rel_l1(candidate, reference):
    """The paper's accuracy metric (Sec. 3.6): sum|O-O'| / sum|O|."""
    num = jnp.sum(jnp.abs(candidate - reference))
    den = jnp.sum(jnp.abs(reference))
    return num / den
