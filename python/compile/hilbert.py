"""Hilbert-curve token permutation (paper Sec. 3.7) — Python port of
rust/src/sparge/hilbert.rs (Skilling transform + index sort). A golden-file
test (test_hilbert.py vs `sparge analyze --hilbert-golden`) keeps the two
implementations bit-identical."""

import numpy as np


def hilbert_index(point, bits):
    """Hilbert index of a 3-D point with `bits` bits per axis (Skilling's
    AxestoTranspose + bit interleave). point: (3,) ints."""
    x = list(int(v) for v in point)
    n = 3
    m = 1 << (bits - 1)

    q = m
    while q > 1:
        p = q - 1
        for i in range(n):
            if x[i] & q:
                x[0] ^= p
            else:
                t = (x[0] ^ x[i]) & p
                x[0] ^= t
                x[i] ^= t
        q >>= 1
    for i in range(1, n):
        x[i] ^= x[i - 1]
    t = 0
    q = m
    while q > 1:
        if x[n - 1] & q:
            t ^= q - 1
        q >>= 1
    for i in range(n):
        x[i] ^= t

    out = 0
    for b in range(bits - 1, -1, -1):
        for i in range(n):
            out = (out << 1) | ((x[i] >> b) & 1)
    return out


def hilbert_order(t, h, w):
    """Token order for a T*H*W grid: order[pos] = row-major linear index of
    the token at flattened position pos (matches Rust `token_order` for
    Permutation::HilbertCurve)."""
    maxdim = max(t, h, w, 1)
    bits = max((maxdim - 1).bit_length(), 1)
    cells = []
    for tt in range(t):
        for hh in range(h):
            for ww in range(w):
                cells.append((hilbert_index((tt, hh, ww), bits), (tt * h + hh) * w + ww))
    cells.sort()
    return np.array([lin for _, lin in cells], dtype=np.int64)


def invert_order(order):
    inv = np.empty_like(order)
    inv[order] = np.arange(len(order))
    return inv
