"""L2: JAX models whose attention dispatches to the SpargeAttn kernels.

Two model families, both defined over a single flat f32 parameter vector
(so the Rust runtime feeds/receives a handful of opaque buffers instead of
dozens of named arrays):

- ``TextLM``: byte-level causal transformer (the Llama3.1 proxy of
  DESIGN.md Sec. 3) with sinusoidal positions, trained from scratch through
  the exported ``lm_train_step`` HLO by the Rust e2e driver.
- ``DiT``: bidirectional diffusion-transformer proxy over latent token
  grids (the CogvideoX / Mochi / Flux proxy), used by the video/image
  benches and the denoise-loop example.

Attention mode is a build-time switch: ``dense`` (exact) or ``sparge``
(stage-1 prediction + block-masked attention — numerically identical to
the skipping kernel; see kernels/sparge.py for why the lean simulated form
is used inside model artifacts).
"""

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref as kref
from .kernels import sparge as ksparge


# ----------------------------------------------------------------------
# configuration
# ----------------------------------------------------------------------

@dataclass(frozen=True)
class SpargeCfg:
    tau: float = 0.95
    theta: float = 0.4
    bq: int = 32
    bk: int = 32


@dataclass(frozen=True)
class LmCfg:
    vocab: int = 256
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 4
    d_ff: int = 512
    sparge: SpargeCfg = field(default_factory=SpargeCfg)

    @property
    def d_head(self):
        return self.d_model // self.n_heads


@dataclass(frozen=True)
class DitCfg:
    d_in: int = 16
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 4
    d_ff: int = 512
    sparge: SpargeCfg = field(default_factory=lambda: SpargeCfg(tau=0.9, theta=0.35))

    @property
    def d_head(self):
        return self.d_model // self.n_heads


# ----------------------------------------------------------------------
# flat parameter packing
# ----------------------------------------------------------------------

def _block_spec(prefix, d_model, d_ff):
    return [
        (prefix + "ln1_g", (d_model,)),
        (prefix + "ln1_b", (d_model,)),
        (prefix + "wq", (d_model, d_model)),
        (prefix + "wk", (d_model, d_model)),
        (prefix + "wv", (d_model, d_model)),
        (prefix + "wo", (d_model, d_model)),
        (prefix + "ln2_g", (d_model,)),
        (prefix + "ln2_b", (d_model,)),
        (prefix + "w1", (d_model, d_ff)),
        (prefix + "b1", (d_ff,)),
        (prefix + "w2", (d_ff, d_model)),
        (prefix + "b2", (d_model,)),
    ]


def lm_param_spec(cfg: LmCfg):
    """Ordered (name, shape) list defining the flat layout."""
    spec = [("tok_emb", (cfg.vocab, cfg.d_model))]
    for i in range(cfg.n_layers):
        spec += _block_spec(f"layer{i}.", cfg.d_model, cfg.d_ff)
    spec += [("lnf_g", (cfg.d_model,)), ("lnf_b", (cfg.d_model,)), ("head", (cfg.d_model, cfg.vocab))]
    return spec


def dit_param_spec(cfg: DitCfg):
    spec = [("proj_in", (cfg.d_in, cfg.d_model)), ("t_emb", (cfg.d_model,))]
    for i in range(cfg.n_layers):
        spec += _block_spec(f"layer{i}.", cfg.d_model, cfg.d_ff)
    spec += [("lnf_g", (cfg.d_model,)), ("lnf_b", (cfg.d_model,)), ("proj_out", (cfg.d_model, cfg.d_in))]
    return spec


def param_count(spec):
    return sum(int(np.prod(shape)) for _, shape in spec)


def unflatten(flat, spec):
    """Slice the flat vector into named arrays (static offsets — lowers to
    plain slices in HLO)."""
    out = {}
    off = 0
    for name, shape in spec:
        size = int(np.prod(shape))
        out[name] = flat[off:off + size].reshape(shape)
        off += size
    return out


def init_params(spec, seed=0, scale=0.02):
    """Gaussian init, ones/zeros for norms & biases. Returns np.float32."""
    rng = np.random.default_rng(seed)
    chunks = []
    for name, shape in spec:
        base = name.split(".")[-1]
        if base.endswith("_g"):
            arr = np.ones(shape, np.float32)
        elif base.endswith("_b") or base in ("b1", "b2"):
            arr = np.zeros(shape, np.float32)
        else:
            arr = rng.standard_normal(shape).astype(np.float32) * scale
        chunks.append(arr.ravel())
    return np.concatenate(chunks)


# ----------------------------------------------------------------------
# building blocks
# ----------------------------------------------------------------------

def layer_norm(x, g, b, eps=1e-5):
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def sinusoidal_positions(t, d):
    pos = jnp.arange(t)[:, None].astype(jnp.float32)
    i = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    angles = pos / jnp.power(10000.0, 2.0 * i / d)
    return jnp.concatenate([jnp.sin(angles), jnp.cos(angles)], axis=-1)


def _head_attention(q, k, v, *, causal, mode, sp: SpargeCfg):
    """Single-head dispatch: exact dense or simulated sparge."""
    if mode == "dense":
        return kref.attention_dense(q, k, v, causal=causal)
    out, _ = ksparge.sparge_attention_simulated(
        q, k, v, tau=sp.tau, theta=sp.theta, bq=sp.bq, bk=sp.bk, causal=causal
    )
    return out


def multi_head_attention(x, wq, wk, wv, wo, n_heads, *, causal, mode, sp):
    t, dm = x.shape
    dh = dm // n_heads
    q = (x @ wq).reshape(t, n_heads, dh).transpose(1, 0, 2)
    k = (x @ wk).reshape(t, n_heads, dh).transpose(1, 0, 2)
    v = (x @ wv).reshape(t, n_heads, dh).transpose(1, 0, 2)
    # vmap over heads (not a Python loop): one sort/predict instance per
    # layer in the lowered HLO instead of n_heads — the old xla_extension
    # the Rust runtime binds compiles repeated sort instances superlinearly.
    heads = jax.vmap(
        lambda qh, kh, vh: _head_attention(qh, kh, vh, causal=causal, mode=mode, sp=sp)
    )(q, k, v)
    concat = heads.transpose(1, 0, 2).reshape(t, dm)
    return concat @ wo


def _block(x, p, prefix, n_heads, *, causal, mode, sp):
    h = layer_norm(x, p[prefix + "ln1_g"], p[prefix + "ln1_b"])
    x = x + multi_head_attention(
        h, p[prefix + "wq"], p[prefix + "wk"], p[prefix + "wv"], p[prefix + "wo"],
        n_heads, causal=causal, mode=mode, sp=sp,
    )
    h = layer_norm(x, p[prefix + "ln2_g"], p[prefix + "ln2_b"])
    h = jax.nn.gelu(h @ p[prefix + "w1"] + p[prefix + "b1"])
    return x + h @ p[prefix + "w2"] + p[prefix + "b2"]


# ----------------------------------------------------------------------
# TextLM
# ----------------------------------------------------------------------

def lm_forward(cfg: LmCfg, flat_params, tokens, *, mode="dense"):
    """tokens: (T,) int32 -> logits (T, vocab)."""
    p = unflatten(flat_params, lm_param_spec(cfg))
    x = p["tok_emb"][tokens] + sinusoidal_positions(tokens.shape[0], cfg.d_model)
    for i in range(cfg.n_layers):
        x = _block(x, p, f"layer{i}.", cfg.n_heads, causal=True, mode=mode, sp=cfg.sparge)
    x = layer_norm(x, p["lnf_g"], p["lnf_b"])
    return x @ p["head"]


def lm_loss(cfg: LmCfg, flat_params, tokens, *, mode="dense"):
    """Next-byte cross-entropy over a (T,) sequence."""
    logits = lm_forward(cfg, flat_params, tokens, mode=mode)
    logp = jax.nn.log_softmax(logits[:-1], axis=-1)
    tgt = tokens[1:]
    nll = -jnp.take_along_axis(logp, tgt[:, None], axis=-1).mean()
    return nll


def lm_batch_loss(cfg: LmCfg, flat_params, tokens, *, mode="dense"):
    """tokens: (B, T) int32 -> scalar mean loss."""
    return jax.vmap(lambda t: lm_loss(cfg, flat_params, t, mode=mode))(tokens).mean()


def lm_train_step(cfg: LmCfg, flat_params, m, v, step, tokens,
                  lr=3e-3, beta1=0.9, beta2=0.99, eps=1e-8):
    """One Adam step on the batch loss. All state is flat f32 vectors.

    Returns (params', m', v', step', loss)."""
    loss, grads = jax.value_and_grad(
        lambda fp: lm_batch_loss(cfg, fp, tokens, mode="dense")
    )(flat_params)
    step = step + 1.0
    m = beta1 * m + (1 - beta1) * grads
    v = beta2 * v + (1 - beta2) * grads * grads
    mhat = m / (1 - beta1 ** step)
    vhat = v / (1 - beta2 ** step)
    new_params = flat_params - lr * mhat / (jnp.sqrt(vhat) + eps)
    return new_params, m, v, step, loss


# ----------------------------------------------------------------------
# DiT proxy
# ----------------------------------------------------------------------

def dit_forward(cfg: DitCfg, flat_params, latents, t_scalar, *, mode="dense"):
    """latents: (N, d_in) tokens; t_scalar: () diffusion timestep in [0,1].
    Returns the predicted denoising direction, (N, d_in)."""
    p = unflatten(flat_params, dit_param_spec(cfg))
    x = latents @ p["proj_in"]
    x = x + jnp.sin(t_scalar * 100.0) * p["t_emb"][None, :]
    x = x + sinusoidal_positions(latents.shape[0], cfg.d_model)
    for i in range(cfg.n_layers):
        x = _block(x, p, f"layer{i}.", cfg.n_heads, causal=False, mode=mode, sp=cfg.sparge)
    x = layer_norm(x, p["lnf_g"], p["lnf_b"])
    return x @ p["proj_out"]
