"""Writer/reader for the binary tensor-trace format shared with the Rust
runtime (rust/src/workloads/trace.rs). Little-endian, versioned:

    magic u32 = 0x53504721 ("SPG!"), version u32 = 1, ntensor u32,
    then per tensor: ndim u32, dims u32*ndim, f32 data row-major.
"""

import struct

import numpy as np

MAGIC = 0x53504721
VERSION = 1


def save(path, tensors):
    """tensors: iterable of float32-convertible numpy arrays."""
    with open(path, "wb") as f:
        tensors = list(tensors)
        f.write(struct.pack("<III", MAGIC, VERSION, len(tensors)))
        for t in tensors:
            a = np.ascontiguousarray(np.asarray(t), dtype=np.float32)
            f.write(struct.pack("<I", a.ndim))
            f.write(struct.pack(f"<{a.ndim}I", *a.shape))
            f.write(a.tobytes())


def load(path):
    with open(path, "rb") as f:
        magic, version, count = struct.unpack("<III", f.read(12))
        if magic != MAGIC:
            raise ValueError(f"bad magic {magic:#x}")
        if version != VERSION:
            raise ValueError(f"unsupported version {version}")
        out = []
        for _ in range(count):
            (ndim,) = struct.unpack("<I", f.read(4))
            shape = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
            total = int(np.prod(shape)) if ndim else 1
            data = np.frombuffer(f.read(4 * total), dtype="<f4").reshape(shape)
            out.append(data.copy())
        return out
