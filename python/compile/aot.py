"""AOT export: lower the L1/L2 computations to HLO *text* artifacts the
Rust runtime loads via PJRT.

HLO text (not ``lowered.compile().serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids that
xla_extension 0.5.1 (the version the published ``xla`` crate binds)
rejects; the text parser reassigns ids and round-trips cleanly.

Run as ``python -m compile.aot --out-dir ../artifacts`` (the Makefile's
``make artifacts``). Python never runs after this step.
"""

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from . import trace_io
from .kernels import ref as kref
from .kernels import sparge as ksparge

# attention artifact geometry (single head, paper-style head dim)
ATTN_D = 64
ATTN_SEQ_LENS = (1024, 2048)
ATTN_BQ, ATTN_BK, ATTN_CW = 64, 64, 4
ATTN_TAU, ATTN_THETA, ATTN_LAMBDA = 0.95, 0.4, -8.0

# model artifact geometry
LM_CFG = M.LmCfg()
LM_SEQ_LENS = (256, 1024, 2048)
TRAIN_B, TRAIN_T = 8, 256
DIT_CFG = M.DitCfg()
DIT_N = 1152  # 2 x 24 x 24 latent grid


def to_hlo_text(lowered):
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _iospec(shapes_dtypes):
    return [{"shape": list(s), "dtype": d} for s, d in shapes_dtypes]


class Exporter:
    def __init__(self, out_dir):
        self.out_dir = out_dir
        self.manifest = {"version": 1, "artifacts": {}}
        os.makedirs(out_dir, exist_ok=True)

    def export(self, name, fn, arg_specs, inputs, outputs, meta=None):
        t0 = time.time()
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        path = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, path), "w") as f:
            f.write(text)
        self.manifest["artifacts"][name] = {
            "path": path,
            "inputs": _iospec(inputs),
            "outputs": _iospec(outputs),
            "meta": meta or {},
        }
        print(f"  [{time.time()-t0:6.1f}s] {name}: {len(text)/1e6:.2f} MB")

    def finish(self):
        with open(os.path.join(self.out_dir, "manifest.json"), "w") as f:
            json.dump(self.manifest, f, indent=1)
        print(f"wrote manifest with {len(self.manifest['artifacts'])} artifacts")


def export_attention(ex: Exporter):
    """Single-head attention ops: dense oracle and the SpargeAttn Pallas
    kernel (stage-1 mask computed in-graph; tau/theta/lambda baked)."""
    for n in ATTN_SEQ_LENS:
        qkv = [_spec((n, ATTN_D))] * 3

        ex.export(
            f"attn_dense_{n}",
            lambda q, k, v: (kref.attention_dense(q, k, v),),
            qkv,
            inputs=[((n, ATTN_D), "f32")] * 3,
            outputs=[((n, ATTN_D), "f32")],
            meta={"kind": "attn_dense", "seq": n, "d": ATTN_D},
        )

        def sparge_fn(q, k, v):
            out, mask = ksparge.sparge_attention(
                q, k, v, tau=ATTN_TAU, theta=ATTN_THETA, lam=ATTN_LAMBDA,
                bq=ATTN_BQ, bk=ATTN_BK, cw=ATTN_CW,
            )
            density = jnp.mean(mask.astype(jnp.float32))
            return out, density

        ex.export(
            f"attn_sparge_{n}",
            sparge_fn,
            qkv,
            inputs=[((n, ATTN_D), "f32")] * 3,
            outputs=[((n, ATTN_D), "f32"), ((), "f32")],
            meta={
                "kind": "attn_sparge", "seq": n, "d": ATTN_D,
                "tau": ATTN_TAU, "theta": ATTN_THETA, "lambda": ATTN_LAMBDA,
                "bq": ATTN_BQ, "bk": ATTN_BK, "cw": ATTN_CW,
            },
        )


def export_lm(ex: Exporter):
    spec = M.lm_param_spec(LM_CFG)
    pcount = M.param_count(spec)
    meta_base = {
        "d_model": LM_CFG.d_model, "n_heads": LM_CFG.n_heads,
        "n_layers": LM_CFG.n_layers, "vocab": LM_CFG.vocab,
        "params": pcount,
    }

    # initial weights + Adam state seeds, via the shared trace format
    params0 = M.init_params(spec, seed=0)
    trace_io.save(os.path.join(ex.out_dir, "lm_init.spg"), [params0])
    print(f"  lm params: {pcount/1e6:.2f}M")

    for t in LM_SEQ_LENS:
        for mode in ("dense", "sparge"):
            fn = functools.partial(
                lambda fp, toks, mode: (M.lm_forward(LM_CFG, fp, toks, mode=mode),),
                mode=mode,
            )
            ex.export(
                f"lm_fwd_{mode}_{t}",
                fn,
                [_spec((pcount,)), _spec((t,), jnp.int32)],
                inputs=[((pcount,), "f32"), ((t,), "i32")],
                outputs=[((t, LM_CFG.vocab), "f32")],
                meta={**meta_base, "kind": f"lm_fwd_{mode}", "seq": t,
                      **({"tau": LM_CFG.sparge.tau, "theta": LM_CFG.sparge.theta,
                          "bq": LM_CFG.sparge.bq, "bk": LM_CFG.sparge.bk}
                         if mode == "sparge" else {})},
            )

    def train_fn(fp, m, v, step, tokens):
        return M.lm_train_step(LM_CFG, fp, m, v, step, tokens)

    ex.export(
        f"lm_train_step_{TRAIN_B}x{TRAIN_T}",
        train_fn,
        [_spec((pcount,)), _spec((pcount,)), _spec((pcount,)), _spec(()),
         _spec((TRAIN_B, TRAIN_T), jnp.int32)],
        inputs=[((pcount,), "f32")] * 3 + [((), "f32"), ((TRAIN_B, TRAIN_T), "i32")],
        outputs=[((pcount,), "f32")] * 3 + [((), "f32"), ((), "f32")],
        meta={**meta_base, "kind": "lm_train_step", "batch": TRAIN_B, "seq": TRAIN_T},
    )


def export_dit(ex: Exporter):
    spec = M.dit_param_spec(DIT_CFG)
    pcount = M.param_count(spec)
    params0 = M.init_params(spec, seed=1)
    trace_io.save(os.path.join(ex.out_dir, "dit_init.spg"), [params0])

    for mode in ("dense", "sparge"):
        fn = functools.partial(
            lambda fp, x, t, mode: (M.dit_forward(DIT_CFG, fp, x, t, mode=mode),),
            mode=mode,
        )
        ex.export(
            f"dit_fwd_{mode}_{DIT_N}",
            fn,
            [_spec((pcount,)), _spec((DIT_N, DIT_CFG.d_in)), _spec(())],
            inputs=[((pcount,), "f32"), ((DIT_N, DIT_CFG.d_in), "f32"), ((), "f32")],
            outputs=[((DIT_N, DIT_CFG.d_in), "f32")],
            meta={"kind": f"dit_fwd_{mode}", "seq": DIT_N, "d_in": DIT_CFG.d_in,
                  "params": pcount},
        )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: attn,lm,dit")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else {"attn", "lm", "dit"}

    ex = Exporter(args.out_dir)
    if "attn" in only:
        print("== attention artifacts ==")
        export_attention(ex)
    if "lm" in only:
        print("== LM artifacts ==")
        export_lm(ex)
    if "dit" in only:
        print("== DiT artifacts ==")
        export_dit(ex)
    ex.finish()


if __name__ == "__main__":
    main()
