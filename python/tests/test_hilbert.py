"""Hilbert permutation: bijection, adjacency, and the cross-language golden
order that pins the Python port to the Rust implementation."""

import numpy as np
from hypothesis import given, strategies as st

from compile import hilbert


@given(t=st.integers(1, 4), h=st.integers(1, 6), w=st.integers(1, 6))
def test_order_is_bijection(t, h, w):
    order = hilbert.hilbert_order(t, h, w)
    assert len(order) == t * h * w
    assert sorted(order.tolist()) == list(range(t * h * w))


def test_adjacent_steps_on_pow2_cube():
    t = h = w = 4
    order = hilbert.hilbert_order(t, h, w)
    coords = [(i // (h * w), (i // w) % h, i % w) for i in order]
    for a, b in zip(coords, coords[1:]):
        dist = sum(abs(x - y) for x, y in zip(a, b))
        assert dist == 1, f"non-adjacent {a} -> {b}"


def test_invert_order():
    order = hilbert.hilbert_order(2, 3, 4)
    inv = hilbert.invert_order(order)
    np.testing.assert_array_equal(order[inv], np.arange(24))
    np.testing.assert_array_equal(inv[order], np.arange(24))


def test_golden_order_2x4x4():
    """Golden file shared with rust (rust/tests/hilbert_golden.rs computes
    the same constant). If either implementation changes, both tests break
    together."""
    order = hilbert.hilbert_order(2, 4, 4).tolist()
    assert order == GOLDEN_2x4x4, f"order changed: {order}"


def test_golden_index_values():
    assert hilbert.hilbert_index((0, 0, 0), 2) == 0
    vals = {hilbert.hilbert_index((a, b, c), 1) for a in range(2) for b in range(2) for c in range(2)}
    assert vals == set(range(8))


# generated once from this implementation and cross-checked against the
# Rust hilbert_index (see rust/tests/hilbert_golden.rs)
GOLDEN_2x4x4 = [
    0, 4, 20, 16, 17, 21, 5, 1, 2, 3, 19, 18, 22, 23, 7, 6,
    10, 11, 15, 14, 30, 31, 27, 26, 25, 9, 13, 29, 28, 12, 8, 24,
]
