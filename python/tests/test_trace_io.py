"""Trace interchange format: roundtrip + byte-level golden (the format the
Rust side reads/writes — rust/src/workloads/trace.rs)."""

import struct

import numpy as np

from compile import trace_io


def test_roundtrip(tmp_path):
    p = tmp_path / "t.spg"
    a = np.arange(12, dtype=np.float32).reshape(3, 4)
    b = np.float32([[1.5]])
    trace_io.save(p, [a, b])
    back = trace_io.load(p)
    assert len(back) == 2
    np.testing.assert_array_equal(back[0], a)
    np.testing.assert_array_equal(back[1], b)


def test_header_bytes(tmp_path):
    p = tmp_path / "h.spg"
    trace_io.save(p, [np.zeros((2,), np.float32)])
    raw = p.read_bytes()
    magic, version, count = struct.unpack("<III", raw[:12])
    assert magic == 0x53504721
    assert version == 1
    assert count == 1
    (ndim,) = struct.unpack("<I", raw[12:16])
    assert ndim == 1
    (dim0,) = struct.unpack("<I", raw[16:20])
    assert dim0 == 2


def test_rejects_bad_magic(tmp_path):
    p = tmp_path / "bad.spg"
    p.write_bytes(b"NOPE" + b"\x00" * 8)
    try:
        trace_io.load(p)
        assert False, "should raise"
    except ValueError:
        pass
