"""Combined-path integration tests for the L1 kernel: stage-1 mask +
stage-2 lambda + causality together, and agreement between the predicted
mask and the realized attention mass."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import predict, ref, sparge


def structured_qk(rng, n, d, nb, signal=6.0, noise=0.3):
    dirs = rng.standard_normal((nb, d)).astype(np.float32)
    dirs /= np.linalg.norm(dirs, axis=1, keepdims=True)
    q = np.zeros((n, d), np.float32)
    k = np.zeros((n, d), np.float32)
    for t in range(n):
        g = (t * nb) // n
        q[t] = dirs[g] * signal + rng.standard_normal(d) * noise
        k[t] = dirs[g] * signal + rng.standard_normal(d) * noise
    return jnp.array(q), jnp.array(k)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10**6))
def test_causal_sparse_with_lambda_bounded_error(seed):
    rng = np.random.default_rng(seed)
    n, d, b = 256, 16, 32
    q, k = structured_qk(rng, n, d, nb=8)
    v = jnp.array(rng.standard_normal((n, d)), jnp.float32)
    out, mask = sparge.sparge_attention(
        q, k, v, tau=0.95, theta=0.3, lam=-8.0, bq=b, bk=b, causal=True
    )
    want = ref.attention_dense(q, k, v, causal=True)
    err = float(ref.rel_l1(out, want))
    assert err < 0.08, f"causal sparge rel_l1 {err}"
    # causal mask domain respected
    m = np.asarray(mask)
    for i in range(m.shape[0]):
        for j in range(m.shape[1]):
            if j > i:
                assert not m[i, j]


def test_mask_covers_the_attention_mass():
    """The realized dense attention mass inside the predicted mask must be
    at least ~tau on structured inputs (the prediction-is-accurate claim)."""
    rng = np.random.default_rng(3)
    n, d, b = 256, 16, 32
    q, k = structured_qk(rng, n, d, nb=8)
    tau = 0.9
    mask, _, _, _ = predict.predict_mask(q, k, b, b, tau=tau, theta=0.3)
    s = (q @ k.T) / jnp.sqrt(jnp.float32(d))
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    elem = jnp.repeat(jnp.repeat(mask, b, axis=0), b, axis=1)
    covered = float((p * elem).sum() / p.sum())
    assert covered > tau - 0.07, f"mask covers only {covered:.3f} of mass"


def test_quantized_sparge_pipeline():
    """INT8 scores + stage-1 mask compose: output close to f32 dense."""
    from compile.kernels import quant

    rng = np.random.default_rng(4)
    n, d, b = 128, 32, 32
    q, k = structured_qk(rng, n, d, nb=4)
    v = jnp.array(rng.standard_normal((n, d)), jnp.float32)
    mask, _, _, _ = predict.predict_mask(q, k, b, b, tau=0.98, theta=0.2)
    s_q = quant.qk_scores_quantized(q, k, b, b)
    elem = jnp.repeat(jnp.repeat(mask, b, axis=0), b, axis=1)
    s_q = jnp.where(elem, s_q, -jnp.inf)
    p = jnp.exp(s_q - s_q.max(-1, keepdims=True))
    p = jnp.where(jnp.isfinite(s_q), p, 0.0)
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    out = p @ v
    want = ref.attention_dense(q, k, v)
    err = float(ref.rel_l1(out, want))
    assert err < 0.08, f"quant+mask rel_l1 {err}"


def test_kernel_accepts_rectangular_blocks():
    rng = np.random.default_rng(5)
    n, m, d = 128, 192, 16
    q = jnp.array(rng.standard_normal((n, d)), jnp.float32)
    k = jnp.array(rng.standard_normal((m, d)), jnp.float32)
    v = jnp.array(rng.standard_normal((m, d)), jnp.float32)
    mask = jnp.ones((n // 32, m // 64), jnp.int32)
    out = sparge.sparge_attention_pallas(q, k, v, mask, bq=32, bk=64, cw=2)
    want = ref.attention_dense(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5, rtol=2e-5)


def test_lambda_respects_row_group_granularity():
    """cw=1 (whole tile is one group) must skip no more than cw=4."""
    rng = np.random.default_rng(6)
    n, d, b = 128, 16, 32
    q = jnp.array(rng.standard_normal((n, d)), jnp.float32)
    karr = np.asarray(rng.standard_normal((n, d)), np.float32)
    karr[::32] *= 12.0
    k = jnp.array(karr)
    v = jnp.array(rng.standard_normal((n, d)), jnp.float32)
    mask = jnp.ones((4, 4), jnp.int32)
    dense = ref.attention_dense(q, k, v)
    out1 = sparge.sparge_attention_pallas(q, k, v, mask, bq=b, bk=b, cw=1, lam=-6.0)
    out4 = sparge.sparge_attention_pallas(q, k, v, mask, bq=b, bk=b, cw=4, lam=-6.0)
    err1 = float(ref.rel_l1(out1, dense))
    err4 = float(ref.rel_l1(out4, dense))
    # coarser groups are *more* conservative (a single active row vetoes
    # the whole group), so cw=1 error <= cw=4 error + slack
    assert err1 <= err4 + 0.02, f"cw=1 err {err1} vs cw=4 err {err4}"
