"""Stage-1 prediction: compression, self-similarity, TopCdf invariants."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from compile.kernels import predict


def test_compress_means():
    x = jnp.array([[1., 0.], [3., 0.], [10., 2.], [20., 4.]], jnp.float32)
    out = np.asarray(predict.compress_blocks(x, 2))
    np.testing.assert_allclose(out, [[2., 0.], [15., 3.]])


def test_cos_sim_identical_rows_is_one():
    x = jnp.tile(jnp.array([[1., 2., -1.]], jnp.float32), (8, 1))
    sim = np.asarray(predict.cos_sim_blocks(x, 4))
    np.testing.assert_allclose(sim, 1.0, atol=1e-5)


def test_cos_sim_orthogonal_rows():
    x = jnp.array([[1., 0.], [0., 1.], [1., 0.], [0., 1.]], jnp.float32)
    sim = np.asarray(predict.cos_sim_blocks(x, 4))
    np.testing.assert_allclose(sim, 0.5, atol=1e-5)


@given(n=st.integers(1, 30), tau=st.floats(0.01, 0.999), seed=st.integers(0, 10**6))
def test_top_cdf_coverage_and_minimality(n, tau, seed):
    rng = np.random.default_rng(seed)
    p = jnp.array(rng.random((1, n)) + 1e-6, jnp.float32)
    sel = np.asarray(predict.top_cdf(p, tau))[0]
    pn = np.asarray(p)[0]
    picked = pn[sel].sum()
    total = pn.sum()
    assert sel.sum() >= 1
    assert picked >= tau * total - 1e-4          # coverage reached
    if sel.sum() > 1:                             # minimality
        assert picked - pn[sel].min() < tau * total + 1e-4
    # order property: unselected <= selected min
    if (~sel).any() and sel.any():
        assert pn[~sel].max() <= pn[sel].min() + 1e-6


def test_top_cdf_crossing_element_included():
    p = jnp.array([[0.50, 0.48, 0.02]], jnp.float32)
    sel = np.asarray(predict.top_cdf(p, 0.95))[0]
    assert sel.tolist() == [True, True, False]


def test_predict_tau_one_selects_all():
    rng = np.random.default_rng(0)
    q = jnp.array(rng.standard_normal((32, 8)), jnp.float32)
    k = jnp.array(rng.standard_normal((32, 8)), jnp.float32)
    mask, _, _, _ = predict.predict_mask(q, k, 8, 8, tau=1.0, theta=-1.0)
    assert bool(np.asarray(mask).all())


def test_fix_blocks_force_rows_cols():
    rng = np.random.default_rng(1)
    q = jnp.array(rng.standard_normal((16, 4)), jnp.float32)
    k = np.asarray(rng.standard_normal((16, 4)), dtype=np.float32)
    # make K block 1 anti-correlated
    k[4:8] = np.array([[1, 0, 0, 0], [-1, 0, 0, 0], [1, 0, 0, 0], [-1, 0, 0, 0]], np.float32) * 3
    mask, sim_q, sim_k, _ = predict.predict_mask(q, jnp.array(k), 4, 4, tau=0.1, theta=0.9)
    mask = np.asarray(mask)
    sim_k = np.asarray(sim_k)
    for j in range(4):
        if sim_k[j] < 0.9:
            assert mask[:, j].all(), f"fix col {j} not forced"


@given(seed=st.integers(0, 10**6), tau=st.floats(0.05, 1.0))
def test_causal_mask_lower_triangular(seed, tau):
    rng = np.random.default_rng(seed)
    n, b = 64, 8
    q = jnp.array(rng.standard_normal((n, 8)), jnp.float32)
    k = jnp.array(rng.standard_normal((n, 8)), jnp.float32)
    mask, _, _, _ = predict.predict_mask(q, k, b, b, tau=tau, theta=0.0, causal=True)
    mask = np.asarray(mask)
    for i in range(mask.shape[0]):
        for j in range(mask.shape[1]):
            if j > i:
                assert not mask[i, j]
    # every row keeps at least one block
    assert (mask.sum(axis=1) >= 1).all()


def test_local_pattern_selects_diagonal():
    n, d, b = 64, 16, 8
    q = np.zeros((n, d), np.float32)
    k = np.zeros((n, d), np.float32)
    for t in range(n):
        q[t, (t // b) % d] = 4.0
        k[t, (t // b) % d] = 4.0
    mask, _, _, _ = predict.predict_mask(jnp.array(q), jnp.array(k), b, b, tau=0.3, theta=0.0)
    mask = np.asarray(mask)
    assert all(mask[i, i] for i in range(mask.shape[0]))
    assert mask.mean() < 0.5
