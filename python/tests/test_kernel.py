"""The CORE correctness signal: the Pallas SpargeAttn kernel vs the
pure-jnp oracle, swept over shapes/blocks/causality with hypothesis."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, sparge


def mk(rng, *shape):
    return jnp.array(rng.standard_normal(shape), jnp.float32)


@settings(max_examples=12, deadline=None)
@given(
    nb=st.integers(1, 4),          # number of q blocks
    mb=st.integers(1, 4),          # number of k blocks
    bq=st.sampled_from([16, 32]),
    bk=st.sampled_from([16, 32]),
    d=st.sampled_from([8, 32, 64]),
    cw=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 10**6),
)
def test_full_mask_matches_dense(nb, mb, bq, bk, d, cw, seed):
    rng = np.random.default_rng(seed)
    n, m = nb * bq, mb * bk
    q = mk(rng, n, d)
    k, v = mk(rng, m, d), mk(rng, m, d)
    mask = jnp.ones((nb, mb), jnp.int32)
    out = sparge.sparge_attention_pallas(q, k, v, mask, bq=bq, bk=bk, cw=cw)
    want = ref.attention_dense(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5, rtol=2e-5)


@settings(max_examples=12, deadline=None)
@given(
    nb=st.integers(1, 4),
    mb=st.integers(1, 4),
    seed=st.integers(0, 10**6),
)
def test_skipping_equals_masking(nb, mb, seed):
    """Random block mask through the kernel == -inf masking in the oracle."""
    rng = np.random.default_rng(seed)
    bq = bk = 16
    d = 16
    n, m = nb * bq, mb * bk
    q = mk(rng, n, d)
    k, v = mk(rng, m, d), mk(rng, m, d)
    mask = rng.integers(0, 2, (nb, mb))
    mask[:, 0] = 1  # at least one block per row
    maskj = jnp.array(mask, jnp.int32)
    out = sparge.sparge_attention_pallas(q, k, v, maskj, bq=bq, bk=bk, cw=2)
    want = ref.attention_block_masked(q, k, v, maskj, bq, bk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5, rtol=2e-5)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10**6), cw=st.sampled_from([1, 2, 4]))
def test_causal_matches_dense(seed, cw):
    rng = np.random.default_rng(seed)
    n, d, b = 96, 16, 32
    q, k, v = (mk(rng, n, d) for _ in range(3))
    mask = jnp.ones((n // b, n // b), jnp.int32)
    out = sparge.sparge_attention_pallas(q, k, v, mask, bq=b, bk=b, cw=cw, causal=True)
    want = ref.attention_dense(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5, rtol=2e-5)


def test_lambda_very_negative_is_lossless():
    rng = np.random.default_rng(7)
    n, d, b = 128, 16, 32
    q, k, v = (mk(rng, n, d) for _ in range(3))
    mask = jnp.ones((4, 4), jnp.int32)
    out = sparge.sparge_attention_pallas(q, k, v, mask, bq=b, bk=b, cw=4, lam=-1e9)
    want = ref.attention_dense(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5, rtol=2e-5)


def test_lambda_moderate_bounds_error():
    """Spiky keys make later blocks negligible; lambda must skip them with
    a small relative-L1 error."""
    rng = np.random.default_rng(8)
    n, d, b = 256, 16, 32
    q = mk(rng, n, d)
    k = np.asarray(rng.standard_normal((n, d)), np.float32)
    k[::32] *= 12.0  # one spiked key per block
    k = jnp.array(k)
    v = mk(rng, n, d)
    mask = jnp.ones((n // b, n // b), jnp.int32)
    out = sparge.sparge_attention_pallas(q, k, v, mask, bq=b, bk=b, cw=4, lam=-8.0)
    want = ref.attention_dense(q, k, v)
    err = float(ref.rel_l1(out, want))
    assert err < 0.05, f"rel_l1 {err}"


def test_all_masked_row_outputs_zero():
    rng = np.random.default_rng(9)
    n, d, b = 32, 8, 16
    q, k, v = (mk(rng, n, d) for _ in range(3))
    mask = jnp.array([[0, 0], [1, 1]], jnp.int32)
    out = np.asarray(sparge.sparge_attention_pallas(q, k, v, mask, bq=b, bk=b, cw=2))
    assert np.all(out[:16] == 0.0)
    assert np.any(out[16:] != 0.0)


def test_end_to_end_sparge_accuracy_on_local_pattern():
    """Structured inputs: prediction + kernel reach real sparsity with
    small error vs dense."""
    rng = np.random.default_rng(10)
    n, d, b = 512, 32, 32
    nb = 8
    dirs = rng.standard_normal((nb, d)).astype(np.float32)
    dirs /= np.linalg.norm(dirs, axis=1, keepdims=True)
    q = np.zeros((n, d), np.float32)
    k = np.zeros((n, d), np.float32)
    for t in range(n):
        g = (t * nb) // n
        q[t] = dirs[g] * 6 + rng.standard_normal(d) * 0.3
        k[t] = dirs[g] * 6 + rng.standard_normal(d) * 0.3
    v = mk(rng, n, d)
    out, mask = sparge.sparge_attention(
        jnp.array(q), jnp.array(k), v, tau=0.95, theta=0.3, lam=-8.0, bq=b, bk=b
    )
    want = ref.attention_dense(jnp.array(q), jnp.array(k), v)
    err = float(ref.rel_l1(out, want))
    density = float(np.asarray(mask).mean())
    assert err < 0.05, f"rel_l1 {err}"
    assert density < 0.6, f"mask density {density}"


def test_simulated_matches_kernel():
    """The lean jnp 'simulated' sparge used in model artifacts must match
    the Pallas kernel (lam disabled) exactly."""
    rng = np.random.default_rng(11)
    n, d, b = 128, 16, 32
    q, k, v = (mk(rng, n, d) for _ in range(3))
    out_k, mask_k = sparge.sparge_attention(q, k, v, tau=0.8, theta=0.2, bq=b, bk=b)
    out_s, mask_s = sparge.sparge_attention_simulated(q, k, v, tau=0.8, theta=0.2, bq=b, bk=b)
    assert np.array_equal(np.asarray(mask_k), np.asarray(mask_s))
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_s), atol=2e-5, rtol=2e-5)
