"""AOT export path: HLO text artifacts parse and the manifest is complete."""

import json

import jax
import jax.numpy as jnp

from compile import aot
from compile import model as M


def test_to_hlo_text_contains_module():
    lowered = jax.jit(lambda x: (x * 2,)).lower(jax.ShapeDtypeStruct((2, 2), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[2,2]" in text


def test_export_small_artifact(tmp_path):
    ex = aot.Exporter(str(tmp_path))
    ex.export(
        "toy",
        lambda x: (x + 1.0,),
        [jax.ShapeDtypeStruct((4,), jnp.float32)],
        inputs=[((4,), "f32")],
        outputs=[((4,), "f32")],
        meta={"kind": "toy"},
    )
    ex.finish()
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    art = manifest["artifacts"]["toy"]
    assert art["path"] == "toy.hlo.txt"
    assert art["inputs"][0]["shape"] == [4]
    text = (tmp_path / "toy.hlo.txt").read_text()
    assert "HloModule" in text


def test_lm_fwd_lowering_has_expected_signature(tmp_path):
    """The exported LM forward takes (params, tokens) and yields logits."""
    cfg = M.LmCfg(n_layers=1, d_model=32, d_ff=64, n_heads=2)
    spec = M.lm_param_spec(cfg)
    pcount = M.param_count(spec)
    lowered = jax.jit(lambda fp, t: (M.lm_forward(cfg, fp, t),)).lower(
        jax.ShapeDtypeStruct((pcount,), jnp.float32),
        jax.ShapeDtypeStruct((32,), jnp.int32),
    )
    text = aot.to_hlo_text(lowered)
    assert f"f32[{pcount}]" in text
    assert "s32[32]" in text
    assert "f32[32,256]" in text  # logits
