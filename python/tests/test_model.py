"""L2 model: shapes, training signal, sparge-mode fidelity."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M

CFG = M.LmCfg(n_layers=2, d_model=64, d_ff=128, n_heads=2)
DCFG = M.DitCfg(n_layers=2, d_model=64, d_ff=128, n_heads=2, d_in=8)


def test_param_spec_and_count():
    spec = M.lm_param_spec(CFG)
    names = [n for n, _ in spec]
    assert names[0] == "tok_emb" and names[-1] == "head"
    flat = M.init_params(spec, seed=0)
    assert flat.shape == (M.param_count(spec),)
    p = M.unflatten(jnp.array(flat), spec)
    assert p["layer0.wq"].shape == (64, 64)
    # norms start at one, biases at zero
    assert float(p["layer0.ln1_g"].mean()) == 1.0
    assert float(p["layer0.b1"].mean()) == 0.0


def test_lm_forward_shapes():
    spec = M.lm_param_spec(CFG)
    flat = jnp.array(M.init_params(spec, seed=0))
    toks = jnp.arange(64, dtype=jnp.int32) % 256
    logits = M.lm_forward(CFG, flat, toks)
    assert logits.shape == (64, CFG.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_lm_is_causal():
    """Changing a future token must not change past logits."""
    spec = M.lm_param_spec(CFG)
    flat = jnp.array(M.init_params(spec, seed=0))
    toks = jnp.arange(64, dtype=jnp.int32) % 256
    l1 = M.lm_forward(CFG, flat, toks)
    toks2 = toks.at[-1].set((toks[-1] + 7) % 256)
    l2 = M.lm_forward(CFG, flat, toks2)
    np.testing.assert_allclose(np.asarray(l1)[:-1], np.asarray(l2)[:-1], atol=1e-5)


def test_train_step_reduces_loss():
    spec = M.lm_param_spec(CFG)
    flat = jnp.array(M.init_params(spec, seed=0))
    m = jnp.zeros_like(flat)
    v = jnp.zeros_like(flat)
    step = jnp.float32(0.0)
    rng = np.random.default_rng(0)
    toks = jnp.array(rng.integers(97, 110, (4, 64)), jnp.int32)  # tiny alphabet
    train = jax.jit(lambda f, m, v, s, t: M.lm_train_step(CFG, f, m, v, s, t))
    losses = []
    for _ in range(12):
        flat, m, v, step, loss = train(flat, m, v, step, toks)
        losses.append(float(loss))
    assert losses[0] > np.log(256) * 0.8  # starts near uniform
    assert losses[-1] < losses[0] * 0.7, f"no learning: {losses[0]:.3f} -> {losses[-1]:.3f}"


def test_sparge_mode_close_to_dense_on_repetitive_input():
    spec = M.lm_param_spec(CFG)
    flat = jnp.array(M.init_params(spec, seed=0))
    toks = jnp.tile(jnp.arange(32, dtype=jnp.int32), 4)  # 128 tokens, repetitive
    dense = M.lm_forward(CFG, flat, toks, mode="dense")
    sp = M.lm_forward(CFG, flat, toks, mode="sparge")
    # at init with tau=0.95 the outputs should be close in probability space
    pd = jax.nn.softmax(dense, axis=-1)
    ps = jax.nn.softmax(sp, axis=-1)
    err = float(jnp.abs(pd - ps).sum() / jnp.abs(pd).sum())
    assert err < 0.15, f"sparge-vs-dense prob rel-L1 {err}"


def test_dit_forward_shapes_and_time_dependence():
    spec = M.dit_param_spec(DCFG)
    flat = jnp.array(M.init_params(spec, seed=1))
    rng = np.random.default_rng(2)
    x = jnp.array(rng.standard_normal((96, DCFG.d_in)), jnp.float32)
    o1 = M.dit_forward(DCFG, flat, x, jnp.float32(0.1))
    o2 = M.dit_forward(DCFG, flat, x, jnp.float32(0.9))
    assert o1.shape == (96, DCFG.d_in)
    assert not np.allclose(np.asarray(o1), np.asarray(o2))


def test_dit_sparge_mode_runs():
    spec = M.dit_param_spec(DCFG)
    flat = jnp.array(M.init_params(spec, seed=1))
    rng = np.random.default_rng(3)
    x = jnp.array(rng.standard_normal((96, DCFG.d_in)), jnp.float32)
    o = M.dit_forward(DCFG, flat, x, jnp.float32(0.5), mode="sparge")
    assert bool(jnp.isfinite(o).all())
