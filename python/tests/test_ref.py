"""Oracle sanity: the dense reference must satisfy attention identities."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from compile.kernels import ref


def mk(rng, *shape):
    return jnp.array(rng.standard_normal(shape), jnp.float32)


def test_uniform_scores_average_v():
    rng = np.random.default_rng(0)
    n, d = 16, 8
    q = jnp.zeros((n, d), jnp.float32)
    k, v = mk(rng, n, d), mk(rng, n, d)
    out = ref.attention_dense(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.tile(np.asarray(v).mean(0), (n, 1)), atol=1e-5)


def test_causal_first_row_is_v0():
    rng = np.random.default_rng(1)
    q, k, v = (mk(rng, 8, 4) for _ in range(3))
    out = ref.attention_dense(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out)[0], np.asarray(v)[0], atol=1e-5)


@given(n=st.integers(2, 24), d=st.integers(1, 16), seed=st.integers(0, 10**6))
def test_convex_combination(n, d, seed):
    rng = np.random.default_rng(seed)
    q, k = mk(rng, n, d), mk(rng, n, d)
    v = jnp.ones((n, d), jnp.float32)
    out = ref.attention_dense(q, k, v)
    np.testing.assert_allclose(np.asarray(out), 1.0, atol=1e-4)


def test_block_mask_all_ones_equals_dense():
    rng = np.random.default_rng(2)
    n, d, b = 32, 8, 8
    q, k, v = (mk(rng, n, d) for _ in range(3))
    mask = jnp.ones((n // b, n // b), bool)
    np.testing.assert_allclose(
        np.asarray(ref.attention_block_masked(q, k, v, mask, b, b)),
        np.asarray(ref.attention_dense(q, k, v)),
        atol=1e-5,
    )


def test_block_mask_zero_rows_output_zero():
    rng = np.random.default_rng(3)
    n, d, b = 16, 4, 8
    q, k, v = (mk(rng, n, d) for _ in range(3))
    mask = jnp.zeros((2, 2), bool).at[1, :].set(True)
    out = np.asarray(ref.attention_block_masked(q, k, v, mask, b, b))
    assert np.all(out[:8] == 0.0)
    assert np.any(out[8:] != 0.0)


@given(seed=st.integers(0, 10**6))
def test_rel_l1_properties(seed):
    rng = np.random.default_rng(seed)
    a = mk(rng, 4, 4)
    assert float(ref.rel_l1(a, a)) == pytest.approx(0.0, abs=1e-7)
    b = a + 0.1
    assert float(ref.rel_l1(b, a)) > 0.0
