"""SageAttention INT8 quantization semantics (Sec. 3.5)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from compile.kernels import quant, ref


@given(nb=st.integers(1, 4), b=st.sampled_from([8, 16]), d=st.sampled_from([4, 16]),
       seed=st.integers(0, 10**6))
def test_roundtrip_error_within_half_step(nb, b, d, seed):
    rng = np.random.default_rng(seed)
    x = jnp.array(rng.standard_normal((nb * b, d)), jnp.float32)
    q, scale = quant.quantize_blockwise(x, b)
    back = quant.dequantize_blockwise(q, scale, b)
    xb = np.asarray(x).reshape(nb, b, d)
    step = np.abs(xb).max(axis=(1, 2)) / 127.0
    err = np.abs(np.asarray(back).reshape(nb, b, d) - xb)
    assert (err <= step[:, None, None] * 0.5 + 1e-6).all()


def test_zero_block():
    q, scale = quant.quantize_blockwise(jnp.zeros((8, 4), jnp.float32), 8)
    assert np.all(np.asarray(q) == 0)


def test_smoothing_removes_common_offset():
    rng = np.random.default_rng(1)
    k = jnp.array(rng.standard_normal((32, 8)) + 10.0, jnp.float32)
    ks, mean = quant.smooth_k(k)
    assert float(jnp.abs(ks).max()) < float(jnp.abs(k).max()) / 2


def test_smoothing_is_softmax_invariant():
    """softmax(Q (K - mean)^T) == softmax(Q K^T) row-wise."""
    rng = np.random.default_rng(2)
    q = jnp.array(rng.standard_normal((16, 8)), jnp.float32)
    k = jnp.array(rng.standard_normal((24, 8)), jnp.float32)
    v = jnp.array(rng.standard_normal((24, 8)), jnp.float32)
    ks, _ = quant.smooth_k(k)
    np.testing.assert_allclose(
        np.asarray(ref.attention_dense(q, k, v)),
        np.asarray(ref.attention_dense(q, ks, v)),
        atol=1e-4, rtol=1e-4,
    )


@given(seed=st.integers(0, 10**6))
def test_quantized_scores_close(seed):
    rng = np.random.default_rng(seed)
    n, d, b = 64, 32, 16
    q = jnp.array(rng.standard_normal((n, d)), jnp.float32)
    k = jnp.array(rng.standard_normal((n, d)), jnp.float32)
    s_q = quant.qk_scores_quantized(q, k, b, b)
    s_f = (q @ k.T) / jnp.sqrt(jnp.float32(d))
    # softmax-level agreement is what matters: compare attention outputs
    v = jnp.array(rng.standard_normal((n, d)), jnp.float32)
    pq = jnp.exp(s_q - s_q.max(-1, keepdims=True))
    pq = pq / pq.sum(-1, keepdims=True)
    pf = jnp.exp(s_f - s_f.max(-1, keepdims=True))
    pf = pf / pf.sum(-1, keepdims=True)
    err = float(ref.rel_l1(pq @ v, pf @ v))
    assert err < 0.08, f"attention-output rel_l1 {err}"
