import os
import sys

# make `compile` importable when pytest runs from python/ or repo root
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from hypothesis import settings

settings.register_profile("sparge", max_examples=20, deadline=None)
settings.load_profile("sparge")
