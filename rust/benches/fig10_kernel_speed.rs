//! Fig. 10 reproduction: kernel speed (TOPS) under varying sparsity.
//! Inputs: 22K sequence (Mochi's length), head dim 128 — the figure's
//! exact geometry at full scale, scaled down by default for CPU.
//!
//! Series: SpargeAttn (ours, INT8), SpargeAttn+FA2 (ours, f32),
//! MInference, and the dense FlashAttention2 horizontal line. Sparsity is
//! swept via τ (ours) / keep-budget (MInference).
//!
//! Expected shape: both Sparge variants scale up with sparsity and
//! dominate MInference at every operating point; the INT8 variant sits
//! above the f32 one.
//!
//! Run: `cargo bench --bench fig10_kernel_speed`

use sparge::attention::types::AttnConfig;
use sparge::experiments::{bench_reps, full_scale, run_method, Method};
use sparge::sparge::kernel::SpargeParams;
use sparge::util::rng::Pcg;
use sparge::util::table::{fnum, Table};
use sparge::workloads::{video, VideoSpec};

fn main() {
    let (spec, label) = if full_scale() {
        (VideoSpec { t: 28, h: 28, w: 28, d: 128, smooth: 0.96, signal: 11.0 }, "22K")
    } else {
        (VideoSpec { t: 4, h: 24, w: 24, d: 128, smooth: 0.96, signal: 11.0 }, "2.3K")
    };
    let reps = bench_reps();
    println!("Fig. 10 — kernel speed vs sparsity (seq {label}, head dim 128, reps {reps})\n");

    let cfg = AttnConfig { bq: 128, bk: 64, causal: false, scale: None, cw: 4 };
    let mut rng = Pcg::seeded(1010);
    let s = video::generate_grid(&spec, &mut rng);
    let (nq, nk, d) = (s.q.dim(0), s.k.dim(0), s.q.dim(1));

    let dense = run_method(&s, &cfg, &Method::Full);
    let dense_tops = dense.tops(nq, nk, d, false) * 1e3;

    let mut table = Table::new(
        &format!("kernel speed under varying sparsity (dense FA2 line: {} GOPS cpu)", fnum(dense_tops, 1)),
        &["method", "target", "achieved sparsity", "GOPS(cpu)", "TOPS(gpu-translated)", "speedup vs dense"],
    );
    // ours: sweep tau; both f32 (FA2) and int8 (Sage) kernels
    for &tau in &[0.99f32, 0.97, 0.95, 0.9, 0.8, 0.7] {
        for quant in [false, true] {
            let m = Method::Sparge(SpargeParams { tau, theta: 0.3, lambda: Some(-8.0), quant });
            let mut best: Option<sparge::experiments::MethodRun> = None;
            for _ in 0..reps {
                let r = run_method(&s, &cfg, &m);
                if best.as_ref().map(|b| r.seconds < b.seconds).unwrap_or(true) {
                    best = Some(r);
                }
            }
            let r = best.unwrap();
            table.row(&[
                m.label(),
                format!("tau={tau}"),
                fnum(r.stats.sparsity(), 3),
                fnum(r.tops(nq, nk, d, false) * 1e3, 1),
                fnum(r.gpu_tops(dense.seconds), 1),
                format!("{:.2}x", dense.seconds / r.seconds),
            ]);
        }
    }
    // MInference sweep
    for &budget in &[0.7f64, 0.5, 0.3] {
        let m = Method::Minference { budget };
        let mut best: Option<sparge::experiments::MethodRun> = None;
        for _ in 0..reps {
            let r = run_method(&s, &cfg, &m);
            if best.as_ref().map(|b| r.seconds < b.seconds).unwrap_or(true) {
                best = Some(r);
            }
        }
        let r = best.unwrap();
        table.row(&[
            m.label(),
            format!("keep={budget}"),
            fnum(r.stats.sparsity(), 3),
            fnum(r.tops(nq, nk, d, false) * 1e3, 1),
            fnum(r.gpu_tops(dense.seconds), 1),
            format!("{:.2}x", dense.seconds / r.seconds),
        ]);
    }
    table.print();
    println!("\npaper Fig.10 shape: ours > ours+FA2 > baselines at every sparsity; all rise with sparsity");
}
