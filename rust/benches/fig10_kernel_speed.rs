//! Fig. 10 reproduction: kernel speed (TOPS) under varying sparsity.
//! Inputs: 22K sequence (Mochi's length), head dim 128 — the figure's
//! exact geometry at full scale, scaled down by default for CPU.
//!
//! Series: SpargeAttn (ours, INT8), SpargeAttn+FA2 (ours, f32),
//! MInference, and the dense FlashAttention2 horizontal line. Sparsity is
//! swept via τ (ours) / keep-budget (MInference). All methods run through
//! the unified tiled driver with `SPARGE_BENCH_THREADS` row workers
//! (default: one per core).
//!
//! Expected shape: both Sparge variants scale up with sparsity and
//! dominate MInference at every operating point; the INT8 variant sits
//! above the f32 one.
//!
//! A second section measures intra-head row parallelism on a single head
//! at n ≥ 4096: wall-clock speedup of `threads = cores` over
//! `threads = 1`, with bitwise-identical outputs and SkipStats.
//!
//! Run: `cargo bench --bench fig10_kernel_speed`

use std::time::Instant;

use sparge::attention::types::AttnConfig;
use sparge::attention::{AttnEngine, Execution, KvSplit};
use sparge::coordinator::{AttnStreamSpec, SeqStream, SessionManager};
use sparge::experiments::{bench_reps, bench_threads, full_scale, run_method_threads, Method};
use sparge::sparge::kernel::SpargeParams;
use sparge::util::rng::Pcg;
use sparge::util::stats::percentile_sorted;
use sparge::util::table::{fnum, Table};
use sparge::workloads::{video, VideoSpec};

fn best_of(reps: usize, f: impl Fn() -> sparge::experiments::MethodRun) -> sparge::experiments::MethodRun {
    let mut best: Option<sparge::experiments::MethodRun> = None;
    for _ in 0..reps {
        let r = f();
        if best.as_ref().map(|b| r.seconds < b.seconds).unwrap_or(true) {
            best = Some(r);
        }
    }
    best.unwrap()
}

fn main() {
    let (spec, label) = if full_scale() {
        (VideoSpec { t: 28, h: 28, w: 28, d: 128, smooth: 0.96, signal: 11.0 }, "22K")
    } else {
        (VideoSpec { t: 4, h: 24, w: 24, d: 128, smooth: 0.96, signal: 11.0 }, "2.3K")
    };
    let reps = bench_reps();
    let threads = bench_threads();
    println!("Fig. 10 — kernel speed vs sparsity (seq {label}, head dim 128, reps {reps}, threads {threads})\n");

    let cfg = AttnConfig { bq: 128, bk: 64, causal: false, scale: None, cw: 4, row_offset: 0 };
    let mut rng = Pcg::seeded(1010);
    let s = video::generate_grid(&spec, &mut rng);
    let (nq, nk, d) = (s.q.dim(0), s.k.dim(0), s.q.dim(1));

    let dense = best_of(reps, || run_method_threads(&s, &cfg, &Method::Full, threads));
    let dense_tops = dense.tops(nq, nk, d, false) * 1e3;

    let mut table = Table::new(
        &format!("kernel speed under varying sparsity (dense FA2 line: {} GOPS cpu)", fnum(dense_tops, 1)),
        &["method", "target", "achieved sparsity", "GOPS(cpu)", "TOPS(gpu-translated)", "speedup vs dense"],
    );
    // ours: sweep tau; both f32 (FA2) and int8 (Sage) kernels
    for &tau in &[0.99f32, 0.97, 0.95, 0.9, 0.8, 0.7] {
        for quant in [false, true] {
            let m = Method::Sparge(SpargeParams { tau, theta: 0.3, lambda: Some(-8.0), quant });
            let r = best_of(reps, || run_method_threads(&s, &cfg, &m, threads));
            table.row(&[
                m.label(),
                format!("tau={tau}"),
                fnum(r.stats.sparsity(), 3),
                fnum(r.tops(nq, nk, d, false) * 1e3, 1),
                fnum(r.gpu_tops(dense.seconds), 1),
                format!("{:.2}x", dense.seconds / r.seconds),
            ]);
        }
    }
    // MInference sweep
    for &budget in &[0.7f64, 0.5, 0.3] {
        let m = Method::Minference { budget };
        let r = best_of(reps, || run_method_threads(&s, &cfg, &m, threads));
        table.row(&[
            m.label(),
            format!("keep={budget}"),
            fnum(r.stats.sparsity(), 3),
            fnum(r.tops(nq, nk, d, false) * 1e3, 1),
            fnum(r.gpu_tops(dense.seconds), 1),
            format!("{:.2}x", dense.seconds / r.seconds),
        ]);
    }
    table.print();
    println!("\npaper Fig.10 shape: ours > ours+FA2 > baselines at every sparsity; all rise with sparsity");

    // -- intra-head row-parallel scaling: one head, n >= 4096 ------------
    let scale_spec = if full_scale() {
        spec
    } else {
        VideoSpec { t: 8, h: 24, w: 24, d: 128, smooth: 0.96, signal: 11.0 }
    };
    let mut rng = Pcg::seeded(1011);
    let ss = video::generate_grid(&scale_spec, &mut rng);
    let n = ss.q.dim(0);
    println!("\nrow-parallel scaling — single head, n={n}, threads 1 vs {threads}");
    let mut scaling = Table::new(
        "unified-driver row parallelism (bitwise-identical outputs)",
        &["method", "t=1 (s)", &format!("t={threads} (s)"), "speedup", "stats identical"],
    );
    for m in [
        Method::Full,
        Method::Sparge(SpargeParams { tau: 0.95, theta: 0.3, lambda: Some(-8.0), quant: false }),
    ] {
        let serial = best_of(reps, || run_method_threads(&ss, &cfg, &m, 1));
        let par = best_of(reps, || run_method_threads(&ss, &cfg, &m, threads));
        let same = serial.stats == par.stats && serial.out == par.out;
        assert!(same, "{}: parallel run diverged from serial", m.label());
        scaling.row(&[
            m.label(),
            fnum(serial.seconds, 3),
            fnum(par.seconds, 3),
            format!("{:.2}x", serial.seconds / par.seconds),
            "yes".into(),
        ]);
    }
    scaling.print();

    // -- split-KV decode scaling: one session, 1-row steps ---------------
    // run_tiled has a single query-tile row to hand out at decode, so its
    // wall-clock cannot scale with threads; the split-KV driver fans
    // contiguous KV spans of the cached keys across the pool instead
    // (S = ceil(n_kblocks / span) from the cache length, so outputs are
    // bitwise-identical at every pool size).
    let steps = 32;
    let n0 = n - steps;
    println!("\nsplit-KV decode scaling — one session, cache {n0} keys, {steps} steps, d 128");
    let mut dec = Table::new(
        "decode tokens/s by driver (dense f32 engine; prefill untimed)",
        &["pool", "split-KV off", "split-KV on", "on/off"],
    );
    let decode_rate = |pool: usize, split: KvSplit| -> f64 {
        let engine = AttnEngine::builder()
            .config(cfg)
            .execution(Execution::Pool(pool))
            .kv_split(split)
            .build();
        let mut session = engine.session();
        session.prefill(&ss.q.rows(0, n0), &ss.k.rows(0, n0), &ss.v.rows(0, n0));
        let t0 = Instant::now();
        for t in n0..n {
            session.decode(&ss.q.rows(t, t + 1), &ss.k.rows(t, t + 1), &ss.v.rows(t, t + 1));
        }
        steps as f64 / t0.elapsed().as_secs_f64()
    };
    for pool in [1usize, 2, threads.max(4)] {
        let off = decode_rate(pool, KvSplit::Off);
        let on = decode_rate(pool, KvSplit::Auto);
        dec.row(&[format!("{pool}"), fnum(off, 1), fnum(on, 1), format!("{:.2}x", on / off)]);
    }
    dec.print();
    println!("expected: the off column is flat in pool size; the on column climbs with it");

    // -- ragged-tail stragglers: one long + many short sessions ----------
    // The batched tick's worst case: one session with a deep KV cache
    // (its decode step costs ~long/short more than the others). Chunked
    // self-scheduling + the participating submitter keep the short
    // sessions from idling behind a static partition, and split-KV lets
    // leftover workers help the long session's own step. Tick p99/p50
    // spread is the straggler metric.
    let long_prefill = if full_scale() { 4096 } else { 1024 };
    let short_prefill = 128;
    let steps = 32;
    let mut ragged_specs =
        vec![AttnStreamSpec { prefill: long_prefill, decode: steps, d: 64, seed: 1700 }];
    for i in 0..7u64 {
        ragged_specs.push(AttnStreamSpec { prefill: short_prefill, decode: steps, d: 64, seed: 1701 + i });
    }
    println!(
        "\nragged-tail stragglers — 1 long (cache {long_prefill}) + 7 short (cache {short_prefill}) \
         sessions, {steps} decode steps each"
    );
    let mut ragged = Table::new(
        "batched decode ticks under ragged session costs (sparge f32, split-KV auto)",
        &["pool", "tok/s", "tick p50", "tick p99", "p99/p50"],
    );
    for pool in [1usize, 2, threads.max(4)] {
        let engine = AttnEngine::builder()
            .config(AttnConfig::causal())
            .sparge(&SpargeParams { tau: 0.9, theta: 0.3, lambda: None, quant: false })
            .execution(Execution::Pool(pool))
            .kv_split(KvSplit::Auto)
            .build();
        let mut mgr = SessionManager::new(&engine, 256);
        for (i, s) in ragged_specs.iter().enumerate() {
            mgr.admit(i as u64, SeqStream::synth(s), Instant::now());
        }
        while mgr.prefilling() > 0 {
            mgr.tick();
        }
        let t0 = Instant::now();
        let mut tokens = 0usize;
        let mut ticks = Vec::new();
        while mgr.active() > 0 {
            // prefill is drained, so every active session decodes one
            // row this tick; counting sessions-per-tick credits only the
            // decode work actually done in the timed window (retirement
            // totals would include steps taken during the untimed drain)
            tokens += mgr.active();
            let tick0 = Instant::now();
            mgr.tick();
            ticks.push(tick0.elapsed().as_secs_f64());
        }
        let rate = tokens as f64 / t0.elapsed().as_secs_f64();
        ticks.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let (p50, p99) = (percentile_sorted(&ticks, 0.50), percentile_sorted(&ticks, 0.99));
        ragged.row(&[
            format!("{pool}"),
            fnum(rate, 1),
            format!("{} us", fnum(p50 * 1e6, 0)),
            format!("{} us", fnum(p99 * 1e6, 0)),
            format!("{:.2}x", p99 / p50.max(1e-12)),
        ]);
    }
    ragged.print();
    println!("expected: p99/p50 stays bounded as the pool grows — the long session no longer strands a tick");
}
