//! Fig. 10 reproduction: kernel speed (TOPS) under varying sparsity.
//! Inputs: 22K sequence (Mochi's length), head dim 128 — the figure's
//! exact geometry at full scale, scaled down by default for CPU.
//!
//! Series: SpargeAttn (ours, INT8), SpargeAttn+FA2 (ours, f32),
//! MInference, and the dense FlashAttention2 horizontal line. Sparsity is
//! swept via τ (ours) / keep-budget (MInference). All methods run through
//! the unified tiled driver with `SPARGE_BENCH_THREADS` row workers
//! (default: one per core).
//!
//! Expected shape: both Sparge variants scale up with sparsity and
//! dominate MInference at every operating point; the INT8 variant sits
//! above the f32 one.
//!
//! A second section measures intra-head row parallelism on a single head
//! at n ≥ 4096: wall-clock speedup of `threads = cores` over
//! `threads = 1`, with bitwise-identical outputs and SkipStats.
//!
//! The opening section is the **microkernel scoreboard**: direct timings
//! of the dispatch tier (`tensor::microkernel::Backend`) on the
//! attention tile shapes — f32 QKᵀ, the m=1 decode GEMV, the dot
//! product, the INT8 i8×i8→i32 kernel, and the P̃·V accumulate — for
//! every runtime-available backend, with speedup vs the portable
//! lane-by-lane kernels.
//!
//! Run: `cargo bench --bench fig10_kernel_speed`
//! Pass `-- --json` to also write a `BENCH_fig10.json` snapshot (the
//! CI perf-trajectory artifact).

use std::time::Instant;

use sparge::attention::types::AttnConfig;
use sparge::attention::{AttnEngine, Execution, KvSplit};
use sparge::coordinator::{AttnStreamSpec, SeqStream, SessionManager};
use sparge::experiments::{bench_reps, bench_threads, full_scale, run_method_threads, Method};
use sparge::sparge::kernel::SpargeParams;
use sparge::tensor::microkernel::Backend;
use sparge::tensor::Tensor;
use sparge::util::json::Json;
use sparge::util::rng::Pcg;
use sparge::util::stats::percentile_sorted;
use sparge::util::table::{fnum, Table};
use sparge::workloads::{video, VideoSpec};

fn best_of(reps: usize, f: impl Fn() -> sparge::experiments::MethodRun) -> sparge::experiments::MethodRun {
    let mut best: Option<sparge::experiments::MethodRun> = None;
    for _ in 0..reps {
        let r = f();
        if best.as_ref().map(|b| r.seconds < b.seconds).unwrap_or(true) {
            best = Some(r);
        }
    }
    best.unwrap()
}

/// Best-of-`reps` per-call seconds for a microkernel body, with the
/// inner iteration count sized from the kernel's flop count so tiny
/// kernels (a 128-wide dot) still fill a measurable window.
fn time_kernel(reps: usize, flops: f64, mut f: impl FnMut()) -> f64 {
    let target = if full_scale() { 2e8 } else { 2e7 };
    let iters = ((target / flops) as usize).clamp(1, 4_000_000);
    let mut best = f64::INFINITY;
    for _ in 0..reps.max(3) {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        let s = t0.elapsed().as_secs_f64() / iters as f64;
        if s < best {
            best = s;
        }
    }
    best
}

fn main() {
    let (spec, label) = if full_scale() {
        (VideoSpec { t: 28, h: 28, w: 28, d: 128, smooth: 0.96, signal: 11.0 }, "22K")
    } else {
        (VideoSpec { t: 4, h: 24, w: 24, d: 128, smooth: 0.96, signal: 11.0 }, "2.3K")
    };
    let reps = bench_reps();
    let threads = bench_threads();
    let json_mode = std::env::args().any(|a| a == "--json");
    println!("Fig. 10 — kernel speed vs sparsity (seq {label}, head dim 128, reps {reps}, threads {threads})\n");

    // -- microkernel scoreboard: the three flop-dominant inner loops -----
    // Direct timings of the dispatch tier on the paper's tile shapes
    // (b_q = 128, b_k = 64, d = 128). Every `ScoreKernel` routes its
    // inner loops through `Backend::select()`, so the selected row of
    // this table is the per-block cost everything above it pays.
    println!("microkernel scoreboard — selected backend: {}", Backend::select().name());
    let mut micro = Table::new(
        "hot-loop kernels by backend (fixed-order kernels are bitwise across backends)",
        &["kernel", "shape", "backend", "GOP/s", "vs portable"],
    );
    let mut micro_json: Vec<Json> = Vec::new();
    {
        let (m, n, k) = (128usize, 64usize, 128usize);
        let mut rng = Pcg::seeded(1013);
        let a = Tensor::randn(&[m, k], &mut rng);
        let b = Tensor::randn(&[n, k], &mut rng);
        let p = Tensor::randn(&[m, n], &mut rng); // P̃ tile: (m, b_k)
        let vb = Tensor::randn(&[n, k], &mut rng); // V block: (b_k, d)
        let ai: Vec<i8> = a.data().iter().map(|x| (x * 20.0).clamp(-127.0, 127.0) as i8).collect();
        let bi: Vec<i8> = b.data().iter().map(|x| (x * 20.0).clamp(-127.0, 127.0) as i8).collect();
        let mut c_nt = vec![0f32; m * n];
        let mut c_gemv = vec![0f32; n];
        let mut c_i8 = vec![0i32; m * n];
        let mut c_nn = vec![0f32; m * k];
        let mut sink = 0f32;
        let gemm_flops = (2 * m * n * k) as f64;
        let mut bench_kernel = |name: &str, shape: &str, flops: f64, f: &mut dyn FnMut(Backend)| {
            let mut portable_t = f64::INFINITY;
            for &bk in Backend::all() {
                let t = time_kernel(reps, flops, || f(bk));
                if bk == Backend::Portable {
                    portable_t = t;
                }
                let gops = flops / t / 1e9;
                let speedup = portable_t / t;
                micro.row(&[
                    name.into(),
                    shape.into(),
                    bk.name().into(),
                    fnum(gops, 2),
                    format!("{speedup:.2}x"),
                ]);
                micro_json.push(Json::obj(vec![
                    ("kernel", Json::str(name)),
                    ("backend", Json::str(bk.name())),
                    ("gops", Json::num(gops)),
                    ("speedup_vs_portable", Json::num(speedup)),
                ]));
            }
        };
        bench_kernel("qk_nt_f32", "(128,128)x(64,128)T", gemm_flops, &mut |bk| {
            bk.matmul_nt_into(a.data(), b.data(), &mut c_nt, m, n, k);
        });
        bench_kernel("qk_gemv_f32", "(1,128)x(64,128)T", (2 * n * k) as f64, &mut |bk| {
            bk.gemv_nt(&a.data()[..k], b.data(), &mut c_gemv, n, k);
        });
        bench_kernel("dot_f32", "(128,)x(128,)", (2 * k) as f64, &mut |bk| {
            sink += bk.dot(&a.data()[..k], &b.data()[..k]);
        });
        bench_kernel("qk_nt_i8", "(128,128)x(64,128)T", gemm_flops, &mut |bk| {
            bk.matmul_nt_i8(&ai, &bi, &mut c_i8, m, n, k);
        });
        bench_kernel("pv_nn_acc_f32", "(128,64)x(64,128)", gemm_flops, &mut |bk| {
            bk.matmul_nn_acc(p.data(), vb.data(), &mut c_nn, m, k, n, true, false);
        });
        std::hint::black_box(sink);
    }
    micro.print();
    println!("expected: fixed-order f32 kernels gain from explicit lanes; int8 gains most (madd)\n");

    let cfg = AttnConfig { bq: 128, bk: 64, causal: false, scale: None, cw: 4, row_offset: 0 };
    let mut rng = Pcg::seeded(1010);
    let s = video::generate_grid(&spec, &mut rng);
    let (nq, nk, d) = (s.q.dim(0), s.k.dim(0), s.q.dim(1));

    let dense = best_of(reps, || run_method_threads(&s, &cfg, &Method::Full, threads));
    let dense_tops = dense.tops(nq, nk, d, false) * 1e3;

    let mut table = Table::new(
        &format!("kernel speed under varying sparsity (dense FA2 line: {} GOPS cpu)", fnum(dense_tops, 1)),
        &["method", "target", "achieved sparsity", "GOPS(cpu)", "TOPS(gpu-translated)", "speedup vs dense"],
    );
    let mut sweep_json: Vec<Json> = Vec::new();
    let mut sweep_row = |table: &mut Table, m: &Method, target: String, r: &sparge::experiments::MethodRun| {
        let gops = r.tops(nq, nk, d, false) * 1e3;
        let speedup = dense.seconds / r.seconds;
        table.row(&[
            m.label(),
            target.clone(),
            fnum(r.stats.sparsity(), 3),
            fnum(gops, 1),
            fnum(r.gpu_tops(dense.seconds), 1),
            format!("{speedup:.2}x"),
        ]);
        sweep_json.push(Json::obj(vec![
            ("method", Json::str(&m.label())),
            ("target", Json::str(&target)),
            ("sparsity", Json::num(r.stats.sparsity())),
            ("gops", Json::num(gops)),
            ("speedup_vs_dense", Json::num(speedup)),
        ]));
    };
    // ours: sweep tau; both f32 (FA2) and int8 (Sage) kernels
    for &tau in &[0.99f32, 0.97, 0.95, 0.9, 0.8, 0.7] {
        for quant in [false, true] {
            let m = Method::Sparge(SpargeParams { tau, theta: 0.3, lambda: Some(-8.0), quant });
            let r = best_of(reps, || run_method_threads(&s, &cfg, &m, threads));
            sweep_row(&mut table, &m, format!("tau={tau}"), &r);
        }
    }
    // MInference sweep
    for &budget in &[0.7f64, 0.5, 0.3] {
        let m = Method::Minference { budget };
        let r = best_of(reps, || run_method_threads(&s, &cfg, &m, threads));
        sweep_row(&mut table, &m, format!("keep={budget}"), &r);
    }
    table.print();
    println!("\npaper Fig.10 shape: ours > ours+FA2 > baselines at every sparsity; all rise with sparsity");

    // -- intra-head row-parallel scaling: one head, n >= 4096 ------------
    let scale_spec = if full_scale() {
        spec
    } else {
        VideoSpec { t: 8, h: 24, w: 24, d: 128, smooth: 0.96, signal: 11.0 }
    };
    let mut rng = Pcg::seeded(1011);
    let ss = video::generate_grid(&scale_spec, &mut rng);
    let n = ss.q.dim(0);
    println!("\nrow-parallel scaling — single head, n={n}, threads 1 vs {threads}");
    let mut scaling = Table::new(
        "unified-driver row parallelism (bitwise-identical outputs)",
        &["method", "t=1 (s)", &format!("t={threads} (s)"), "speedup", "stats identical"],
    );
    for m in [
        Method::Full,
        Method::Sparge(SpargeParams { tau: 0.95, theta: 0.3, lambda: Some(-8.0), quant: false }),
    ] {
        let serial = best_of(reps, || run_method_threads(&ss, &cfg, &m, 1));
        let par = best_of(reps, || run_method_threads(&ss, &cfg, &m, threads));
        let same = serial.stats == par.stats && serial.out == par.out;
        assert!(same, "{}: parallel run diverged from serial", m.label());
        scaling.row(&[
            m.label(),
            fnum(serial.seconds, 3),
            fnum(par.seconds, 3),
            format!("{:.2}x", serial.seconds / par.seconds),
            "yes".into(),
        ]);
    }
    scaling.print();

    // -- split-KV decode scaling: one session, 1-row steps ---------------
    // run_tiled has a single query-tile row to hand out at decode, so its
    // wall-clock cannot scale with threads; the split-KV driver fans
    // contiguous KV spans of the cached keys across the pool instead
    // (S = ceil(n_kblocks / span) from the cache length, so outputs are
    // bitwise-identical at every pool size).
    let steps = 32;
    let n0 = n - steps;
    println!("\nsplit-KV decode scaling — one session, cache {n0} keys, {steps} steps, d 128");
    let mut dec = Table::new(
        "decode tokens/s by driver (dense f32 engine; prefill untimed)",
        &["pool", "split-KV off", "split-KV on", "on/off"],
    );
    let decode_rate = |pool: usize, split: KvSplit| -> f64 {
        let engine = AttnEngine::builder()
            .config(cfg)
            .execution(Execution::Pool(pool))
            .kv_split(split)
            .build();
        let mut session = engine.session();
        session.prefill(&ss.q.rows(0, n0), &ss.k.rows(0, n0), &ss.v.rows(0, n0));
        let t0 = Instant::now();
        for t in n0..n {
            session.decode(&ss.q.rows(t, t + 1), &ss.k.rows(t, t + 1), &ss.v.rows(t, t + 1));
        }
        steps as f64 / t0.elapsed().as_secs_f64()
    };
    let mut dec_json: Vec<Json> = Vec::new();
    for pool in [1usize, 2, threads.max(4)] {
        let off = decode_rate(pool, KvSplit::Off);
        let on = decode_rate(pool, KvSplit::Auto);
        dec.row(&[format!("{pool}"), fnum(off, 1), fnum(on, 1), format!("{:.2}x", on / off)]);
        dec_json.push(Json::obj(vec![
            ("pool", Json::num(pool as f64)),
            ("tok_s_split_off", Json::num(off)),
            ("tok_s_split_on", Json::num(on)),
        ]));
    }
    dec.print();
    println!("expected: the off column is flat in pool size; the on column climbs with it");

    // -- ragged-tail stragglers: one long + many short sessions ----------
    // The batched tick's worst case: one session with a deep KV cache
    // (its decode step costs ~long/short more than the others). Chunked
    // self-scheduling + the participating submitter keep the short
    // sessions from idling behind a static partition, and split-KV lets
    // leftover workers help the long session's own step. Tick p99/p50
    // spread is the straggler metric.
    let long_prefill = if full_scale() { 4096 } else { 1024 };
    let short_prefill = 128;
    let steps = 32;
    let mut ragged_specs =
        vec![AttnStreamSpec { prefill: long_prefill, decode: steps, d: 64, seed: 1700, ..Default::default() }];
    for i in 0..7u64 {
        ragged_specs.push(AttnStreamSpec { prefill: short_prefill, decode: steps, d: 64, seed: 1701 + i, ..Default::default() });
    }
    println!(
        "\nragged-tail stragglers — 1 long (cache {long_prefill}) + 7 short (cache {short_prefill}) \
         sessions, {steps} decode steps each"
    );
    let mut ragged = Table::new(
        "batched decode ticks under ragged session costs (sparge f32, split-KV auto)",
        &["pool", "tok/s", "tick p50", "tick p99", "p99/p50"],
    );
    for pool in [1usize, 2, threads.max(4)] {
        let engine = AttnEngine::builder()
            .config(AttnConfig::causal())
            .sparge(&SpargeParams { tau: 0.9, theta: 0.3, lambda: None, quant: false })
            .execution(Execution::Pool(pool))
            .kv_split(KvSplit::Auto)
            .build();
        let mut mgr = SessionManager::new(&engine, 256);
        for (i, s) in ragged_specs.iter().enumerate() {
            mgr.admit(i as u64, SeqStream::synth(s), Instant::now());
        }
        while mgr.prefilling() > 0 {
            mgr.tick();
        }
        let t0 = Instant::now();
        let mut tokens = 0usize;
        let mut ticks = Vec::new();
        while mgr.active() > 0 {
            // prefill is drained, so every active session decodes one
            // row this tick; counting sessions-per-tick credits only the
            // decode work actually done in the timed window (retirement
            // totals would include steps taken during the untimed drain)
            tokens += mgr.active();
            let tick0 = Instant::now();
            mgr.tick();
            ticks.push(tick0.elapsed().as_secs_f64());
        }
        let rate = tokens as f64 / t0.elapsed().as_secs_f64();
        ticks.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let (p50, p99) = (percentile_sorted(&ticks, 0.50), percentile_sorted(&ticks, 0.99));
        ragged.row(&[
            format!("{pool}"),
            fnum(rate, 1),
            format!("{} us", fnum(p50 * 1e6, 0)),
            format!("{} us", fnum(p99 * 1e6, 0)),
            format!("{:.2}x", p99 / p50.max(1e-12)),
        ]);
    }
    ragged.print();
    println!("expected: p99/p50 stays bounded as the pool grows — the long session no longer strands a tick");

    if json_mode {
        let doc = Json::obj(vec![
            ("bench", Json::str("fig10_kernel_speed")),
            ("seq", Json::str(label)),
            ("threads", Json::num(threads as f64)),
            ("reps", Json::num(reps as f64)),
            ("selected_backend", Json::str(Backend::select().name())),
            ("dense_gops", Json::num(dense_tops)),
            ("microkernels", Json::Arr(micro_json)),
            ("sweep", Json::Arr(sweep_json)),
            ("decode_splitkv", Json::Arr(dec_json)),
        ]);
        std::fs::write("BENCH_fig10.json", doc.dump() + "\n").expect("write BENCH_fig10.json");
        println!("\nwrote BENCH_fig10.json");
    }
}
