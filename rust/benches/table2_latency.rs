//! Table 2 / Fig. 1 reproduction: end-to-end generation latency for
//! Original (f32 dense), SageAttn (dense + INT8), SpargeAttn (sparse +
//! INT8) on the CogvideoX-proxy, Mochi-proxy, and Llama3.1-proxy stacks.
//!
//! The "model" here is the attention stack (layers × heads) plus a
//! non-attention residue modelled from the paper's own Table 2: on Mochi,
//! SageAttn lifts 1897s → 1544s, implying attention ≈ 62% of e2e at the
//! paper's quant speedup; we carry the same non-attention fraction so the
//! Amdahl shape is comparable. Expected: SpargeAttn ≈ 1.5–1.9× over
//! Original (paper: 1.64× CogvideoX, 1.83× Mochi, 1.54–1.73× Llama).
//!
//! Run: `cargo bench --bench table2_latency`

use sparge::attention::types::AttnConfig;
use sparge::experiments::{bench_reps, bench_threads, full_scale, run_method_threads, Method};
use sparge::models::{suite, Task, Workload};
use sparge::sparge::kernel::SpargeParams;
use sparge::sparge::tune::{tune_layer, CalibSample, TuneOptions};
use sparge::util::rng::Pcg;
use sparge::util::table::{fnum, Table};
use sparge::workloads::{self, QkvSample};

/// Non-attention share of end-to-end time, per the paper's Table 2 (see
/// module docs).
const NON_ATTN_FRACTION: f64 = 0.38;

fn attention_stack_seconds(samples: &[QkvSample], cfg: &AttnConfig, method: &Method) -> f64 {
    samples.iter().map(|s| run_method_threads(s, cfg, method, bench_threads()).seconds).sum()
}

fn main() {
    let scale = if full_scale() { 1 } else { 16 };
    let reps = bench_reps();
    println!("Table 2 — end-to-end generation latency (scale 1/{scale}, reps {reps})\n");

    let mut table = Table::new(
        "Original vs SageAttn vs SpargeAttn (paper Table 2 shape)",
        &["Model", "Original", "SageAttn", "SpargeAttn", "speedup", "paper speedup"],
    );
    let picks = ["CogvideoX-proxy", "Mochi-proxy", "Llama3.1-proxy"];
    let paper = ["1.64x", "1.83x", "1.73x"];
    for (name, paper_speedup) in picks.iter().zip(paper) {
        let card = suite(scale).into_iter().find(|c| c.name == *name).unwrap();
        let cfg = card.attn_config();
        // one sample per (layer, head) pair — the model's attention stack
        let n_stack = card.layers * card.heads;
        let samples: Vec<QkvSample> = (0..n_stack)
            .map(|i| {
                let mut rng = Pcg::new(202, i as u64);
                match card.workload {
                    Workload::Lm(spec) => workloads::synthetic::generate(&spec, &mut rng),
                    Workload::Grid(spec) => workloads::video::generate_grid(&spec, &mut rng),
                }
            })
            .collect();

        let tuned = tune_layer(
            &[CalibSample { q: samples[0].q.clone(), k: samples[0].k.clone(), v: samples[0].v.clone() }],
            &cfg,
            &TuneOptions { l1: card.l1, l2: card.l2, ..Default::default() },
        );

        let methods = [
            ("orig", Method::Full),
            ("sage", Method::Sparge(SpargeParams { tau: 1.0, theta: -1.0, lambda: None, quant: true })),
            ("sparge", Method::Sparge(SpargeParams { quant: true, ..tuned.params })),
        ];
        let mut times = Vec::new();
        for (_, m) in &methods {
            let mut best = f64::INFINITY;
            for _ in 0..reps {
                best = best.min(attention_stack_seconds(&samples, &cfg, m));
            }
            times.push(best);
        }
        // Amdahl: add the paper-derived non-attention residue
        let residue = times[0] * NON_ATTN_FRACTION / (1.0 - NON_ATTN_FRACTION);
        let e2e: Vec<f64> = times.iter().map(|t| t + residue).collect();
        let _ = card.task == Task::Text;
        table.row(&[
            card.name.to_string(),
            format!("{} s", fnum(e2e[0], 2)),
            format!("{} s", fnum(e2e[1], 2)),
            format!("{} s", fnum(e2e[2], 2)),
            format!("{:.2}x", e2e[0] / e2e[2]),
            paper_speedup.to_string(),
        ]);
    }
    table.print();
    println!("\nsparsity comes from the tuned stage-1+2 filters; quant path is Sage INT8.");
}
