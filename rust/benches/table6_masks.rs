//! Table 6 reproduction: sparsity decomposition — only M_g (stage 1),
//! only M_pv (stage-2 λ filter), and both — on the Llama3.1-proxy
//! Needle-in-a-Haystack workload.
//!
//! Expected shape (paper, 128K): only-M_g 51.2%, only-M_pv 27.7%,
//! combined 54% — the two filters overlap but are not redundant.
//!
//! Run: `cargo bench --bench table6_masks`

use sparge::attention::types::BlockMask;
use sparge::attention::{AttnEngine, SparsityPolicy};
use sparge::experiments::full_scale;
use sparge::models::suite;
use sparge::sparge::predict::{predict, PredictParams};
use sparge::util::rng::Pcg;
use sparge::util::table::{pct, Table};
use sparge::workloads::synthetic;

fn main() {
    let scale = if full_scale() { 1 } else { 8 };
    let card = suite(scale).into_iter().find(|c| c.name == "Llama3.1-proxy").unwrap();
    let sparge::models::Workload::Lm(spec) = card.workload else { unreachable!() };
    let cfg = card.attn_config();
    println!("Table 6 — sparsity from M_g and M_pv (NIAH-style LM workload, N={})\n", spec.n);

    let mut rng = Pcg::seeded(606);
    let s = synthetic::generate(&spec, &mut rng);
    // tune (tau, theta, lambda) under the paper's Llama bounds first — the
    // decomposition uses the *tuned* operating point, as the paper does
    let tuned = sparge::sparge::tune::tune_layer(
        &[sparge::sparge::tune::CalibSample { q: s.q.clone(), k: s.k.clone(), v: s.v.clone() }],
        &cfg,
        &sparge::sparge::tune::TuneOptions { l1: card.l1, l2: card.l2, ..Default::default() },
    );
    let (tau, theta) = (tuned.params.tau, tuned.params.theta);
    let lambda = tuned.params.lambda.unwrap_or(-5.0);
    println!("tuned operating point: tau={tau} theta={theta} lambda={lambda}\n");

    let run = |mask: &BlockMask, lam: Option<f32>| {
        AttnEngine::builder()
            .config(cfg)
            .policy(SparsityPolicy::External { mask: mask.clone(), lambda: lam })
            .build()
            .attention(&s.q, &s.k, &s.v)
            .stats
    };

    // only M_g
    let pred = predict(&s.q, &s.k, &cfg, &PredictParams { tau, theta });
    let st_mg = run(&pred.mask, None);

    // only M_pv: full stage-1 mask, λ active
    let full_mask = BlockMask::new_all(pred.mask.rows, pred.mask.cols, true);
    let st_pv = run(&full_mask, Some(lambda));

    // both
    let st_both = run(&pred.mask, Some(lambda));

    let mut table = Table::new(
        "sparsity decomposition (paper Table 6 shape)",
        &["Strategy", "only M_g", "only M_pv", "M_g + M_pv"],
    );
    table.row(&[
        "Sparsity".into(),
        pct(st_mg.sparsity()),
        pct(st_pv.sparsity()),
        pct(st_both.sparsity()),
    ]);
    table.print();
    println!("\npaper (128K): 51.2% | 27.7% | 54%");
    assert!(st_both.sparsity() >= st_mg.sparsity() - 1e-9, "combined must dominate stage 1");
}
