//! Table 5 / Table 10 / Appendix A.2 reproduction: ablation of the
//! self-similarity judge (fix-block protection).
//!
//! With the judge (θ tuned) non-coherent blocks are never compressed;
//! without it (θ = −1) every block is compressed and TopCdf can drop real
//! mass. Following A.2 we also report the *filtered* subset — the cases
//! where the judge changes the error by ≥ 0.05 — where the protection
//! effect concentrates (most of those come from Random permutation).
//!
//! Expected shape: similar mean L1 with/without on friendly orderings, a
//! mild sparsity cost for the judge, and a large L1 gap on the filtered
//! subset (paper: 0.0555 vs 0.154 on Mochi).
//!
//! Run: `cargo bench --bench table5_simjudge`

use sparge::attention::types::AttnConfig;
use sparge::attention::{AttnEngine, SparsityPolicy};
use sparge::experiments::full_scale;
use sparge::models::suite;
use sparge::sparge::hilbert::Permutation;
use sparge::sparge::kernel::SpargeParams;
use sparge::sparge::metrics::rel_l1;
use sparge::sparge::predict::{predict, PredictParams};
use sparge::util::rng::Pcg;
use sparge::util::stats::mean;
use sparge::util::table::{fnum, Table};
use sparge::workloads::video;

struct Case {
    l1_with: f64,
    l1_without: f64,
    sp_with: f64,
    sp_without: f64,
}

fn main() {
    let scale = if full_scale() { 1 } else { 16 };
    println!("Table 5/10 — self-similarity judge ablation (scale 1/{scale})\n");

    let card = suite(scale).into_iter().find(|c| c.name == "Mochi-proxy").unwrap();
    let sparge::models::Workload::Grid(spec) = card.workload else { unreachable!() };
    let cfg: AttnConfig = card.attn_config();
    let kernel_params = SpargeParams { tau: 0.9, theta: 0.45, lambda: None, quant: false };

    // cases: several seeds × several permutations (incl. Random, where the
    // judge matters most — A.2's observation)
    let mut cases = Vec::new();
    for seed in 0..6u64 {
        let mut rng = Pcg::new(505, seed);
        let sample = video::generate_grid(&spec, &mut rng);
        for perm in [Permutation::RowMajor, Permutation::HilbertCurve, Permutation::Random] {
            let ps = video::permute(&sample, &spec, perm, seed);
            let dense = AttnEngine::dense(cfg).attention(&ps.q, &ps.k, &ps.v).out;

            let pp = PredictParams { tau: kernel_params.tau, theta: kernel_params.theta };
            let with = predict(&ps.q, &ps.k, &cfg, &pp);
            let without = predict(&ps.q, &ps.k, &cfg, &PredictParams { tau: kernel_params.tau, theta: -1.0 });
            let run = |mask: &sparge::attention::BlockMask| {
                AttnEngine::builder()
                    .config(cfg)
                    .policy(SparsityPolicy::External { mask: mask.clone(), lambda: kernel_params.lambda })
                    .build()
                    .attention(&ps.q, &ps.k, &ps.v)
            };
            let r_w = run(&with.mask);
            let r_wo = run(&without.mask);
            cases.push(Case {
                l1_with: rel_l1(&r_w.out, &dense),
                l1_without: rel_l1(&r_wo.out, &dense),
                sp_with: r_w.stats.sparsity(),
                sp_without: r_wo.stats.sparsity(),
            });
        }
    }

    let filtered: Vec<&Case> = cases.iter().filter(|c| (c.l1_without - c.l1_with).abs() >= 0.05).collect();
    let mut table = Table::new(
        "impact of the self-similarity judge (paper Table 10 shape)",
        &["Metric", "w/ judge", "w/o judge", "filter w/ judge", "filter w/o judge"],
    );
    let m = |f: fn(&Case) -> f64, cs: &[&Case]| mean(&cs.iter().map(|c| f(c)).collect::<Vec<_>>());
    let all: Vec<&Case> = cases.iter().collect();
    table.row(&[
        "L1 error v".into(),
        fnum(m(|c| c.l1_with, &all), 4),
        fnum(m(|c| c.l1_without, &all), 4),
        if filtered.is_empty() { "-".into() } else { fnum(m(|c| c.l1_with, &filtered), 4) },
        if filtered.is_empty() { "-".into() } else { fnum(m(|c| c.l1_without, &filtered), 4) },
    ]);
    table.row(&[
        "Sparsity ^".into(),
        fnum(m(|c| c.sp_with, &all), 3),
        fnum(m(|c| c.sp_without, &all), 3),
        if filtered.is_empty() { "-".into() } else { fnum(m(|c| c.sp_with, &filtered), 3) },
        if filtered.is_empty() { "-".into() } else { fnum(m(|c| c.sp_without, &filtered), 3) },
    ]);
    table.print();
    println!(
        "\n{} of {} cases pass the |deltaL1| >= 0.05 filter (A.2 keeps ~2%; Random-permutation cases dominate)",
        filtered.len(),
        cases.len()
    );
    println!("paper (Mochi): w/ 0.0343/0.301, w/o 0.0365/0.305; filtered: 0.0555 vs 0.154");
}
