//! Table 1 reproduction: end-to-end metrics across text/image/video proxy
//! models for Full-Attention, MInference (×2 budgets), FlexPrefill (×2 γ),
//! and SpargeAttn (tuned per model).
//!
//! Substitutions vs the paper (DESIGN.md §3): proxy workloads replace the
//! real models; quality columns are attention-output fidelity metrics
//! computable without pretrained scorers — rel-L1 ↓ (the paper's tuning
//! metric), cosine ↑ (CLIPSIM-style alignment proxy), PSNR ↑ (VQA-style
//! fidelity proxy). Speed is measured TOPS (CPU) and GPU-translated TOPS
//! (sparsity + overhead folded into the paper's full-attention baseline).
//!
//! Expected shape: SpargeAttn reaches the highest speed at comparable or
//! better fidelity; FlexPrefill collapses on image models; MInference
//! degrades fidelity at matched sparsity.
//!
//! Run: `cargo bench --bench table1_end2end` (SPARGE_BENCH_FULL=1 for
//! paper-scale sequence lengths).

use sparge::experiments::{bench_threads, full_scale, run_method_threads, Method};
use sparge::models::{suite, Workload};
use sparge::sparge::kernel::SpargeParams;
use sparge::sparge::metrics::{cosine, psnr, rel_l1};
use sparge::sparge::tune::{tune_layer, CalibSample, TuneOptions};
use sparge::util::rng::Pcg;
use sparge::util::table::{fnum, Table};
use sparge::workloads;

fn main() {
    let scale = if full_scale() { 1 } else { 16 };
    println!("Table 1 — end-to-end metrics (scale 1/{scale}; SPARGE_BENCH_FULL=1 for paper scale)\n");

    for card in suite(scale) {
        let cfg = card.attn_config();
        let mut rng = Pcg::seeded(101);
        let sample = match card.workload {
            Workload::Lm(spec) => workloads::synthetic::generate(&spec, &mut rng),
            Workload::Grid(spec) => workloads::video::generate_grid(&spec, &mut rng),
        };

        // tune sparge under the paper's per-model bounds (Sec. 3.6)
        let tuned = tune_layer(
            &[CalibSample { q: sample.q.clone(), k: sample.k.clone(), v: sample.v.clone() }],
            &cfg,
            &TuneOptions { l1: card.l1, l2: card.l2, ..Default::default() },
        );
        let sparge_params = SpargeParams { quant: true, ..tuned.params };

        let methods = vec![
            Method::Full,
            Method::Minference { budget: 0.5 },
            Method::FlexPrefill { gamma: 0.99 },
            Method::Minference { budget: 0.7 },
            Method::FlexPrefill { gamma: 0.95 },
            Method::Sparge(sparge_params),
        ];

        let dense = run_method_threads(&sample, &cfg, &Method::Full, bench_threads());
        let (nq, nk, d) = (sample.q.dim(0), sample.k.dim(0), sample.q.dim(1));
        let mut table = Table::new(
            &format!("{} (seq {}, l1={}, l2={})", card.name, card.seq_len(), card.l1, card.l2),
            &["Attention (Sparsity)", "TOPS(cpu)", "TOPS(gpu-translated)", "rel-L1 v", "Cos ^", "PSNR ^"],
        );
        for m in &methods {
            let r = run_method_threads(&sample, &cfg, m, bench_threads());
            table.row(&[
                format!("{} ({:.2})", m.label(), r.stats.sparsity()),
                fnum(r.tops(nq, nk, d, cfg.causal) * 1e3, 2), // CPU GOPS reads better
                fnum(r.gpu_tops(dense.seconds), 1),
                fnum(rel_l1(&r.out, &dense.out), 4),
                fnum(cosine(&r.out, &dense.out), 4),
                {
                    let p = psnr(&r.out, &dense.out);
                    if p.is_finite() { fnum(p, 1) } else { "inf".into() }
                },
            ]);
        }
        table.print();
        println!();
    }
    println!("note: TOPS(cpu) column is GOPS on this CPU substrate; the gpu-translated");
    println!("column maps sparsity+overhead onto the paper's 160-TOPS full-attention baseline.");
}
