//! Table 8 (serving): continuous batching vs sequential request-level
//! scheduling on mixed prefill/decode traffic.
//!
//! Every request is an attention-session stream (seeded synthetic QKV:
//! a prompt to prefill + single-row decode steps) served by the **same**
//! shared `AttnEngine`/worker pool. The baseline drains the queue one
//! request at a time (`run_sequential`: one-shot prefill, then every
//! decode step — the old `run_one` discipline); the serving loop runs
//! the coordinator's continuous-batching scheduler (admit per tick,
//! bounded `b_q`-aligned prefill chunks, one decode row per active
//! session per tick). Reported: throughput (decode tokens/s), TTFT
//! (time from arrival to first token, queueing included) and TPOT
//! (per-output-token latency), each mean and p95.
//!
//! Continuous batching does not make the kernels faster — it reshapes
//! *waiting*: sequential TTFT grows linearly with queue position, while
//! interleaved ticks start every stream within one chunk-sized tick (at
//! the cost of a higher TPOT, since active sessions share the engine).
//!
//! Run: `cargo bench --bench table8_serving`
//! Env: `SPARGE_BENCH_THREADS` (engine pool size), `SPARGE_BENCH_FULL`
//! (paper-scale prompts).

use std::time::{Duration, Instant};

use sparge::attention::{AttnConfig, AttnEngine, Execution};
use sparge::coordinator::{
    run_sequential, AttnMode, AttnStreamSpec, BatchPolicy, Coordinator, SeqStream, ServeOptions,
};
use sparge::experiments::{bench_threads, full_scale};
use sparge::sparge::SpargeParams;
use sparge::util::stats::percentile_sorted;
use sparge::util::table::{fnum, Table};

struct Run {
    tokens_per_sec: f64,
    ttft: Vec<f64>,
    tpot: Vec<f64>,
    wall: f64,
}

fn summarize(label: &str, r: &Run, table: &mut Table) {
    let sorted = |v: &[f64]| {
        let mut s = v.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        s
    };
    let mean = |v: &[f64]| if v.is_empty() { 0.0 } else { v.iter().sum::<f64>() / v.len() as f64 };
    let (ttft, tpot) = (sorted(&r.ttft), sorted(&r.tpot));
    table.row(&[
        label.to_string(),
        fnum(r.tokens_per_sec, 1),
        format!("{} ms", fnum(mean(&r.ttft) * 1e3, 1)),
        format!("{} ms", fnum(percentile_sorted(&ttft, 0.95) * 1e3, 1)),
        format!("{} ms", fnum(mean(&r.tpot) * 1e3, 2)),
        format!("{} ms", fnum(percentile_sorted(&tpot, 0.95) * 1e3, 2)),
        format!("{} s", fnum(r.wall, 2)),
    ]);
}

fn sequential_run(opts: &ServeOptions, specs: &[AttnStreamSpec]) -> Run {
    let engine = AttnEngine::builder()
        .config(opts.cfg)
        .sparge(&opts.params)
        .execution(Execution::Pool(opts.threads))
        .build();
    let t0 = Instant::now();
    let mut ttft = Vec::new();
    let mut tpot = Vec::new();
    let mut tokens = 0usize;
    for (i, s) in specs.iter().enumerate() {
        // all requests "arrive" at t0; a queued request's TTFT includes
        // the whole head-of-line wait under request-level scheduling
        let queued = t0.elapsed().as_secs_f64();
        let r = run_sequential(&engine, i as u64, &SeqStream::synth(s));
        ttft.push(queued + r.ttft);
        tpot.extend_from_slice(&r.tpot);
        tokens += r.tokens;
    }
    let wall = t0.elapsed().as_secs_f64();
    Run { tokens_per_sec: tokens as f64 / wall, ttft, tpot, wall }
}

fn continuous_run(opts: &ServeOptions, max_batch: usize, specs: &[AttnStreamSpec]) -> Run {
    let c = Coordinator::start_kernel(
        BatchPolicy { max_batch, max_wait: Duration::from_millis(1), ..Default::default() },
        opts.clone(),
    );
    let t0 = Instant::now();
    let rxs: Vec<_> =
        specs.iter().map(|s| c.submit_stream(*s, AttnMode::Sparge).expect("submit")).collect();
    let mut ttft = Vec::new();
    let mut tpot_mean = Vec::new();
    let mut tokens = 0usize;
    for rx in rxs {
        let r = rx.recv().expect("response");
        ttft.push(r.ttft.unwrap_or(0.0));
        if let Some(t) = r.tpot {
            tpot_mean.push(t);
        }
        tokens += r.tokens;
    }
    let wall = t0.elapsed().as_secs_f64();
    c.shutdown();
    Run { tokens_per_sec: tokens as f64 / wall, ttft, tpot: tpot_mean, wall }
}

fn main() {
    let threads = bench_threads();
    let scale = if full_scale() { 4 } else { 1 };
    let opts = ServeOptions {
        chunk: 128 * scale,
        params: SpargeParams { tau: 0.9, theta: 0.3, lambda: None, quant: false },
        cfg: AttnConfig::causal(),
        threads,
    };
    // mixed traffic: short, medium, and long prompts, all decode-heavy
    // enough that interleaving matters
    let mut specs = Vec::new();
    for i in 0..12u64 {
        let prefill = [256, 512, 1024][i as usize % 3] * scale;
        specs.push(AttnStreamSpec { prefill, decode: 24, d: 64, seed: 900 + i });
    }
    println!(
        "Table 8 — serving: continuous batching vs sequential run_one \
         ({} streams, d 64, chunk {}, threads {threads})\n",
        specs.len(),
        opts.chunk
    );
    let mut table = Table::new(
        "mixed prefill/decode traffic through one shared AttnEngine",
        &["schedule", "tok/s", "TTFT mean", "TTFT p95", "TPOT mean", "TPOT p95", "wall"],
    );
    let seq = sequential_run(&opts, &specs);
    summarize("sequential (run_one)", &seq, &mut table);
    for max_batch in [4, 8] {
        let run = continuous_run(&opts, max_batch, &specs);
        summarize(&format!("continuous (max_batch {max_batch})"), &run, &mut table);
    }
    table.print();
    println!(
        "\nTTFT: arrival -> first token (queueing included). Sequential TTFT grows with queue \
         position; the continuous loop starts every stream within one chunk-sized tick."
    );
}
