//! Table 8 (serving): continuous batching vs sequential request-level
//! scheduling on mixed prefill/decode traffic.
//!
//! Every request is an attention-session stream (seeded synthetic QKV:
//! a prompt to prefill + single-row decode steps) served by the **same**
//! shared `AttnEngine`/worker pool. The baseline drains the queue one
//! request at a time (`run_sequential`: one-shot prefill, then every
//! decode step — the old `run_one` discipline); the serving loop runs
//! the coordinator's continuous-batching scheduler (admit per tick,
//! bounded `b_q`-aligned prefill chunks, one decode row per active
//! session per tick). Reported: throughput (decode tokens/s), TTFT
//! (time from arrival to first token, queueing included) and TPOT
//! (per-output-token latency), each mean and p95.
//!
//! Continuous batching does not make the kernels faster — it reshapes
//! *waiting*: sequential TTFT grows linearly with queue position, while
//! interleaved ticks start every stream within one chunk-sized tick (at
//! the cost of a higher TPOT, since active sessions share the engine).
//!
//! Run: `cargo bench --bench table8_serving`
//! Env: `SPARGE_BENCH_THREADS` (engine pool size), `SPARGE_BENCH_FULL`
//! (paper-scale prompts).

use std::time::{Duration, Instant};

use sparge::attention::{AttnConfig, AttnEngine, Execution, KvSplit};
use sparge::coordinator::{
    run_sequential, AttnMode, AttnStreamSpec, BatchPolicy, Coordinator, SeqStream, ServeOptions,
    SessionManager,
};
use sparge::experiments::{bench_threads, full_scale};
use sparge::sparge::SpargeParams;
use sparge::util::stats::percentile_sorted;
use sparge::util::table::{fnum, Table};

struct Run {
    tokens_per_sec: f64,
    ttft: Vec<f64>,
    tpot: Vec<f64>,
    wall: f64,
}

fn summarize(label: &str, r: &Run, table: &mut Table) {
    let sorted = |v: &[f64]| {
        let mut s = v.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        s
    };
    let mean = |v: &[f64]| if v.is_empty() { 0.0 } else { v.iter().sum::<f64>() / v.len() as f64 };
    let (ttft, tpot) = (sorted(&r.ttft), sorted(&r.tpot));
    table.row(&[
        label.to_string(),
        fnum(r.tokens_per_sec, 1),
        format!("{} ms", fnum(mean(&r.ttft) * 1e3, 1)),
        format!("{} ms", fnum(percentile_sorted(&ttft, 0.95) * 1e3, 1)),
        format!("{} ms", fnum(mean(&r.tpot) * 1e3, 2)),
        format!("{} ms", fnum(percentile_sorted(&tpot, 0.95) * 1e3, 2)),
        format!("{} s", fnum(r.wall, 2)),
    ]);
}

fn sequential_run(opts: &ServeOptions, specs: &[AttnStreamSpec]) -> Run {
    let engine = AttnEngine::builder()
        .config(opts.cfg)
        .sparge(&opts.params)
        .execution(Execution::Pool(opts.threads))
        .build();
    let t0 = Instant::now();
    let mut ttft = Vec::new();
    let mut tpot = Vec::new();
    let mut tokens = 0usize;
    for (i, s) in specs.iter().enumerate() {
        // all requests "arrive" at t0; a queued request's TTFT includes
        // the whole head-of-line wait under request-level scheduling
        let queued = t0.elapsed().as_secs_f64();
        let r = run_sequential(&engine, i as u64, &SeqStream::synth(s));
        ttft.push(queued + r.ttft);
        tpot.extend_from_slice(&r.tpot);
        tokens += r.tokens;
    }
    let wall = t0.elapsed().as_secs_f64();
    Run { tokens_per_sec: tokens as f64 / wall, ttft, tpot, wall }
}

fn continuous_run(opts: &ServeOptions, max_batch: usize, specs: &[AttnStreamSpec]) -> Run {
    let c = Coordinator::start_kernel(
        BatchPolicy { max_batch, max_wait: Duration::from_millis(1), ..Default::default() },
        opts.clone(),
    );
    let t0 = Instant::now();
    let rxs: Vec<_> =
        specs.iter().map(|s| c.submit_stream(*s, AttnMode::Sparge).expect("submit")).collect();
    let mut ttft = Vec::new();
    let mut tpot_mean = Vec::new();
    let mut tokens = 0usize;
    for rx in rxs {
        let r = rx.recv().expect("response");
        ttft.push(r.ttft.unwrap_or(0.0));
        if let Some(t) = r.tpot {
            tpot_mean.push(t);
        }
        tokens += r.tokens;
    }
    let wall = t0.elapsed().as_secs_f64();
    c.shutdown();
    Run { tokens_per_sec: tokens as f64 / wall, ttft, tpot: tpot_mean, wall }
}

/// Drive one batch of streams through a [`SessionManager`], prefill
/// untimed, and measure decode-phase tokens/s. Returns the rate plus the
/// per-session sparsity vector so callers can assert the metrics are
/// schedule-invariant.
fn decode_phase_run(
    opts: &ServeOptions,
    pool: usize,
    split: KvSplit,
    specs: &[AttnStreamSpec],
) -> (f64, Vec<(u64, f64)>) {
    let engine = AttnEngine::builder()
        .config(opts.cfg)
        .sparge(&opts.params)
        .execution(Execution::Pool(pool))
        .kv_split(split)
        .build();
    let mut mgr = SessionManager::new(&engine, opts.chunk);
    for (i, s) in specs.iter().enumerate() {
        mgr.admit(i as u64, SeqStream::synth(s), Instant::now());
    }
    let mut done = Vec::new();
    while mgr.prefilling() > 0 {
        done.extend(mgr.tick());
    }
    let t0 = Instant::now();
    let mut tokens = 0usize;
    while mgr.active() > 0 {
        for r in mgr.tick() {
            tokens += r.tokens;
            done.push(r);
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    done.sort_by_key(|r| r.id);
    let sparsity = done.iter().map(|r| (r.id, r.stats.sparsity())).collect();
    (tokens as f64 / secs, sparsity)
}

fn main() {
    let threads = bench_threads();
    let scale = if full_scale() { 4 } else { 1 };
    let opts = ServeOptions {
        chunk: 128 * scale,
        params: SpargeParams { tau: 0.9, theta: 0.3, lambda: None, quant: false },
        cfg: AttnConfig::causal(),
        threads,
        kv_split: KvSplit::Auto,
    };
    // mixed traffic: short, medium, and long prompts, all decode-heavy
    // enough that interleaving matters
    let mut specs = Vec::new();
    for i in 0..12u64 {
        let prefill = [256, 512, 1024][i as usize % 3] * scale;
        specs.push(AttnStreamSpec { prefill, decode: 24, d: 64, seed: 900 + i });
    }
    println!(
        "Table 8 — serving: continuous batching vs sequential run_one \
         ({} streams, d 64, chunk {}, threads {threads})\n",
        specs.len(),
        opts.chunk
    );
    let mut table = Table::new(
        "mixed prefill/decode traffic through one shared AttnEngine",
        &["schedule", "tok/s", "TTFT mean", "TTFT p95", "TPOT mean", "TPOT p95", "wall"],
    );
    let seq = sequential_run(&opts, &specs);
    summarize("sequential (run_one)", &seq, &mut table);
    for max_batch in [4, 8] {
        let run = continuous_run(&opts, max_batch, &specs);
        summarize(&format!("continuous (max_batch {max_batch})"), &run, &mut table);
    }
    table.print();
    println!(
        "\nTTFT: arrival -> first token (queueing included). Sequential TTFT grows with queue \
         position; the continuous loop starts every stream within one chunk-sized tick."
    );

    // -- decode-phase scaling: batched cross-session ticks ---------------
    // 6 concurrent streams past their prompts; every tick advances all of
    // them in one map over the pool, so tokens/s should climb with pool
    // size. Prefill is untimed; per-session sparsity must not move with
    // the schedule.
    let batch_specs: Vec<AttnStreamSpec> = (0..6u64)
        .map(|i| AttnStreamSpec { prefill: 256 * scale, decode: 48, d: 64, seed: 950 + i })
        .collect();
    println!(
        "\ndecode-phase throughput — {} concurrent streams, prefill {} (untimed), 48 tokens each",
        batch_specs.len(),
        256 * scale
    );
    let mut batch_table = Table::new(
        "batched cross-session decode (one Exec::map per tick over the shared pool)",
        &["pool", "tok/s", "vs pool 1"],
    );
    let mut baseline_rate = 0.0;
    let mut baseline_sparsity: Option<Vec<(u64, f64)>> = None;
    for pool in [1usize, 2, 4, 8] {
        let (rate, sparsity) = decode_phase_run(&opts, pool, KvSplit::Auto, &batch_specs);
        match &baseline_sparsity {
            None => {
                baseline_rate = rate;
                baseline_sparsity = Some(sparsity);
            }
            Some(b) => assert_eq!(&sparsity, b, "per-session sparsity moved with pool size {pool}"),
        }
        batch_table.row(&[format!("{pool}"), fnum(rate, 1), format!("{:.2}x", rate / baseline_rate)]);
    }
    batch_table.print();

    // -- decode-phase scaling: split-KV inside one session ---------------
    // A lone decoding stream has no cross-session parallelism to offer;
    // split-KV is what lets its 1-row steps use the pool, by fanning
    // contiguous KV spans across workers.
    let solo_spec = [AttnStreamSpec { prefill: 1024 * scale, decode: 32, d: 64, seed: 977 }];
    println!(
        "\nsingle-session decode — cache {} keys, 32 steps: split-KV on vs off per pool size",
        1024 * scale
    );
    let mut solo_table = Table::new(
        "split-KV decode (span = 4 k-blocks, S from cache length — identical bits at every pool size)",
        &["pool", "split-KV off tok/s", "split-KV on tok/s", "on/off"],
    );
    let mut solo_sparsity: Option<Vec<(u64, f64)>> = None;
    for pool in [1usize, 2, 4, 8] {
        let (off, sp_off) = decode_phase_run(&opts, pool, KvSplit::Off, &solo_spec);
        let (on, sp_on) = decode_phase_run(&opts, pool, KvSplit::Auto, &solo_spec);
        assert_eq!(sp_off, sp_on, "split-KV changed sparsity at pool {pool}");
        match &solo_sparsity {
            None => solo_sparsity = Some(sp_off),
            Some(b) => assert_eq!(&sp_off, b, "sparsity moved with pool size {pool}"),
        }
        solo_table.row(&[format!("{pool}"), fnum(off, 1), fnum(on, 1), format!("{:.2}x", on / off)]);
    }
    solo_table.print();
    println!(
        "\ndecode scaling: batched ticks scale with streams x pool; split-KV covers the lone-stream \
         tail. Sparsity metrics are asserted identical across schedules, pool sizes, and drivers."
    );
}
