//! Table 8 (serving): continuous batching vs sequential request-level
//! scheduling on mixed prefill/decode traffic.
//!
//! Every request is an attention-session stream (seeded synthetic QKV:
//! a prompt to prefill + single-row decode steps) served by the **same**
//! shared `AttnEngine`/worker pool. The baseline drains the queue one
//! request at a time (`run_sequential`: one-shot prefill, then every
//! decode step — the old `run_one` discipline); the serving loop runs
//! the coordinator's continuous-batching scheduler (admit per tick,
//! bounded `b_q`-aligned prefill chunks, one decode row per active
//! session per tick). Reported: throughput (decode tokens/s), TTFT
//! (time from arrival to first token, queueing included) and TPOT
//! (per-output-token latency), each mean and p95.
//!
//! Continuous batching does not make the kernels faster — it reshapes
//! *waiting*: sequential TTFT grows linearly with queue position, while
//! interleaved ticks start every stream within one chunk-sized tick (at
//! the cost of a higher TPOT, since active sessions share the engine).
//!
//! The decode-phase tables also report **allocations per token** (this
//! binary installs the counting allocator; a warmed-up steady-state tick
//! should sit near zero — the per-tick residue is scheduler bookkeeping,
//! never per-step attention scratch) and **p50/p99 tick latency** (the
//! straggler metric chunked self-scheduling + submitter participation
//! are aimed at).
//!
//! The **paged KV** section drives resident-session scale points
//! through a paged `SessionManager` over one fixed frame pool and
//! reports the memory plane: peak frames/bytes, prefix-reuse hit rate
//! (pairwise-duplicated prompts share their prompt frames CoW and skip
//! the duplicate prefill), evictions, and load-shed (deferred)
//! admissions once the traffic exceeds the pool.
//!
//! The **QoS** section runs mixed-priority traffic at 2x frame
//! oversubscription with a memory offload tier installed and reports
//! per-priority TTFT/TPOT p50/p99 plus the preempted / resumed /
//! overload-transition counters (and asserts zero priority inversions)
//! — the degradation-ordering half of the serving story.
//!
//! Run: `cargo bench --bench table8_serving`
//! Pass `-- --json` to also write a `BENCH_table8.json` snapshot (the
//! CI perf-trajectory artifact).
//! Env: `SPARGE_BENCH_THREADS` (engine pool size), `SPARGE_BENCH_FULL`
//! (paper-scale prompts).

use std::time::{Duration, Instant};

use sparge::attention::{AttnConfig, AttnEngine, Execution, KvSplit, MemTier, PageAllocator};
use sparge::coordinator::qos::PRIORITIES;
use sparge::coordinator::{
    run_sequential, AttnMode, AttnStreamSpec, BatchPolicy, Coordinator, RequestLimits, SeqOutcome,
    SeqStream, ServeOptions, SessionManager,
};
use sparge::experiments::{bench_threads, full_scale};
use sparge::sparge::SpargeParams;
use sparge::util::alloc::{global_allocations, CountingAlloc};
use sparge::util::json::Json;
use sparge::util::stats::percentile_sorted;
use sparge::util::table::{fnum, Table};

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

struct Run {
    tokens_per_sec: f64,
    ttft: Vec<f64>,
    tpot: Vec<f64>,
    wall: f64,
    outcomes: Outcomes,
}

/// Fault-tier outcome counters for one schedule (all zero on a healthy
/// run — the JSON schema carries them so a chaos-flagged serving
/// regression is visible in the perf-trajectory artifact too).
#[derive(Default)]
struct Outcomes {
    quarantined: u64,
    deadline_cancelled: u64,
    shed: u64,
    injected_faults: u64,
    drain_ms: f64,
}

fn summarize(label: &str, r: &Run, table: &mut Table, json: &mut Vec<Json>) {
    let sorted = |v: &[f64]| {
        let mut s = v.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        s
    };
    let mean = |v: &[f64]| if v.is_empty() { 0.0 } else { v.iter().sum::<f64>() / v.len() as f64 };
    let (ttft, tpot) = (sorted(&r.ttft), sorted(&r.tpot));
    let (ttft_mean, ttft_p95) = (mean(&r.ttft), percentile_sorted(&ttft, 0.95));
    let (tpot_mean, tpot_p95) = (mean(&r.tpot), percentile_sorted(&tpot, 0.95));
    table.row(&[
        label.to_string(),
        fnum(r.tokens_per_sec, 1),
        format!("{} ms", fnum(ttft_mean * 1e3, 1)),
        format!("{} ms", fnum(ttft_p95 * 1e3, 1)),
        format!("{} ms", fnum(tpot_mean * 1e3, 2)),
        format!("{} ms", fnum(tpot_p95 * 1e3, 2)),
        format!("{} s", fnum(r.wall, 2)),
    ]);
    json.push(Json::obj(vec![
        ("schedule", Json::str(label)),
        ("tok_s", Json::num(r.tokens_per_sec)),
        ("ttft_mean_s", Json::num(ttft_mean)),
        ("ttft_p95_s", Json::num(ttft_p95)),
        ("tpot_mean_s", Json::num(tpot_mean)),
        ("tpot_p95_s", Json::num(tpot_p95)),
        ("wall_s", Json::num(r.wall)),
        ("quarantined", Json::num(r.outcomes.quarantined as f64)),
        ("deadline_cancelled", Json::num(r.outcomes.deadline_cancelled as f64)),
        ("shed", Json::num(r.outcomes.shed as f64)),
        ("injected_faults", Json::num(r.outcomes.injected_faults as f64)),
        ("drain_ms", Json::num(r.outcomes.drain_ms)),
    ]));
}

fn sequential_run(opts: &ServeOptions, specs: &[AttnStreamSpec]) -> Run {
    let engine = AttnEngine::builder()
        .config(opts.cfg)
        .sparge(&opts.params)
        .execution(Execution::Pool(opts.threads))
        .build();
    let t0 = Instant::now();
    let mut ttft = Vec::new();
    let mut tpot = Vec::new();
    let mut tokens = 0usize;
    for (i, s) in specs.iter().enumerate() {
        // all requests "arrive" at t0; a queued request's TTFT includes
        // the whole head-of-line wait under request-level scheduling
        let queued = t0.elapsed().as_secs_f64();
        let r = run_sequential(&engine, i as u64, &SeqStream::synth(s));
        ttft.push(queued + r.ttft);
        tpot.extend_from_slice(&r.tpot);
        tokens += r.tokens;
    }
    let wall = t0.elapsed().as_secs_f64();
    Run { tokens_per_sec: tokens as f64 / wall, ttft, tpot, wall, outcomes: Outcomes::default() }
}

fn continuous_run(opts: &ServeOptions, max_batch: usize, specs: &[AttnStreamSpec]) -> Run {
    let c = Coordinator::start_kernel(
        BatchPolicy { max_batch, max_wait: Duration::from_millis(1), ..Default::default() },
        opts.clone(),
    );
    let t0 = Instant::now();
    let rxs: Vec<_> =
        specs.iter().map(|s| c.submit_stream(*s, AttnMode::Sparge).expect("submit")).collect();
    let mut ttft = Vec::new();
    let mut tpot_mean = Vec::new();
    let mut tokens = 0usize;
    for rx in rxs {
        let r = rx.recv().expect("response");
        ttft.push(r.ttft.unwrap_or(0.0));
        if let Some(t) = r.tpot {
            tpot_mean.push(t);
        }
        tokens += r.tokens;
    }
    let wall = t0.elapsed().as_secs_f64();
    // drain telemetry (drain duration, injected-fault count) is recorded
    // by the serve loop on shutdown, so snapshot after joining it
    let metrics = std::sync::Arc::clone(&c.metrics);
    c.shutdown();
    let s = metrics.snapshot();
    Run {
        tokens_per_sec: tokens as f64 / wall,
        ttft,
        tpot: tpot_mean,
        wall,
        outcomes: Outcomes {
            quarantined: s.quarantined,
            deadline_cancelled: s.deadline_cancelled,
            shed: s.shed,
            injected_faults: s.injected_faults,
            drain_ms: s.drain_duration * 1e3,
        },
    }
}

/// Decode-phase measurements for one schedule: throughput, per-session
/// sparsity (asserted schedule-invariant by callers), steady-state
/// allocations per decoded token, and tick-latency percentiles.
struct DecodePhase {
    rate: f64,
    sparsity: Vec<(u64, f64)>,
    allocs_per_token: f64,
    tick_p50: f64,
    tick_p99: f64,
}

/// Drive one batch of streams through a [`SessionManager`], prefill
/// untimed (it also warms caches, workspaces, and span plans), then
/// measure the decode phase: tokens/s, allocations/token, and per-tick
/// latency percentiles.
fn decode_phase_run(
    opts: &ServeOptions,
    pool: usize,
    split: KvSplit,
    specs: &[AttnStreamSpec],
) -> DecodePhase {
    let engine = AttnEngine::builder()
        .config(opts.cfg)
        .sparge(&opts.params)
        .execution(Execution::Pool(pool))
        .kv_split(split)
        .build();
    let mut mgr = SessionManager::new(&engine, opts.chunk);
    for (i, s) in specs.iter().enumerate() {
        mgr.admit(i as u64, SeqStream::synth(s), Instant::now());
    }
    let mut done = Vec::new();
    while mgr.prefilling() > 0 {
        done.extend(mgr.tick());
    }
    let t0 = Instant::now();
    let allocs0 = global_allocations();
    let mut tokens = 0usize;
    let mut ticks = Vec::new();
    while mgr.active() > 0 {
        // every active session is past its prompt here (prefill drained
        // above, no further admissions) and advances exactly one decode
        // row this tick — a session retires in the tick of its last
        // step. Counting sessions-per-tick credits the timed window with
        // exactly the decode work it performed; retirement totals
        // (`SeqResult::tokens`) would also include steps already taken
        // during the untimed drain and overstate tok/s.
        tokens += mgr.active();
        let tick0 = Instant::now();
        done.extend(mgr.tick());
        ticks.push(tick0.elapsed().as_secs_f64());
    }
    let secs = t0.elapsed().as_secs_f64();
    let allocs = global_allocations() - allocs0;
    ticks.sort_by(|a, b| a.partial_cmp(b).unwrap());
    done.sort_by_key(|r| r.id);
    let sparsity = done.iter().map(|r| (r.id, r.stats.sparsity())).collect();
    DecodePhase {
        rate: tokens as f64 / secs,
        sparsity,
        allocs_per_token: allocs as f64 / tokens.max(1) as f64,
        tick_p50: percentile_sorted(&ticks, 0.50),
        tick_p99: percentile_sorted(&ticks, 0.99),
    }
}

fn main() {
    let threads = bench_threads();
    let json_mode = std::env::args().any(|a| a == "--json");
    let scale = if full_scale() { 4 } else { 1 };
    let opts = ServeOptions {
        chunk: 128 * scale,
        params: SpargeParams { tau: 0.9, theta: 0.3, lambda: None, quant: false },
        cfg: AttnConfig::causal(),
        threads,
        kv_split: KvSplit::Auto,
        fault: None,
        paged: None,
    };
    // mixed traffic: short, medium, and long prompts, all decode-heavy
    // enough that interleaving matters
    let mut specs = Vec::new();
    for i in 0..12u64 {
        let prefill = [256, 512, 1024][i as usize % 3] * scale;
        specs.push(AttnStreamSpec { prefill, decode: 24, d: 64, seed: 900 + i, ..Default::default() });
    }
    println!(
        "Table 8 — serving: continuous batching vs sequential run_one \
         ({} streams, d 64, chunk {}, threads {threads})\n",
        specs.len(),
        opts.chunk
    );
    let mut table = Table::new(
        "mixed prefill/decode traffic through one shared AttnEngine",
        &["schedule", "tok/s", "TTFT mean", "TTFT p95", "TPOT mean", "TPOT p95", "wall"],
    );
    let mut mixed_json: Vec<Json> = Vec::new();
    let seq = sequential_run(&opts, &specs);
    summarize("sequential (run_one)", &seq, &mut table, &mut mixed_json);
    for max_batch in [4, 8] {
        let run = continuous_run(&opts, max_batch, &specs);
        summarize(&format!("continuous (max_batch {max_batch})"), &run, &mut table, &mut mixed_json);
    }
    table.print();
    println!(
        "\nTTFT: arrival -> first token (queueing included). Sequential TTFT grows with queue \
         position; the continuous loop starts every stream within one chunk-sized tick."
    );

    // -- decode-phase scaling: batched cross-session ticks ---------------
    // 6 concurrent streams past their prompts; every tick advances all of
    // them in one map over the pool, so tokens/s should climb with pool
    // size. Prefill is untimed; per-session sparsity must not move with
    // the schedule.
    let batch_specs: Vec<AttnStreamSpec> = (0..6u64)
        .map(|i| AttnStreamSpec { prefill: 256 * scale, decode: 48, d: 64, seed: 950 + i, ..Default::default() })
        .collect();
    println!(
        "\ndecode-phase throughput — {} concurrent streams, prefill {} (untimed), 48 tokens each",
        batch_specs.len(),
        256 * scale
    );
    let mut batch_table = Table::new(
        "batched cross-session decode (one chunk-self-scheduled fan-out per tick over the shared pool)",
        &["pool", "tok/s", "vs pool 1", "allocs/token", "tick p50", "tick p99"],
    );
    let mut baseline_rate = 0.0;
    let mut baseline_sparsity: Option<Vec<(u64, f64)>> = None;
    let mut batch_json: Vec<Json> = Vec::new();
    for pool in [1usize, 2, 4, 8] {
        let r = decode_phase_run(&opts, pool, KvSplit::Auto, &batch_specs);
        match &baseline_sparsity {
            None => {
                baseline_rate = r.rate;
                baseline_sparsity = Some(r.sparsity);
            }
            Some(b) => assert_eq!(&r.sparsity, b, "per-session sparsity moved with pool size {pool}"),
        }
        batch_table.row(&[
            format!("{pool}"),
            fnum(r.rate, 1),
            format!("{:.2}x", r.rate / baseline_rate),
            fnum(r.allocs_per_token, 2),
            format!("{} us", fnum(r.tick_p50 * 1e6, 0)),
            format!("{} us", fnum(r.tick_p99 * 1e6, 0)),
        ]);
        batch_json.push(Json::obj(vec![
            ("pool", Json::num(pool as f64)),
            ("tok_s", Json::num(r.rate)),
            ("allocs_per_token", Json::num(r.allocs_per_token)),
            ("tick_p50_s", Json::num(r.tick_p50)),
            ("tick_p99_s", Json::num(r.tick_p99)),
        ]));
    }
    batch_table.print();
    println!(
        "allocs/token: counting-allocator delta over the decode phase / tokens — per-step attention \
         scratch is workspace-recycled (asserted zero in tests/alloc_regression.rs); the residue is \
         per-tick scheduler bookkeeping."
    );

    // -- decode-phase scaling: split-KV inside one session ---------------
    // A lone decoding stream has no cross-session parallelism to offer;
    // split-KV is what lets its 1-row steps use the pool, by fanning
    // contiguous KV spans across workers.
    let solo_spec = [AttnStreamSpec { prefill: 1024 * scale, decode: 32, d: 64, seed: 977, ..Default::default() }];
    println!(
        "\nsingle-session decode — cache {} keys, 32 steps: split-KV on vs off per pool size",
        1024 * scale
    );
    let mut solo_table = Table::new(
        "split-KV decode (span = 4 k-blocks, S from cache length — identical bits at every pool size)",
        &["pool", "split-KV off tok/s", "split-KV on tok/s", "on/off", "allocs/token (on)"],
    );
    let mut solo_sparsity: Option<Vec<(u64, f64)>> = None;
    let mut solo_json: Vec<Json> = Vec::new();
    for pool in [1usize, 2, 4, 8] {
        let off = decode_phase_run(&opts, pool, KvSplit::Off, &solo_spec);
        let on = decode_phase_run(&opts, pool, KvSplit::Auto, &solo_spec);
        assert_eq!(off.sparsity, on.sparsity, "split-KV changed sparsity at pool {pool}");
        match &solo_sparsity {
            None => solo_sparsity = Some(off.sparsity),
            Some(b) => assert_eq!(&off.sparsity, b, "sparsity moved with pool size {pool}"),
        }
        solo_table.row(&[
            format!("{pool}"),
            fnum(off.rate, 1),
            fnum(on.rate, 1),
            format!("{:.2}x", on.rate / off.rate),
            fnum(on.allocs_per_token, 2),
        ]);
        solo_json.push(Json::obj(vec![
            ("pool", Json::num(pool as f64)),
            ("tok_s_split_off", Json::num(off.rate)),
            ("tok_s_split_on", Json::num(on.rate)),
            ("allocs_per_token_on", Json::num(on.allocs_per_token)),
        ]));
    }
    solo_table.print();
    println!(
        "\ndecode scaling: batched ticks scale with streams x pool; split-KV covers the lone-stream \
         tail. Sparsity metrics are asserted identical across schedules, pool sizes, and drivers."
    );

    // -- paged KV serving: the memory plane under frame pressure ----------
    // Resident-session scale points through a paged SessionManager over
    // one fixed frame pool. Prompts are duplicated pairwise and sized to
    // one whole-prompt prefill chunk, so every odd admission is a
    // prefix-registry hit (its prefill is skipped and its prompt frames
    // are shared); the pool covers exactly 4 solo sessions, so the
    // 8-session point must defer admissions (reservation-based
    // load-shedding) until earlier sessions retire and their prefixes
    // are reclaimed.
    let paged_prefill = opts.chunk; // one chunk == whole prompt => registry-eligible
    let frames_per = (paged_prefill + 24).div_ceil(opts.cfg.bk);
    let pool_frames = 4 * frames_per;
    println!(
        "\npaged KV serving — fixed pool of {pool_frames} frames ({} rows/frame), prompts \
         duplicated pairwise, prefill {paged_prefill}, 24 tokens each",
        opts.cfg.bk
    );
    let mut paged_table = Table::new(
        "paged serving memory plane (frames/bytes are pool-wide; deferred = load-shed admissions)",
        &["sessions", "tok/s (e2e)", "peak frames", "peak MB", "prefix hits", "evictions", "deferred"],
    );
    let mut paged_json: Vec<Json> = Vec::new();
    for sessions in [2usize, 4, 8] {
        let engine = AttnEngine::builder()
            .config(opts.cfg)
            .sparge(&opts.params)
            .execution(Execution::Pool(threads))
            .kv_split(KvSplit::Auto)
            .build();
        let mut mgr = SessionManager::new_paged(
            &engine,
            opts.chunk,
            PageAllocator::new(pool_frames, opts.cfg.bk, 64, 64),
        );
        let t0 = Instant::now();
        for i in 0..sessions as u64 {
            // seeds 0,0,1,1,…: each odd admission replays the previous
            // prompt and should hit the prefix registry
            let spec =
                AttnStreamSpec { prefill: paged_prefill, decode: 24, d: 64, seed: 980 + i / 2, ..Default::default() };
            mgr.admit(i, SeqStream::synth(&spec), Instant::now());
        }
        let mut done = Vec::new();
        let mut guard = 0usize;
        while mgr.active() > 0 || mgr.pending() > 0 {
            done.extend(mgr.tick());
            guard += 1;
            assert!(guard < 1_000_000, "paged serving failed to drain");
        }
        let wall = t0.elapsed().as_secs_f64();
        let tokens: usize = done.iter().map(|r| r.tokens).sum();
        let stats = mgr.page_stats().expect("paged manager");
        let peak_bytes = stats.peak_frames * stats.frame_bytes;
        let hit_rate = stats.prefix_hits as f64 / sessions as f64;
        paged_table.row(&[
            format!("{sessions}"),
            fnum(tokens as f64 / wall, 1),
            format!("{}/{}", stats.peak_frames, stats.frames),
            fnum(peak_bytes as f64 / 1e6, 2),
            format!("{} ({:.0}%)", stats.prefix_hits, hit_rate * 100.0),
            format!("{}", stats.evictions),
            format!("{}", stats.load_sheds),
        ]);
        paged_json.push(Json::obj(vec![
            ("sessions", Json::num(sessions as f64)),
            ("tok_s", Json::num(tokens as f64 / wall)),
            ("frames", Json::num(stats.frames as f64)),
            ("peak_frames", Json::num(stats.peak_frames as f64)),
            ("frame_bytes", Json::num(stats.frame_bytes as f64)),
            ("peak_bytes", Json::num(peak_bytes as f64)),
            ("prefix_hits", Json::num(stats.prefix_hits as f64)),
            ("cow_splits", Json::num(stats.cow_splits as f64)),
            ("evictions", Json::num(stats.evictions as f64)),
            ("load_sheds", Json::num(stats.load_sheds as f64)),
        ]));
    }
    paged_table.print();
    println!(
        "peak MB = peak frames x frame bytes (K + V + pooled stage-1 state per frame). Prefix hits \
         skip the duplicate prompt's prefill and share its frames; deferred admissions queue until \
         retiring sessions return frames instead of growing the pool."
    );

    // -- QoS under overload: per-priority latency at 2x oversubscription --
    // Twice as many sessions as the pool covers, priorities mixed
    // round-robin, a memory offload tier installed so preemption
    // checkpoints instead of discarding. The overload detector should
    // preempt/shed Low first: the spread between the High and Low TTFT
    // p99 *is* the QoS mechanism, and the preempted/resumed counters
    // below are the receipts. `priority_inversions` must print 0 — a
    // higher-priority stream never waits on frames a lower one holds.
    let qos_sessions = 8usize; // pool covers 4 => 2x frame oversubscription
    println!(
        "\nQoS serving — {qos_sessions} mixed-priority sessions over a {pool_frames}-frame pool \
         (2x oversubscription), prefill {paged_prefill}, 24 tokens each"
    );
    let engine = AttnEngine::builder()
        .config(opts.cfg)
        .sparge(&opts.params)
        .execution(Execution::Pool(threads))
        .kv_split(KvSplit::Auto)
        .build();
    let mut mgr = SessionManager::new_paged(
        &engine,
        opts.chunk,
        PageAllocator::new(pool_frames, opts.cfg.bk, 64, 64),
    );
    mgr.set_offload_tier(Box::new(MemTier::new()));
    let t0 = Instant::now();
    for i in 0..qos_sessions as u64 {
        let spec = AttnStreamSpec {
            prefill: paged_prefill,
            decode: 24,
            d: 64,
            seed: 990 + i, // distinct prompts: no prefix sharing softens the pressure
            ..Default::default()
        };
        mgr.admit_with(
            i,
            SeqStream::synth(&spec),
            Instant::now(),
            RequestLimits { priority: PRIORITIES[i as usize % 3], ..Default::default() },
        );
    }
    let mut done = Vec::new();
    let mut guard = 0usize;
    while mgr.active() > 0 || mgr.pending() > 0 {
        done.extend(mgr.tick());
        guard += 1;
        assert!(guard < 1_000_000, "qos serving failed to drain");
    }
    let qos_wall = t0.elapsed().as_secs_f64();
    let (preempted, resumed, to_preempting, to_shedding, inversions) = mgr.qos_counters();
    let mut qos_table = Table::new(
        "per-priority latency under overload (preemption takes the lowest resident rank first)",
        &["priority", "done", "TTFT p50", "TTFT p99", "TPOT p50", "TPOT p99"],
    );
    let mut qos_rows: Vec<Json> = Vec::new();
    for p in PRIORITIES.iter().rev() {
        // latency reservoirs cover completed streams only — a shed Low
        // stream has no first token and would deflate the percentiles
        let completed: Vec<_> = done
            .iter()
            .filter(|r| r.priority == *p && r.outcome == SeqOutcome::Completed)
            .collect();
        let mut ttft: Vec<f64> = completed.iter().map(|r| r.ttft).collect();
        let mut tpot: Vec<f64> =
            completed.iter().flat_map(|r| r.tpot.iter().copied()).collect();
        ttft.sort_by(|a, b| a.partial_cmp(b).unwrap());
        tpot.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let count = completed.len();
        // percentile_sorted asserts non-empty; an all-shed class reports 0
        let pct = |v: &[f64], q: f64| if v.is_empty() { 0.0 } else { percentile_sorted(v, q) };
        let (ttft_p50, ttft_p99) = (pct(&ttft, 0.50), pct(&ttft, 0.99));
        let (tpot_p50, tpot_p99) = (pct(&tpot, 0.50), pct(&tpot, 0.99));
        qos_table.row(&[
            p.name().to_string(),
            format!("{count}"),
            format!("{} ms", fnum(ttft_p50 * 1e3, 1)),
            format!("{} ms", fnum(ttft_p99 * 1e3, 1)),
            format!("{} ms", fnum(tpot_p50 * 1e3, 2)),
            format!("{} ms", fnum(tpot_p99 * 1e3, 2)),
        ]);
        qos_rows.push(Json::obj(vec![
            ("priority", Json::str(p.name())),
            ("done", Json::num(count as f64)),
            ("ttft_p50_s", Json::num(ttft_p50)),
            ("ttft_p99_s", Json::num(ttft_p99)),
            ("tpot_p50_s", Json::num(tpot_p50)),
            ("tpot_p99_s", Json::num(tpot_p99)),
        ]));
    }
    qos_table.print();
    println!(
        "preempted {preempted}, resumed {resumed}, overload transitions \
         {to_preempting} (-> preempting) / {to_shedding} (-> shedding), \
         priority inversions {inversions} (must be 0), wall {} s",
        fnum(qos_wall, 2)
    );
    assert_eq!(inversions, 0, "priority inversion under the bench schedule");
    let qos_json = Json::obj(vec![
        ("sessions", Json::num(qos_sessions as f64)),
        ("pool_frames", Json::num(pool_frames as f64)),
        ("oversubscription", Json::num(2.0)),
        ("wall_s", Json::num(qos_wall)),
        ("preempted", Json::num(preempted as f64)),
        ("resumed", Json::num(resumed as f64)),
        ("overload_to_preempting", Json::num(to_preempting as f64)),
        ("overload_to_shedding", Json::num(to_shedding as f64)),
        ("priority_inversions", Json::num(inversions as f64)),
        ("by_priority", Json::Arr(qos_rows)),
    ]);

    if json_mode {
        let doc = Json::obj(vec![
            ("bench", Json::str("table8_serving")),
            ("threads", Json::num(threads as f64)),
            ("scale", Json::num(scale as f64)),
            ("mixed_traffic", Json::Arr(mixed_json)),
            ("decode_phase", Json::Arr(batch_json)),
            ("solo_splitkv", Json::Arr(solo_json)),
            ("paged_serving", Json::Arr(paged_json)),
            ("qos_serving", qos_json),
        ]);
        std::fs::write("BENCH_table8.json", doc.dump() + "\n").expect("write BENCH_table8.json");
        println!("\nwrote BENCH_table8.json");
    }
}
