//! Table 11 / Fig. 9 / Fig. 11 reproduction: Needle-in-a-Haystack
//! retrieval, Full-Attention vs SpargeAttn, plus attention-level baseline
//! comparison.
//!
//! Part 1 drives the *real* trained byte-LM through the runtime artifacts
//! (requires `make artifacts`; uses `artifacts/lm_trained.spg` if the
//! serve_llm example has produced it, otherwise trains ~120 quick steps).
//! Depth × mode grid mirrors Fig. 9/11's depth sweep.
//!
//! Part 2 isolates the attention operator: retrieval-critical heavy-hitter
//! keys on the LM-proxy workload, scoring whether each method's output
//! preserves the needle rows (rel-L1 on needle rows), Sparge vs MInference
//! vs FlexPrefill at matched sparsity.
//!
//! Run: `cargo bench --bench table11_niah`

use sparge::attention::types::AttnConfig;
use sparge::coordinator::engine::{TRAIN_B, TRAIN_T};
use sparge::coordinator::{AttnMode, EngineHandle};
use sparge::experiments::{bench_threads, run_method_threads, Method};
use sparge::runtime::Manifest;
use sparge::sparge::kernel::SpargeParams;
use sparge::sparge::metrics::rel_l1;
use sparge::util::rng::Pcg;
use sparge::util::table::{fnum, Table};
use sparge::workloads::{synthetic, text, SyntheticSpec};

fn main() -> anyhow::Result<()> {
    println!("Table 11 / Fig. 9+11 — Needle-in-a-Haystack\n");
    part1_model_niah()?;
    part2_attention_level();
    Ok(())
}

fn part1_model_niah() -> anyhow::Result<()> {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        println!("[part 1 skipped: run `make artifacts` first]\n");
        return Ok(());
    }
    let engine = EngineHandle::spawn(&dir)?;
    // load (or quickly produce) trained weights
    let ckpt = dir.join("lm_trained.spg");
    if ckpt.exists() {
        let t = sparge::workloads::trace::load(&ckpt)?;
        engine.load_params(t.into_iter().next().unwrap().into_vec())?;
        println!("loaded trained weights from {}", ckpt.display());
    } else {
        println!("no checkpoint found; training 120 quick steps...");
        let mut rng = Pcg::seeded(42);
        let corpus = text::corpus_with_kv(1 << 20, &mut rng);
        for _ in 0..120 {
            let mut batch = Vec::with_capacity(TRAIN_B * TRAIN_T);
            for _ in 0..TRAIN_B {
                let start = rng.range(0, corpus.len() - TRAIN_T - 1);
                batch.extend(corpus[start..start + TRAIN_T].iter().map(|&b| b as i32));
            }
            engine.train_step(batch)?;
        }
    }

    let depths = [0.1f64, 0.35, 0.65, 0.9];
    let mut table = Table::new(
        "NIAH through the served byte-LM (236-byte context = train length)",
        &["mode", "depth 0.1", "depth 0.35", "depth 0.65", "depth 0.9", "mean acc", "mean latency (ms)"],
    );
    for mode in [AttnMode::Dense, AttnMode::Sparge] {
        let mut row = vec![mode.name().to_string()];
        let mut accs = Vec::new();
        let mut lat = 0f64;
        for (i, &depth) in depths.iter().enumerate() {
            let mut acc = 0f64;
            let reps = 3;
            for r in 0..reps {
                let mut nrng = Pcg::new(1111, (i * 10 + r) as u64);
                let inst = text::niah(236, depth, &mut nrng);
                let t0 = std::time::Instant::now();
                let out = engine.generate(&inst.prompt, inst.answer.len(), mode)?;
                lat += t0.elapsed().as_secs_f64();
                acc += text::niah_score(&out, &inst.answer);
            }
            acc /= reps as f64;
            accs.push(acc);
            row.push(fnum(acc, 2));
        }
        row.push(fnum(accs.iter().sum::<f64>() / accs.len() as f64, 3));
        row.push(fnum(lat / (depths.len() * 3) as f64 * 1e3, 0));
        table.row(&row);
    }
    table.print();
    println!("expected: sparge accuracy ≈ dense accuracy at every depth (paper: 0.863 vs 0.838 @24K)\n");
    Ok(())
}

fn part2_attention_level() {
    // needle = a burst of heavy-hitter keys mid-sequence; score = fidelity
    // of the attention output restricted to rows that attend to the needle
    let n = 16_384;
    let d = 64;
    let cfg = AttnConfig { bq: 128, bk: 64, causal: true, scale: None, cw: 4, row_offset: 0 };
    let mut rng = Pcg::seeded(2222);
    let mut s = synthetic::generate(&SyntheticSpec::lm_like(n, d), &mut rng);
    // implant the needle: 32 keys at 40% depth with a distinctive direction
    let needle_at = (n as f64 * 0.4) as usize;
    for r in needle_at..needle_at + 32 {
        for x in s.k.row_mut(r) {
            *x *= 3.0;
        }
    }

    let dense = run_method_threads(&s, &cfg, &Method::Full, bench_threads());
    let methods = [
        Method::Minference { budget: 0.5 },
        Method::FlexPrefill { gamma: 0.95 },
        Method::Sparge(SpargeParams { tau: 0.95, theta: 0.4, lambda: Some(-8.0), quant: false }),
    ];
    let mut table = Table::new(
        "attention-level needle fidelity (16K causal LM workload)",
        &["method", "sparsity", "rel-L1 (all rows)", "rel-L1 (post-needle rows)"],
    );
    table.row(&["Full-Attention".into(), "0.00".into(), "0".into(), "0".into()]);
    for m in &methods {
        let r = run_method_threads(&s, &cfg, m, bench_threads());
        let post = |t: &sparge::tensor::Tensor| t.rows(needle_at + 32, n.min(needle_at + 4096));
        table.row(&[
            m.label(),
            fnum(r.stats.sparsity(), 2),
            fnum(rel_l1(&r.out, &dense.out), 4),
            fnum(rel_l1(&post(&r.out), &post(&dense.out)), 4),
        ]);
    }
    table.print();
    println!("expected: sparge preserves post-needle rows better than baselines at equal sparsity");
}
