//! Table 3 reproduction: overhead of sparse block prediction vs full
//! attention latency across sequence lengths.
//!
//! Expected shape: overhead falls from a few percent at 8K to well under
//! 1% at 64K+ (prediction is O(N²·d/(bq·bk)) vs attention's O(N²·d)).
//!
//! Run: `cargo bench --bench table3_overhead`
//! (8K–32K by default; SPARGE_BENCH_FULL=1 adds 64K and 128K — dense
//! attention at 128K takes minutes per repetition on CPU.)

use sparge::attention::types::AttnConfig;
use sparge::attention::AttnEngine;
use sparge::experiments::{bench_reps, full_scale};
use sparge::sparge::predict::{predict, PredictParams};
use sparge::util::rng::Pcg;
use sparge::util::table::{fnum, Table};
use sparge::util::timer::time_once;
use sparge::workloads::{synthetic, SyntheticSpec};

fn main() {
    let mut lens = vec![8_192usize, 16_384, 32_768];
    if full_scale() {
        lens.push(65_536);
        lens.push(131_072);
    }
    let reps = bench_reps();
    println!("Table 3 — prediction overhead vs full attention (reps {reps})\n");

    let cfg = AttnConfig { bq: 128, bk: 64, causal: false, scale: None, cw: 4, row_offset: 0 };
    let params = PredictParams { tau: 0.95, theta: 0.4 };
    let mut table = Table::new(
        "overhead of sparse block prediction (paper Table 3 shape)",
        &["Sequence Len", "Prediction (ms)", "Full Attention (ms)", "Overhead"],
    );
    let dense = AttnEngine::dense(cfg);
    for &n in &lens {
        let mut rng = Pcg::seeded(303);
        let s = synthetic::generate(&SyntheticSpec::lm_like(n, 64), &mut rng);
        let mut t_pred = f64::INFINITY;
        let mut t_attn = f64::INFINITY;
        for _ in 0..reps {
            let (_, tp) = time_once(|| predict(&s.q, &s.k, &cfg, &params));
            t_pred = t_pred.min(tp);
            let (_, ta) = time_once(|| dense.attention(&s.q, &s.k, &s.v));
            t_attn = t_attn.min(ta);
        }
        table.row(&[
            format!("{}k", n / 1024),
            fnum(t_pred * 1e3, 3),
            fnum(t_attn * 1e3, 2),
            format!("{:.3}%", 100.0 * t_pred / t_attn),
        ]);
    }
    table.print();
    println!("\npaper: 3.78% @8k, 1.82% @16k, 0.91% @32k, 0.61% @64k, 0.52% @128k");
}
