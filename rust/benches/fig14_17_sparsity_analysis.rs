//! Fig. 14–17 reproduction: sparsity distribution on the CogvideoX-proxy
//! across model layers, denoising timesteps, input samples, and attention
//! heads.
//!
//! Simulation mapping (DESIGN.md §3): layers and heads vary in their
//! attention locality (deeper layers and some heads are more diffuse —
//! modelled by per-layer/head smooth+signal); timesteps interpolate
//! between pure noise (t=1) and structured latents (t=0), so sparsity
//! rises as denoising progresses — the paper's observation.
//!
//! Run: `cargo bench --bench fig14_17_sparsity_analysis`

use sparge::attention::types::AttnConfig;
use sparge::attention::AttnEngine;
use sparge::sparge::kernel::SpargeParams;
use sparge::tensor::Tensor;
use sparge::util::rng::Pcg;
use sparge::util::table::{fnum, Table};
use sparge::workloads::video::{self, VideoSpec};

fn sparsity_of(q: &Tensor, k: &Tensor, v: &Tensor, cfg: &AttnConfig, params: &SpargeParams) -> f64 {
    AttnEngine::sparge(*cfg, params).attention(q, k, v).stats.sparsity()
}

fn spec_for(layer: usize, head: usize) -> VideoSpec {
    // locality falls with depth; heads alternate local/diffuse (Fig. 17's
    // spread)
    let smooth = 0.97 - 0.01 * layer as f32 - 0.015 * (head % 4) as f32;
    let signal = 12.0 - 0.8 * layer as f32 - 1.2 * (head % 3) as f32;
    VideoSpec { t: 2, h: 24, w: 24, d: 64, smooth, signal }
}

fn noisy_sample(spec: &VideoSpec, t: f32, seed: u64) -> sparge::workloads::QkvSample {
    // diffusion timestep t in [0,1]: latents = (1-t)*structured + t*noise
    let mut rng = Pcg::new(1414, seed);
    let s = video::generate_grid(spec, &mut rng);
    let mut noise_rng = Pcg::new(1515, seed);
    let blend = |x: &Tensor, rng: &mut Pcg| {
        let mut out = x.clone();
        let scale = x.abs_max();
        for v in out.data_mut() {
            *v = (1.0 - t) * *v + t * rng.gauss() * scale * 0.3;
        }
        out
    };
    sparge::workloads::QkvSample { q: blend(&s.q, &mut noise_rng), k: blend(&s.k, &mut noise_rng), v: s.v }
}

fn main() {
    println!("Fig. 14-17 — sparsity analysis over the CogvideoX-proxy\n");
    let cfg = AttnConfig { bq: 128, bk: 64, causal: false, scale: None, cw: 4, row_offset: 0 };
    let params = SpargeParams { tau: 0.95, theta: 0.35, lambda: Some(-8.0), quant: false };

    // Fig. 14: layer-wise
    let mut t14 = Table::new("Fig. 14 — layer-wise sparsity", &["layer", "sparsity"]);
    for layer in 0..8 {
        let spec = spec_for(layer, 0);
        let s = noisy_sample(&spec, 0.2, layer as u64);
        t14.row(&[layer.to_string(), fnum(sparsity_of(&s.q, &s.k, &s.v, &cfg, &params), 3)]);
    }
    t14.print();

    // Fig. 15: timestep-wise (t=1 noise -> t=0 clean)
    let mut t15 = Table::new("Fig. 15 — timestep-wise sparsity (denoising 1.0 -> 0.0)", &["t", "sparsity"]);
    let spec = spec_for(2, 0);
    let mut sp_first = 0.0;
    let mut sp_last = 0.0;
    for (i, &t) in [1.0f32, 0.8, 0.6, 0.4, 0.2, 0.05].iter().enumerate() {
        let s = noisy_sample(&spec, t, 99);
        let sp = sparsity_of(&s.q, &s.k, &s.v, &cfg, &params);
        if i == 0 {
            sp_first = sp;
        }
        sp_last = sp;
        t15.row(&[fnum(t as f64, 2), fnum(sp, 3)]);
    }
    t15.print();
    assert!(sp_last > sp_first, "sparsity must increase as denoising progresses");

    // Fig. 16: sample-wise
    let mut t16 = Table::new("Fig. 16 — sample-wise sparsity", &["sample", "sparsity"]);
    for seed in 0..8u64 {
        let s = noisy_sample(&spec_for(2, 0), 0.2, 1000 + seed);
        t16.row(&[seed.to_string(), fnum(sparsity_of(&s.q, &s.k, &s.v, &cfg, &params), 3)]);
    }
    t16.print();

    // Fig. 17: head-wise
    let mut t17 = Table::new("Fig. 17 — head-wise sparsity (layer 2)", &["head", "sparsity"]);
    for head in 0..8 {
        let spec = spec_for(2, head);
        let s = noisy_sample(&spec, 0.2, 2000 + head as u64);
        t17.row(&[head.to_string(), fnum(sparsity_of(&s.q, &s.k, &s.v, &cfg, &params), 3)]);
    }
    t17.print();
    println!("\npaper observations reproduced: sparsity varies across layers & heads;");
    println!("sparsity increases as the sample timestep advances (denoises).");
}
