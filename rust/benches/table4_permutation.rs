//! Table 4 / Table 9 / Appendix A.1 reproduction: effect of token
//! permutation (Random / Rowmajor / Columnmajor / Timemajor /
//! HilbertCurve) on block self-similarity, accuracy, and sparsity, on the
//! CogvideoX-proxy and Mochi-proxy grids.
//!
//! Protocol follows A.1: hyper-parameters pre-searched per permutation
//! under l1=0.05, l2=0.06; block sizes 128 (query) / 64 (key); precision
//! vs dense FlashAttention.
//!
//! Expected shape (paper Table 9): HilbertCurve highest Sim-q/Sim-k and
//! sparsity; Random retains precision but loses nearly all sparsity.
//!
//! Run: `cargo bench --bench table4_permutation`

use sparge::attention::AttnEngine;
use sparge::experiments::full_scale;
use sparge::models::suite;
use sparge::sparge::hilbert::Permutation;
use sparge::sparge::metrics::{avg_block_similarity, rel_l1};
use sparge::sparge::tune::{tune_layer, CalibSample, TuneOptions};
use sparge::util::rng::Pcg;
use sparge::util::table::{fnum, Table};
use sparge::workloads::video;

fn main() {
    let scale = if full_scale() { 1 } else { 16 };
    println!("Table 4/9 — permutation ablation (scale 1/{scale})\n");

    for name in ["CogvideoX-proxy", "Mochi-proxy"] {
        let card = suite(scale).into_iter().find(|c| c.name == name).unwrap();
        let sparge::models::Workload::Grid(spec) = card.workload else { unreachable!() };
        let cfg = card.attn_config();
        let mut rng = Pcg::seeded(404);
        let sample = video::generate_grid(&spec, &mut rng);

        let tune_opts = TuneOptions {
            l1: 0.05,
            l2: 0.06,
            tau_grid: vec![0.98, 0.95, 0.9, 0.8],
            theta_grid: vec![0.0, 0.25, 0.45],
            lambda_grid: vec![-8.0, -5.0],
            quant: false,
        };

        let mut table = Table::new(
            &format!("{} ({} tokens, {}x{}x{})", card.name, spec.tokens(), spec.t, spec.h, spec.w),
            &["Method", "Sim-q ^", "Sim-k ^", "L1 v", "Sparsity ^"],
        );
        for perm in Permutation::all() {
            let ps = video::permute(&sample, &spec, perm, 7);
            let tuned = tune_layer(
                &[CalibSample { q: ps.q.clone(), k: ps.k.clone(), v: ps.v.clone() }],
                &cfg,
                &tune_opts,
            );
            let dense = AttnEngine::dense(cfg).attention(&ps.q, &ps.k, &ps.v).out;
            let res = AttnEngine::sparge(cfg, &tuned.params).attention(&ps.q, &ps.k, &ps.v);
            table.row(&[
                perm.name().to_string(),
                fnum(avg_block_similarity(&ps.q, cfg.bq), 3),
                fnum(avg_block_similarity(&ps.k, cfg.bk), 3),
                fnum(rel_l1(&res.out, &dense), 4),
                fnum(res.stats.sparsity(), 3),
            ]);
        }
        table.print();
        println!();
    }
    println!("paper (Mochi): Random .321/.019/.0414/.048, Rowmajor .551/.390/.0307/.363,");
    println!("              Timemajor .514/.367/.0342/.338, Hilbert .572/.479/.0389/.392");
}
