//! Table 7 reproduction: sparsity increases with sequence length under a
//! constant accuracy bound (Llama3.1-proxy, l1=0.08/l2=0.09).
//!
//! Expected shape (paper): 6.8% @8K → 26.4% @16K → 35.7% @24K →
//! 49.8% @48K → 54% @128K. Mechanism: the attention neighbourhood is
//! roughly constant in tokens, so its *share* of the sequence shrinks
//! as N grows.
//!
//! Run: `cargo bench --bench table7_seqlen` (up to 32K by default;
//! SPARGE_BENCH_FULL=1 adds 64K and 128K).

use sparge::experiments::full_scale;
use sparge::models::suite;
use sparge::sparge::tune::{tune_layer, CalibSample, TuneOptions};
use sparge::util::rng::Pcg;
use sparge::util::table::{pct, Table};
use sparge::workloads::{synthetic, SyntheticSpec};

fn main() {
    let mut lens = vec![4_096usize, 8_192, 16_384];
    if full_scale() {
        lens.push(32_768);
        lens.push(65_536);
        lens.push(131_072);
    }
    let card = suite(1).into_iter().find(|c| c.name == "Llama3.1-proxy").unwrap();
    let cfg = card.attn_config();
    println!("Table 7 — sparsity vs sequence length (constant bound l1={}, l2={})\n", card.l1, card.l2);

    let opts = TuneOptions {
        l1: card.l1,
        l2: card.l2,
        tau_grid: vec![0.98, 0.95, 0.9],
        theta_grid: vec![0.0, 0.3],
        lambda_grid: vec![-5.0],
        quant: false,
    };

    let mut header = vec!["Sequence Len".to_string()];
    let mut row = vec!["Sparsity".to_string()];
    for &n in &lens {
        let mut rng = Pcg::seeded(707);
        let s = synthetic::generate(&SyntheticSpec::lm_like(n, 64), &mut rng);
        let res = tune_layer(&[CalibSample { q: s.q, k: s.k, v: s.v }], &cfg, &opts);
        header.push(format!("{}K", n / 1024));
        row.push(pct(res.sparsity));
        let p = res.params;
        let sp = res.sparsity;
        eprintln!("  N={n}: sparsity {sp:.3} (tau={}, theta={}, L1={:.4})", p.tau, p.theta, res.l1_error);
    }
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new("sparsity grows with N (paper Table 7 shape)", &header_refs);
    table.row(&row);
    table.print();
    println!("\npaper: 6.8% @8K, 26.4% @16K, 35.7% @24K, 49.8% @48K, 54% @128K");
}
