//! Chaos property suite for the serving loop's fault tier: hundreds of
//! seeded random fault schedules (worker panics, frame exhaustion,
//! stalls, poisoned inputs) over monolithic and paged session managers,
//! asserting the graceful-degradation invariants hold under every one:
//!
//! 1. the loop always drains — no schedule wedges it;
//! 2. every admitted request terminates with **exactly one** outcome
//!    (completed / deadline-cancelled / quarantined / shed);
//! 3. no frame or prefix-registry leak: after drain the paged pool is
//!    whole (`PageAllocator::assert_all_free`) and the registry empty;
//! 4. every produced output row is finite, and every stream's output is
//!    a **bitwise prefix** of its fault-free sequential run — faults may
//!    truncate a stream, never corrupt it (stalls change no bits at
//!    all; poison is screened before it reaches a kernel);
//! 5. under burst-arrival overload of a tight pool (the QoS tier), the
//!    no-priority-inversion counter stays 0 on every seed: a request is
//!    never shed while a strictly lower-priority resident holds frames.
//!
//! Seed count comes from `SPARGE_CHAOS_SEEDS` (default 10 for local
//! runs; CI's chaos job sweeps 64 in release).

use std::sync::Once;
use std::time::Instant;

use sparge::attention::paged::PageAllocator;
use sparge::attention::{AttnConfig, AttnEngine, Execution};
use sparge::coordinator::{
    run_sequential, AttnStreamSpec, FaultPlan, Priority, RequestLimits, SeqOutcome, SeqResult,
    SeqStream, SessionManager,
};
use sparge::sparge::SpargeParams;
use sparge::util::rng::Pcg;

/// Injected worker panics unwind with a known payload; silence just
/// those so a 64-seed sweep doesn't bury real failures in noise.
fn quiet_injected_panics() {
    static HOOK: Once = Once::new();
    HOOK.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let expected = info
                .payload()
                .downcast_ref::<&str>()
                .map(|s| s.contains("injected fault"))
                .or_else(|| {
                    info.payload().downcast_ref::<String>().map(|s| s.contains("injected fault"))
                })
                .unwrap_or(false);
            if !expected {
                prev(info);
            }
        }));
    });
}

fn chaos_seeds() -> u64 {
    std::env::var("SPARGE_CHAOS_SEEDS").ok().and_then(|s| s.parse().ok()).unwrap_or(10)
}

fn engine(pool: usize) -> AttnEngine {
    let cfg = AttnConfig { bq: 8, bk: 8, causal: true, scale: None, cw: 2, row_offset: 0 };
    let params = SpargeParams { tau: 0.9, theta: 0.3, lambda: None, quant: false };
    AttnEngine::builder().config(cfg).sparge(&params).execution(Execution::Pool(pool)).build()
}

/// One seeded random workload: stream specs (prefill multiples of `bq`
/// so chunked prefill stays bitwise-faithful), per-request limits, and
/// a fault schedule over the streams' ids.
struct Schedule {
    specs: Vec<AttnStreamSpec>,
    plan: FaultPlan,
    /// Ticks to run before handing the rest to `drain()` — exercises
    /// mid-flight shutdown on some seeds and pure drain on others.
    pre_ticks: u64,
}

fn schedule(seed: u64) -> Schedule {
    let mut rng = Pcg::new(seed, 0xc4a0_5c4e_d01e_5eed);
    let n = 3 + rng.below(4) as usize; // 3..=6 streams
    let mut specs = Vec::with_capacity(n);
    for i in 0..n {
        let limits = RequestLimits {
            // deadlines are either "already expired" (0) or "never in
            // this test" (10 s) — mid-run expiry would be timing-flaky
            deadline_ms: if rng.chance(0.15) {
                Some(if rng.chance(0.5) { 0 } else { 10_000 })
            } else {
                None
            },
            token_budget: if rng.chance(0.3) { Some(1 + rng.below(4) as usize) } else { None },
            // mixed QoS classes exercise priority admission order and
            // (on tight paged pools) the preemption machinery
            priority: match rng.below(3) {
                0 => Priority::Low,
                1 => Priority::High,
                _ => Priority::Normal,
            },
        };
        specs.push(AttnStreamSpec {
            prefill: 8 * rng.below(3) as usize, // 0, 8, or 16 rows
            decode: 1 + rng.below(6) as usize,  // 1..=6 steps
            d: 16,
            seed: seed.wrapping_mul(1000).wrapping_add(i as u64),
            limits,
        });
    }
    let ids: Vec<u64> = (0..n as u64).collect();
    let plan = FaultPlan::seeded(seed, 24, &ids, 1 + rng.below(5) as usize);
    Schedule { specs, plan, pre_ticks: rng.below(6) }
}

/// Drive one manager over the schedule: admit everything, tick
/// `pre_ticks` times, then drain. Returns every terminal result.
fn run_chaos(mgr: &mut SessionManager<'_>, sched: &Schedule) -> Vec<SeqResult> {
    for (i, s) in sched.specs.iter().enumerate() {
        mgr.admit_with(i as u64, SeqStream::synth(s), Instant::now(), s.limits);
    }
    let mut done = Vec::new();
    for _ in 0..sched.pre_ticks {
        done.extend(mgr.tick());
    }
    done.extend(mgr.drain());
    done.sort_by_key(|r| r.id);
    done
}

/// The shared invariant battery: every request exactly one outcome,
/// every output finite and a bitwise prefix of its fault-free
/// sequential run.
fn assert_invariants(engine: &AttnEngine, sched: &Schedule, done: &[SeqResult], seed: u64) {
    assert_eq!(
        done.len(),
        sched.specs.len(),
        "seed {seed}: every admitted request must terminate exactly once"
    );
    for (i, r) in done.iter().enumerate() {
        assert_eq!(r.id, i as u64, "seed {seed}: duplicate or missing outcome");
        assert!(
            matches!(
                r.outcome,
                SeqOutcome::Completed
                    | SeqOutcome::DeadlineCancelled
                    | SeqOutcome::Quarantined
                    | SeqOutcome::Shed
            ),
            "seed {seed}: stream {i} has no terminal outcome"
        );
        assert!(
            r.out.data().iter().all(|x| x.is_finite()),
            "seed {seed}: stream {i} ({:?}) emitted a non-finite output row",
            r.outcome
        );
        // faults truncate, never corrupt: whatever rows were produced
        // are bitwise-identical to the fault-free sequential run
        let clean = run_sequential(engine, r.id, &SeqStream::synth(&sched.specs[i]));
        let m = r.out.data().len();
        assert!(
            m <= clean.out.data().len(),
            "seed {seed}: stream {i} produced more rows than its stream holds"
        );
        assert_eq!(
            r.out.data(),
            &clean.out.data()[..m],
            "seed {seed}: stream {i} ({:?}) diverged from its fault-free prefix",
            r.outcome
        );
        if r.outcome == SeqOutcome::Completed && sched.specs[i].limits.token_budget.is_none() {
            assert_eq!(
                r.out.data().len(),
                clean.out.data().len(),
                "seed {seed}: unbudgeted completed stream {i} is short"
            );
        }
    }
}

#[test]
fn chaos_mono_schedules_hold_invariants() {
    quiet_injected_panics();
    let engine = engine(2);
    for seed in 0..chaos_seeds() {
        let sched = schedule(seed);
        let mut mgr = SessionManager::new(&engine, 8);
        mgr.set_fault_plan(Some(sched.plan.clone()));
        let done = run_chaos(&mut mgr, &sched);
        assert_invariants(&engine, &sched, &done, seed);
        assert_eq!(mgr.active(), 0, "seed {seed}: drain left residents");
    }
}

#[test]
fn chaos_paged_schedules_hold_invariants() {
    quiet_injected_panics();
    let engine = engine(2);
    for seed in 0..chaos_seeds() {
        let sched = schedule(seed);
        let mut rng = Pcg::new(seed, 0xf4a3_e5_0f_a11);
        // pool sizes from "tight" (sheds and evictions) to "roomy"
        let frames = 4 + 2 * rng.below(8) as usize;
        let alloc = PageAllocator::new(frames, 8, 16, 16);
        let mut mgr = SessionManager::new_paged(&engine, 8, alloc);
        mgr.set_fault_plan(Some(sched.plan.clone()));
        let done = run_chaos(&mut mgr, &sched);
        assert_invariants(&engine, &sched, &done, seed);
        assert_eq!(mgr.active(), 0, "seed {seed}: drain left residents");
        assert_eq!(mgr.pending(), 0, "seed {seed}: drain left queued streams");
        assert_eq!(mgr.prefix_entries(), 0, "seed {seed}: drain left registry entries");
        // drain() already ran assert_all_free; re-check the counter here
        // so a leak shows up with the seed attached
        let stats = mgr.page_stats().expect("paged manager");
        assert_eq!(stats.frames_in_use, 0, "seed {seed}: frame leak after drain");
        let (_, _, _, _, inversions) = mgr.qos_counters();
        assert_eq!(inversions, 0, "seed {seed}: priority inversion under faults");
        mgr.assert_frames_all_free();
    }
}

#[test]
fn chaos_overload_bursts_hold_qos_invariants() {
    // The QoS tier under burst-arrival overload: a deliberately tight
    // pool (~2x oversubscribed once the bursts land) drives the
    // hysteresis detector through Preempting/Shedding, and every seed
    // must still satisfy: exactly one terminal outcome per arrival,
    // survivors bitwise-faithful to their fault-free sequential run,
    // zero priority inversions, and a whole pool after drain.
    quiet_injected_panics();
    let engine = engine(2);
    for seed in 0..chaos_seeds() {
        let mut rng = Pcg::new(seed, 0xb025_7d01_ce5e_ed03);
        let plan = FaultPlan::default().with_bursts(FaultPlan::seeded_bursts(seed, 10, 3, 3));
        let arrivals: u32 = plan.bursts().iter().map(|&(_, c)| c).sum();
        let frames = 4 + rng.below(3) as usize;
        let alloc = PageAllocator::new(frames, 8, 16, 16);
        let mut mgr = SessionManager::new_paged(&engine, 8, alloc);
        let n = 2 + arrivals as usize;
        let mut specs = Vec::with_capacity(n);
        for i in 0..n {
            let limits = RequestLimits {
                priority: match rng.below(3) {
                    0 => Priority::Low,
                    1 => Priority::High,
                    _ => Priority::Normal,
                },
                ..Default::default()
            };
            specs.push(AttnStreamSpec {
                prefill: 8 + 8 * rng.below(2) as usize, // 8 or 16 rows
                decode: 1 + rng.below(6) as usize,      // 1..=6 steps
                d: 16,
                seed: seed.wrapping_mul(4096).wrapping_add(i as u64),
                limits,
            });
        }
        let sched = Schedule { specs, plan, pre_ticks: 0 };
        // two base residents up front; the rest arrive mid-serve at
        // their scheduled burst ticks
        let mut next = 0usize;
        let mut done = Vec::new();
        for _ in 0..2 {
            let s = &sched.specs[next];
            mgr.admit_with(next as u64, SeqStream::synth(s), Instant::now(), s.limits);
            next += 1;
        }
        for tick in 0..10u64 {
            for _ in 0..sched.plan.burst_at(tick) {
                let s = &sched.specs[next];
                mgr.admit_with(next as u64, SeqStream::synth(s), Instant::now(), s.limits);
                next += 1;
            }
            done.extend(mgr.tick());
        }
        assert_eq!(next, sched.specs.len(), "seed {seed}: burst schedule under-delivered");
        done.extend(mgr.drain());
        done.sort_by_key(|r| r.id);
        assert_invariants(&engine, &sched, &done, seed);
        let (_, _, _, _, inversions) = mgr.qos_counters();
        assert_eq!(inversions, 0, "seed {seed}: priority inversion under overload");
        assert_eq!(mgr.active(), 0, "seed {seed}: drain left residents");
        assert_eq!(mgr.pending(), 0, "seed {seed}: drain left queued streams");
        assert_eq!(mgr.prefix_entries(), 0, "seed {seed}: drain left registry entries");
        mgr.assert_frames_all_free();
    }
}

#[test]
fn chaos_fault_free_schedules_complete_everything() {
    // The same seeded workloads with NO plan installed: every stream
    // without an already-expired deadline completes — recovery machinery
    // at rest must be invisible.
    let engine = engine(2);
    for seed in 0..chaos_seeds().min(16) {
        let sched = schedule(seed);
        let mut mgr = SessionManager::new(&engine, 8);
        let done = run_chaos(&mut mgr, &sched);
        assert_invariants(&engine, &sched, &done, seed);
        assert_eq!(mgr.faults_injected(), 0, "seed {seed}: no plan, no injections");
        for (i, r) in done.iter().enumerate() {
            let expired = sched.specs[i].limits.deadline_ms == Some(0);
            if !expired {
                assert_eq!(
                    r.outcome,
                    SeqOutcome::Completed,
                    "seed {seed}: stream {i} failed without any fault installed"
                );
            }
        }
    }
}

#[test]
fn chaos_survivors_match_fault_free_run_bitwise() {
    // The sharpest determinism claim: for streams that complete in BOTH
    // the faulted and fault-free runs of the same schedule, the outputs
    // and stats are bitwise-identical — other streams' panics, stalls,
    // exhaustion, and poison never leak into a survivor.
    quiet_injected_panics();
    let engine = engine(2);
    for seed in 0..chaos_seeds() {
        let sched = schedule(seed);
        let run = |plan: Option<FaultPlan>| {
            let mut mgr = SessionManager::new(&engine, 8);
            mgr.set_fault_plan(plan);
            run_chaos(&mut mgr, &sched)
        };
        let clean = run(None);
        let faulted = run(Some(sched.plan.clone()));
        assert_eq!(clean.len(), faulted.len());
        for (c, f) in clean.iter().zip(&faulted) {
            if c.outcome == SeqOutcome::Completed && f.outcome == SeqOutcome::Completed {
                assert_eq!(f.out, c.out, "seed {seed}: survivor {} diverged", c.id);
                assert_eq!(f.stats, c.stats, "seed {seed}: survivor {} stats diverged", c.id);
                assert_eq!(f.tokens, c.tokens);
            }
        }
    }
}
