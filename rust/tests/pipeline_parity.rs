//! Golden parity suite for the unified tiled-attention pipeline.
//!
//! The pre-refactor engines each carried their own q-block × k-block loop
//! (dense flash, sparge f32, sparge quant, baselines-through-the-kernel).
//! Those loops are reproduced here, verbatim, as *reference*
//! implementations built from the same public tile/score primitives; the
//! unified driver must match them **bitwise** (stronger than the 1e-6
//! budget) and report byte-identical `SkipStats`, for random shapes,
//! masks, and parameters — and the parallel-row driver must be bitwise
//! equal to `threads = 1` for every backend.

use sparge::attention::types::{AttnConfig, BlockMask, SkipStats};
use sparge::attention::{score_block, AttnEngine, Execution, FlashTile, Precision, SparsityPolicy};
use sparge::baselines;
use sparge::sparge::kernel::SpargeParams;
use sparge::tensor::microkernel::Backend;
use sparge::tensor::quant::{self, QuantBlock};
use sparge::tensor::Tensor;
use sparge::util::prop::{assert_allclose, Cases};
use sparge::util::rng::Pcg;

/// Dense engine one-shot (the old `attention_flash_stats`).
fn engine_dense(q: &Tensor, k: &Tensor, v: &Tensor, cfg: &AttnConfig) -> (Tensor, SkipStats) {
    let r = AttnEngine::dense(*cfg).attention(q, k, v);
    (r.out, r.stats)
}

/// External-mask engine one-shot (the old `sparse_flash`), with execution.
fn engine_masked(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    mask: &BlockMask,
    cfg: &AttnConfig,
    params: &SpargeParams,
    exec: Execution,
) -> (Tensor, SkipStats) {
    let engine = AttnEngine::builder()
        .config(*cfg)
        .precision(if params.quant { Precision::Int8 } else { Precision::F32 })
        .policy(SparsityPolicy::External { mask: mask.clone(), lambda: params.lambda })
        .execution(exec)
        .build();
    let r = engine.attention(q, k, v);
    (r.out, r.stats)
}

// ---------------------------------------------------------------------
// Reference implementations: the pre-refactor loops, kept verbatim.
// ---------------------------------------------------------------------

/// Pre-refactor `attention_flash_stats`: the dense tiled loop.
fn reference_flash_stats(q: &Tensor, k: &Tensor, v: &Tensor, cfg: &AttnConfig) -> (Tensor, SkipStats) {
    let n = q.dim(0);
    let nk = k.dim(0);
    let scale = cfg.scale_for(q.dim(1));
    let mut out = Tensor::zeros(&[n, v.dim(1)]);
    let mut stats = SkipStats { cw: cfg.cw, ..Default::default() };
    let mut sbuf = vec![0f32; cfg.bq * cfg.bk];

    let mut q0 = 0;
    while q0 < n {
        let q1 = (q0 + cfg.bq).min(n);
        let mut tile = FlashTile::new(q1 - q0, v.dim(1), cfg.bk);
        let mut k0 = 0;
        while k0 < nk {
            let k1 = (k0 + cfg.bk).min(nk);
            if cfg.causal && k0 > q1 - 1 {
                break;
            }
            stats.qk_total += 1;
            stats.pv_total += 1;
            score_block(q, k, q0, q1, k0, k1, 0, scale, cfg.causal, &mut sbuf);
            tile.ingest(
                &sbuf[..(q1 - q0) * (k1 - k0)],
                k1 - k0,
                &v.data()[k0 * v.dim(1)..k1 * v.dim(1)],
                None,
                cfg.cw,
                &mut stats,
                true, // pre-refactor loops always took the zero-skip branch
                Backend::select(),
            );
            k0 = k1;
        }
        out.data_mut()[q0 * v.dim(1)..q1 * v.dim(1)].copy_from_slice(&tile.finalize());
        q0 = q1;
    }
    (out, stats)
}

/// Pre-refactor `sparse_flash_f32`: the masked tiled loop with λ.
fn reference_sparse_f32(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    mask: &BlockMask,
    cfg: &AttnConfig,
    lambda: Option<f32>,
) -> (Tensor, SkipStats) {
    let n = q.dim(0);
    let nk = k.dim(0);
    let dv = v.dim(1);
    let scale = cfg.scale_for(q.dim(1));
    let mut out = Tensor::zeros(&[n, dv]);
    let mut stats = SkipStats { cw: cfg.cw, ..Default::default() };
    let mut sbuf = vec![0f32; cfg.bq * cfg.bk];

    for bi in 0..mask.rows {
        let q0 = bi * cfg.bq;
        let q1 = (q0 + cfg.bq).min(n);
        let mut tile = FlashTile::new(q1 - q0, dv, cfg.bk);
        for bj in 0..mask.cols {
            let k0 = bj * cfg.bk;
            let k1 = (k0 + cfg.bk).min(nk);
            if cfg.causal && k0 > q1 - 1 {
                break;
            }
            stats.qk_total += 1;
            stats.pv_total += 1;
            if !mask.get(bi, bj) {
                stats.qk_skipped += 1;
                stats.pv_skipped += 1;
                continue;
            }
            score_block(q, k, q0, q1, k0, k1, 0, scale, cfg.causal, &mut sbuf);
            let vb = &v.data()[k0 * dv..k1 * dv];
            tile.ingest(
                &sbuf[..(q1 - q0) * (k1 - k0)],
                k1 - k0,
                vb,
                lambda,
                cfg.cw,
                &mut stats,
                true,
                Backend::select(),
            );
        }
        out.data_mut()[q0 * dv..q1 * dv].copy_from_slice(&tile.finalize());
    }
    (out, stats)
}

/// Pre-refactor `sparse_flash_quant`: INT8 dequant scoring with inline
/// causal masking, pre-quantizing *all* K blocks (the old behavior the
/// causal-domain bound now avoids — outputs must be unchanged by it).
fn reference_sparse_quant(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    mask: &BlockMask,
    cfg: &AttnConfig,
    lambda: Option<f32>,
) -> (Tensor, SkipStats) {
    let n = q.dim(0);
    let dv = v.dim(1);
    let scale = cfg.scale_for(q.dim(1));

    let kmean = quant::channel_mean(k);
    let ksm = quant::smooth(k, &kmean);
    let qb: Vec<QuantBlock> = quant::quantize_blocks(q, cfg.bq);
    let kb: Vec<QuantBlock> = quant::quantize_blocks(&ksm, cfg.bk);

    let mut out = Tensor::zeros(&[n, dv]);
    let mut stats = SkipStats { cw: cfg.cw, ..Default::default() };
    let mut sbuf = vec![0f32; cfg.bq * cfg.bk];

    for (bi, qblk) in qb.iter().enumerate() {
        let q0 = bi * cfg.bq;
        let q1 = q0 + qblk.rows;
        let mut tile = FlashTile::new(qblk.rows, dv, cfg.bk);
        for (bj, kblk) in kb.iter().enumerate() {
            let k0 = bj * cfg.bk;
            let k1 = k0 + kblk.rows;
            if cfg.causal && k0 > q1 - 1 {
                break;
            }
            stats.qk_total += 1;
            stats.pv_total += 1;
            if !mask.get(bi, bj) {
                stats.qk_skipped += 1;
                stats.pv_skipped += 1;
                continue;
            }
            let sb = &mut sbuf[..qblk.rows * kblk.rows];
            quant::qk_dequant(qblk, kblk, scale, sb);
            if cfg.causal {
                for i in 0..qblk.rows {
                    let gi = q0 + i;
                    for j in 0..kblk.rows {
                        if k0 + j > gi {
                            sb[i * kblk.rows + j] = f32::NEG_INFINITY;
                        }
                    }
                }
            }
            tile.ingest(
                sb,
                kblk.rows,
                &v.data()[k0 * dv..k1 * dv],
                lambda,
                cfg.cw,
                &mut stats,
                true,
                Backend::select(),
            );
        }
        out.data_mut()[q0 * dv..q1 * dv].copy_from_slice(&tile.finalize());
    }
    (out, stats)
}

// ---------------------------------------------------------------------
// Helpers
// ---------------------------------------------------------------------

fn random_mask(rng: &mut Pcg, tm: usize, tn: usize, density: f64) -> BlockMask {
    let mut mask = BlockMask::new_all(tm, tn, false);
    for i in 0..tm {
        mask.set(i, rng.range(0, tn), true);
        for j in 0..tn {
            if rng.chance(density) {
                mask.set(i, j, true);
            }
        }
    }
    mask
}

fn check_identical(
    label: &str,
    got: &(Tensor, SkipStats),
    want: &(Tensor, SkipStats),
) -> Result<(), String> {
    if got.1 != want.1 {
        return Err(format!("{label}: SkipStats diverge: {:?} vs {:?}", got.1, want.1));
    }
    if got.0 != want.0 {
        return Err(format!("{label}: output not bitwise equal to the pre-refactor loop"));
    }
    // the 1e-6 budget the refactor was specified against (implied by
    // bitwise equality; kept as an explicit, independent check)
    assert_allclose(got.0.data(), want.0.data(), 1e-6, 1e-6, label)
}

fn random_cfg(rng: &mut Pcg) -> AttnConfig {
    AttnConfig {
        bq: rng.range(1, 24),
        bk: rng.range(1, 24),
        causal: rng.chance(0.5),
        scale: None,
        cw: rng.range(1, 5),
        row_offset: 0,
    }
}

// ---------------------------------------------------------------------
// Parity: unified driver vs pre-refactor loops
// ---------------------------------------------------------------------

#[test]
fn dense_flash_parity() {
    Cases::standard(9101).check(|rng| {
        let nq = rng.range(1, 90);
        let nk = if rng.chance(0.3) { rng.range(1, 90) } else { nq };
        let d = [4, 8, 16, 32][rng.range(0, 4)];
        let mut cfg = random_cfg(rng);
        // causal attention assumes nq == nk in this codebase
        if nq != nk {
            cfg.causal = false;
        }
        let q = Tensor::randn(&[nq, d], rng);
        let k = Tensor::randn(&[nk, d], rng);
        let v = Tensor::randn(&[nk, d], rng);
        let got = engine_dense(&q, &k, &v, &cfg);
        let want = reference_flash_stats(&q, &k, &v, &cfg);
        check_identical("dense-flash", &got, &want)
    });
}

#[test]
fn sparge_f32_parity() {
    Cases::standard(9102).check(|rng| {
        let n = rng.range(4, 96);
        let d = 8;
        let cfg = random_cfg(rng);
        let q = Tensor::randn(&[n, d], rng);
        let k = Tensor::randn(&[n, d], rng);
        let v = Tensor::randn(&[n, d], rng);
        let mask = random_mask(rng, cfg.n_qblocks(n), cfg.n_kblocks(n), 0.6);
        let lambda = if rng.chance(0.5) { Some(-(rng.f32() * 10.0) - 0.5) } else { None };
        let params = SpargeParams { tau: 1.0, theta: -1.0, lambda, quant: false };
        let got = engine_masked(&q, &k, &v, &mask, &cfg, &params, Execution::Inline);
        let want = reference_sparse_f32(&q, &k, &v, &mask, &cfg, lambda);
        check_identical("sparge-f32", &got, &want)
    });
}

#[test]
fn sparge_quant_parity() {
    Cases::standard(9103).check(|rng| {
        let n = rng.range(4, 96);
        let d = 16;
        let cfg = random_cfg(rng);
        let q = Tensor::randn(&[n, d], rng);
        let k = Tensor::randn(&[n, d], rng);
        let v = Tensor::randn(&[n, d], rng);
        let mask = random_mask(rng, cfg.n_qblocks(n), cfg.n_kblocks(n), 0.6);
        let lambda = if rng.chance(0.5) { Some(-(rng.f32() * 10.0) - 0.5) } else { None };
        let params = SpargeParams { tau: 1.0, theta: -1.0, lambda, quant: true };
        let got = engine_masked(&q, &k, &v, &mask, &cfg, &params, Execution::Inline);
        let want = reference_sparse_quant(&q, &k, &v, &mask, &cfg, lambda);
        check_identical("sparge-quant", &got, &want)
    });
}

#[test]
fn baseline_mask_parity() {
    Cases::standard(9104).check(|rng| {
        let n = rng.range(32, 128);
        let d = 8;
        let cfg = AttnConfig { bq: 16, bk: 16, causal: rng.chance(0.5), scale: None, cw: 2, row_offset: 0 };
        let q = Tensor::randn(&[n, d], rng);
        let k = Tensor::randn(&[n, d], rng);
        let v = Tensor::randn(&[n, d], rng);
        let masks = [
            baselines::minference_mask(&q, &k, &cfg, 0.5),
            baselines::flexprefill_mask(&q, &k, &cfg, 0.9),
            baselines::sliding_window_mask(n, n, &cfg, 1, 3),
        ];
        let params = SpargeParams { tau: 1.0, theta: -1.0, lambda: None, quant: false };
        for (mi, mask) in masks.iter().enumerate() {
            let got = engine_masked(&q, &k, &v, mask, &cfg, &params, Execution::Inline);
            let want = reference_sparse_f32(&q, &k, &v, mask, &cfg, None);
            check_identical(&format!("baseline-{mi}"), &got, &want)?;
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Determinism: parallel rows are bitwise equal to serial, all backends
// ---------------------------------------------------------------------

#[test]
fn row_parallel_bitwise_determinism_all_backends() {
    Cases::standard(9105).check(|rng| {
        let n = rng.range(8, 160);
        let d = 16;
        let cfg = random_cfg(rng);
        let q = Tensor::randn(&[n, d], rng);
        let k = Tensor::randn(&[n, d], rng);
        let v = Tensor::randn(&[n, d], rng);
        let mask = random_mask(rng, cfg.n_qblocks(n), cfg.n_kblocks(n), 0.6);
        let threads = [2, 3, 8][rng.range(0, 3)];

        // dense flash: inline vs scoped threads vs persistent pool
        let (o1, s1) = engine_dense(&q, &k, &v, &cfg);
        for exec in [Execution::Threads(threads), Execution::Pool(threads)] {
            let engine = AttnEngine::builder().config(cfg).execution(exec).build();
            let r = engine.attention(&q, &k, &v);
            if o1 != r.out || s1 != r.stats {
                return Err(format!("dense flash diverges at {exec:?}"));
            }
        }

        // sparge f32 + quant, with and without λ
        for quant in [false, true] {
            for lambda in [None, Some(-4.0f32)] {
                let params = SpargeParams { tau: 1.0, theta: -1.0, lambda, quant };
                let (o1, s1) = engine_masked(&q, &k, &v, &mask, &cfg, &params, Execution::Inline);
                for exec in [Execution::Threads(threads), Execution::Pool(threads)] {
                    let (ot, st) = engine_masked(&q, &k, &v, &mask, &cfg, &params, exec);
                    if o1 != ot {
                        return Err(format!("quant={quant} λ={lambda:?} output diverges at {exec:?}"));
                    }
                    if s1 != st {
                        return Err(format!("quant={quant} λ={lambda:?} stats diverge at {exec:?}"));
                    }
                }
            }
        }
        Ok(())
    });
}
