//! Integration over the serving coordinator: engine actor, batcher,
//! scheduler, metrics, and the TCP JSON-lines server. Requires artifacts
//! (no-ops with a notice otherwise).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use sparge::coordinator::{AttnMode, BatchPolicy, Coordinator, EngineHandle, ServeOptions};
use sparge::runtime::Manifest;
use sparge::util::json::Json;

fn coordinator() -> Option<Arc<Coordinator>> {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("[skipped: no artifacts — run `make artifacts`]");
        return None;
    }
    let engine = EngineHandle::spawn(&dir).expect("engine");
    Some(Arc::new(Coordinator::start(engine, BatchPolicy::default())))
}

#[test]
fn generate_roundtrip_both_modes() {
    let Some(c) = coordinator() else { return };
    for mode in [AttnMode::Dense, AttnMode::Sparge] {
        let resp = c.generate(b"the sparse attention ".to_vec(), 4, mode).unwrap();
        assert_eq!(resp.output.len(), 4, "mode {}", mode.name());
        assert!(resp.latency > 0.0);
        assert_eq!(resp.mode, mode);
    }
    let snap = c.metrics.snapshot();
    assert_eq!(snap.requests, 2);
    assert_eq!(snap.errors, 0);
    assert_eq!(snap.tokens_out, 8);
}

#[test]
fn concurrent_burst_is_fully_served() {
    let Some(c) = coordinator() else { return };
    let mut rxs = Vec::new();
    for i in 0..6 {
        let prompt = format!("request number {i} ");
        rxs.push(c.submit(prompt.into_bytes(), 2, AttnMode::Dense).unwrap());
    }
    let mut ids = Vec::new();
    for rx in rxs {
        let resp = rx.recv().unwrap();
        assert_eq!(resp.output.len(), 2);
        ids.push(resp.id);
    }
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), 6, "duplicate or lost responses");
}

#[test]
fn engine_scoring_and_params_roundtrip() {
    let Some(c) = coordinator() else { return };
    let engine = c.engine().expect("model engine");
    let nll = engine.score_nll(b"the attention is sparse and the model is fast. ", AttnMode::Dense).unwrap();
    assert!(nll.is_finite() && nll > 0.0);
    // params roundtrip
    let params = engine.get_params().unwrap();
    engine.load_params(params.clone()).unwrap();
    assert!(engine.load_params(vec![0.0; 3]).is_err(), "wrong size must fail");
}

#[test]
fn tcp_server_json_protocol() {
    let Some(c) = coordinator() else { return };
    // bind an ephemeral port, serve a single connection in a thread
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let c2 = Arc::clone(&c);
    let server = std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        sparge::coordinator::server::handle_conn(&c2, stream).unwrap();
    });

    let mut client = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(client.try_clone().unwrap());
    let mut ask = |req: &str| -> Json {
        client.write_all(req.as_bytes()).unwrap();
        client.write_all(b"\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        Json::parse(line.trim()).unwrap()
    };

    let pong = ask(r#"{"op":"ping"}"#);
    assert_eq!(pong.get("ok"), Some(&Json::Bool(true)));

    let gen = ask(r#"{"op":"generate","prompt":"hello attention ","max_new":3,"mode":"dense"}"#);
    assert!(!gen.get("output").unwrap().as_str().unwrap().is_empty());
    assert!(gen.get("latency_ms").unwrap().as_f64().unwrap() > 0.0);

    let probe = ask(r#"{"op":"attn","n":256,"d":32,"seed":7,"tau":0.9,"threads":2}"#);
    let sparsity = probe.get("sparsity").unwrap().as_f64().unwrap();
    assert!((0.0..=1.0).contains(&sparsity));
    assert_eq!(probe.get("threads").unwrap().as_usize().unwrap(), 2);

    let stats = ask(r#"{"op":"stats"}"#);
    assert!(stats.get("requests").unwrap().as_f64().unwrap() >= 1.0);
    assert!(stats.get("sparse_requests").unwrap().as_f64().unwrap() >= 1.0);
    assert!(stats.get("mean_sparsity").unwrap().as_f64().is_some());

    let err = ask(r#"{"op":"nonsense"}"#);
    assert!(err.get("error").is_some());

    let bad = ask("this is not json");
    assert!(bad.get("error").is_some());

    drop(client);
    drop(reader);
    server.join().unwrap();
}

#[test]
fn connection_hardening_timeouts_and_structured_read_errors() {
    // No artifact gate: a kernel-only coordinator exercises the server's
    // connection hardening. `handle_conn` must (a) arm read/write
    // timeouts on the accepted socket, (b) answer malformed JSON with a
    // structured {"error": ...} line, and (c) answer a line that fails
    // to *read* (invalid UTF-8) with a structured error before closing —
    // never a silent drop.
    let opts = ServeOptions {
        chunk: 32,
        params: sparge::sparge::SpargeParams { tau: 0.9, theta: 0.3, lambda: None, quant: false },
        cfg: sparge::attention::AttnConfig {
            bq: 16,
            bk: 8,
            causal: true,
            scale: None,
            cw: 2,
            row_offset: 0,
        },
        threads: 1,
        kv_split: sparge::attention::KvSplit::Auto,
        fault: None,
        paged: None,
    };
    let c = Arc::new(Coordinator::start_kernel(BatchPolicy::default(), opts));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let c2 = Arc::clone(&c);
    let server = std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        // a try_clone dups the fd but shares the socket, so the timeouts
        // handle_conn arms are observable on the probe after it returns
        let probe = stream.try_clone().unwrap();
        let r = sparge::coordinator::server::handle_conn(&c2, stream);
        assert_eq!(
            probe.read_timeout().unwrap(),
            Some(sparge::coordinator::server::CONN_READ_TIMEOUT),
            "handle_conn must arm the read timeout"
        );
        assert_eq!(
            probe.write_timeout().unwrap(),
            Some(sparge::coordinator::server::CONN_WRITE_TIMEOUT),
            "handle_conn must arm the write timeout"
        );
        assert!(r.is_err(), "an unreadable line must end the connection with an error");
    });

    let mut client = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(client.try_clone().unwrap());
    let mut ask = |req: &[u8]| -> Json {
        client.write_all(req).unwrap();
        client.write_all(b"\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        Json::parse(line.trim()).unwrap()
    };

    // sanity: the connection serves a valid op first
    let pong = ask(br#"{"op":"ping"}"#);
    assert_eq!(pong.get("ok"), Some(&Json::Bool(true)));

    // malformed JSON: structured error, connection stays open
    let bad = ask(b"this is not json");
    assert!(
        bad.get("error").and_then(|v| v.as_str()).is_some_and(|e| e.contains("bad json")),
        "malformed JSON must get a structured error"
    );
    let pong = ask(br#"{"op":"ping"}"#);
    assert_eq!(pong.get("ok"), Some(&Json::Bool(true)), "connection survives a bad line");

    // unreadable line (invalid UTF-8): structured error, then close
    let err = ask(&[0xff, 0xfe, 0xfd]);
    assert!(
        err.get("error").and_then(|v| v.as_str()).is_some_and(|e| e.contains("read failed")),
        "an unreadable line must get a structured error before the connection closes"
    );
    let mut rest = String::new();
    assert_eq!(reader.read_line(&mut rest).unwrap(), 0, "connection closes after a read failure");

    drop(client);
    drop(reader);
    server.join().unwrap();
}

#[test]
fn paged_serving_shed_carries_structured_backpressure() {
    // Artifact-free: a kernel-only coordinator over a tiny paged frame
    // pool. A stream whose KV footprint exceeds the whole pool is
    // terminally unservable — it must retire as a structured shed whose
    // response carries the retry hint, while a pool-sized stream served
    // right after completes normally (the loop survives the shed).
    use sparge::coordinator::{AttnStreamSpec, PagedServe};
    let opts = ServeOptions {
        chunk: 32,
        params: sparge::sparge::SpargeParams { tau: 0.9, theta: 0.3, lambda: None, quant: false },
        cfg: sparge::attention::AttnConfig {
            bq: 16,
            bk: 8,
            causal: true,
            scale: None,
            cw: 2,
            row_offset: 0,
        },
        threads: 1,
        kv_split: sparge::attention::KvSplit::Auto,
        fault: None,
        paged: Some(PagedServe { frames: 4, d: 16, dv: 16, spill_to_disk: false }),
    };
    let c = Coordinator::start_kernel(BatchPolicy::default(), opts);
    // pool-sized stream: 20 rows = 3 frames of 4, completes
    let ok = c
        .serve_stream(AttnStreamSpec { prefill: 16, decode: 4, d: 16, seed: 3, ..Default::default() })
        .unwrap();
    assert!(ok.error.is_none(), "pool-sized stream must complete: {:?}", ok.error);
    assert_eq!(ok.tokens, 4);
    assert!(ok.retry_after_ms.is_none(), "a completed stream carries no retry hint");
    // 52 rows = 7 frames > the pool's 4: terminally unservable, shed
    let shed = c
        .serve_stream(AttnStreamSpec { prefill: 48, decode: 4, d: 16, seed: 4, ..Default::default() })
        .unwrap();
    assert_eq!(shed.error.as_deref(), Some("stream terminated: shed"));
    assert!(shed.retry_after_ms.is_some(), "a shed stream must carry retry_after_ms");
    assert!(shed.queue_depth.is_some(), "a shed stream must carry queue_depth");
    // dims mismatched to the pool fail the request, never the loop
    let bad = c
        .serve_stream(AttnStreamSpec { prefill: 16, decode: 2, d: 32, seed: 5, ..Default::default() })
        .unwrap();
    assert!(
        bad.error.as_deref().is_some_and(|e| e.contains("paged KV pool")),
        "mismatched dims must get a structured error: {:?}",
        bad.error
    );
    // the stats op exports the shed counter and the QoS keys
    let stats = sparge::coordinator::server::dispatch(&c, r#"{"op":"stats"}"#);
    assert!(stats.get("shed").unwrap().as_f64().unwrap() >= 1.0);
    assert!(stats.get("overload_state").and_then(|v| v.as_str()).is_some());
    assert!(stats.get("ttft_p99_ms_by_priority").is_some());
    assert!(stats.get("preempted").is_some());
    // a bad priority string on the serve op is a structured error too
    let err = sparge::coordinator::server::dispatch(
        &c,
        r#"{"op":"attn","mode":"serve","sessions":1,"n":16,"steps":2,"d":16,"priority":"urgent"}"#,
    );
    assert!(
        err.get("error").and_then(|v| v.as_str()).is_some_and(|e| e.contains("bad priority")),
        "unknown priority must be rejected"
    );
    c.shutdown();
}

#[test]
fn attention_probe_records_per_request_sparsity() {
    let Some(c) = coordinator() else { return };
    let params = sparge::sparge::SpargeParams::default();
    let r = c.attention_probe(512, 32, 7, &params, 4);
    assert!((0.0..=1.0).contains(&r.sparsity));
    assert!(r.seconds > 0.0);
    // determinism: same seed + params => same sparsity at any thread count
    let r2 = c.attention_probe(512, 32, 7, &params, 1);
    assert_eq!(r.sparsity, r2.sparsity);
    let snap = c.metrics.snapshot();
    assert_eq!(snap.sparse_requests, 2);
    assert!((snap.mean_sparsity - r.sparsity).abs() < 1e-12);
}

#[test]
fn decode_probe_reports_per_step_sparsity() {
    let Some(c) = coordinator() else { return };
    let params = sparge::sparge::SpargeParams::default();
    let r = c.attention_decode_probe(256, 32, 9, &params, 8, 2);
    assert_eq!(r.step_sparsity.len(), 8);
    assert!((0.0..=1.0).contains(&r.prefill_sparsity));
    for (i, s) in r.step_sparsity.iter().enumerate() {
        assert!((0.0..=1.0).contains(s), "step {i} sparsity {s}");
    }
    let mean = r.step_sparsity.iter().sum::<f64>() / 8.0;
    assert!((r.mean_step_sparsity - mean).abs() < 1e-12);
    // determinism across thread counts, like the prefill probe
    let r2 = c.attention_decode_probe(256, 32, 9, &params, 8, 1);
    assert_eq!(r.step_sparsity, r2.step_sparsity);
    // wire protocol: decode mode responds with the per-step array
    let resp = sparge::coordinator::server::dispatch(
        &c,
        r#"{"op":"attn","mode":"decode","n":128,"d":16,"steps":4,"seed":3,"threads":1}"#,
    );
    assert_eq!(resp.get("mode").and_then(|v| v.as_str()), Some("decode"));
    assert_eq!(resp.get("per_step_sparsity").and_then(|v| v.as_arr()).map(|a| a.len()), Some(4));
    assert!(resp.get("mean_step_sparsity").and_then(|v| v.as_f64()).is_some());
}

#[test]
fn backpressure_rejects_when_full() {
    let Some(dir) = Some(Manifest::default_dir()) else { return };
    if !dir.join("manifest.json").exists() {
        return;
    }
    let engine = EngineHandle::spawn(&dir).expect("engine");
    let c = Coordinator::start(
        engine,
        BatchPolicy {
            max_batch: 1,
            max_wait: std::time::Duration::from_millis(1),
            capacity: 2,
            ..Default::default()
        },
    );
    // flood faster than the engine can drain; some submissions must fail
    let mut rejected = 0;
    for _ in 0..64 {
        if c.submit(b"x".to_vec(), 1, AttnMode::Dense).is_err() {
            rejected += 1;
        }
    }
    assert!(rejected > 0, "expected backpressure rejections");
}
