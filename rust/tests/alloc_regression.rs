//! Allocation-regression suite for the serving hot path: a **warmed-up
//! f32 decode step performs zero heap allocations** — under the dense,
//! external-mask, INT8, and `Predicted` policies, and for whole
//! `SessionManager` ticks — because the worker/session `Workspace`
//! arenas, the session's cached `SpanPlan` and predicted mask, the
//! manager's tick arenas, and the amortized KV-cache capacity absorb
//! every piece of per-step scratch.
//!
//! The binary installs a counting global allocator. All assertions live
//! in **one** `#[test]` so the libtest harness runs a single thread and
//! cannot inject allocations mid-measurement: `Exec::Inline` windows are
//! asserted exactly zero on the thread-local counter; pool windows use
//! the process-global counter with a min-over-rounds guard (a pool
//! worker that was starved of spans during warmup may lazily size its
//! arena once — after that first touch every round must be clean).
//!
//! Geometry notes: with `b_k = 16`, decode steps that keep the cache
//! inside one `b_k` block leave the split-KV plan untouched (`kend`
//! unchanged ⇒ O(1) revalidation), and the amortized doubling of
//! `AttnSession::reserve_rows` means no capacity event occurs after the
//! warmup window. Crossing into a new block rebuilds the plan/arena —
//! that (amortized, O(cache/b_k) times per stream) is outside the
//! steady-state contract and outside the measured windows.

use sparge::attention::{AttnConfig, AttnEngine, BlockMask, Execution, KvSplit, SparsityPolicy};
use sparge::tensor::Tensor;
use sparge::util::alloc::{global_allocations, thread_allocations, CountingAlloc};
use sparge::util::rng::Pcg;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

const D: usize = 32;
const N: usize = 256;

fn cfg() -> AttnConfig {
    AttnConfig { bq: 16, bk: 16, causal: true, scale: None, cw: 2, row_offset: 0 }
}

/// Pre-sliced single-row q/k/v tensors so the measured loops do no
/// caller-side allocation.
fn rows(seed: u64) -> Vec<(Tensor, Tensor, Tensor)> {
    let mut rng = Pcg::seeded(seed);
    let q = Tensor::randn(&[N, D], &mut rng);
    let k = Tensor::randn(&[N, D], &mut rng);
    let v = Tensor::randn(&[N, D], &mut rng);
    (0..N).map(|t| (q.rows(t, t + 1), k.rows(t, t + 1), v.rows(t, t + 1))).collect()
}

/// Prefill 32 rows and decode through row index `warm_to` (exclusive),
/// leaving the session warm: capacity doubled past `N`, workspace at
/// high water, span plan built for the current `kend`.
fn warm<'e>(
    engine: &'e AttnEngine,
    toks: &[(Tensor, Tensor, Tensor)],
    warm_to: usize,
) -> (sparge::attention::AttnSession<'e>, Vec<f32>) {
    let mut session = engine.session();
    let pre = 32;
    let qs: Vec<f32> = toks[..pre].iter().flat_map(|(q, _, _)| q.data().to_vec()).collect();
    let ks: Vec<f32> = toks[..pre].iter().flat_map(|(_, k, _)| k.data().to_vec()).collect();
    let vs: Vec<f32> = toks[..pre].iter().flat_map(|(_, _, v)| v.data().to_vec()).collect();
    session.prefill(
        &Tensor::from_vec(&[pre, D], qs),
        &Tensor::from_vec(&[pre, D], ks),
        &Tensor::from_vec(&[pre, D], vs),
    );
    let mut out = vec![0f32; D];
    for (q, k, v) in &toks[pre..warm_to] {
        session.decode_into(q, k, v, &mut out);
    }
    (session, out)
}

/// Paged twin of [`warm`]: prefill 32 rows into pool frames and decode
/// through `warm_to`, leaving session, allocator free list, page table,
/// workspace, and span plan all at high water.
fn warm_paged<'e>(
    engine: &'e AttnEngine,
    alloc: &mut sparge::attention::PageAllocator,
    toks: &[(Tensor, Tensor, Tensor)],
    warm_to: usize,
) -> (sparge::attention::PagedAttnSession<'e>, Vec<f32>) {
    let mut session = engine.paged_session();
    let pre = 32;
    let qs: Vec<f32> = toks[..pre].iter().flat_map(|(q, _, _)| q.data().to_vec()).collect();
    let ks: Vec<f32> = toks[..pre].iter().flat_map(|(_, k, _)| k.data().to_vec()).collect();
    let vs: Vec<f32> = toks[..pre].iter().flat_map(|(_, _, v)| v.data().to_vec()).collect();
    let r = session.prefill(
        alloc,
        &Tensor::from_vec(&[pre, D], qs),
        &Tensor::from_vec(&[pre, D], ks),
        &Tensor::from_vec(&[pre, D], vs),
    );
    assert!(r.is_some(), "warm pool must cover the prefill");
    let mut out = vec![0f32; D];
    for (q, k, v) in &toks[pre..warm_to] {
        let r = session.decode_into(alloc, q, k, v, &mut out);
        assert!(r.is_some(), "warm pool must cover every decode frame");
    }
    (session, out)
}

#[test]
fn warmed_up_decode_steps_allocate_nothing() {
    let toks = rows(4242);
    // Measured window: decode steps taking the cache from 210 rows to
    // 224 rows — all inside k-block 14 (ceil(rows/16) = 14 for rows in
    // 209..=224), all inside the 256-row capacity reserved during
    // warmup. The counting allocator itself must be live:
    let probe0 = thread_allocations();
    let probe: Vec<u64> = vec![1, 2, 3];
    assert!(thread_allocations() > probe0, "counting allocator is not installed");
    drop(probe);

    // -- Exec::Inline, dense f32 λ-off, both drivers: exactly zero ------
    for split in [KvSplit::Off, KvSplit::Auto, KvSplit::Blocks(2)] {
        let engine = AttnEngine::builder().config(cfg()).kv_split(split).build();
        let (mut session, mut out) = warm(&engine, &toks, 209);
        let before = thread_allocations();
        for (q, k, v) in &toks[209..223] {
            session.decode_into(q, k, v, &mut out);
        }
        let delta = thread_allocations() - before;
        assert_eq!(
            delta, 0,
            "dense f32 λ-off decode step allocated under Exec::Inline, {split:?} ({delta} allocations / 14 steps)"
        );
        assert_eq!(session.len(), 223);
    }

    // -- Inline, external mask with λ ON: stage-2 skipping is free too --
    {
        let mask = BlockMask::new_all(N / 16, N / 16, true);
        let engine = AttnEngine::builder()
            .config(cfg())
            .policy(SparsityPolicy::External { mask, lambda: Some(-6.0) })
            .kv_split(KvSplit::Auto)
            .build();
        let (mut session, mut out) = warm(&engine, &toks, 209);
        let before = thread_allocations();
        for (q, k, v) in &toks[209..223] {
            session.decode_into(q, k, v, &mut out);
        }
        assert_eq!(thread_allocations() - before, 0, "external-mask λ-on decode step allocated");
    }

    // -- INT8 dense: cached K quantization + staged Q, still zero -------
    {
        let engine = AttnEngine::builder()
            .config(cfg())
            .precision(sparge::attention::Precision::Int8)
            .kv_split(KvSplit::Auto)
            .build();
        let (mut session, mut out) = warm(&engine, &toks, 209);
        let before = thread_allocations();
        for (q, k, v) in &toks[209..223] {
            session.decode_into(q, k, v, &mut out);
        }
        assert_eq!(thread_allocations() - before, 0, "INT8 dense decode step allocated");
    }

    // -- Predicted policy: the per-step stage-1 mask is pooled too ------
    // Each step rebuilds the session-owned mask in place from workspace
    // arenas (pooled K means, Ŝ/P̂ staging, TopCdf index sort) — zero
    // allocations even though every step runs the full predictor.
    {
        use sparge::sparge::SpargeParams;
        let params = SpargeParams { tau: 0.9, theta: 0.3, lambda: Some(-6.0), quant: false };
        let engine =
            AttnEngine::builder().config(cfg()).sparge(&params).kv_split(KvSplit::Auto).build();
        let (mut session, mut out) = warm(&engine, &toks, 209);
        let before = thread_allocations();
        for (q, k, v) in &toks[209..223] {
            session.decode_into(q, k, v, &mut out);
        }
        let delta = thread_allocations() - before;
        assert_eq!(delta, 0, "predicted-policy decode step allocated ({delta} / 14 steps)");
    }

    // -- Paged KV cache: frame-resident decode is zero-alloc too --------
    // The page table, free list, per-frame pooled state, and span plan
    // are all at high water after warmup; the measured window stays
    // inside k-block 14, so no frame claim (claims fire when
    // `rows % b_k == 0` — row 208 during warmup, row 224 after the
    // window) and no CoW (every frame is singly referenced).
    {
        use sparge::attention::PageAllocator;
        for split in [KvSplit::Off, KvSplit::Auto, KvSplit::Blocks(2)] {
            let engine = AttnEngine::builder().config(cfg()).kv_split(split).build();
            let mut alloc = PageAllocator::new(32, 16, D, D);
            let (mut session, mut out) = warm_paged(&engine, &mut alloc, &toks, 209);
            let before = thread_allocations();
            for (q, k, v) in &toks[209..223] {
                let r = session.decode_into(&mut alloc, q, k, v, &mut out);
                assert!(r.is_some(), "pool must not exhaust inside the window");
            }
            let delta = thread_allocations() - before;
            assert_eq!(
                delta, 0,
                "paged dense f32 decode step allocated under Exec::Inline, {split:?} ({delta} allocations / 14 steps)"
            );
            assert_eq!(session.len(), 223);
            session.release(&mut alloc);
            alloc.assert_all_free();
        }
    }

    // -- SessionManager ticks: scheduling bookkeeping is arena-backed ---
    // Three sessions decoding in lockstep exercise the batched fan-out
    // (tick-persistent phase snapshot + ready indices); a warmed decode
    // tick — steps AND the scheduling around them — allocates nothing.
    // The measured window (decode tokens 40..47 per session) sits clear
    // of KV-capacity doublings, k-block crossings, and the per-token
    // latency vector's amortized growth.
    {
        use sparge::coordinator::{SeqStream, SessionManager};
        use std::time::Instant;
        let engine = AttnEngine::builder().config(cfg()).kv_split(KvSplit::Off).build();
        let mut mgr = SessionManager::new(&engine, 32);
        for (i, seed) in [(0u64, 91u64), (1, 92), (2, 93)] {
            let mut rng = Pcg::seeded(seed);
            let q = Tensor::randn(&[96, D], &mut rng);
            let k = Tensor::randn(&[96, D], &mut rng);
            let v = Tensor::randn(&[96, D], &mut rng);
            mgr.admit(i, SeqStream { q, k, v, prefill: 32 }, Instant::now());
        }
        for _ in 0..40 {
            mgr.tick(); // 1 prefill tick + 39 warmup decode ticks
        }
        let before = thread_allocations();
        for _ in 0..7 {
            let done = mgr.tick();
            assert!(done.is_empty(), "measured ticks must not retire sessions");
        }
        let delta = thread_allocations() - before;
        assert_eq!(delta, 0, "warmed serving tick allocated ({delta} / 7 ticks of 3 sessions)");
    }

    // -- Paged SessionManager ticks: admission + frames, still zero -----
    // Same traffic over a paged pool: with the pending queue drained the
    // reservation-based admission check breaks immediately. Unlike the
    // monolithic window above, the measured decode appends (cache rows
    // 64..70 per session) deliberately CROSS a frame boundary — the
    // claims at rows 64 are each session's fifth page-table entry, which
    // without the admission-time `PagedAttnSession::reserve_rows`
    // pre-size would reallocate the table mid-step — so a warmed paged
    // serving tick allocates nothing even while claiming fresh frames.
    {
        use sparge::attention::PageAllocator;
        use sparge::coordinator::{SeqStream, SessionManager};
        use std::time::Instant;
        let engine = AttnEngine::builder().config(cfg()).kv_split(KvSplit::Off).build();
        let mut mgr = SessionManager::new_paged(&engine, 32, PageAllocator::new(32, 16, D, D));
        for (i, seed) in [(0u64, 91u64), (1, 92), (2, 93)] {
            let mut rng = Pcg::seeded(seed);
            let q = Tensor::randn(&[96, D], &mut rng);
            let k = Tensor::randn(&[96, D], &mut rng);
            let v = Tensor::randn(&[96, D], &mut rng);
            mgr.admit(i, SeqStream { q, k, v, prefill: 32 }, Instant::now());
        }
        for _ in 0..33 {
            mgr.tick(); // admission + prefill tick, then warmup to cache row 64
        }
        let before = thread_allocations();
        for _ in 0..7 {
            let done = mgr.tick();
            assert!(done.is_empty(), "measured ticks must not retire sessions");
        }
        let delta = thread_allocations() - before;
        assert_eq!(delta, 0, "warmed paged serving tick allocated ({delta} / 7 ticks of 3 sessions)");
        let ps = mgr.page_stats().expect("paged manager has page stats");
        assert_eq!(ps.claims, 15, "the measured window claimed each session's fifth frame");
        // finish the residents and prove the pool comes back whole
        mgr.drain();
        mgr.release_prefixes();
        mgr.assert_frames_all_free();
    }

    // -- Pool execution: workers' own arenas absorb the span scratch ----
    // Span reductions land on nondeterministic workers (chunked
    // self-scheduling), so a worker starved during warmup may size its
    // arena on first touch; after that, rounds must be clean — assert
    // the *minimum* round delta is zero on the global counter.
    {
        let engine = AttnEngine::builder()
            .config(cfg())
            .execution(Execution::Pool(2))
            .kv_split(KvSplit::Blocks(1))
            .build();
        let (mut session, mut out) = warm(&engine, &toks, 209);
        let mut deltas = Vec::new();
        for round in 0..7 {
            let t0 = 209 + round * 2;
            let before = global_allocations();
            for (q, k, v) in &toks[t0..t0 + 2] {
                session.decode_into(q, k, v, &mut out);
            }
            deltas.push(global_allocations() - before);
        }
        let min = *deltas.iter().min().unwrap();
        assert_eq!(
            min, 0,
            "pooled split-KV decode allocates on every round ({deltas:?} over 7 rounds of 2 steps)"
        );
    }
}
