//! Golden suite for the stateful serving path: `AttnSession` decode and
//! **chunked prefill** must reproduce one-shot full-sequence prefill
//! **bitwise** (f32, λ off — see the parity contract in
//! `attention::engine`; chunk edges on `b_q` boundaries additionally
//! reproduce the one-shot `SkipStats` and extend parity to λ-on, the
//! predicted policy, and INT8), the stage-1 predictor must stay
//! incremental across decode steps and blockwise across prefill chunks
//! (update counters, never a full `compress_blocks` recompute), sessions
//! must be deterministic and reusable, and results must be invariant to
//! the engine's worker-pool size.

use sparge::attention::types::{AttnConfig, BlockMask};
use sparge::attention::{AttnEngine, Execution, Precision, SparsityPolicy};
use sparge::sparge::kernel::SpargeParams;
use sparge::tensor::Tensor;
use sparge::util::rng::Pcg;

fn qkv(n: usize, d: usize, seed: u64) -> (Tensor, Tensor, Tensor) {
    let mut rng = Pcg::seeded(seed);
    (Tensor::randn(&[n, d], &mut rng), Tensor::randn(&[n, d], &mut rng), Tensor::randn(&[n, d], &mut rng))
}

/// Prefill the first `n0` rows, decode the rest token by token, and
/// assemble the full (n × d) output.
fn run_split(engine: &AttnEngine, q: &Tensor, k: &Tensor, v: &Tensor, n0: usize) -> Tensor {
    let n = q.dim(0);
    let mut session = engine.session();
    let mut data = Vec::with_capacity(n * v.dim(1));
    if n0 > 0 {
        let pre = session.prefill(&q.rows(0, n0), &k.rows(0, n0), &v.rows(0, n0));
        data.extend_from_slice(pre.out.data());
    }
    for t in n0..n {
        let r = session.decode(&q.rows(t, t + 1), &k.rows(t, t + 1), &v.rows(t, t + 1));
        assert_eq!(r.out.shape(), &[1, v.dim(1)]);
        data.extend_from_slice(r.out.data());
    }
    assert_eq!(session.len(), n);
    assert_eq!(session.steps(), n - n0);
    Tensor::from_vec(&[n, v.dim(1)], data)
}

/// Prefill through chunks ending at `edges` (strictly increasing; the
/// last edge is the prompt length), then decode row by row to `n`.
/// Returns the assembled output rows and the summed `SkipStats` over
/// every chunk and decode step.
fn run_chunked(
    engine: &AttnEngine,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    edges: &[usize],
) -> (Tensor, sparge::attention::SkipStats) {
    let n = q.dim(0);
    let mut session = engine.session();
    let mut data = Vec::with_capacity(n * v.dim(1));
    let mut stats = sparge::attention::SkipStats::default();
    let mut start = 0;
    for &end in edges {
        let r = session.prefill_chunk(&q.rows(start, end), &k.rows(start, end), &v.rows(start, end));
        assert_eq!(r.out.shape(), &[end - start, v.dim(1)]);
        data.extend_from_slice(r.out.data());
        stats.merge(&r.stats);
        start = end;
    }
    for t in start..n {
        let r = session.decode(&q.rows(t, t + 1), &k.rows(t, t + 1), &v.rows(t, t + 1));
        data.extend_from_slice(r.out.data());
        stats.merge(&r.stats);
    }
    assert_eq!(session.len(), n);
    (Tensor::from_vec(&[n, v.dim(1)], data), stats)
}

/// One-shot prefill of the first `edges.last()` rows + decode of the
/// rest, with summed stats — the chunked runs' reference.
fn run_one_shot(
    engine: &AttnEngine,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    n0: usize,
) -> (Tensor, sparge::attention::SkipStats) {
    run_chunked(engine, q, k, v, &[n0])
}

#[test]
fn chunked_prefill_matches_one_shot_bitwise_dense() {
    // Output parity holds for ANY chunk edges (per-row independence +
    // exact float no-ops on masked tails); stats parity additionally
    // holds when interior edges sit on b_q boundaries — including edges
    // that are OFF the b_k grid (bq=8, bk=16: edge 24 splits a K block).
    let n = 57;
    let (q, k, v) = qkv(n, 16, 2024);
    for (bq, bk, edges, stats_must_match) in [
        (16, 8, vec![16, 48, 57], true),  // b_q-aligned interior edges
        (8, 16, vec![24, 40, 57], true),  // b_q-aligned, off the b_k grid
        (16, 8, vec![13, 30, 57], false), // ragged edges: outputs only
        (16, 16, vec![57], true),         // single chunk == prefill()
        (8, 8, vec![8, 16, 24, 32, 40, 48, 56, 57], true), // many tiny chunks
    ] {
        let cfg = AttnConfig { bq, bk, causal: true, scale: None, cw: 2, row_offset: 0 };
        let engine = AttnEngine::dense(cfg);
        let (full, full_stats) = run_one_shot(&engine, &q, &k, &v, n);
        let (chunked, chunked_stats) = run_chunked(&engine, &q, &k, &v, &edges);
        assert_eq!(chunked, full, "chunked prefill diverged (bq={bq} bk={bk} edges={edges:?})");
        if stats_must_match {
            assert_eq!(chunked_stats, full_stats, "stats diverged (bq={bq} bk={bk} edges={edges:?})");
        }
    }
}

#[test]
fn chunked_prefill_then_decode_matches_one_shot_exactly() {
    // The acceptance criterion end to end: N-chunk prefill followed by
    // decode steps must produce identical output rows AND identical
    // summed SkipStats to one-shot prefill + the same decode steps, for
    // dense and external-mask policies (f32, λ off).
    let (n, n0, d) = (96, 72, 16);
    let (q, k, v) = qkv(n, d, 2025);
    let cfg = AttnConfig { bq: 16, bk: 8, causal: true, scale: None, cw: 2, row_offset: 0 };
    let edges = [16, 64, 72]; // uneven chunks, b_q-aligned
    {
        let engine = AttnEngine::dense(cfg);
        let (full, fs) = run_one_shot(&engine, &q, &k, &v, n0);
        let (chunked, cs) = run_chunked(&engine, &q, &k, &v, &edges);
        assert_eq!(chunked, full, "dense chunked+decode diverged");
        assert_eq!(cs, fs, "dense chunked+decode stats diverged");
    }
    {
        let (tm, tn) = (cfg.n_qblocks(n), cfg.n_kblocks(n));
        let mut rng = Pcg::seeded(77);
        let mut mask = BlockMask::new_all(tm, tn, false);
        for i in 0..tm {
            mask.set(i, 0, true);
            for j in 0..tn {
                if rng.chance(0.5) {
                    mask.set(i, j, true);
                }
            }
        }
        let engine = AttnEngine::builder()
            .config(cfg)
            .policy(SparsityPolicy::External { mask, lambda: None })
            .build();
        let (full, fs) = run_one_shot(&engine, &q, &k, &v, n0);
        let (chunked, cs) = run_chunked(&engine, &q, &k, &v, &edges);
        assert!(fs.sparsity() > 0.0, "mask produced no skips; test is vacuous");
        assert_eq!(chunked, full, "external chunked+decode diverged");
        assert_eq!(cs, fs, "external chunked+decode stats diverged");
    }
}

#[test]
fn chunked_prefill_lambda_on_is_bitwise_with_aligned_edges() {
    // Stage-2 λ decisions are per-tile; b_q-aligned chunk edges reproduce
    // the one-shot tiling, so even λ-on runs stay bitwise-equal.
    let (n, d) = (128, 16);
    let (mut q, mut k, v) = qkv(n, d, 2026);
    for r in 0..8 {
        for x in k.row_mut(r) {
            *x *= 10.0;
        }
    }
    for r in 0..n {
        for x in q.row_mut(r) {
            *x *= 2.0;
        }
    }
    let cfg = AttnConfig { bq: 16, bk: 16, causal: true, scale: None, cw: 4, row_offset: 0 };
    let mask = BlockMask::new_all(8, 8, true);
    let engine = AttnEngine::builder()
        .config(cfg)
        .policy(SparsityPolicy::External { mask, lambda: Some(-4.0) })
        .build();
    let (full, fs) = run_one_shot(&engine, &q, &k, &v, 96);
    assert!(fs.pv_skipped_frac > 0.0, "λ never fired; test is vacuous");
    let (chunked, cs) = run_chunked(&engine, &q, &k, &v, &[32, 80, 96]);
    assert_eq!(chunked, full, "λ-on aligned chunked prefill diverged");
    assert_eq!(cs, fs, "λ-on aligned chunked stats diverged");
}

#[test]
fn chunked_prefill_predicted_policy_is_bitwise_and_blockwise_incremental() {
    // Predicted-policy parity (edges on both the b_q and b_k grids:
    // bk | bq makes every b_q edge suffice), plus the KPool counter
    // discipline: chunk 1 is the bulk build, later chunks are blockwise
    // extends, decode appends stay incremental.
    let (n, n0, d) = (88, 64, 16);
    let (q, k, v) = qkv(n, d, 2027);
    let cfg = AttnConfig { bq: 16, bk: 8, causal: true, scale: None, cw: 2, row_offset: 0 };
    let params = SpargeParams { tau: 0.9, theta: 0.3, lambda: None, quant: false };
    let engine = AttnEngine::sparge(cfg, &params);
    let (full, _) = run_one_shot(&engine, &q, &k, &v, n0);

    let mut session = engine.session();
    let edges = [16, 48, 64];
    let mut data = Vec::new();
    let mut start = 0;
    for (ci, &end) in edges.iter().enumerate() {
        let r = session.prefill_chunk(&q.rows(start, end), &k.rows(start, end), &v.rows(start, end));
        data.extend_from_slice(r.out.data());
        let c = session.predictor_counters();
        assert_eq!(c.full_recomputes, 1, "chunk {ci} re-ran a bulk scan");
        assert_eq!(c.chunk_extends, ci, "chunk {ci} missed a blockwise extend");
        assert_eq!(c.incremental_updates, 0);
        start = end;
    }
    for t in n0..n {
        let r = session.decode(&q.rows(t, t + 1), &k.rows(t, t + 1), &v.rows(t, t + 1));
        data.extend_from_slice(r.out.data());
        let c = session.predictor_counters();
        assert_eq!((c.full_recomputes, c.chunk_extends), (1, edges.len() - 1));
        assert_eq!(c.incremental_updates, t + 1 - n0, "decode step {t} missed an incremental update");
    }
    let chunked = Tensor::from_vec(&[n, d], data);
    assert_eq!(chunked, full, "predicted-policy chunked prefill diverged");
}

#[test]
fn chunked_prefill_int8_is_bitwise_with_aligned_edges_and_shared_mean() {
    // INT8 parity needs (a) chunk edges on both block grids so Q/K quant
    // blocks coincide with the one-shot blocks, and (b) a smoothing mean
    // the first chunk reproduces exactly — ± paired K rows make every
    // chunk's channel mean exactly +0.0, so the frozen mean equals the
    // one-shot global mean bit-for-bit.
    let (n, d) = (96, 16);
    let (q, mut k, v) = qkv(n, d, 2028);
    for r in (0..n).step_by(2) {
        let neg: Vec<f32> = k.row(r).iter().map(|&x| -x).collect();
        k.row_mut(r + 1).copy_from_slice(&neg);
    }
    let cfg = AttnConfig { bq: 16, bk: 8, causal: true, scale: None, cw: 2, row_offset: 0 };
    let engine = AttnEngine::builder().config(cfg).precision(Precision::Int8).build();
    let (full, fs) = run_one_shot(&engine, &q, &k, &v, 80);
    let (chunked, cs) = run_chunked(&engine, &q, &k, &v, &[32, 48, 80]);
    assert_eq!(chunked, full, "int8 aligned chunked prefill diverged");
    assert_eq!(cs, fs, "int8 aligned chunked stats diverged");
}

#[test]
fn chunked_prefill_int8_ragged_edges_track_the_f32_oracle() {
    // General INT8 chunking (frozen first-chunk mean, ragged edges) is
    // approximate by design; it must stay within the INT8 budget of the
    // f32 dense oracle.
    let (n, d) = (72, 16);
    let (q, k, v) = qkv(n, d, 2029);
    let cfg = AttnConfig { bq: 16, bk: 16, causal: true, scale: None, cw: 2, row_offset: 0 };
    let engine = AttnEngine::builder().config(cfg).precision(Precision::Int8).build();
    let (chunked, _) = run_chunked(&engine, &q, &k, &v, &[11, 40, 60]);
    let oracle = sparge::attention::attention_naive(&q, &k, &v, &cfg);
    let err = sparge::util::prop::rel_l1(chunked.data(), oracle.data());
    assert!(err < 0.05, "int8 ragged chunked prefill rel-L1 {err}");
}

#[test]
fn decode_matches_prefill_bitwise_dense() {
    // ragged everywhere on purpose: n not a multiple of bq or bk, and the
    // prefill/decode split lands mid-block
    for (n, n0, bq, bk) in [(57, 25, 16, 8), (64, 32, 16, 16), (41, 0, 8, 4), (33, 32, 32, 32)] {
        let (q, k, v) = qkv(n, 16, 1000 + n as u64);
        let cfg = AttnConfig { bq, bk, causal: true, scale: None, cw: 2, row_offset: 0 };
        let engine = AttnEngine::dense(cfg);
        let full = engine.attention(&q, &k, &v);
        let split = run_split(&engine, &q, &k, &v, n0);
        assert_eq!(split, full.out, "decode path diverged (n={n} n0={n0} bq={bq} bk={bk})");
    }
}

#[test]
fn decode_matches_prefill_bitwise_external_mask() {
    // real stage-1 skipping during decode, still bitwise-equal to prefill
    let (n, n0, d) = (96, 40, 16);
    let (q, k, v) = qkv(n, d, 42);
    let cfg = AttnConfig { bq: 16, bk: 8, causal: true, scale: None, cw: 2, row_offset: 0 };
    let mut rng = Pcg::seeded(43);
    let (tm, tn) = (cfg.n_qblocks(n), cfg.n_kblocks(n));
    let mut mask = BlockMask::new_all(tm, tn, false);
    for i in 0..tm {
        mask.set(i, 0, true); // causal rows always keep block 0
        for j in 0..tn {
            if rng.chance(0.5) {
                mask.set(i, j, true);
            }
        }
    }
    let engine = AttnEngine::builder()
        .config(cfg)
        .policy(SparsityPolicy::External { mask: mask.clone(), lambda: None })
        .build();
    let full = engine.attention(&q, &k, &v);
    assert!(full.stats.sparsity() > 0.0, "mask produced no skips; test is vacuous");
    let split = run_split(&engine, &q, &k, &v, n0);
    assert_eq!(split, full.out, "masked decode path diverged");
}

#[test]
fn decode_predictor_is_incremental_with_counters() {
    // The acceptance invariant: decoding N tokens performs N incremental
    // predictor updates and zero additional full recomputes (the prefill
    // bulk build is the only full scan in the session's lifetime).
    let (n, n0, d) = (80, 48, 16);
    let (q, k, v) = qkv(n, d, 7);
    let cfg = AttnConfig { bq: 16, bk: 16, causal: true, scale: None, cw: 2, row_offset: 0 };
    let params = SpargeParams { tau: 0.9, theta: 0.3, lambda: None, quant: false };
    let engine = AttnEngine::sparge(cfg, &params);
    let mut session = engine.session();
    session.prefill(&q.rows(0, n0), &k.rows(0, n0), &v.rows(0, n0));
    let after_prefill = session.predictor_counters();
    assert_eq!(after_prefill.full_recomputes, 1, "prefill is exactly one bulk scan");
    assert_eq!(after_prefill.incremental_updates, 0);
    for t in n0..n {
        let r = session.decode(&q.rows(t, t + 1), &k.rows(t, t + 1), &v.rows(t, t + 1));
        let mask = r.mask.expect("predicted policy emits a per-step mask");
        assert_eq!(mask.rows, 1);
        assert_eq!(mask.cols, cfg.n_kblocks(t + 1));
        assert!((0.0..=1.0).contains(&r.stats.sparsity()));
        let c = session.predictor_counters();
        assert_eq!(c.full_recomputes, 1, "decode step {t} re-ran a full compress_blocks scan");
        assert_eq!(c.incremental_updates, t + 1 - n0, "decode step {t} missed an incremental update");
    }
}

#[test]
fn decode_parity_holds_while_predictor_stays_incremental() {
    // Both halves of the acceptance criterion in one run: bitwise decode ==
    // prefill AND per-token incremental predictor updates, on a *Predicted*
    // policy. θ > 1 makes every block a fix block, so the predicted mask is
    // deterministically full in both prefill and decode (no TopCdf float
    // tie-breaks) while the stage-1 predictor still pools every row.
    let (n, n0, d) = (72, 40, 16);
    let (q, k, v) = qkv(n, d, 91);
    let cfg = AttnConfig { bq: 16, bk: 8, causal: true, scale: None, cw: 2, row_offset: 0 };
    let params = SpargeParams { tau: 0.9, theta: 1.5, lambda: None, quant: false };
    let engine = AttnEngine::sparge(cfg, &params);
    let full = engine.attention(&q, &k, &v);
    let full_mask = full.mask.as_ref().expect("predicted mask");
    assert_eq!(full_mask.count_active(), {
        // every causal-domain block is forced on by the θ>1 fix rule
        let (tm, tn) = (cfg.n_qblocks(n), cfg.n_kblocks(n));
        (0..tm).map(|i| tn.min(((i + 1) * cfg.bq).min(n).div_ceil(cfg.bk))).sum::<usize>()
    });
    let mut session = engine.session();
    let pre = session.prefill(&q.rows(0, n0), &k.rows(0, n0), &v.rows(0, n0));
    assert_eq!(pre.out.data(), &full.out.data()[..n0 * d]);
    assert_eq!(session.predictor_counters().full_recomputes, 1);
    for t in n0..n {
        let r = session.decode(&q.rows(t, t + 1), &k.rows(t, t + 1), &v.rows(t, t + 1));
        assert_eq!(r.out.data(), &full.out.data()[t * d..(t + 1) * d], "row {t} diverged");
        assert_eq!(r.mask.expect("step mask").count_active(), cfg.n_kblocks(t + 1));
        let c = session.predictor_counters();
        assert_eq!((c.full_recomputes, c.incremental_updates), (1, t + 1 - n0));
    }
}

#[test]
fn session_reuse_is_deterministic() {
    // same engine, two sessions in sequence, identical inputs => identical
    // outputs; plus two sessions concurrently from two threads
    let (n, n0, d) = (48, 24, 8);
    let (q, k, v) = qkv(n, d, 11);
    let cfg = AttnConfig { bq: 8, bk: 8, causal: true, scale: None, cw: 2, row_offset: 0 };
    let params = SpargeParams { tau: 0.9, theta: 0.3, lambda: Some(-6.0), quant: false };
    let engine = AttnEngine::sparge(cfg, &params);
    let a = run_split(&engine, &q, &k, &v, n0);
    let b = run_split(&engine, &q, &k, &v, n0);
    assert_eq!(a, b, "sequential session reuse diverged");
    let outs: Vec<Tensor> = std::thread::scope(|scope| {
        let handles: Vec<_> =
            (0..2).map(|_| scope.spawn(|| run_split(&engine, &q, &k, &v, n0))).collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for o in outs {
        assert_eq!(o, a, "concurrent session diverged");
    }
}

#[test]
fn pool_size_invariance_across_1_2_8_workers() {
    let (n, n0, d) = (96, 64, 16);
    let (q, k, v) = qkv(n, d, 12);
    let cfg = AttnConfig { bq: 16, bk: 16, causal: true, scale: None, cw: 2, row_offset: 0 };
    let reference = {
        let engine = AttnEngine::dense(cfg);
        run_split(&engine, &q, &k, &v, n0)
    };
    for exec in [Execution::Pool(1), Execution::Pool(2), Execution::Pool(8), Execution::Threads(4)] {
        let engine = AttnEngine::builder().config(cfg).execution(exec).build();
        let split = run_split(&engine, &q, &k, &v, n0);
        assert_eq!(split, reference, "{exec:?} diverged from inline");
    }
}

#[test]
fn decode_lambda_skips_count_whole_blocks() {
    // fractional tile accounting: a 1-row decode tile has one row group
    // covering the whole block, so λ skips must land in whole-block units
    // (the old per-c_w accounting would count 1/c_w here).
    let (n, n0, d) = (128, 64, 16);
    let (mut q, mut k, v) = qkv(n, d, 13);
    // spiky keys early in the sequence so later rows concentrate there and
    // λ fires on the rest
    for r in 0..8 {
        for x in k.row_mut(r) {
            *x *= 10.0;
        }
    }
    for r in 0..n {
        for x in q.row_mut(r) {
            *x *= 2.0;
        }
    }
    let mask = BlockMask::new_all(8, 8, true);
    let cfg = AttnConfig { bq: 16, bk: 16, causal: true, scale: None, cw: 4, row_offset: 0 };
    let engine = AttnEngine::builder()
        .config(cfg)
        .policy(SparsityPolicy::External { mask, lambda: Some(-4.0) })
        .build();
    let mut session = engine.session();
    session.prefill(&q.rows(0, n0), &k.rows(0, n0), &v.rows(0, n0));
    let mut any_skip = false;
    for t in n0..n {
        let r = session.decode(&q.rows(t, t + 1), &k.rows(t, t + 1), &v.rows(t, t + 1));
        let frac = r.stats.pv_skipped_frac;
        assert_eq!(frac.fract(), 0.0, "decode λ skip not whole-block at t={t}: {frac}");
        any_skip |= frac > 0.0;
    }
    assert!(any_skip, "λ never fired; accounting test is vacuous");
}
