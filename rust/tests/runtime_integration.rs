//! Integration over the PJRT runtime: load AOT artifacts, execute, and
//! cross-check against the Rust engine and the Python-side semantics.
//! All tests no-op with a notice when `make artifacts` has not run.

use sparge::attention::types::AttnConfig;
use sparge::attention::{attention_naive, AttnEngine};
use sparge::runtime::{Manifest, Runtime, Value};
use sparge::sparge::kernel::SpargeParams;
use sparge::sparge::metrics::rel_l1;
use sparge::tensor::Tensor;
use sparge::util::rng::Pcg;

fn runtime() -> Option<Runtime> {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("[skipped: no artifacts — run `make artifacts`]");
        return None;
    }
    Some(Runtime::new(&dir).expect("runtime"))
}

fn qkv(n: usize, d: usize, seed: u64) -> (Tensor, Tensor, Tensor) {
    let mut rng = Pcg::seeded(seed);
    (Tensor::randn(&[n, d], &mut rng), Tensor::randn(&[n, d], &mut rng), Tensor::randn(&[n, d], &mut rng))
}

#[test]
fn manifest_covers_expected_artifacts() {
    let Some(rt) = runtime() else { return };
    for name in [
        "attn_dense_1024",
        "attn_sparge_1024",
        "attn_dense_2048",
        "attn_sparge_2048",
        "lm_fwd_dense_256",
        "lm_fwd_sparge_256",
        "lm_train_step_8x256",
        "dit_fwd_dense_1152",
        "dit_fwd_sparge_1152",
    ] {
        assert!(rt.manifest.get(name).is_ok(), "missing artifact {name}");
    }
}

#[test]
fn dense_artifact_matches_rust_engine() {
    let Some(rt) = runtime() else { return };
    let (q, k, v) = qkv(1024, 64, 7);
    let out = rt
        .run("attn_dense_1024", &[Value::from_tensor(&q), Value::from_tensor(&k), Value::from_tensor(&v)])
        .unwrap();
    let hlo = out[0].to_tensor().unwrap();
    let rust = attention_naive(&q, &k, &v, &AttnConfig::default());
    let err = rel_l1(&hlo, &rust);
    assert!(err < 1e-4, "dense artifact rel-L1 {err}");
}

#[test]
fn sparge_artifact_matches_rust_sparge_semantics() {
    // The attn_sparge artifact bakes tau=0.95, theta=0.4, lambda=-8,
    // bq=bk=64, cw=4 (aot.py constants). The Rust engine with the same
    // params must land close — small mask differences from fp tie-breaks
    // are tolerated via a loose rel-L1 bound vs the DENSE reference.
    let Some(rt) = runtime() else { return };
    let art = rt.manifest.get("attn_sparge_1024").unwrap().clone();
    let tau = art.meta_f64("tau").unwrap() as f32;
    let theta = art.meta_f64("theta").unwrap() as f32;
    let lambda = art.meta_f64("lambda").unwrap() as f32;
    let bq = art.meta_f64("bq").unwrap() as usize;
    let bk = art.meta_f64("bk").unwrap() as usize;
    let cw = art.meta_f64("cw").unwrap() as usize;

    let (q, k, v) = qkv(1024, 64, 8);
    let out = rt
        .run("attn_sparge_1024", &[Value::from_tensor(&q), Value::from_tensor(&k), Value::from_tensor(&v)])
        .unwrap();
    let hlo = out[0].to_tensor().unwrap();
    let density = out[1].scalar().unwrap();
    assert!((0.0..=1.0).contains(&density), "density {density}");

    let cfg = AttnConfig { bq, bk, causal: false, scale: None, cw, row_offset: 0 };
    let params = SpargeParams { tau, theta, lambda: Some(lambda), quant: false };
    let rust = AttnEngine::sparge(cfg, &params).attention(&q, &k, &v);
    let dense = AttnEngine::dense(cfg).attention(&q, &k, &v).out;

    let hlo_vs_dense = rel_l1(&hlo, &dense);
    let rust_vs_dense = rel_l1(&rust.out, &dense);
    // both implementations must stay close to dense, and close to each other
    assert!(hlo_vs_dense < 0.10, "hlo rel-L1 vs dense {hlo_vs_dense}");
    assert!(rust_vs_dense < 0.10, "rust rel-L1 vs dense {rust_vs_dense}");
    let cross = rel_l1(&hlo, &rust.out);
    assert!(cross < 0.10, "cross-layer rel-L1 {cross}");
    // achieved mask densities should roughly agree
    let rust_density = 1.0 - rust.mask.as_ref().expect("predicted mask").sparsity();
    assert!((density - rust_density).abs() < 0.25, "densities {density} vs {rust_density}");
}

#[test]
fn lm_forward_runs_and_is_causal_consistent() {
    let Some(rt) = runtime() else { return };
    let init = sparge::workloads::trace::load(&rt.dir().join("lm_init.spg")).unwrap();
    let params = init.into_iter().next().unwrap().into_vec();
    let n = params.len();

    let toks: Vec<i32> = (0..256).map(|i| (i * 7 % 96 + 32) as i32).collect();
    let logits = rt
        .run("lm_fwd_dense_256", &[Value::F32(params.clone(), vec![n]), Value::I32(toks.clone(), vec![256])])
        .unwrap();
    let l1 = logits[0].as_f32().unwrap().to_vec();

    // change the last token: logits for earlier positions must not move
    let mut toks2 = toks.clone();
    toks2[255] = (toks2[255] + 13) % 256;
    let logits2 = rt
        .run("lm_fwd_dense_256", &[Value::F32(params, vec![n]), Value::I32(toks2, vec![256])])
        .unwrap();
    let l2 = logits2[0].as_f32().unwrap();
    let vocab = 256;
    for t in 0..255 {
        for vv in 0..vocab {
            let a = l1[t * vocab + vv];
            let b = l2[t * vocab + vv];
            assert!((a - b).abs() < 1e-4, "causality broken at t={t}");
        }
    }
}

#[test]
fn train_step_decreases_loss_deterministically() {
    let Some(rt) = runtime() else { return };
    let init = sparge::workloads::trace::load(&rt.dir().join("lm_init.spg")).unwrap();
    let mut params = init.into_iter().next().unwrap().into_vec();
    let n = params.len();
    let mut m = vec![0f32; n];
    let mut v = vec![0f32; n];
    let mut step = 0f32;

    // one fixed batch, several steps: loss must drop (overfit one batch)
    let mut rng = Pcg::seeded(33);
    let corpus = sparge::workloads::text::corpus(8 * 256 + 1, &mut rng);
    let batch: Vec<i32> = corpus[..8 * 256].iter().map(|&b| b as i32).collect();

    let mut losses = Vec::new();
    for _ in 0..5 {
        let out = rt
            .run(
                "lm_train_step_8x256",
                &[
                    Value::F32(params.clone(), vec![n]),
                    Value::F32(m.clone(), vec![n]),
                    Value::F32(v.clone(), vec![n]),
                    Value::scalar_f32(step),
                    Value::I32(batch.clone(), vec![8, 256]),
                ],
            )
            .unwrap();
        params = out[0].as_f32().unwrap().to_vec();
        m = out[1].as_f32().unwrap().to_vec();
        v = out[2].as_f32().unwrap().to_vec();
        step = out[3].scalar().unwrap() as f32;
        losses.push(out[4].scalar().unwrap());
    }
    assert!(losses[4] < losses[0], "no learning: {losses:?}");
    assert_eq!(step, 5.0);
}

#[test]
fn dit_artifacts_dense_and_sparge_agree() {
    let Some(rt) = runtime() else { return };
    let init = sparge::workloads::trace::load(&rt.dir().join("dit_init.spg")).unwrap();
    let params = init.into_iter().next().unwrap().into_vec();
    let n = params.len();
    let mut rng = Pcg::seeded(44);
    let latents = rng.gauss_vec(1152 * 16);

    let run = |name: &str| {
        rt.run(
            name,
            &[
                Value::F32(params.clone(), vec![n]),
                Value::F32(latents.clone(), vec![1152, 16]),
                Value::scalar_f32(0.5),
            ],
        )
        .unwrap()[0]
            .to_tensor()
            .unwrap()
    };
    let dense = run("dit_fwd_dense_1152");
    let sparge_out = run("dit_fwd_sparge_1152");
    let err = rel_l1(&sparge_out, &dense);
    assert!(err < 0.15, "dit sparge-vs-dense rel-L1 {err}");
}

#[test]
fn executor_rejects_wrong_shapes() {
    let Some(rt) = runtime() else { return };
    let exe = rt.executor("attn_dense_1024").unwrap();
    let bad = vec![Value::F32(vec![0.0; 4], vec![2, 2]); 3];
    assert!(exe.run(&bad).is_err());
    let too_few = vec![Value::F32(vec![0.0; 1024 * 64], vec![1024, 64])];
    assert!(exe.run(&too_few).is_err());
}
