//! Cross-language golden test: the Rust Hilbert order must match the
//! Python implementation bit-for-bit (python/tests/test_hilbert.py holds
//! the same constant).

use sparge::sparge::hilbert::{token_order, Permutation};

const GOLDEN_2X4X4: [usize; 32] = [
    0, 4, 20, 16, 17, 21, 5, 1, 2, 3, 19, 18, 22, 23, 7, 6, 10, 11, 15, 14, 30, 31, 27, 26, 25,
    9, 13, 29, 28, 12, 8, 24,
];

#[test]
fn golden_order_2x4x4_matches_python() {
    let order = token_order(Permutation::HilbertCurve, 2, 4, 4, 0);
    assert_eq!(order, GOLDEN_2X4X4.to_vec());
}

#[test]
fn golden_index_values() {
    use sparge::sparge::hilbert::hilbert_index;
    assert_eq!(hilbert_index([0, 0, 0], 2), 0);
    let mut vals: Vec<u128> = Vec::new();
    for a in 0..2 {
        for b in 0..2 {
            for c in 0..2 {
                vals.push(hilbert_index([a, b, c], 1));
            }
        }
    }
    vals.sort_unstable();
    assert_eq!(vals, (0..8).collect::<Vec<u128>>());
}
