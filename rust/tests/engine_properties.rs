//! Cross-module property tests over the pure-Rust engine (no artifacts
//! needed): the paper's semantic invariants at the integration level.

use sparge::attention::types::{AttnConfig, BlockMask, SkipStats};
use sparge::attention::{AttnEngine, SparsityPolicy};
use sparge::baselines;
use sparge::sparge::kernel::SpargeParams;
use sparge::sparge::metrics::rel_l1;
use sparge::sparge::predict::{predict, PredictParams};
use sparge::tensor::Tensor;
use sparge::util::prop::Cases;
use sparge::util::rng::Pcg;
use sparge::workloads::{synthetic, video, SyntheticSpec, VideoSpec};

fn dense_flash(q: &Tensor, k: &Tensor, v: &Tensor, cfg: &AttnConfig) -> Tensor {
    AttnEngine::dense(*cfg).attention(q, k, v).out
}

fn masked(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    mask: &BlockMask,
    cfg: &AttnConfig,
    params: &SpargeParams,
) -> (Tensor, SkipStats) {
    let engine = AttnEngine::builder()
        .config(*cfg)
        .precision(params.precision())
        .policy(SparsityPolicy::External { mask: mask.clone(), lambda: params.lambda })
        .build();
    let r = engine.attention(q, k, v);
    (r.out, r.stats)
}

/// τ monotonicity: lowering τ can only raise (or keep) sparsity and can
/// only raise (or keep) the error.
#[test]
fn tau_monotonicity_on_structured_workloads() {
    Cases::new(9001, 8).check(|rng| {
        let n = 512 + rng.range(0, 4) * 128;
        let s = synthetic::generate(&SyntheticSpec::lm_like(n, 32), rng);
        let cfg = AttnConfig { bq: 64, bk: 32, causal: false, scale: None, cw: 2, row_offset: 0 };
        let dense = dense_flash(&s.q, &s.k, &s.v, &cfg);
        let mut last_sparsity = -1.0f64;
        for tau in [0.99f32, 0.9, 0.7, 0.5] {
            let params = SpargeParams { tau, theta: 0.3, lambda: None, quant: false };
            let res = AttnEngine::sparge(cfg, &params).attention(&s.q, &s.k, &s.v);
            if res.stats.sparsity() + 1e-9 < last_sparsity {
                return Err(format!("sparsity not monotone at tau={tau}"));
            }
            last_sparsity = res.stats.sparsity();
            let _ = rel_l1(&res.out, &dense);
        }
        Ok(())
    });
}

/// Baseline masks through the kernel: every method's output rows remain
/// convex combinations of V rows (|out| bounded by max |V|).
#[test]
fn outputs_bounded_by_value_range() {
    Cases::new(9002, 6).check(|rng| {
        let s = synthetic::generate(&SyntheticSpec::lm_like(256, 16), rng);
        let cfg = AttnConfig { bq: 32, bk: 32, causal: false, scale: None, cw: 2, row_offset: 0 };
        let vmax = s.v.abs_max();
        let masks = [
            baselines::minference_mask(&s.q, &s.k, &cfg, 0.5),
            baselines::flexprefill_mask(&s.q, &s.k, &cfg, 0.9),
            baselines::sliding_window_mask(256, 256, &cfg, 1, 3),
            predict(&s.q, &s.k, &cfg, &PredictParams::default()).mask,
        ];
        let params = SpargeParams { tau: 1.0, theta: -1.0, lambda: None, quant: false };
        for mask in &masks {
            let (out, _) = masked(&s.q, &s.k, &s.v, mask, &cfg, &params);
            if out.abs_max() > vmax + 1e-4 {
                return Err(format!("output {} exceeds value range {}", out.abs_max(), vmax));
            }
        }
        Ok(())
    });
}

/// Permutation invariance: sparge on permuted inputs, un-permuted, equals
/// sparge-quality on the original ordering within the dense-error budget —
/// i.e. attention itself commutes with the permutation (§3.7's premise).
#[test]
fn attention_commutes_with_permutation() {
    let spec = VideoSpec { t: 2, h: 8, w: 8, d: 16, smooth: 0.9, signal: 6.0 };
    let mut rng = Pcg::seeded(9003);
    let s = video::generate_grid(&spec, &mut rng);
    let cfg = AttnConfig { bq: 16, bk: 16, causal: false, scale: None, cw: 2, row_offset: 0 };

    use sparge::sparge::hilbert::{invert_order, permute_rows, token_order, Permutation};
    let dense = dense_flash(&s.q, &s.k, &s.v, &cfg);
    let order = token_order(Permutation::HilbertCurve, 2, 8, 8, 0);
    let ps = video::permute(&s, &spec, Permutation::HilbertCurve, 0);
    let dense_perm = dense_flash(&ps.q, &ps.k, &ps.v, &cfg);
    let back = permute_rows(&dense_perm, &invert_order(&order));
    let err = rel_l1(&back, &dense);
    assert!(err < 1e-5, "dense attention not permutation invariant: {err}");
}

/// Combined-filter dominance: (M_g + λ) sparsity ≥ M_g-only sparsity on
/// any workload/params.
#[test]
fn lambda_only_adds_sparsity() {
    Cases::new(9004, 6).check(|rng| {
        let s = synthetic::generate(&SyntheticSpec::lm_like(384, 16), rng);
        let cfg = AttnConfig { bq: 32, bk: 32, causal: rng.chance(0.5), scale: None, cw: 2, row_offset: 0 };
        let pred = predict(&s.q, &s.k, &cfg, &PredictParams { tau: 0.9, theta: 0.3 });
        let p1 = SpargeParams { tau: 0.9, theta: 0.3, lambda: None, quant: false };
        let p2 = SpargeParams { lambda: Some(-5.0), ..p1 };
        let (_, st1) = masked(&s.q, &s.k, &s.v, &pred.mask, &cfg, &p1);
        let (_, st2) = masked(&s.q, &s.k, &s.v, &pred.mask, &cfg, &p2);
        if st2.sparsity() + 1e-12 < st1.sparsity() {
            return Err(format!("lambda reduced sparsity: {} vs {}", st2.sparsity(), st1.sparsity()));
        }
        Ok(())
    });
}

/// Quantized and f32 kernels agree on structured inputs within the INT8
/// budget, for identical masks.
#[test]
fn quant_and_f32_kernels_agree() {
    Cases::new(9005, 5).check(|rng| {
        let s = synthetic::generate(&SyntheticSpec::lm_like(256, 32), rng);
        let cfg = AttnConfig { bq: 32, bk: 32, causal: false, scale: None, cw: 2, row_offset: 0 };
        let mask = BlockMask::new_all(cfg.n_qblocks(256), cfg.n_kblocks(256), true);
        let base = SpargeParams { tau: 1.0, theta: -1.0, lambda: None, quant: false };
        let (f32_out, _) = masked(&s.q, &s.k, &s.v, &mask, &cfg, &base);
        let (q_out, _) = masked(&s.q, &s.k, &s.v, &mask, &cfg, &SpargeParams { quant: true, ..base });
        let err = rel_l1(&q_out, &f32_out);
        if err > 0.05 {
            return Err(format!("int8 rel-L1 {err}"));
        }
        Ok(())
    });
}
