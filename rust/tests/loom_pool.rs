//! Loom models of the [`WorkerPool`] concurrency protocols.
//!
//! These tests only exist under `--cfg loom`, which swaps the pool's
//! mutex/condvar/threads for loom's model-checked versions (see the
//! `sync` shim in `src/util/threadpool.rs`). Run them with:
//!
//! ```text
//! RUSTFLAGS="--cfg loom" LOOM_MAX_PREEMPTIONS=3 \
//!     cargo test --release --test loom_pool
//! ```
//!
//! Loom executes each model body under every schedule (bounded by
//! `LOOM_MAX_PREEMPTIONS`), so the assertions below are checked against
//! all worker/submitter interleavings, not just the ones a timing-based
//! test happens to hit. Two protocols are under test:
//!
//! - **Chunked self-scheduling claims**: every index of a job runs
//!   exactly once, the submitting thread participates (and
//!   deterministically claims the first chunk — it installs the job and
//!   claims under a single lock hold), and `run_ws` does not return
//!   before all indices finish.
//! - **Per-epoch panic latch**: a panicking index surfaces on *its own*
//!   submitter, the pool stays usable afterwards, and a concurrent
//!   clean submitter never observes a foreign panic.
//!
//! Models stay tiny (pool of 1–2 workers, 2–3 indices) because loom's
//! state space is exponential in threads × synchronization operations;
//! loom's limit is 4 threads per model and these use at most 3.

#![cfg(loom)]

use sparge::util::threadpool::{WorkerPool, Workspace};

use loom::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// The expected-panic models below throw (and catch) panics on every
/// explored schedule; silence just those payloads so a real failure's
/// message is still printed by the default hook.
fn quiet_expected_panics() {
    static HOOK: std::sync::Once = std::sync::Once::new();
    HOOK.call_once(|| {
        let default = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let msg = info
                .payload()
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| info.payload().downcast_ref::<String>().cloned())
                .unwrap_or_default();
            if msg.contains("boom") || msg.contains("WorkerPool job panicked") {
                return;
            }
            default(info);
        }));
    });
}

#[test]
fn chunked_claims_cover_every_index_exactly_once() {
    // Pool of 2 workers + participating submitter, 3 indices: with
    // claim_chunk(3, 3) == 1, every claim is a single index, so all
    // claim/claim and claim/finish races are explored. The claim must
    // hand out each index to exactly one participant, and run_ws must
    // not return until all of them have executed.
    loom::model(|| {
        let pool = WorkerPool::new(2);
        let hits: Vec<AtomicUsize> = (0..3).map(|_| AtomicUsize::new(0)).collect();
        let mut ws = Workspace::default();
        pool.run_ws(3, &mut ws, &|i, _ws| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "index {i} must run exactly once");
        }
        drop(pool);
    });
}

#[test]
fn submitter_participates_and_claims_the_first_chunk() {
    // The submitter installs the job and claims its first chunk under
    // one continuous lock hold, so index 0 lands on the submitting
    // thread on every schedule — observable as a push into the
    // *caller's* workspace (the worker pushes into its own, invisible
    // here). This is the determinism hook the workspace-persistence
    // contract leans on: the caller's arena is always warmed.
    loom::model(|| {
        let pool = WorkerPool::new(1);
        let mut ws = Workspace::default();
        pool.run_ws(2, &mut ws, &|i, ws| ws.pred_idx.push(i));
        assert_eq!(
            ws.pred_idx.first(),
            Some(&0),
            "submitter must claim the first chunk of its own job"
        );
        drop(pool);
    });
}

#[test]
fn panic_latch_reports_to_the_submitter_and_pool_survives() {
    // A panicking index (which may run on the worker or on the
    // participating submitter, depending on the schedule) must turn
    // into a panic out of `run_ws` on the submitting thread, and the
    // job slot must be released so the next job runs to completion.
    quiet_expected_panics();
    loom::model(|| {
        let pool = WorkerPool::new(1);
        let mut ws = Workspace::default();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run_ws(2, &mut ws, &|i, _ws| {
                if i == 1 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err(), "a panicking index must propagate to the submitter");
        let hits = AtomicUsize::new(0);
        pool.run_ws(2, &mut ws, &|_i, _ws| {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 2, "pool must stay usable after a panic");
        drop(pool);
    });
}

#[test]
fn panic_latch_never_misattributes_across_submitters() {
    // Two submitters share one pool (the serving + probe composition):
    // a panicking job must report to the submitter that installed it —
    // keyed by epoch in `panicked_epochs` — and the clean submitter
    // must complete normally on every interleaving of the two jobs.
    // If the latch were a single flag, schedules where the panicking
    // epoch completes around the clean submitter's wait would
    // misattribute; the model proves the epoch-keyed set does not.
    quiet_expected_panics();
    loom::model(|| {
        let pool = Arc::new(WorkerPool::new(1));
        let p = Arc::clone(&pool);
        let panicker = loom::thread::spawn(move || {
            let mut ws = Workspace::default();
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                p.run_ws(2, &mut ws, &|i, _ws| {
                    if i == 1 {
                        panic!("boom");
                    }
                });
            }));
            assert!(r.is_err(), "the panicking job must report to its own submitter");
        });
        let mut ws = Workspace::default();
        let hits = AtomicUsize::new(0);
        pool.run_ws(2, &mut ws, &|_i, _ws| {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 2, "clean job must complete all indices");
        // A join failure here means the panic was misattributed: the
        // panicking submitter saw a clean completion (its entry was
        // consumed by someone else).
        panicker.join().expect("panicking submitter must observe its own panic");
        drop(pool);
    });
}

#[test]
fn caught_fault_domain_delivers_to_its_own_epoch_only() {
    // The serving fault tier's attribution contract: a tick that submits
    // through `run_ws_caught` (the fault-domain entry — panics are
    // collected per index instead of re-raised) must receive exactly the
    // indices that panicked in ITS job, while a concurrent clean
    // submitter's epoch consumes nothing — on every interleaving,
    // including schedules where the faulting job's epoch latches around
    // the clean submitter's wait. A single shared latch (rather than the
    // epoch-keyed `panicked_epochs` set) would fail here by handing the
    // clean epoch the foreign index or by double-delivering it.
    quiet_expected_panics();
    loom::model(|| {
        let pool = Arc::new(WorkerPool::new(1));
        let p = Arc::clone(&pool);
        let faulter = loom::thread::spawn(move || {
            let mut ws = Workspace::default();
            let bad = p.run_ws_caught(2, &mut ws, &|i, _ws| {
                if i == 1 {
                    panic!("boom");
                }
            });
            assert_eq!(bad, vec![1], "the faulting tick must collect exactly its own bad index");
        });
        let mut ws = Workspace::default();
        let hits = AtomicUsize::new(0);
        let clean = pool.run_ws_caught(2, &mut ws, &|_i, _ws| {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert!(clean.is_empty(), "a clean epoch must never absorb a foreign panic");
        assert_eq!(hits.load(Ordering::SeqCst), 2, "clean job must complete all indices");
        faulter.join().expect("faulting submitter must not itself panic — run_ws_caught contains");
        drop(pool);
    });
}
