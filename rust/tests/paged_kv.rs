//! Golden parity + property suite for the paged KV cache
//! (`sparge::attention::paged`): bitwise equivalence between paged and
//! monolithic sessions across the full policy × split × executor matrix,
//! copy-on-write prefix sharing (frame savings with identical outputs),
//! evict → re-page-in parity, free-list exhaustion (deferral, never
//! corruption), and the paged serving manager against the monolithic one.

use std::time::Instant;

use sparge::attention::{
    AttnConfig, AttnEngine, AttnOutput, BlockMask, DiskTier, Execution, KvSplit, MemTier,
    OffloadTier, PageAllocator, Precision, PrefixRegistry, SparsityPolicy,
};
use sparge::coordinator::{run_sequential, AttnStreamSpec, SeqStream, SessionManager};
use sparge::sparge::SpargeParams;
use sparge::tensor::Tensor;
use sparge::util::prop::{assert_allclose, Cases};
use sparge::util::rng::Pcg;

fn qkv(n: usize, d: usize, seed: u64) -> (Tensor, Tensor, Tensor) {
    let mut rng = Pcg::seeded(seed);
    (Tensor::randn(&[n, d], &mut rng), Tensor::randn(&[n, d], &mut rng), Tensor::randn(&[n, d], &mut rng))
}

/// Random full-sequence mask with every block kept at least once per row
/// (decode rows must keep the tail block they append into).
fn decode_safe_mask(seed: u64, rows: usize, cols: usize) -> BlockMask {
    let mut rng = Pcg::seeded(seed);
    let mut mask = BlockMask::new_all(rows, cols, false);
    for i in 0..rows {
        mask.set(i, rng.range(0, cols), true);
        for j in 0..cols {
            if rng.chance(0.5) {
                mask.set(i, j, true);
            }
        }
    }
    mask
}

/// One-shot prefill then per-token decode through a monolithic session.
fn run_mono(engine: &AttnEngine, q: &Tensor, k: &Tensor, v: &Tensor, n0: usize) -> Vec<AttnOutput> {
    let mut session = engine.session();
    let mut outs = Vec::new();
    if n0 > 0 {
        outs.push(session.prefill(&q.rows(0, n0), &k.rows(0, n0), &v.rows(0, n0)));
    }
    for t in n0..q.dim(0) {
        outs.push(session.decode(&q.rows(t, t + 1), &k.rows(t, t + 1), &v.rows(t, t + 1)));
    }
    outs
}

/// The same schedule through a paged session over `alloc`; releases the
/// session's frames before returning.
fn run_paged(
    engine: &AttnEngine,
    alloc: &mut PageAllocator,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    n0: usize,
) -> Vec<AttnOutput> {
    let mut session = engine.paged_session();
    let mut outs = Vec::new();
    if n0 > 0 {
        outs.push(
            session.prefill(alloc, &q.rows(0, n0), &k.rows(0, n0), &v.rows(0, n0)).expect("frames"),
        );
    }
    for t in n0..q.dim(0) {
        outs.push(
            session
                .decode(alloc, &q.rows(t, t + 1), &k.rows(t, t + 1), &v.rows(t, t + 1))
                .expect("frames"),
        );
    }
    session.release(alloc);
    outs
}

#[test]
fn paged_matches_monolithic_bitwise_f32_all_compositions() {
    // The tentpole contract: for f32/λ-off engines the paged session is
    // bitwise-identical to the monolithic one — outputs, SkipStats, and
    // stage-1 masks — for dense / external / predicted policies, split-KV
    // off and auto, and every executor (inline, scoped threads, pools of
    // 1/2/8). 40-row prefill + 32 decode steps per composition.
    let (q, k, v) = qkv(72, 16, 901);
    let cfg = AttnConfig { bq: 16, bk: 8, causal: true, scale: None, cw: 2, row_offset: 0 };
    let n0 = 40;
    let ext_mask = decode_safe_mask(902, cfg.n_qblocks(72), cfg.n_kblocks(72));
    let policies: Vec<(&str, SparsityPolicy)> = vec![
        ("dense", SparsityPolicy::Dense),
        ("external", SparsityPolicy::External { mask: ext_mask, lambda: None }),
        (
            "predicted",
            SparsityPolicy::Predicted {
                params: SpargeParams { tau: 0.9, theta: 0.3, lambda: None, quant: false }
                    .predict_params(),
                lambda: None,
            },
        ),
    ];
    for (label, policy) in &policies {
        for split in [KvSplit::Off, KvSplit::Auto] {
            for exec in
                [Execution::Inline, Execution::Threads(4), Execution::Pool(1), Execution::Pool(2), Execution::Pool(8)]
            {
                let engine = AttnEngine::builder()
                    .config(cfg)
                    .policy(policy.clone())
                    .execution(exec)
                    .kv_split(split)
                    .build();
                let mono = run_mono(&engine, &q, &k, &v, n0);
                let mut alloc = PageAllocator::new(16, 8, 16, 16);
                let paged = run_paged(&engine, &mut alloc, &q, &k, &v, n0);
                assert_eq!(mono.len(), paged.len());
                for (t, (a, b)) in mono.iter().zip(&paged).enumerate() {
                    let tag = format!("{label} {split:?} {exec:?} step {t}");
                    assert_eq!(a.out, b.out, "{tag}: output bits");
                    assert_eq!(a.stats, b.stats, "{tag}: stats bits");
                    assert_eq!(a.mask, b.mask, "{tag}: stage-1 mask");
                }
                alloc.assert_all_free();
            }
        }
    }
}

#[test]
fn paged_int8_allclose_with_exact_stats() {
    // INT8: the paged per-frame payloads are byte-identical to the
    // monolithic per-block ones (blocks quantize independently from the
    // same rows and the same frozen smoothing mean), so stats and masks
    // are exact; outputs are compared allclose per the INT8 contract.
    let (q, k, v) = qkv(72, 16, 903);
    let cfg = AttnConfig { bq: 16, bk: 8, causal: true, scale: None, cw: 2, row_offset: 0 };
    let n0 = 40;
    let policies: Vec<(&str, SparsityPolicy)> = vec![
        ("dense-int8", SparsityPolicy::Dense),
        (
            "predicted-int8",
            SparsityPolicy::Predicted {
                params: SpargeParams { tau: 0.9, theta: 0.3, lambda: None, quant: true }
                    .predict_params(),
                lambda: None,
            },
        ),
    ];
    for (label, policy) in &policies {
        for split in [KvSplit::Off, KvSplit::Auto] {
            let engine = AttnEngine::builder()
                .config(cfg)
                .precision(Precision::Int8)
                .policy(policy.clone())
                .kv_split(split)
                .build();
            let mono = run_mono(&engine, &q, &k, &v, n0);
            let mut alloc = PageAllocator::new(16, 8, 16, 16).with_quant();
            let paged = run_paged(&engine, &mut alloc, &q, &k, &v, n0);
            for (t, (a, b)) in mono.iter().zip(&paged).enumerate() {
                let tag = format!("{label} {split:?} step {t}");
                assert_allclose(b.out.data(), a.out.data(), 1e-4, 1e-3, &tag).unwrap();
                assert_eq!(a.stats, b.stats, "{tag}: stats must be exact");
                assert_eq!(a.mask, b.mask, "{tag}: stage-1 mask must be exact");
            }
            alloc.assert_all_free();
        }
    }
}

#[test]
fn prefix_sharing_saves_frames_and_keeps_outputs_bitwise() {
    // Two sessions opened from the same 36-row prompt (partial tail
    // frame: 36 = 4×8 + 4) must map the SAME frames — the second prefill
    // claims zero new frames and skips its compute — while both sessions'
    // prefill and divergent decode outputs stay bitwise-identical to
    // private monolithic sessions. The first divergent append CoW-splits
    // only the partial tail frame.
    let d = 16;
    let prompt = 36;
    let steps = 6;
    let (qa, ka, va) = qkv(prompt + steps, d, 911);
    let (qb_full, kb_full, vb_full) = qkv(prompt + steps, d, 912);
    // stream B shares A's prompt rows, then diverges
    let splice = |shared: &Tensor, own: &Tensor| {
        let mut flat = shared.rows(0, prompt).data().to_vec();
        flat.extend_from_slice(own.rows(prompt, prompt + steps).data());
        Tensor::from_vec(&[prompt + steps, d], flat)
    };
    let (qb, kb, vb) = (splice(&qa, &qb_full), splice(&ka, &kb_full), splice(&va, &vb_full));

    let cfg = AttnConfig { bq: 8, bk: 8, causal: true, scale: None, cw: 2, row_offset: 0 };
    let engine = AttnEngine::builder()
        .config(cfg)
        .policy(SparsityPolicy::Predicted {
            params: SpargeParams { tau: 0.9, theta: 0.3, lambda: None, quant: false }.predict_params(),
            lambda: None,
        })
        .build();
    let mono_a = run_mono(&engine, &qa, &ka, &va, prompt);
    let mono_b = run_mono(&engine, &qb, &kb, &vb, prompt);

    let mut alloc = PageAllocator::new(24, 8, d, d);
    let mut reg = PrefixRegistry::new();
    let mut s1 = engine.paged_session();
    let mut s2 = engine.paged_session();
    let pq = qa.rows(0, prompt);
    let pk = ka.rows(0, prompt);
    let pv = va.rows(0, prompt);
    let r1 = s1.prefill_shared(&mut alloc, &mut reg, &pq, &pk, &pv).expect("frames");
    let solo_frames = alloc.stats().frames_in_use;
    assert_eq!(solo_frames, 5, "36 rows under b_k=8 occupy 5 frames");
    let r2 = s2.prefill_shared(&mut alloc, &mut reg, &pq, &pk, &pv).expect("frames");
    // measurably fewer than 2× solo: the second prompt claims NO frames
    assert_eq!(alloc.stats().frames_in_use, solo_frames, "prefix hit maps the same frames");
    assert_eq!(alloc.stats().prefix_hits, 1);
    assert_eq!(r1.out, mono_a[0].out, "lender prefill bits");
    assert_eq!(r2.out, mono_a[0].out, "borrower adopts the cached prefill bitwise");
    assert_eq!(r1.stats, mono_a[0].stats);
    assert_eq!(r2.stats, mono_a[0].stats);

    // divergent decode: each session's first append CoW-splits the shared
    // partial tail frame; outputs track each stream's private baseline
    for (t, step) in (prompt..prompt + steps).enumerate() {
        let oa = s1
            .decode(&mut alloc, &qa.rows(step, step + 1), &ka.rows(step, step + 1), &va.rows(step, step + 1))
            .expect("frames");
        let ob = s2
            .decode(&mut alloc, &qb.rows(step, step + 1), &kb.rows(step, step + 1), &vb.rows(step, step + 1))
            .expect("frames");
        assert_eq!(oa.out, mono_a[1 + t].out, "lender decode step {t} bits");
        assert_eq!(ob.out, mono_b[1 + t].out, "borrower decode step {t} bits");
        assert_eq!(oa.stats, mono_a[1 + t].stats);
        assert_eq!(ob.stats, mono_b[1 + t].stats);
    }
    assert_eq!(alloc.stats().cow_splits, 2, "one CoW split per diverging writer");

    s1.release(&mut alloc);
    s2.release(&mut alloc);
    reg.clear(&mut alloc);
    alloc.assert_all_free();
}

#[test]
fn prefix_hit_requires_matching_query_rows() {
    // Attention output is a function of Q: two prompts with identical
    // K/V but different Q must NOT adopt each other's cached prefill
    // output — the prefix key covers the query rows too, so the second
    // prompt misses, computes its own (correct) rows, and registers a
    // separate entry. A replay with the first prompt's exact Q still
    // hits.
    let d = 16;
    let n = 20;
    let (qa, k, v) = qkv(n, d, 941);
    let mut rng = Pcg::seeded(942);
    let qb = Tensor::randn(&[n, d], &mut rng);
    let cfg = AttnConfig { bq: 8, bk: 8, causal: true, scale: None, cw: 2, row_offset: 0 };
    let engine = AttnEngine::builder().config(cfg).build();
    let ma = engine.session().prefill(&qa, &k, &v);
    let mb = engine.session().prefill(&qb, &k, &v);
    assert_ne!(ma.out, mb.out, "distinct Q must give distinct baselines");

    let mut alloc = PageAllocator::new(16, 8, d, d);
    let mut reg = PrefixRegistry::new();
    let mut s1 = engine.paged_session();
    let mut s2 = engine.paged_session();
    let r1 = s1.prefill_shared(&mut alloc, &mut reg, &qa, &k, &v).expect("frames");
    let r2 = s2.prefill_shared(&mut alloc, &mut reg, &qb, &k, &v).expect("frames");
    assert_eq!(r1.out, ma.out, "lender prefill bits");
    assert_eq!(r2.out, mb.out, "same K/V with different Q must not adopt the lender's output");
    assert_eq!(alloc.stats().prefix_hits, 0, "Q participates in the prefix key");
    assert_eq!(reg.len(), 2, "the Q-mismatched prompt registers its own entry");

    // bit-identical replay of the first prompt still shares
    let mut s3 = engine.paged_session();
    let r3 = s3.prefill_shared(&mut alloc, &mut reg, &qa, &k, &v).expect("frames");
    assert_eq!(r3.out, ma.out);
    assert_eq!(alloc.stats().prefix_hits, 1);

    s1.release(&mut alloc);
    s2.release(&mut alloc);
    s3.release(&mut alloc);
    reg.clear(&mut alloc);
    alloc.assert_all_free();
}

#[test]
fn mid_tick_append_half_is_never_evicted() {
    // Regression (high): under frame exhaustion the LRU eviction cascade
    // must never spill a session that already ran its serial append half
    // this tick — its batched compute half is still pending and would
    // run `decode_step` over an empty page table. Construction: A and B
    // share a two-frame prompt whose full first frame stays shared for
    // both lifetimes (so `PrefixRegistry::shed` can't rescue the pool),
    // C is admitted one tick later and its claims consume the admission
    // slack; the unreserved CoW/boundary claims then exhaust the free
    // list mid-tick and the cascade (A starves → evicts B → B's
    // re-page-in starves → only mid-step sessions remain) must load-shed
    // instead of evicting a session between its halves. Every stream
    // must still retire with the sequential baseline's exact bits.
    let cfg = AttnConfig { bq: 8, bk: 8, causal: true, scale: None, cw: 2, row_offset: 0 };
    let engine = AttnEngine::builder().config(cfg).build();
    let shared = AttnStreamSpec { prefill: 12, decode: 8, d: 16, seed: 951, ..Default::default() };
    let other = AttnStreamSpec { prefill: 16, decode: 8, d: 16, seed: 952, ..Default::default() };
    let specs = [shared, shared, other];
    let sequential: Vec<_> = specs
        .iter()
        .enumerate()
        .map(|(i, s)| run_sequential(&engine, i as u64, &SeqStream::synth(s)))
        .collect();

    let mut mgr = SessionManager::new_paged(&engine, 16, PageAllocator::new(7, 8, 16, 16));
    let t0 = Instant::now();
    mgr.admit(0, SeqStream::synth(&specs[0]), t0);
    mgr.admit(1, SeqStream::synth(&specs[1]), t0);
    let mut done = mgr.tick(); // A prefills (2 frames), B prefix-hits
    mgr.admit(2, SeqStream::synth(&specs[2]), t0);
    for _ in 0..10_000 {
        done.extend(mgr.tick());
        if mgr.active() == 0 && mgr.pending() == 0 {
            break;
        }
    }
    assert!(mgr.active() == 0 && mgr.pending() == 0, "manager failed to drain under pressure");
    done.sort_by_key(|r| r.id);
    assert_eq!(done.len(), specs.len());
    for (m, s) in done.iter().zip(&sequential) {
        assert_eq!(m.out, s.out, "eviction cascade changed output bits (id {})", m.id);
        assert_eq!(m.stats, s.stats, "eviction cascade changed stats (id {})", m.id);
    }
    let ps = mgr.page_stats().expect("page stats");
    assert!(ps.evictions > 0, "the scenario must actually exercise LRU eviction");
    assert!(ps.load_sheds > 0, "the cascade must shed when only mid-step sessions remain");
    mgr.release_prefixes();
    mgr.assert_frames_all_free();
}

#[test]
fn evict_and_repage_in_decode_is_bitwise() {
    // A session evicted mid-decode (frames spilled and released) must,
    // after transparent re-page-in, keep producing the exact bits of a
    // never-evicted paged session and of the monolithic baseline —
    // including the predictor's pooled state, which pages with the
    // frames. INT8 re-page-in requantizes from the restored rows, which
    // is byte-identical, so the INT8 run is compared exactly against its
    // own never-evicted twin.
    let (q, k, v) = qkv(64, 16, 921);
    let cfg = AttnConfig { bq: 16, bk: 8, causal: true, scale: None, cw: 2, row_offset: 0 };
    let n0 = 32;
    let predicted = SparsityPolicy::Predicted {
        params: SpargeParams { tau: 0.9, theta: 0.3, lambda: None, quant: false }.predict_params(),
        lambda: None,
    };
    let engine = AttnEngine::builder().config(cfg).policy(predicted).build();
    let mono = run_mono(&engine, &q, &k, &v, n0);

    let mut alloc = PageAllocator::new(16, 8, 16, 16);
    let mut session = engine.paged_session();
    let mut outs = Vec::new();
    outs.push(session.prefill(&mut alloc, &q.rows(0, n0), &k.rows(0, n0), &v.rows(0, n0)).expect("frames"));
    for t in n0..q.dim(0) {
        if t == n0 + 16 {
            session.evict(&mut alloc);
            assert!(session.is_evicted());
            assert_eq!(alloc.stats().frames_in_use, 0, "eviction returns every frame");
        }
        outs.push(
            session
                .decode(&mut alloc, &q.rows(t, t + 1), &k.rows(t, t + 1), &v.rows(t, t + 1))
                .expect("frames"),
        );
    }
    assert_eq!(alloc.stats().evictions, 1);
    for (t, (a, b)) in mono.iter().zip(&outs).enumerate() {
        assert_eq!(a.out, b.out, "evicted run step {t} output bits");
        assert_eq!(a.stats, b.stats, "evicted run step {t} stats bits");
        assert_eq!(a.mask, b.mask, "evicted run step {t} mask");
    }
    session.release(&mut alloc);
    alloc.assert_all_free();

    // INT8: evicted vs never-evicted paged twins must agree exactly
    let engine8 = AttnEngine::builder().config(cfg).precision(Precision::Int8).build();
    let mut alloc_a = PageAllocator::new(16, 8, 16, 16).with_quant();
    let plain = run_paged(&engine8, &mut alloc_a, &q, &k, &v, n0);
    let mut alloc_b = PageAllocator::new(16, 8, 16, 16).with_quant();
    let mut s8 = engine8.paged_session();
    let mut evicted = Vec::new();
    evicted.push(s8.prefill(&mut alloc_b, &q.rows(0, n0), &k.rows(0, n0), &v.rows(0, n0)).expect("frames"));
    for t in n0..q.dim(0) {
        if t == n0 + 7 {
            s8.evict(&mut alloc_b);
        }
        evicted.push(
            s8.decode(&mut alloc_b, &q.rows(t, t + 1), &k.rows(t, t + 1), &v.rows(t, t + 1))
                .expect("frames"),
        );
    }
    for (t, (a, b)) in plain.iter().zip(&evicted).enumerate() {
        assert_eq!(a.out, b.out, "int8 evict/repage step {t}: requantized payloads must match");
        assert_eq!(a.stats, b.stats);
    }
    s8.release(&mut alloc_b);
    alloc_a.assert_all_free();
    alloc_b.assert_all_free();
}

#[test]
fn suspend_and_resume_mid_decode_is_bitwise() {
    // The preemption tentpole contract: a session suspended mid-decode
    // (payload checkpointed to an offload tier, every frame released)
    // must, after resume, keep producing the exact bits of the
    // monolithic baseline — for f32/λ-off across every executor, through
    // both the in-memory tier and the checksummed on-disk tier.
    let (q, k, v) = qkv(64, 16, 941);
    let cfg = AttnConfig { bq: 16, bk: 8, causal: true, scale: None, cw: 2, row_offset: 0 };
    let n0 = 32;
    let predicted = SparsityPolicy::Predicted {
        params: SpargeParams { tau: 0.9, theta: 0.3, lambda: None, quant: false }.predict_params(),
        lambda: None,
    };
    let execs = [
        Execution::Inline,
        Execution::Threads(4),
        Execution::Pool(1),
        Execution::Pool(2),
        Execution::Pool(8),
    ];
    for (ei, exec) in execs.into_iter().enumerate() {
        let engine =
            AttnEngine::builder().config(cfg).policy(predicted.clone()).execution(exec).build();
        let mono = run_mono(&engine, &q, &k, &v, n0);
        for disk in [false, true] {
            let mut tier: Box<dyn OffloadTier> = if disk {
                Box::new(DiskTier::scratch(&format!("pin-{ei}")).expect("temp dir"))
            } else {
                Box::new(MemTier::new())
            };
            // 8 frames holds the 64-row stream exactly; 16 is roomy
            for frames in [8, 16] {
                let mut alloc = PageAllocator::new(frames, 8, 16, 16);
                let mut session = engine.paged_session();
                let mut outs = Vec::new();
                outs.push(
                    session
                        .prefill(&mut alloc, &q.rows(0, n0), &k.rows(0, n0), &v.rows(0, n0))
                        .expect("frames"),
                );
                for t in n0..q.dim(0) {
                    if t == n0 + 16 {
                        assert!(
                            session.suspend(&mut alloc, 7, tier.as_mut()),
                            "suspend must checkpoint (disk={disk})"
                        );
                        assert!(session.is_suspended());
                        assert_eq!(
                            alloc.stats().frames_in_use,
                            0,
                            "suspension returns every frame"
                        );
                        assert!(
                            session.resume(&mut alloc, 7, tier.as_mut()).expect("tier load"),
                            "an empty pool must cover the re-page-in"
                        );
                        assert!(!session.is_suspended());
                        tier.discard(7);
                    }
                    outs.push(
                        session
                            .decode(&mut alloc, &q.rows(t, t + 1), &k.rows(t, t + 1), &v.rows(t, t + 1))
                            .expect("frames"),
                    );
                }
                for (t, (a, b)) in mono.iter().zip(&outs).enumerate() {
                    assert_eq!(a.out, b.out, "suspend/resume step {t} output bits (disk={disk})");
                    assert_eq!(a.stats, b.stats, "suspend/resume step {t} stats (disk={disk})");
                    assert_eq!(a.mask, b.mask, "suspend/resume step {t} mask (disk={disk})");
                }
                session.release(&mut alloc);
                alloc.assert_all_free();
            }
        }
    }
}

#[test]
fn free_list_exhaustion_defers_and_never_corrupts() {
    // Property: with a pool far smaller than the offered load, appends
    // return `false`/`None` (state untouched) instead of panicking or
    // corrupting, retrying the SAME token after frames free up yields the
    // bits the monolithic baseline produces, and refcount accounting
    // returns the pool to empty.
    Cases::standard(931).check(|rng| {
        let d = rng.range(2, 10);
        let bk = rng.range(1, 5);
        let frames = rng.range(2, 6);
        let cfg = AttnConfig { bq: 4, bk, causal: true, scale: None, cw: 2, row_offset: 0 };
        let engine = AttnEngine::builder().config(cfg).build();
        // session A alone needs the whole pool, so decoding alongside B
        // (which claims at least one frame) MUST starve A at some point
        let tokens = frames * bk;
        let mk_stream = |seed: u64| {
            let mut r = Pcg::seeded(seed);
            (
                Tensor::randn(&[tokens, d], &mut r),
                Tensor::randn(&[tokens, d], &mut r),
                Tensor::randn(&[tokens, d], &mut r),
            )
        };
        let (qa, ka, va) = mk_stream(rng.range(1, 1 << 20) as u64);
        let (qb, kb, vb) = mk_stream(rng.range(1, 1 << 20) as u64);
        let mono = run_mono(&engine, &qa, &ka, &va, 0);

        let mut alloc = PageAllocator::new(frames, bk, d, d);
        let mut sa = engine.paged_session();
        let mut sb = engine.paged_session();
        let (mut ta, mut tb) = (0usize, 0usize);
        let mut starved = false;
        // round-robin decode; when A starves, release B and retry the SAME
        // token — the retry must produce exactly the monolithic bits
        for _ in 0..4 * tokens + 8 {
            if ta == tokens {
                break;
            }
            let rows_before = sa.len();
            match sa.decode(&mut alloc, &qa.rows(ta, ta + 1), &ka.rows(ta, ta + 1), &va.rows(ta, ta + 1))
            {
                Some(out) => {
                    if out.out != mono[ta].out || out.stats != mono[ta].stats {
                        return Err(format!("session A diverged at token {ta}"));
                    }
                    ta += 1;
                }
                None => {
                    if sa.len() != rows_before {
                        return Err("failed append mutated the session".into());
                    }
                    starved = true;
                    sb.release(&mut alloc);
                    tb = tokens; // B stops decoding (its cache is gone)
                }
            }
            if tb < tokens
                && sb
                    .decode(&mut alloc, &qb.rows(tb, tb + 1), &kb.rows(tb, tb + 1), &vb.rows(tb, tb + 1))
                    .is_some()
            {
                tb += 1;
            }
        }
        if !starved {
            return Err("pool never exhausted — the property tested nothing".into());
        }
        if ta != tokens {
            return Err(format!("session A starved permanently at {ta}/{tokens}"));
        }
        sa.release(&mut alloc);
        sb.release(&mut alloc);
        if alloc.stats().frames_in_use != 0 {
            return Err("frames leaked".into());
        }
        if alloc.free_frames() != frames {
            return Err("free list incomplete".into());
        }
        alloc.assert_all_free();
        Ok(())
    });
}

/// Drive a manager until idle, admitting everything up front.
fn drain(mgr: &mut SessionManager<'_>, specs: &[AttnStreamSpec]) -> Vec<sparge::coordinator::SeqResult> {
    for (i, s) in specs.iter().enumerate() {
        mgr.admit(i as u64, SeqStream::synth(s), Instant::now());
    }
    let mut done = Vec::new();
    for _ in 0..10_000 {
        done.extend(mgr.tick());
        if mgr.active() == 0 && mgr.pending() == 0 {
            break;
        }
    }
    assert!(mgr.active() == 0 && mgr.pending() == 0, "manager failed to drain");
    done.sort_by_key(|r| r.id);
    done
}

#[test]
fn paged_manager_matches_monolithic_manager_bitwise() {
    // Serving-level acceptance: the paged manager (frame pool + prefix
    // registry + frame-aware admission) reproduces the monolithic
    // manager's outputs and stats bitwise for an f32/λ-off predicted
    // engine — including two identical prompts, where the second rides
    // the prefix registry instead of recomputing its prefill.
    let cfg = AttnConfig { bq: 16, bk: 8, causal: true, scale: None, cw: 2, row_offset: 0 };
    let params = SpargeParams { tau: 0.9, theta: 0.3, lambda: None, quant: false };
    let engine =
        AttnEngine::builder().config(cfg).sparge(&params).execution(Execution::Pool(2)).build();
    let spec = |prefill, decode, seed| AttnStreamSpec { prefill, decode, d: 16, seed, ..Default::default() };
    let specs = [
        spec(40, 8, 51),
        spec(16, 6, 52),
        spec(0, 6, 53),
        spec(16, 6, 52), // identical to #1: exercises the prefix registry
        spec(33, 5, 54),
    ];
    let mut mono_mgr = SessionManager::new(&engine, 16);
    let mono = drain(&mut mono_mgr, &specs);
    let mut paged_mgr = SessionManager::new_paged(&engine, 16, PageAllocator::new(64, 8, 16, 16));
    let paged = drain(&mut paged_mgr, &specs);
    assert_eq!(mono.len(), paged.len());
    for (a, b) in mono.iter().zip(&paged) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.out, b.out, "paged manager diverged (id {})", a.id);
        assert_eq!(a.stats, b.stats, "paged manager stats diverged (id {})", a.id);
        assert_eq!(a.tokens, b.tokens);
    }
    let ps = paged_mgr.page_stats().expect("paged manager has page stats");
    assert_eq!(ps.prefix_hits, 1, "the duplicate prompt hits the registry");
    paged_mgr.release_prefixes();
    paged_mgr.assert_frames_all_free();
}

#[test]
fn paged_manager_defers_admission_under_frame_pressure() {
    // A pool that holds barely more than one stream: admission must
    // defer (load-shed counter, not a panic or an OOM), evict idle
    // sessions to make room, and still retire every stream with the
    // sequential baseline's bits.
    let cfg = AttnConfig { bq: 8, bk: 8, causal: true, scale: None, cw: 2, row_offset: 0 };
    let engine = AttnEngine::builder().config(cfg).build();
    let spec = |seed| AttnStreamSpec { prefill: 16, decode: 8, d: 16, seed, ..Default::default() };
    let specs = [spec(61), spec(62), spec(63)];
    let sequential: Vec<_> = specs
        .iter()
        .enumerate()
        .map(|(i, s)| run_sequential(&engine, i as u64, &SeqStream::synth(s)))
        .collect();
    // each stream needs ceil(24/8) = 3 frames; 4 frames ≈ 1.3 streams
    let mut mgr = SessionManager::new_paged(&engine, 64, PageAllocator::new(4, 8, 16, 16));
    let done = drain(&mut mgr, &specs);
    assert_eq!(done.len(), specs.len());
    for (m, s) in done.iter().zip(&sequential) {
        assert_eq!(m.out, s.out, "deferred admission changed output bits (id {})", m.id);
        assert_eq!(m.stats, s.stats);
    }
    let ps = mgr.page_stats().expect("page stats");
    assert!(ps.load_sheds > 0, "a 4-frame pool must shed under 3×3-frame load");
    assert!(ps.peak_frames <= 4, "admission never oversubscribed the pool");
    mgr.release_prefixes();
    mgr.assert_frames_all_free();
}
