//! Integration over the continuous-batching serving loop, artifact-free:
//! a kernel-only [`Coordinator`] (no PJRT engine) serves attention-stream
//! requests through the SessionManager — chunked offset-aware prefill,
//! per-tick decode, TTFT/TPOT metrics, and the `attn`/`serve` server op.
//! Unlike `coordinator_integration.rs`, every test here runs in CI.

use std::sync::Arc;
use std::time::Duration;

use sparge::attention::{AttnConfig, AttnEngine, Execution};
use sparge::coordinator::{
    run_sequential, AttnMode, AttnStreamSpec, BatchPolicy, Coordinator, SeqStream, ServeOptions,
};
use sparge::sparge::SpargeParams;

fn opts() -> ServeOptions {
    // small geometry so tests stay fast; bk | bq keeps chunked prefill
    // bitwise-faithful for the predicted policy too
    ServeOptions {
        chunk: 32,
        params: SpargeParams { tau: 0.9, theta: 0.3, lambda: None, quant: false },
        cfg: AttnConfig { bq: 16, bk: 8, causal: true, scale: None, cw: 2, row_offset: 0 },
        threads: 2,
        kv_split: sparge::attention::KvSplit::Auto,
        fault: None,
        paged: None,
    }
}

fn spec(prefill: usize, decode: usize, seed: u64) -> AttnStreamSpec {
    AttnStreamSpec { prefill, decode, d: 16, seed, ..Default::default() }
}

#[test]
fn stream_roundtrip_records_serving_metrics() {
    let c = Coordinator::start_kernel(BatchPolicy::default(), opts());
    let resp = c.serve_stream(spec(48, 6, 41)).unwrap();
    assert_eq!(resp.tokens, 6);
    assert!(resp.output.is_empty());
    let ttft = resp.ttft.expect("stream reports ttft");
    assert!(ttft > 0.0 && resp.latency >= ttft);
    assert!(resp.tpot.expect("stream reports tpot") > 0.0);
    let sparsity = resp.sparsity.expect("stream reports sparsity");
    assert!((0.0..=1.0).contains(&sparsity));
    let snap = c.metrics.snapshot();
    assert_eq!(snap.requests, 1);
    assert_eq!(snap.tokens_out, 6);
    assert_eq!(snap.sparse_requests, 1);
    assert_eq!(snap.ttft_count, 1);
    assert_eq!(snap.tpot_count, 5, "tokens after the first record tpot");
    assert!(snap.ttft_p50 > 0.0 && snap.tpot_p50 > 0.0);
    c.shutdown();
}

#[test]
fn concurrent_streams_are_fully_served() {
    let c = Arc::new(Coordinator::start_kernel(
        BatchPolicy { max_batch: 3, max_wait: Duration::from_millis(5), ..Default::default() },
        opts(),
    ));
    let mut rxs = Vec::new();
    for i in 0..8 {
        rxs.push(c.submit_stream(spec(24 + 8 * i, 4, 100 + i as u64), AttnMode::Sparge).unwrap());
    }
    let mut ids = Vec::new();
    for rx in rxs {
        let resp = rx.recv().unwrap();
        assert_eq!(resp.tokens, 4);
        ids.push(resp.id);
    }
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), 8, "duplicate or lost responses");
    assert_eq!(c.metrics.snapshot().requests, 8);
}

#[test]
fn continuous_loop_with_max_batch_1_reproduces_sequential_outputs() {
    // The acceptance criterion at the coordinator level: with one active
    // slot, the loop's chunked execution must reproduce the sequential
    // baseline's sparsity (stats are bitwise through the loop — outputs
    // are golden-tested at the SessionManager layer, which exposes rows).
    let o = opts();
    let engine = AttnEngine::builder()
        .config(o.cfg)
        .sparge(&o.params)
        .execution(Execution::Pool(o.threads))
        .build();
    let specs = [spec(40, 5, 7), spec(33, 3, 8), spec(64, 2, 9)];
    let c = Coordinator::start_kernel(
        BatchPolicy { max_batch: 1, max_wait: Duration::from_millis(1), ..Default::default() },
        o,
    );
    for (i, s) in specs.iter().enumerate() {
        let resp = c.serve_stream(*s).unwrap();
        let baseline = run_sequential(&engine, i as u64, &SeqStream::synth(s));
        assert_eq!(
            resp.sparsity.unwrap(),
            baseline.stats.sparsity(),
            "stream {i} sparsity diverged from the sequential baseline"
        );
        assert_eq!(resp.tokens, baseline.tokens);
    }
}

#[test]
fn serve_op_reports_per_session_latencies() {
    let c = Arc::new(Coordinator::start_kernel(BatchPolicy::default(), opts()));
    let resp = sparge::coordinator::server::dispatch(
        &c,
        r#"{"op":"attn","mode":"serve","sessions":3,"n":32,"steps":4,"d":16,"seed":5}"#,
    );
    assert_eq!(resp.get("mode").and_then(|v| v.as_str()), Some("serve"));
    let sessions = resp.get("sessions").and_then(|v| v.as_arr()).expect("sessions array");
    assert_eq!(sessions.len(), 3);
    for s in sessions {
        assert!(s.get("ttft_ms").and_then(|v| v.as_f64()).unwrap() > 0.0);
        assert!((0.0..=1.0).contains(&s.get("sparsity").and_then(|v| v.as_f64()).unwrap()));
        assert_eq!(s.get("tokens").and_then(|v| v.as_usize()), Some(4));
    }
    assert!(resp.get("tokens_per_sec").and_then(|v| v.as_f64()).unwrap() > 0.0);
    // stats op surfaces the token-latency reservoirs
    let stats = sparge::coordinator::server::dispatch(&c, r#"{"op":"stats"}"#);
    assert_eq!(stats.get("ttft_count").and_then(|v| v.as_f64()), Some(3.0));
    assert!(stats.get("tpot_p50_ms").and_then(|v| v.as_f64()).unwrap() > 0.0);
    assert!(stats.get("ttft_p99_ms").and_then(|v| v.as_f64()).unwrap() > 0.0);
}

#[test]
fn mixed_queue_drains_on_shutdown() {
    // Streams queued beyond the active cap must all be served before
    // shutdown returns (close → drain → retire → join).
    let c = Coordinator::start_kernel(
        BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(1), ..Default::default() },
        opts(),
    );
    let rxs: Vec<_> =
        (0..6).map(|i| c.submit_stream(spec(16, 2, 200 + i), AttnMode::Sparge).unwrap()).collect();
    c.shutdown();
    for rx in rxs {
        let resp = rx.recv().expect("request dropped during shutdown");
        assert_eq!(resp.tokens, 2);
    }
}
