//! Golden + property suite for the split-KV (Flash-Decoding) serving
//! path: `run_tiled_splitkv` vs the serial driver, the determinism
//! contract (bitwise across exec modes and pool sizes for a fixed span
//! size — S comes from the cache length, never the worker count), λ
//! span-locality, and session-level decode parity for every precision ×
//! filter composition.

use sparge::attention::{
    run_tiled, run_tiled_splitkv, AttnConfig, AttnEngine, AttnOutput, BlockMask, Exec, Execution,
    F32Kernel, KvSplit, MaskFilter, Precision, SparsityPolicy,
};
use sparge::sparge::SpargeParams;
use sparge::tensor::Tensor;
use sparge::util::prop::{assert_allclose, rel_l1, Cases};
use sparge::util::rng::Pcg;
use sparge::util::threadpool::WorkerPool;

fn qkv(n: usize, d: usize, seed: u64) -> (Tensor, Tensor, Tensor) {
    let mut rng = Pcg::seeded(seed);
    (Tensor::randn(&[n, d], &mut rng), Tensor::randn(&[n, d], &mut rng), Tensor::randn(&[n, d], &mut rng))
}

/// Random mask with at least one kept block per row.
fn random_mask(rng: &mut Pcg, rows: usize, cols: usize) -> BlockMask {
    let mut mask = BlockMask::new_all(rows, cols, false);
    for i in 0..rows {
        mask.set(i, rng.range(0, cols), true);
        for j in 0..cols {
            if rng.chance(0.6) {
                mask.set(i, j, true);
            }
        }
    }
    mask
}

#[test]
fn splitkv_matches_serial_driver_with_masks_and_offsets() {
    // The core property: for any n/bq/bk/cw/row_offset/causal geometry,
    // any span size, and a random stage-1 mask, the split driver is
    // allclose to the serial one and (λ off) its span-summed SkipStats
    // are *exactly* the serial counters.
    Cases::standard(811).check(|rng| {
        let n = rng.range(1, 60);
        let d = 8;
        let cfg = AttnConfig {
            bq: rng.range(1, 18),
            bk: rng.range(1, 18),
            causal: rng.chance(0.5),
            scale: None,
            cw: rng.range(1, 4),
            row_offset: if rng.chance(0.5) { rng.range(0, 30) } else { 0 },
        };
        let span = rng.range(1, 6);
        let nk = n + cfg.row_offset;
        let q = Tensor::randn(&[n, d], rng);
        let k = Tensor::randn(&[nk, d], rng);
        let v = Tensor::randn(&[nk, d], rng);
        let mask = random_mask(rng, cfg.n_qblocks(n), cfg.n_kblocks(nk));
        let kernel = F32Kernel::new(&q, &k, &cfg);
        let filter = MaskFilter::new(&mask, None);
        let (serial, st_serial) = run_tiled(&q, &k, &v, &cfg, &kernel, &filter, Exec::Inline);
        let (split, st_split) = run_tiled_splitkv(&q, &k, &v, &cfg, &kernel, &filter, Exec::Inline, span);
        if st_serial != st_split {
            return Err(format!("stats not exact: {st_serial:?} vs {st_split:?}"));
        }
        assert_allclose(split.data(), serial.data(), 1e-4, 1e-3, "splitkv-vs-serial")
    });
}

#[test]
fn splitkv_bitwise_across_exec_modes_and_pool_sizes() {
    // The determinism contract: S is derived from the cache length, so a
    // fixed span size must give identical bits under Inline, scoped
    // threads, and pools of size 1/2/8 — λ on, to cover the stage-2
    // accounting too.
    let (_, k, v) = qkv(96, 16, 812);
    let q = Tensor::randn(&[1, 16], &mut Pcg::seeded(813));
    let cfg = AttnConfig { bq: 16, bk: 8, causal: false, scale: None, cw: 2, row_offset: 0 };
    let kernel = F32Kernel::new(&q, &k, &cfg);
    let mask = BlockMask::new_all(1, cfg.n_kblocks(96), true);
    let filter = MaskFilter::new(&mask, Some(-4.0));
    for span in [1usize, 2, 3, 5] {
        let (base, st_base) = run_tiled_splitkv(&q, &k, &v, &cfg, &kernel, &filter, Exec::Inline, span);
        for pool_size in [1usize, 2, 8] {
            let pool = WorkerPool::new(pool_size);
            let (o, s) = run_tiled_splitkv(&q, &k, &v, &cfg, &kernel, &filter, Exec::Pool(&pool), span);
            assert_eq!(o, base, "span {span} pool {pool_size} output bits");
            assert_eq!(s, st_base, "span {span} pool {pool_size} stats bits");
        }
        let (o, s) = run_tiled_splitkv(&q, &k, &v, &cfg, &kernel, &filter, Exec::Threads(4), span);
        assert_eq!(o, base, "span {span} threads output bits");
        assert_eq!(s, st_base, "span {span} threads stats bits");
    }
}

#[test]
fn lambda_span_locality_is_conservative_and_deterministic() {
    // Stage-2 λ thresholds against the span-local running maximum, which
    // is ≤ the serial running maximum — so every group a span skips, the
    // serial pass also skips: pv_skipped_frac(split) ≤ pv_skipped_frac
    // (serial), and the split value is identical across exec modes.
    let pool = WorkerPool::new(4);
    Cases::standard(814).check(|rng| {
        let n = rng.range(8, 80);
        let d = 8;
        let cfg = AttnConfig {
            bq: rng.range(2, 18),
            bk: rng.range(2, 18),
            causal: rng.chance(0.5),
            scale: None,
            cw: rng.range(1, 4),
            row_offset: 0,
        };
        let span = rng.range(1, 4);
        let mut q = Tensor::randn(&[n, d], rng);
        let k = Tensor::randn(&[n, d], rng);
        let v = Tensor::randn(&[n, d], rng);
        // spike some queries so λ has contrast to fire on
        for r in (0..n).step_by(5) {
            for x in q.row_mut(r) {
                *x *= 6.0;
            }
        }
        let mask = BlockMask::new_all(cfg.n_qblocks(n), cfg.n_kblocks(n), true);
        let filter = MaskFilter::new(&mask, Some(-5.0));
        let kernel = F32Kernel::new(&q, &k, &cfg);
        let (serial, st_serial) = run_tiled(&q, &k, &v, &cfg, &kernel, &filter, Exec::Inline);
        let (split, st_split) = run_tiled_splitkv(&q, &k, &v, &cfg, &kernel, &filter, Exec::Inline, span);
        if st_split.pv_skipped_frac > st_serial.pv_skipped_frac + 1e-12 {
            return Err(format!(
                "span-local λ skipped more than serial: {} vs {}",
                st_split.pv_skipped_frac, st_serial.pv_skipped_frac
            ));
        }
        let (o_pool, st_pool) =
            run_tiled_splitkv(&q, &k, &v, &cfg, &kernel, &filter, Exec::Pool(&pool), span);
        if o_pool != split || st_pool != st_split {
            return Err("λ-on splitkv not deterministic across exec modes".into());
        }
        // λ only drops near-zero probability mass; both paths stay close
        assert_allclose(split.data(), serial.data(), 1e-2, 1e-2, "lambda-splitkv-vs-serial")
    });
}

/// Decode a suffix of the stream through a session, returning per-step
/// outputs and stats.
fn decode_tail(engine: &AttnEngine, q: &Tensor, k: &Tensor, v: &Tensor, n0: usize) -> Vec<AttnOutput> {
    let n = q.dim(0);
    let mut session = engine.session();
    if n0 > 0 {
        session.prefill(&q.rows(0, n0), &k.rows(0, n0), &v.rows(0, n0));
    }
    (n0..n)
        .map(|t| session.decode(&q.rows(t, t + 1), &k.rows(t, t + 1), &v.rows(t, t + 1)))
        .collect()
}

#[test]
fn session_decode_splitkv_parity_all_compositions() {
    // Engine-level acceptance: split-KV decode is allclose to the serial
    // path for f32 and INT8, under dense / external / predicted filters,
    // λ on and off; with λ off the per-step SkipStats are exactly equal.
    let (q, k, v) = qkv(88, 16, 815);
    let cfg = AttnConfig { bq: 16, bk: 8, causal: true, scale: None, cw: 2, row_offset: 0 };
    let n0 = 48;
    let ext_mask = {
        let mut rng = Pcg::seeded(816);
        let mut m = random_mask(&mut rng, cfg.n_qblocks(88), cfg.n_kblocks(88));
        // decode rows must keep at least the tail block they append
        for i in 0..m.rows {
            for j in 0..m.cols {
                if rng.chance(0.3) {
                    m.set(i, j, true);
                }
            }
        }
        m
    };
    type Compose = (&'static str, Precision, SparsityPolicy, bool);
    let compositions: Vec<Compose> = vec![
        ("dense-f32", Precision::F32, SparsityPolicy::Dense, true),
        (
            "external-f32",
            Precision::F32,
            SparsityPolicy::External { mask: ext_mask.clone(), lambda: None },
            true,
        ),
        (
            "external-f32-lambda",
            Precision::F32,
            SparsityPolicy::External { mask: ext_mask.clone(), lambda: Some(-12.0) },
            false,
        ),
        (
            "predicted-f32",
            Precision::F32,
            SparsityPolicy::Predicted {
                params: SpargeParams { tau: 0.9, theta: 0.3, lambda: None, quant: false }
                    .predict_params(),
                lambda: None,
            },
            true,
        ),
        ("dense-int8", Precision::Int8, SparsityPolicy::Dense, true),
        (
            "predicted-int8-lambda",
            Precision::Int8,
            SparsityPolicy::Predicted {
                params: SpargeParams { tau: 0.9, theta: 0.3, lambda: None, quant: true }
                    .predict_params(),
                lambda: Some(-12.0),
            },
            false,
        ),
    ];
    for (label, precision, policy, stats_exact) in compositions {
        let serial = AttnEngine::builder().config(cfg).precision(precision).policy(policy.clone()).build();
        let split = AttnEngine::builder()
            .config(cfg)
            .precision(precision)
            .policy(policy)
            .kv_split(KvSplit::Blocks(2))
            .build();
        let base = decode_tail(&serial, &q, &k, &v, n0);
        let fast = decode_tail(&split, &q, &k, &v, n0);
        for (t, (a, b)) in base.iter().zip(&fast).enumerate() {
            assert_allclose(b.out.data(), a.out.data(), 1e-4, 1e-3, &format!("{label} step {t}"))
                .unwrap();
            assert_eq!(a.mask, b.mask, "{label} step {t}: stage-1 masks must be identical");
            if stats_exact {
                assert_eq!(a.stats, b.stats, "{label} step {t}: λ-off stats must merge exactly");
            }
        }
    }
}

#[test]
fn session_decode_splitkv_bitwise_across_pool_sizes() {
    // The serving determinism guarantee end to end: one fixed span size,
    // four executors — identical bits from every session.
    let (q, k, v) = qkv(72, 16, 817);
    let cfg = AttnConfig { bq: 16, bk: 8, causal: true, scale: None, cw: 2, row_offset: 0 };
    let params = SpargeParams { tau: 0.9, theta: 0.3, lambda: Some(-6.0), quant: false };
    let mk = |exec: Execution| {
        AttnEngine::builder().config(cfg).sparge(&params).kv_split(KvSplit::Blocks(2)).execution(exec).build()
    };
    let base_engine = mk(Execution::Inline);
    let base = decode_tail(&base_engine, &q, &k, &v, 32);
    for exec in [Execution::Threads(4), Execution::Pool(1), Execution::Pool(2), Execution::Pool(8)] {
        let engine = mk(exec);
        let runs = decode_tail(&engine, &q, &k, &v, 32);
        for (t, (a, b)) in base.iter().zip(&runs).enumerate() {
            assert_eq!(a.out, b.out, "{exec:?} step {t} output bits");
            assert_eq!(a.stats, b.stats, "{exec:?} step {t} stats bits");
        }
    }
}

#[test]
fn sub_bq_prefill_chunks_route_through_splitkv_and_stay_faithful() {
    // A chunked prefill whose chunks are shorter than b_q is a
    // single-tile call against a long cache — exactly the split-KV shape.
    // f32/λ-off: rows must stay allclose to the one-shot prefill and
    // (split vs serial engine, same chunking) stats exactly equal.
    let (q, k, v) = qkv(72, 8, 818);
    let cfg = AttnConfig { bq: 16, bk: 4, causal: true, scale: None, cw: 2, row_offset: 0 };
    let serial = AttnEngine::dense(cfg);
    let split = AttnEngine::builder().config(cfg).kv_split(KvSplit::Blocks(2)).build();
    let oneshot = {
        let mut s = serial.session();
        s.prefill(&q, &k, &v).out
    };
    let edges = [0usize, 8, 16, 24, 40, 48, 60, 72]; // several sub-b_q chunks
    let mut split_rows: Vec<f32> = Vec::new();
    let mut serial_session = serial.session();
    let mut split_session = split.session();
    for w in edges.windows(2) {
        let (a, b) = (w[0], w[1]);
        let rs = serial_session.prefill_chunk(&q.rows(a, b), &k.rows(a, b), &v.rows(a, b));
        let rp = split_session.prefill_chunk(&q.rows(a, b), &k.rows(a, b), &v.rows(a, b));
        assert_eq!(rs.stats, rp.stats, "chunk {a}..{b}: λ-off chunk stats must be exact");
        assert_allclose(
            rp.out.data(),
            rs.out.data(),
            1e-4,
            1e-3,
            &format!("chunk {a}..{b} vs serial engine"),
        )
        .unwrap();
        split_rows.extend_from_slice(rp.out.data());
    }
    assert_allclose(&split_rows, oneshot.data(), 1e-4, 1e-3, "splitkv chunks vs one-shot").unwrap();
    // INT8 sanity on the same chunking: stays within the quant budget
    let split_q = AttnEngine::builder()
        .config(cfg)
        .precision(Precision::Int8)
        .kv_split(KvSplit::Blocks(2))
        .build();
    let mut sq = split_q.session();
    let mut rows_q: Vec<f32> = Vec::new();
    for w in edges.windows(2) {
        let r = sq.prefill_chunk(&q.rows(w[0], w[1]), &k.rows(w[0], w[1]), &v.rows(w[0], w[1]));
        rows_q.extend_from_slice(r.out.data());
    }
    let err = rel_l1(&rows_q, oneshot.data());
    assert!(err < 0.05, "int8 splitkv chunked prefill rel-L1 {err}");
}

#[test]
fn auto_split_engages_only_on_decode_shapes() {
    // Routing is shape-based: a tall (prefill) call must produce the same
    // bits with split-KV on and off — it runs the row-parallel driver
    // either way; only single-tile calls change reduction trees.
    let (q, k, v) = qkv(96, 16, 819);
    let cfg = AttnConfig { bq: 16, bk: 8, causal: true, scale: None, cw: 2, row_offset: 0 };
    let off = AttnEngine::dense(cfg).attention(&q, &k, &v);
    let auto = AttnEngine::builder().config(cfg).kv_split(KvSplit::Auto).build().attention(&q, &k, &v);
    assert_eq!(off.out, auto.out, "tall calls must not re-route");
    assert_eq!(off.stats, auto.stats);
}
