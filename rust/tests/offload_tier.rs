//! Property suite for the offload-tier seam
//! (`sparge::attention::offload`): random checkpoint geometries ×
//! precisions round-trip **byte-identically** through both the
//! in-memory and the checksummed on-disk tier (NaN payload bits
//! included); any single flipped byte of an on-disk checkpoint surfaces
//! as a quarantine value — never a panic; and every session-level
//! scenario returns its frame pool to empty (`assert_all_free`).

use sparge::attention::{
    AttnConfig, AttnEngine, DiskTier, FrameCheckpoint, MemTier, OffloadError, OffloadTier,
    PageAllocator, Precision,
};
use sparge::tensor::Tensor;
use sparge::util::prop::Cases;
use sparge::util::rng::Pcg;

/// A random checkpoint with plausible per-frame geometry and adversarial
/// payload bits: every f32 section occasionally gets a NaN with a
/// payload, which must survive the round-trip as exact bits.
fn random_ckpt(rng: &mut Pcg, quant: bool) -> (FrameCheckpoint, usize) {
    let d = rng.range(1, 12);
    let dv = rng.range(1, 12);
    let bk = rng.range(1, 7);
    let frames = rng.range(1, 7);
    let mut adversarial = |rng: &mut Pcg| -> f32 {
        if rng.chance(0.05) {
            f32::from_bits(0x7fc0_0000 | rng.next_u32() & 0x003f_ffff)
        } else {
            rng.gauss()
        }
    };
    let mut c = FrameCheckpoint { d, dv, ..Default::default() };
    for _ in 0..frames {
        let rows = rng.range(1, bk + 1);
        c.prow.push(rows);
        c.sim.push(adversarial(rng));
        for _ in 0..rows * d {
            c.k.push(adversarial(rng));
            if quant {
                c.qdata.push(rng.next_u32() as i8);
            }
        }
        for _ in 0..rows * dv {
            c.v.push(adversarial(rng));
        }
        for _ in 0..d {
            c.psum.push(adversarial(rng));
        }
        if quant {
            c.qscale.push(rng.f32().abs() + 1e-3);
        }
    }
    (c, bk)
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn assert_payload_bits_eq(a: &FrameCheckpoint, b: &FrameCheckpoint, what: &str) {
    assert_eq!(a.d, b.d, "{what}: d");
    assert_eq!(a.dv, b.dv, "{what}: dv");
    assert_eq!(a.prow, b.prow, "{what}: prow");
    assert_eq!(bits(&a.sim), bits(&b.sim), "{what}: sim bits");
    assert_eq!(bits(&a.k), bits(&b.k), "{what}: k bits");
    assert_eq!(bits(&a.v), bits(&b.v), "{what}: v bits");
    assert_eq!(bits(&a.psum), bits(&b.psum), "{what}: psum bits");
    assert_eq!(bits(&a.qscale), bits(&b.qscale), "{what}: qscale bits");
    assert_eq!(a.qdata, b.qdata, "{what}: qdata bytes");
}

#[test]
fn random_checkpoints_round_trip_byte_identically_through_both_tiers() {
    let mut disk = DiskTier::scratch("prop-roundtrip").expect("temp dir");
    let mut mem = MemTier::new();
    Cases::standard(1101).check(|rng| {
        let quant = rng.chance(0.5);
        let (original, bk) = random_ckpt(rng, quant);
        assert!(original.consistent(bk), "generator must produce consistent geometry");
        let key = rng.next_u64();
        for (tier, label) in
            [(&mut mem as &mut dyn OffloadTier, "mem"), (&mut disk as &mut dyn OffloadTier, "disk")]
        {
            let mut ckpt = original.clone();
            tier.store(key, &mut ckpt).expect("store");
            assert!(ckpt.is_empty(), "{label}: store must empty the caller's checkpoint");
            let mut back = FrameCheckpoint::default();
            tier.load(key, &mut back).expect("load");
            assert_payload_bits_eq(&back, &original, label);
            assert!(back.consistent(bk), "{label}: round-trip must stay consistent");
            assert!(tier.is_empty(), "{label}: load consumes the stored payload");
        }
    });
}

#[test]
fn any_flipped_byte_quarantines_never_panics() {
    // Flip one byte at a RANDOM offset of a stored on-disk checkpoint:
    // wherever it lands — magic, header lengths, payload, or the
    // trailing checksum itself — the load must come back as a Corrupt
    // value. A truncated file behaves the same.
    let mut tier = DiskTier::scratch("prop-corrupt").expect("temp dir");
    Cases::standard(1102).check(|rng| {
        let quant = rng.chance(0.5);
        let (original, _) = random_ckpt(rng, quant);
        let key = rng.next_u64();
        let mut ckpt = original.clone();
        tier.store(key, &mut ckpt).expect("store");
        let path = tier.path_for(key);
        let mut bytes = std::fs::read(&path).expect("stored file");
        if rng.chance(0.8) {
            let at = rng.range(0, bytes.len());
            let bit = 1u8 << rng.below(8);
            bytes[at] ^= bit;
            std::fs::write(&path, &bytes).expect("rewrite");
        } else {
            std::fs::write(&path, &bytes[..rng.range(0, bytes.len())]).expect("truncate");
        }
        let mut back = FrameCheckpoint::default();
        assert_eq!(
            tier.load(key, &mut back),
            Err(OffloadError::Corrupt),
            "a damaged checkpoint must quarantine as a value"
        );
        assert!(tier.is_empty(), "a corrupt load still consumes the key");
    });
}

#[test]
fn session_suspend_resume_scenarios_return_the_pool_to_empty() {
    // Session-level property over random pool sizes × precisions: a
    // paged session suspended to either tier mid-decode and resumed
    // produces the exact bits of its never-suspended twin, and every
    // scenario — including a corrupted-checkpoint quarantine — closes
    // with `assert_all_free`.
    Cases::standard(1103).check(|rng| {
        let d = rng.range(2, 10);
        let bk = rng.range(1, 5);
        let frames = rng.range(2, 6);
        let int8 = rng.chance(0.3);
        let cfg = AttnConfig { bq: 4, bk, causal: true, scale: None, cw: 2, row_offset: 0 };
        let engine = if int8 {
            AttnEngine::builder().config(cfg).precision(Precision::Int8).build()
        } else {
            AttnEngine::builder().config(cfg).build()
        };
        let tokens = frames * bk;
        let mut r = Pcg::seeded(rng.next_u64());
        let q = Tensor::randn(&[tokens, d], &mut r);
        let k = Tensor::randn(&[tokens, d], &mut r);
        let v = Tensor::randn(&[tokens, d], &mut r);
        let mk_alloc = |frames: usize| {
            let a = PageAllocator::new(frames, bk, d, d);
            if int8 {
                a.with_quant()
            } else {
                a
            }
        };
        // twin A: never suspended
        let mut alloc_a = mk_alloc(frames);
        let mut sa = engine.paged_session();
        let mut plain = Vec::new();
        for t in 0..tokens {
            plain.push(
                sa.decode(&mut alloc_a, &q.rows(t, t + 1), &k.rows(t, t + 1), &v.rows(t, t + 1))
                    .expect("pool fits the stream"),
            );
        }
        // twin B: suspended to a random tier mid-decode, then resumed
        let mut tier: Box<dyn OffloadTier> = if rng.chance(0.5) {
            Box::new(DiskTier::scratch("prop-session").expect("temp dir"))
        } else {
            Box::new(MemTier::new())
        };
        let cut = rng.range(1, tokens);
        let mut alloc_b = mk_alloc(frames);
        let mut sb = engine.paged_session();
        let mut interrupted = Vec::new();
        for t in 0..tokens {
            if t == cut {
                assert!(sb.suspend(&mut alloc_b, 9, tier.as_mut()), "suspend must checkpoint");
                assert_eq!(alloc_b.stats().frames_in_use, 0, "suspension frees every frame");
                assert!(
                    sb.resume(&mut alloc_b, 9, tier.as_mut()).expect("tier load"),
                    "the empty pool must cover the re-page-in"
                );
                tier.discard(9);
            }
            interrupted.push(
                sb.decode(&mut alloc_b, &q.rows(t, t + 1), &k.rows(t, t + 1), &v.rows(t, t + 1))
                    .expect("pool fits the stream"),
            );
        }
        for (t, (a, b)) in plain.iter().zip(&interrupted).enumerate() {
            assert_eq!(a.out, b.out, "step {t}: suspend/resume must stay bitwise");
            assert_eq!(a.stats, b.stats, "step {t}: stats must stay bitwise");
        }
        sa.release(&mut alloc_a);
        sb.release(&mut alloc_b);
        alloc_a.assert_all_free();
        alloc_b.assert_all_free();
    });
}

#[test]
fn corrupted_resume_quarantines_and_pool_stays_whole() {
    // The quarantine path end-to-end at the session level: suspend to
    // disk, rot the file, resume fails as a value, the session is
    // permanently suspended, and the pool is already whole.
    let cfg = AttnConfig { bq: 4, bk: 4, causal: true, scale: None, cw: 2, row_offset: 0 };
    let engine = AttnEngine::builder().config(cfg).build();
    let mut r = Pcg::seeded(77);
    let q = Tensor::randn(&[8, 6], &mut r);
    let k = Tensor::randn(&[8, 6], &mut r);
    let v = Tensor::randn(&[8, 6], &mut r);
    let mut alloc = PageAllocator::new(4, 4, 6, 6);
    let mut s = engine.paged_session();
    for t in 0..8 {
        s.decode(&mut alloc, &q.rows(t, t + 1), &k.rows(t, t + 1), &v.rows(t, t + 1)).expect("frames");
    }
    let mut tier = DiskTier::scratch("prop-quarantine").expect("temp dir");
    assert!(s.suspend(&mut alloc, 3, &mut tier));
    let path = tier.path_for(3);
    let mut bytes = std::fs::read(&path).expect("stored file");
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x10;
    std::fs::write(&path, &bytes).expect("rewrite");
    assert_eq!(s.resume(&mut alloc, 3, &mut tier), Err(OffloadError::Corrupt));
    assert!(s.is_suspended(), "a lost checkpoint leaves the session suspended");
    s.release(&mut alloc);
    alloc.assert_all_free();
}
