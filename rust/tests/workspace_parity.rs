//! Workspace-reuse and scheduling-determinism golden suite.
//!
//! Two contracts from the allocation-free hot-path refactor:
//!
//! 1. **Bitwise-neutral scratch reuse.** Sessions and pool workers run
//!    over recycled `Workspace` arenas and cached `SpanPlan`s instead of
//!    fresh allocations. Reuse must never change a bit: every
//!    composition (f32/INT8 × dense/predicted × Inline/Threads/Pool ×
//!    pool sizes 1/2/8 × split-KV off/on) must produce identical decode
//!    rows and stats to the inline fresh-state baseline — and a *second*
//!    stream over the same warmed engine (dirty worker arenas, dirty
//!    session-free pools) must reproduce the first run exactly.
//!
//! 2. **Chunked self-scheduling determinism.** The pool hands out
//!    indices in timing-dependent chunks and the submitter participates;
//!    with artificially skewed per-block compute (pseudorandom stalls —
//!    "shuffled worker speeds"), outputs and stats must not move:
//!    scheduling order may vary, merge order may not.

use std::time::Duration;

use sparge::attention::{
    run_tiled, run_tiled_splitkv, AttnConfig, AttnEngine, DenseFilter, Exec, Execution, F32Kernel,
    KvSplit, Precision, ScoreKernel, ScoreScratch, SkipStats, SparsityPolicy,
};
use sparge::sparge::SpargeParams;
use sparge::tensor::Tensor;
use sparge::util::rng::Pcg;
use sparge::util::threadpool::WorkerPool;

fn qkv(n: usize, d: usize, seed: u64) -> (Tensor, Tensor, Tensor) {
    let mut rng = Pcg::seeded(seed);
    (Tensor::randn(&[n, d], &mut rng), Tensor::randn(&[n, d], &mut rng), Tensor::randn(&[n, d], &mut rng))
}

/// Prefill rows [0, n0) in one shot, then decode the rest through
/// `decode_into`; returns every decode row (concatenated) plus per-step
/// stats.
fn run_stream(
    engine: &AttnEngine,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    n0: usize,
) -> (Vec<f32>, Vec<SkipStats>) {
    let n = q.dim(0);
    let dv = v.dim(1);
    let mut session = engine.session();
    session.prefill(&q.rows(0, n0), &k.rows(0, n0), &v.rows(0, n0));
    let mut rows = vec![0f32; (n - n0) * dv];
    let mut stats = Vec::new();
    for t in n0..n {
        let (st, _mask) = session.decode_into(
            &q.rows(t, t + 1),
            &k.rows(t, t + 1),
            &v.rows(t, t + 1),
            &mut rows[(t - n0) * dv..(t - n0 + 1) * dv],
        );
        stats.push(st);
    }
    (rows, stats)
}

#[test]
fn workspace_reuse_parity_across_all_compositions() {
    let (n, d, n0) = (64, 8, 32);
    let (q, k, v) = qkv(n, d, 9001);
    let cfg = AttnConfig { bq: 16, bk: 8, causal: true, scale: None, cw: 2, row_offset: 0 };
    let params = SpargeParams { tau: 0.9, theta: 0.3, lambda: Some(-6.0), quant: false };

    for precision in [Precision::F32, Precision::Int8] {
        for predicted in [false, true] {
            for split in [KvSplit::Off, KvSplit::Auto] {
                let build = |exec: Execution| {
                    let mut b = AttnEngine::builder().config(cfg).execution(exec).kv_split(split);
                    if predicted {
                        b = b.sparge(&params).precision(precision);
                    } else {
                        b = b.precision(precision).policy(SparsityPolicy::Dense);
                    }
                    b.build()
                };
                let label = format!("{precision:?}/predicted={predicted}/{split:?}");
                let baseline = run_stream(&build(Execution::Inline), &q, &k, &v, n0);
                for exec in [
                    Execution::Threads(3),
                    Execution::Pool(1),
                    Execution::Pool(2),
                    Execution::Pool(8),
                ] {
                    let engine = build(exec);
                    let first = run_stream(&engine, &q, &k, &v, n0);
                    assert_eq!(first.0, baseline.0, "{label} {exec:?}: rows diverged from inline");
                    assert_eq!(first.1, baseline.1, "{label} {exec:?}: stats diverged from inline");
                    // second stream over the warmed engine: dirty worker
                    // arenas must be bitwise-invisible
                    let second = run_stream(&engine, &q, &k, &v, n0);
                    assert_eq!(second.0, first.0, "{label} {exec:?}: warmed rerun diverged");
                    assert_eq!(second.1, first.1, "{label} {exec:?}: warmed rerun stats diverged");
                }
            }
        }
    }
}

/// An f32 kernel with pseudorandom per-block stalls — simulates workers
/// of wildly different speeds without touching any value.
struct SkewedKernel<'a> {
    inner: F32Kernel<'a>,
    seed: u64,
}

impl ScoreKernel for SkewedKernel<'_> {
    fn score_block(
        &self,
        q0: usize,
        q1: usize,
        k0: usize,
        k1: usize,
        out: &mut [f32],
        scratch: &mut ScoreScratch<'_>,
    ) {
        let h = (q0 as u64 + 1)
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add((k0 as u64) << 7)
            .wrapping_add(self.seed);
        if h % 3 == 0 {
            std::thread::sleep(Duration::from_micros(h % 300));
        }
        self.inner.score_block(q0, q1, k0, k1, out, scratch);
    }
}

#[test]
fn skewed_worker_speeds_never_change_results() {
    let (n, d) = (96, 8);
    let (qt, kt, vt) = qkv(n, d, 9002);
    let q1 = qt.rows(0, 1);
    let cfg = AttnConfig { bq: 16, bk: 8, causal: false, scale: None, cw: 2, row_offset: 0 };
    let pool2 = WorkerPool::new(2);
    let pool8 = WorkerPool::new(8);
    for round in 0..4u64 {
        // decode shape through the split driver: (row, span) items of
        // very different cost
        let kernel = SkewedKernel { inner: F32Kernel::new(&q1, &kt, &cfg), seed: round };
        let (base, st_base) =
            run_tiled_splitkv(&q1, &kt, &vt, &cfg, &kernel, &DenseFilter, Exec::Inline, 1);
        for (exec, name) in
            [(Exec::Threads(4), "threads"), (Exec::Pool(&pool2), "pool2"), (Exec::Pool(&pool8), "pool8")]
        {
            let (o, s) = run_tiled_splitkv(&q1, &kt, &vt, &cfg, &kernel, &DenseFilter, exec, 1);
            assert_eq!(o, base, "splitkv round {round} {name}: output moved with scheduling");
            assert_eq!(s, st_base, "splitkv round {round} {name}: stats moved with scheduling");
        }
        // prefill shape through the row driver: ragged row costs
        let kernel = SkewedKernel { inner: F32Kernel::new(&qt, &kt, &cfg), seed: round };
        let (base, st_base) = run_tiled(&qt, &kt, &vt, &cfg, &kernel, &DenseFilter, Exec::Inline);
        for (exec, name) in
            [(Exec::Threads(4), "threads"), (Exec::Pool(&pool2), "pool2"), (Exec::Pool(&pool8), "pool8")]
        {
            let (o, s) = run_tiled(&qt, &kt, &vt, &cfg, &kernel, &DenseFilter, exec);
            assert_eq!(o, base, "tiled round {round} {name}: output moved with scheduling");
            assert_eq!(s, st_base, "tiled round {round} {name}: stats moved with scheduling");
        }
    }
}
