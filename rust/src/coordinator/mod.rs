//! L3 serving coordinator: a **continuous-batching** serving loop over
//! two engines — the PJRT model engine actor (AOT-compiled HLO, owns the
//! non-`Send` runtime) for byte-LM generation, and one shared
//! [`crate::attention::AttnEngine`]/worker pool for attention-session
//! streams. Python never runs on this path.
//!
//! Scheduling is **iteration-level** (vLLM-style), not request-level: the
//! scheduler thread ticks, and each tick admits, advances, and retires —
//! a long prompt never monopolizes the engines because prompts prefill in
//! bounded chunks and every active sequence decodes one token per tick.
//!
//! ```text
//!             submit / submit_stream (any thread)
//!                          │
//!                     [ Batcher ]   bounded FIFO, in-place mode drain,
//!                          │        max_age aging bound
//!        ┌─ admit (≤ max_batch active) ──────────────┐
//!        │                                           │ per tick
//!  Payload::Generate                      Payload::AttnStream
//!   one lm_logits step/tick                [ SessionManager ]
//!   (PJRT engine actor)                 admit → chunked prefill
//!        │                              (≤ chunk rows, b_q-aligned)
//!        │                                → decode ticks → retire
//!        └────────── retire: respond + Metrics ──────┘
//!          (latency/compute + TTFT/TPOT + sparsity)
//! ```
//!
//! Request lifecycle: **admit** (popped from the batcher when a slot is
//! free) → **chunked prefill** (attention streams; one bounded
//! `prefill_chunk` per tick, so time-to-first-token of everything queued
//! stays capped) → **decode ticks** (one token per tick, interleaved
//! across all active sequences) → **retire** (respond, record
//! latency/TTFT/TPOT and per-session sparsity).
//!
//! Kernel-level `attn` probe ops still run the tiled pipeline directly on
//! connection threads (no queueing); the `attn`/`serve` op pushes real
//! streams through the serving loop instead. The TCP JSON-lines
//! [`server`] is the external interface; [`metrics`] aggregates serving
//! counters plus TTFT/TPOT reservoirs.
//!
//! ## Fault tolerance and graceful degradation
//!
//! The loop is built to degrade per-request, never per-loop. Every
//! stream retires with exactly one [`session_manager::SeqOutcome`]:
//! `Completed` (possibly truncated by a token budget),
//! `DeadlineCancelled` (its [`request::RequestLimits`] deadline passed a
//! tick boundary; partial output kept), `Quarantined` (a worker-job
//! panic or a non-finite input row was contained to that session — its
//! frames release through the same path an eviction uses, and the other
//! residents' outputs stay bitwise identical), or `Shed` (terminally
//! unservable or dropped at drain). [`SessionManager::drain`] is the
//! shutdown half: stop admitting, finish or cancel every resident, and
//! assert the paged pool returns to zero frames in use. The [`fault`]
//! module is the *injection* seam only — a seeded [`fault::FaultPlan`]
//! (installed via `ServeOptions::fault`) makes these paths fire on
//! demand for the chaos suite (`tests/chaos_serving.rs`); the recovery
//! machinery itself is always compiled in and costs one branch per tick
//! when no plan is installed.

pub mod batcher;
pub mod engine;
pub mod fault;
pub mod metrics;
pub mod qos;
pub mod request;
pub mod scheduler;
pub mod server;
pub mod session_manager;

pub use batcher::{BatchPolicy, Batcher};
pub use engine::EngineHandle;
pub use fault::{FaultEvent, FaultKind, FaultPlan};
pub use metrics::Metrics;
pub use qos::{OverloadDetector, OverloadState, Priority};
pub use request::{AttnMode, AttnStreamSpec, GenerateRequest, GenerateResponse, Payload, RequestLimits};
pub use scheduler::{AttnProbeResult, Coordinator, DecodeProbeResult, PagedServe, ServeOptions};
pub use session_manager::{run_sequential, SeqOutcome, SeqResult, SeqStream, SessionManager};
