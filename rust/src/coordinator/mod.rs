//! L3 serving coordinator: a **continuous-batching** serving loop over
//! two engines — the PJRT model engine actor (AOT-compiled HLO, owns the
//! non-`Send` runtime) for byte-LM generation, and one shared
//! [`crate::attention::AttnEngine`]/worker pool for attention-session
//! streams. Python never runs on this path.
//!
//! Scheduling is **iteration-level** (vLLM-style), not request-level: the
//! scheduler thread ticks, and each tick admits, advances, and retires —
//! a long prompt never monopolizes the engines because prompts prefill in
//! bounded chunks and every active sequence decodes one token per tick.
//!
//! ```text
//!             submit / submit_stream (any thread)
//!                          │
//!                     [ Batcher ]   bounded FIFO, in-place mode drain,
//!                          │        max_age aging bound
//!        ┌─ admit (≤ max_batch active) ──────────────┐
//!        │                                           │ per tick
//!  Payload::Generate                      Payload::AttnStream
//!   one lm_logits step/tick                [ SessionManager ]
//!   (PJRT engine actor)                 admit → chunked prefill
//!        │                              (≤ chunk rows, b_q-aligned)
//!        │                                → decode ticks → retire
//!        └────────── retire: respond + Metrics ──────┘
//!          (latency/compute + TTFT/TPOT + sparsity)
//! ```
//!
//! Request lifecycle: **admit** (popped from the batcher when a slot is
//! free) → **chunked prefill** (attention streams; one bounded
//! `prefill_chunk` per tick, so time-to-first-token of everything queued
//! stays capped) → **decode ticks** (one token per tick, interleaved
//! across all active sequences) → **retire** (respond, record
//! latency/TTFT/TPOT and per-session sparsity).
//!
//! Kernel-level `attn` probe ops still run the tiled pipeline directly on
//! connection threads (no queueing); the `attn`/`serve` op pushes real
//! streams through the serving loop instead. The TCP JSON-lines
//! [`server`] is the external interface; [`metrics`] aggregates serving
//! counters plus TTFT/TPOT reservoirs.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod request;
pub mod scheduler;
pub mod server;
pub mod session_manager;

pub use batcher::{BatchPolicy, Batcher};
pub use engine::EngineHandle;
pub use metrics::Metrics;
pub use request::{AttnMode, AttnStreamSpec, GenerateRequest, GenerateResponse, Payload};
pub use scheduler::{AttnProbeResult, Coordinator, DecodeProbeResult, ServeOptions};
pub use session_manager::{run_sequential, SeqResult, SeqStream, SessionManager};
