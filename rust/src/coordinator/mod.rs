//! L3 serving coordinator: request types, dynamic batcher, scheduler,
//! engine actor (owns the non-`Send` PJRT runtime), TCP JSON-lines server,
//! and metrics. Python never runs on this path — the engine executes
//! AOT-compiled HLO artifacts only. Kernel-level `attn` probe requests run
//! the unified tiled pipeline directly (no engine) and feed per-request
//! sparsity into the serving metrics.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod request;
pub mod scheduler;
pub mod server;

pub use batcher::{BatchPolicy, Batcher};
pub use engine::EngineHandle;
pub use metrics::Metrics;
pub use request::{AttnMode, GenerateRequest, GenerateResponse};
pub use scheduler::{AttnProbeResult, Coordinator, DecodeProbeResult};
