//! Quality-of-service policy for the serving loop: per-request
//! priorities, the hysteresis overload detector, and the backpressure
//! hint math — the *decisions* behind priority-aware preemption, kept
//! separate from the tick mechanics in [`super::session_manager`].
//!
//! ## Priorities
//!
//! Every request carries a [`Priority`] (default `Normal`, parsed off
//! the serve op's `"priority"` field). Priorities order **degradation**,
//! not throughput: under frame pressure the lowest-priority resident is
//! preempted first (checkpointed through an offload tier, resumed later
//! bitwise-identically), and when the loop must shed, it sheds the
//! lowest-priority pending request — a high-priority stream never sheds
//! while a strictly lower-priority resident is holding frames (the
//! *no-priority-inversion* invariant, asserted for every chaos seed).
//! Admission ages: a pending request gains one effective rank step per
//! [`AGE_RANK_TICKS`] ticks waited, so low priority is served late,
//! never starved.
//!
//! ## Hysteresis overload control
//!
//! The [`OverloadDetector`] folds three signals — free-frame watermarks,
//! tick duration, and pending-queue depth — into three states:
//!
//! ```text
//!            pending>0 && (free ≤ ¼ || slow tick)      free==0 && deep queue, twice
//!   Normal ───────────────────────────► Preempting ─────────────────────► Shedding
//!      ▲                                    │  ▲                              │
//!      └──── pending==0 || free ≥ ½ ────────┘  └────── pressure clears ───────┘
//! ```
//!
//! Enter and exit watermarks differ (¼ vs ½ free) so the state cannot
//! flap on the boundary, and escalation to `Shedding` requires the deep
//! signal to hold for consecutive observations — one slow tick degrades
//! ordering, it does not drop traffic. All inputs are values the tick
//! already has; `observe` allocates nothing and never panics (this file
//! is under sparge-lint's `serving-no-panic`, and the observe call is a
//! `hot_fns` entry).

/// Per-request serving priority. Order is meaningful (`Low < Normal <
/// High`): under pressure, lower ranks pay first.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    Low,
    #[default]
    Normal,
    High,
}

/// All priorities, indexable by [`Priority::rank`] (metrics reservoirs
/// are per-priority arrays in this order).
pub const PRIORITIES: [Priority; 3] = [Priority::Low, Priority::Normal, Priority::High];

impl Priority {
    pub fn parse(s: &str) -> Option<Priority> {
        match s {
            "low" => Some(Priority::Low),
            "normal" => Some(Priority::Normal),
            "high" => Some(Priority::High),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Priority::Low => "low",
            Priority::Normal => "normal",
            Priority::High => "high",
        }
    }

    /// Numeric rank (`Low`=0 … `High`=2); also the per-priority metrics
    /// index.
    pub fn rank(&self) -> u8 {
        *self as u8
    }
}

/// Ticks a pending request must wait to gain one effective rank step at
/// admission — the aging bound that keeps low priority from starving.
/// Aging affects *admission order only*: preemption compares declared
/// ranks, so an aged `Low` request never evicts anyone.
pub const AGE_RANK_TICKS: u64 = 32;

/// Admission-ordering rank: declared rank plus one step per
/// [`AGE_RANK_TICKS`] ticks waited (unbounded — a request that waits
/// long enough outranks fresh `High` arrivals and must be admitted
/// next).
pub fn effective_rank(p: Priority, waited_ticks: u64) -> u64 {
    p.rank() as u64 + waited_ticks / AGE_RANK_TICKS
}

/// Overload posture of the serving loop, decided once per tick.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum OverloadState {
    /// Frames and ticks are healthy: prefill-first ordering (feed new
    /// streams), no preemption.
    #[default]
    Normal,
    /// Frame or tick pressure with work waiting: decode-first ordering
    /// and preempt the lowest-priority resident to admit higher-priority
    /// pending work.
    Preempting,
    /// Sustained deep pressure: additionally shed the lowest-priority
    /// pending request (with a structured retry hint) instead of letting
    /// the queue grow unboundedly.
    Shedding,
}

impl OverloadState {
    pub fn name(&self) -> &'static str {
        match self {
            OverloadState::Normal => "normal",
            OverloadState::Preempting => "preempting",
            OverloadState::Shedding => "shedding",
        }
    }
}

/// Hysteresis overload detector (see the module docs for the state
/// machine). One per serving loop; feed it once per tick.
#[derive(Debug, Default)]
pub struct OverloadDetector {
    state: OverloadState,
    /// Consecutive observations of the deep-pressure signal (gates the
    /// escalation to `Shedding`).
    deep_streak: u32,
    to_preempting: u64,
    to_shedding: u64,
}

impl OverloadDetector {
    /// Free-frame fraction at or below which pressure *enters* (with
    /// pending work).
    pub const ENTER_FREE_FRAC: f64 = 0.25;
    /// Free-frame fraction at or above which pressure *exits* — strictly
    /// above the enter watermark, so the state cannot flap.
    pub const EXIT_FREE_FRAC: f64 = 0.5;
    /// A tick slower than this counts as pressure on its own.
    pub const SLOW_TICK_SECS: f64 = 0.25;
    /// Pending depth that (with zero free frames) counts as deep
    /// pressure.
    pub const DEEP_PENDING: usize = 8;
    /// Consecutive deep observations required to escalate to shedding.
    pub const DEEP_STREAK: u32 = 2;

    pub fn new() -> OverloadDetector {
        OverloadDetector::default()
    }

    /// Current posture (last `observe` result).
    pub fn state(&self) -> OverloadState {
        self.state
    }

    /// Lifetime transition counters: (entries into `Preempting` from
    /// `Normal`, entries into `Shedding`).
    pub fn transitions(&self) -> (u64, u64) {
        (self.to_preempting, self.to_shedding)
    }

    /// Fold one tick's signals into the state machine and return the
    /// posture the *next* tick should run under. Escalation requires
    /// pending work: an idle loop with a full pool is saturated, not
    /// overloaded. Zero-alloc, never panics.
    pub fn observe(
        &mut self,
        free_frames: usize,
        total_frames: usize,
        pending: usize,
        tick_secs: f64,
    ) -> OverloadState {
        let free_frac =
            if total_frames == 0 { 1.0 } else { free_frames as f64 / total_frames as f64 };
        let pressured = pending > 0
            && (free_frac <= Self::ENTER_FREE_FRAC || tick_secs >= Self::SLOW_TICK_SECS);
        let deep = free_frames == 0 && pending >= Self::DEEP_PENDING;
        if deep {
            self.deep_streak = self.deep_streak.saturating_add(1);
        } else {
            self.deep_streak = 0;
        }
        let next = match self.state {
            OverloadState::Normal => {
                if pressured {
                    OverloadState::Preempting
                } else {
                    OverloadState::Normal
                }
            }
            OverloadState::Preempting => {
                if self.deep_streak >= Self::DEEP_STREAK {
                    OverloadState::Shedding
                } else if pending == 0 || free_frac >= Self::EXIT_FREE_FRAC {
                    OverloadState::Normal
                } else {
                    OverloadState::Preempting
                }
            }
            OverloadState::Shedding => {
                if deep {
                    OverloadState::Shedding
                } else {
                    OverloadState::Preempting
                }
            }
        };
        if next != self.state {
            match (self.state, next) {
                (OverloadState::Normal, OverloadState::Preempting) => self.to_preempting += 1,
                (_, OverloadState::Shedding) => self.to_shedding += 1,
                _ => {}
            }
        }
        self.state = next;
        next
    }
}

/// Structured backpressure hint for a shed or rejected request: how long
/// the client should wait before retrying, scaled by posture and queue
/// depth. Paired with the raw `queue_depth` on the wire so clients can
/// implement their own policy too.
pub fn retry_after_ms(state: OverloadState, queue_depth: usize) -> u64 {
    let base = match state {
        OverloadState::Normal => 25,
        OverloadState::Preempting => 100,
        OverloadState::Shedding => 400,
    };
    base + 25 * queue_depth as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn priority_parse_roundtrip_and_order() {
        for p in PRIORITIES {
            assert_eq!(Priority::parse(p.name()), Some(p));
            assert_eq!(PRIORITIES[p.rank() as usize], p);
        }
        assert_eq!(Priority::parse("urgent"), None);
        assert!(Priority::Low < Priority::Normal && Priority::Normal < Priority::High);
        assert_eq!(Priority::default(), Priority::Normal);
    }

    #[test]
    fn aging_lets_low_outrank_fresh_high() {
        assert_eq!(effective_rank(Priority::Low, 0), 0);
        assert!(effective_rank(Priority::Low, 0) < effective_rank(Priority::High, 0));
        let aged = effective_rank(Priority::Low, 3 * AGE_RANK_TICKS);
        assert!(aged > effective_rank(Priority::High, 0), "aged low must eventually win admission");
    }

    #[test]
    fn detector_hysteresis_and_streak_gate() {
        let mut det = OverloadDetector::new();
        assert_eq!(det.state(), OverloadState::Normal);

        // pressure without pending work is saturation, not overload
        assert_eq!(det.observe(0, 16, 0, 0.0), OverloadState::Normal);

        // frame pressure with pending work escalates
        assert_eq!(det.observe(4, 16, 1, 0.0), OverloadState::Preempting);
        // in the hysteresis band (between ¼ and ½ free): hold
        assert_eq!(det.observe(6, 16, 1, 0.0), OverloadState::Preempting);
        // above the exit watermark: recover
        assert_eq!(det.observe(8, 16, 1, 0.0), OverloadState::Normal);
        // a slow tick alone is pressure too
        assert_eq!(det.observe(16, 16, 1, 1.0), OverloadState::Preempting);
        assert_eq!(det.observe(16, 16, 0, 0.0), OverloadState::Normal);

        // shedding needs the deep signal to hold for the streak
        assert_eq!(det.observe(0, 16, 16, 0.0), OverloadState::Preempting);
        assert_eq!(det.observe(0, 16, 16, 0.0), OverloadState::Shedding);
        // deep pressure clears -> back to preempting, then normal
        assert_eq!(det.observe(2, 16, 4, 0.0), OverloadState::Preempting);
        assert_eq!(det.observe(12, 16, 4, 0.0), OverloadState::Normal);

        let (to_p, to_s) = det.transitions();
        assert_eq!(to_p, 3);
        assert_eq!(to_s, 1);
    }

    #[test]
    fn retry_hints_scale_with_posture_and_depth() {
        assert!(retry_after_ms(OverloadState::Normal, 0) < retry_after_ms(OverloadState::Preempting, 0));
        assert!(
            retry_after_ms(OverloadState::Preempting, 0) < retry_after_ms(OverloadState::Shedding, 0)
        );
        assert!(retry_after_ms(OverloadState::Shedding, 9) > retry_after_ms(OverloadState::Shedding, 1));
    }
}
