//! TCP JSON-lines server: the external interface of the coordinator.
//!
//! Protocol (one JSON object per line, response per line):
//!   {"op":"generate","prompt":"...","max_new":16,"mode":"sparge"}
//!     -> {"id":1,"output":"...","latency_ms":12.3,"compute_ms":11.0}
//!   {"op":"attn","n":2048,"d":64,"seed":7,"tau":0.9,"threads":8}
//!     -> {"sparsity":0.42,"latency_ms":8.1,"n":2048,"threads":8}
//!        (kernel probe through the unified attention engine; sparsity is
//!        recorded per request into the serving metrics)
//!   {"op":"attn","mode":"decode","n":1024,"steps":16,"d":64,"tau":0.9}
//!     -> {"mode":"decode","prefill_sparsity":0.4,
//!         "per_step_sparsity":[...],"mean_step_sparsity":0.45,...}
//!        (serving-path probe: AttnSession prefill + N single-row decode
//!        steps, per-step sparsity observable end-to-end)
//!   {"op":"attn","mode":"serve","sessions":4,"n":1024,"steps":32,"d":64,
//!    "deadline_ms":500,"token_budget":16,"priority":"high"}
//!     -> {"mode":"serve","sessions":[{"id":..,"ttft_ms":..,"tpot_ms":..,
//!         "sparsity":..,"error":null},...],"wall_ms":...,"tokens_per_sec":...}
//!        (continuous-batching traffic: N seeded attention streams
//!        submitted through the scheduler's serving loop — chunked
//!        prefill + per-tick decode over the shared AttnEngine.
//!        `deadline_ms`/`token_budget` are optional per-request limits;
//!        `priority` — "low"/"normal"/"high" — feeds QoS scheduling on a
//!        paged coordinator. A stream that misses its deadline or is
//!        quarantined reports a non-null "error" with its terminal
//!        outcome; one shed under overload additionally carries
//!        "retry_after_ms" and "queue_depth" so the client knows when to
//!        come back — as does a submit rejected by queue backpressure)
//!   {"op":"stats"} -> {"requests":...,"mean_sparsity":...,
//!                      "ttft_p50_ms":...,"tpot_p50_ms":...,
//!                      "ttft_p99_ms_by_priority":{"low":..,...},
//!                      "quarantined":...,"deadline_cancelled":...,
//!                      "shed":...,"injected_faults":...,"drain_ms":...,
//!                      "preempted":...,"resumed":...,
//!                      "overload_state":"normal",...}
//!   {"op":"ping"}  -> {"ok":true}

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::util::json::Json;
use crate::util::threadpool::ThreadPool;

use super::qos::{Priority, PRIORITIES};
use super::request::AttnMode;
use super::scheduler::Coordinator;

/// Per-connection socket read timeout: a client that stops sending
/// mid-line cannot pin a connection worker forever.
pub const CONN_READ_TIMEOUT: Duration = Duration::from_secs(30);
/// Per-connection socket write timeout: a client that stops reading
/// cannot wedge a worker in `write_all`.
pub const CONN_WRITE_TIMEOUT: Duration = Duration::from_secs(30);

/// Serve forever on `addr` (e.g. "127.0.0.1:7071").
pub fn serve(coordinator: Arc<Coordinator>, addr: &str) -> Result<()> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    crate::log_info!("serving on {addr}");
    let pool = ThreadPool::default_size();
    for stream in listener.incoming() {
        match stream {
            Ok(s) => {
                let c = Arc::clone(&coordinator);
                pool.submit(move || {
                    if let Err(e) = handle_conn(&c, s) {
                        crate::log_warn!("connection error: {e:#}");
                    }
                });
            }
            Err(e) => crate::log_warn!("accept error: {e}"),
        }
    }
    Ok(())
}

/// Handle one client connection (many requests per connection).
///
/// The socket gets read/write timeouts ([`CONN_READ_TIMEOUT`],
/// [`CONN_WRITE_TIMEOUT`]) so a stalled or dead peer releases its
/// connection worker, and a line that fails to read (invalid UTF-8,
/// timeout, reset) gets a structured JSON error response before the
/// connection closes — never a silent drop.
pub fn handle_conn(coordinator: &Coordinator, stream: TcpStream) -> Result<()> {
    let peer = stream.peer_addr().ok();
    crate::log_debug!("client connected: {peer:?}");
    stream.set_read_timeout(Some(CONN_READ_TIMEOUT)).context("set read timeout")?;
    stream.set_write_timeout(Some(CONN_WRITE_TIMEOUT)).context("set write timeout")?;
    let reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(e) => {
                // malformed line (e.g. invalid UTF-8) or socket-level
                // failure: answer with a structured error, then close
                let err = Json::obj(vec![("error", Json::str(&format!("read failed: {e}")))]);
                let _ = writer.write_all(err.dump().as_bytes());
                let _ = writer.write_all(b"\n");
                let _ = writer.flush();
                return Err(e.into());
            }
        };
        if line.trim().is_empty() {
            continue;
        }
        let response = dispatch(coordinator, &line);
        writer.write_all(response.dump().as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
    }
    Ok(())
}

/// A per-priority triple (indexed by `Priority::rank`) as a JSON object
/// keyed `"low"`/`"normal"`/`"high"`, each value scaled by `scale`.
fn by_priority(vals: &[f64; 3], scale: f64) -> Json {
    Json::obj(PRIORITIES.iter().map(|p| (p.name(), Json::num(vals[p.rank() as usize] * scale))).collect())
}

/// Parse and execute one request line (exposed for tests).
pub fn dispatch(coordinator: &Coordinator, line: &str) -> Json {
    match dispatch_inner(coordinator, line) {
        Ok(j) => j,
        Err(e) => Json::obj(vec![("error", Json::str(&format!("{e:#}")))]),
    }
}

fn dispatch_inner(coordinator: &Coordinator, line: &str) -> Result<Json> {
    let req = Json::parse(line).map_err(|e| anyhow::anyhow!("bad json: {e}"))?;
    let op = req.get("op").and_then(|v| v.as_str()).context("missing 'op'")?;
    match op {
        "ping" => Ok(Json::obj(vec![("ok", Json::Bool(true))])),
        "stats" => {
            let s = coordinator.metrics.snapshot();
            Ok(Json::obj(vec![
                ("requests", Json::num(s.requests as f64)),
                ("tokens_out", Json::num(s.tokens_out as f64)),
                ("errors", Json::num(s.errors as f64)),
                ("latency_p50_ms", Json::num(s.latency_p50 * 1e3)),
                ("latency_p99_ms", Json::num(s.latency_p99 * 1e3)),
                ("tokens_per_sec", Json::num(s.tokens_per_sec)),
                ("queue_depth", Json::num(coordinator.queue_depth() as f64)),
                ("sparse_requests", Json::num(s.sparse_requests as f64)),
                ("mean_sparsity", Json::num(s.mean_sparsity)),
                // token-level serving latencies from the continuous-
                // batching loop (0 until it has retired a request)
                ("ttft_count", Json::num(s.ttft_count as f64)),
                ("ttft_p50_ms", Json::num(s.ttft_p50 * 1e3)),
                ("ttft_p99_ms", Json::num(s.ttft_p99 * 1e3)),
                ("tpot_count", Json::num(s.tpot_count as f64)),
                ("tpot_p50_ms", Json::num(s.tpot_p50 * 1e3)),
                ("tpot_p99_ms", Json::num(s.tpot_p99 * 1e3)),
                // per-priority token latencies (QoS tier observability;
                // keys "low"/"normal"/"high", all 0 until that tier has
                // retired a stream)
                ("ttft_count_by_priority", by_priority(&s.ttft_count_by_priority.map(|c| c as f64), 1.0)),
                ("ttft_p50_ms_by_priority", by_priority(&s.ttft_p50_by_priority, 1e3)),
                ("ttft_p99_ms_by_priority", by_priority(&s.ttft_p99_by_priority, 1e3)),
                ("tpot_count_by_priority", by_priority(&s.tpot_count_by_priority.map(|c| c as f64), 1.0)),
                ("tpot_p50_ms_by_priority", by_priority(&s.tpot_p50_by_priority, 1e3)),
                ("tpot_p99_ms_by_priority", by_priority(&s.tpot_p99_by_priority, 1e3)),
                // fault-tier outcome counters (graceful degradation)
                ("quarantined", Json::num(s.quarantined as f64)),
                ("deadline_cancelled", Json::num(s.deadline_cancelled as f64)),
                ("shed", Json::num(s.shed as f64)),
                ("injected_faults", Json::num(s.injected_faults as f64)),
                ("drain_ms", Json::num(s.drain_duration * 1e3)),
                // QoS / overload-control counters (preemption tier)
                ("preempted", Json::num(s.preempted as f64)),
                ("resumed", Json::num(s.resumed as f64)),
                ("overload_to_preempting", Json::num(s.overload_to_preempting as f64)),
                ("overload_to_shedding", Json::num(s.overload_to_shedding as f64)),
                ("priority_inversions", Json::num(s.priority_inversions as f64)),
                ("overload_state", Json::str(coordinator.overload_state().name())),
            ]))
        }
        "attn" => {
            let n = req.get("n").and_then(|v| v.as_usize()).unwrap_or(1024);
            let d = req.get("d").and_then(|v| v.as_usize()).unwrap_or(64);
            let seed = req.get("seed").and_then(|v| v.as_usize()).unwrap_or(1) as u64;
            let threads = req
                .get("threads")
                .and_then(|v| v.as_usize())
                .unwrap_or_else(crate::util::threadpool::default_threads)
                .clamp(1, crate::util::threadpool::default_threads());
            let params = crate::sparge::SpargeParams {
                tau: req.get("tau").and_then(|v| v.as_f64()).unwrap_or(0.9) as f32,
                theta: req.get("theta").and_then(|v| v.as_f64()).unwrap_or(0.3) as f32,
                lambda: req.get("lambda").and_then(|v| v.as_f64()).map(|l| l as f32),
                quant: req.get("quant").and_then(|v| v.as_bool()).unwrap_or(false),
            };
            // keep probes survivable: probes run synchronously on connection
            // workers, so cap the synthesized QKV (~25 MB at 8192×256) and
            // the attention cost; threads never exceed the machine's cores
            anyhow::ensure!(n > 0 && n <= 1 << 13, "n out of range (1..=8192)");
            anyhow::ensure!(d > 0 && d <= 256, "d out of range (1..=256)");
            match req.get("mode").and_then(|v| v.as_str()).unwrap_or("prefill") {
                "decode" => {
                    let steps = req.get("steps").and_then(|v| v.as_usize()).unwrap_or(16);
                    anyhow::ensure!(steps >= 1 && steps <= 1024, "steps out of range (1..=1024)");
                    let r = coordinator.attention_decode_probe(n, d, seed, &params, steps, threads);
                    Ok(Json::obj(vec![
                        ("mode", Json::str("decode")),
                        ("prefill_sparsity", Json::num(r.prefill_sparsity)),
                        ("per_step_sparsity", Json::arr(r.step_sparsity.iter().map(|&s| Json::num(s)))),
                        ("mean_step_sparsity", Json::num(r.mean_step_sparsity)),
                        ("latency_ms", Json::num(r.seconds * 1e3)),
                        ("n", Json::num(r.n as f64)),
                        ("d", Json::num(r.d as f64)),
                        ("steps", Json::num(r.steps as f64)),
                        ("threads", Json::num(r.threads as f64)),
                    ]))
                }
                "prefill" => {
                    let r = coordinator.attention_probe(n, d, seed, &params, threads);
                    Ok(Json::obj(vec![
                        ("sparsity", Json::num(r.sparsity)),
                        ("latency_ms", Json::num(r.seconds * 1e3)),
                        ("n", Json::num(r.n as f64)),
                        ("d", Json::num(r.d as f64)),
                        ("threads", Json::num(r.threads as f64)),
                    ]))
                }
                "serve" => {
                    // real serving traffic: N streams through the
                    // continuous-batching loop (TTFT capped by chunked
                    // prefill), not a caller-thread probe. The engine
                    // composition is fixed at coordinator startup, so
                    // probe-only knobs must be rejected, not silently
                    // ignored.
                    for key in ["tau", "theta", "lambda", "quant", "threads"] {
                        anyhow::ensure!(
                            req.get(key).is_none(),
                            "'{key}' is fixed by the serving engine at startup; \
                             the serve mode does not accept it"
                        );
                    }
                    let sessions = req.get("sessions").and_then(|v| v.as_usize()).unwrap_or(4);
                    let steps = req.get("steps").and_then(|v| v.as_usize()).unwrap_or(16);
                    anyhow::ensure!((1..=64).contains(&sessions), "sessions out of range (1..=64)");
                    anyhow::ensure!(steps <= 1024, "steps out of range (0..=1024)");
                    // per-request serving limits: enforced by the manager
                    // at tick boundaries (deadline → cancelled with a
                    // structured error; budget → truncated completion)
                    let priority = match req.get("priority").and_then(|v| v.as_str()) {
                        Some(s) => Priority::parse(s)
                            .with_context(|| format!("bad priority '{s}' (want low/normal/high)"))?,
                        None => Priority::default(),
                    };
                    let limits = crate::coordinator::request::RequestLimits {
                        deadline_ms: req.get("deadline_ms").and_then(|v| v.as_usize()).map(|m| m as u64),
                        token_budget: req.get("token_budget").and_then(|v| v.as_usize()),
                        priority,
                    };
                    let t0 = std::time::Instant::now();
                    let submitted: Vec<_> = (0..sessions)
                        .map(|i| {
                            let spec = crate::coordinator::request::AttnStreamSpec {
                                prefill: n,
                                decode: steps,
                                d,
                                seed: seed.wrapping_add(i as u64),
                                limits,
                            };
                            coordinator.submit_stream(spec, AttnMode::Sparge)
                        })
                        .collect();
                    if submitted.iter().any(|r| r.is_err()) {
                        // queue backpressure: the batcher refused the
                        // submit, so answer with the structured retry
                        // hint instead of a bare error string
                        let (retry_ms, depth) = coordinator.retry_hint();
                        return Ok(Json::obj(vec![
                            ("error", Json::str("queue full or closed (backpressure)")),
                            ("retry_after_ms", Json::num(retry_ms as f64)),
                            ("queue_depth", Json::num(depth as f64)),
                        ]));
                    }
                    let rxs: Vec<_> = submitted.into_iter().flatten().collect();
                    let mut rows = Vec::with_capacity(sessions);
                    let mut tokens = 0usize;
                    for rx in rxs {
                        let r = rx.recv().map_err(|_| anyhow::anyhow!("stream dropped"))?;
                        tokens += r.tokens;
                        let mut row = vec![
                            ("id", Json::num(r.id as f64)),
                            ("ttft_ms", Json::num(r.ttft.unwrap_or(0.0) * 1e3)),
                            ("tpot_ms", Json::num(r.tpot.unwrap_or(0.0) * 1e3)),
                            ("sparsity", Json::num(r.sparsity.unwrap_or(0.0))),
                            ("tokens", Json::num(r.tokens as f64)),
                            (
                                "error",
                                r.error.as_deref().map_or(Json::Null, Json::str),
                            ),
                        ];
                        // a stream shed under overload carries the retry
                        // hint the loop computed the tick it was dropped
                        if let Some(ms) = r.retry_after_ms {
                            row.push(("retry_after_ms", Json::num(ms as f64)));
                        }
                        if let Some(depth) = r.queue_depth {
                            row.push(("queue_depth", Json::num(depth as f64)));
                        }
                        rows.push(Json::obj(row));
                    }
                    let wall = t0.elapsed().as_secs_f64();
                    Ok(Json::obj(vec![
                        ("mode", Json::str("serve")),
                        ("sessions", Json::arr(rows.into_iter())),
                        ("wall_ms", Json::num(wall * 1e3)),
                        (
                            "tokens_per_sec",
                            Json::num(if wall > 0.0 { tokens as f64 / wall } else { 0.0 }),
                        ),
                    ]))
                }
                other => anyhow::bail!("unknown attn mode '{other}' (want 'prefill', 'decode', or 'serve')"),
            }
        }
        "generate" => {
            let prompt = req.get("prompt").and_then(|v| v.as_str()).context("missing 'prompt'")?;
            let max_new = req.get("max_new").and_then(|v| v.as_usize()).unwrap_or(16);
            let mode = req
                .get("mode")
                .and_then(|v| v.as_str())
                .map(|s| AttnMode::parse(s).context("bad mode"))
                .transpose()?
                .unwrap_or(AttnMode::Sparge);
            let resp = coordinator.generate(prompt.as_bytes().to_vec(), max_new, mode)?;
            Ok(Json::obj(vec![
                ("id", Json::num(resp.id as f64)),
                ("output", Json::str(&String::from_utf8_lossy(&resp.output))),
                ("latency_ms", Json::num(resp.latency * 1e3)),
                ("compute_ms", Json::num(resp.compute * 1e3)),
                ("mode", Json::str(resp.mode.name())),
            ]))
        }
        other => anyhow::bail!("unknown op '{other}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bad_json_reports_error() {
        // dispatch without a coordinator is impossible; parse errors are
        // caught before the coordinator is touched, so a dangling ref works
        // via a never-called closure. Instead test the JSON layer directly:
        let parsed = Json::parse("not json");
        assert!(parsed.is_err());
    }
}
