//! Request/response types for the serving coordinator.

use std::time::Instant;

/// Attention execution mode for a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AttnMode {
    Dense,
    Sparge,
}

impl AttnMode {
    pub fn parse(s: &str) -> Option<AttnMode> {
        match s {
            "dense" => Some(AttnMode::Dense),
            "sparge" => Some(AttnMode::Sparge),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            AttnMode::Dense => "dense",
            AttnMode::Sparge => "sparge",
        }
    }
}

/// A text-generation request (byte-level LM).
#[derive(Clone, Debug)]
pub struct GenerateRequest {
    pub id: u64,
    pub prompt: Vec<u8>,
    pub max_new_tokens: usize,
    pub mode: AttnMode,
}

/// Response to a generation request.
#[derive(Clone, Debug)]
pub struct GenerateResponse {
    pub id: u64,
    pub output: Vec<u8>,
    /// End-to-end latency (seconds) including queueing.
    pub latency: f64,
    /// Pure model-execution time (seconds).
    pub compute: f64,
    pub mode: AttnMode,
}

/// A queued request with its arrival timestamp.
#[derive(Debug)]
pub struct QueuedRequest {
    pub req: GenerateRequest,
    pub arrived: Instant,
    pub respond: std::sync::mpsc::Sender<GenerateResponse>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parse_roundtrip() {
        assert_eq!(AttnMode::parse("dense"), Some(AttnMode::Dense));
        assert_eq!(AttnMode::parse("sparge"), Some(AttnMode::Sparge));
        assert_eq!(AttnMode::parse("???"), None);
        assert_eq!(AttnMode::parse(AttnMode::Sparge.name()), Some(AttnMode::Sparge));
    }
}
