//! Serving metrics: thread-safe counters + latency reservoir, including
//! per-priority TTFT/TPOT reservoirs and the QoS counters behind
//! priority-aware preemption (preemptions, resumes, overload
//! transitions) — exported through the `stats` op and
//! `table8_serving --json`.

use std::sync::Mutex;

use super::qos::Priority;

/// Registry of serving counters. Cheap to share behind an `Arc`.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    requests: u64,
    tokens_out: u64,
    errors: u64,
    sparse_requests: u64,
    latencies: Vec<f64>,
    compute: Vec<f64>,
    sparsity: Vec<f64>,
    /// Time-to-first-token samples (seconds), recorded by the serving loop
    /// per request that produced at least one token.
    ttft: Vec<f64>,
    /// Per-output-token latency samples (seconds) for tokens after the
    /// first — the continuous-batching loop's decode-tick cadence.
    tpot: Vec<f64>,
    /// Streams retired by the quarantine path (worker-job panic or
    /// poisoned input caught at a tick boundary).
    quarantined: u64,
    /// Streams cancelled because their per-request deadline expired.
    deadline_cancelled: u64,
    /// Streams shed terminally (unservable, or pending at drain).
    shed: u64,
    /// Faults the installed `FaultPlan` actually injected (0 without a
    /// plan — production serving never increments this).
    injected_faults: u64,
    /// Wall-clock seconds the last graceful drain took.
    drain_duration: f64,
    /// Per-priority TTFT samples, indexed by `Priority::rank()` — the
    /// observable half of priority-aware scheduling: `high` TTFT must
    /// hold under overload while `low` degrades first.
    ttft_by_priority: [Vec<f64>; 3],
    /// Per-priority per-output-token samples, indexed like `ttft_by_priority`.
    tpot_by_priority: [Vec<f64>; 3],
    /// Sessions preempted to the offload tier.
    preempted: u64,
    /// Preempted sessions resumed from the tier.
    resumed: u64,
    /// Overload detector entries into `Preempting`.
    overload_to_preempting: u64,
    /// Overload detector entries into `Shedding`.
    overload_to_shedding: u64,
    /// Requests shed while a strictly lower-priority resident held
    /// frames. Structurally 0 — exported so dashboards (and the chaos
    /// suite) can pin the invariant.
    priority_inversions: u64,
}

/// A point-in-time snapshot for reporting.
#[derive(Clone, Debug, Default)]
pub struct Snapshot {
    pub requests: u64,
    pub tokens_out: u64,
    pub errors: u64,
    /// Requests that reported a kernel sparsity.
    pub sparse_requests: u64,
    pub latency_p50: f64,
    pub latency_p99: f64,
    pub mean_compute: f64,
    pub tokens_per_sec: f64,
    /// Mean achieved sparsity over sparsity-reporting requests (0 if none).
    pub mean_sparsity: f64,
    /// Requests that recorded a time-to-first-token.
    pub ttft_count: u64,
    pub ttft_p50: f64,
    pub ttft_p99: f64,
    /// Per-output-token latency samples recorded.
    pub tpot_count: u64,
    pub tpot_p50: f64,
    pub tpot_p99: f64,
    /// Streams quarantined (worker panic / poisoned input).
    pub quarantined: u64,
    /// Streams cancelled at their deadline.
    pub deadline_cancelled: u64,
    /// Streams shed terminally.
    pub shed: u64,
    /// Faults injected by an installed `FaultPlan` (0 in production).
    pub injected_faults: u64,
    /// Wall-clock seconds of the last graceful drain.
    pub drain_duration: f64,
    /// Per-priority TTFT sample counts, indexed by `Priority::rank()`
    /// (`[low, normal, high]`).
    pub ttft_count_by_priority: [u64; 3],
    pub ttft_p50_by_priority: [f64; 3],
    pub ttft_p99_by_priority: [f64; 3],
    /// Per-priority TPOT sample counts, indexed like the TTFT arrays.
    pub tpot_count_by_priority: [u64; 3],
    pub tpot_p50_by_priority: [f64; 3],
    pub tpot_p99_by_priority: [f64; 3],
    /// Sessions preempted to the offload tier.
    pub preempted: u64,
    /// Preempted sessions resumed from the tier.
    pub resumed: u64,
    /// Overload detector entries into `Preempting`.
    pub overload_to_preempting: u64,
    /// Overload detector entries into `Shedding`.
    pub overload_to_shedding: u64,
    /// Sheds that happened past a lower-priority resident (always 0; the
    /// scheduler's preemption order forbids them).
    pub priority_inversions: u64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Bound a sample reservoir: keep the newest 4096 samples.
    fn trim(v: &mut Vec<f64>) {
        if v.len() > 4096 {
            let cut = v.len() - 4096;
            v.drain(..cut);
        }
    }

    /// Record a completed request. `sparsity` is the achieved kernel
    /// sparsity when the request ran through the sparse pipeline and
    /// reported it, else `None`.
    pub fn record(&self, tokens_out: usize, latency: f64, compute: f64, sparsity: Option<f64>) {
        let mut g = self.inner.lock().unwrap();
        g.requests += 1;
        g.tokens_out += tokens_out as u64;
        g.latencies.push(latency);
        g.compute.push(compute);
        if let Some(s) = sparsity {
            g.sparse_requests += 1;
            g.sparsity.push(s);
            Self::trim(&mut g.sparsity);
        }
        Self::trim(&mut g.latencies);
        Self::trim(&mut g.compute);
    }

    /// Record a kernel-level `attn` probe: only its per-request sparsity.
    /// Probe timings deliberately stay out of the request/latency/compute
    /// reservoirs so serving metrics keep describing generation traffic.
    pub fn record_probe(&self, sparsity: f64) {
        let mut g = self.inner.lock().unwrap();
        g.sparse_requests += 1;
        g.sparsity.push(sparsity);
        Self::trim(&mut g.sparsity);
    }

    pub fn record_error(&self) {
        self.inner.lock().unwrap().errors += 1;
    }

    /// Record a terminal non-success stream outcome by its
    /// `SeqOutcome::name()` string ("quarantined", "deadline_cancelled",
    /// "shed"). Unknown names are ignored — `record_error` carries the
    /// aggregate either way.
    pub fn record_outcome(&self, outcome: &str) {
        let mut g = self.inner.lock().unwrap();
        match outcome {
            "quarantined" => g.quarantined += 1,
            "deadline_cancelled" => g.deadline_cancelled += 1,
            "shed" => g.shed += 1,
            _ => {}
        }
    }

    /// Record the total faults a `FaultPlan` injected over a serve loop's
    /// lifetime (taken once at drain).
    pub fn record_injected_faults(&self, n: u64) {
        self.inner.lock().unwrap().injected_faults += n;
    }

    /// Record how long a graceful drain took (seconds).
    pub fn record_drain_duration(&self, seconds: f64) {
        self.inner.lock().unwrap().drain_duration = seconds;
    }

    /// Record the serving loop's token-level timings for one retired
    /// request: the time to its first output token and the per-token
    /// latencies of every following output token (both in seconds).
    pub fn record_token_latency(&self, ttft: f64, tpot: &[f64]) {
        let mut g = self.inner.lock().unwrap();
        g.ttft.push(ttft);
        g.tpot.extend_from_slice(tpot);
        Self::trim(&mut g.ttft);
        Self::trim(&mut g.tpot);
    }

    /// [`Metrics::record_token_latency`] attributed to a priority class:
    /// feeds both the aggregate reservoirs and the per-priority ones, so
    /// the aggregates stay exactly what they were for callers that never
    /// set a priority.
    pub fn record_token_latency_for(&self, priority: Priority, ttft: f64, tpot: &[f64]) {
        let mut g = self.inner.lock().unwrap();
        g.ttft.push(ttft);
        g.tpot.extend_from_slice(tpot);
        Self::trim(&mut g.ttft);
        Self::trim(&mut g.tpot);
        let r = priority.rank() as usize;
        g.ttft_by_priority[r].push(ttft);
        g.tpot_by_priority[r].extend_from_slice(tpot);
        Self::trim(&mut g.ttft_by_priority[r]);
        Self::trim(&mut g.tpot_by_priority[r]);
    }

    /// Fold in the session manager's lifetime QoS counters (taken once
    /// at drain, like `record_injected_faults`): preemptions, resumes,
    /// overload transitions, and the (structurally zero) priority
    /// inversions.
    pub fn record_qos(
        &self,
        preempted: u64,
        resumed: u64,
        to_preempting: u64,
        to_shedding: u64,
        inversions: u64,
    ) {
        let mut g = self.inner.lock().unwrap();
        g.preempted += preempted;
        g.resumed += resumed;
        g.overload_to_preempting += to_preempting;
        g.overload_to_shedding += to_shedding;
        g.priority_inversions += inversions;
    }

    pub fn snapshot(&self) -> Snapshot {
        let g = self.inner.lock().unwrap();
        let sorted = |v: &[f64]| {
            let mut s = v.to_vec();
            s.sort_by(|a, b| a.partial_cmp(b).unwrap());
            s
        };
        let pct = |s: &[f64], p: f64| {
            if s.is_empty() {
                0.0
            } else {
                crate::util::stats::percentile_sorted(s, p)
            }
        };
        let lat = sorted(&g.latencies);
        let ttft = sorted(&g.ttft);
        let tpot = sorted(&g.tpot);
        let total_compute: f64 = g.compute.iter().sum();
        let total_sparsity: f64 = g.sparsity.iter().sum();
        let mut ttft_count_by_priority = [0u64; 3];
        let mut ttft_p50_by_priority = [0.0; 3];
        let mut ttft_p99_by_priority = [0.0; 3];
        let mut tpot_count_by_priority = [0u64; 3];
        let mut tpot_p50_by_priority = [0.0; 3];
        let mut tpot_p99_by_priority = [0.0; 3];
        for r in 0..3 {
            let t = sorted(&g.ttft_by_priority[r]);
            ttft_count_by_priority[r] = t.len() as u64;
            ttft_p50_by_priority[r] = pct(&t, 0.5);
            ttft_p99_by_priority[r] = pct(&t, 0.99);
            let t = sorted(&g.tpot_by_priority[r]);
            tpot_count_by_priority[r] = t.len() as u64;
            tpot_p50_by_priority[r] = pct(&t, 0.5);
            tpot_p99_by_priority[r] = pct(&t, 0.99);
        }
        Snapshot {
            requests: g.requests,
            tokens_out: g.tokens_out,
            errors: g.errors,
            sparse_requests: g.sparse_requests,
            latency_p50: pct(&lat, 0.5),
            latency_p99: pct(&lat, 0.99),
            mean_compute: if g.compute.is_empty() { 0.0 } else { total_compute / g.compute.len() as f64 },
            tokens_per_sec: if total_compute > 0.0 { g.tokens_out as f64 / total_compute } else { 0.0 },
            mean_sparsity: if g.sparsity.is_empty() { 0.0 } else { total_sparsity / g.sparsity.len() as f64 },
            ttft_count: g.ttft.len() as u64,
            ttft_p50: pct(&ttft, 0.5),
            ttft_p99: pct(&ttft, 0.99),
            tpot_count: g.tpot.len() as u64,
            tpot_p50: pct(&tpot, 0.5),
            tpot_p99: pct(&tpot, 0.99),
            quarantined: g.quarantined,
            deadline_cancelled: g.deadline_cancelled,
            shed: g.shed,
            injected_faults: g.injected_faults,
            drain_duration: g.drain_duration,
            ttft_count_by_priority,
            ttft_p50_by_priority,
            ttft_p99_by_priority,
            tpot_count_by_priority,
            tpot_p50_by_priority,
            tpot_p99_by_priority,
            preempted: g.preempted,
            resumed: g.resumed,
            overload_to_preempting: g.overload_to_preempting,
            overload_to_shedding: g.overload_to_shedding,
            priority_inversions: g.priority_inversions,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.record(10, 0.5, 0.4, None);
        m.record(20, 1.5, 1.2, None);
        m.record_error();
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.tokens_out, 30);
        assert_eq!(s.errors, 1);
        assert_eq!(s.sparse_requests, 0);
        assert!((s.latency_p50 - 1.0).abs() < 1e-9);
        assert!((s.mean_compute - 0.8).abs() < 1e-9);
        assert!((s.tokens_per_sec - 30.0 / 1.6).abs() < 1e-9);
        assert_eq!(s.mean_sparsity, 0.0);
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.latency_p50, 0.0);
        assert_eq!(s.mean_sparsity, 0.0);
    }

    #[test]
    fn per_request_sparsity_is_aggregated() {
        let m = Metrics::new();
        m.record(0, 0.1, 0.1, Some(0.6));
        m.record(0, 0.1, 0.1, Some(0.8));
        m.record(5, 0.1, 0.1, None);
        let s = m.snapshot();
        assert_eq!(s.requests, 3);
        assert_eq!(s.sparse_requests, 2);
        assert!((s.mean_sparsity - 0.7).abs() < 1e-9);
    }

    #[test]
    fn probes_do_not_pollute_serving_reservoirs() {
        let m = Metrics::new();
        m.record(10, 0.5, 0.4, None);
        m.record_probe(0.25);
        m.record_probe(0.75);
        let s = m.snapshot();
        // probes count toward sparsity aggregates only
        assert_eq!(s.requests, 1);
        assert_eq!(s.tokens_out, 10);
        assert_eq!(s.sparse_requests, 2);
        assert!((s.mean_sparsity - 0.5).abs() < 1e-9);
        assert!((s.latency_p50 - 0.5).abs() < 1e-9);
        assert!((s.mean_compute - 0.4).abs() < 1e-9);
    }

    #[test]
    fn token_latency_reservoirs() {
        let m = Metrics::new();
        m.record_token_latency(0.5, &[0.1, 0.1, 0.3]);
        m.record_token_latency(1.5, &[0.2]);
        let s = m.snapshot();
        assert_eq!(s.ttft_count, 2);
        assert_eq!(s.tpot_count, 4);
        assert!((s.ttft_p50 - 1.0).abs() < 1e-9);
        assert!(s.tpot_p50 >= 0.1 && s.tpot_p50 <= 0.3);
        assert!(s.ttft_p99 <= 1.5 + 1e-9 && s.ttft_p99 >= 1.0);
        // token timings never touch the request/latency reservoirs
        assert_eq!(s.requests, 0);
        assert_eq!(s.latency_p50, 0.0);
    }

    #[test]
    fn empty_token_latency_is_zero() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.ttft_count, 0);
        assert_eq!(s.ttft_p50, 0.0);
        assert_eq!(s.tpot_p99, 0.0);
    }

    #[test]
    fn outcome_counters_and_fault_telemetry() {
        let m = Metrics::new();
        m.record_outcome("quarantined");
        m.record_outcome("quarantined");
        m.record_outcome("deadline_cancelled");
        m.record_outcome("shed");
        m.record_outcome("completed"); // success is not an error counter
        m.record_injected_faults(7);
        m.record_drain_duration(0.25);
        let s = m.snapshot();
        assert_eq!(s.quarantined, 2);
        assert_eq!(s.deadline_cancelled, 1);
        assert_eq!(s.shed, 1);
        assert_eq!(s.injected_faults, 7);
        assert!((s.drain_duration - 0.25).abs() < 1e-12);
    }

    #[test]
    fn per_priority_reservoirs_and_qos_counters() {
        let m = Metrics::new();
        m.record_token_latency_for(Priority::High, 0.1, &[0.01, 0.02]);
        m.record_token_latency_for(Priority::Low, 0.9, &[0.5]);
        m.record_token_latency(0.4, &[]); // unattributed: aggregates only
        m.record_qos(3, 2, 4, 1, 0);
        let s = m.snapshot();
        assert_eq!(s.ttft_count_by_priority, [1, 0, 1]);
        assert_eq!(s.tpot_count_by_priority, [1, 0, 2]);
        assert!(s.ttft_p99_by_priority[0] > s.ttft_p99_by_priority[2]);
        assert_eq!(s.ttft_count, 3, "attributed samples also feed the aggregate");
        assert_eq!(s.preempted, 3);
        assert_eq!(s.resumed, 2);
        assert_eq!(s.overload_to_preempting, 4);
        assert_eq!(s.overload_to_shedding, 1);
        assert_eq!(s.priority_inversions, 0);
    }

    #[test]
    fn reservoir_is_bounded() {
        let m = Metrics::new();
        for _ in 0..5000 {
            m.record(1, 0.1, 0.1, Some(0.5));
        }
        assert!(m.inner.lock().unwrap().latencies.len() <= 4096);
        assert!(m.inner.lock().unwrap().sparsity.len() <= 4096);
        assert_eq!(m.snapshot().requests, 5000);
        assert_eq!(m.snapshot().sparse_requests, 5000);
    }
}
