//! The model engine: a dedicated thread owning the PJRT [`Runtime`]
//! (executables hold non-`Send` pointers) behind a channel-based actor
//! interface, so the multi-threaded coordinator can call it safely.
//!
//! Operations: LM logits / greedy generation / scoring (dense or sparge
//! artifacts), LM train steps (the e2e training driver), and DiT denoise
//! steps for the video benches.

use std::sync::mpsc;
use std::thread;

use anyhow::{anyhow, Context, Result};

use crate::runtime::{Runtime, Value};

use super::request::AttnMode;

/// LM context lengths exported by aot.py, ascending.
pub const LM_CTXS: &[usize] = &[256, 1024, 2048];
/// Train-step geometry exported by aot.py.
pub const TRAIN_B: usize = 8;
pub const TRAIN_T: usize = 256;

enum Msg {
    LmLogits { tokens: Vec<i32>, mode: AttnMode, reply: mpsc::Sender<Result<Vec<f32>>> },
    TrainStep { tokens: Vec<i32>, reply: mpsc::Sender<Result<f64>> },
    DitDenoise {
        latents: Vec<f32>,
        n: usize,
        d: usize,
        t: f32,
        mode: AttnMode,
        reply: mpsc::Sender<Result<Vec<f32>>>,
    },
    LoadParams { params: Vec<f32>, reply: mpsc::Sender<Result<()>> },
    GetParams { reply: mpsc::Sender<Result<Vec<f32>>> },
    Shutdown,
}

/// Cloneable, `Send` handle to the engine thread.
#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::Sender<Msg>,
}

/// Engine thread state.
struct Engine {
    rt: Runtime,
    /// flat LM params (+ Adam state while training)
    params: Vec<f32>,
    adam_m: Vec<f32>,
    adam_v: Vec<f32>,
    step: f32,
    dit_params: Option<Vec<f32>>,
}

impl Engine {
    fn new(artifact_dir: &std::path::Path) -> Result<Engine> {
        let rt = Runtime::new(artifact_dir)?;
        // initial weights from the build-time trace
        let init = crate::workloads::trace::load(&rt.dir().join("lm_init.spg"))
            .context("loading lm_init.spg")?;
        let params = init.into_iter().next().context("lm_init.spg empty")?.into_vec();
        let n = params.len();
        let dit_params = crate::workloads::trace::load(&rt.dir().join("dit_init.spg"))
            .ok()
            .and_then(|v| v.into_iter().next())
            .map(|t| t.into_vec());
        Ok(Engine { rt, params, adam_m: vec![0.0; n], adam_v: vec![0.0; n], step: 0.0, dit_params })
    }

    fn lm_artifact(&self, len: usize, mode: AttnMode) -> Result<(String, usize)> {
        let ctx = *LM_CTXS
            .iter()
            .find(|&&c| c >= len)
            .ok_or_else(|| anyhow!("prompt length {len} exceeds max context {}", LM_CTXS.last().unwrap()))?;
        Ok((format!("lm_fwd_{}_{}", mode.name(), ctx), ctx))
    }

    fn lm_logits(&self, tokens: &[i32], mode: AttnMode) -> Result<Vec<f32>> {
        let (name, ctx) = self.lm_artifact(tokens.len(), mode)?;
        // left-pad with zeros to the artifact context (causal attention:
        // padding on the left influences the suffix, so pad with byte 0x20
        // (space) — inert filler in the byte vocabulary).
        let mut padded = vec![b' ' as i32; ctx - tokens.len()];
        padded.extend_from_slice(tokens);
        let out = self.rt.run(
            &name,
            &[
                Value::F32(self.params.clone(), vec![self.params.len()]),
                Value::I32(padded, vec![ctx]),
            ],
        )?;
        let logits = out.into_iter().next().context("no logits")?;
        let vocab = logits.shape()[1];
        let data = match logits {
            Value::F32(d, _) => d,
            _ => return Err(anyhow!("logits not f32")),
        };
        // return only the rows for the real (unpadded) tokens
        let pad = ctx - tokens.len();
        Ok(data[pad * vocab..].to_vec())
    }

    fn train_step(&mut self, tokens: &[i32]) -> Result<f64> {
        anyhow::ensure!(tokens.len() == TRAIN_B * TRAIN_T, "train batch must be {TRAIN_B}x{TRAIN_T}");
        let n = self.params.len();
        let name = format!("lm_train_step_{TRAIN_B}x{TRAIN_T}");
        let out = self.rt.run(
            &name,
            &[
                Value::F32(self.params.clone(), vec![n]),
                Value::F32(self.adam_m.clone(), vec![n]),
                Value::F32(self.adam_v.clone(), vec![n]),
                Value::scalar_f32(self.step),
                Value::I32(tokens.to_vec(), vec![TRAIN_B, TRAIN_T]),
            ],
        )?;
        let mut it = out.into_iter();
        self.params = match it.next().context("params out")? {
            Value::F32(d, _) => d,
            _ => return Err(anyhow!("bad params dtype")),
        };
        self.adam_m = match it.next().context("m out")? {
            Value::F32(d, _) => d,
            _ => return Err(anyhow!("bad m dtype")),
        };
        self.adam_v = match it.next().context("v out")? {
            Value::F32(d, _) => d,
            _ => return Err(anyhow!("bad v dtype")),
        };
        self.step = it.next().context("step out")?.scalar()? as f32;
        let loss = it.next().context("loss out")?.scalar()?;
        Ok(loss)
    }

    fn dit_denoise(&self, latents: &[f32], n: usize, d: usize, t: f32, mode: AttnMode) -> Result<Vec<f32>> {
        let params = self.dit_params.as_ref().context("no dit params loaded")?;
        let name = format!("dit_fwd_{}_{n}", mode.name());
        let out = self.rt.run(
            &name,
            &[
                Value::F32(params.clone(), vec![params.len()]),
                Value::F32(latents.to_vec(), vec![n, d]),
                Value::scalar_f32(t),
            ],
        )?;
        match out.into_iter().next().context("no dit output")? {
            Value::F32(data, _) => Ok(data),
            _ => Err(anyhow!("dit output not f32")),
        }
    }

    fn serve(mut self, rx: mpsc::Receiver<Msg>) {
        while let Ok(msg) = rx.recv() {
            match msg {
                Msg::LmLogits { tokens, mode, reply } => {
                    let _ = reply.send(self.lm_logits(&tokens, mode));
                }
                Msg::TrainStep { tokens, reply } => {
                    let _ = reply.send(self.train_step(&tokens));
                }
                Msg::DitDenoise { latents, n, d, t, mode, reply } => {
                    let _ = reply.send(self.dit_denoise(&latents, n, d, t, mode));
                }
                Msg::LoadParams { params, reply } => {
                    let _ = reply.send(if params.len() == self.params.len() {
                        self.params = params;
                        Ok(())
                    } else {
                        Err(anyhow!("param size mismatch: {} vs {}", params.len(), self.params.len()))
                    });
                }
                Msg::GetParams { reply } => {
                    let _ = reply.send(Ok(self.params.clone()));
                }
                Msg::Shutdown => break,
            }
        }
    }
}

impl EngineHandle {
    /// Spawn the engine thread over an artifact directory.
    pub fn spawn(artifact_dir: &std::path::Path) -> Result<EngineHandle> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let dir = artifact_dir.to_path_buf();
        thread::Builder::new()
            .name("sparge-engine".into())
            .spawn(move || match Engine::new(&dir) {
                Ok(engine) => {
                    let _ = ready_tx.send(Ok(()));
                    engine.serve(rx);
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                }
            })
            .expect("spawn engine");
        ready_rx.recv().context("engine thread died")??;
        Ok(EngineHandle { tx })
    }

    fn call<T>(&self, build: impl FnOnce(mpsc::Sender<Result<T>>) -> Msg) -> Result<T> {
        let (reply, rx) = mpsc::channel();
        self.tx.send(build(reply)).map_err(|_| anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow!("engine dropped reply"))?
    }

    /// Logits for all positions of `tokens` ((len × vocab) row-major).
    pub fn lm_logits(&self, tokens: Vec<i32>, mode: AttnMode) -> Result<Vec<f32>> {
        self.call(|reply| Msg::LmLogits { tokens, mode, reply })
    }

    /// One Adam step over a (TRAIN_B × TRAIN_T) token batch; returns loss.
    pub fn train_step(&self, tokens: Vec<i32>) -> Result<f64> {
        self.call(|reply| Msg::TrainStep { tokens, reply })
    }

    /// One DiT denoise step; `n` must match an exported artifact.
    pub fn dit_denoise(
        &self,
        latents: Vec<f32>,
        n: usize,
        d: usize,
        t: f32,
        mode: AttnMode,
    ) -> Result<Vec<f32>> {
        self.call(|reply| Msg::DitDenoise { latents, n, d, t, mode, reply })
    }

    /// Replace LM weights (e.g. after loading a trained checkpoint).
    pub fn load_params(&self, params: Vec<f32>) -> Result<()> {
        self.call(|reply| Msg::LoadParams { params, reply })
    }

    /// Snapshot LM weights (e.g. to save a checkpoint).
    pub fn get_params(&self) -> Result<Vec<f32>> {
        self.call(|reply| Msg::GetParams { reply })
    }

    /// One greedy token step: trim `tokens` to the artifact context, run
    /// the forward pass, argmax the last row, append, and return the new
    /// byte. **The** single source of truth for the LM decode step — both
    /// [`EngineHandle::generate`] and the scheduler's per-tick LM step go
    /// through it, which is what makes the continuous-batching loop with
    /// `max_batch = 1` reproduce sequential outputs exactly.
    pub fn lm_next_token(&self, tokens: &mut Vec<i32>, mode: AttnMode) -> Result<u8> {
        anyhow::ensure!(!tokens.is_empty(), "empty token context");
        let max_ctx = *LM_CTXS.last().unwrap();
        if tokens.len() > max_ctx {
            let excess = tokens.len() - max_ctx;
            tokens.drain(..excess);
        }
        let logits = self.lm_logits(tokens.clone(), mode)?;
        let vocab = 256;
        let last = &logits[(tokens.len() - 1) * vocab..tokens.len() * vocab];
        let next = last
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i as i32)
            .unwrap();
        tokens.push(next);
        Ok(next as u8)
    }

    /// Greedy generation: returns `max_new` generated bytes.
    pub fn generate(&self, prompt: &[u8], max_new: usize, mode: AttnMode) -> Result<Vec<u8>> {
        let mut tokens: Vec<i32> = prompt.iter().map(|&b| b as i32).collect();
        let mut out = Vec::with_capacity(max_new);
        for _ in 0..max_new {
            out.push(self.lm_next_token(&mut tokens, mode)?);
        }
        Ok(out)
    }

    /// Mean next-byte negative log-likelihood of `tokens` (perplexity =
    /// exp of this).
    pub fn score_nll(&self, tokens: &[u8], mode: AttnMode) -> Result<f64> {
        let toks: Vec<i32> = tokens.iter().map(|&b| b as i32).collect();
        let logits = self.lm_logits(toks.clone(), mode)?;
        let vocab = 256;
        let mut nll = 0f64;
        let n = toks.len();
        for t in 0..n - 1 {
            let row = &logits[t * vocab..(t + 1) * vocab];
            let lse = crate::tensor::ops::logsumexp(row) as f64;
            nll += lse - row[toks[t + 1] as usize] as f64;
        }
        Ok(nll / (n - 1) as f64)
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(Msg::Shutdown);
    }
}

/// Test-only stub engine: a real `sparge-engine`-named thread behind a
/// normal [`EngineHandle`], with no PJRT runtime — every model op answers
/// with an error. The returned receiver reports how the thread exited:
/// `true` for an explicit [`Msg::Shutdown`] (what `Coordinator` must
/// deliver), `false` for a dropped channel. Lets coordinator lifecycle
/// and error paths run where no artifacts exist.
#[cfg(test)]
pub(crate) fn stub_engine() -> (EngineHandle, mpsc::Receiver<bool>) {
    let (tx, rx) = mpsc::channel::<Msg>();
    let (exit_tx, exit_rx) = mpsc::channel::<bool>();
    thread::Builder::new()
        .name("sparge-engine".into())
        .spawn(move || {
            while let Ok(msg) = rx.recv() {
                match msg {
                    Msg::LmLogits { reply, .. } => {
                        let _ = reply.send(Err(anyhow!("stub engine")));
                    }
                    Msg::TrainStep { reply, .. } => {
                        let _ = reply.send(Err(anyhow!("stub engine")));
                    }
                    Msg::DitDenoise { reply, .. } => {
                        let _ = reply.send(Err(anyhow!("stub engine")));
                    }
                    Msg::LoadParams { reply, .. } => {
                        let _ = reply.send(Err(anyhow!("stub engine")));
                    }
                    Msg::GetParams { reply } => {
                        let _ = reply.send(Err(anyhow!("stub engine")));
                    }
                    Msg::Shutdown => {
                        let _ = exit_tx.send(true);
                        return;
                    }
                }
            }
            let _ = exit_tx.send(false);
        })
        .expect("spawn stub engine");
    (EngineHandle { tx }, exit_rx)
}
