//! [`SessionManager`]: N live attention sessions over **one** shared
//! [`AttnEngine`]/worker pool — the token-level execution core of the
//! continuous-batching serving loop.
//!
//! Each admitted request is a [`SeqStream`] (prompt rows + decode rows,
//! deterministic from an [`AttnStreamSpec`] seed). The scheduler drives
//! the manager in ticks; per tick every active session advances by one
//! unit of work (phases snapshotted at tick start, so a session never
//! advances twice in one tick):
//!
//! - **prefilling** sessions run one *bounded* prompt chunk
//!   ([`crate::attention::AttnSession::prefill_chunk`], at most
//!   `chunk` rows, interior edges aligned down to `b_q` so chunked
//!   execution is bitwise-faithful to one-shot prefill — see the parity
//!   notes in [`crate::attention::engine`]). Chunks run one session at a
//!   time: a chunk is many query-tile rows, which the engine already
//!   fans across its pool. Bounding the chunk caps how long any tick can
//!   run, which caps time-to-first-token for every other queued and
//!   active session;
//! - **decoding** sessions advance one single-row step each, **batched**:
//!   every decode-ready session is advanced inside one pool fan-out, so
//!   token-phase throughput scales with cores across sessions. Each step
//!   runs `Exec::Inline` inside its worker (the pipeline is
//!   bitwise-identical across exec modes, so outputs do not depend on
//!   batch composition), writes its output row **directly into the
//!   session's preallocated result buffer**
//!   ([`crate::attention::AttnSession::decode_into`]) and draws scratch
//!   from session/worker-owned workspaces. The manager's phase snapshot
//!   and fan-out index list live in tick-persistent arenas, so a
//!   warmed-up decode tick performs no heap allocation at all — not in
//!   any session's step and not in the scheduling bookkeeping around
//!   them (`tests/alloc_regression.rs` pins this). The pool hands
//!   sessions out by chunked self-scheduling with the scheduler thread
//!   participating, so one slow session (a ragged long-cache tail) no
//!   longer serializes the tick behind idle workers. A *lone* decoding
//!   session instead keeps the engine's own executor, which lets the
//!   engine's split-KV policy fan the single step's KV spans across the
//!   same pool — the two levels of decode parallelism time-share one set
//!   of workers;
//! - finished sessions retire with a [`SeqResult`]: output rows, merged
//!   [`SkipStats`], TTFT, per-output-token latencies, compute seconds.
//!
//! A manager built with [`SessionManager::new_paged`] runs the same tick
//! structure over **paged** sessions: every KV cache lives in a shared
//! [`crate::attention::paged::PageAllocator`] frame pool. Admission
//! reserves each active session's worst-case remaining frame need, so a
//! stream is admitted only when the pool can cover its whole lifetime —
//! otherwise unreferenced shared-prefix frames are reclaimed and, when
//! even that is not enough, the stream defers with a load-shed counter.
//! Identical whole-prompt prefills share their prefix frames
//! copy-on-write, each decode step splits into a serial frame-claim
//! half and a batched compute half over the read-only allocator, and a
//! decode claim that outruns the free list (a CoW split or re-page-in)
//! spills the least-recently-advanced resident session to make room —
//! never one that already ran its append half this tick, whose pending
//! compute half still needs its page table. For
//! f32/λ-off engines the paged manager's outputs and stats are
//! bitwise-identical to the monolithic one's (`tests/paged_kv.rs`).
//!
//! [`run_sequential`] is the request-level baseline (one-shot prefill,
//! then all decode steps, one request at a time): with `max_batch = 1`
//! the continuous loop reproduces its per-request outputs exactly under
//! `KvSplit::Off` (with split-KV on, a sub-`b_q` tail chunk of a
//! chunked prefill re-trees its reduction, so those prompt rows are
//! allclose instead — decode rows and all `SkipStats` stay exact), and
//! `benches/table8_serving.rs` measures what interleaving buys over it
//! (including decode tokens/s vs pool size, split-KV on and off).

use std::collections::VecDeque;
use std::time::Instant;

use crate::attention::paged::{PageAllocator, PageStats, PagedAttnSession, PrefixRegistry};
use crate::attention::pipeline::{debug_assert_disjoint_slots, SendPtr};
use crate::attention::{AttnEngine, AttnSession, Exec, SkipStats, Workspace};
use crate::tensor::Tensor;
use crate::workloads::{synthetic, SyntheticSpec};

use super::request::AttnStreamSpec;

/// The token stream a session consumes: `prefill` prompt rows of q/k/v,
/// then one decode row per step until the rows run out.
#[derive(Clone, Debug)]
pub struct SeqStream {
    pub q: Tensor,
    pub k: Tensor,
    pub v: Tensor,
    pub prefill: usize,
}

impl SeqStream {
    /// Deterministic synthetic stream for a spec (seeded LM-like QKV of
    /// `prefill + decode` rows).
    pub fn synth(spec: &AttnStreamSpec) -> SeqStream {
        let n = spec.prefill + spec.decode;
        assert!(n > 0, "empty attention stream");
        let mut rng = crate::util::rng::Pcg::seeded(spec.seed);
        let s = synthetic::generate(&SyntheticSpec::lm_like(n, spec.d), &mut rng);
        SeqStream { q: s.q, k: s.k, v: s.v, prefill: spec.prefill }
    }

    /// Total rows (prefill + decode).
    pub fn len(&self) -> usize {
        self.q.dim(0)
    }

    pub fn is_empty(&self) -> bool {
        self.q.dim(0) == 0
    }

    /// Decode steps this stream will take.
    pub fn decode_steps(&self) -> usize {
        self.len() - self.prefill
    }
}

/// A retired sequence: everything the serving loop reports and records.
#[derive(Clone, Debug)]
pub struct SeqResult {
    pub id: u64,
    /// All output rows, prefill then decode ((prefill+decode) × dv).
    pub out: Tensor,
    /// Merged skip counters over every prefill chunk and decode step.
    pub stats: SkipStats,
    /// Decode rows produced (the stream's output tokens).
    pub tokens: usize,
    /// Seconds from arrival to the first output token (the first decode
    /// row, or prefill completion for decode-less streams).
    pub ttft: f64,
    /// Per-output-token latencies (seconds) for tokens after the first.
    pub tpot: Vec<f64>,
    /// Seconds from arrival to retirement.
    pub latency: f64,
    /// Summed kernel seconds across the session's chunks and steps.
    pub compute: f64,
}

impl SeqResult {
    /// Mean per-output-token latency; 0 when fewer than two tokens.
    pub fn tpot_mean(&self) -> f64 {
        if self.tpot.is_empty() {
            0.0
        } else {
            self.tpot.iter().sum::<f64>() / self.tpot.len() as f64
        }
    }
}

/// The two KV-ownership models a managed sequence can run under: a
/// monolithic session (private cache tensors) or a paged session over
/// the manager's shared frame pool. A manager is homogeneous — every
/// admitted sequence uses the model the constructor picked.
enum SeqSession<'e> {
    Mono(AttnSession<'e>),
    Paged(PagedAttnSession<'e>),
}

struct ActiveSeq<'e> {
    id: u64,
    stream: SeqStream,
    session: SeqSession<'e>,
    prefilled: usize,
    decoded: usize,
    /// All output rows, preallocated at admission for the stream's full
    /// length — decode steps write their row into the tail in place.
    out: Vec<f32>,
    /// Reusable 1-row staging tensors for decode steps (the stream rows
    /// are copied in, never re-wrapped — no per-token allocation).
    qrow: Tensor,
    krow: Tensor,
    vrow: Tensor,
    stats: SkipStats,
    arrived: Instant,
    compute: f64,
    ttft: Option<f64>,
    tpot: Vec<f64>,
    /// Tick stamp of the last unit of work (the paged manager's LRU
    /// eviction key — least-recently-advanced spills first).
    last_advanced: u64,
    /// Seconds spent in this tick's serial append half of a paged decode
    /// step, folded into the step's latency sample when the parallel
    /// compute half lands.
    pending_dt: f64,
}

impl ActiveSeq<'_> {
    fn finished(&self) -> bool {
        self.prefilled == self.stream.prefill && self.decoded == self.stream.decode_steps()
    }

    /// Run one bounded prefill chunk (`chunk` rows, pre-aligned by the
    /// manager) and do the session's bookkeeping.
    fn advance_prefill(&mut self, chunk: usize) {
        let t0 = Instant::now();
        let end = (self.prefilled + chunk).min(self.stream.prefill);
        let SeqSession::Mono(session) = &mut self.session else {
            return; // paged sessions advance via advance_prefill_paged
        };
        let r = session.prefill_chunk(
            &self.stream.q.rows(self.prefilled, end),
            &self.stream.k.rows(self.prefilled, end),
            &self.stream.v.rows(self.prefilled, end),
        );
        self.out.extend_from_slice(r.out.data());
        self.stats.merge(&r.stats);
        self.prefilled = end;
        self.compute += t0.elapsed().as_secs_f64();
        if self.finished() {
            // decode-less stream: the prompt's last row is its first (and
            // only) "token"
            self.ttft = Some(self.arrived.elapsed().as_secs_f64());
        }
    }

    /// Paged twin of [`ActiveSeq::advance_prefill`]: a whole-prompt first
    /// chunk routes through the shared-prefix registry (identical prompts
    /// map the same frames and skip the compute); later chunks prefill
    /// normally. When the free list cannot cover the chunk the session is
    /// left untouched and simply retries next tick — deferral, not
    /// failure.
    fn advance_prefill_paged(
        &mut self,
        chunk: usize,
        alloc: &mut PageAllocator,
        registry: &mut PrefixRegistry,
        tick: u64,
    ) {
        let t0 = Instant::now();
        let end = (self.prefilled + chunk).min(self.stream.prefill);
        let q = self.stream.q.rows(self.prefilled, end);
        let k = self.stream.k.rows(self.prefilled, end);
        let v = self.stream.v.rows(self.prefilled, end);
        let SeqSession::Paged(session) = &mut self.session else {
            return; // mono sessions advance via advance_prefill
        };
        let whole_prompt = self.prefilled == 0 && end == self.stream.prefill;
        let r = if whole_prompt {
            session.prefill_shared(alloc, registry, &q, &k, &v)
        } else {
            session.prefill_chunk(alloc, &q, &k, &v)
        };
        let Some(r) = r else { return };
        self.out.extend_from_slice(r.out.data());
        self.stats.merge(&r.stats);
        self.prefilled = end;
        self.last_advanced = tick;
        self.compute += t0.elapsed().as_secs_f64();
        if self.finished() {
            self.ttft = Some(self.arrived.elapsed().as_secs_f64());
        }
    }

    /// Run one single-row decode step under `exec` (the engine's own
    /// executor when this session is advanced alone, `Exec::Inline` when
    /// it is advanced inside the batched cross-session fan-out — outputs
    /// are bitwise-identical either way) and do the session's
    /// bookkeeping. Allocation-free once the session is warm: the stream
    /// row is copied into reusable staging tensors and the output row is
    /// written straight into the preallocated result buffer.
    fn advance_decode(&mut self, exec: Exec<'_>) {
        let t0 = Instant::now();
        let t = self.stream.prefill + self.decoded;
        self.qrow.data_mut().copy_from_slice(self.stream.q.row(t));
        self.krow.data_mut().copy_from_slice(self.stream.k.row(t));
        self.vrow.data_mut().copy_from_slice(self.stream.v.row(t));
        let dv = self.stream.v.dim(1);
        let base = self.out.len();
        self.out.resize(base + dv, 0.0);
        let SeqSession::Mono(session) = &mut self.session else {
            return; // paged sessions advance via begin/finish_decode_paged
        };
        let (stats, _mask) = session.decode_into_with_exec(
            &self.qrow,
            &self.krow,
            &self.vrow,
            &mut self.out[base..],
            exec,
        );
        self.stats.merge(&stats);
        self.decoded += 1;
        let dt = t0.elapsed().as_secs_f64();
        self.compute += dt;
        if self.ttft.is_none() {
            self.ttft = Some(self.arrived.elapsed().as_secs_f64());
        } else {
            self.tpot.push(dt);
        }
    }

    /// Serial half of a paged decode step: stage the token's rows,
    /// re-page-in if the session was evicted, and claim/CoW the tail
    /// frame (all the `&mut PageAllocator` work). `false` — session
    /// untouched — when frames are short; the session skips this tick and
    /// retries. Allocation-free once warm.
    fn begin_decode_paged(&mut self, alloc: &mut PageAllocator, tick: u64) -> bool {
        let t0 = Instant::now();
        let t = self.stream.prefill + self.decoded;
        self.qrow.data_mut().copy_from_slice(self.stream.q.row(t));
        self.krow.data_mut().copy_from_slice(self.stream.k.row(t));
        self.vrow.data_mut().copy_from_slice(self.stream.v.row(t));
        let SeqSession::Paged(session) = &mut self.session else {
            return false;
        };
        if !session.ensure_resident(alloc) {
            return false;
        }
        if !session.append_token(alloc, &self.qrow, &self.krow, &self.vrow) {
            return false;
        }
        self.last_advanced = tick;
        self.pending_dt = t0.elapsed().as_secs_f64();
        true
    }

    /// Parallel half of a paged decode step: run the compute over the
    /// shared `&PageAllocator` (read-only during compute, so the batched
    /// tick fans many sessions over one borrow) and fold this tick's
    /// append seconds into the step's latency sample.
    fn finish_decode_paged(&mut self, alloc: &PageAllocator, exec: Exec<'_>) {
        let t0 = Instant::now();
        let dv = self.stream.v.dim(1);
        let base = self.out.len();
        self.out.resize(base + dv, 0.0);
        let SeqSession::Paged(session) = &mut self.session else {
            return;
        };
        let (stats, _predicted) = session.decode_step(alloc, &self.qrow, exec, &mut self.out[base..]);
        self.stats.merge(&stats);
        self.decoded += 1;
        let dt = self.pending_dt + t0.elapsed().as_secs_f64();
        self.pending_dt = 0.0;
        self.compute += dt;
        if self.ttft.is_none() {
            self.ttft = Some(self.arrived.elapsed().as_secs_f64());
        } else {
            self.tpot.push(dt);
        }
    }

    fn into_result(self) -> SeqResult {
        let dv = self.stream.v.dim(1);
        let rows = self.out.len() / dv;
        SeqResult {
            id: self.id,
            out: Tensor::from_vec(&[rows, dv], self.out),
            stats: self.stats,
            tokens: self.decoded,
            ttft: self.ttft.unwrap_or(0.0),
            tpot: self.tpot,
            latency: self.arrived.elapsed().as_secs_f64(),
            compute: self.compute,
        }
    }
}

/// The paged manager's memory plane: the shared frame pool, the
/// shared-prefix registry, and the frame-aware admission queue.
struct PagedServing {
    alloc: PageAllocator,
    registry: PrefixRegistry,
    /// Streams admitted by the caller but not yet holding frames —
    /// admission into `active` happens inside `tick`, keyed on the free
    /// list.
    pending: VecDeque<(u64, SeqStream, Instant)>,
    /// Ticks on which admission stalled with the queue non-empty even
    /// after LRU eviction (the load-shed signal).
    deferred: u64,
}

/// N live [`AttnSession`]s over one shared engine; see the module docs.
pub struct SessionManager<'e> {
    engine: &'e AttnEngine,
    /// Max prompt rows per prefill tick, before `b_q` alignment.
    chunk: usize,
    active: Vec<ActiveSeq<'e>>,
    /// Tick-persistent phase snapshot (parallel to `active`), rebuilt in
    /// place each tick so whole warmed decode ticks allocate nothing.
    decode_phase: Vec<bool>,
    /// Tick-persistent indices (into `active`) of the decode-ready
    /// sessions, fanned out by the batched decode phase.
    ready_idx: Vec<usize>,
    /// The scheduler thread's own workspace for participating in the
    /// batched decode fan-out (each session's step draws on the session's
    /// arena; this one just satisfies the seam).
    tick_ws: Workspace,
    /// `Some` for paged managers (see [`SessionManager::new_paged`]);
    /// `None` managers run monolithic sessions exactly as before.
    paging: Option<PagedServing>,
    /// Tick counter — the LRU stamp source for paged eviction.
    ticks: u64,
}

impl<'e> SessionManager<'e> {
    /// `chunk` bounds the prompt rows a session prefills per tick; interior
    /// chunk edges are aligned down to the engine's `b_q` (at least one
    /// query block per tick) so chunked prefill stays bitwise-faithful to
    /// one-shot prefill.
    pub fn new(engine: &'e AttnEngine, chunk: usize) -> SessionManager<'e> {
        assert!(chunk > 0, "prefill chunk must be positive");
        SessionManager {
            engine,
            chunk,
            active: Vec::new(),
            decode_phase: Vec::new(),
            ready_idx: Vec::new(),
            tick_ws: Workspace::default(),
            paging: None,
            ticks: 0,
        }
    }

    /// A manager whose sessions page their KV caches out of `alloc`
    /// instead of owning private tensors. Admission becomes frame-aware:
    /// [`SessionManager::admit`] only enqueues, and each tick admits
    /// pending streams while the free list covers their worst-case frame
    /// need plus every active session's outstanding reservation
    /// (reclaiming unreferenced shared-prefix frames under pressure, and
    /// counting a load-shed instead of failing when even that is not
    /// enough). Whole-prompt prefills route through a shared-prefix
    /// registry, so identical prompts map the same frames and skip their
    /// prefill compute; decode claims that still outrun the pool evict
    /// the least-recently-advanced resident session.
    pub fn new_paged(engine: &'e AttnEngine, chunk: usize, alloc: PageAllocator) -> SessionManager<'e> {
        let mut m = SessionManager::new(engine, chunk);
        m.paging = Some(PagedServing {
            alloc,
            registry: PrefixRegistry::new(),
            pending: VecDeque::new(),
            deferred: 0,
        });
        m
    }

    /// Live session count.
    pub fn active(&self) -> usize {
        self.active.len()
    }

    /// Sessions still consuming their prompt.
    pub fn prefilling(&self) -> usize {
        self.active.iter().filter(|s| s.prefilled < s.stream.prefill).count()
    }

    /// Sessions past their prompt, producing decode tokens.
    pub fn decoding(&self) -> usize {
        self.active.len() - self.prefilling()
    }

    /// Rows per prefill tick: `chunk` aligned down to a `b_q` multiple.
    fn chunk_rows(&self) -> usize {
        let bq = self.engine.config().bq;
        (self.chunk / bq * bq).max(bq)
    }

    /// Open a session for a stream. The caller enforces its own admission
    /// cap (the scheduler admits up to `BatchPolicy::max_batch`). Paged
    /// managers only *enqueue* here — the frame-aware admission into the
    /// active set happens inside [`SessionManager::tick`].
    pub fn admit(&mut self, id: u64, stream: SeqStream, arrived: Instant) {
        assert!(!stream.is_empty(), "empty attention stream");
        if let Some(p) = self.paging.as_mut() {
            p.pending.push_back((id, stream, arrived));
            return;
        }
        let session = SeqSession::Mono(self.engine.session());
        self.push_active(id, stream, arrived, session);
    }

    /// Streams enqueued on a paged manager but not yet holding frames.
    pub fn pending(&self) -> usize {
        self.paging.as_ref().map_or(0, |p| p.pending.len())
    }

    /// Memory-plane counter snapshot of a paged manager (`None` for
    /// monolithic managers).
    pub fn page_stats(&self) -> Option<PageStats> {
        self.paging.as_ref().map(|p| p.alloc.stats())
    }

    /// Registered shared prompt prefixes (paged managers).
    pub fn prefix_entries(&self) -> usize {
        self.paging.as_ref().map_or(0, |p| p.registry.len())
    }

    /// Drop the shared-prefix registry's frame references (frames still
    /// mapped by live sessions stay resident through those sessions).
    pub fn release_prefixes(&mut self) {
        if let Some(p) = self.paging.as_mut() {
            p.registry.clear(&mut p.alloc);
        }
    }

    fn push_active(&mut self, id: u64, stream: SeqStream, arrived: Instant, session: SeqSession<'e>) {
        let d = stream.q.dim(1);
        let dv = stream.v.dim(1);
        let total = stream.len() * dv;
        let steps = stream.decode_steps();
        self.active.push(ActiveSeq {
            id,
            session,
            qrow: Tensor::zeros(&[1, d]),
            krow: Tensor::zeros(&[1, d]),
            vrow: Tensor::zeros(&[1, dv]),
            stream,
            prefilled: 0,
            decoded: 0,
            // the stream's full output, reserved up front: decode steps
            // extend into capacity, never reallocating mid-flight
            out: Vec::with_capacity(total),
            stats: SkipStats::default(),
            arrived,
            compute: 0.0,
            ttft: None,
            // one sample per output token after the first: reserved up
            // front so warmed ticks never grow it mid-flight
            tpot: Vec::with_capacity(steps.saturating_sub(1)),
            last_advanced: self.ticks,
            pending_dt: 0.0,
        });
    }

    /// Spill the least-recently-advanced resident decode-phase session
    /// other than `exclude` (its frames recycle; it transparently
    /// re-pages-in on its next decode). Sessions stamped `tick` are never
    /// candidates: a stamp equal to the current tick means the session
    /// already ran its serial append half this tick and its batched
    /// compute half is still pending — spilling it in between would hand
    /// `decode_step` an empty page table. `false` when no session is
    /// evictable.
    fn evict_lru(
        active: &mut [ActiveSeq<'_>],
        alloc: &mut PageAllocator,
        tick: u64,
        exclude: Option<usize>,
    ) -> bool {
        let mut best: Option<usize> = None;
        for (i, s) in active.iter().enumerate() {
            if Some(i) == exclude {
                continue; // never spill the session we are advancing
            }
            if s.last_advanced == tick {
                continue; // mid-step this tick: append done, compute pending
            }
            if s.prefilled < s.stream.prefill {
                continue; // mid-prompt sessions keep their frames
            }
            let resident = match &s.session {
                SeqSession::Paged(p) => !p.is_evicted() && p.frames_held() > 0,
                SeqSession::Mono(_) => false,
            };
            if !resident {
                continue;
            }
            if best.map_or(true, |b| s.last_advanced < active[b].last_advanced) {
                best = Some(i);
            }
        }
        let Some(i) = best else { return false };
        if let SeqSession::Paged(p) = &mut active[i].session {
            p.evict(alloc);
        }
        true
    }

    /// One scheduling tick: every active session advances one unit —
    /// prefilling sessions by one bounded chunk (serially: each chunk
    /// already fans its query-tile rows across the pool), decode-ready
    /// sessions by one token **in one batched map over the engine's
    /// workers** — and finished sessions retire (in admission order).
    /// Phases are snapshotted at tick start, so a session that finishes
    /// its prompt this tick starts decoding next tick, exactly like the
    /// old serial loop.
    pub fn tick(&mut self) -> Vec<SeqResult> {
        self.ticks += 1;
        if self.paging.is_some() {
            return self.tick_paged();
        }
        let chunk = self.chunk_rows();
        // phase snapshot: one unit of work per session per tick (rebuilt
        // in the tick-persistent arenas — no per-tick slot vector)
        self.decode_phase.clear();
        self.decode_phase.extend(self.active.iter().map(|s| s.prefilled == s.stream.prefill));
        for (seq, &decoding) in self.active.iter_mut().zip(&self.decode_phase) {
            if !decoding {
                seq.advance_prefill(chunk);
            }
        }
        self.ready_idx.clear();
        for (i, (s, &d)) in self.active.iter().zip(&self.decode_phase).enumerate() {
            if d && s.decoded < s.stream.decode_steps() {
                self.ready_idx.push(i);
            }
        }
        match self.ready_idx.len() {
            0 => {}
            // a lone decoder keeps the engine's executor: the engine's
            // split-KV policy fans the step's KV spans across the pool
            1 => self.active[self.ready_idx[0]].advance_decode(self.engine.exec()),
            // cross-session batch: one chunk-self-scheduled fan-out over
            // (session, step) pairs — the scheduler thread participates
            // with the manager's persistent workspace; each participant
            // runs exactly one session's step inline
            _ => {
                // Each fan-out item owns exactly one `ActiveSeq` slot;
                // a duplicate index in `ready_idx` would alias a mutable
                // borrow — assert disjointness before sharing the pointer.
                debug_assert_disjoint_slots(self.ready_idx.len(), |t| (self.ready_idx[t], 1));
                let base = SendPtr(self.active.as_mut_ptr());
                let idx = &self.ready_idx;
                self.engine.exec().for_each_ws(idx.len(), &mut self.tick_ws, |t, _ws| {
                    // SAFETY: `ready_idx` holds distinct in-bounds indices
                    // into `active`, and `for_each_ws` hands each `t` to
                    // exactly one participant — so every `ActiveSeq` is
                    // mutably borrowed at most once, and never while
                    // `active` itself is touched (the fan-out returns
                    // before the retirement scan below).
                    let seq = unsafe { &mut *base.0.add(idx[t]) };
                    seq.advance_decode(Exec::Inline);
                });
            }
        }
        // Retirement is rare (once per sequence) and returns ownership to
        // the caller; steady-state ticks take the empty-Vec no-alloc path.
        // sparge-lint: allow(hot-path-no-alloc)
        let mut done = Vec::new();
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].finished() {
                done.push(self.active.remove(i).into_result());
            } else {
                i += 1;
            }
        }
        done
    }

    /// The paged tick: reservation-based frame-aware admission (shedding
    /// unreferenced prefix frames under pressure, load-shedding when even
    /// that is not enough), then the same phase structure as the
    /// monolithic tick with each decode step split into a serial append
    /// half (`&mut` allocator, LRU-evicting another resident session if
    /// a CoW split outruns the free list) and a batched compute half
    /// fanned over the shared `&` allocator.
    /// Sessions the free list cannot serve this tick are skipped, not
    /// failed — they retry next tick. A steady-state decode tick stays
    /// allocation-free (`tests/alloc_regression.rs`).
    fn tick_paged(&mut self) -> Vec<SeqResult> {
        let chunk = self.chunk_rows();
        let bk = self.engine.config().bk;
        let tick = self.ticks;
        // 1) frame-aware admission, oldest first. Every active paged
        // session carries a standing *reservation* for its worst-case
        // remaining frame need (full stream length in frames, minus the
        // frames it already maps — evicted sessions reserve their full
        // re-page-in), so a newcomer is admitted only when the free list
        // covers its whole stream ON TOP of every resident session
        // finishing. Without the reservation, several same-tick
        // admissions would each pass a naive free-list check before any
        // of them claims a frame — and the pool could wedge with every
        // session starved and nothing left to retire. Unreferenced
        // shared-prefix frames are reclaimed (least-hit first) before
        // shedding load.
        loop {
            let Some(p) = self.paging.as_mut() else { break };
            let need = match p.pending.front() {
                Some((_, stream, _)) => stream.len().div_ceil(bk),
                None => break,
            };
            let outstanding: usize = self
                .active
                .iter()
                .map(|s| match &s.session {
                    SeqSession::Paged(ps) => {
                        s.stream.len().div_ceil(bk).saturating_sub(ps.frames_held())
                    }
                    SeqSession::Mono(_) => 0,
                })
                .sum();
            while p.alloc.free_frames() < need + outstanding {
                if !p.registry.shed(&mut p.alloc) {
                    break;
                }
            }
            if p.alloc.free_frames() < need + outstanding {
                p.alloc.note_load_shed();
                p.deferred += 1;
                break;
            }
            let Some((id, stream, arrived)) = p.pending.pop_front() else { break };
            let mut paged = self.engine.paged_session();
            // page table + staged sims sized to the stream's worst case
            // now, so boundary-crossing decode claims stay zero-alloc
            paged.reserve_rows(&p.alloc, stream.len());
            let session = SeqSession::Paged(paged);
            self.push_active(id, stream, arrived, session);
        }
        // 2) phase snapshot + serial prefill (same structure as the
        // monolithic tick; a frame-starved chunk defers to next tick)
        self.decode_phase.clear();
        self.decode_phase.extend(self.active.iter().map(|s| s.prefilled == s.stream.prefill));
        for i in 0..self.active.len() {
            if !self.decode_phase[i] {
                let Some(p) = self.paging.as_mut() else { break };
                self.active[i].advance_prefill_paged(chunk, &mut p.alloc, &mut p.registry, tick);
            }
        }
        // 3) decode — serial append halves first (frame claims need the
        // allocator mutably); sessions whose claim cannot be covered drop
        // out of this tick's batch untouched
        self.ready_idx.clear();
        for (i, (s, &d)) in self.active.iter().zip(&self.decode_phase).enumerate() {
            if d && s.decoded < s.stream.decode_steps() {
                self.ready_idx.push(i);
            }
        }
        let mut kept = 0;
        for r in 0..self.ready_idx.len() {
            let i = self.ready_idx[r];
            let Some(p) = self.paging.as_mut() else { break };
            // A CoW split (and the +1 it claims beyond the session's
            // admission reservation) or a re-page-in can outrun the free
            // list: reclaim unreferenced prefix frames first, then spill
            // the least-recently-advanced resident session that is NOT
            // mid-step this tick (neither the one we are advancing nor
            // one that already claimed its tail frame and is awaiting
            // its batched compute half), and only shed (skip this tick,
            // retry next) when neither frees anything. Each retry either
            // shrinks the registry or the resident set, so the loop
            // terminates.
            let mut ok = self.active[i].begin_decode_paged(&mut p.alloc, tick);
            while !ok {
                if !(p.registry.shed(&mut p.alloc)
                    || Self::evict_lru(&mut self.active, &mut p.alloc, tick, Some(i)))
                {
                    p.alloc.note_load_shed();
                    break;
                }
                ok = self.active[i].begin_decode_paged(&mut p.alloc, tick);
            }
            if ok {
                self.ready_idx[kept] = i;
                kept += 1;
            }
        }
        self.ready_idx.truncate(kept);
        // ... then the compute halves over the shared read-only allocator:
        // a lone decoder keeps the engine's executor (split-KV fans its
        // spans), a batch fans sessions across the pool exactly like the
        // monolithic tick
        match self.ready_idx.len() {
            0 => {}
            1 => {
                if let Some(p) = self.paging.as_ref() {
                    self.active[self.ready_idx[0]].finish_decode_paged(&p.alloc, self.engine.exec());
                }
            }
            _ => {
                debug_assert_disjoint_slots(self.ready_idx.len(), |t| (self.ready_idx[t], 1));
                let base = SendPtr(self.active.as_mut_ptr());
                let idx = &self.ready_idx;
                if let Some(p) = self.paging.as_ref() {
                    let alloc = &p.alloc;
                    self.engine.exec().for_each_ws(idx.len(), &mut self.tick_ws, |t, _ws| {
                        // SAFETY: `ready_idx` holds distinct in-bounds
                        // indices into `active`, and `for_each_ws` hands
                        // each `t` to exactly one participant — so every
                        // `ActiveSeq` is mutably borrowed at most once,
                        // and never while `active` itself is touched. The
                        // allocator is only *read* during the compute
                        // halves (all `&mut` work happened in the serial
                        // append phase above).
                        let seq = unsafe { &mut *base.0.add(idx[t]) };
                        seq.finish_decode_paged(alloc, Exec::Inline);
                    });
                }
            }
        }
        // 4) retirement releases the session's frame references back to
        // the pool before handing the result to the caller
        // sparge-lint: allow(hot-path-no-alloc)
        let mut done = Vec::new();
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].finished() {
                let mut seq = self.active.remove(i);
                if let (SeqSession::Paged(ps), Some(p)) = (&mut seq.session, self.paging.as_mut()) {
                    ps.release(&mut p.alloc);
                }
                done.push(seq.into_result());
            } else {
                i += 1;
            }
        }
        done
    }
}

/// Request-level baseline: one-shot prefill then every decode step, on the
/// caller's thread. Same engine, same [`SeqResult`] accounting — the
/// sequential scheduler the continuous-batching loop replaces (and, with
/// `max_batch = 1`, reproduces bitwise for f32 engines under
/// `KvSplit::Off`; split-KV keeps decode rows and stats exact but makes
/// sub-`b_q` prefill tail chunks allclose — see the module docs).
pub fn run_sequential(engine: &AttnEngine, id: u64, stream: &SeqStream) -> SeqResult {
    let arrived = Instant::now();
    let mut session = engine.session();
    let mut out = Vec::new();
    let mut stats = SkipStats::default();
    let mut compute = 0.0;
    let mut ttft = None;
    let mut tpot = Vec::new();
    if stream.prefill > 0 {
        let t0 = Instant::now();
        let r = session.prefill(
            &stream.q.rows(0, stream.prefill),
            &stream.k.rows(0, stream.prefill),
            &stream.v.rows(0, stream.prefill),
        );
        out.extend_from_slice(r.out.data());
        stats.merge(&r.stats);
        compute += t0.elapsed().as_secs_f64();
        if stream.decode_steps() == 0 {
            ttft = Some(arrived.elapsed().as_secs_f64());
        }
    }
    for t in stream.prefill..stream.len() {
        let t0 = Instant::now();
        let r = session.decode(&stream.q.rows(t, t + 1), &stream.k.rows(t, t + 1), &stream.v.rows(t, t + 1));
        out.extend_from_slice(r.out.data());
        stats.merge(&r.stats);
        let dt = t0.elapsed().as_secs_f64();
        compute += dt;
        if ttft.is_none() {
            ttft = Some(arrived.elapsed().as_secs_f64());
        } else {
            tpot.push(dt);
        }
    }
    let dv = stream.v.dim(1);
    let rows = out.len() / dv;
    SeqResult {
        id,
        out: Tensor::from_vec(&[rows, dv], out),
        stats,
        tokens: stream.decode_steps(),
        ttft: ttft.unwrap_or(0.0),
        tpot,
        latency: arrived.elapsed().as_secs_f64(),
        compute,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::{AttnConfig, AttnEngine, Execution, KvSplit};
    use crate::sparge::SpargeParams;

    fn spec(prefill: usize, decode: usize, seed: u64) -> AttnStreamSpec {
        AttnStreamSpec { prefill, decode, d: 16, seed }
    }

    fn serving_engine(bq: usize, bk: usize, pool: usize) -> AttnEngine {
        let cfg = AttnConfig { bq, bk, causal: true, scale: None, cw: 2, row_offset: 0 };
        let params = SpargeParams { tau: 0.9, theta: 0.3, lambda: None, quant: false };
        AttnEngine::builder().config(cfg).sparge(&params).execution(Execution::Pool(pool)).build()
    }

    /// Drive the manager like the scheduler does, with an admission cap.
    fn run_managed(
        engine: &AttnEngine,
        chunk: usize,
        max_active: usize,
        specs: &[AttnStreamSpec],
    ) -> Vec<SeqResult> {
        let mut mgr = SessionManager::new(engine, chunk);
        let mut queue: std::collections::VecDeque<(u64, SeqStream)> =
            specs.iter().enumerate().map(|(i, s)| (i as u64, SeqStream::synth(s))).collect();
        let mut done = Vec::new();
        while !queue.is_empty() || mgr.active() > 0 {
            while mgr.active() < max_active {
                match queue.pop_front() {
                    Some((id, stream)) => mgr.admit(id, stream, Instant::now()),
                    None => break,
                }
            }
            done.extend(mgr.tick());
        }
        done.sort_by_key(|r| r.id);
        done
    }

    #[test]
    fn managed_loop_matches_sequential_bitwise_any_batch_size() {
        // b_q-aligned chunks (bk | bq here) keep chunked prefill bitwise
        // == one-shot, so the whole continuous schedule must reproduce the
        // sequential baseline's outputs AND stats, at max_active 1 and 4.
        let engine = serving_engine(16, 8, 2);
        let specs =
            [spec(40, 8, 1), spec(16, 0, 2), spec(0, 6, 3), spec(33, 5, 4), spec(64, 12, 5)];
        let sequential: Vec<SeqResult> = specs
            .iter()
            .enumerate()
            .map(|(i, s)| run_sequential(&engine, i as u64, &SeqStream::synth(s)))
            .collect();
        for max_active in [1, 4] {
            let managed = run_managed(&engine, 16, max_active, &specs);
            assert_eq!(managed.len(), sequential.len());
            for (m, s) in managed.iter().zip(&sequential) {
                assert_eq!(m.id, s.id);
                assert_eq!(m.out, s.out, "outputs diverged (max_active {max_active}, id {})", m.id);
                assert_eq!(m.stats, s.stats, "stats diverged (max_active {max_active}, id {})", m.id);
                assert_eq!(m.tokens, s.tokens);
            }
        }
    }

    #[test]
    fn batched_tick_with_split_kv_matches_sequential_bitwise() {
        // The serving composition (pool + split-KV): the batched decode
        // phase runs steps Exec::Inline inside pool workers while the
        // sequential baseline runs them over the engine's pool (with
        // split-KV fanning the spans) — identical bits, because driver
        // routing is shape-based and both drivers are exec-invariant.
        // chunk (64) covers every prompt, so prefill is the *same* single
        // call on both sides: with split-KV on, a sub-b_q tail chunk of a
        // multi-chunk prefill routes through the split driver and would
        // only be allclose to the one-shot rows (tested at the session
        // layer in tests/splitkv_decode.rs); stats stay exact either way.
        let cfg = AttnConfig { bq: 16, bk: 8, causal: true, scale: None, cw: 2, row_offset: 0 };
        let params = SpargeParams { tau: 0.9, theta: 0.3, lambda: None, quant: false };
        let engine = AttnEngine::builder()
            .config(cfg)
            .sparge(&params)
            .execution(Execution::Pool(4))
            .kv_split(KvSplit::Blocks(2))
            .build();
        let specs = [spec(40, 8, 21), spec(16, 6, 22), spec(0, 6, 23), spec(33, 5, 24)];
        let sequential: Vec<SeqResult> = specs
            .iter()
            .enumerate()
            .map(|(i, s)| run_sequential(&engine, i as u64, &SeqStream::synth(s)))
            .collect();
        for max_active in [1, 4] {
            let managed = run_managed(&engine, 64, max_active, &specs);
            for (m, s) in managed.iter().zip(&sequential) {
                assert_eq!(m.out, s.out, "split-KV outputs diverged (batch {max_active}, id {})", m.id);
                assert_eq!(m.stats, s.stats, "split-KV stats diverged (batch {max_active}, id {})", m.id);
            }
        }
        // chunked prefill under split-KV: outputs re-tree (allclose at the
        // session layer) but the merged per-request stats remain exact
        for max_active in [1, 4] {
            let managed = run_managed(&engine, 16, max_active, &specs);
            for (m, s) in managed.iter().zip(&sequential) {
                assert_eq!(m.stats, s.stats, "chunked split-KV stats (batch {max_active}, id {})", m.id);
            }
        }
    }

    #[test]
    fn miri_batched_tick_sendptr_fanout_tiny() {
        // Miri-sized model of the batched decode arm: three decode-only
        // streams are ready on the very first tick, so every tick runs
        // the SendPtr fan-out over `active` (the raw-pointer path Miri
        // checks for aliasing violations). Results must still match the
        // sequential baseline bitwise.
        let engine = serving_engine(8, 8, 2);
        let specs = [spec(0, 3, 41), spec(0, 3, 42), spec(0, 2, 43)];
        let sequential: Vec<SeqResult> = specs
            .iter()
            .enumerate()
            .map(|(i, s)| run_sequential(&engine, i as u64, &SeqStream::synth(s)))
            .collect();
        let managed = run_managed(&engine, 8, 3, &specs);
        assert_eq!(managed.len(), sequential.len());
        for (m, s) in managed.iter().zip(&sequential) {
            assert_eq!(m.out, s.out, "batched fan-out diverged (id {})", m.id);
            assert_eq!(m.stats, s.stats);
        }
    }

    #[test]
    fn chunk_bound_caps_prefill_ticks() {
        // A 70-row prompt with chunk 16 takes ceil(70/16)=5 prefill ticks
        // (interior edges at 16/32/48/64), then decode ticks.
        let engine = serving_engine(16, 16, 1);
        let mut mgr = SessionManager::new(&engine, 20); // aligns down to 16
        mgr.admit(7, SeqStream::synth(&spec(70, 2, 9)), Instant::now());
        let mut prefill_ticks = 0;
        let mut result = None;
        for _ in 0..16 {
            let done = mgr.tick();
            if mgr.active() > 0 || !done.is_empty() {
                if done.is_empty() {
                    prefill_ticks += 1;
                } else {
                    result = done.into_iter().next();
                    break;
                }
            }
        }
        let r = result.expect("stream retired");
        assert_eq!(r.out.dim(0), 72);
        assert_eq!(r.tokens, 2);
        // 5 prefill ticks + first decode tick happen before retirement
        assert_eq!(prefill_ticks, 6);
        assert_eq!(r.tpot.len(), 1, "second decode token records one tpot sample");
    }

    #[test]
    fn ttft_and_tpot_accounting() {
        let engine = serving_engine(8, 8, 1);
        let r = run_sequential(&engine, 0, &SeqStream::synth(&spec(24, 4, 11)));
        assert!(r.ttft > 0.0);
        assert_eq!(r.tokens, 4);
        assert_eq!(r.tpot.len(), 3, "tokens after the first record tpot");
        assert!(r.tpot_mean() > 0.0);
        assert!(r.latency >= r.ttft);
        // decode-less stream still gets a TTFT (prompt completion)
        let r0 = run_sequential(&engine, 1, &SeqStream::synth(&spec(16, 0, 12)));
        assert!(r0.ttft > 0.0);
        assert_eq!(r0.tokens, 0);
        assert!(r0.tpot.is_empty());
    }
}
