//! [`SessionManager`]: N live attention sessions over **one** shared
//! [`AttnEngine`]/worker pool — the token-level execution core of the
//! continuous-batching serving loop.
//!
//! Each admitted request is a [`SeqStream`] (prompt rows + decode rows,
//! deterministic from an [`AttnStreamSpec`] seed). The scheduler drives
//! the manager in ticks; per tick every active session advances by one
//! unit of work (phases snapshotted at tick start, so a session never
//! advances twice in one tick):
//!
//! - **prefilling** sessions run one *bounded* prompt chunk
//!   ([`crate::attention::AttnSession::prefill_chunk`], at most
//!   `chunk` rows, interior edges aligned down to `b_q` so chunked
//!   execution is bitwise-faithful to one-shot prefill — see the parity
//!   notes in [`crate::attention::engine`]). Chunks run one session at a
//!   time: a chunk is many query-tile rows, which the engine already
//!   fans across its pool. Bounding the chunk caps how long any tick can
//!   run, which caps time-to-first-token for every other queued and
//!   active session;
//! - **decoding** sessions advance one single-row step each, **batched**:
//!   every decode-ready session is advanced inside one pool fan-out, so
//!   token-phase throughput scales with cores across sessions. Each step
//!   runs `Exec::Inline` inside its worker (the pipeline is
//!   bitwise-identical across exec modes, so outputs do not depend on
//!   batch composition), writes its output row **directly into the
//!   session's preallocated result buffer**
//!   ([`crate::attention::AttnSession::decode_into`]) and draws scratch
//!   from session/worker-owned workspaces. The manager's phase snapshot
//!   and fan-out index list live in tick-persistent arenas, so a
//!   warmed-up decode tick performs no heap allocation at all — not in
//!   any session's step and not in the scheduling bookkeeping around
//!   them (`tests/alloc_regression.rs` pins this). The pool hands
//!   sessions out by chunked self-scheduling with the scheduler thread
//!   participating, so one slow session (a ragged long-cache tail) no
//!   longer serializes the tick behind idle workers. A *lone* decoding
//!   session instead keeps the engine's own executor, which lets the
//!   engine's split-KV policy fan the single step's KV spans across the
//!   same pool — the two levels of decode parallelism time-share one set
//!   of workers;
//! - finished sessions retire with a [`SeqResult`]: output rows, merged
//!   [`SkipStats`], TTFT, per-output-token latencies, compute seconds.
//!
//! A manager built with [`SessionManager::new_paged`] runs the same tick
//! structure over **paged** sessions: every KV cache lives in a shared
//! [`crate::attention::paged::PageAllocator`] frame pool. Admission
//! reserves each active session's worst-case remaining frame need, so a
//! stream is admitted only when the pool can cover its whole lifetime —
//! otherwise unreferenced shared-prefix frames are reclaimed and, when
//! even that is not enough, the stream defers with a load-shed counter.
//! Identical whole-prompt prefills share their prefix frames
//! copy-on-write, each decode step splits into a serial frame-claim
//! half and a batched compute half over the read-only allocator, and a
//! decode claim that outruns the free list (a CoW split or re-page-in)
//! spills the least-recently-advanced resident session to make room —
//! never one that already ran its append half this tick, whose pending
//! compute half still needs its page table. For
//! f32/λ-off engines the paged manager's outputs and stats are
//! bitwise-identical to the monolithic one's (`tests/paged_kv.rs`).
//!
//! [`run_sequential`] is the request-level baseline (one-shot prefill,
//! then all decode steps, one request at a time): with `max_batch = 1`
//! the continuous loop reproduces its per-request outputs exactly under
//! `KvSplit::Off` (with split-KV on, a sub-`b_q` tail chunk of a
//! chunked prefill re-trees its reduction, so those prompt rows are
//! allclose instead — decode rows and all `SkipStats` stay exact), and
//! `benches/table8_serving.rs` measures what interleaving buys over it
//! (including decode tokens/s vs pool size, split-KV on and off).

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::Instant;

use crate::attention::offload::{MemTier, OffloadTier};
use crate::attention::paged::{PageAllocator, PageStats, PagedAttnSession, PrefixRegistry};
use crate::attention::pipeline::{debug_assert_disjoint_slots, SendPtr};
use crate::attention::{AttnEngine, AttnSession, Exec, SkipStats, Workspace};
use crate::tensor::Tensor;
use crate::workloads::{synthetic, SyntheticSpec};

use super::fault::{FaultKind, FaultPlan};
use super::qos::{effective_rank, retry_after_ms, OverloadDetector, OverloadState, Priority};
use super::request::{AttnStreamSpec, RequestLimits};

/// The token stream a session consumes: `prefill` prompt rows of q/k/v,
/// then one decode row per step until the rows run out.
#[derive(Clone, Debug)]
pub struct SeqStream {
    pub q: Tensor,
    pub k: Tensor,
    pub v: Tensor,
    pub prefill: usize,
}

impl SeqStream {
    /// Deterministic synthetic stream for a spec (seeded LM-like QKV of
    /// `prefill + decode` rows).
    pub fn synth(spec: &AttnStreamSpec) -> SeqStream {
        let n = spec.prefill + spec.decode;
        assert!(n > 0, "empty attention stream");
        let mut rng = crate::util::rng::Pcg::seeded(spec.seed);
        let s = synthetic::generate(&SyntheticSpec::lm_like(n, spec.d), &mut rng);
        SeqStream { q: s.q, k: s.k, v: s.v, prefill: spec.prefill }
    }

    /// Total rows (prefill + decode).
    pub fn len(&self) -> usize {
        self.q.dim(0)
    }

    pub fn is_empty(&self) -> bool {
        self.q.dim(0) == 0
    }

    /// Decode steps this stream will take.
    pub fn decode_steps(&self) -> usize {
        self.len() - self.prefill
    }
}

/// How a managed sequence terminated. Every admitted request reaches
/// **exactly one** of these (the chaos suite's core invariant): the
/// happy path completes, a deadline cancels, a panicking or poisoned
/// stream quarantines, and a stream the pool can never hold sheds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SeqOutcome {
    /// Ran to the end of its stream (or its token budget — a budget is
    /// a stop condition, not a failure).
    Completed,
    /// Cancelled at a tick boundary after its deadline passed; partial
    /// output is kept, frames are released.
    DeadlineCancelled,
    /// Contained after a worker-job panic or a non-finite (NaN/Inf)
    /// decode input: the session left the loop, its frames returned via
    /// the eviction path, and no other stream was touched.
    Quarantined,
    /// Removed without running: its frame need exceeds what the pool
    /// can ever offer, or it was still queued when the manager drained.
    Shed,
}

impl SeqOutcome {
    pub fn name(&self) -> &'static str {
        match self {
            SeqOutcome::Completed => "completed",
            SeqOutcome::DeadlineCancelled => "deadline_cancelled",
            SeqOutcome::Quarantined => "quarantined",
            SeqOutcome::Shed => "shed",
        }
    }
}

/// A retired sequence: everything the serving loop reports and records.
#[derive(Clone, Debug)]
pub struct SeqResult {
    pub id: u64,
    /// All output rows, prefill then decode ((prefill+decode) × dv).
    pub out: Tensor,
    /// Merged skip counters over every prefill chunk and decode step.
    pub stats: SkipStats,
    /// Decode rows produced (the stream's output tokens).
    pub tokens: usize,
    /// Seconds from arrival to the first output token (the first decode
    /// row, or prefill completion for decode-less streams).
    pub ttft: f64,
    /// Per-output-token latencies (seconds) for tokens after the first.
    pub tpot: Vec<f64>,
    /// Seconds from arrival to retirement.
    pub latency: f64,
    /// Summed kernel seconds across the session's chunks and steps.
    pub compute: f64,
    /// How the sequence terminated (see [`SeqOutcome`]).
    pub outcome: SeqOutcome,
    /// The request's declared serving priority (for per-priority
    /// latency reservoirs and backpressure responses).
    pub priority: Priority,
}

impl SeqResult {
    /// Mean per-output-token latency; 0 when fewer than two tokens.
    pub fn tpot_mean(&self) -> f64 {
        if self.tpot.is_empty() {
            0.0
        } else {
            self.tpot.iter().sum::<f64>() / self.tpot.len() as f64
        }
    }
}

/// The two KV-ownership models a managed sequence can run under: a
/// monolithic session (private cache tensors) or a paged session over
/// the manager's shared frame pool. A manager is homogeneous — every
/// admitted sequence uses the model the constructor picked.
enum SeqSession<'e> {
    Mono(AttnSession<'e>),
    Paged(PagedAttnSession<'e>),
}

struct ActiveSeq<'e> {
    id: u64,
    stream: SeqStream,
    session: SeqSession<'e>,
    prefilled: usize,
    decoded: usize,
    /// All output rows, preallocated at admission for the stream's full
    /// length — decode steps write their row into the tail in place.
    out: Vec<f32>,
    /// Reusable 1-row staging tensors for decode steps (the stream rows
    /// are copied in, never re-wrapped — no per-token allocation).
    qrow: Tensor,
    krow: Tensor,
    vrow: Tensor,
    stats: SkipStats,
    arrived: Instant,
    compute: f64,
    ttft: Option<f64>,
    tpot: Vec<f64>,
    /// Tick stamp of the last unit of work (the paged manager's LRU
    /// eviction key — least-recently-advanced spills first).
    last_advanced: u64,
    /// Seconds spent in this tick's serial append half of a paged decode
    /// step, folded into the step's latency sample when the parallel
    /// compute half lands.
    pending_dt: f64,
    /// Per-request deadline/budget, enforced at tick boundaries.
    limits: RequestLimits,
    /// Terminal state once decided — the session takes no further work
    /// and retires at this tick's retirement scan.
    outcome: Option<SeqOutcome>,
    /// A worker-scoped injected fault ([`FaultKind::WorkerPanic`] /
    /// [`FaultKind::Stall`]) armed for this session's next decode
    /// compute; detonated (and cleared) inside the worker job.
    injected: Option<FaultKind>,
}

impl ActiveSeq<'_> {
    /// Decode steps this sequence will actually take: the stream's
    /// length, clamped by any token budget.
    fn target_steps(&self) -> usize {
        match self.limits.token_budget {
            Some(b) => self.stream.decode_steps().min(b),
            None => self.stream.decode_steps(),
        }
    }

    fn finished(&self) -> bool {
        self.prefilled == self.stream.prefill && self.decoded == self.target_steps()
    }

    /// True when this is a paged session currently suspended to the
    /// offload tier (frames released, payload checkpointed). Suspended
    /// sessions take no tick work until the resume pass brings them back.
    fn paged_suspended(&self) -> bool {
        matches!(&self.session, SeqSession::Paged(ps) if ps.is_suspended())
    }

    /// Run one bounded prefill chunk (`chunk` rows, pre-aligned by the
    /// manager) and do the session's bookkeeping.
    fn advance_prefill(&mut self, chunk: usize) {
        let t0 = Instant::now();
        let end = (self.prefilled + chunk).min(self.stream.prefill);
        let SeqSession::Mono(session) = &mut self.session else {
            return; // paged sessions advance via advance_prefill_paged
        };
        let r = session.prefill_chunk(
            &self.stream.q.rows(self.prefilled, end),
            &self.stream.k.rows(self.prefilled, end),
            &self.stream.v.rows(self.prefilled, end),
        );
        self.out.extend_from_slice(r.out.data());
        self.stats.merge(&r.stats);
        self.prefilled = end;
        self.compute += t0.elapsed().as_secs_f64();
        if self.finished() {
            // decode-less stream: the prompt's last row is its first (and
            // only) "token"
            self.ttft = Some(self.arrived.elapsed().as_secs_f64());
        }
    }

    /// Paged twin of [`ActiveSeq::advance_prefill`]: a whole-prompt first
    /// chunk routes through the shared-prefix registry (identical prompts
    /// map the same frames and skip the compute); later chunks prefill
    /// normally. When the free list cannot cover the chunk the session is
    /// left untouched and simply retries next tick — deferral, not
    /// failure.
    fn advance_prefill_paged(
        &mut self,
        chunk: usize,
        alloc: &mut PageAllocator,
        registry: &mut PrefixRegistry,
        tick: u64,
    ) {
        let t0 = Instant::now();
        let end = (self.prefilled + chunk).min(self.stream.prefill);
        let q = self.stream.q.rows(self.prefilled, end);
        let k = self.stream.k.rows(self.prefilled, end);
        let v = self.stream.v.rows(self.prefilled, end);
        let SeqSession::Paged(session) = &mut self.session else {
            return; // mono sessions advance via advance_prefill
        };
        let whole_prompt = self.prefilled == 0 && end == self.stream.prefill;
        let r = if whole_prompt {
            session.prefill_shared(alloc, registry, &q, &k, &v)
        } else {
            session.prefill_chunk(alloc, &q, &k, &v)
        };
        let Some(r) = r else { return };
        self.out.extend_from_slice(r.out.data());
        self.stats.merge(&r.stats);
        self.prefilled = end;
        self.last_advanced = tick;
        self.compute += t0.elapsed().as_secs_f64();
        if self.finished() {
            self.ttft = Some(self.arrived.elapsed().as_secs_f64());
        }
    }

    /// Run one single-row decode step under `exec` (the engine's own
    /// executor when this session is advanced alone, `Exec::Inline` when
    /// it is advanced inside the batched cross-session fan-out — outputs
    /// are bitwise-identical either way) and do the session's
    /// bookkeeping. Allocation-free once the session is warm: the stream
    /// row is copied into reusable staging tensors and the output row is
    /// written straight into the preallocated result buffer.
    fn advance_decode(&mut self, exec: Exec<'_>) {
        if let Some(kind) = self.injected.take() {
            // inside the worker job running this session's step: a
            // WorkerPanic unwinds here (attributed to this index by the
            // pool, quarantined by the tick), a Stall sleeps here
            kind.detonate();
        }
        let t0 = Instant::now();
        let t = self.stream.prefill + self.decoded;
        self.qrow.data_mut().copy_from_slice(self.stream.q.row(t));
        self.krow.data_mut().copy_from_slice(self.stream.k.row(t));
        self.vrow.data_mut().copy_from_slice(self.stream.v.row(t));
        let dv = self.stream.v.dim(1);
        let base = self.out.len();
        self.out.resize(base + dv, 0.0);
        let SeqSession::Mono(session) = &mut self.session else {
            return; // paged sessions advance via begin/finish_decode_paged
        };
        let (stats, _mask) = session.decode_into_with_exec(
            &self.qrow,
            &self.krow,
            &self.vrow,
            &mut self.out[base..],
            exec,
        );
        self.stats.merge(&stats);
        self.decoded += 1;
        let dt = t0.elapsed().as_secs_f64();
        self.compute += dt;
        if self.ttft.is_none() {
            self.ttft = Some(self.arrived.elapsed().as_secs_f64());
        } else {
            self.tpot.push(dt);
        }
    }

    /// Serial half of a paged decode step: stage the token's rows,
    /// re-page-in if the session was evicted, and claim/CoW the tail
    /// frame (all the `&mut PageAllocator` work). `false` — session
    /// untouched — when frames are short; the session skips this tick and
    /// retries. Allocation-free once warm.
    fn begin_decode_paged(&mut self, alloc: &mut PageAllocator, tick: u64) -> bool {
        let t0 = Instant::now();
        let t = self.stream.prefill + self.decoded;
        self.qrow.data_mut().copy_from_slice(self.stream.q.row(t));
        self.krow.data_mut().copy_from_slice(self.stream.k.row(t));
        self.vrow.data_mut().copy_from_slice(self.stream.v.row(t));
        let SeqSession::Paged(session) = &mut self.session else {
            return false;
        };
        if !session.ensure_resident(alloc) {
            return false;
        }
        if !session.append_token(alloc, &self.qrow, &self.krow, &self.vrow) {
            return false;
        }
        self.last_advanced = tick;
        self.pending_dt = t0.elapsed().as_secs_f64();
        true
    }

    /// Parallel half of a paged decode step: run the compute over the
    /// shared `&PageAllocator` (read-only during compute, so the batched
    /// tick fans many sessions over one borrow) and fold this tick's
    /// append seconds into the step's latency sample.
    fn finish_decode_paged(&mut self, alloc: &PageAllocator, exec: Exec<'_>) {
        if let Some(kind) = self.injected.take() {
            // the batched compute half is the paged worker job — see
            // [`ActiveSeq::advance_decode`]
            kind.detonate();
        }
        let t0 = Instant::now();
        let dv = self.stream.v.dim(1);
        let base = self.out.len();
        self.out.resize(base + dv, 0.0);
        let SeqSession::Paged(session) = &mut self.session else {
            return;
        };
        let (stats, _predicted) = session.decode_step(alloc, &self.qrow, exec, &mut self.out[base..]);
        self.stats.merge(&stats);
        self.decoded += 1;
        let dt = self.pending_dt + t0.elapsed().as_secs_f64();
        self.pending_dt = 0.0;
        self.compute += dt;
        if self.ttft.is_none() {
            self.ttft = Some(self.arrived.elapsed().as_secs_f64());
        } else {
            self.tpot.push(dt);
        }
    }

    fn into_result(self) -> SeqResult {
        let dv = self.stream.v.dim(1);
        let rows = self.out.len() / dv;
        SeqResult {
            id: self.id,
            out: Tensor::from_vec(&[rows, dv], self.out),
            stats: self.stats,
            tokens: self.decoded,
            ttft: self.ttft.unwrap_or(0.0),
            tpot: self.tpot,
            latency: self.arrived.elapsed().as_secs_f64(),
            compute: self.compute,
            outcome: self.outcome.unwrap_or(SeqOutcome::Completed),
            priority: self.limits.priority,
        }
    }
}

/// True when every element of row `r` is finite — the poison screen a
/// decode input passes before it may reach a kernel.
fn row_finite(t: &Tensor, r: usize) -> bool {
    t.row(r).iter().all(|x| x.is_finite())
}

/// A stream enqueued on a paged manager, waiting for frame-aware
/// admission.
struct PendingSeq {
    id: u64,
    stream: SeqStream,
    arrived: Instant,
    limits: RequestLimits,
    /// Manager tick at enqueue — the aging clock: admission order is
    /// [`effective_rank`] over `ticks - queued_tick`, so low priority is
    /// served late, never starved.
    queued_tick: u64,
}

/// The paged manager's memory plane: the shared frame pool, the
/// shared-prefix registry, the aged-priority admission queue, and the
/// QoS machinery behind preemption — the offload tier checkpoints spill
/// through and the hysteresis overload detector that gates it all.
struct PagedServing {
    alloc: PageAllocator,
    registry: PrefixRegistry,
    /// Streams admitted by the caller but not yet holding frames —
    /// admission into `active` happens inside `tick`, keyed on the free
    /// list and ordered by aged priority.
    pending: VecDeque<PendingSeq>,
    /// Ticks on which admission stalled with the queue non-empty even
    /// after LRU eviction (the load-shed signal).
    deferred: u64,
    /// Where preempted sessions checkpoint their frame payloads
    /// (in-memory by default; [`SessionManager::set_offload_tier`]
    /// installs e.g. a checksummed [`crate::attention::DiskTier`]).
    tier: Box<dyn OffloadTier + Send>,
    /// Hysteresis overload detector; its posture orders each tick
    /// (prefill-first vs decode-first) and gates preemption/shedding.
    detector: OverloadDetector,
    /// Wall-clock seconds the previous tick took — the tick-duration
    /// signal fed to the detector at the top of the next tick.
    last_tick_secs: f64,
    /// Sessions preempted to the offload tier (lifetime counter).
    preempted: u64,
    /// Suspended sessions brought back from the tier (lifetime counter).
    resumed: u64,
    /// Times a request was shed while a strictly lower-priority resident
    /// held frames. The preemption order makes this structurally
    /// impossible; the chaos suite asserts it stays 0 under every seed.
    inversions: u64,
}

/// N live [`AttnSession`]s over one shared engine; see the module docs.
pub struct SessionManager<'e> {
    engine: &'e AttnEngine,
    /// Max prompt rows per prefill tick, before `b_q` alignment.
    chunk: usize,
    active: Vec<ActiveSeq<'e>>,
    /// Tick-persistent phase snapshot (parallel to `active`), rebuilt in
    /// place each tick so whole warmed decode ticks allocate nothing.
    decode_phase: Vec<bool>,
    /// Tick-persistent indices (into `active`) of the decode-ready
    /// sessions, fanned out by the batched decode phase.
    ready_idx: Vec<usize>,
    /// The scheduler thread's own workspace for participating in the
    /// batched decode fan-out (each session's step draws on the session's
    /// arena; this one just satisfies the seam).
    tick_ws: Workspace,
    /// `Some` for paged managers (see [`SessionManager::new_paged`]);
    /// `None` managers run monolithic sessions exactly as before.
    paging: Option<PagedServing>,
    /// Tick counter — the LRU stamp source for paged eviction.
    ticks: u64,
    /// Injection schedule, if one is installed. `None` (the default and
    /// every production path) costs one branch per tick; the recovery
    /// machinery below is armed either way.
    fault: Option<FaultPlan>,
    /// Fault events applied so far (exhaustion counted per denied
    /// claim) — exported through metrics as `injected_faults`.
    faults_injected: u64,
}

impl<'e> SessionManager<'e> {
    /// `chunk` bounds the prompt rows a session prefills per tick; interior
    /// chunk edges are aligned down to the engine's `b_q` (at least one
    /// query block per tick) so chunked prefill stays bitwise-faithful to
    /// one-shot prefill.
    pub fn new(engine: &'e AttnEngine, chunk: usize) -> SessionManager<'e> {
        assert!(chunk > 0, "prefill chunk must be positive");
        SessionManager {
            engine,
            chunk,
            active: Vec::new(),
            decode_phase: Vec::new(),
            ready_idx: Vec::new(),
            tick_ws: Workspace::default(),
            paging: None,
            ticks: 0,
            fault: None,
            faults_injected: 0,
        }
    }

    /// A manager whose sessions page their KV caches out of `alloc`
    /// instead of owning private tensors. Admission becomes frame-aware:
    /// [`SessionManager::admit`] only enqueues, and each tick admits
    /// pending streams while the free list covers their worst-case frame
    /// need plus every active session's outstanding reservation
    /// (reclaiming unreferenced shared-prefix frames under pressure, and
    /// counting a load-shed instead of failing when even that is not
    /// enough). Whole-prompt prefills route through a shared-prefix
    /// registry, so identical prompts map the same frames and skip their
    /// prefill compute; decode claims that still outrun the pool evict
    /// the least-recently-advanced resident session.
    pub fn new_paged(engine: &'e AttnEngine, chunk: usize, alloc: PageAllocator) -> SessionManager<'e> {
        let mut m = SessionManager::new(engine, chunk);
        m.paging = Some(PagedServing {
            alloc,
            registry: PrefixRegistry::new(),
            pending: VecDeque::new(),
            deferred: 0,
            tier: Box::new(MemTier::new()),
            detector: OverloadDetector::new(),
            last_tick_secs: 0.0,
            preempted: 0,
            resumed: 0,
            inversions: 0,
        });
        m
    }

    /// Install the offload tier preempted sessions checkpoint through
    /// (replacing the in-memory default). Call before serving: a
    /// checkpoint stored in the old tier is not visible to the new one.
    /// No-op on monolithic managers.
    pub fn set_offload_tier(&mut self, tier: Box<dyn OffloadTier + Send>) {
        if let Some(p) = self.paging.as_mut() {
            p.tier = tier;
        }
    }

    /// Overload posture the next tick will run under (`Normal` for
    /// monolithic managers, which have no frame pressure to detect).
    pub fn overload_state(&self) -> OverloadState {
        self.paging.as_ref().map_or(OverloadState::Normal, |p| p.detector.state())
    }

    /// QoS lifetime counters: (preempted, resumed, entries into
    /// `Preempting`, entries into `Shedding`, priority inversions).
    /// All zero for monolithic managers.
    pub fn qos_counters(&self) -> (u64, u64, u64, u64, u64) {
        self.paging.as_ref().map_or((0, 0, 0, 0, 0), |p| {
            let (to_p, to_s) = p.detector.transitions();
            (p.preempted, p.resumed, to_p, to_s, p.inversions)
        })
    }

    /// Structured backpressure hint for a rejected/shed request right
    /// now: retry-after milliseconds scaled by the current posture and
    /// pending depth (see [`retry_after_ms`]).
    pub fn retry_hint_ms(&self) -> u64 {
        retry_after_ms(self.overload_state(), self.pending())
    }

    /// Live session count.
    pub fn active(&self) -> usize {
        self.active.len()
    }

    /// Sessions still consuming their prompt.
    pub fn prefilling(&self) -> usize {
        self.active.iter().filter(|s| s.prefilled < s.stream.prefill).count()
    }

    /// Sessions past their prompt, producing decode tokens.
    pub fn decoding(&self) -> usize {
        self.active.len() - self.prefilling()
    }

    /// Rows per prefill tick: `chunk` aligned down to a `b_q` multiple.
    fn chunk_rows(&self) -> usize {
        let bq = self.engine.config().bq;
        (self.chunk / bq * bq).max(bq)
    }

    /// Open a session for a stream. The caller enforces its own admission
    /// cap (the scheduler admits up to `BatchPolicy::max_batch`). Paged
    /// managers only *enqueue* here — the frame-aware admission into the
    /// active set happens inside [`SessionManager::tick`].
    pub fn admit(&mut self, id: u64, stream: SeqStream, arrived: Instant) {
        self.admit_with(id, stream, arrived, RequestLimits::default());
    }

    /// [`SessionManager::admit`] with per-request [`RequestLimits`]
    /// (deadline / token budget), enforced at tick boundaries.
    pub fn admit_with(&mut self, id: u64, stream: SeqStream, arrived: Instant, limits: RequestLimits) {
        assert!(!stream.is_empty(), "empty attention stream");
        if let Some(p) = self.paging.as_mut() {
            let queued_tick = self.ticks;
            p.pending.push_back(PendingSeq { id, stream, arrived, limits, queued_tick });
            return;
        }
        let session = SeqSession::Mono(self.engine.session());
        self.push_active(id, stream, arrived, limits, session);
    }

    /// Install (or clear) a deterministic fault-injection schedule. The
    /// plan only *injects*; recovery — quarantine, deadlines, drain —
    /// is always armed. With `None` (the default) the tick pays one
    /// branch and the zero-alloc contracts are untouched.
    pub fn set_fault_plan(&mut self, plan: Option<FaultPlan>) {
        self.fault = plan;
    }

    /// Fault events applied so far (exhaustion counted per denied claim).
    pub fn faults_injected(&self) -> u64 {
        self.faults_injected
    }

    /// Streams enqueued on a paged manager but not yet holding frames.
    pub fn pending(&self) -> usize {
        self.paging.as_ref().map_or(0, |p| p.pending.len())
    }

    /// Memory-plane counter snapshot of a paged manager (`None` for
    /// monolithic managers).
    pub fn page_stats(&self) -> Option<PageStats> {
        self.paging.as_ref().map(|p| p.alloc.stats())
    }

    /// Registered shared prompt prefixes (paged managers).
    pub fn prefix_entries(&self) -> usize {
        self.paging.as_ref().map_or(0, |p| p.registry.len())
    }

    /// Drop the shared-prefix registry's frame references (frames still
    /// mapped by live sessions stay resident through those sessions).
    pub fn release_prefixes(&mut self) {
        if let Some(p) = self.paging.as_mut() {
            p.registry.clear(&mut p.alloc);
        }
    }

    /// Frame-leak check ([`PageAllocator::assert_all_free`]) on the paged
    /// pool; fails loudly with the offending frame ids. No-op on
    /// monolithic managers. Callers with live prefix-registry entries
    /// must [`Self::release_prefixes`] first.
    pub fn assert_frames_all_free(&self) {
        if let Some(p) = self.paging.as_ref() {
            p.alloc.assert_all_free();
        }
    }

    fn push_active(
        &mut self,
        id: u64,
        stream: SeqStream,
        arrived: Instant,
        limits: RequestLimits,
        session: SeqSession<'e>,
    ) {
        let d = stream.q.dim(1);
        let dv = stream.v.dim(1);
        let total = stream.len() * dv;
        let steps = stream.decode_steps();
        self.active.push(ActiveSeq {
            id,
            session,
            qrow: Tensor::zeros(&[1, d]),
            krow: Tensor::zeros(&[1, d]),
            vrow: Tensor::zeros(&[1, dv]),
            stream,
            prefilled: 0,
            decoded: 0,
            // the stream's full output, reserved up front: decode steps
            // extend into capacity, never reallocating mid-flight
            out: Vec::with_capacity(total),
            stats: SkipStats::default(),
            arrived,
            compute: 0.0,
            ttft: None,
            // one sample per output token after the first: reserved up
            // front so warmed ticks never grow it mid-flight
            tpot: Vec::with_capacity(steps.saturating_sub(1)),
            last_advanced: self.ticks,
            pending_dt: 0.0,
            limits,
            outcome: None,
            injected: None,
        });
    }

    /// A zero-output result for a request that terminates without ever
    /// running (shed from the pending queue, or expired before
    /// admission).
    fn terminal_result(
        id: u64,
        stream: &SeqStream,
        arrived: Instant,
        priority: Priority,
        outcome: SeqOutcome,
    ) -> SeqResult {
        let dv = stream.v.dim(1);
        SeqResult {
            id,
            out: Tensor::from_vec(&[0, dv], Vec::new()),
            stats: SkipStats::default(),
            tokens: 0,
            ttft: 0.0,
            tpot: Vec::new(),
            latency: arrived.elapsed().as_secs_f64(),
            compute: 0.0,
            outcome,
            priority,
        }
    }

    /// Tick-boundary fault/limit pass, run before any session advances:
    /// apply this tick's injected faults (poison lands in the stream
    /// rows, worker-scoped faults arm on their session, exhaustion
    /// lands on the allocator), then enforce deadlines and screen the
    /// next decode inputs for non-finite values. Recovery is always
    /// armed; with no plan installed this is one branch plus the
    /// deadline/poison screens, none of which allocate.
    fn apply_tick_boundary(&mut self) {
        let tick = self.ticks;
        if let Some(plan) = &self.fault {
            let denials = plan.exhaustion_at(tick);
            if denials > 0 {
                if let Some(p) = self.paging.as_mut() {
                    p.alloc.inject_exhaustion(denials);
                    self.faults_injected += denials;
                }
            }
            for seq in &mut self.active {
                let Some(kind) = plan.fault_for(tick, seq.id) else { continue };
                self.faults_injected += 1;
                match kind {
                    FaultKind::PoisonInput => {
                        // poison the next decode input row; the screen
                        // below catches it before it reaches a kernel
                        if seq.decoded < seq.target_steps() && seq.stream.decode_steps() > 0 {
                            let t = seq.stream.prefill + seq.decoded;
                            FaultKind::poison_row(seq.stream.q.row_mut(t));
                        }
                    }
                    FaultKind::WorkerPanic | FaultKind::Stall { .. } => {
                        seq.injected = Some(kind);
                    }
                    FaultKind::FrameExhaustion { .. } => {} // allocator-scoped, handled above
                }
            }
        }
        for seq in &mut self.active {
            if seq.outcome.is_some() {
                continue;
            }
            if let Some(ms) = seq.limits.deadline_ms {
                if seq.arrived.elapsed().as_millis() as u64 > ms {
                    seq.outcome = Some(SeqOutcome::DeadlineCancelled);
                    continue;
                }
            }
            // poison screen: the row a decode step would stage this tick
            if seq.prefilled == seq.stream.prefill && seq.decoded < seq.target_steps() {
                let t = seq.stream.prefill + seq.decoded;
                if !row_finite(&seq.stream.q, t)
                    || !row_finite(&seq.stream.k, t)
                    || !row_finite(&seq.stream.v, t)
                {
                    seq.outcome = Some(SeqOutcome::Quarantined);
                }
            }
        }
    }

    /// Spill the least-recently-advanced resident decode-phase session
    /// other than `exclude` (its frames recycle; it transparently
    /// re-pages-in on its next decode). Sessions stamped `tick` are never
    /// candidates: a stamp equal to the current tick means the session
    /// already ran its serial append half this tick and its batched
    /// compute half is still pending — spilling it in between would hand
    /// `decode_step` an empty page table. `false` when no session is
    /// evictable.
    fn evict_lru(
        active: &mut [ActiveSeq<'_>],
        alloc: &mut PageAllocator,
        tick: u64,
        exclude: Option<usize>,
    ) -> bool {
        let mut best: Option<usize> = None;
        for (i, s) in active.iter().enumerate() {
            if Some(i) == exclude {
                continue; // never spill the session we are advancing
            }
            if s.last_advanced == tick {
                continue; // mid-step this tick: append done, compute pending
            }
            if s.prefilled < s.stream.prefill {
                continue; // mid-prompt sessions keep their frames
            }
            let resident = match &s.session {
                SeqSession::Paged(p) => !p.is_evicted() && p.frames_held() > 0,
                SeqSession::Mono(_) => false,
            };
            if !resident {
                continue;
            }
            if best.map_or(true, |b| s.last_advanced < active[b].last_advanced) {
                best = Some(i);
            }
        }
        let Some(i) = best else { return false };
        if let SeqSession::Paged(p) = &mut active[i].session {
            p.evict(alloc);
        }
        true
    }

    /// One scheduling tick: every active session advances one unit —
    /// prefilling sessions by one bounded chunk (serially: each chunk
    /// already fans its query-tile rows across the pool), decode-ready
    /// sessions by one token **in one batched map over the engine's
    /// workers** — and finished sessions retire (in admission order).
    /// Phases are snapshotted at tick start, so a session that finishes
    /// its prompt this tick starts decoding next tick, exactly like the
    /// old serial loop.
    pub fn tick(&mut self) -> Vec<SeqResult> {
        self.ticks += 1;
        self.apply_tick_boundary();
        if self.paging.is_some() {
            return self.tick_paged();
        }
        let chunk = self.chunk_rows();
        // phase snapshot: one unit of work per session per tick (rebuilt
        // in the tick-persistent arenas — no per-tick slot vector)
        self.decode_phase.clear();
        self.decode_phase.extend(self.active.iter().map(|s| s.prefilled == s.stream.prefill));
        for (seq, &decoding) in self.active.iter_mut().zip(&self.decode_phase) {
            if !decoding && seq.outcome.is_none() {
                seq.advance_prefill(chunk);
            }
        }
        self.ready_idx.clear();
        for (i, (s, &d)) in self.active.iter().zip(&self.decode_phase).enumerate() {
            if d && s.outcome.is_none() && s.decoded < s.target_steps() {
                self.ready_idx.push(i);
            }
        }
        match self.ready_idx.len() {
            0 => {}
            // a lone decoder keeps the engine's executor: the engine's
            // split-KV policy fans the step's KV spans across the pool.
            // A panic (injected or real) is contained here — the step
            // either ran the whole engine fan-out or unwound before any
            // other session was touched — and quarantines the session.
            1 => {
                let i = self.ready_idx[0];
                let exec = self.engine.exec();
                let seq = &mut self.active[i];
                if catch_unwind(AssertUnwindSafe(|| seq.advance_decode(exec))).is_err() {
                    seq.outcome = Some(SeqOutcome::Quarantined);
                }
            }
            // cross-session batch: one chunk-self-scheduled fan-out over
            // (session, step) pairs — the scheduler thread participates
            // with the manager's persistent workspace; each participant
            // runs exactly one session's step inline. Panicking steps
            // are *attributed* (not re-raised): each failed index
            // quarantines exactly its own session.
            _ => {
                // Each fan-out item owns exactly one `ActiveSeq` slot;
                // a duplicate index in `ready_idx` would alias a mutable
                // borrow — assert disjointness before sharing the pointer.
                debug_assert_disjoint_slots(self.ready_idx.len(), |t| (self.ready_idx[t], 1));
                let base = SendPtr(self.active.as_mut_ptr());
                let idx = &self.ready_idx;
                let bad = self.engine.exec().try_for_each_ws(idx.len(), &mut self.tick_ws, |t, _ws| {
                    // SAFETY: `ready_idx` holds distinct in-bounds indices
                    // into `active`, and `try_for_each_ws` hands each `t`
                    // to exactly one participant — so every `ActiveSeq` is
                    // mutably borrowed at most once, and never while
                    // `active` itself is touched (the fan-out returns
                    // before the retirement scan below). A panicking
                    // index unwinds out of its closure only — the borrow
                    // ends with the unwind, and the index is reported,
                    // never retried.
                    let seq = unsafe { &mut *base.0.add(idx[t]) };
                    seq.advance_decode(Exec::Inline);
                });
                for t in bad {
                    let slot = self.ready_idx[t];
                    self.active[slot].outcome = Some(SeqOutcome::Quarantined);
                }
            }
        }
        // Retirement is rare (once per sequence) and returns ownership to
        // the caller; steady-state ticks take the empty-Vec no-alloc path.
        // sparge-lint: allow(hot-path-no-alloc)
        let mut done = Vec::new();
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].finished() || self.active[i].outcome.is_some() {
                done.push(self.active.remove(i).into_result());
            } else {
                i += 1;
            }
        }
        done
    }

    /// The paged tick: overload posture first (the hysteresis detector
    /// over free-frame watermarks, the previous tick's duration, and
    /// queue depth), then a resume pass for preempted sessions, then
    /// reservation-based frame-aware admission over the *aged-priority*
    /// queue — preempting the lowest-priority resident to the offload
    /// tier and, under sustained deep pressure, shedding the lowest-
    /// priority pending request. The session phases keep the monolithic
    /// tick's structure, ordered prefill-first on healthy ticks (the
    /// long-standing order, bit-for-bit) and decode-first under
    /// pressure; each decode step splits into a serial append half
    /// (`&mut` allocator, LRU-evicting another resident session if a
    /// CoW split outruns the free list) and a batched compute half
    /// fanned over the shared `&` allocator.
    /// Sessions the free list cannot serve this tick are skipped, not
    /// failed — they retry next tick. A steady-state decode tick stays
    /// allocation-free (`tests/alloc_regression.rs`).
    fn tick_paged(&mut self) -> Vec<SeqResult> {
        let chunk = self.chunk_rows();
        let bk = self.engine.config().bk;
        let tick = self.ticks;
        let t0 = Instant::now();
        // Terminal results can arise before any session runs (expired or
        // unservable pending streams) — collect them with retirement.
        // sparge-lint: allow(hot-path-no-alloc)
        let mut done = Vec::new();
        // 0) posture for THIS tick: every input is a value the tick
        // already has, so the observe call is free; the result orders
        // the passes below and gates preemption/shedding.
        let state = match self.paging.as_mut() {
            Some(p) => {
                let (free, total) = (p.alloc.free_frames(), p.alloc.capacity());
                let (pending, last) = (p.pending.len(), p.last_tick_secs);
                p.detector.observe(free, total, pending, last)
            }
            None => return done,
        };
        // 1a) resume pass: preempted sessions re-page-in before anything
        // else claims frames, highest declared rank first
        self.resume_suspended(bk, tick);
        // 1b) frame-aware admission over the aged-priority queue
        self.admit_pending(bk, tick, state, &mut done);
        // 2) phase snapshot (one unit of work per session per tick),
        // then the two passes ordered by posture: healthy ticks feed new
        // streams first (prefill-first — the long-standing order, kept
        // bit-for-bit); pressured ticks finish in-flight tokens first
        // (decode-first), so capacity freed by preemption drains work
        // already holding frames before opening new fronts.
        self.decode_phase.clear();
        self.decode_phase.extend(self.active.iter().map(|s| s.prefilled == s.stream.prefill));
        if state == OverloadState::Normal {
            self.prefill_pass(chunk, tick);
            self.decode_pass(tick);
        } else {
            self.decode_pass(tick);
            self.prefill_pass(chunk, tick);
        }
        // 3) retirement releases the session's frame references back to
        // the pool — and drops any checkpoint it left in the offload
        // tier — before handing the result to the caller: terminal
        // outcomes (quarantine, deadline) take the same release path an
        // eviction uses, so neither a frame nor an offloaded checkpoint
        // outlives its stream
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].finished() || self.active[i].outcome.is_some() {
                let mut seq = self.active.remove(i);
                if let (SeqSession::Paged(ps), Some(p)) = (&mut seq.session, self.paging.as_mut()) {
                    ps.release(&mut p.alloc);
                    p.tier.discard(seq.id);
                }
                done.push(seq.into_result());
            } else {
                i += 1;
            }
        }
        if let Some(p) = self.paging.as_mut() {
            p.last_tick_secs = t0.elapsed().as_secs_f64();
        }
        done
    }

    /// Resume pass: bring suspended (preempted) sessions back from the
    /// offload tier while free frames cover their full re-page-in,
    /// highest *declared* rank first. Strict rank order: when the
    /// best-ranked suspended session does not fit, nothing below it
    /// resumes either — frames free up as residents retire, and jumping
    /// a smaller low-rank session ahead would be a priority inversion.
    /// A tier load failure (lost or corrupt checkpoint) quarantines the
    /// session: the payload is unrecoverable, never a panic.
    fn resume_suspended(&mut self, bk: usize, tick: u64) {
        loop {
            let mut best: Option<usize> = None;
            for (i, s) in self.active.iter().enumerate() {
                if s.outcome.is_some() || !s.paged_suspended() {
                    continue;
                }
                if best.map_or(true, |b| {
                    s.limits.priority.rank() > self.active[b].limits.priority.rank()
                }) {
                    best = Some(i);
                }
            }
            let Some(i) = best else { return };
            let Some(p) = self.paging.as_mut() else { return };
            let id = self.active[i].id;
            let SeqSession::Paged(ps) = &mut self.active[i].session else { return };
            if p.alloc.free_frames() < PagedAttnSession::frames_for_rows(ps.len(), bk) {
                return;
            }
            match ps.resume(&mut p.alloc, id, p.tier.as_mut()) {
                Ok(_) => {
                    p.resumed += 1;
                    // freshly resumed: stamped so it is not a preemption
                    // candidate again this same tick
                    self.active[i].last_advanced = tick;
                }
                Err(_) => {
                    self.active[i].outcome = Some(SeqOutcome::Quarantined);
                }
            }
        }
    }

    /// Outstanding worst-case frame reservations over the active set:
    /// every paged session's full stream length in frames, minus what it
    /// already maps (evicted sessions reserve their full re-page-in).
    /// Suspended sessions are excluded — their frames are exactly the
    /// capacity preemption freed, and they re-enter the sum on resume.
    fn outstanding_frames(&self, bk: usize) -> usize {
        self.active
            .iter()
            .map(|s| match &s.session {
                SeqSession::Paged(ps) if !ps.is_suspended() => {
                    s.stream.len().div_ceil(bk).saturating_sub(ps.frames_held())
                }
                _ => 0,
            })
            .sum()
    }

    /// Frame-aware admission over the aged-priority queue. Every active
    /// paged session carries a standing *reservation* for its worst-case
    /// remaining frame need, so a newcomer is admitted only when the
    /// free list covers its whole stream ON TOP of every resident
    /// session finishing — without it, several same-tick admissions
    /// would each pass a naive free-list check before any of them claims
    /// a frame, and the pool could wedge with every session starved.
    /// While the candidate is short, unreferenced shared-prefix frames
    /// are reclaimed (least-hit first); under a pressured posture the
    /// lowest resident strictly below the candidate's declared rank is
    /// preempted to the offload tier; and a `Shedding` posture drops the
    /// lowest-priority pending request (at most one per tick, never past
    /// a strictly lower-priority resident still holding frames — the
    /// no-priority-inversion invariant). Anything else defers: a
    /// load-shed count, not a failure.
    fn admit_pending(
        &mut self,
        bk: usize,
        tick: u64,
        state: OverloadState,
        done: &mut Vec<SeqResult>,
    ) {
        // Screen the whole queue first: a queued stream can terminate
        // without ever running — its deadline passed while waiting, or
        // its frame need exceeds what the pool can EVER offer. Priority
        // admission means the queue is no longer FIFO, so a doomed entry
        // cannot be left to be noticed "when it reaches the front".
        if let Some(p) = self.paging.as_mut() {
            let mut qi = 0;
            while qi < p.pending.len() {
                let e = &p.pending[qi];
                let expired = e
                    .limits
                    .deadline_ms
                    .is_some_and(|ms| e.arrived.elapsed().as_millis() as u64 > ms);
                let unservable = e.stream.len().div_ceil(bk) > p.alloc.capacity();
                if !(expired || unservable) {
                    qi += 1;
                    continue;
                }
                let outcome = if expired {
                    SeqOutcome::DeadlineCancelled
                } else {
                    p.alloc.note_load_shed();
                    SeqOutcome::Shed
                };
                if let Some(e) = p.pending.remove(qi) {
                    done.push(Self::terminal_result(
                        e.id,
                        &e.stream,
                        e.arrived,
                        e.limits.priority,
                        outcome,
                    ));
                }
            }
        }
        let mut shed_this_tick = false;
        loop {
            // candidate: highest effective (aged) rank; FIFO among
            // equals — all-default-priority queues admit oldest-first,
            // exactly the pre-QoS order
            let Some(p) = self.paging.as_ref() else { return };
            let mut best: Option<(usize, u64)> = None;
            for (i, e) in p.pending.iter().enumerate() {
                let er = effective_rank(e.limits.priority, tick.saturating_sub(e.queued_tick));
                if best.map_or(true, |(_, b)| er > b) {
                    best = Some((i, er));
                }
            }
            let Some((ci, _)) = best else { return };
            let need = p.pending[ci].stream.len().div_ceil(bk);
            let crank = p.pending[ci].limits.priority.rank();
            // cover the candidate: reclaim unreferenced prefix frames,
            // then (under pressure) preempt strictly-lower residents.
            // Each retry shrinks the registry or the resident frame
            // holders, so the loop terminates.
            let covered = loop {
                let outstanding = self.outstanding_frames(bk);
                let Some(p) = self.paging.as_mut() else { return };
                if p.alloc.free_frames() >= need + outstanding {
                    break true;
                }
                if p.registry.shed(&mut p.alloc) {
                    continue;
                }
                if state != OverloadState::Normal {
                    let PagedServing { alloc, tier, preempted, .. } = p;
                    if Self::preempt_below(&mut self.active, alloc, tier.as_mut(), crank, tick) {
                        *preempted += 1;
                        continue;
                    }
                }
                break false;
            };
            if covered {
                let Some(p) = self.paging.as_mut() else { return };
                let Some(e) = p.pending.remove(ci) else { return };
                let mut paged = self.engine.paged_session();
                // page table + staged sims sized to the stream's worst
                // case now, so boundary-crossing decode claims stay
                // zero-alloc
                paged.reserve_rows(&p.alloc, e.stream.len());
                self.push_active(e.id, e.stream, e.arrived, e.limits, SeqSession::Paged(paged));
                continue;
            }
            // Shedding posture: drop the lowest-effective-rank pending
            // request (at most one per tick) — unless a strictly
            // lower-priority resident still holds frames, in which case
            // shedding would invert priority: defer instead and let the
            // preemption path free those frames on a later tick.
            if state == OverloadState::Shedding && !shed_this_tick {
                let Some(p) = self.paging.as_ref() else { return };
                let mut vic: Option<(usize, u64)> = None;
                for (i, e) in p.pending.iter().enumerate() {
                    let er = effective_rank(e.limits.priority, tick.saturating_sub(e.queued_tick));
                    if vic.map_or(true, |(_, b)| er < b) {
                        vic = Some((i, er));
                    }
                }
                if let Some((vi, _)) = vic {
                    let vrank = p.pending[vi].limits.priority.rank();
                    if !Self::holds_frames_below(&self.active, vrank) {
                        let Some(p) = self.paging.as_mut() else { return };
                        shed_this_tick = true;
                        p.alloc.note_load_shed();
                        if let Some(e) = p.pending.remove(vi) {
                            done.push(Self::terminal_result(
                                e.id,
                                &e.stream,
                                e.arrived,
                                e.limits.priority,
                                SeqOutcome::Shed,
                            ));
                        }
                        continue;
                    }
                }
            }
            // defer: count one load-shed and stop admitting this tick
            let Some(p) = self.paging.as_mut() else { return };
            p.alloc.note_load_shed();
            p.deferred += 1;
            return;
        }
    }

    /// Preempt (suspend to the offload tier) the resident paged session
    /// with the lowest declared rank strictly below `rank`, least-
    /// recently-advanced within a rank. Never one mid-step this tick
    /// (its pending compute half still needs its page table), never one
    /// already suspended or holding no frames. Unlike
    /// [`SessionManager::evict_lru`], mid-prompt sessions ARE eligible —
    /// excluding them would let a low-priority prefill block a
    /// high-priority admission, the exact inversion preemption exists to
    /// prevent (a preempted prefill transparently re-pages-in on its
    /// next chunk). `rank` is the admission candidate's *declared* rank:
    /// aging affects admission order only, so an aged `Low` request
    /// never evicts anyone, and equal-priority traffic never preempts
    /// itself. True when a session's frames were actually freed.
    fn preempt_below(
        active: &mut [ActiveSeq<'_>],
        alloc: &mut PageAllocator,
        tier: &mut dyn OffloadTier,
        rank: u8,
        tick: u64,
    ) -> bool {
        let mut best: Option<usize> = None;
        for (i, s) in active.iter().enumerate() {
            if s.outcome.is_some() || s.limits.priority.rank() >= rank || s.last_advanced == tick {
                continue;
            }
            let resident = matches!(&s.session, SeqSession::Paged(ps) if ps.frames_held() > 0);
            if !resident {
                continue;
            }
            if best.map_or(true, |b| {
                (s.limits.priority.rank(), s.last_advanced)
                    < (active[b].limits.priority.rank(), active[b].last_advanced)
            }) {
                best = Some(i);
            }
        }
        let Some(i) = best else { return false };
        let id = active[i].id;
        let SeqSession::Paged(ps) = &mut active[i].session else { return false };
        let held = ps.frames_held();
        // a tier-store failure still freed the frames (the checkpoint
        // stays session-local, a plain eviction) — the admission goal is
        // met either way, so the return value only tracks the frames
        ps.suspend(alloc, id, tier);
        held > 0 && ps.frames_held() == 0
    }

    /// True when any live resident with declared rank strictly below
    /// `rank` still holds frames — the no-priority-inversion guard
    /// consulted before any shed.
    fn holds_frames_below(active: &[ActiveSeq<'_>], rank: u8) -> bool {
        active.iter().any(|s| {
            s.outcome.is_none()
                && s.limits.priority.rank() < rank
                && matches!(&s.session, SeqSession::Paged(ps) if ps.frames_held() > 0)
        })
    }

    /// The prefill pass of a paged tick: one bounded chunk per
    /// mid-prompt session, serially (a chunk already fans its query-tile
    /// rows across the pool). A frame-starved or suspended session is
    /// left untouched and retries a later tick — deferral, not failure.
    fn prefill_pass(&mut self, chunk: usize, tick: u64) {
        for i in 0..self.active.len() {
            if !self.decode_phase[i] && self.active[i].outcome.is_none() {
                let Some(p) = self.paging.as_mut() else { break };
                self.active[i].advance_prefill_paged(chunk, &mut p.alloc, &mut p.registry, tick);
            }
        }
    }

    /// The decode pass of a paged tick — serial append halves first
    /// (frame claims need the allocator mutably); sessions whose claim
    /// cannot be covered drop out of this tick's batch untouched, and
    /// suspended sessions wait for the resume pass instead of churning
    /// the eviction path.
    fn decode_pass(&mut self, tick: u64) {
        self.ready_idx.clear();
        for (i, (s, &d)) in self.active.iter().zip(&self.decode_phase).enumerate() {
            if d && s.outcome.is_none() && s.decoded < s.target_steps() && !s.paged_suspended() {
                self.ready_idx.push(i);
            }
        }
        let mut kept = 0;
        for r in 0..self.ready_idx.len() {
            let i = self.ready_idx[r];
            let Some(p) = self.paging.as_mut() else { break };
            // A CoW split (and the +1 it claims beyond the session's
            // admission reservation) or a re-page-in can outrun the free
            // list: reclaim unreferenced prefix frames first, then spill
            // the least-recently-advanced resident session that is NOT
            // mid-step this tick (neither the one we are advancing nor
            // one that already claimed its tail frame and is awaiting
            // its batched compute half), and only shed (skip this tick,
            // retry next) when neither frees anything. Each retry either
            // shrinks the registry or the resident set, so the loop
            // terminates.
            let mut ok = self.active[i].begin_decode_paged(&mut p.alloc, tick);
            while !ok {
                if !(p.registry.shed(&mut p.alloc)
                    || Self::evict_lru(&mut self.active, &mut p.alloc, tick, Some(i)))
                {
                    p.alloc.note_load_shed();
                    break;
                }
                ok = self.active[i].begin_decode_paged(&mut p.alloc, tick);
            }
            if ok {
                self.ready_idx[kept] = i;
                kept += 1;
            }
        }
        self.ready_idx.truncate(kept);
        // ... then the compute halves over the shared read-only allocator:
        // a lone decoder keeps the engine's executor (split-KV fans its
        // spans), a batch fans sessions across the pool exactly like the
        // monolithic tick
        match self.ready_idx.len() {
            0 => {}
            1 => {
                if let Some(p) = self.paging.as_ref() {
                    let i = self.ready_idx[0];
                    let alloc = &p.alloc;
                    let exec = self.engine.exec();
                    let seq = &mut self.active[i];
                    // a panic here (injected or real) is contained to
                    // this session — see the monolithic tick's lone arm
                    if catch_unwind(AssertUnwindSafe(|| seq.finish_decode_paged(alloc, exec)))
                        .is_err()
                    {
                        seq.outcome = Some(SeqOutcome::Quarantined);
                    }
                }
            }
            _ => {
                debug_assert_disjoint_slots(self.ready_idx.len(), |t| (self.ready_idx[t], 1));
                let base = SendPtr(self.active.as_mut_ptr());
                let idx = &self.ready_idx;
                if let Some(p) = self.paging.as_ref() {
                    let alloc = &p.alloc;
                    let bad =
                        self.engine.exec().try_for_each_ws(idx.len(), &mut self.tick_ws, |t, _ws| {
                            // SAFETY: `ready_idx` holds distinct in-bounds
                            // indices into `active`, and `try_for_each_ws`
                            // hands each `t` to exactly one participant —
                            // so every `ActiveSeq` is mutably borrowed at
                            // most once, and never while `active` itself
                            // is touched. The allocator is only *read*
                            // during the compute halves (all `&mut` work
                            // happened in the serial append phase above).
                            // A panicking index unwinds out of its closure
                            // only; it is reported, never retried.
                            let seq = unsafe { &mut *base.0.add(idx[t]) };
                            seq.finish_decode_paged(alloc, Exec::Inline);
                        });
                    for t in bad {
                        let slot = self.ready_idx[t];
                        self.active[slot].outcome = Some(SeqOutcome::Quarantined);
                    }
                }
            }
        }
    }

    /// Graceful drain: stop admitting (every still-pending stream sheds
    /// terminally), tick until every resident finishes or cancels by
    /// its limits, release the shared-prefix registry, and assert the
    /// frame pool is whole — zero frames in use, every frame back on
    /// the free list. Returns the terminal [`SeqResult`]s so the caller
    /// can answer every in-flight request before shutdown.
    pub fn drain(&mut self) -> Vec<SeqResult> {
        let mut done = Vec::new();
        if let Some(p) = self.paging.as_mut() {
            while let Some(e) = p.pending.pop_front() {
                p.alloc.note_load_shed();
                done.push(Self::terminal_result(
                    e.id,
                    &e.stream,
                    e.arrived,
                    e.limits.priority,
                    SeqOutcome::Shed,
                ));
            }
        }
        // Every tick retires at least the sessions whose outcome is
        // decided, and resident sessions always make progress once the
        // pending queue is empty (admission pressure is gone, injected
        // exhaustion budgets are finite) — the guard only trips on a
        // genuine livelock bug.
        let mut guard: u64 = 0;
        while self.active() > 0 {
            done.extend(self.tick());
            guard += 1;
            assert!(guard < 1_000_000, "SessionManager::drain failed to converge");
        }
        if let Some(p) = self.paging.as_mut() {
            p.registry.clear(&mut p.alloc);
            p.alloc.assert_all_free();
        }
        done
    }
}

/// Request-level baseline: one-shot prefill then every decode step, on the
/// caller's thread. Same engine, same [`SeqResult`] accounting — the
/// sequential scheduler the continuous-batching loop replaces (and, with
/// `max_batch = 1`, reproduces bitwise for f32 engines under
/// `KvSplit::Off`; split-KV keeps decode rows and stats exact but makes
/// sub-`b_q` prefill tail chunks allclose — see the module docs).
pub fn run_sequential(engine: &AttnEngine, id: u64, stream: &SeqStream) -> SeqResult {
    let arrived = Instant::now();
    let mut session = engine.session();
    let mut out = Vec::new();
    let mut stats = SkipStats::default();
    let mut compute = 0.0;
    let mut ttft = None;
    let mut tpot = Vec::new();
    if stream.prefill > 0 {
        let t0 = Instant::now();
        let r = session.prefill(
            &stream.q.rows(0, stream.prefill),
            &stream.k.rows(0, stream.prefill),
            &stream.v.rows(0, stream.prefill),
        );
        out.extend_from_slice(r.out.data());
        stats.merge(&r.stats);
        compute += t0.elapsed().as_secs_f64();
        if stream.decode_steps() == 0 {
            ttft = Some(arrived.elapsed().as_secs_f64());
        }
    }
    for t in stream.prefill..stream.len() {
        let t0 = Instant::now();
        let r = session.decode(&stream.q.rows(t, t + 1), &stream.k.rows(t, t + 1), &stream.v.rows(t, t + 1));
        out.extend_from_slice(r.out.data());
        stats.merge(&r.stats);
        let dt = t0.elapsed().as_secs_f64();
        compute += dt;
        if ttft.is_none() {
            ttft = Some(arrived.elapsed().as_secs_f64());
        } else {
            tpot.push(dt);
        }
    }
    let dv = stream.v.dim(1);
    let rows = out.len() / dv;
    SeqResult {
        id,
        out: Tensor::from_vec(&[rows, dv], out),
        stats,
        tokens: stream.decode_steps(),
        ttft: ttft.unwrap_or(0.0),
        tpot,
        latency: arrived.elapsed().as_secs_f64(),
        compute,
        outcome: SeqOutcome::Completed,
        priority: Priority::default(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::{AttnConfig, AttnEngine, Execution, KvSplit};
    use crate::sparge::SpargeParams;

    fn spec(prefill: usize, decode: usize, seed: u64) -> AttnStreamSpec {
        AttnStreamSpec { prefill, decode, d: 16, seed, ..Default::default() }
    }

    fn serving_engine(bq: usize, bk: usize, pool: usize) -> AttnEngine {
        let cfg = AttnConfig { bq, bk, causal: true, scale: None, cw: 2, row_offset: 0 };
        let params = SpargeParams { tau: 0.9, theta: 0.3, lambda: None, quant: false };
        AttnEngine::builder().config(cfg).sparge(&params).execution(Execution::Pool(pool)).build()
    }

    /// Drive the manager like the scheduler does, with an admission cap.
    fn run_managed(
        engine: &AttnEngine,
        chunk: usize,
        max_active: usize,
        specs: &[AttnStreamSpec],
    ) -> Vec<SeqResult> {
        let mut mgr = SessionManager::new(engine, chunk);
        let mut queue: std::collections::VecDeque<(u64, SeqStream)> =
            specs.iter().enumerate().map(|(i, s)| (i as u64, SeqStream::synth(s))).collect();
        let mut done = Vec::new();
        while !queue.is_empty() || mgr.active() > 0 {
            while mgr.active() < max_active {
                match queue.pop_front() {
                    Some((id, stream)) => mgr.admit(id, stream, Instant::now()),
                    None => break,
                }
            }
            done.extend(mgr.tick());
        }
        done.sort_by_key(|r| r.id);
        done
    }

    #[test]
    fn managed_loop_matches_sequential_bitwise_any_batch_size() {
        // b_q-aligned chunks (bk | bq here) keep chunked prefill bitwise
        // == one-shot, so the whole continuous schedule must reproduce the
        // sequential baseline's outputs AND stats, at max_active 1 and 4.
        let engine = serving_engine(16, 8, 2);
        let specs =
            [spec(40, 8, 1), spec(16, 0, 2), spec(0, 6, 3), spec(33, 5, 4), spec(64, 12, 5)];
        let sequential: Vec<SeqResult> = specs
            .iter()
            .enumerate()
            .map(|(i, s)| run_sequential(&engine, i as u64, &SeqStream::synth(s)))
            .collect();
        for max_active in [1, 4] {
            let managed = run_managed(&engine, 16, max_active, &specs);
            assert_eq!(managed.len(), sequential.len());
            for (m, s) in managed.iter().zip(&sequential) {
                assert_eq!(m.id, s.id);
                assert_eq!(m.out, s.out, "outputs diverged (max_active {max_active}, id {})", m.id);
                assert_eq!(m.stats, s.stats, "stats diverged (max_active {max_active}, id {})", m.id);
                assert_eq!(m.tokens, s.tokens);
            }
        }
    }

    #[test]
    fn batched_tick_with_split_kv_matches_sequential_bitwise() {
        // The serving composition (pool + split-KV): the batched decode
        // phase runs steps Exec::Inline inside pool workers while the
        // sequential baseline runs them over the engine's pool (with
        // split-KV fanning the spans) — identical bits, because driver
        // routing is shape-based and both drivers are exec-invariant.
        // chunk (64) covers every prompt, so prefill is the *same* single
        // call on both sides: with split-KV on, a sub-b_q tail chunk of a
        // multi-chunk prefill routes through the split driver and would
        // only be allclose to the one-shot rows (tested at the session
        // layer in tests/splitkv_decode.rs); stats stay exact either way.
        let cfg = AttnConfig { bq: 16, bk: 8, causal: true, scale: None, cw: 2, row_offset: 0 };
        let params = SpargeParams { tau: 0.9, theta: 0.3, lambda: None, quant: false };
        let engine = AttnEngine::builder()
            .config(cfg)
            .sparge(&params)
            .execution(Execution::Pool(4))
            .kv_split(KvSplit::Blocks(2))
            .build();
        let specs = [spec(40, 8, 21), spec(16, 6, 22), spec(0, 6, 23), spec(33, 5, 24)];
        let sequential: Vec<SeqResult> = specs
            .iter()
            .enumerate()
            .map(|(i, s)| run_sequential(&engine, i as u64, &SeqStream::synth(s)))
            .collect();
        for max_active in [1, 4] {
            let managed = run_managed(&engine, 64, max_active, &specs);
            for (m, s) in managed.iter().zip(&sequential) {
                assert_eq!(m.out, s.out, "split-KV outputs diverged (batch {max_active}, id {})", m.id);
                assert_eq!(m.stats, s.stats, "split-KV stats diverged (batch {max_active}, id {})", m.id);
            }
        }
        // chunked prefill under split-KV: outputs re-tree (allclose at the
        // session layer) but the merged per-request stats remain exact
        for max_active in [1, 4] {
            let managed = run_managed(&engine, 16, max_active, &specs);
            for (m, s) in managed.iter().zip(&sequential) {
                assert_eq!(m.stats, s.stats, "chunked split-KV stats (batch {max_active}, id {})", m.id);
            }
        }
    }

    #[test]
    fn miri_batched_tick_sendptr_fanout_tiny() {
        // Miri-sized model of the batched decode arm: three decode-only
        // streams are ready on the very first tick, so every tick runs
        // the SendPtr fan-out over `active` (the raw-pointer path Miri
        // checks for aliasing violations). Results must still match the
        // sequential baseline bitwise.
        let engine = serving_engine(8, 8, 2);
        let specs = [spec(0, 3, 41), spec(0, 3, 42), spec(0, 2, 43)];
        let sequential: Vec<SeqResult> = specs
            .iter()
            .enumerate()
            .map(|(i, s)| run_sequential(&engine, i as u64, &SeqStream::synth(s)))
            .collect();
        let managed = run_managed(&engine, 8, 3, &specs);
        assert_eq!(managed.len(), sequential.len());
        for (m, s) in managed.iter().zip(&sequential) {
            assert_eq!(m.out, s.out, "batched fan-out diverged (id {})", m.id);
            assert_eq!(m.stats, s.stats);
        }
    }

    #[test]
    fn chunk_bound_caps_prefill_ticks() {
        // A 70-row prompt with chunk 16 takes ceil(70/16)=5 prefill ticks
        // (interior edges at 16/32/48/64), then decode ticks.
        let engine = serving_engine(16, 16, 1);
        let mut mgr = SessionManager::new(&engine, 20); // aligns down to 16
        mgr.admit(7, SeqStream::synth(&spec(70, 2, 9)), Instant::now());
        let mut prefill_ticks = 0;
        let mut result = None;
        for _ in 0..16 {
            let done = mgr.tick();
            if mgr.active() > 0 || !done.is_empty() {
                if done.is_empty() {
                    prefill_ticks += 1;
                } else {
                    result = done.into_iter().next();
                    break;
                }
            }
        }
        let r = result.expect("stream retired");
        assert_eq!(r.out.dim(0), 72);
        assert_eq!(r.tokens, 2);
        // 5 prefill ticks + first decode tick happen before retirement
        assert_eq!(prefill_ticks, 6);
        assert_eq!(r.tpot.len(), 1, "second decode token records one tpot sample");
    }

    #[test]
    fn ttft_and_tpot_accounting() {
        let engine = serving_engine(8, 8, 1);
        let r = run_sequential(&engine, 0, &SeqStream::synth(&spec(24, 4, 11)));
        assert!(r.ttft > 0.0);
        assert_eq!(r.tokens, 4);
        assert_eq!(r.tpot.len(), 3, "tokens after the first record tpot");
        assert!(r.tpot_mean() > 0.0);
        assert!(r.latency >= r.ttft);
        // decode-less stream still gets a TTFT (prompt completion)
        let r0 = run_sequential(&engine, 1, &SeqStream::synth(&spec(16, 0, 12)));
        assert!(r0.ttft > 0.0);
        assert_eq!(r0.tokens, 0);
        assert!(r0.tpot.is_empty());
    }

    use crate::coordinator::fault::{FaultEvent, FaultPlan};

    #[test]
    fn injected_panic_quarantines_only_its_session() {
        // Session 1 panics on tick 2's batched fan-out; sessions 0 and 2
        // must complete bitwise-identically to a fault-free run.
        let engine = serving_engine(8, 8, 2);
        let specs = [spec(0, 6, 61), spec(0, 6, 62), spec(0, 6, 63)];
        let clean: Vec<SeqResult> = specs
            .iter()
            .enumerate()
            .map(|(i, s)| run_sequential(&engine, i as u64, &SeqStream::synth(s)))
            .collect();
        let mut mgr = SessionManager::new(&engine, 8);
        mgr.set_fault_plan(Some(FaultPlan::new(vec![FaultEvent {
            at_tick: 2,
            session: Some(1),
            kind: FaultKind::WorkerPanic,
        }])));
        for (i, s) in specs.iter().enumerate() {
            mgr.admit(i as u64, SeqStream::synth(s), Instant::now());
        }
        let mut done = Vec::new();
        while mgr.active() > 0 {
            done.extend(mgr.tick());
        }
        done.sort_by_key(|r| r.id);
        assert_eq!(done.len(), 3, "every request reaches exactly one outcome");
        assert_eq!(done[1].outcome, SeqOutcome::Quarantined);
        assert_eq!(mgr.faults_injected(), 1);
        for i in [0usize, 2] {
            assert_eq!(done[i].outcome, SeqOutcome::Completed);
            assert_eq!(done[i].out, clean[i].out, "survivor {i} diverged from fault-free run");
            assert_eq!(done[i].stats, clean[i].stats);
        }
    }

    #[test]
    fn poisoned_input_is_screened_before_any_kernel() {
        let engine = serving_engine(8, 8, 1);
        let mut mgr = SessionManager::new(&engine, 8);
        mgr.set_fault_plan(Some(FaultPlan::new(vec![FaultEvent {
            at_tick: 3,
            session: Some(0),
            kind: FaultKind::PoisonInput,
        }])));
        mgr.admit(0, SeqStream::synth(&spec(0, 8, 71)), Instant::now());
        let mut done = Vec::new();
        while mgr.active() > 0 {
            done.extend(mgr.tick());
        }
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].outcome, SeqOutcome::Quarantined);
        // the screen caught it at the tick boundary: the poisoned row
        // never reached a kernel, so every produced row is finite
        assert!(done[0].out.data().iter().all(|x| x.is_finite()));
        assert!(done[0].tokens < 8);
    }

    #[test]
    fn stall_fault_changes_no_bits() {
        let engine = serving_engine(8, 8, 2);
        let specs = [spec(0, 5, 81), spec(0, 5, 82)];
        let clean: Vec<SeqResult> = specs
            .iter()
            .enumerate()
            .map(|(i, s)| run_sequential(&engine, i as u64, &SeqStream::synth(s)))
            .collect();
        let mut mgr = SessionManager::new(&engine, 8);
        mgr.set_fault_plan(Some(FaultPlan::new(vec![FaultEvent {
            at_tick: 1,
            session: None,
            kind: FaultKind::Stall { micros: 300 },
        }])));
        for (i, s) in specs.iter().enumerate() {
            mgr.admit(i as u64, SeqStream::synth(s), Instant::now());
        }
        let mut done = Vec::new();
        while mgr.active() > 0 {
            done.extend(mgr.tick());
        }
        done.sort_by_key(|r| r.id);
        for (d, c) in done.iter().zip(&clean) {
            assert_eq!(d.outcome, SeqOutcome::Completed);
            assert_eq!(d.out, c.out, "a stall must never change output bits");
        }
    }

    #[test]
    fn token_budget_truncates_and_completes() {
        let engine = serving_engine(8, 8, 1);
        let mut mgr = SessionManager::new(&engine, 8);
        let limits = RequestLimits { deadline_ms: None, token_budget: Some(3), ..Default::default() };
        mgr.admit_with(0, SeqStream::synth(&spec(16, 10, 91)), Instant::now(), limits);
        let mut done = Vec::new();
        while mgr.active() > 0 {
            done.extend(mgr.tick());
        }
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].outcome, SeqOutcome::Completed);
        assert_eq!(done[0].tokens, 3, "budget is a stop condition");
        // budgeted prefix is bitwise-identical to the unbudgeted run
        let full = run_sequential(&engine, 0, &SeqStream::synth(&spec(16, 10, 91)));
        assert_eq!(done[0].out.data(), &full.out.data()[..done[0].out.data().len()]);
    }

    #[test]
    fn expired_deadline_cancels_at_tick_boundary() {
        let engine = serving_engine(8, 8, 1);
        let mut mgr = SessionManager::new(&engine, 8);
        let limits = RequestLimits { deadline_ms: Some(0), token_budget: None, ..Default::default() };
        // arrived in the past: already expired at the first tick boundary
        mgr.admit_with(0, SeqStream::synth(&spec(8, 4, 92)), Instant::now(), limits);
        std::thread::sleep(std::time::Duration::from_millis(2));
        let mut done = Vec::new();
        while mgr.active() > 0 {
            done.extend(mgr.tick());
        }
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].outcome, SeqOutcome::DeadlineCancelled);
        assert_eq!(done[0].tokens, 0, "cancelled before any decode step");
    }

    #[test]
    fn drain_finishes_residents_and_sheds_pending() {
        // Paged manager with a pool sized for one stream: drain must
        // finish the resident, shed the queue, and leave zero frames.
        let engine = serving_engine(8, 8, 1);
        let alloc = PageAllocator::new(4, 8, 16, 16);
        let mut mgr = SessionManager::new_paged(&engine, 8, alloc);
        for i in 0..4u64 {
            mgr.admit(i, SeqStream::synth(&spec(8, 4, 100 + i)), Instant::now());
        }
        // one tick: the first stream(s) go resident, the rest stay queued
        let mut done = mgr.tick();
        done.extend(mgr.drain());
        assert_eq!(mgr.active(), 0);
        assert_eq!(mgr.pending(), 0);
        assert_eq!(done.len(), 4, "every admitted request terminated exactly once");
        let stats = mgr.page_stats().expect("paged");
        assert_eq!(stats.frames_in_use, 0, "drain returned every frame");
        assert!(done.iter().all(|r| matches!(
            r.outcome,
            SeqOutcome::Completed | SeqOutcome::Shed
        )));
        assert!(done.iter().any(|r| r.outcome == SeqOutcome::Completed));
    }

    #[test]
    fn unservable_stream_sheds_instead_of_wedging_the_queue() {
        // A stream needing more frames than the pool owns must shed
        // terminally — and the stream queued behind it must still run.
        let engine = serving_engine(8, 8, 1);
        let alloc = PageAllocator::new(2, 8, 16, 16); // 2 frames = 16 rows
        let mut mgr = SessionManager::new_paged(&engine, 8, alloc);
        mgr.admit(0, SeqStream::synth(&spec(32, 4, 110)), Instant::now()); // needs 5 frames
        mgr.admit(1, SeqStream::synth(&spec(8, 2, 111)), Instant::now()); // fits
        let mut done = Vec::new();
        let mut guard = 0;
        while mgr.active() > 0 || mgr.pending() > 0 {
            done.extend(mgr.tick());
            guard += 1;
            assert!(guard < 1000, "queue wedged behind an unservable stream");
        }
        done.sort_by_key(|r| r.id);
        assert_eq!(done.len(), 2);
        assert_eq!(done[0].outcome, SeqOutcome::Shed);
        assert_eq!(done[1].outcome, SeqOutcome::Completed);
        mgr.release_prefixes();
        let stats = mgr.page_stats().expect("paged");
        assert_eq!(stats.frames_in_use, 0);
    }

    #[test]
    fn injected_exhaustion_defers_but_never_breaks_the_paged_run() {
        // Artificial claim denials mid-run: the defer/evict machinery
        // absorbs them and the final outputs match the fault-free run.
        let engine = serving_engine(8, 8, 1);
        let mk = || PageAllocator::new(16, 8, 16, 16);
        let specs = [spec(16, 4, 120), spec(16, 4, 121)];
        let run = |plan: Option<FaultPlan>| {
            let mut mgr = SessionManager::new_paged(&engine, 8, mk());
            mgr.set_fault_plan(plan);
            for (i, s) in specs.iter().enumerate() {
                mgr.admit(i as u64, SeqStream::synth(s), Instant::now());
            }
            let mut done = Vec::new();
            let mut guard = 0;
            while mgr.active() > 0 || mgr.pending() > 0 {
                done.extend(mgr.tick());
                guard += 1;
                assert!(guard < 10_000, "exhaustion wedged the loop");
            }
            done.sort_by_key(|r| r.id);
            done
        };
        let clean = run(None);
        let faulted = run(Some(FaultPlan::new(vec![
            FaultEvent { at_tick: 2, session: None, kind: FaultKind::FrameExhaustion { claims: 3 } },
            FaultEvent { at_tick: 4, session: None, kind: FaultKind::FrameExhaustion { claims: 2 } },
        ])));
        assert_eq!(clean.len(), faulted.len());
        for (c, f) in clean.iter().zip(&faulted) {
            assert_eq!(f.outcome, SeqOutcome::Completed);
            assert_eq!(c.out, f.out, "exhaustion changed output bits (id {})", c.id);
            assert_eq!(c.stats, f.stats);
        }
    }

    #[test]
    fn high_priority_preempts_low_and_both_complete_bitwise() {
        // Pool of 4 frames; a Low stream fills it (3 prefill chunks +
        // one decode step), then a High stream arrives. The detector
        // sees zero free frames with work pending and turns Preempting;
        // the tick checkpoints Low to the offload tier, admits High,
        // and resumes Low once High retires — both outputs must be
        // bitwise-identical to uninterrupted sequential runs.
        let engine = serving_engine(8, 8, 1);
        let alloc = PageAllocator::new(4, 8, 16, 16);
        let mut mgr = SessionManager::new_paged(&engine, 8, alloc);
        let low = spec(24, 4, 210); // 28 rows = all 4 frames
        let high = spec(16, 4, 211); // 20 rows = 3 frames
        let lo = RequestLimits { priority: Priority::Low, ..Default::default() };
        let hi = RequestLimits { priority: Priority::High, ..Default::default() };
        mgr.admit_with(0, SeqStream::synth(&low), Instant::now(), lo);
        for _ in 0..4 {
            assert!(mgr.tick().is_empty(), "Low must still be mid-stream");
        }
        mgr.admit_with(1, SeqStream::synth(&high), Instant::now(), hi);
        let mut done = Vec::new();
        let mut guard = 0;
        while mgr.active() > 0 || mgr.pending() > 0 {
            done.extend(mgr.tick());
            guard += 1;
            assert!(guard < 1000, "preemption wedged the loop");
        }
        done.sort_by_key(|r| r.id);
        let (preempted, resumed, to_preempting, _, inversions) = mgr.qos_counters();
        assert_eq!(preempted, 1, "the Low resident is preempted exactly once");
        assert_eq!(resumed, 1, "and resumed exactly once");
        assert!(to_preempting >= 1, "the detector must have entered Preempting");
        assert_eq!(inversions, 0);
        assert_eq!(done.len(), 2);
        for (i, s) in [low, high].iter().enumerate() {
            let seq = run_sequential(&engine, i as u64, &SeqStream::synth(s));
            assert_eq!(done[i].outcome, SeqOutcome::Completed, "id {i}");
            assert_eq!(done[i].out, seq.out, "preempt/resume must stay bitwise (id {i})");
            assert_eq!(done[i].stats, seq.stats, "id {i}");
            assert_eq!(done[i].tokens, seq.tokens, "id {i}");
        }
        mgr.assert_frames_all_free();
    }
}
