//! Deterministic fault injection for the serving loop.
//!
//! A [`FaultPlan`] is a seeded schedule of failures — worker-job panics,
//! artificial frame exhaustion, slow-worker stalls, poisoned (NaN)
//! decode inputs — that the `SessionManager` consults at tick
//! boundaries. The plan is the *injection* seam only: the recovery
//! machinery (quarantine, deadline cancellation, drain) is always
//! compiled in and always armed; the plan merely makes the failure
//! paths fire on demand so the chaos suite (`tests/chaos_serving.rs`)
//! can drive hundreds of seeded schedules and assert the loop's
//! invariants hold under every one.
//!
//! Contracts:
//! - **Deterministic**: the same seed yields the same schedule, and the
//!   manager applies events in a fixed order (event order within a
//!   tick, session order within an event), so a chaos failure replays
//!   exactly from its seed.
//! - **Zero cost when absent**: the manager holds an
//!   `Option<FaultPlan>`; with `None` the per-tick check is one branch
//!   and the hot path allocates nothing (the `alloc_regression` tick
//!   sections run with no plan installed and must not move).
//! - **O(events) per tick, no allocation**: consulting the plan scans
//!   the event list — faults are rare and schedules are small; there is
//!   no per-tick index to build.

use std::time::Duration;

use crate::util::rng::Pcg;

/// One kind of injected failure.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Panic inside the targeted session's decode job on the worker
    /// that runs it — exercising the `WorkerPool` per-index attribution
    /// path and the manager's quarantine recovery.
    WorkerPanic,
    /// Deny the next `claims` calls to `PageAllocator::claim`, as if the
    /// pool were exhausted — exercising the defer/evict/shed machinery
    /// mid-stream instead of only at admission.
    FrameExhaustion { claims: u32 },
    /// Sleep `micros` microseconds inside the targeted session's decode
    /// job — a slow worker. Must never change any output bit; chunked
    /// self-scheduling absorbs the straggler.
    Stall { micros: u64 },
    /// Overwrite the targeted session's next decode input row with NaN
    /// — exercising the poison screen and quarantine path.
    PoisonInput,
}

impl FaultKind {
    /// Execute the hot-path effect of a worker-scoped fault, on the
    /// thread running the faulted session's decode job. `WorkerPanic`
    /// unwinds (the pool attributes it to its index; the manager
    /// quarantines the session); `Stall` sleeps; the other kinds act at
    /// tick boundaries instead and are no-ops here.
    pub fn detonate(&self) {
        match self {
            FaultKind::WorkerPanic => {
                // sparge-lint: allow(serving-no-panic)
                panic!("injected fault: worker job panic");
            }
            FaultKind::Stall { micros } => std::thread::sleep(Duration::from_micros(*micros)),
            FaultKind::FrameExhaustion { .. } | FaultKind::PoisonInput => {}
        }
    }

    /// Poison a staged decode input row in place (the `PoisonInput`
    /// effect): every element becomes NaN, which the manager's
    /// tick-boundary screen must catch before the row reaches a kernel.
    pub fn poison_row(row: &mut [f32]) {
        for x in row.iter_mut() {
            *x = f32::NAN;
        }
    }
}

/// One scheduled fault: fire `kind` at manager tick `at_tick` (0-based,
/// counted per manager), scoped to one session or to every session
/// active at that tick.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultEvent {
    pub at_tick: u64,
    /// `Some(id)`: only that session. `None`: every session active at
    /// `at_tick` (for session-scoped kinds); irrelevant for
    /// `FrameExhaustion`, which acts on the allocator.
    pub session: Option<u64>,
    pub kind: FaultKind,
}

/// A deterministic schedule of [`FaultEvent`]s, installed on a
/// `SessionManager` via `set_fault_plan` (directly or through
/// `ServeOptions::fault`).
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    events: Vec<FaultEvent>,
    /// Burst-arrival schedule for the chaos harness: `(at_tick, count)`
    /// pairs telling the driver to submit `count` extra requests when
    /// the manager reaches `at_tick`. Arrival shaping is driver-side —
    /// the manager itself never consults this — so it lives in its own
    /// field and leaves [`FaultPlan::seeded`]'s RNG stream untouched.
    bursts: Vec<(u64, u32)>,
}

impl FaultPlan {
    /// An explicit schedule. Events are kept in the given order; the
    /// manager applies same-tick events first-to-last.
    pub fn new(events: Vec<FaultEvent>) -> FaultPlan {
        FaultPlan { events, bursts: Vec::new() }
    }

    /// Attach a burst-arrival schedule (see the `bursts` field docs).
    /// Pairs are kept in the given order; same-tick pairs accumulate.
    pub fn with_bursts(mut self, bursts: Vec<(u64, u32)>) -> FaultPlan {
        self.bursts = bursts;
        self
    }

    /// A seeded burst schedule: `n` bursts over ticks `[0, ticks)`, each
    /// of `1..=max` arrivals. Deterministic in every argument, and drawn
    /// from its own RNG stream so composing it with [`FaultPlan::seeded`]
    /// never perturbs the fault events of an existing seed.
    pub fn seeded_bursts(seed: u64, ticks: u64, n: usize, max: u32) -> Vec<(u64, u32)> {
        let mut rng = Pcg::new(seed, 0xb025_7a11_0f5e_ed02);
        let mut bursts = Vec::with_capacity(n);
        for _ in 0..n {
            let at_tick = if ticks == 0 { 0 } else { rng.below(ticks) };
            let count = 1 + rng.below(max.max(1) as u64) as u32;
            bursts.push((at_tick, count));
        }
        bursts.sort_by_key(|&(t, _)| t);
        bursts
    }

    /// The burst-arrival schedule, in application order.
    pub fn bursts(&self) -> &[(u64, u32)] {
        &self.bursts
    }

    /// Total extra arrivals the driver should submit at `tick`.
    pub fn burst_at(&self, tick: u64) -> u32 {
        self.bursts.iter().filter(|&&(t, _)| t == tick).map(|&(_, c)| c).sum()
    }

    /// A seeded random schedule: `n` events over ticks `[0, ticks)`
    /// targeting ids drawn from `sessions` (each event has a small
    /// chance of broadcasting to all sessions). Deterministic in
    /// (`seed`, `ticks`, `sessions`, `n`) — the chaos suite's whole
    /// schedule replays from its seed.
    pub fn seeded(seed: u64, ticks: u64, sessions: &[u64], n: usize) -> FaultPlan {
        let mut rng = Pcg::new(seed, 0x0fa0_17de_ad5e_ed01);
        let mut events = Vec::with_capacity(n);
        for _ in 0..n {
            let at_tick = if ticks == 0 { 0 } else { rng.below(ticks) };
            let session = if sessions.is_empty() || rng.chance(0.1) {
                None
            } else {
                Some(sessions[rng.range(0, sessions.len())])
            };
            let kind = match rng.below(4) {
                0 => FaultKind::WorkerPanic,
                1 => FaultKind::FrameExhaustion { claims: 1 + rng.below(3) as u32 },
                2 => FaultKind::Stall { micros: 1 + rng.below(200) },
                _ => FaultKind::PoisonInput,
            };
            events.push(FaultEvent { at_tick, session, kind });
        }
        events.sort_by_key(|e| e.at_tick);
        FaultPlan { events, bursts: Vec::new() }
    }

    /// The full schedule, in application order.
    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    /// Total artificial claim denials scheduled for `tick` (the
    /// `FrameExhaustion` budget the manager feeds to
    /// `PageAllocator::inject_exhaustion` at the top of the tick).
    pub fn exhaustion_at(&self, tick: u64) -> u64 {
        let mut denials = 0u64;
        for e in &self.events {
            if e.at_tick == tick {
                if let FaultKind::FrameExhaustion { claims } = e.kind {
                    denials += claims as u64;
                }
            }
        }
        denials
    }

    /// The first session-scoped fault targeting `session` at `tick`
    /// (`WorkerPanic`, `Stall`, or `PoisonInput`; exhaustion is
    /// allocator-scoped and reported by [`FaultPlan::exhaustion_at`]).
    /// First-match-wins keeps application order deterministic when a
    /// schedule stacks several faults on one (tick, session).
    pub fn fault_for(&self, tick: u64, session: u64) -> Option<FaultKind> {
        self.events.iter().find_map(|e| {
            let scoped = e.at_tick == tick
                && e.session.is_none_or(|s| s == session)
                && !matches!(e.kind, FaultKind::FrameExhaustion { .. });
            scoped.then_some(e.kind)
        })
    }

    /// True when the schedule has no event at or after `tick` — the
    /// drain loop uses this to know no further injections can fire.
    pub fn exhausted_after(&self, tick: u64) -> bool {
        self.events.iter().all(|e| e.at_tick < tick)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeded_plan_is_deterministic() {
        let a = FaultPlan::seeded(42, 100, &[1, 2, 3], 16);
        let b = FaultPlan::seeded(42, 100, &[1, 2, 3], 16);
        assert_eq!(a.events(), b.events());
        assert_eq!(a.events().len(), 16);
        let c = FaultPlan::seeded(43, 100, &[1, 2, 3], 16);
        assert_ne!(a.events(), c.events(), "different seeds must differ");
    }

    #[test]
    fn exhaustion_sums_only_frame_events_at_the_tick() {
        let plan = FaultPlan::new(vec![
            FaultEvent { at_tick: 3, session: None, kind: FaultKind::FrameExhaustion { claims: 2 } },
            FaultEvent { at_tick: 3, session: Some(7), kind: FaultKind::WorkerPanic },
            FaultEvent { at_tick: 3, session: None, kind: FaultKind::FrameExhaustion { claims: 1 } },
            FaultEvent { at_tick: 4, session: None, kind: FaultKind::FrameExhaustion { claims: 9 } },
        ]);
        assert_eq!(plan.exhaustion_at(3), 3);
        assert_eq!(plan.exhaustion_at(4), 9);
        assert_eq!(plan.exhaustion_at(5), 0);
    }

    #[test]
    fn fault_for_scopes_by_tick_and_session() {
        let plan = FaultPlan::new(vec![
            FaultEvent { at_tick: 1, session: Some(5), kind: FaultKind::PoisonInput },
            FaultEvent { at_tick: 2, session: None, kind: FaultKind::Stall { micros: 10 } },
            FaultEvent { at_tick: 2, session: Some(5), kind: FaultKind::WorkerPanic },
        ]);
        assert_eq!(plan.fault_for(1, 5), Some(FaultKind::PoisonInput));
        assert_eq!(plan.fault_for(1, 6), None);
        // broadcast event hits every session; first match wins over the
        // later session-specific event
        assert_eq!(plan.fault_for(2, 5), Some(FaultKind::Stall { micros: 10 }));
        assert_eq!(plan.fault_for(2, 9), Some(FaultKind::Stall { micros: 10 }));
        assert!(plan.exhausted_after(3));
        assert!(!plan.exhausted_after(2));
    }

    #[test]
    fn burst_schedule_is_deterministic_and_separate_from_events() {
        // the burst stream must not perturb the fault-event stream: the
        // same seed with and without bursts yields identical events
        let plain = FaultPlan::seeded(42, 100, &[1, 2, 3], 16);
        let bursts = FaultPlan::seeded_bursts(42, 100, 8, 4);
        let with = FaultPlan::seeded(42, 100, &[1, 2, 3], 16).with_bursts(bursts.clone());
        assert_eq!(plain.events(), with.events());
        assert_eq!(FaultPlan::seeded_bursts(42, 100, 8, 4), bursts, "bursts replay from seed");
        assert_eq!(with.bursts().len(), 8);
        assert!(with.bursts().iter().all(|&(t, c)| t < 100 && (1..=4).contains(&c)));
        // same-tick pairs accumulate
        let p = FaultPlan::default().with_bursts(vec![(3, 2), (3, 1), (5, 4)]);
        assert_eq!(p.burst_at(3), 3);
        assert_eq!(p.burst_at(5), 4);
        assert_eq!(p.burst_at(4), 0);
    }

    #[test]
    fn poison_row_is_all_nan() {
        let mut row = vec![1.0f32; 8];
        FaultKind::poison_row(&mut row);
        assert!(row.iter().all(|x| x.is_nan()));
    }

    #[test]
    fn detonate_stall_and_tick_scoped_kinds_do_not_unwind() {
        FaultKind::Stall { micros: 1 }.detonate();
        FaultKind::FrameExhaustion { claims: 1 }.detonate();
        FaultKind::PoisonInput.detonate();
        let r = std::panic::catch_unwind(|| FaultKind::WorkerPanic.detonate());
        assert!(r.is_err(), "WorkerPanic must unwind");
    }
}
