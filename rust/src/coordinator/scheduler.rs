//! Scheduler: the continuous-batching worker loop and the top-level
//! [`Coordinator`] facade tying queue, engines, and metrics together.
//!
//! The worker schedules at **token level**, not request level: each loop
//! iteration (tick) admits new requests from the batcher up to
//! `BatchPolicy::max_batch` concurrently active sequences, advances every
//! active sequence by one unit of work, and retires the finished ones.
//! Attention-stream requests live in a [`SessionManager`] (N sessions,
//! one shared [`AttnEngine`]/worker pool; one *bounded* prefill chunk or
//! one decode row per tick), LM requests take one greedy token step
//! through the PJRT engine actor per tick. A long prompt therefore never
//! monopolizes the engine — queued requests start within one chunk-sized
//! tick, which is what caps time-to-first-token under mixed traffic.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::attention::paged::PageAllocator;
use crate::attention::{AttnConfig, AttnEngine, DiskTier, Execution, KvSplit};
use crate::sparge::SpargeParams;
use crate::util::threadpool::WorkerPool;

use super::batcher::{BatchPolicy, Batcher};
use super::engine::EngineHandle;
use super::fault::FaultPlan;
use super::metrics::Metrics;
use super::qos::{retry_after_ms, OverloadState};
use super::request::{AttnMode, AttnStreamSpec, GenerateRequest, GenerateResponse, Payload, QueuedRequest};
use super::session_manager::{SeqOutcome, SeqResult, SeqStream, SessionManager};

/// Result of a kernel-level attention probe request.
#[derive(Clone, Copy, Debug)]
pub struct AttnProbeResult {
    /// Achieved sparsity (stage-1 + stage-2 skips over dense totals).
    pub sparsity: f64,
    /// Wall-clock seconds for predict + sparse attention.
    pub seconds: f64,
    pub n: usize,
    pub d: usize,
    pub threads: usize,
}

/// Result of a decode-mode probe: per-step serving-path sparsity from an
/// [`crate::attention::AttnSession`] (prefill `n` tokens, then `steps`
/// single-row decode steps).
#[derive(Clone, Debug)]
pub struct DecodeProbeResult {
    /// Sparsity of the prefill call.
    pub prefill_sparsity: f64,
    /// Sparsity of each decode step, in order (exact fractional
    /// accounting — see `SkipStats::pv_skipped_frac`).
    pub step_sparsity: Vec<f64>,
    /// Mean over `step_sparsity` (0 when `steps` is 0).
    pub mean_step_sparsity: f64,
    /// Wall-clock seconds for prefill + all decode steps.
    pub seconds: f64,
    pub n: usize,
    pub d: usize,
    pub steps: usize,
    pub threads: usize,
}

/// Composition of the serving loop's shared attention engine and its
/// chunking discipline.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Max prompt rows an attention stream prefills per tick (aligned
    /// down to the engine's `b_q` by the [`SessionManager`]).
    pub chunk: usize,
    /// SpargeAttn composition of the shared engine (τ/θ stage 1, λ stage
    /// 2, INT8 toggle).
    pub params: SpargeParams,
    /// Attention geometry; causal, `row_offset` 0 (sessions manage it).
    pub cfg: AttnConfig,
    /// Worker-pool size of the shared engine.
    pub threads: usize,
    /// Split-KV policy of the shared engine. Defaults to
    /// [`KvSplit::Auto`]: the serving loop is exactly the decode-shaped
    /// workload Flash-Decoding exists for, and the serving contract is
    /// determinism across pool sizes (which split-KV preserves), not
    /// bitwise decode≡prefill parity (which it trades away).
    pub kv_split: KvSplit,
    /// Optional fault-injection schedule for the serving loop (chaos
    /// testing). `None` — the default, and the only sane production
    /// value — costs one branch per tick; the recovery machinery
    /// (quarantine, deadlines, drain) is always armed regardless.
    pub fault: Option<FaultPlan>,
    /// Serve attention streams out of a shared paged KV frame pool
    /// instead of per-session caches. Paged serving is what enables
    /// frame-aware admission, priority-aware preemption through the
    /// offload tier, and overload shedding with structured backpressure
    /// on the wire. `None` (the default) keeps monolithic sessions.
    pub paged: Option<PagedServe>,
}

/// Paged-serving composition (see [`ServeOptions::paged`]). Every
/// admitted stream must match the pool's head dims — a mismatched spec
/// fails its request with a structured error, never the loop.
#[derive(Clone, Debug)]
pub struct PagedServe {
    /// Frames in the pool, each `cfg.bk` rows.
    pub frames: usize,
    /// K head dim of the pool.
    pub d: usize,
    /// V dim of the pool.
    pub dv: usize,
    /// Checkpoint preempted sessions to a checksummed on-disk tier
    /// (under the OS temp dir) instead of the in-memory default.
    pub spill_to_disk: bool,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            chunk: 256,
            params: SpargeParams::default(),
            cfg: AttnConfig::causal(),
            threads: crate::util::threadpool::default_threads(),
            kv_split: KvSplit::Auto,
            fault: None,
            paged: None,
        }
    }
}

impl ServeOptions {
    /// Build the serving engine over `pool` — the coordinator's one
    /// shared worker pool, which the probe engines join too.
    fn build_engine(&self, pool: Arc<WorkerPool>) -> AttnEngine {
        AttnEngine::builder()
            .config(self.cfg)
            .sparge(&self.params)
            .kv_split(self.kv_split)
            .shared_pool(pool)
            .build()
    }
}

/// The serving coordinator: submit generation or attention-stream
/// requests from any thread; the scheduler thread runs them through the
/// continuous-batching loop.
pub struct Coordinator {
    batcher: Arc<Batcher>,
    pub metrics: Arc<Metrics>,
    engine: Option<EngineHandle>,
    /// The one worker pool every attention composition shares: the
    /// serving loop's engine and both probe engines run over it, so
    /// mixed-mode traffic never oversubscribes the machine with per-use
    /// pools.
    attn_pool: Arc<WorkerPool>,
    next_id: AtomicU64,
    worker: Option<thread::JoinHandle<()>>,
    /// Overload posture published by the scheduler thread once per tick
    /// (`OverloadState` encoded 0/1/2) so submit-side rejections can
    /// carry an honest, posture-scaled retry hint.
    overload: Arc<AtomicU8>,
}

impl Coordinator {
    /// Start the scheduler over a PJRT model engine with default serving
    /// options.
    pub fn start(engine: EngineHandle, policy: BatchPolicy) -> Coordinator {
        Coordinator::start_with(Some(engine), policy, ServeOptions::default())
    }

    /// Kernel-only coordinator: no PJRT engine. Attention streams are
    /// served through the shared [`AttnEngine`]; LM generation requests
    /// fail fast with an error response.
    pub fn start_kernel(policy: BatchPolicy, opts: ServeOptions) -> Coordinator {
        Coordinator::start_with(None, policy, opts)
    }

    /// Start the continuous-batching scheduler.
    ///
    /// Panics (on the caller's thread, before anything is spawned) when
    /// `opts` is unservable — the alternative is a delayed assert inside
    /// the scheduler thread that would wedge every future request.
    pub fn start_with(
        engine: Option<EngineHandle>,
        policy: BatchPolicy,
        opts: ServeOptions,
    ) -> Coordinator {
        assert!(opts.cfg.causal, "serving needs a causal attention engine (chunked prefill)");
        assert_eq!(opts.cfg.row_offset, 0, "ServeOptions.cfg.row_offset must be 0 (sessions manage it)");
        assert!(opts.chunk > 0, "ServeOptions.chunk must be positive");
        assert!(policy.max_batch > 0, "BatchPolicy.max_batch must be positive");
        let batcher = Arc::new(Batcher::new(policy));
        let metrics = Arc::new(Metrics::new());
        let attn_pool = WorkerPool::shared(opts.threads);
        let attn_engine = opts.build_engine(Arc::clone(&attn_pool));
        let overload = Arc::new(AtomicU8::new(0));
        let worker = {
            let batcher = Arc::clone(&batcher);
            let metrics = Arc::clone(&metrics);
            let engine = engine.clone();
            let overload = Arc::clone(&overload);
            thread::Builder::new()
                .name("sparge-scheduler".into())
                .spawn(move || {
                    serve_loop(&batcher, engine.as_ref(), &metrics, policy, &opts, &attn_engine, &overload)
                })
                .expect("spawn scheduler")
        };
        Coordinator {
            batcher,
            metrics,
            engine,
            attn_pool,
            next_id: AtomicU64::new(1),
            worker: Some(worker),
            overload,
        }
    }

    /// Overload posture of the serving loop as of its last tick
    /// (`Normal` until the loop has observed anything).
    pub fn overload_state(&self) -> OverloadState {
        match self.overload.load(Ordering::Relaxed) {
            1 => OverloadState::Preempting,
            2 => OverloadState::Shedding,
            _ => OverloadState::Normal,
        }
    }

    /// Structured backpressure for a rejected submit: `(retry_after_ms,
    /// queue_depth)` scaled by the loop's posture and the batcher depth
    /// at this instant — what the server puts on the wire next to a
    /// "queue full" error.
    pub fn retry_hint(&self) -> (u64, usize) {
        let depth = self.batcher.depth();
        (retry_after_ms(self.overload_state(), depth), depth)
    }

    fn enqueue(
        &self,
        mode: AttnMode,
        payload: Payload,
    ) -> Result<mpsc::Receiver<GenerateResponse>> {
        let (tx, rx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        let item = QueuedRequest {
            req: GenerateRequest { id, mode, payload },
            arrived: Instant::now(),
            respond: tx,
        };
        self.batcher.submit(item).map_err(|_| anyhow!("queue full or closed (backpressure)"))?;
        Ok(rx)
    }

    /// Fire-and-forget submit; the response arrives on the returned channel.
    pub fn submit(
        &self,
        prompt: Vec<u8>,
        max_new_tokens: usize,
        mode: AttnMode,
    ) -> Result<mpsc::Receiver<GenerateResponse>> {
        self.enqueue(mode, Payload::Generate { prompt, max_new_tokens })
    }

    /// Submit an attention-session stream (serving-path traffic through
    /// the shared engine, chunked prefill + per-tick decode).
    pub fn submit_stream(
        &self,
        spec: AttnStreamSpec,
        mode: AttnMode,
    ) -> Result<mpsc::Receiver<GenerateResponse>> {
        self.enqueue(mode, Payload::AttnStream(spec))
    }

    /// Blocking convenience: submit and wait.
    pub fn generate(&self, prompt: Vec<u8>, max_new: usize, mode: AttnMode) -> Result<GenerateResponse> {
        let rx = self.submit(prompt, max_new, mode)?;
        rx.recv().map_err(|_| anyhow!("request dropped"))
    }

    /// Blocking convenience: run one attention stream through the loop.
    pub fn serve_stream(&self, spec: AttnStreamSpec) -> Result<GenerateResponse> {
        let rx = self.submit_stream(spec, AttnMode::Sparge)?;
        rx.recv().map_err(|_| anyhow!("request dropped"))
    }

    /// Direct model-engine access (training, scoring, denoise); `None` on
    /// a kernel-only coordinator.
    pub fn engine(&self) -> Option<&EngineHandle> {
        self.engine.as_ref()
    }

    /// Build a probe's attention engine: over the coordinator's shared
    /// worker pool when `threads` matches its size (the default probe
    /// path — no extra threads are ever spawned), falling back to scoped
    /// per-call threads for an explicit different worker count.
    ///
    /// Sharing is deliberate and has a cost: the pool serializes
    /// submitters, so a large probe queues ahead of the serving loop's
    /// next tick (and vice versa) for the duration of one `run`. That is
    /// what "probing the serving configuration" means — the probe
    /// measures the pool the streams actually run on. An operator who
    /// wants an isolated measurement passes a `threads` value different
    /// from the pool size and gets the old scoped-thread behavior.
    fn probe_engine(&self, builder: crate::attention::AttnEngineBuilder, threads: usize) -> AttnEngine {
        if threads == self.attn_pool.size() {
            builder.shared_pool(Arc::clone(&self.attn_pool)).build()
        } else {
            builder.execution(Execution::Threads(threads)).build()
        }
    }

    /// Kernel-level attention probe: run single-head SpargeAttn on a
    /// seeded synthetic workload through the unified tiled pipeline
    /// (`attention::pipeline::run_tiled`), with query-block rows fanned
    /// across `threads` workers, and record the achieved per-request
    /// sparsity into the serving metrics (sparsity aggregates only).
    ///
    /// Runs on the caller's thread: it needs no PJRT engine, so probes
    /// never queue behind generation traffic.
    pub fn attention_probe(
        &self,
        n: usize,
        d: usize,
        seed: u64,
        params: &crate::sparge::SpargeParams,
        threads: usize,
    ) -> AttnProbeResult {
        let mut rng = crate::util::rng::Pcg::seeded(seed);
        let s =
            crate::workloads::synthetic::generate(&crate::workloads::SyntheticSpec::lm_like(n, d), &mut rng);
        let cfg = crate::attention::AttnConfig::default();
        let engine = self.probe_engine(AttnEngine::builder().config(cfg).sparge(params), threads);
        let t0 = Instant::now();
        let res = engine.attention(&s.q, &s.k, &s.v);
        let seconds = t0.elapsed().as_secs_f64();
        let sparsity = res.stats.sparsity();
        // probes feed the sparsity aggregates only; their timings must not
        // distort generation latency/throughput metrics
        self.metrics.record_probe(sparsity);
        AttnProbeResult { sparsity, seconds, n, d, threads }
    }

    /// Decode-mode probe for the serving path: open an
    /// [`crate::attention::AttnSession`] over a seeded synthetic causal
    /// workload of `n + steps` tokens, prefill the first `n`, decode the
    /// rest one row at a time, and report per-step sparsity. The mean step
    /// sparsity feeds the serving metrics' sparsity aggregates (like
    /// [`Coordinator::attention_probe`], timings stay out of the
    /// generation reservoirs).
    pub fn attention_decode_probe(
        &self,
        n: usize,
        d: usize,
        seed: u64,
        params: &crate::sparge::SpargeParams,
        steps: usize,
        threads: usize,
    ) -> DecodeProbeResult {
        let mut rng = crate::util::rng::Pcg::seeded(seed);
        let s = crate::workloads::synthetic::generate(
            &crate::workloads::SyntheticSpec::lm_like(n + steps, d),
            &mut rng,
        );
        let cfg = crate::attention::AttnConfig { causal: true, ..Default::default() };
        let engine = self.probe_engine(AttnEngine::builder().config(cfg).sparge(params), threads);
        let mut session = engine.session();
        let t0 = Instant::now();
        let prefill = session.prefill(&s.q.rows(0, n), &s.k.rows(0, n), &s.v.rows(0, n));
        let mut step_sparsity = Vec::with_capacity(steps);
        for t in n..n + steps {
            let r = session.decode(&s.q.rows(t, t + 1), &s.k.rows(t, t + 1), &s.v.rows(t, t + 1));
            step_sparsity.push(r.stats.sparsity());
        }
        let seconds = t0.elapsed().as_secs_f64();
        let mean_step_sparsity = if step_sparsity.is_empty() {
            0.0
        } else {
            step_sparsity.iter().sum::<f64>() / step_sparsity.len() as f64
        };
        self.metrics.record_probe(mean_step_sparsity);
        DecodeProbeResult {
            prefill_sparsity: prefill.stats.sparsity(),
            step_sparsity,
            mean_step_sparsity,
            seconds,
            n,
            d,
            steps,
            threads,
        }
    }

    pub fn queue_depth(&self) -> usize {
        self.batcher.depth()
    }

    /// Graceful shutdown: drain the queue, stop the worker, stop the
    /// model-engine thread. `Drop` performs the same sequence, so a
    /// dropped coordinator leaves no thread behind.
    pub fn shutdown(self) {
        drop(self);
    }

    fn close_internal(&mut self) {
        self.batcher.close();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        if let Some(engine) = &self.engine {
            engine.shutdown();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.close_internal();
    }
}

/// One active LM sequence in the continuous-batching loop: greedy
/// byte-level generation, one `lm_logits` step per tick (the same trim +
/// argmax discipline as `EngineHandle::generate`, so `max_batch = 1`
/// reproduces the sequential outputs exactly).
struct LmActive {
    id: u64,
    mode: AttnMode,
    tokens: Vec<i32>,
    max_new: usize,
    out: Vec<u8>,
    arrived: Instant,
    respond: mpsc::Sender<GenerateResponse>,
    compute: f64,
    ttft: Option<f64>,
    tpot: Vec<f64>,
    failed: bool,
}

impl LmActive {
    fn new(
        id: u64,
        mode: AttnMode,
        prompt: Vec<u8>,
        max_new: usize,
        arrived: Instant,
        respond: mpsc::Sender<GenerateResponse>,
    ) -> LmActive {
        LmActive {
            id,
            mode,
            tokens: prompt.iter().map(|&b| b as i32).collect(),
            max_new,
            out: Vec::with_capacity(max_new),
            arrived,
            respond,
            compute: 0.0,
            ttft: None,
            tpot: Vec::new(),
            failed: false,
        }
    }

    /// One greedy token step (`EngineHandle::lm_next_token`, the same
    /// code path `generate` loops over); `true` when finished. An error
    /// — engine failure, or an empty prompt — fails the request without
    /// touching the scheduler thread.
    fn step(&mut self, engine: Option<&EngineHandle>) -> bool {
        if self.out.len() >= self.max_new {
            return true;
        }
        let Some(engine) = engine else {
            self.failed = true;
            crate::log_error!("request {}: no model engine (kernel-only coordinator)", self.id);
            return true;
        };
        let t0 = Instant::now();
        match engine.lm_next_token(&mut self.tokens, self.mode) {
            Ok(byte) => {
                let dt = t0.elapsed().as_secs_f64();
                self.compute += dt;
                if self.ttft.is_none() {
                    self.ttft = Some(self.arrived.elapsed().as_secs_f64());
                } else {
                    self.tpot.push(dt);
                }
                self.out.push(byte);
                self.out.len() >= self.max_new
            }
            Err(e) => {
                crate::log_error!("request {} failed: {e:#}", self.id);
                self.failed = true;
                true
            }
        }
    }

    fn finish(self, metrics: &Metrics) {
        let latency = self.arrived.elapsed().as_secs_f64();
        if self.failed {
            metrics.record_error();
        } else {
            // LM artifacts don't report kernel sparsity; attention
            // streams and probes do.
            metrics.record(self.out.len(), latency, self.compute, None);
            if let Some(t) = self.ttft {
                metrics.record_token_latency(t, &self.tpot);
            }
        }
        let tpot_mean = if self.tpot.is_empty() {
            None
        } else {
            Some(self.tpot.iter().sum::<f64>() / self.tpot.len() as f64)
        };
        let _ = self.respond.send(GenerateResponse {
            id: self.id,
            latency,
            compute: self.compute,
            mode: self.mode,
            tokens: self.out.len(),
            ttft: self.ttft,
            tpot: tpot_mean,
            sparsity: None,
            error: if self.failed { Some("generation failed".to_string()) } else { None },
            retry_after_ms: None,
            queue_depth: None,
            output: self.out,
        });
    }
}

/// Attention-stream bookkeeping the manager does not carry.
struct PendingStream {
    mode: AttnMode,
    respond: mpsc::Sender<GenerateResponse>,
}

fn respond_stream(
    metrics: &Metrics,
    pending: PendingStream,
    res: SeqResult,
    backpressure: (u64, usize),
) {
    match res.outcome {
        SeqOutcome::Completed => {
            let sparsity = res.stats.sparsity();
            metrics.record(res.tokens, res.latency, res.compute, Some(sparsity));
            metrics.record_token_latency_for(res.priority, res.ttft, &res.tpot);
            let _ = pending.respond.send(GenerateResponse {
                id: res.id,
                output: Vec::new(),
                latency: res.latency,
                compute: res.compute,
                mode: pending.mode,
                tokens: res.tokens,
                ttft: Some(res.ttft),
                tpot: if res.tpot.is_empty() { None } else { Some(res.tpot_mean()) },
                sparsity: Some(sparsity),
                error: None,
                retry_after_ms: None,
                queue_depth: None,
            });
        }
        outcome => {
            // terminal non-success: the stream was quarantined, cancelled
            // at its deadline, or shed — report the outcome as a
            // structured error instead of a silent drop, and keep any
            // partial output stats it earned. A shed stream additionally
            // carries the backpressure pair: it was dropped for capacity,
            // so the client is told when (and against what depth) to retry.
            metrics.record_error();
            metrics.record_outcome(outcome.name());
            let shed = outcome == SeqOutcome::Shed;
            let _ = pending.respond.send(GenerateResponse {
                id: res.id,
                output: Vec::new(),
                latency: res.latency,
                compute: res.compute,
                mode: pending.mode,
                tokens: res.tokens,
                ttft: if res.tokens > 0 { Some(res.ttft) } else { None },
                tpot: if res.tpot.is_empty() { None } else { Some(res.tpot_mean()) },
                sparsity: None,
                error: Some(format!("stream terminated: {}", outcome.name())),
                retry_after_ms: if shed { Some(backpressure.0) } else { None },
                queue_depth: if shed { Some(backpressure.1) } else { None },
            });
        }
    }
}

/// The continuous-batching worker loop (see module docs). Runs until the
/// batcher closes and every admitted sequence has retired.
fn serve_loop(
    batcher: &Batcher,
    engine: Option<&EngineHandle>,
    metrics: &Metrics,
    policy: BatchPolicy,
    opts: &ServeOptions,
    attn_engine: &AttnEngine,
    overload: &AtomicU8,
) {
    let mut mgr = match &opts.paged {
        Some(pg) => SessionManager::new_paged(
            attn_engine,
            opts.chunk,
            PageAllocator::new(pg.frames, opts.cfg.bk, pg.d, pg.dv),
        ),
        None => SessionManager::new(attn_engine, opts.chunk),
    };
    if opts.paged.as_ref().is_some_and(|pg| pg.spill_to_disk) {
        match DiskTier::scratch("serve") {
            Ok(tier) => mgr.set_offload_tier(Box::new(tier)),
            // an unusable temp dir degrades to the in-memory tier — the
            // loop must serve either way
            Err(e) => crate::log_error!("disk offload tier unavailable ({}), using memory", e.name()),
        }
    }
    mgr.set_fault_plan(opts.fault.clone());
    let mut lm: Vec<LmActive> = Vec::new();
    let mut pending: HashMap<u64, PendingStream> = HashMap::new();
    loop {
        // admit: block when idle (nothing to advance), poll otherwise
        let incoming = if lm.is_empty() && mgr.active() == 0 {
            match batcher.next_batch() {
                Some(batch) => batch,
                None => break, // closed and drained
            }
        } else {
            batcher.poll(policy.max_batch.saturating_sub(lm.len() + mgr.active()))
        };
        for item in incoming {
            let QueuedRequest { req, arrived, respond } = item;
            match req.payload {
                Payload::Generate { prompt, max_new_tokens } => {
                    lm.push(LmActive::new(req.id, req.mode, prompt, max_new_tokens, arrived, respond));
                }
                Payload::AttnStream(spec) => {
                    // a degenerate or pool-mismatched spec must fail the
                    // request, not panic the scheduler thread (paged
                    // sessions assert their dims against the frame pool)
                    let mismatch = opts
                        .paged
                        .as_ref()
                        .map(|pg| spec.d != pg.d || spec.d != pg.dv)
                        .unwrap_or(false);
                    if spec.prefill + spec.decode == 0 || spec.d == 0 || mismatch {
                        let what = if mismatch {
                            "attention stream dims do not match the paged KV pool"
                        } else {
                            "empty attention stream spec"
                        };
                        metrics.record_error();
                        crate::log_error!("request {}: {}", req.id, what);
                        let _ = respond.send(GenerateResponse {
                            id: req.id,
                            output: Vec::new(),
                            latency: arrived.elapsed().as_secs_f64(),
                            compute: 0.0,
                            mode: req.mode,
                            tokens: 0,
                            ttft: None,
                            tpot: None,
                            sparsity: None,
                            error: Some(what.to_string()),
                            retry_after_ms: None,
                            queue_depth: None,
                        });
                        continue;
                    }
                    pending.insert(req.id, PendingStream { mode: req.mode, respond });
                    mgr.admit_with(req.id, SeqStream::synth(&spec), arrived, spec.limits);
                }
            }
        }
        // advance every attention stream one chunk/token
        let retired = mgr.tick();
        // publish the posture the tick just computed, so submit-side
        // rejections carry an honest retry hint; shed responses below use
        // the same pair
        let state = mgr.overload_state();
        overload.store(state as u8, Ordering::Relaxed);
        let bp = (mgr.retry_hint_ms(), mgr.pending() + batcher.depth());
        for res in retired {
            if let Some(p) = pending.remove(&res.id) {
                respond_stream(metrics, p, res, bp);
            }
        }
        // advance every LM sequence one token
        let mut i = 0;
        while i < lm.len() {
            if lm[i].step(engine) {
                lm.remove(i).finish(metrics);
            } else {
                i += 1;
            }
        }
    }
    // graceful drain: the batcher is closed, so nothing new can be
    // admitted. The loop above only breaks once every resident retired,
    // but drain() still runs the terminal invariants (shed anything the
    // manager queued internally, release every frame, assert the paged
    // pool is empty) and answers any straggler.
    let t0 = Instant::now();
    let drained = mgr.drain();
    let bp = (mgr.retry_hint_ms(), mgr.pending());
    for res in drained {
        if let Some(p) = pending.remove(&res.id) {
            respond_stream(metrics, p, res, bp);
        }
    }
    metrics.record_drain_duration(t0.elapsed().as_secs_f64());
    metrics.record_injected_faults(mgr.faults_injected());
    let (preempted, resumed, to_preempting, to_shedding, inversions) = mgr.qos_counters();
    metrics.record_qos(preempted, resumed, to_preempting, to_shedding, inversions);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn dropped_coordinator_stops_the_engine_thread() {
        // The Drop-leak regression: dropping (not shutdown()-ing) the
        // coordinator must still deliver the engine shutdown message, so
        // the `sparge-engine` thread exits instead of leaking.
        let (engine, shutdown_rx) = super::super::engine::stub_engine();
        let c = Coordinator::start(engine, BatchPolicy::default());
        drop(c);
        let got = shutdown_rx.recv_timeout(Duration::from_secs(10));
        assert_eq!(got.ok(), Some(true), "engine thread did not receive shutdown on drop");
    }

    #[test]
    fn shutdown_also_stops_the_engine_thread() {
        let (engine, shutdown_rx) = super::super::engine::stub_engine();
        let c = Coordinator::start(engine, BatchPolicy::default());
        c.shutdown();
        let got = shutdown_rx.recv_timeout(Duration::from_secs(10));
        assert_eq!(got.ok(), Some(true));
    }

    #[test]
    fn generate_against_stub_engine_fails_cleanly() {
        // The loop's error path: a stub engine errors every lm_logits
        // call; the request must retire with an error response, not wedge
        // the scheduler.
        let (engine, _shutdown_rx) = super::super::engine::stub_engine();
        let c = Coordinator::start(engine, BatchPolicy::default());
        let resp = c.generate(b"hello".to_vec(), 4, AttnMode::Dense).unwrap();
        assert!(resp.output.is_empty());
        assert_eq!(resp.tokens, 0);
        assert_eq!(c.metrics.snapshot().errors, 1);
        c.shutdown();
    }

    #[test]
    fn empty_prompt_fails_cleanly_instead_of_panicking() {
        // lm_next_token rejects an empty context before indexing logits,
        // so the request errors instead of underflowing `tokens.len() - 1`
        // on the scheduler thread (which would wedge the whole loop).
        let (engine, _shutdown_rx) = super::super::engine::stub_engine();
        let c = Coordinator::start(engine, BatchPolicy::default());
        let resp = c.generate(Vec::new(), 3, AttnMode::Sparge).unwrap();
        assert!(resp.output.is_empty());
        assert_eq!(c.metrics.snapshot().errors, 1);
        // the loop survives: a later request still gets served
        let resp2 = c.generate(b"ok".to_vec(), 1, AttnMode::Sparge).unwrap();
        assert_eq!(resp2.tokens, 0, "stub engine errors, but the loop answered");
        c.shutdown();
    }

    #[test]
    fn kernel_only_coordinator_rejects_lm_requests() {
        let c = Coordinator::start_kernel(BatchPolicy::default(), ServeOptions::default());
        assert!(c.engine().is_none());
        let resp = c.generate(b"hi".to_vec(), 2, AttnMode::Sparge).unwrap();
        assert!(resp.output.is_empty());
        assert_eq!(c.metrics.snapshot().errors, 1);
    }
}
