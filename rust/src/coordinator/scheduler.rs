//! Scheduler: the worker loop that drains the batcher and drives the
//! engine, plus the top-level [`Coordinator`] facade tying queue, engine,
//! and metrics together.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::batcher::{BatchPolicy, Batcher};
use super::engine::EngineHandle;
use super::metrics::Metrics;
use super::request::{AttnMode, GenerateRequest, GenerateResponse, QueuedRequest};

/// Result of a kernel-level attention probe request.
#[derive(Clone, Copy, Debug)]
pub struct AttnProbeResult {
    /// Achieved sparsity (stage-1 + stage-2 skips over dense totals).
    pub sparsity: f64,
    /// Wall-clock seconds for predict + sparse attention.
    pub seconds: f64,
    pub n: usize,
    pub d: usize,
    pub threads: usize,
}

/// Result of a decode-mode probe: per-step serving-path sparsity from an
/// [`crate::attention::AttnSession`] (prefill `n` tokens, then `steps`
/// single-row decode steps).
#[derive(Clone, Debug)]
pub struct DecodeProbeResult {
    /// Sparsity of the prefill call.
    pub prefill_sparsity: f64,
    /// Sparsity of each decode step, in order (exact fractional
    /// accounting — see `SkipStats::pv_skipped_frac`).
    pub step_sparsity: Vec<f64>,
    /// Mean over `step_sparsity` (0 when `steps` is 0).
    pub mean_step_sparsity: f64,
    /// Wall-clock seconds for prefill + all decode steps.
    pub seconds: f64,
    pub n: usize,
    pub d: usize,
    pub steps: usize,
    pub threads: usize,
}

/// The serving coordinator: submit generation requests from any thread;
/// a scheduler thread batches them and executes on the engine.
pub struct Coordinator {
    batcher: Arc<Batcher>,
    pub metrics: Arc<Metrics>,
    engine: EngineHandle,
    next_id: AtomicU64,
    worker: Option<thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Start the scheduler over an engine.
    pub fn start(engine: EngineHandle, policy: BatchPolicy) -> Coordinator {
        let batcher = Arc::new(Batcher::new(policy));
        let metrics = Arc::new(Metrics::new());
        let worker = {
            let batcher = Arc::clone(&batcher);
            let metrics = Arc::clone(&metrics);
            let engine = engine.clone();
            thread::Builder::new()
                .name("sparge-scheduler".into())
                .spawn(move || {
                    while let Some(batch) = batcher.next_batch() {
                        for item in batch {
                            run_one(&engine, &metrics, item);
                        }
                    }
                })
                .expect("spawn scheduler")
        };
        Coordinator { batcher, metrics, engine, next_id: AtomicU64::new(1), worker: Some(worker) }
    }

    /// Fire-and-forget submit; the response arrives on the returned channel.
    pub fn submit(
        &self,
        prompt: Vec<u8>,
        max_new_tokens: usize,
        mode: AttnMode,
    ) -> Result<mpsc::Receiver<GenerateResponse>> {
        let (tx, rx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        let item = QueuedRequest {
            req: GenerateRequest { id, prompt, max_new_tokens, mode },
            arrived: Instant::now(),
            respond: tx,
        };
        self.batcher.submit(item).map_err(|_| anyhow!("queue full or closed (backpressure)"))?;
        Ok(rx)
    }

    /// Blocking convenience: submit and wait.
    pub fn generate(&self, prompt: Vec<u8>, max_new: usize, mode: AttnMode) -> Result<GenerateResponse> {
        let rx = self.submit(prompt, max_new, mode)?;
        rx.recv().map_err(|_| anyhow!("request dropped"))
    }

    /// Direct engine access (training, scoring, denoise).
    pub fn engine(&self) -> &EngineHandle {
        &self.engine
    }

    /// Kernel-level attention probe: run single-head SpargeAttn on a
    /// seeded synthetic workload through the unified tiled pipeline
    /// (`attention::pipeline::run_tiled`), with query-block rows fanned
    /// across `threads` workers, and record the achieved per-request
    /// sparsity into the serving metrics (sparsity aggregates only).
    ///
    /// Runs on the caller's thread: it needs no PJRT engine, so probes
    /// never queue behind generation traffic.
    pub fn attention_probe(
        &self,
        n: usize,
        d: usize,
        seed: u64,
        params: &crate::sparge::SpargeParams,
        threads: usize,
    ) -> AttnProbeResult {
        let mut rng = crate::util::rng::Pcg::seeded(seed);
        let s =
            crate::workloads::synthetic::generate(&crate::workloads::SyntheticSpec::lm_like(n, d), &mut rng);
        let cfg = crate::attention::AttnConfig::default();
        let engine = crate::attention::AttnEngine::builder()
            .config(cfg)
            .sparge(params)
            .execution(crate::attention::Execution::Threads(threads))
            .build();
        let t0 = Instant::now();
        let res = engine.attention(&s.q, &s.k, &s.v);
        let seconds = t0.elapsed().as_secs_f64();
        let sparsity = res.stats.sparsity();
        // probes feed the sparsity aggregates only; their timings must not
        // distort generation latency/throughput metrics
        self.metrics.record_probe(sparsity);
        AttnProbeResult { sparsity, seconds, n, d, threads }
    }

    /// Decode-mode probe for the serving path: open an
    /// [`crate::attention::AttnSession`] over a seeded synthetic causal
    /// workload of `n + steps` tokens, prefill the first `n`, decode the
    /// rest one row at a time, and report per-step sparsity. The mean step
    /// sparsity feeds the serving metrics' sparsity aggregates (like
    /// [`Coordinator::attention_probe`], timings stay out of the
    /// generation reservoirs).
    pub fn attention_decode_probe(
        &self,
        n: usize,
        d: usize,
        seed: u64,
        params: &crate::sparge::SpargeParams,
        steps: usize,
        threads: usize,
    ) -> DecodeProbeResult {
        let mut rng = crate::util::rng::Pcg::seeded(seed);
        let s = crate::workloads::synthetic::generate(
            &crate::workloads::SyntheticSpec::lm_like(n + steps, d),
            &mut rng,
        );
        let cfg = crate::attention::AttnConfig { causal: true, ..Default::default() };
        let engine = crate::attention::AttnEngine::builder()
            .config(cfg)
            .sparge(params)
            .execution(crate::attention::Execution::Threads(threads))
            .build();
        let mut session = engine.session();
        let t0 = Instant::now();
        let prefill = session.prefill(&s.q.rows(0, n), &s.k.rows(0, n), &s.v.rows(0, n));
        let mut step_sparsity = Vec::with_capacity(steps);
        for t in n..n + steps {
            let r = session.decode(&s.q.rows(t, t + 1), &s.k.rows(t, t + 1), &s.v.rows(t, t + 1));
            step_sparsity.push(r.stats.sparsity());
        }
        let seconds = t0.elapsed().as_secs_f64();
        let mean_step_sparsity = if step_sparsity.is_empty() {
            0.0
        } else {
            step_sparsity.iter().sum::<f64>() / step_sparsity.len() as f64
        };
        self.metrics.record_probe(mean_step_sparsity);
        DecodeProbeResult {
            prefill_sparsity: prefill.stats.sparsity(),
            step_sparsity,
            mean_step_sparsity,
            seconds,
            n,
            d,
            steps,
            threads,
        }
    }

    pub fn queue_depth(&self) -> usize {
        self.batcher.depth()
    }

    /// Graceful shutdown: drain the queue, stop the worker.
    pub fn shutdown(mut self) {
        self.batcher.close();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        self.engine.shutdown();
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.batcher.close();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn run_one(engine: &EngineHandle, metrics: &Metrics, item: QueuedRequest) {
    let QueuedRequest { req, arrived, respond } = item;
    let t0 = Instant::now();
    match engine.generate(&req.prompt, req.max_new_tokens, req.mode) {
        Ok(output) => {
            let compute = t0.elapsed().as_secs_f64();
            let latency = arrived.elapsed().as_secs_f64();
            // LM artifacts don't report kernel sparsity; attention probes do.
            metrics.record(output.len(), latency, compute, None);
            let _ = respond.send(GenerateResponse { id: req.id, output, latency, compute, mode: req.mode });
        }
        Err(e) => {
            metrics.record_error();
            crate::log_error!("request {} failed: {e:#}", req.id);
            let _ = respond.send(GenerateResponse {
                id: req.id,
                output: Vec::new(),
                latency: arrived.elapsed().as_secs_f64(),
                compute: t0.elapsed().as_secs_f64(),
                mode: req.mode,
            });
        }
    }
}
