//! Dynamic batcher: a bounded FIFO with condvar wakeups that groups
//! queued generation requests by attention mode, so the engine amortizes
//! compilation/cache warmth across a batch (the vLLM-router-style
//! structure scaled to this runtime).
//!
//! Fairness: the queue is never reordered — a batch drains matching
//! requests *in place* (matching prefix pops free; stragglers behind a
//! non-matching item are extracted with bounded `VecDeque::remove`s, not
//! a full pop-and-rebuild of the queue), and the batch mode is always the
//! *oldest* waiter's mode, so a minority mode can never be stranded
//! behind a steady front-runner stream. [`BatchPolicy::max_age`] is the
//! aging bound: once the oldest waiter has aged past it, `next_batch`
//! skips the fill wait and ships immediately.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use super::request::QueuedRequest;

/// Batch-forming policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Max requests per batch — and, in the continuous-batching loop, the
    /// max concurrently active sessions.
    pub max_batch: usize,
    /// How long to wait for more requests once one is pending.
    pub max_wait: Duration,
    /// Queue capacity (backpressure: submit fails beyond this).
    pub capacity: usize,
    /// Aging bound: when the oldest queued request has waited at least
    /// this long, the next batch ships without waiting to fill.
    pub max_age: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(20),
            capacity: 1024,
            max_age: Duration::from_millis(250),
        }
    }
}

/// Thread-safe batching queue.
pub struct Batcher {
    policy: BatchPolicy,
    state: Mutex<State>,
    cv: Condvar,
}

#[derive(Default)]
struct State {
    queue: VecDeque<QueuedRequest>,
    closed: bool,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Batcher {
        Batcher { policy, state: Mutex::new(State::default()), cv: Condvar::new() }
    }

    /// Enqueue a request. Errors when the queue is full (backpressure) or
    /// closed.
    pub fn submit(&self, req: QueuedRequest) -> Result<(), QueuedRequest> {
        let mut g = self.state.lock().unwrap();
        if g.closed || g.queue.len() >= self.policy.capacity {
            return Err(req);
        }
        g.queue.push_back(req);
        self.cv.notify_one();
        Ok(())
    }

    /// Current queue depth.
    pub fn depth(&self) -> usize {
        self.state.lock().unwrap().queue.len()
    }

    /// Drain up to `max` requests of the oldest waiter's mode, in place:
    /// the matching prefix pops for free and any stragglers behind a
    /// non-matching item are removed individually, so non-matching
    /// requests keep their (arrival-order) positions.
    fn drain_mode(queue: &mut VecDeque<QueuedRequest>, max: usize) -> Vec<QueuedRequest> {
        let mut batch = Vec::new();
        let Some(front) = queue.front() else {
            return batch;
        };
        let mode = front.req.mode;
        while batch.len() < max && queue.front().is_some_and(|q| q.req.mode == mode) {
            batch.push(queue.pop_front().unwrap());
        }
        let mut i = 0;
        while i < queue.len() && batch.len() < max {
            if queue[i].req.mode == mode {
                batch.push(queue.remove(i).unwrap());
            } else {
                i += 1;
            }
        }
        batch
    }

    /// Pull the next batch: blocks until at least one request is queued
    /// (or the batcher closes → `None`), then waits up to `max_wait` for
    /// the batch to fill — unless the oldest waiter has already aged past
    /// `max_age`, in which case it ships immediately. All requests in a
    /// batch share the oldest waiter's attention mode so the engine hits
    /// one artifact.
    pub fn next_batch(&self) -> Option<Vec<QueuedRequest>> {
        let mut g = self.state.lock().unwrap();
        loop {
            if !g.queue.is_empty() {
                break;
            }
            if g.closed {
                return None;
            }
            g = self.cv.wait(g).unwrap();
        }
        // wait briefly for more arrivals, but never hold back an aged front
        if g.queue.front().unwrap().arrived.elapsed() < self.policy.max_age {
            let deadline = Instant::now() + self.policy.max_wait;
            while g.queue.len() < self.policy.max_batch && !g.closed {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (ng, timeout) = self.cv.wait_timeout(g, deadline - now).unwrap();
                g = ng;
                if timeout.timed_out() {
                    break;
                }
            }
        }
        Some(Self::drain_mode(&mut g.queue, self.policy.max_batch))
    }

    /// Non-blocking admission for the continuous-batching loop: pop up to
    /// `max` requests in arrival order, regardless of mode (iteration-level
    /// scheduling interleaves per-token steps, so there is no per-batch
    /// artifact affinity to preserve). Empty when the queue is empty.
    pub fn poll(&self, max: usize) -> Vec<QueuedRequest> {
        if max == 0 {
            return Vec::new();
        }
        let mut g = self.state.lock().unwrap();
        let take = max.min(g.queue.len());
        g.queue.drain(..take).collect()
    }

    /// Close the queue; `next_batch` drains then returns `None`.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{AttnMode, GenerateRequest, Payload};
    use std::sync::mpsc;
    use std::sync::Arc;

    fn mk(id: u64, mode: AttnMode) -> QueuedRequest {
        let (tx, _rx) = mpsc::channel();
        QueuedRequest {
            req: GenerateRequest {
                id,
                mode,
                payload: Payload::Generate { prompt: vec![b'a'], max_new_tokens: 1 },
            },
            arrived: Instant::now(),
            respond: tx,
        }
    }

    fn mk_aged(id: u64, mode: AttnMode, age: Duration) -> QueuedRequest {
        let mut q = mk(id, mode);
        q.arrived = Instant::now() - age;
        q
    }

    #[test]
    fn batches_same_mode_together() {
        let b = Batcher::new(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            capacity: 16,
            ..Default::default()
        });
        b.submit(mk(1, AttnMode::Sparge)).unwrap();
        b.submit(mk(2, AttnMode::Sparge)).unwrap();
        b.submit(mk(3, AttnMode::Dense)).unwrap();
        b.submit(mk(4, AttnMode::Sparge)).unwrap();
        let batch = b.next_batch().unwrap();
        let ids: Vec<u64> = batch.iter().map(|q| q.req.id).collect();
        assert_eq!(ids, vec![1, 2, 4]);
        let batch2 = b.next_batch().unwrap();
        assert_eq!(batch2[0].req.id, 3);
    }

    #[test]
    fn respects_max_batch() {
        let b = Batcher::new(BatchPolicy {
            max_batch: 2,
            max_wait: Duration::from_millis(1),
            capacity: 16,
            ..Default::default()
        });
        for i in 0..5 {
            b.submit(mk(i, AttnMode::Dense)).unwrap();
        }
        assert_eq!(b.next_batch().unwrap().len(), 2);
        assert_eq!(b.next_batch().unwrap().len(), 2);
        assert_eq!(b.next_batch().unwrap().len(), 1);
    }

    #[test]
    fn minority_mode_is_never_stranded() {
        // A steady sparge stream with one dense request in the middle: the
        // dense request must be served as soon as it is the oldest waiter
        // (second batch), not starved behind later sparge arrivals.
        let b = Batcher::new(BatchPolicy {
            max_batch: 2,
            max_wait: Duration::from_millis(1),
            capacity: 64,
            ..Default::default()
        });
        b.submit(mk(1, AttnMode::Sparge)).unwrap();
        b.submit(mk(2, AttnMode::Dense)).unwrap();
        for id in 3..9 {
            b.submit(mk(id, AttnMode::Sparge)).unwrap();
        }
        let first: Vec<u64> = b.next_batch().unwrap().iter().map(|q| q.req.id).collect();
        assert_eq!(first, vec![1, 3]);
        let second: Vec<u64> = b.next_batch().unwrap().iter().map(|q| q.req.id).collect();
        assert_eq!(second, vec![2], "oldest waiter's mode must define the batch");
        let third: Vec<u64> = b.next_batch().unwrap().iter().map(|q| q.req.id).collect();
        assert_eq!(third, vec![4, 5]);
    }

    #[test]
    fn aged_front_ships_without_fill_wait() {
        let b = Batcher::new(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_secs(5), // would stall the test if waited
            capacity: 16,
            max_age: Duration::from_millis(50),
        });
        b.submit(mk_aged(1, AttnMode::Dense, Duration::from_millis(200))).unwrap();
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() < Duration::from_secs(1), "aged request waited for fill");
    }

    #[test]
    fn poll_is_nonblocking_and_fifo_across_modes() {
        let b = Batcher::new(BatchPolicy::default());
        assert!(b.poll(4).is_empty());
        b.submit(mk(1, AttnMode::Sparge)).unwrap();
        b.submit(mk(2, AttnMode::Dense)).unwrap();
        b.submit(mk(3, AttnMode::Sparge)).unwrap();
        let ids: Vec<u64> = b.poll(2).iter().map(|q| q.req.id).collect();
        assert_eq!(ids, vec![1, 2], "poll admits in arrival order, mode-blind");
        assert_eq!(b.depth(), 1);
        assert!(b.poll(0).is_empty());
    }

    #[test]
    fn backpressure_when_full() {
        let b = Batcher::new(BatchPolicy {
            max_batch: 2,
            max_wait: Duration::from_millis(1),
            capacity: 2,
            ..Default::default()
        });
        b.submit(mk(1, AttnMode::Dense)).unwrap();
        b.submit(mk(2, AttnMode::Dense)).unwrap();
        assert!(b.submit(mk(3, AttnMode::Dense)).is_err());
        assert_eq!(b.depth(), 2);
    }

    #[test]
    fn close_unblocks_waiters() {
        let b = Arc::new(Batcher::new(BatchPolicy::default()));
        let b2 = Arc::clone(&b);
        let h = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(20));
        b.close();
        assert!(h.join().unwrap().is_none());
    }

    #[test]
    fn waits_to_fill_batch() {
        let policy = BatchPolicy {
            max_batch: 2,
            max_wait: Duration::from_millis(200),
            capacity: 8,
            ..Default::default()
        };
        let b = Arc::new(Batcher::new(policy));
        let b2 = Arc::clone(&b);
        b.submit(mk(1, AttnMode::Dense)).unwrap();
        let h = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(30));
        b.submit(mk(2, AttnMode::Dense)).unwrap();
        let batch = h.join().unwrap().unwrap();
        assert_eq!(batch.len(), 2);
    }
}
