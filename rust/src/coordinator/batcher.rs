//! Dynamic batcher: a bounded FIFO with condvar wakeups that groups
//! queued generation requests into batches by attention mode, so the
//! engine amortizes compilation/cache warmth across a batch (the
//! vLLM-router-style structure scaled to this runtime).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use super::request::QueuedRequest;

/// Batch-forming policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Max requests per batch.
    pub max_batch: usize,
    /// How long to wait for more requests once one is pending.
    pub max_wait: Duration,
    /// Queue capacity (backpressure: submit fails beyond this).
    pub capacity: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(20), capacity: 1024 }
    }
}

/// Thread-safe batching queue.
pub struct Batcher {
    policy: BatchPolicy,
    state: Mutex<State>,
    cv: Condvar,
}

#[derive(Default)]
struct State {
    queue: VecDeque<QueuedRequest>,
    closed: bool,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Batcher {
        Batcher { policy, state: Mutex::new(State::default()), cv: Condvar::new() }
    }

    /// Enqueue a request. Errors when the queue is full (backpressure) or
    /// closed.
    pub fn submit(&self, req: QueuedRequest) -> Result<(), QueuedRequest> {
        let mut g = self.state.lock().unwrap();
        if g.closed || g.queue.len() >= self.policy.capacity {
            return Err(req);
        }
        g.queue.push_back(req);
        self.cv.notify_one();
        Ok(())
    }

    /// Current queue depth.
    pub fn depth(&self) -> usize {
        self.state.lock().unwrap().queue.len()
    }

    /// Pull the next batch: blocks until at least one request is queued
    /// (or the batcher closes → `None`), then waits up to `max_wait` for
    /// the batch to fill. All requests in a batch share the same attention
    /// mode (front-runner's mode) so the engine hits one artifact.
    pub fn next_batch(&self) -> Option<Vec<QueuedRequest>> {
        let mut g = self.state.lock().unwrap();
        loop {
            if !g.queue.is_empty() {
                break;
            }
            if g.closed {
                return None;
            }
            g = self.cv.wait(g).unwrap();
        }
        // wait briefly for more arrivals
        let deadline = Instant::now() + self.policy.max_wait;
        while g.queue.len() < self.policy.max_batch && !g.closed {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (ng, timeout) = self.cv.wait_timeout(g, deadline - now).unwrap();
            g = ng;
            if timeout.timed_out() {
                break;
            }
        }
        let mode = g.queue.front().unwrap().req.mode;
        let mut batch = Vec::new();
        let mut rest = VecDeque::new();
        while let Some(item) = g.queue.pop_front() {
            if batch.len() < self.policy.max_batch && item.req.mode == mode {
                batch.push(item);
            } else {
                rest.push_back(item);
            }
        }
        g.queue = rest;
        Some(batch)
    }

    /// Close the queue; `next_batch` drains then returns `None`.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{AttnMode, GenerateRequest};
    use std::sync::mpsc;
    use std::sync::Arc;

    fn mk(id: u64, mode: AttnMode) -> QueuedRequest {
        let (tx, _rx) = mpsc::channel();
        QueuedRequest {
            req: GenerateRequest { id, prompt: vec![b'a'], max_new_tokens: 1, mode },
            arrived: Instant::now(),
            respond: tx,
        }
    }

    #[test]
    fn batches_same_mode_together() {
        let b = Batcher::new(BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1), capacity: 16 });
        b.submit(mk(1, AttnMode::Sparge)).unwrap();
        b.submit(mk(2, AttnMode::Sparge)).unwrap();
        b.submit(mk(3, AttnMode::Dense)).unwrap();
        b.submit(mk(4, AttnMode::Sparge)).unwrap();
        let batch = b.next_batch().unwrap();
        let ids: Vec<u64> = batch.iter().map(|q| q.req.id).collect();
        assert_eq!(ids, vec![1, 2, 4]);
        let batch2 = b.next_batch().unwrap();
        assert_eq!(batch2[0].req.id, 3);
    }

    #[test]
    fn respects_max_batch() {
        let b = Batcher::new(BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(1), capacity: 16 });
        for i in 0..5 {
            b.submit(mk(i, AttnMode::Dense)).unwrap();
        }
        assert_eq!(b.next_batch().unwrap().len(), 2);
        assert_eq!(b.next_batch().unwrap().len(), 2);
        assert_eq!(b.next_batch().unwrap().len(), 1);
    }

    #[test]
    fn backpressure_when_full() {
        let b = Batcher::new(BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(1), capacity: 2 });
        b.submit(mk(1, AttnMode::Dense)).unwrap();
        b.submit(mk(2, AttnMode::Dense)).unwrap();
        assert!(b.submit(mk(3, AttnMode::Dense)).is_err());
        assert_eq!(b.depth(), 2);
    }

    #[test]
    fn close_unblocks_waiters() {
        let b = Arc::new(Batcher::new(BatchPolicy::default()));
        let b2 = Arc::clone(&b);
        let h = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(20));
        b.close();
        assert!(h.join().unwrap().is_none());
    }

    #[test]
    fn waits_to_fill_batch() {
        let policy = BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(200), capacity: 8 };
        let b = Arc::new(Batcher::new(policy));
        let b2 = Arc::clone(&b);
        b.submit(mk(1, AttnMode::Dense)).unwrap();
        let h = std::thread::spawn(move || b2.next_batch());
        std::thread::sleep(Duration::from_millis(30));
        b.submit(mk(2, AttnMode::Dense)).unwrap();
        let batch = h.join().unwrap().unwrap();
        assert_eq!(batch.len(), 2);
    }
}
