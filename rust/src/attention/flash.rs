//! Dense blockwise FlashAttention (online softmax) in f32 — deprecated
//! free-function shims over the [`AttnEngine`] composition (dense policy ×
//! [`super::pipeline::F32Kernel`] × chosen execution). New code should
//! build an engine once and reuse it; see the migration table in
//! [`crate::attention`].

use crate::tensor::Tensor;

use super::engine::{AttnEngine, Execution};
use super::types::{AttnConfig, SkipStats};

/// Dense blockwise FlashAttention over a single head. Numerically matches
/// `attention_naive` to fp32 rounding.
#[deprecated(note = "build an AttnEngine::dense(cfg) once and call .attention(q, k, v)")]
pub fn attention_flash(q: &Tensor, k: &Tensor, v: &Tensor, cfg: &AttnConfig) -> Tensor {
    AttnEngine::dense(*cfg).attention(q, k, v).out
}

/// Dense flash that also reports the block-op counters (all executed).
#[deprecated(note = "build an AttnEngine::dense(cfg) once and call .attention(q, k, v)")]
pub fn attention_flash_stats(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    cfg: &AttnConfig,
) -> (Tensor, SkipStats) {
    let r = AttnEngine::dense(*cfg).attention(q, k, v);
    (r.out, r.stats)
}

/// Dense flash with query-block rows partitioned across `threads` workers.
/// Output and stats are bitwise identical for every thread count.
#[deprecated(note = "use AttnEngine::builder().execution(Execution::Threads(n) or ::Pool(n))")]
pub fn attention_flash_stats_threads(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    cfg: &AttnConfig,
    threads: usize,
) -> (Tensor, SkipStats) {
    let engine = AttnEngine::builder().config(*cfg).execution(Execution::Threads(threads)).build();
    let r = engine.attention(q, k, v);
    (r.out, r.stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::dense::attention_naive;
    use crate::util::prop::{assert_allclose, Cases};

    fn dense(q: &Tensor, k: &Tensor, v: &Tensor, cfg: &AttnConfig) -> (Tensor, SkipStats) {
        let r = AttnEngine::dense(*cfg).attention(q, k, v);
        (r.out, r.stats)
    }

    #[test]
    fn flash_matches_naive_noncausal() {
        Cases::standard(501).check(|rng| {
            let n = rng.range(1, 70);
            let d = [4, 8, 16][rng.range(0, 3)];
            let cfg = AttnConfig {
                bq: rng.range(1, 20),
                bk: rng.range(1, 20),
                causal: false,
                scale: None,
                cw: rng.range(1, 5),
                row_offset: 0,
            };
            let q = Tensor::randn(&[n, d], rng);
            let k = Tensor::randn(&[n, d], rng);
            let v = Tensor::randn(&[n, d], rng);
            let (fast, _) = dense(&q, &k, &v, &cfg);
            let slow = attention_naive(&q, &k, &v, &cfg);
            assert_allclose(fast.data(), slow.data(), 1e-4, 1e-3, "flash-vs-naive")
        });
    }

    #[test]
    fn flash_matches_naive_causal() {
        Cases::standard(502).check(|rng| {
            let n = rng.range(1, 70);
            let d = 8;
            let cfg = AttnConfig {
                bq: rng.range(1, 20),
                bk: rng.range(1, 20),
                causal: true,
                scale: None,
                cw: 2,
                row_offset: 0,
            };
            let q = Tensor::randn(&[n, d], rng);
            let k = Tensor::randn(&[n, d], rng);
            let v = Tensor::randn(&[n, d], rng);
            let (fast, _) = dense(&q, &k, &v, &cfg);
            let slow = attention_naive(&q, &k, &v, &cfg);
            assert_allclose(fast.data(), slow.data(), 1e-4, 1e-3, "flash-causal")
        });
    }

    #[test]
    fn cross_attention_rectangular() {
        let mut rng = crate::util::rng::Pcg::seeded(9);
        let (nq, nk, d) = (33, 57, 8);
        let q = Tensor::randn(&[nq, d], &mut rng);
        let k = Tensor::randn(&[nk, d], &mut rng);
        let v = Tensor::randn(&[nk, d], &mut rng);
        let cfg = AttnConfig { bq: 16, bk: 16, ..Default::default() };
        let (fast, _) = dense(&q, &k, &v, &cfg);
        let slow = attention_naive(&q, &k, &v, &cfg);
        assert_allclose(fast.data(), slow.data(), 1e-4, 1e-3, "rect").unwrap();
    }

    #[test]
    fn dense_stats_count_all_blocks() {
        let mut rng = crate::util::rng::Pcg::seeded(10);
        let (n, d) = (64, 8);
        let q = Tensor::randn(&[n, d], &mut rng);
        let k = Tensor::randn(&[n, d], &mut rng);
        let v = Tensor::randn(&[n, d], &mut rng);
        let cfg = AttnConfig { bq: 16, bk: 16, causal: false, scale: None, cw: 2, row_offset: 0 };
        let (_, stats) = dense(&q, &k, &v, &cfg);
        assert_eq!(stats.qk_total, 16);
        assert_eq!(stats.pv_total, 16);
        assert_eq!(stats.qk_skipped, 0);
        assert_eq!(stats.sparsity(), 0.0);
    }

    #[test]
    fn causal_stats_skip_upper_triangle() {
        let mut rng = crate::util::rng::Pcg::seeded(11);
        let (n, d) = (64, 8);
        let q = Tensor::randn(&[n, d], &mut rng);
        let k = Tensor::randn(&[n, d], &mut rng);
        let v = Tensor::randn(&[n, d], &mut rng);
        let cfg = AttnConfig { bq: 16, bk: 16, causal: true, scale: None, cw: 2, row_offset: 0 };
        let (_, stats) = dense(&q, &k, &v, &cfg);
        // 4 q-blocks; block row i visits i+1 k-blocks => 1+2+3+4 = 10
        assert_eq!(stats.qk_total, 10);
    }

    #[test]
    fn deprecated_shims_match_engine() {
        // the shims stay bitwise-faithful while call sites migrate
        let mut rng = crate::util::rng::Pcg::seeded(16);
        let (n, d) = (200, 16);
        let q = Tensor::randn(&[n, d], &mut rng);
        let k = Tensor::randn(&[n, d], &mut rng);
        let v = Tensor::randn(&[n, d], &mut rng);
        let cfg = AttnConfig { bq: 32, bk: 16, causal: true, scale: None, cw: 2, row_offset: 0 };
        let (o, s) = dense(&q, &k, &v, &cfg);
        #[allow(deprecated)]
        {
            assert_eq!(attention_flash(&q, &k, &v, &cfg), o);
            let (o1, s1) = attention_flash_stats(&q, &k, &v, &cfg);
            let (o8, s8) = attention_flash_stats_threads(&q, &k, &v, &cfg, 8);
            assert_eq!(o1, o);
            assert_eq!(s1, s);
            assert_eq!(o8, o);
            assert_eq!(s8, s);
        }
    }
}
