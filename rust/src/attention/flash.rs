//! Blockwise FlashAttention (online softmax) in f32 — the dense tiled
//! engine (§3.1) and the `FlashTile` accumulator shared with the sparse
//! SpargeAttn kernel in `crate::sparge::kernel`.

use crate::tensor::{matmul, Tensor};

use super::types::{AttnConfig, SkipStats};

/// Per-query-tile online-softmax state: running row maxima `m`, partition
/// sums `l`, and unnormalized output `O` (Eq. 1 of the paper).
pub struct FlashTile {
    pub rows: usize,
    pub d: usize,
    pub m: Vec<f32>,
    pub l: Vec<f32>,
    pub o: Vec<f32>,
    /// Scratch for P̃ (rows × current bk).
    p: Vec<f32>,
}

impl FlashTile {
    pub fn new(rows: usize, d: usize, max_bk: usize) -> FlashTile {
        FlashTile {
            rows,
            d,
            m: vec![f32::NEG_INFINITY; rows],
            l: vec![0.0; rows],
            o: vec![0.0; rows * d],
            p: vec![0.0; rows * max_bk],
        }
    }

    /// Ingest one score block `s` (rows × bk, already scaled and causal-
    /// masked). `v` is the (bk × d) value block. When `lambda` is set, the
    /// tile is split into `cw` row groups and a group's P̃V product is
    /// skipped when `max(m_local − m_new) < λ` over the group (§3.4);
    /// skipped groups are counted into `stats.pv_skipped_groups`.
    pub fn ingest(
        &mut self,
        s: &[f32],
        bk: usize,
        v: &[f32],
        lambda: Option<f32>,
        cw: usize,
        stats: &mut SkipStats,
    ) {
        debug_assert_eq!(s.len(), self.rows * bk);
        debug_assert_eq!(v.len(), bk * self.d);
        let rows = self.rows;
        let d = self.d;

        // Per-row: local max, new max, rescale o/l, exponentiate into p.
        let mut m_local = vec![f32::NEG_INFINITY; rows];
        for i in 0..rows {
            let srow = &s[i * bk..(i + 1) * bk];
            let ml = srow.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            m_local[i] = ml;
            let m_new = self.m[i].max(ml);
            if m_new == f32::NEG_INFINITY {
                // fully-masked so far; nothing to accumulate
                for pv in &mut self.p[i * bk..(i + 1) * bk] {
                    *pv = 0.0;
                }
                continue;
            }
            let factor = if self.m[i] == f32::NEG_INFINITY { 0.0 } else { (self.m[i] - m_new).exp() };
            if factor != 1.0 {
                self.l[i] *= factor;
                for ov in &mut self.o[i * d..(i + 1) * d] {
                    *ov *= factor;
                }
            }
            self.m[i] = m_new;
            let prow = &mut self.p[i * bk..(i + 1) * bk];
            let mut lsum = 0f32;
            for (pv, &sv) in prow.iter_mut().zip(srow) {
                let e = if sv == f32::NEG_INFINITY { 0.0 } else { (sv - m_new).exp() };
                *pv = e;
                lsum += e;
            }
            self.l[i] += lsum;
        }

        // P̃V per row group, with optional λ skipping.
        let cw = cw.max(1).min(rows);
        let group = rows.div_ceil(cw);
        let mut g0 = 0;
        while g0 < rows {
            let g1 = (g0 + group).min(rows);
            let skip = match lambda {
                Some(lam) => {
                    let worst = (g0..g1)
                        .map(|i| m_local[i] - self.m[i])
                        .fold(f32::NEG_INFINITY, f32::max);
                    worst < lam
                }
                None => false,
            };
            if skip {
                stats.pv_skipped_groups += 1;
            } else {
                matmul::matmul_nn_acc(
                    &self.p[g0 * bk..g1 * bk],
                    v,
                    &mut self.o[g0 * d..g1 * d],
                    g1 - g0,
                    d,
                    bk,
                    true,
                );
            }
            g0 = g1;
        }
    }

    /// Normalize and return the output rows (rows × d).
    pub fn finalize(mut self) -> Vec<f32> {
        for i in 0..self.rows {
            let l = self.l[i];
            let inv = if l > 0.0 { 1.0 / l } else { 0.0 };
            for ov in &mut self.o[i * self.d..(i + 1) * self.d] {
                *ov *= inv;
            }
        }
        self.o
    }
}

/// Compute a scaled, causal-masked score block S_ij = Q_i K_jᵀ·scale.
///
/// `q0`/`k0` are the global row offsets of the blocks (for causal masking).
pub fn score_block(
    q: &Tensor,
    k: &Tensor,
    q0: usize,
    q1: usize,
    k0: usize,
    k1: usize,
    scale: f32,
    causal: bool,
    out: &mut [f32],
) {
    let d = q.dim(1);
    let (bq, bk) = (q1 - q0, k1 - k0);
    debug_assert!(out.len() >= bq * bk);
    matmul::matmul_nt_into(
        &q.data()[q0 * d..q1 * d],
        &k.data()[k0 * d..k1 * d],
        &mut out[..bq * bk],
        bq,
        bk,
        d,
    );
    for s in &mut out[..bq * bk] {
        *s *= scale;
    }
    if causal {
        for i in 0..bq {
            let gi = q0 + i;
            for j in 0..bk {
                if k0 + j > gi {
                    out[i * bk + j] = f32::NEG_INFINITY;
                }
            }
        }
    }
}

/// Dense blockwise FlashAttention over a single head. Numerically matches
/// `attention_naive` to fp32 rounding.
pub fn attention_flash(q: &Tensor, k: &Tensor, v: &Tensor, cfg: &AttnConfig) -> Tensor {
    let (out, _) = attention_flash_stats(q, k, v, cfg);
    out
}

/// Dense flash that also reports the block-op counters (all executed).
pub fn attention_flash_stats(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    cfg: &AttnConfig,
) -> (Tensor, SkipStats) {
    assert_eq!(q.dim(1), k.dim(1));
    assert_eq!(k.dim(0), v.dim(0));
    let n = q.dim(0);
    let nk = k.dim(0);
    let d = q.dim(1);
    let scale = cfg.scale_for(d);
    let mut out = Tensor::zeros(&[n, v.dim(1)]);
    let mut stats = SkipStats { cw: cfg.cw, ..Default::default() };
    let mut sbuf = vec![0f32; cfg.bq * cfg.bk];

    let mut q0 = 0;
    while q0 < n {
        let q1 = (q0 + cfg.bq).min(n);
        let mut tile = FlashTile::new(q1 - q0, v.dim(1), cfg.bk);
        let mut k0 = 0;
        while k0 < nk {
            let k1 = (k0 + cfg.bk).min(nk);
            // causal: skip blocks strictly above the diagonal entirely;
            // they are not part of "full attention required".
            if cfg.causal && k0 > q1 - 1 {
                break;
            }
            stats.qk_total += 1;
            stats.pv_total += 1;
            score_block(q, k, q0, q1, k0, k1, scale, cfg.causal, &mut sbuf);
            tile.ingest(&sbuf[..(q1 - q0) * (k1 - k0)], k1 - k0, &v.data()[k0 * v.dim(1)..k1 * v.dim(1)], None, cfg.cw, &mut stats);
            k0 = k1;
        }
        let rows = tile.finalize();
        out.data_mut()[q0 * v.dim(1)..q1 * v.dim(1)].copy_from_slice(&rows);
        q0 = q1;
    }
    (out, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::dense::attention_naive;
    use crate::util::prop::{assert_allclose, Cases};

    #[test]
    fn flash_matches_naive_noncausal() {
        Cases::standard(501).check(|rng| {
            let n = rng.range(1, 70);
            let d = [4, 8, 16][rng.range(0, 3)];
            let cfg = AttnConfig { bq: rng.range(1, 20), bk: rng.range(1, 20), causal: false, scale: None, cw: rng.range(1, 5) };
            let q = Tensor::randn(&[n, d], rng);
            let k = Tensor::randn(&[n, d], rng);
            let v = Tensor::randn(&[n, d], rng);
            let fast = attention_flash(&q, &k, &v, &cfg);
            let slow = attention_naive(&q, &k, &v, &cfg);
            assert_allclose(fast.data(), slow.data(), 1e-4, 1e-3, "flash-vs-naive")
        });
    }

    #[test]
    fn flash_matches_naive_causal() {
        Cases::standard(502).check(|rng| {
            let n = rng.range(1, 70);
            let d = 8;
            let cfg = AttnConfig { bq: rng.range(1, 20), bk: rng.range(1, 20), causal: true, scale: None, cw: 2 };
            let q = Tensor::randn(&[n, d], rng);
            let k = Tensor::randn(&[n, d], rng);
            let v = Tensor::randn(&[n, d], rng);
            let fast = attention_flash(&q, &k, &v, &cfg);
            let slow = attention_naive(&q, &k, &v, &cfg);
            assert_allclose(fast.data(), slow.data(), 1e-4, 1e-3, "flash-causal")
        });
    }

    #[test]
    fn cross_attention_rectangular() {
        let mut rng = crate::util::rng::Pcg::seeded(9);
        let (nq, nk, d) = (33, 57, 8);
        let q = Tensor::randn(&[nq, d], &mut rng);
        let k = Tensor::randn(&[nk, d], &mut rng);
        let v = Tensor::randn(&[nk, d], &mut rng);
        let cfg = AttnConfig { bq: 16, bk: 16, ..Default::default() };
        let fast = attention_flash(&q, &k, &v, &cfg);
        let slow = attention_naive(&q, &k, &v, &cfg);
        assert_allclose(fast.data(), slow.data(), 1e-4, 1e-3, "rect").unwrap();
    }

    #[test]
    fn dense_stats_count_all_blocks() {
        let mut rng = crate::util::rng::Pcg::seeded(10);
        let (n, d) = (64, 8);
        let q = Tensor::randn(&[n, d], &mut rng);
        let k = Tensor::randn(&[n, d], &mut rng);
        let v = Tensor::randn(&[n, d], &mut rng);
        let cfg = AttnConfig { bq: 16, bk: 16, causal: false, scale: None, cw: 2 };
        let (_, stats) = attention_flash_stats(&q, &k, &v, &cfg);
        assert_eq!(stats.qk_total, 16);
        assert_eq!(stats.pv_total, 16);
        assert_eq!(stats.qk_skipped, 0);
        assert_eq!(stats.sparsity(), 0.0);
    }

    #[test]
    fn causal_stats_skip_upper_triangle() {
        let mut rng = crate::util::rng::Pcg::seeded(11);
        let (n, d) = (64, 8);
        let q = Tensor::randn(&[n, d], &mut rng);
        let k = Tensor::randn(&[n, d], &mut rng);
        let v = Tensor::randn(&[n, d], &mut rng);
        let cfg = AttnConfig { bq: 16, bk: 16, causal: true, scale: None, cw: 2 };
        let (_, stats) = attention_flash_stats(&q, &k, &v, &cfg);
        // 4 q-blocks; block row i visits i+1 k-blocks => 1+2+3+4 = 10
        assert_eq!(stats.qk_total, 10);
    }

    #[test]
    fn lambda_zero_threshold_never_fires_on_first_block() {
        // With one block, m_local == m_new so the λ test (strict <) never
        // triggers for λ<=0; output must equal dense.
        let mut rng = crate::util::rng::Pcg::seeded(12);
        let (n, d) = (8, 4);
        let q = Tensor::randn(&[n, d], &mut rng);
        let k = Tensor::randn(&[n, d], &mut rng);
        let v = Tensor::randn(&[n, d], &mut rng);
        let mut tile = FlashTile::new(n, d, n);
        let mut s = vec![0f32; n * n];
        score_block(&q, &k, 0, n, 0, n, 0.5, false, &mut s);
        let mut stats = SkipStats::default();
        tile.ingest(&s, n, v.data(), Some(-0.1), 2, &mut stats);
        assert_eq!(stats.pv_skipped_groups, 0);
    }
}
