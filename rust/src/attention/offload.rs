//! Tiered offload backends for checkpointed KV swap-out: where a
//! preempted session's frame payload lives while its frames serve
//! someone else.
//!
//! [`super::paged::PagedAttnSession::evict`] spills a session's frame
//! contents into a session-owned buffer. This module generalizes that
//! buffer into a seam: [`FrameCheckpoint`] is the spilled payload (K/V
//! rows, the pooled stage-1 sums/sims, and — under INT8 — the per-frame
//! quantized payload bytes), and an [`OffloadTier`] is anywhere such a
//! payload can park:
//!
//! - [`MemTier`] — the in-memory tier the old private `Spill` buffer
//!   grew into: checkpoints move in and out by pointer swap, no copy,
//!   no serialization, cannot fail.
//! - [`DiskTier`] — one file per checkpoint under a caller-chosen
//!   directory, serialized with a trailing FNV-1a 64 checksum over
//!   every preceding byte. A flipped bit, a truncated file, or a stale
//!   format surfaces as [`OffloadError::Corrupt`] — **a value, never a
//!   panic** — so the serving loop can quarantine the one stream whose
//!   checkpoint rotted and keep running.
//!
//! ## Contracts
//!
//! **Byte-identical round-trips.** `store` then `load` returns the
//! exact payload bits for every tier: f32 sections compare equal as
//! bits (NaN payloads included) and INT8 payload bytes are bit-for-bit
//! — the same spill/re-page-in contract the paged eviction tier pins in
//! `tests/paged_kv.rs`, now holding across a serialization boundary
//! (`tests/offload_tier.rs` sweeps random geometries × precisions
//! through both tiers).
//!
//! **Corruption degrades, never detonates.** Every failure mode of a
//! tier — missing key, IO error, checksum mismatch, malformed section
//! lengths — is an [`OffloadError`]. This file is covered by
//! sparge-lint's `serving-no-panic` rule: the serving loop calls into
//! it on the preemption path and must keep degrading per-request.

use std::path::PathBuf;

/// Why a tier could not produce (or durably take) a checkpoint.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OffloadError {
    /// No payload is stored under the requested key.
    Missing,
    /// The payload failed verification (checksum, magic, or section
    /// geometry) — treat the stream as lost and quarantine it.
    Corrupt,
    /// The backing store failed (disk IO). On `store` the payload is
    /// still intact in the caller's checkpoint.
    Io,
}

impl OffloadError {
    pub fn name(&self) -> &'static str {
        match self {
            OffloadError::Missing => "missing",
            OffloadError::Corrupt => "corrupt",
            OffloadError::Io => "io",
        }
    }
}

/// The spilled payload of one paged session: per-frame K/V rows, pooled
/// stage-1 state, and (INT8 pools) the per-frame quantized payload —
/// exactly the bytes a re-page-in needs to restore the session
/// bit-for-bit. Buffers persist across checkpoint cycles (high-water
/// sized), so refilling one allocates nothing once warm.
#[derive(Clone, Debug, Default)]
pub struct FrameCheckpoint {
    /// K head dim the payload was captured with.
    pub d: usize,
    /// V dim the payload was captured with.
    pub dv: usize,
    /// K rows, concatenated per frame (`sum(prow) × d`).
    pub k: Vec<f32>,
    /// V rows, concatenated per frame (`sum(prow) × dv`).
    pub v: Vec<f32>,
    /// Pooled column sums, one `d`-vector per frame.
    pub psum: Vec<f32>,
    /// Rows held per frame.
    pub prow: Vec<usize>,
    /// Per-frame self-similarity.
    pub sim: Vec<f32>,
    /// Per-frame INT8 dequant scales (empty for f32-only pools).
    pub qscale: Vec<f32>,
    /// INT8 payload bytes, concatenated per frame (`sum(prow) × d`).
    pub qdata: Vec<i8>,
}

impl FrameCheckpoint {
    /// Frames the checkpoint spans.
    pub fn frames(&self) -> usize {
        self.prow.len()
    }

    /// Total K/V rows the checkpoint spans.
    pub fn rows(&self) -> usize {
        self.prow.iter().sum()
    }

    /// Whether the checkpoint holds no payload.
    pub fn is_empty(&self) -> bool {
        self.prow.is_empty()
    }

    /// Empty every section, retaining capacity (arena idiom).
    pub fn clear(&mut self) {
        self.k.clear();
        self.v.clear();
        self.psum.clear();
        self.prow.clear();
        self.sim.clear();
        self.qscale.clear();
        self.qdata.clear();
    }

    /// Internal-geometry check: every section length must agree with
    /// the per-frame row counts (frames hold 1..=`bk` rows). A loaded
    /// checkpoint that fails this must be treated as corrupt — indexing
    /// it would walk off a section.
    pub fn consistent(&self, bk: usize) -> bool {
        let rows = self.rows();
        let frames = self.prow.len();
        self.prow.iter().all(|&r| r >= 1 && r <= bk)
            && self.sim.len() == frames
            && self.k.len() == rows.saturating_mul(self.d)
            && self.v.len() == rows.saturating_mul(self.dv)
            && self.psum.len() == frames.saturating_mul(self.d)
            && (self.qscale.is_empty()
                || (self.qscale.len() == frames && self.qdata.len() == rows.saturating_mul(self.d)))
            && (!self.qscale.is_empty() || self.qdata.is_empty())
    }
}

/// Somewhere a session's frame payload can park while its frames serve
/// other streams. Implementations must round-trip byte-identically and
/// report every failure as a value (see the module docs).
pub trait OffloadTier {
    /// Take `ckpt`'s payload under `key`, replacing any previous
    /// payload stored there. On success the checkpoint is emptied
    /// (capacity retained); on failure it is left untouched, so the
    /// caller still holds the payload locally.
    fn store(&mut self, key: u64, ckpt: &mut FrameCheckpoint) -> Result<(), OffloadError>;

    /// Move the payload stored under `key` back into `into` (replacing
    /// its contents) and drop it from the tier. Corruption and IO
    /// failures come back as errors — the tier never panics on bad
    /// bytes.
    fn load(&mut self, key: u64, into: &mut FrameCheckpoint) -> Result<(), OffloadError>;

    /// Drop any payload stored under `key` without loading it (session
    /// retirement). Unknown keys are a no-op.
    fn discard(&mut self, key: u64);

    /// Checkpoints currently stored.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// The in-memory tier: the old session-private `Spill` buffer,
/// generalized to a keyed store. Checkpoints move by pointer swap —
/// store/load never copy payload bytes and never fail.
#[derive(Default)]
pub struct MemTier {
    slots: Vec<(u64, FrameCheckpoint)>,
}

impl MemTier {
    pub fn new() -> MemTier {
        MemTier::default()
    }
}

impl OffloadTier for MemTier {
    fn store(&mut self, key: u64, ckpt: &mut FrameCheckpoint) -> Result<(), OffloadError> {
        if let Some(slot) = self.slots.iter_mut().find(|(k, _)| *k == key) {
            std::mem::swap(&mut slot.1, ckpt);
            ckpt.clear();
        } else {
            self.slots.push((key, std::mem::take(ckpt)));
        }
        Ok(())
    }

    fn load(&mut self, key: u64, into: &mut FrameCheckpoint) -> Result<(), OffloadError> {
        let Some(i) = self.slots.iter().position(|(k, _)| *k == key) else {
            return Err(OffloadError::Missing);
        };
        let (_, mut ckpt) = self.slots.swap_remove(i);
        std::mem::swap(into, &mut ckpt);
        Ok(())
    }

    fn discard(&mut self, key: u64) {
        if let Some(i) = self.slots.iter().position(|(k, _)| *k == key) {
            self.slots.swap_remove(i);
        }
    }

    fn len(&self) -> usize {
        self.slots.len()
    }
}

/// Header magic for the on-disk checkpoint format ("SPRGOFL1").
const MAGIC: u64 = 0x5350_5247_4F46_4C31;

/// FNV-1a 64 over raw bytes — the same hash family as
/// [`super::paged::prefix_hash`], here guarding the serialized payload.
fn fnv1a(bytes: &[u8]) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    bytes.iter().fold(OFFSET, |h, &b| (h ^ b as u64).wrapping_mul(PRIME))
}

/// The disk tier: one checksummed file per checkpoint under a
/// caller-chosen directory. Every section is little-endian; the
/// trailing u64 is the FNV-1a of every preceding byte, verified before
/// a single section is parsed. Files are removed on load/discard; any
/// leftovers are swept on drop (best-effort).
pub struct DiskTier {
    dir: PathBuf,
    keys: Vec<u64>,
    /// Reusable serialization buffer (high-water sized).
    buf: Vec<u8>,
}

impl DiskTier {
    /// Open a tier rooted at `dir`, creating the directory if needed.
    pub fn new(dir: impl Into<PathBuf>) -> Result<DiskTier, OffloadError> {
        let dir = dir.into();
        if std::fs::create_dir_all(&dir).is_err() {
            return Err(OffloadError::Io);
        }
        Ok(DiskTier { dir, keys: Vec::new(), buf: Vec::new() })
    }

    /// A tier under the OS temp directory, namespaced by process id and
    /// `tag` so concurrent test binaries never collide.
    pub fn scratch(tag: &str) -> Result<DiskTier, OffloadError> {
        let dir = std::env::temp_dir().join(format!("sparge-offload-{}-{tag}", std::process::id()));
        DiskTier::new(dir)
    }

    /// Directory this tier stores under.
    pub fn dir(&self) -> &std::path::Path {
        &self.dir
    }

    /// On-disk path of `key`'s checkpoint (exists only while stored).
    pub fn path_for(&self, key: u64) -> PathBuf {
        self.dir.join(format!("{key:016x}.ckpt"))
    }

    fn encode(buf: &mut Vec<u8>, ckpt: &FrameCheckpoint) {
        buf.clear();
        let mut w64 = |buf: &mut Vec<u8>, x: u64| buf.extend_from_slice(&x.to_le_bytes());
        w64(buf, MAGIC);
        w64(buf, ckpt.d as u64);
        w64(buf, ckpt.dv as u64);
        w64(buf, ckpt.prow.len() as u64);
        w64(buf, ckpt.k.len() as u64);
        w64(buf, ckpt.v.len() as u64);
        w64(buf, ckpt.qscale.len() as u64);
        w64(buf, ckpt.qdata.len() as u64);
        for &r in &ckpt.prow {
            buf.extend_from_slice(&(r as u64).to_le_bytes());
        }
        for &x in ckpt.sim.iter().chain(&ckpt.k).chain(&ckpt.v).chain(&ckpt.psum).chain(&ckpt.qscale) {
            buf.extend_from_slice(&x.to_bits().to_le_bytes());
        }
        for &b in &ckpt.qdata {
            buf.push(b as u8);
        }
        let sum = fnv1a(buf);
        buf.extend_from_slice(&sum.to_le_bytes());
    }

    fn decode(bytes: &[u8], into: &mut FrameCheckpoint) -> Result<(), OffloadError> {
        // verify the trailing checksum before trusting a single byte
        if bytes.len() < 8 * 9 {
            return Err(OffloadError::Corrupt);
        }
        let (body, tail) = bytes.split_at(bytes.len() - 8);
        let mut sum = [0u8; 8];
        sum.copy_from_slice(tail);
        if fnv1a(body) != u64::from_le_bytes(sum) {
            return Err(OffloadError::Corrupt);
        }
        let mut off = 0usize;
        let mut r64 = |body: &[u8]| -> Result<u64, OffloadError> {
            let Some(chunk) = body.get(off..off + 8) else {
                return Err(OffloadError::Corrupt);
            };
            let mut b = [0u8; 8];
            b.copy_from_slice(chunk);
            off += 8;
            Ok(u64::from_le_bytes(b))
        };
        if r64(body)? != MAGIC {
            return Err(OffloadError::Corrupt);
        }
        let to_usize = |x: u64| -> Result<usize, OffloadError> {
            usize::try_from(x).map_err(|_| OffloadError::Corrupt)
        };
        let d = to_usize(r64(body)?)?;
        let dv = to_usize(r64(body)?)?;
        let frames = to_usize(r64(body)?)?;
        let klen = to_usize(r64(body)?)?;
        let vlen = to_usize(r64(body)?)?;
        let qslen = to_usize(r64(body)?)?;
        let qdlen = to_usize(r64(body)?)?;
        // total size must match the header exactly: 8 header words, the
        // per-frame u64 rows, the f32 sections, the i8 payload
        let f32s = frames
            .checked_add(klen)
            .and_then(|x| x.checked_add(vlen))
            .and_then(|x| x.checked_add(frames.checked_mul(d)?))
            .and_then(|x| x.checked_add(qslen))
            .ok_or(OffloadError::Corrupt)?;
        let expect = (8usize + frames)
            .checked_mul(8)
            .and_then(|x| x.checked_add(f32s.checked_mul(4)?))
            .and_then(|x| x.checked_add(qdlen))
            .ok_or(OffloadError::Corrupt)?;
        if body.len() != expect {
            return Err(OffloadError::Corrupt);
        }
        into.clear();
        into.d = d;
        into.dv = dv;
        for _ in 0..frames {
            into.prow.push(to_usize(r64(body)?)?);
        }
        let mut rf32 = |out: &mut Vec<f32>, n: usize| -> Result<(), OffloadError> {
            out.reserve(n);
            for _ in 0..n {
                let Some(chunk) = body.get(off..off + 4) else {
                    return Err(OffloadError::Corrupt);
                };
                let mut b = [0u8; 4];
                b.copy_from_slice(chunk);
                off += 4;
                out.push(f32::from_bits(u32::from_le_bytes(b)));
            }
            Ok(())
        };
        // the borrow of `off` moved into r64 ends before rf32 is built,
        // so re-slice sections with explicit offsets instead
        let _ = &mut rf32;
        let mut pos = off;
        let mut take_f32s = |out: &mut Vec<f32>, n: usize| -> Result<(), OffloadError> {
            let Some(sect) = body.get(pos..pos + n * 4) else {
                return Err(OffloadError::Corrupt);
            };
            out.reserve(n);
            for chunk in sect.chunks_exact(4) {
                let mut b = [0u8; 4];
                b.copy_from_slice(chunk);
                out.push(f32::from_bits(u32::from_le_bytes(b)));
            }
            pos += n * 4;
            Ok(())
        };
        // sim | k | v | psum | qscale, then the i8 payload
        let mut sim = std::mem::take(&mut into.sim);
        let mut k = std::mem::take(&mut into.k);
        let mut v = std::mem::take(&mut into.v);
        let mut psum = std::mem::take(&mut into.psum);
        let mut qscale = std::mem::take(&mut into.qscale);
        let r = take_f32s(&mut sim, frames)
            .and_then(|_| take_f32s(&mut k, klen))
            .and_then(|_| take_f32s(&mut v, vlen))
            .and_then(|_| take_f32s(&mut psum, frames * d))
            .and_then(|_| take_f32s(&mut qscale, qslen));
        into.sim = sim;
        into.k = k;
        into.v = v;
        into.psum = psum;
        into.qscale = qscale;
        r?;
        let Some(qsect) = body.get(pos..pos + qdlen) else {
            return Err(OffloadError::Corrupt);
        };
        into.qdata.reserve(qdlen);
        into.qdata.extend(qsect.iter().map(|&b| b as i8));
        Ok(())
    }
}

impl OffloadTier for DiskTier {
    fn store(&mut self, key: u64, ckpt: &mut FrameCheckpoint) -> Result<(), OffloadError> {
        let mut buf = std::mem::take(&mut self.buf);
        Self::encode(&mut buf, ckpt);
        let r = std::fs::write(self.path_for(key), &buf);
        self.buf = buf;
        if r.is_err() {
            return Err(OffloadError::Io);
        }
        if !self.keys.contains(&key) {
            self.keys.push(key);
        }
        ckpt.clear();
        Ok(())
    }

    fn load(&mut self, key: u64, into: &mut FrameCheckpoint) -> Result<(), OffloadError> {
        let path = self.path_for(key);
        let bytes = match std::fs::read(&path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Err(OffloadError::Missing),
            Err(_) => return Err(OffloadError::Io),
        };
        // the payload leaves the tier either way: a corrupt file is not
        // worth a second read, and the key must not look resumable
        let _ = std::fs::remove_file(&path);
        if let Some(i) = self.keys.iter().position(|&k| k == key) {
            self.keys.swap_remove(i);
        }
        Self::decode(&bytes, into)
    }

    fn discard(&mut self, key: u64) {
        let _ = std::fs::remove_file(self.path_for(key));
        if let Some(i) = self.keys.iter().position(|&k| k == key) {
            self.keys.swap_remove(i);
        }
    }

    fn len(&self) -> usize {
        self.keys.len()
    }
}

impl Drop for DiskTier {
    fn drop(&mut self) {
        // best-effort sweep: leftover checkpoints are garbage once the
        // tier is gone; the dir itself goes too if we emptied it
        for &key in &self.keys {
            let _ = std::fs::remove_file(self.path_for(key));
        }
        let _ = std::fs::remove_dir(&self.dir);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(seed: u64, frames: usize, d: usize, dv: usize, quant: bool) -> FrameCheckpoint {
        let mut x = seed | 1;
        let mut next = || {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            (x as f64 / u64::MAX as f64) as f32 - 0.5
        };
        let bk = 8;
        let mut c = FrameCheckpoint { d, dv, ..Default::default() };
        for b in 0..frames {
            let rows = if b + 1 == frames { 1 + (seed as usize % bk) } else { bk };
            c.prow.push(rows);
            c.sim.push(next());
            for _ in 0..rows * d {
                c.k.push(next());
                c.qdata.push((seed as i8).wrapping_add(c.k.len() as i8));
            }
            for _ in 0..rows * dv {
                c.v.push(next());
            }
            for _ in 0..d {
                c.psum.push(next());
            }
            c.qscale.push(next().abs() + 1e-3);
        }
        if !quant {
            c.qscale.clear();
            c.qdata.clear();
        }
        c
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    fn assert_payload_eq(a: &FrameCheckpoint, b: &FrameCheckpoint) {
        assert_eq!(a.d, b.d);
        assert_eq!(a.dv, b.dv);
        assert_eq!(a.prow, b.prow);
        assert_eq!(bits(&a.sim), bits(&b.sim));
        assert_eq!(bits(&a.k), bits(&b.k));
        assert_eq!(bits(&a.v), bits(&b.v));
        assert_eq!(bits(&a.psum), bits(&b.psum));
        assert_eq!(bits(&a.qscale), bits(&b.qscale));
        assert_eq!(a.qdata, b.qdata);
    }

    #[test]
    fn mem_tier_swaps_payloads_byte_identically() {
        let mut tier = MemTier::new();
        let original = sample(11, 3, 8, 8, true);
        let mut ckpt = original.clone();
        tier.store(7, &mut ckpt).unwrap();
        assert!(ckpt.is_empty(), "store must empty the caller's checkpoint");
        assert_eq!(tier.len(), 1);
        let mut back = FrameCheckpoint::default();
        tier.load(7, &mut back).unwrap();
        assert_payload_eq(&back, &original);
        assert!(tier.is_empty());
        assert_eq!(tier.load(7, &mut back), Err(OffloadError::Missing));
    }

    #[test]
    fn disk_tier_round_trips_and_checksums() {
        let mut tier = DiskTier::scratch("unit-roundtrip").unwrap();
        let original = sample(23, 4, 16, 8, true);
        let mut ckpt = original.clone();
        tier.store(42, &mut ckpt).unwrap();
        assert!(ckpt.is_empty());
        assert!(tier.path_for(42).exists());
        let mut back = FrameCheckpoint::default();
        tier.load(42, &mut back).unwrap();
        assert_payload_eq(&back, &original);
        assert!(!tier.path_for(42).exists(), "load consumes the file");
        assert_eq!(tier.load(42, &mut back), Err(OffloadError::Missing));
    }

    #[test]
    fn disk_tier_flipped_byte_is_corrupt_not_panic() {
        let mut tier = DiskTier::scratch("unit-corrupt").unwrap();
        let mut ckpt = sample(5, 2, 8, 8, false);
        tier.store(1, &mut ckpt).unwrap();
        let path = tier.path_for(1);
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        let mut back = FrameCheckpoint::default();
        assert_eq!(tier.load(1, &mut back), Err(OffloadError::Corrupt));
        // truncation is corruption too, not an index panic
        let mut ckpt2 = sample(6, 2, 8, 8, true);
        tier.store(2, &mut ckpt2).unwrap();
        let path2 = tier.path_for(2);
        let bytes2 = std::fs::read(&path2).unwrap();
        std::fs::write(&path2, &bytes2[..bytes2.len() / 3]).unwrap();
        assert_eq!(tier.load(2, &mut back), Err(OffloadError::Corrupt));
    }

    #[test]
    fn checkpoint_consistency_rejects_bad_geometry() {
        let mut c = sample(9, 3, 8, 8, true);
        assert!(c.consistent(8));
        c.prow[0] = 9; // > bk
        assert!(!c.consistent(8));
        let mut c = sample(9, 3, 8, 8, true);
        c.k.pop();
        assert!(!c.consistent(8));
    }
}
