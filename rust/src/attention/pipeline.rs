//! The unified tiled-attention pipeline: **the one q-block × k-block loop
//! in the crate**, with two drivers over it.
//!
//! Every attention engine — dense FlashAttention, SpargeAttn f32, the
//! SageAttention INT8 variant, and every baseline mask policy — is a thin
//! composition over one of the drivers with two pluggable seams:
//!
//! - [`ScoreKernel`]: how a visited score block `S_ij = Q_i K_jᵀ · scale`
//!   is produced (plain f32 matmul vs. INT8 dequant scoring). The kernel
//!   owns whatever precomputed state it needs (e.g. quantized blocks) and
//!   applies its own causal masking, so the driver never touches scores.
//! - [`BlockFilter`]: which blocks are computed at all — the stage-1
//!   `M_g` lookup (§3.2–3.3), the stage-2 online-softmax λ threshold
//!   (§3.4), and the causal-domain bound that keeps upper-triangle blocks
//!   out of both the loop and the [`SkipStats`] totals.
//! - [`KvSource`]: where the drivers read V blocks (and how long the KV
//!   domain is) — a contiguous tensor pair ([`TensorKv`], the monolithic
//!   session cache) or a paged frame table (`attention::paged`, frames
//!   of exactly `b_k` rows recycled through a free list). The drivers
//!   only ever ask for one `b_k`-aligned block at a time, which is
//!   exactly one frame in the paged layout, so both sources hand back
//!   one contiguous slice and the float path is identical either way.
//!
//! ## The two drivers
//!
//! [`run_tiled`] parallelizes over **query-block rows**: each row's
//! [`FlashTile`] is independent and writes a disjoint slice of the
//! output, so the result is **bitwise identical** for every execution
//! mode and worker count (accumulation order within a tile never
//! changes) and per-row [`SkipStats`] are merged in row order. This is
//! the prefill driver: tall calls have plenty of rows to hand out.
//!
//! [`run_tiled_splitkv`] additionally parallelizes along the **KV axis**
//! (Flash-Decoding style): each row's k-block domain is partitioned into
//! contiguous spans of `span_blocks` k-blocks, every (row, span) pair is
//! reduced independently into a partial online-softmax state `(m, l, o)`,
//! and the spans of a row are combined in fixed span order with
//! [`FlashTile::merge`]. This is the decode driver: a 1-row step
//! (`tm = 1`) that would run serially under `run_tiled` becomes `S`
//! parallel reductions over the KV cache.
//!
//! ## Workspaces: the allocation-free hot path
//!
//! Neither driver allocates scratch per call once warm. All per-call
//! buffers — the tile `(m, l, o, p, m_local)` state, the score block,
//! and INT8 staging — live in a [`Workspace`] arena owned by the thread
//! running the reduction: each pool worker owns one for its lifetime
//! (`util::threadpool`), inline callers (a session) own their own, and
//! the `*_into` driver entry points thread it through. Reuse is
//! **bitwise-neutral**: buffers are truncated views re-initialized to
//! exactly the values a fresh allocation would hold, so the float
//! evaluation order never changes. Split-KV callers additionally keep a
//! [`SpanPlan`] across calls: the span work-list plus the partial-state
//! and per-span stats arenas, revalidated in O(1) per decode step and
//! rebuilt only when the KV cache grows into a new `b_k` block.
//!
//! ### The split-KV determinism contract
//!
//! The span count `S = ceil(kblock_end / span_blocks)` is derived from
//! the **cache length** (through [`BlockFilter::kblock_end`]) and the
//! caller's `span_blocks` — **never** from the worker count. Work items
//! are laid out row-major in span order, each is reduced independently,
//! and partial states are merged left-to-right per row, so outputs *and*
//! merged [`SkipStats`] are bitwise-identical across
//! [`Exec::Inline`]/[`Exec::Threads`]/[`Exec::Pool`] and any pool size.
//! **Scheduling order may vary, merge order may not**: the pool hands
//! out indices by chunked self-scheduling (and the submitting thread
//! claims chunks too), so which worker reduces which span — and when —
//! is timing-dependent, but results are collected per index and folded
//! in plan order, which is a pure function of the call's shape. Relative
//! to `run_tiled` the reduction *tree* changes, so outputs are allclose
//! rather than bitwise — except when one span covers the whole row
//! (`span_blocks ≥ kblock_end`), which reproduces `run_tiled` exactly.
//! Stage-1 `keep` lookups are per-block and stage-2 λ decisions are
//! **span-local** (each span thresholds against its own running maximum,
//! which only makes skipping more conservative), so skip accounting
//! still merges exactly: with λ off the summed counters equal the serial
//! driver's; with λ on they are deterministic per span geometry.
//!
//! ### The microkernel determinism contract
//!
//! Every float op under these drivers bottoms out in a
//! [`Backend`](crate::tensor::microkernel::Backend) — a dispatch handle
//! each [`ScoreKernel`] carries ([`ScoreKernel::microkernel`],
//! defaulting to the process-selected backend) and hands to
//! [`FlashTile::ingest`] for the P̃·V accumulate. The per-kernel
//! decision, stated once in [`crate::tensor::microkernel`] and enforced
//! by its property tests: the QKᵀ family
//! (`matmul_nt_into`/`gemv_nt`/`dot`) and the INT8 dot are in the
//! **fixed-order tier** — bitwise-identical on every backend, so all
//! bitwise contracts above (cross-exec, decode≡prefill, split-KV merge)
//! hold unchanged whether the `simd` feature is on or off. The P̃·V
//! accumulate (`matmul_nn_acc`) is in the **oracle (allclose) tier** —
//! backends agree in summation order but may fuse multiply-add rounding,
//! so outputs are allclose (not bitwise) *between* backends; within any
//! one process the backend is fixed per engine, so every in-process
//! bitwise guarantee is unaffected.
//!
//! ## The `row_offset` causal contract
//!
//! Causal masking is computed against **absolute positions**, not tensor
//! rows: `AttnConfig::row_offset` names the absolute position of query
//! row 0, so query row `i` sits at position `row_offset + i` while key
//! rows are always absolute (`k0 + j`). A whole-sequence call uses
//! `row_offset = 0` (the classic lower triangle); a chunked prefill runs
//! each chunk's query rows against the *full* K/V cache with
//! `row_offset = rows already cached`. Both the per-entry mask (inside
//! every [`ScoreKernel`]) and the causal-domain block bound
//! ([`BlockFilter::kblock_end`]) honor the offset, so for f32 (λ off)
//! an offset chunk is bitwise-identical to the same rows of the one-shot
//! causal run — each query row sees exactly the same visible key set,
//! and fully-masked tail entries contribute exact float no-ops. When the
//! chunk boundaries are multiples of `b_q` the query tiles coincide with
//! the one-shot tiling too, so the summed [`SkipStats`] also match
//! exactly (off-boundary chunks re-tile the rows and may visit a
//! different number of masked-out blocks).
//!
//! Extension recipe: a new sparse-attention baseline is a new
//! [`BlockFilter`] impl; a new score path (a different precision, a new
//! dequant scheme) is a new [`ScoreKernel`] impl. Neither requires touching
//! this loop again.

use std::sync::Mutex;

use crate::tensor::microkernel::Backend;
use crate::tensor::Tensor;
use crate::util::threadpool::{self, WorkerPool, Workspace};

use super::types::{AttnConfig, BlockMask, SkipStats};

/// How the drivers distribute work items across workers. All variants
/// produce bitwise-identical outputs and stats: items are independent,
/// results are collected per index, and merges run in index order —
/// scheduling order may vary, merge order may not.
#[derive(Clone, Copy)]
pub enum Exec<'p> {
    /// Serial on the calling thread.
    Inline,
    /// Scoped threads spawned per call (the legacy mode behind the
    /// deprecated `*_threads` free functions).
    Threads(usize),
    /// A persistent [`WorkerPool`] — created once (by `AttnEngine::build`)
    /// and reused, so hot prefill/decode calls pay no spawn cost; each
    /// worker carries a persistent [`Workspace`], so they pay no
    /// allocation cost either.
    Pool(&'p WorkerPool),
}

impl Exec<'_> {
    /// Deterministic map: `f(i)` for i in 0..n, results in index order.
    pub fn map<T: Send>(&self, n: usize, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
        let mut ws = Workspace::default();
        self.map_ws(n, &mut ws, |i, _ws| f(i))
    }

    /// [`Exec::map`] with workspace plumbing: pool workers pass their own
    /// persistent arenas, inline execution (and the participating pool
    /// submitter) passes the caller's `ws`, scoped threads create one per
    /// spawned thread.
    pub fn map_ws<T: Send>(
        &self,
        n: usize,
        ws: &mut Workspace,
        f: impl Fn(usize, &mut Workspace) -> T + Sync,
    ) -> Vec<T> {
        match self {
            Exec::Inline => (0..n).map(|i| f(i, ws)).collect(),
            Exec::Threads(t) => threadpool::parallel_map_ws(n, *t, f),
            Exec::Pool(p) => p.map_ws(n, ws, f),
        }
    }

    /// Workspace-threaded parallel-for without result collection — the
    /// zero-allocation fan-out (callers write results into preallocated
    /// disjoint slots, e.g. a [`SpanPlan`]'s partial-state arena).
    pub fn for_each_ws(&self, n: usize, ws: &mut Workspace, f: impl Fn(usize, &mut Workspace) + Sync) {
        match self {
            Exec::Inline => {
                for i in 0..n {
                    f(i, ws);
                }
            }
            Exec::Threads(t) => threadpool::parallel_for_ws(n, *t, f),
            Exec::Pool(p) => p.run_ws(n, ws, &f),
        }
    }

    /// [`Exec::for_each_ws`] for callers that own a fault domain: a
    /// panicking index is *attributed* instead of re-raised. Returns the
    /// sorted indices whose invocation panicked — empty on a clean run,
    /// and an empty `Vec` never allocates, so the fault-free fan-out
    /// stays zero-alloc. Every index still runs exactly once regardless
    /// of other indices' failures, in every execution mode.
    pub fn try_for_each_ws(
        &self,
        n: usize,
        ws: &mut Workspace,
        f: impl Fn(usize, &mut Workspace) + Sync,
    ) -> Vec<usize> {
        match self {
            Exec::Inline => {
                // sparge-lint: allow(hot-path-no-alloc) — empty Vec;
                // grows only on the fault path (an index panicked)
                let mut bad = Vec::new();
                for i in 0..n {
                    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(i, &mut *ws)));
                    if r.is_err() {
                        bad.push(i);
                    }
                }
                bad
            }
            Exec::Threads(t) => {
                // sparge-lint: allow(hot-path-no-alloc) — empty Vec;
                // grows only on the fault path (an index panicked)
                let bad = Mutex::new(Vec::new());
                threadpool::parallel_for_ws(n, *t, |i, ws| {
                    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(i, &mut *ws)));
                    if r.is_err() {
                        bad.lock().unwrap().push(i);
                    }
                });
                let mut bad = bad.into_inner().unwrap();
                bad.sort_unstable();
                bad
            }
            Exec::Pool(p) => p.run_ws_caught(n, ws, &f),
        }
    }
}

/// Per-query-tile online-softmax state: running row maxima `m`, partition
/// sums `l`, and unnormalized output `O` (Eq. 1 of the paper).
///
/// On the hot path tiles are built over recycled [`Workspace`] buffers
/// ([`FlashTile::new_in`] / [`FlashTile::recycle`]) so no reduction
/// allocates after warmup; [`FlashTile::new`] allocates fresh buffers for
/// one-off callers. Both initialize identically, so reuse is
/// bitwise-neutral.
pub struct FlashTile {
    pub rows: usize,
    pub d: usize,
    pub m: Vec<f32>,
    pub l: Vec<f32>,
    pub o: Vec<f32>,
    /// Scratch for P̃ (rows × current bk).
    p: Vec<f32>,
    /// Scratch for per-row local maxima, reused across ingested blocks.
    m_local: Vec<f32>,
}

/// Truncate-and-refill a recycled buffer to exactly the state a fresh
/// `vec![fill; n]` would hold (the bitwise-neutral reuse contract).
fn grab(buf: &mut Vec<f32>, n: usize, fill: f32) -> Vec<f32> {
    let mut v = std::mem::take(buf);
    v.clear();
    v.resize(n, fill);
    v
}

impl FlashTile {
    pub fn new(rows: usize, d: usize, max_bk: usize) -> FlashTile {
        FlashTile {
            rows,
            d,
            m: vec![f32::NEG_INFINITY; rows],
            l: vec![0.0; rows],
            o: vec![0.0; rows * d],
            p: vec![0.0; rows * max_bk],
            m_local: vec![f32::NEG_INFINITY; rows],
        }
    }

    /// Build a tile over the workspace's recycled buffers — identical
    /// initial state to [`FlashTile::new`], no allocation once the arena
    /// has reached its high-water size. Return the buffers with
    /// [`FlashTile::recycle`] when done.
    pub fn new_in(ws: &mut Workspace, rows: usize, d: usize, max_bk: usize) -> FlashTile {
        FlashTile {
            rows,
            d,
            m: grab(&mut ws.tile_m, rows, f32::NEG_INFINITY),
            l: grab(&mut ws.tile_l, rows, 0.0),
            o: grab(&mut ws.tile_o, rows * d, 0.0),
            p: grab(&mut ws.tile_p, rows * max_bk, 0.0),
            m_local: grab(&mut ws.tile_m_local, rows, f32::NEG_INFINITY),
        }
    }

    /// Hand the tile's buffers back to the workspace for reuse.
    pub fn recycle(self, ws: &mut Workspace) {
        ws.tile_m = self.m;
        ws.tile_l = self.l;
        ws.tile_o = self.o;
        ws.tile_p = self.p;
        ws.tile_m_local = self.m_local;
    }

    /// Ingest one score block `s` (rows × bk, already scaled and causal-
    /// masked). `v` is the (bk × d) value block. When `lambda` is set, the
    /// tile is split into `cw` row groups and a group's P̃V product is
    /// skipped when `max(m_local − m_new) < λ` over the group (§3.4);
    /// each skipped group adds its exact share of the block,
    /// `(group rows)/(tile rows)`, to `stats.pv_skipped_frac`.
    ///
    /// `sparse_p` tells the P̃V matmul whether this block's P̃ can hold
    /// exact zeros (causal −∞ entries): masked blocks keep the
    /// per-element zero-skip (a whole AXPY saved per masked key), dense
    /// blocks drop the branch from the inner loop. The settings are
    /// `==`-identical (see `matmul_nn_acc`).
    ///
    /// `mk` is the microkernel backend running the P̃V accumulate — the
    /// oracle-tier kernel, so within one process (one backend) ingestion
    /// is deterministic, and across backends it is allclose (see
    /// [`crate::tensor::microkernel`]).
    #[allow(clippy::too_many_arguments)]
    pub fn ingest(
        &mut self,
        s: &[f32],
        bk: usize,
        v: &[f32],
        lambda: Option<f32>,
        cw: usize,
        stats: &mut SkipStats,
        sparse_p: bool,
        mk: Backend,
    ) {
        debug_assert_eq!(s.len(), self.rows * bk);
        debug_assert_eq!(v.len(), bk * self.d);
        let rows = self.rows;
        let d = self.d;

        // Per-row: local max, new max, rescale o/l, exponentiate into p.
        // `m_local[i]` is written before any early-out below, so the group
        // pass always sees this block's values.
        for i in 0..rows {
            let srow = &s[i * bk..(i + 1) * bk];
            let ml = srow.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
            self.m_local[i] = ml;
            let m_new = self.m[i].max(ml);
            if m_new == f32::NEG_INFINITY {
                // fully-masked so far; nothing to accumulate
                for pv in &mut self.p[i * bk..(i + 1) * bk] {
                    *pv = 0.0;
                }
                continue;
            }
            let factor = if self.m[i] == f32::NEG_INFINITY { 0.0 } else { (self.m[i] - m_new).exp() };
            if factor != 1.0 {
                self.l[i] *= factor;
                for ov in &mut self.o[i * d..(i + 1) * d] {
                    *ov *= factor;
                }
            }
            self.m[i] = m_new;
            let prow = &mut self.p[i * bk..(i + 1) * bk];
            let mut lsum = 0f32;
            for (pv, &sv) in prow.iter_mut().zip(srow) {
                let e = if sv == f32::NEG_INFINITY { 0.0 } else { (sv - m_new).exp() };
                *pv = e;
                lsum += e;
            }
            self.l[i] += lsum;
        }

        // P̃V per row group, with optional λ skipping.
        let cw = cw.max(1).min(rows);
        let group = rows.div_ceil(cw);
        let mut g0 = 0;
        while g0 < rows {
            let g1 = (g0 + group).min(rows);
            let skip = match lambda {
                Some(lam) => {
                    let worst = (g0..g1)
                        .map(|i| self.m_local[i] - self.m[i])
                        .fold(f32::NEG_INFINITY, f32::max);
                    worst < lam
                }
                None => false,
            };
            if skip {
                stats.pv_skipped_frac += (g1 - g0) as f64 / rows as f64;
            } else {
                mk.matmul_nn_acc(
                    &self.p[g0 * bk..g1 * bk],
                    v,
                    &mut self.o[g0 * d..g1 * d],
                    g1 - g0,
                    d,
                    bk,
                    true,
                    sparse_p,
                );
            }
            g0 = g1;
        }
    }

    /// Merge another tile's partial online-softmax state into this one —
    /// the Flash-Decoding combine. `other` must cover a *disjoint* span
    /// of the same query rows' KV domain:
    ///
    /// ```text
    /// m ← max(m_a, m_b);  l ← l_a·e^{m_a−m} + l_b·e^{m_b−m};
    /// O ← O_a·e^{m_a−m} + O_b·e^{m_b−m}
    /// ```
    ///
    /// The combine is evaluated in a fixed operand order (self = left,
    /// `other` = right), so a left-to-right fold over spans in span order
    /// is bitwise-deterministic regardless of which worker reduced which
    /// span. Rows that saw only masked entries keep `m = −∞, l = 0` and
    /// merge as exact no-ops.
    pub fn merge(&mut self, other: &FlashTile) {
        assert_eq!(self.rows, other.rows, "merging tiles of different row counts");
        assert_eq!(self.d, other.d, "merging tiles of different head dims");
        merge_rows(&mut self.m, &mut self.l, &mut self.o, &other.m, &other.l, &other.o, self.rows, self.d);
    }

    /// Normalize into the caller's output rows (first rows × d of `out`),
    /// without allocating or copying — same float ops (`o · 1/l` per
    /// element, in element order) as [`FlashTile::finalize`].
    pub fn finalize_into(&self, out: &mut [f32]) {
        debug_assert!(out.len() >= self.rows * self.d);
        for i in 0..self.rows {
            let l = self.l[i];
            let inv = if l > 0.0 { 1.0 / l } else { 0.0 };
            for j in 0..self.d {
                out[i * self.d + j] = self.o[i * self.d + j] * inv;
            }
        }
    }

    /// Normalize and return the output rows (rows × d). One-off/test
    /// convenience; the drivers use [`FlashTile::finalize_into`].
    pub fn finalize(self) -> Vec<f32> {
        let mut out = vec![0.0; self.rows * self.d];
        self.finalize_into(&mut out);
        out
    }
}

/// The raw Flash-Decoding combine over `(m, l, o)` row states — exactly
/// [`FlashTile::merge`]'s float ops, shared with the [`SpanPlan`] arena
/// merge so both paths are bitwise-identical.
#[allow(clippy::too_many_arguments)]
fn merge_rows(
    m_a: &mut [f32],
    l_a: &mut [f32],
    o_a: &mut [f32],
    m_b: &[f32],
    l_b: &[f32],
    o_b: &[f32],
    rows: usize,
    d: usize,
) {
    for i in 0..rows {
        let (ma, mb) = (m_a[i], m_b[i]);
        let m_new = ma.max(mb);
        if m_new == f32::NEG_INFINITY {
            continue; // both spans fully masked: stay the exact zero state
        }
        let fa = if ma == f32::NEG_INFINITY { 0.0 } else { (ma - m_new).exp() };
        let fb = if mb == f32::NEG_INFINITY { 0.0 } else { (mb - m_new).exp() };
        m_a[i] = m_new;
        l_a[i] = fa * l_a[i] + fb * l_b[i];
        let (oa, ob) = (&mut o_a[i * d..(i + 1) * d], &o_b[i * d..(i + 1) * d]);
        for (a, &b) in oa.iter_mut().zip(ob) {
            *a = fa * *a + fb * b;
        }
    }
}

/// Compute a scaled, causal-masked score block S_ij = Q_i K_jᵀ·scale.
///
/// `q0`/`k0` are the tensor-row offsets of the blocks; `row_offset` is the
/// absolute position of query row 0 (the offset-aware causal contract:
/// query row `q0 + i` sits at position `row_offset + q0 + i`, key row
/// `k0 + j` at position `k0 + j`, and `S[i][j]` is masked to −∞ when the
/// key position is past the query position). Whole-sequence callers pass
/// `row_offset = 0` and recover the classic lower-triangle mask.
#[allow(clippy::too_many_arguments)]
pub fn score_block(
    q: &Tensor,
    k: &Tensor,
    q0: usize,
    q1: usize,
    k0: usize,
    k1: usize,
    row_offset: usize,
    scale: f32,
    causal: bool,
    out: &mut [f32],
) {
    score_block_with(Backend::select(), q, k, q0, q1, k0, k1, row_offset, scale, causal, out);
}

/// [`score_block`] on an explicit microkernel backend — the QKᵀ matmul
/// is the fixed-order (bitwise) tier, so every backend produces the same
/// bits; the handle only selects how fast they are produced.
#[allow(clippy::too_many_arguments)]
pub fn score_block_with(
    mk: Backend,
    q: &Tensor,
    k: &Tensor,
    q0: usize,
    q1: usize,
    k0: usize,
    k1: usize,
    row_offset: usize,
    scale: f32,
    causal: bool,
    out: &mut [f32],
) {
    let d = q.dim(1);
    score_block_slices(
        mk,
        &q.data()[q0 * d..q1 * d],
        &k.data()[k0 * d..k1 * d],
        q1 - q0,
        k1 - k0,
        d,
        row_offset + q0,
        k0,
        scale,
        causal,
        out,
    );
}

/// The slice-level core of [`score_block_with`]: score `bq` query rows
/// (`qs`, row-major, head dim `d`) against `bk` key rows (`ks`), masking
/// entry `(i, j)` when key position `k_abs0 + j` exceeds query position
/// `q_abs0 + i`. The contiguous path passes tensor sub-slices with
/// `q_abs0 = row_offset + q0, k_abs0 = k0`; paged kernels pass one
/// frame's K rows with the frame's absolute first row — the float ops
/// and their order are byte-for-byte the same, so paged scoring is
/// bitwise-identical to monolithic scoring by construction.
#[allow(clippy::too_many_arguments)]
pub fn score_block_slices(
    mk: Backend,
    qs: &[f32],
    ks: &[f32],
    bq: usize,
    bk: usize,
    d: usize,
    q_abs0: usize,
    k_abs0: usize,
    scale: f32,
    causal: bool,
    out: &mut [f32],
) {
    debug_assert_eq!(qs.len(), bq * d);
    debug_assert_eq!(ks.len(), bk * d);
    debug_assert!(out.len() >= bq * bk);
    mk.matmul_nt_into(qs, ks, &mut out[..bq * bk], bq, bk, d);
    for s in &mut out[..bq * bk] {
        *s *= scale;
    }
    if causal {
        for i in 0..bq {
            let gi = q_abs0 + i;
            for j in 0..bk {
                if k_abs0 + j > gi {
                    out[i * bk + j] = f32::NEG_INFINITY;
                }
            }
        }
    }
}

/// Where the drivers read V blocks from, and how long the KV domain is.
///
/// The drivers never touch K directly (the [`ScoreKernel`] owns its K
/// state) and only ever request V one `b_k`-aligned block at a time, so
/// a source needs to hand back exactly one contiguous `(k1-k0) × dv`
/// slice per visited block. The monolithic session cache implements this
/// over a contiguous tensor pair ([`TensorKv`]); the paged cache
/// (`attention::paged`) resolves the block through a page table to one
/// frame of exactly `b_k` rows. Both return the same bytes for the same
/// rows, so the reduction's float path — and therefore its bits — is
/// independent of the storage layout.
pub trait KvSource: Sync {
    /// Number of cached K/V rows.
    fn rows(&self) -> usize;

    /// Value head dim (the output width).
    fn dv(&self) -> usize;

    /// The V rows `[k0, k1)` as one contiguous slice of `(k1-k0) * dv`
    /// f32s. Callers only request ranges that lie inside a single
    /// `b_k`-aligned block (the tiled loop's visiting pattern).
    fn v_block(&self, k0: usize, k1: usize) -> &[f32];
}

/// The monolithic [`KvSource`]: a borrowed contiguous K/V tensor pair
/// (the grown-in-place session cache, or caller-provided tensors).
pub struct TensorKv<'a> {
    pub k: &'a Tensor,
    pub v: &'a Tensor,
}

impl KvSource for TensorKv<'_> {
    fn rows(&self) -> usize {
        self.k.dim(0)
    }

    fn dv(&self) -> usize {
        self.v.dim(1)
    }

    fn v_block(&self, k0: usize, k1: usize) -> &[f32] {
        &self.v.data()[k0 * self.v.dim(1)..k1 * self.v.dim(1)]
    }
}

/// Scratch a [`ScoreKernel`] may use while producing a block — borrowed
/// views into the running thread's [`Workspace`], so kernels that stage
/// intermediates (the INT8 i32 accumulator) allocate nothing per block.
pub struct ScoreScratch<'w> {
    /// i32 QKᵀ accumulator for the INT8 dequant path.
    pub acc_i32: &'w mut Vec<i32>,
}

/// How a visited score block is produced. Implementations hold whatever
/// precomputed state they need (Q/K views, quantized blocks, scales) and
/// are shared read-only across row workers (`Sync`); per-block mutable
/// scratch comes from the running thread's [`ScoreScratch`].
pub trait ScoreKernel: Sync {
    /// Write the scaled, causal-masked score block for global query rows
    /// `[q0, q1)` × key rows `[k0, k1)` into `out[..(q1-q0)*(k1-k0)]`.
    fn score_block(
        &self,
        q0: usize,
        q1: usize,
        k0: usize,
        k1: usize,
        out: &mut [f32],
        scratch: &mut ScoreScratch<'_>,
    );

    /// The microkernel backend this kernel's math runs on. The drivers
    /// also use it for the P̃·V accumulate, so one kernel pins the whole
    /// reduction to one backend. Defaults to the process-selected
    /// backend; engines built with an explicit handle override it.
    fn microkernel(&self) -> Backend {
        Backend::select()
    }
}

/// Which blocks the driver visits, and with what stage-2 threshold.
pub trait BlockFilter: Sync {
    /// Stage-1 decision for block (bi, bj). Only called inside the causal
    /// domain; `false` counts the block as skipped in [`SkipStats`].
    fn keep(&self, bi: usize, bj: usize) -> bool;

    /// Stage-2 online-softmax threshold λ (`None` disables the filter).
    fn lambda(&self) -> Option<f32> {
        None
    }

    /// Exclusive k-block bound for the query rows ending at `q1` — the
    /// causal-domain edge, computed against *absolute* positions
    /// (`cfg.row_offset + q1`). Blocks at or past the bound are outside
    /// "full attention required" and excluded from both the loop and the
    /// [`SkipStats`] totals.
    fn kblock_end(&self, q1: usize, cfg: &AttnConfig, tn: usize) -> usize {
        if cfg.causal {
            (cfg.row_offset + q1).div_ceil(cfg.bk).min(tn)
        } else {
            tn
        }
    }
}

/// Plain f32 scoring over borrowed Q/K (the FlashAttention-2 path).
pub struct F32Kernel<'a> {
    q: &'a Tensor,
    k: &'a Tensor,
    scale: f32,
    causal: bool,
    row_offset: usize,
    mk: Backend,
}

impl<'a> F32Kernel<'a> {
    pub fn new(q: &'a Tensor, k: &'a Tensor, cfg: &AttnConfig) -> F32Kernel<'a> {
        assert_eq!(q.dim(1), k.dim(1), "q/k head dim");
        F32Kernel {
            q,
            k,
            scale: cfg.scale_for(q.dim(1)),
            causal: cfg.causal,
            row_offset: cfg.row_offset,
            mk: Backend::select(),
        }
    }

    /// Pin the kernel to an explicit microkernel backend (the engine
    /// builder's `.microkernel(...)` plumbs through here).
    pub fn with_microkernel(mut self, mk: Backend) -> F32Kernel<'a> {
        self.mk = mk;
        self
    }
}

impl ScoreKernel for F32Kernel<'_> {
    fn score_block(
        &self,
        q0: usize,
        q1: usize,
        k0: usize,
        k1: usize,
        out: &mut [f32],
        _scratch: &mut ScoreScratch<'_>,
    ) {
        score_block_with(
            self.mk,
            self.q,
            self.k,
            q0,
            q1,
            k0,
            k1,
            self.row_offset,
            self.scale,
            self.causal,
            out,
        );
    }

    fn microkernel(&self) -> Backend {
        self.mk
    }
}

/// Dense filter: every in-domain block is computed, no λ stage.
pub struct DenseFilter;

impl BlockFilter for DenseFilter {
    fn keep(&self, _bi: usize, _bj: usize) -> bool {
        true
    }
}

/// Stage-1 `BlockMask` lookup plus optional stage-2 λ — the SpargeAttn
/// filter, also driven by every baseline's mask (MInference, FlexPrefill,
/// sliding-window) so mask policy is the only variable between methods.
pub struct MaskFilter<'a> {
    mask: &'a BlockMask,
    lambda: Option<f32>,
}

impl<'a> MaskFilter<'a> {
    pub fn new(mask: &'a BlockMask, lambda: Option<f32>) -> MaskFilter<'a> {
        MaskFilter { mask, lambda }
    }
}

impl BlockFilter for MaskFilter<'_> {
    fn keep(&self, bi: usize, bj: usize) -> bool {
        self.mask.get(bi, bj)
    }

    fn lambda(&self) -> Option<f32> {
        self.lambda
    }
}

/// The unified tiled-attention driver, parallel over query-block rows.
/// Allocating convenience over [`run_tiled_into`] (fresh output tensor
/// and throwaway workspace — fine for prefill-shaped calls, wrong for
/// the decode hot loop).
pub fn run_tiled(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    cfg: &AttnConfig,
    kernel: &impl ScoreKernel,
    filter: &impl BlockFilter,
    exec: Exec<'_>,
) -> (Tensor, SkipStats) {
    let mut out = Tensor::zeros(&[q.dim(0), v.dim(1)]);
    let mut ws = Workspace::default();
    let stats = run_tiled_into(q, k, v, cfg, kernel, filter, exec, &mut ws, out.data_mut());
    (out, stats)
}

/// The unified tiled-attention driver, parallel over query-block rows,
/// writing into the caller's output buffer (`n × dv`, fully overwritten).
///
/// Runs blockwise online-softmax attention of `q` against `k`/`v` under
/// `cfg`, producing scores through `kernel` and block decisions through
/// `filter`. Query-block rows are self-scheduled in chunks across the
/// workers named by `exec` (inline / scoped threads / persistent pool);
/// each row writes a disjoint output slice and accumulates its own
/// [`SkipStats`], merged in row order afterwards — so outputs *and* stats
/// are identical for every execution mode and worker count. Scratch
/// comes from `ws` (inline) or each worker's own arena (pool), so a
/// single-tile call — the decode shape, which short-circuits the
/// fan-out bookkeeping entirely — allocates nothing once warm.
#[allow(clippy::too_many_arguments)]
pub fn run_tiled_into(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    cfg: &AttnConfig,
    kernel: &impl ScoreKernel,
    filter: &impl BlockFilter,
    exec: Exec<'_>,
    ws: &mut Workspace,
    out: &mut [f32],
) -> SkipStats {
    assert_eq!(q.dim(1), k.dim(1), "q/k head dim");
    assert_eq!(k.dim(0), v.dim(0), "k/v rows");
    run_tiled_into_kv(q, &TensorKv { k, v }, cfg, kernel, filter, exec, ws, out)
}

/// [`run_tiled_into`] over an abstract [`KvSource`] — the layer the
/// paged cache plugs into. The tensor-pair entry point above is a thin
/// wrapper, so both storage layouts run the identical reduction.
#[allow(clippy::too_many_arguments)]
pub fn run_tiled_into_kv(
    q: &Tensor,
    kv: &impl KvSource,
    cfg: &AttnConfig,
    kernel: &impl ScoreKernel,
    filter: &impl BlockFilter,
    exec: Exec<'_>,
    ws: &mut Workspace,
    out: &mut [f32],
) -> SkipStats {
    let n = q.dim(0);
    let nk = kv.rows();
    let dv = kv.dv();
    let tm = cfg.n_qblocks(n);
    let tn = cfg.n_kblocks(nk);
    debug_assert_eq!(out.len(), n * dv);

    let mut stats = SkipStats { cw: cfg.cw, ..Default::default() };
    if tm == 1 {
        // Decode-shaped fast path: one tile ran inline under every exec
        // mode anyway (a 1-item map never crosses a thread); skipping the
        // fan-out bookkeeping makes the step allocation-free.
        let kend = filter.kblock_end(n, cfg, tn);
        let (tile, st) = reduce_span(q, kv, cfg, kernel, filter, 0, 0, kend, ws);
        tile.finalize_into(out);
        tile.recycle(ws);
        stats.merge(&st);
        return stats;
    }
    let row_stats = {
        // Disjoint per-row output slices; each worker locks only its own
        // (uncontended) mutex, so no copies and no write races. This
        // collect runs only on the prefill shape — the decode shape
        // (tm == 1) returned above, and alloc_regression pins it.
        // sparge-lint: allow(hot-path-no-alloc)
        let row_out: Vec<Mutex<&mut [f32]>> = out.chunks_mut(cfg.bq * dv).map(Mutex::new).collect();
        exec.map_ws(tm, ws, |bi, wws| {
            let q1 = (bi * cfg.bq + cfg.bq).min(n);
            let kend = filter.kblock_end(q1, cfg, tn);
            let (tile, st) = reduce_span(q, kv, cfg, kernel, filter, bi, 0, kend, wws);
            tile.finalize_into(&mut row_out[bi].lock().unwrap());
            tile.recycle(wws);
            st
        })
    };
    for s in &row_stats {
        stats.merge(s);
    }
    stats
}

/// Reduce k-blocks `[kb0, kb1)` of query-tile row `bi` into a
/// [`FlashTile`] borrowed from `ws` (recycle it when done) — the shared
/// inner loop of both drivers. The span's [`SkipStats`] count exactly its
/// own blocks, so summing span stats in any fixed order reproduces the
/// serial row totals (λ decisions are span-local; see the module docs).
#[allow(clippy::too_many_arguments)]
fn reduce_span(
    q: &Tensor,
    kv: &impl KvSource,
    cfg: &AttnConfig,
    kernel: &impl ScoreKernel,
    filter: &impl BlockFilter,
    bi: usize,
    kb0: usize,
    kb1: usize,
    ws: &mut Workspace,
) -> (FlashTile, SkipStats) {
    let n = q.dim(0);
    let nk = kv.rows();
    let dv = kv.dv();
    let q0 = bi * cfg.bq;
    let q1 = (q0 + cfg.bq).min(n);
    let mut stats = SkipStats { cw: cfg.cw, ..Default::default() };
    let mk = kernel.microkernel();
    let mut tile = FlashTile::new_in(ws, q1 - q0, dv, cfg.bk);
    let mut sbuf = grab(&mut ws.scores, (q1 - q0) * cfg.bk, 0.0);
    {
        let mut scratch = ScoreScratch { acc_i32: &mut ws.quant_i32 };
        for bj in kb0..kb1 {
            let k0 = bj * cfg.bk;
            let k1 = (k0 + cfg.bk).min(nk);
            stats.qk_total += 1;
            stats.pv_total += 1;
            if !filter.keep(bi, bj) {
                stats.qk_skipped += 1;
                stats.pv_skipped += 1;
                continue;
            }
            let sb = &mut sbuf[..(q1 - q0) * (k1 - k0)];
            kernel.score_block(q0, q1, k0, k1, sb, &mut scratch);
            // P̃ holds exact zeros only where this block crosses the
            // causal diagonal for these rows (−∞ entries exist iff the
            // block's last key position exceeds the first row's absolute
            // position); everywhere else the P̃V matmul runs branch-free.
            let sparse_p = cfg.causal && k1 > cfg.row_offset + q0 + 1;
            let vb = kv.v_block(k0, k1);
            tile.ingest(sb, k1 - k0, vb, filter.lambda(), cfg.cw, &mut stats, sparse_p, mk);
        }
    }
    ws.scores = sbuf;
    (tile, stats)
}

/// A cached split-KV execution plan: the (row, span) work-list plus the
/// partial-state and per-span stats arenas, owned by the caller (an
/// `AttnSession` keeps one per sequence) and reused across calls.
///
/// [`SpanPlan::ensure`] revalidates the plan against the call's geometry
/// — for a decode step that is one `kblock_end` comparison, so a step
/// whose cache grew within the same `b_k` block does **no planning work
/// and no allocation**; the item list is rebuilt (reusing capacity) only
/// when the k-domain or span size actually changes. The plan never
/// affects results: it caches a pure function of the call's shape.
#[derive(Default)]
pub struct SpanPlan {
    span_blocks: usize,
    /// Cached per-tile k-block bounds (the plan key, validated per call).
    kends: Vec<usize>,
    /// Work items: (tile row, first k-block, one-past-last k-block),
    /// row-major in ascending span order — the merge walks this exact
    /// order.
    items: Vec<(usize, usize, usize)>,
    /// Per-item partial `(m, l, o)` states: `stride` f32 per item, laid
    /// out `[m; rows][l; rows][o; rows·dv]`.
    partials: Vec<f32>,
    /// Per-item skip counters, folded in item order.
    stats: Vec<SkipStats>,
}

impl SpanPlan {
    pub fn new() -> SpanPlan {
        SpanPlan::default()
    }

    /// Number of work items the current plan holds (tests/benches).
    pub fn items(&self) -> usize {
        self.items.len()
    }

    fn ensure(&mut self, tm: usize, span_blocks: usize, kend_of: impl Fn(usize) -> usize) {
        let mut dirty = self.span_blocks != span_blocks || self.kends.len() != tm;
        if !dirty {
            for (bi, &kend) in self.kends.iter().enumerate() {
                if kend != kend_of(bi) {
                    dirty = true;
                    break;
                }
            }
        }
        if !dirty {
            return;
        }
        self.span_blocks = span_blocks;
        self.kends.clear();
        self.items.clear();
        for bi in 0..tm {
            let kend = kend_of(bi);
            self.kends.push(kend);
            let mut kb0 = 0;
            while kb0 < kend {
                let kb1 = (kb0 + span_blocks).min(kend);
                self.items.push((bi, kb0, kb1));
                kb0 = kb1;
            }
        }
    }
}

/// A `*mut T` the span workers can share: each item writes only its own
/// disjoint slot, and the executor synchronizes completion before any
/// read, so no two accesses alias. Fan-out sites assert the disjointness
/// precondition with [`debug_assert_disjoint_slots`] in debug builds.
pub(crate) struct SendPtr<T>(pub(crate) *mut T);
// SAFETY: the pointer crosses threads, but every fan-out item
// dereferences only its own disjoint slot (see the type docs), so no two
// threads ever touch the same address concurrently.
unsafe impl<T> Send for SendPtr<T> {}
// SAFETY: same argument — a shared `&SendPtr` only ever yields writes to
// per-item disjoint slots, synchronized by executor completion.
unsafe impl<T> Sync for SendPtr<T> {}

/// Debug-assert that the slot ranges a [`SendPtr`] fan-out will write are
/// pairwise disjoint: `slot(w)` returns item `w`'s `(start, len)` in
/// arena elements. Zero-length slots never overlap anything. The check is
/// allocation-free (O(n²) pairwise scan) and compiles to nothing in
/// release builds, so hot paths may call it unconditionally.
#[inline]
pub(crate) fn debug_assert_disjoint_slots(n: usize, slot: impl Fn(usize) -> (usize, usize)) {
    if !cfg!(debug_assertions) {
        return;
    }
    for a in 0..n {
        let (s0, l0) = slot(a);
        for b in (a + 1)..n {
            let (s1, l1) = slot(b);
            assert!(
                l0 == 0 || l1 == 0 || s0 + l0 <= s1 || s1 + l1 <= s0,
                "overlapping fan-out slots: item {a} = [{s0}, {}) vs item {b} = [{s1}, {})",
                s0 + l0,
                s1 + l1
            );
        }
    }
}

/// The split-KV (Flash-Decoding) driver. Allocating convenience over
/// [`run_tiled_splitkv_into`] (throwaway plan/workspace/output — fine
/// for one-off calls and tests, wrong for the decode hot loop).
#[allow(clippy::too_many_arguments)]
pub fn run_tiled_splitkv(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    cfg: &AttnConfig,
    kernel: &impl ScoreKernel,
    filter: &impl BlockFilter,
    exec: Exec<'_>,
    span_blocks: usize,
) -> (Tensor, SkipStats) {
    let mut out = Tensor::zeros(&[q.dim(0), v.dim(1)]);
    let mut plan = SpanPlan::new();
    let mut ws = Workspace::default();
    let stats = run_tiled_splitkv_into(
        q,
        k,
        v,
        cfg,
        kernel,
        filter,
        exec,
        span_blocks,
        &mut plan,
        &mut ws,
        out.data_mut(),
    );
    (out, stats)
}

/// The split-KV (Flash-Decoding) driver: parallel over (query-tile row,
/// KV span) pairs instead of rows alone, so a decode-shaped call (one
/// query row, `tm = 1`) still spreads across the pool.
///
/// Each row's k-block domain `[0, kblock_end)` is cut into contiguous
/// spans of `span_blocks` k-blocks; every span is reduced independently
/// by the shared inner loop into a partial `(m, l, o)` state written to
/// the plan's arena, and the spans of a row are combined left-to-right in
/// span order (the [`FlashTile::merge`] combine). The span geometry
/// depends only on the inputs (cache length, config, `span_blocks`) —
/// **never** on the worker count — so outputs and merged [`SkipStats`]
/// are bitwise-identical for every [`Exec`] mode and pool size (the
/// determinism contract in the module docs). With `span_blocks ≥` the
/// row's k-block count the single span reproduces [`run_tiled`] bitwise.
///
/// Steady-state cost: with a warm `plan` and `ws` a decode step does no
/// heap allocation and no planning work — span reduction writes into the
/// plan's preallocated arenas, and the plan revalidates in O(1) while the
/// cache stays within the same `b_k` block. `span_blocks` trades
/// parallelism against per-span overhead; the `KvSplit::Auto` default of
/// 4 k-blocks keeps a span at ≥ a couple hundred keys of matmul work,
/// far above its fixed cost.
#[allow(clippy::too_many_arguments)]
pub fn run_tiled_splitkv_into(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    cfg: &AttnConfig,
    kernel: &impl ScoreKernel,
    filter: &impl BlockFilter,
    exec: Exec<'_>,
    span_blocks: usize,
    plan: &mut SpanPlan,
    ws: &mut Workspace,
    out: &mut [f32],
) -> SkipStats {
    assert_eq!(q.dim(1), k.dim(1), "q/k head dim");
    assert_eq!(k.dim(0), v.dim(0), "k/v rows");
    run_tiled_splitkv_into_kv(
        q,
        &TensorKv { k, v },
        cfg,
        kernel,
        filter,
        exec,
        span_blocks,
        plan,
        ws,
        out,
    )
}

/// [`run_tiled_splitkv_into`] over an abstract [`KvSource`] — the layer
/// the paged cache plugs into. Same span geometry, same fan-out, same
/// left-to-right merge; only where a V block's bytes come from differs.
#[allow(clippy::too_many_arguments)]
pub fn run_tiled_splitkv_into_kv(
    q: &Tensor,
    kv: &impl KvSource,
    cfg: &AttnConfig,
    kernel: &impl ScoreKernel,
    filter: &impl BlockFilter,
    exec: Exec<'_>,
    span_blocks: usize,
    plan: &mut SpanPlan,
    ws: &mut Workspace,
    out: &mut [f32],
) -> SkipStats {
    assert!(span_blocks > 0, "span_blocks must be positive");
    let n = q.dim(0);
    let nk = kv.rows();
    let dv = kv.dv();
    let tm = cfg.n_qblocks(n);
    let tn = cfg.n_kblocks(nk);
    debug_assert_eq!(out.len(), n * dv);

    plan.ensure(tm, span_blocks, |bi| {
        let q1 = (bi * cfg.bq + cfg.bq).min(n);
        filter.kblock_end(q1, cfg, tn)
    });
    let nitems = plan.items.len();
    let rows_max = cfg.bq.min(n.max(1));
    let stride = rows_max * (2 + dv);
    if plan.partials.len() < nitems * stride {
        plan.partials.resize(nitems * stride, 0.0);
    }
    plan.stats.clear();
    plan.stats.resize(nitems, SkipStats::default());

    {
        let items = &plan.items;
        // Every item's write range must be disjoint before handing the
        // raw arena pointer to the workers below.
        debug_assert_disjoint_slots(nitems, |w| {
            let bi = items[w].0;
            let rows = (bi * cfg.bq + cfg.bq).min(n) - bi * cfg.bq;
            (w * stride, rows * (2 + dv))
        });
        let pptr = SendPtr(plan.partials.as_mut_ptr());
        let sptr = SendPtr(plan.stats.as_mut_ptr());
        exec.for_each_ws(nitems, ws, |w, wws| {
            let (bi, kb0, kb1) = items[w];
            let (tile, st) = reduce_span(q, kv, cfg, kernel, filter, bi, kb0, kb1, wws);
            let rows = tile.rows;
            // SAFETY: item `w` owns slot `w` exclusively (disjoint ranges
            // of the arena), and `for_each_ws` does not return until
            // every item completed — the reads below happen strictly
            // after all writes.
            unsafe {
                let slot = std::slice::from_raw_parts_mut(pptr.0.add(w * stride), rows * (2 + dv));
                slot[..rows].copy_from_slice(&tile.m);
                slot[rows..2 * rows].copy_from_slice(&tile.l);
                slot[2 * rows..].copy_from_slice(&tile.o);
                *sptr.0.add(w) = st;
            }
            tile.recycle(wws);
        });
    }

    // Deterministic merge: items are row-major in span order; fold each
    // row's spans left-to-right into its first slot, then normalize into
    // the caller's rows. Stats fold in the same fixed item order.
    let mut stats = SkipStats { cw: cfg.cw, ..Default::default() };
    for st in &plan.stats {
        stats.merge(st);
    }
    let mut w = 0;
    for bi in 0..tm {
        let q0 = bi * cfg.bq;
        let q1 = (q0 + cfg.bq).min(n);
        let rows = q1 - q0;
        let state = rows * (2 + dv);
        let orow = &mut out[q0 * dv..q1 * dv];
        let w0 = w;
        while w < nitems && plan.items[w].0 == bi {
            w += 1;
        }
        if w == w0 {
            // empty k domain (kend = 0): exactly zero, like run_tiled's
            // fully-masked tiles
            orow.fill(0.0);
            continue;
        }
        for wb in (w0 + 1)..w {
            let (head, tail) = plan.partials.split_at_mut(wb * stride);
            let a = &mut head[w0 * stride..w0 * stride + state];
            let b = &tail[..state];
            let (am, ar) = a.split_at_mut(rows);
            let (al, ao) = ar.split_at_mut(rows);
            let (bm, br) = b.split_at(rows);
            let (bl, bo) = br.split_at(rows);
            merge_rows(am, al, ao, bm, bl, bo, rows, dv);
        }
        let slot = &plan.partials[w0 * stride..w0 * stride + state];
        let (_, lr) = slot.split_at(rows);
        let (l, o) = lr.split_at(rows);
        for i in 0..rows {
            let inv = if l[i] > 0.0 { 1.0 / l[i] } else { 0.0 };
            for j in 0..dv {
                orow[i * dv + j] = o[i * dv + j] * inv;
            }
        }
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::dense::attention_naive;
    use crate::util::prop::{assert_allclose, Cases};
    use crate::util::rng::Pcg;

    fn scratchless_ingest(
        tile: &mut FlashTile,
        s: &[f32],
        bk: usize,
        v: &[f32],
        lambda: Option<f32>,
        cw: usize,
        stats: &mut SkipStats,
    ) {
        tile.ingest(s, bk, v, lambda, cw, stats, true, Backend::select());
    }

    #[test]
    fn lambda_zero_threshold_never_fires_on_first_block() {
        // With one block, m_local == m_new so the λ test (strict <) never
        // triggers for λ<=0; output must equal dense.
        let mut rng = Pcg::seeded(12);
        let (n, d) = (8, 4);
        let q = Tensor::randn(&[n, d], &mut rng);
        let k = Tensor::randn(&[n, d], &mut rng);
        let v = Tensor::randn(&[n, d], &mut rng);
        let mut tile = FlashTile::new(n, d, n);
        let mut s = vec![0f32; n * n];
        score_block(&q, &k, 0, n, 0, n, 0, 0.5, false, &mut s);
        let mut stats = SkipStats::default();
        scratchless_ingest(&mut tile, &s, n, v.data(), Some(-0.1), 2, &mut stats);
        assert_eq!(stats.pv_skipped_frac, 0.0);
    }

    #[test]
    fn ingest_scratch_is_reused_across_blocks() {
        // Two sequential ingests through the same tile must equal one
        // dense pass — the hoisted m_local scratch must not leak state.
        let mut rng = Pcg::seeded(13);
        let (n, d) = (8, 4);
        let q = Tensor::randn(&[n, d], &mut rng);
        let k = Tensor::randn(&[n, d], &mut rng);
        let v = Tensor::randn(&[n, d], &mut rng);
        let cfg = AttnConfig { bq: 8, bk: 4, causal: false, scale: None, cw: 2, row_offset: 0 };
        let kernel = F32Kernel::new(&q, &k, &cfg);
        let (out, _) = run_tiled(&q, &k, &v, &cfg, &kernel, &DenseFilter, Exec::Inline);
        let oracle = attention_naive(&q, &k, &v, &cfg);
        assert_allclose(out.data(), oracle.data(), 1e-4, 1e-3, "scratch-reuse").unwrap();
    }

    #[test]
    fn workspace_tile_matches_fresh_tile_bitwise() {
        // The bitwise-neutral reuse contract: a tile built over a dirty,
        // oversized workspace must behave exactly like a fresh one.
        let mut rng = Pcg::seeded(19);
        let (n, d) = (8, 4);
        let q = Tensor::randn(&[n, d], &mut rng);
        let k = Tensor::randn(&[n, d], &mut rng);
        let v = Tensor::randn(&[n, d], &mut rng);
        let mut s = vec![0f32; n * n];
        score_block(&q, &k, 0, n, 0, n, 0, 0.5, false, &mut s);

        let mut ws = Workspace::default();
        // dirty the arena with a bigger, different-shaped reduction
        let big = FlashTile::new_in(&mut ws, 4 * n, 2 * d, n);
        big.recycle(&mut ws);
        for b in [&mut ws.tile_m, &mut ws.tile_l, &mut ws.tile_o, &mut ws.tile_p, &mut ws.tile_m_local] {
            for x in b.iter_mut() {
                *x = 1234.5;
            }
        }

        let mut fresh = FlashTile::new(n, d, n);
        let mut reused = FlashTile::new_in(&mut ws, n, d, n);
        let (mut st_a, mut st_b) = (SkipStats::default(), SkipStats::default());
        scratchless_ingest(&mut fresh, &s, n, v.data(), Some(-2.0), 2, &mut st_a);
        scratchless_ingest(&mut reused, &s, n, v.data(), Some(-2.0), 2, &mut st_b);
        assert_eq!(st_a, st_b);
        assert_eq!(fresh.m, reused.m);
        assert_eq!(fresh.l, reused.l);
        assert_eq!(fresh.o, reused.o);
        assert_eq!(fresh.finalize(), reused.finalize());
    }

    #[test]
    fn finalize_into_matches_finalize() {
        let mut rng = Pcg::seeded(20);
        let (n, d) = (6, 8);
        let q = Tensor::randn(&[n, d], &mut rng);
        let k = Tensor::randn(&[n, d], &mut rng);
        let v = Tensor::randn(&[n, d], &mut rng);
        let mut s = vec![0f32; n * n];
        score_block(&q, &k, 0, n, 0, n, 0, 0.5, false, &mut s);
        let mut tile = FlashTile::new(n, d, n);
        let mut stats = SkipStats::default();
        scratchless_ingest(&mut tile, &s, n, v.data(), None, 2, &mut stats);
        let mut into = vec![7.0f32; n * d];
        tile.finalize_into(&mut into);
        assert_eq!(into, tile.finalize(), "finalize_into must be the same bits as finalize");
    }

    #[test]
    fn driver_matches_oracle_under_all_exec_modes() {
        let pool = crate::util::threadpool::WorkerPool::new(3);
        Cases::standard(801).check(|rng| {
            let n = rng.range(1, 70);
            let d = [4, 8, 16][rng.range(0, 3)];
            let cfg = AttnConfig {
                bq: rng.range(1, 20),
                bk: rng.range(1, 20),
                causal: rng.chance(0.5),
                scale: None,
                cw: rng.range(1, 5),
                row_offset: 0,
            };
            let q = Tensor::randn(&[n, d], rng);
            let k = Tensor::randn(&[n, d], rng);
            let v = Tensor::randn(&[n, d], rng);
            let kernel = F32Kernel::new(&q, &k, &cfg);
            let (o1, s1) = run_tiled(&q, &k, &v, &cfg, &kernel, &DenseFilter, Exec::Inline);
            let (o4, s4) = run_tiled(&q, &k, &v, &cfg, &kernel, &DenseFilter, Exec::Threads(4));
            let (op, sp) = run_tiled(&q, &k, &v, &cfg, &kernel, &DenseFilter, Exec::Pool(&pool));
            if o1 != o4 || o1 != op {
                return Err("exec modes not bitwise equal".into());
            }
            if s1 != s4 || s1 != sp {
                return Err("exec-mode stats differ".into());
            }
            let oracle = attention_naive(&q, &k, &v, &cfg);
            assert_allclose(o1.data(), oracle.data(), 1e-4, 1e-3, "driver-vs-oracle")
        });
    }

    #[test]
    fn causal_domain_bound_excludes_upper_triangle() {
        let mut rng = Pcg::seeded(14);
        let (n, d) = (64, 8);
        let q = Tensor::randn(&[n, d], &mut rng);
        let k = Tensor::randn(&[n, d], &mut rng);
        let v = Tensor::randn(&[n, d], &mut rng);
        let cfg = AttnConfig { bq: 16, bk: 16, causal: true, scale: None, cw: 2, row_offset: 0 };
        let kernel = F32Kernel::new(&q, &k, &cfg);
        let (_, stats) = run_tiled(&q, &k, &v, &cfg, &kernel, &DenseFilter, Exec::Inline);
        // 4 q-blocks; block row i visits i+1 k-blocks => 1+2+3+4 = 10
        assert_eq!(stats.qk_total, 10);
        assert_eq!(stats.pv_total, 10);
    }

    #[test]
    fn row_offset_chunk_matches_rows_of_full_causal_run() {
        // The offset-aware causal contract: running query rows [c0, n) with
        // row_offset = c0 against the full K/V must reproduce rows c0.. of
        // the whole-sequence causal run bitwise — every query row sees the
        // same visible key set, and tile re-partitioning cannot change
        // per-row online-softmax state (f32, λ off).
        let pool = crate::util::threadpool::WorkerPool::new(2);
        Cases::standard(802).check(|rng| {
            let n = rng.range(8, 80);
            let c0 = rng.range(1, n);
            let d = 8;
            let cfg = AttnConfig {
                bq: rng.range(1, 20),
                bk: rng.range(1, 20),
                causal: true,
                scale: None,
                cw: rng.range(1, 4),
                row_offset: 0,
            };
            let q = Tensor::randn(&[n, d], rng);
            let k = Tensor::randn(&[n, d], rng);
            let v = Tensor::randn(&[n, d], rng);
            let kernel = F32Kernel::new(&q, &k, &cfg);
            let (full, _) = run_tiled(&q, &k, &v, &cfg, &kernel, &DenseFilter, Exec::Inline);
            let qc = q.rows(c0, n);
            let ccfg = cfg.at_offset(c0);
            let ckernel = F32Kernel::new(&qc, &k, &ccfg);
            let (chunk, _) = run_tiled(&qc, &k, &v, &ccfg, &ckernel, &DenseFilter, Exec::Pool(&pool));
            if chunk.data() != &full.data()[c0 * d..] {
                return Err(format!("offset chunk diverged (n={n} c0={c0} bq={} bk={})", cfg.bq, cfg.bk));
            }
            Ok(())
        });
    }

    #[test]
    fn row_offset_extends_causal_domain_bound() {
        // A 1-row query at offset p must visit exactly the k blocks a
        // decode step at position p would: ceil((p+1)/bk).
        let mut rng = Pcg::seeded(16);
        let (n, d) = (40, 4);
        let k = Tensor::randn(&[n, d], &mut rng);
        let v = Tensor::randn(&[n, d], &mut rng);
        let q = Tensor::randn(&[1, d], &mut rng);
        let cfg = AttnConfig { bq: 8, bk: 8, causal: true, scale: None, cw: 1, row_offset: 25 };
        let kernel = F32Kernel::new(&q, &k, &cfg);
        let (_, stats) = run_tiled(&q, &k, &v, &cfg, &kernel, &DenseFilter, Exec::Inline);
        assert_eq!(stats.qk_total, 26usize.div_ceil(8));
    }

    #[test]
    fn merge_combines_disjoint_spans_like_one_pass() {
        // Two tiles ingesting disjoint halves, merged, must agree with one
        // tile ingesting both halves (allclose: the reduction tree differs).
        let mut rng = Pcg::seeded(17);
        let (rows, d, bk) = (8, 4, 8);
        let q = Tensor::randn(&[rows, d], &mut rng);
        let k = Tensor::randn(&[2 * bk, d], &mut rng);
        let v = Tensor::randn(&[2 * bk, d], &mut rng);
        let mut s = vec![0f32; rows * bk];
        let mut stats = SkipStats::default();

        let mut serial = FlashTile::new(rows, d, bk);
        let mut left = FlashTile::new(rows, d, bk);
        let mut right = FlashTile::new(rows, d, bk);
        score_block(&q, &k, 0, rows, 0, bk, 0, 0.5, false, &mut s);
        scratchless_ingest(&mut serial, &s, bk, &v.data()[..bk * d], None, 1, &mut stats);
        scratchless_ingest(&mut left, &s, bk, &v.data()[..bk * d], None, 1, &mut stats);
        score_block(&q, &k, 0, rows, bk, 2 * bk, 0, 0.5, false, &mut s);
        scratchless_ingest(&mut serial, &s, bk, &v.data()[bk * d..], None, 1, &mut stats);
        scratchless_ingest(&mut right, &s, bk, &v.data()[bk * d..], None, 1, &mut stats);

        left.merge(&right);
        assert_allclose(&left.finalize(), &serial.finalize(), 1e-5, 1e-5, "merge-vs-one-pass").unwrap();
    }

    #[test]
    fn merge_keeps_fully_masked_rows_zero() {
        let (rows, d) = (2, 4);
        let mut a = FlashTile::new(rows, d, 4);
        let mut b = FlashTile::new(rows, d, 4);
        // row 0 of b sees one real entry; row 1 stays fully masked in both
        let s = [1.0f32, f32::NEG_INFINITY, f32::NEG_INFINITY, f32::NEG_INFINITY];
        let mut stats = SkipStats::default();
        scratchless_ingest(&mut b, &s[..2], 1, &[3.0, 0.0, 0.0, 0.0], None, 1, &mut stats);
        a.merge(&b);
        assert_eq!(a.m[1], f32::NEG_INFINITY);
        let out = a.finalize();
        assert_eq!(&out[d..], &[0.0; 4], "masked row must finalize to zero");
        assert_eq!(&out[..d], &[3.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn splitkv_single_span_reproduces_run_tiled_bitwise() {
        let mut rng = Pcg::seeded(18);
        let (n, d) = (40, 8);
        let q = Tensor::randn(&[n, d], &mut rng);
        let k = Tensor::randn(&[n, d], &mut rng);
        let v = Tensor::randn(&[n, d], &mut rng);
        let cfg = AttnConfig { bq: 16, bk: 8, causal: true, scale: None, cw: 2, row_offset: 0 };
        let kernel = F32Kernel::new(&q, &k, &cfg);
        let (serial, s1) = run_tiled(&q, &k, &v, &cfg, &kernel, &DenseFilter, Exec::Inline);
        let (split, s2) =
            run_tiled_splitkv(&q, &k, &v, &cfg, &kernel, &DenseFilter, Exec::Inline, cfg.n_kblocks(n));
        assert_eq!(serial, split, "one span per row must be the serial reduction");
        assert_eq!(s1, s2);
    }

    #[test]
    fn splitkv_matches_run_tiled_and_is_exec_invariant() {
        let pool2 = crate::util::threadpool::WorkerPool::new(2);
        let pool8 = crate::util::threadpool::WorkerPool::new(8);
        Cases::standard(803).check(|rng| {
            let n = rng.range(1, 70);
            let d = 8;
            let cfg = AttnConfig {
                bq: rng.range(1, 20),
                bk: rng.range(1, 20),
                causal: rng.chance(0.5),
                scale: None,
                cw: rng.range(1, 4),
                row_offset: if rng.chance(0.5) { rng.range(0, 40) } else { 0 },
            };
            let span = rng.range(1, 5);
            let q = Tensor::randn(&[n, d], rng);
            let k = Tensor::randn(&[n + cfg.row_offset, d], rng);
            let v = Tensor::randn(&[n + cfg.row_offset, d], rng);
            let kernel = F32Kernel::new(&q, &k, &cfg);
            let (serial, st_serial) = run_tiled(&q, &k, &v, &cfg, &kernel, &DenseFilter, Exec::Inline);
            let (split, st_split) =
                run_tiled_splitkv(&q, &k, &v, &cfg, &kernel, &DenseFilter, Exec::Inline, span);
            // λ off: span stats sum exactly to the serial row totals
            if st_serial != st_split {
                return Err(format!("splitkv stats diverged: {st_serial:?} vs {st_split:?}"));
            }
            for (exec, name) in [
                (Exec::Threads(4), "threads"),
                (Exec::Pool(&pool2), "pool2"),
                (Exec::Pool(&pool8), "pool8"),
            ] {
                let (o, s) = run_tiled_splitkv(&q, &k, &v, &cfg, &kernel, &DenseFilter, exec, span);
                if o != split || s != st_split {
                    return Err(format!("splitkv not bitwise under {name}"));
                }
            }
            assert_allclose(split.data(), serial.data(), 1e-4, 1e-3, "splitkv-vs-serial")
        });
    }

    #[test]
    fn splitkv_plan_and_workspace_reuse_is_bitwise_neutral() {
        // Decode-style growth: one SpanPlan + Workspace carried across a
        // growing KV domain must give the same bits as fresh state per
        // call — and revalidate without rebuilding while the k-domain
        // stays put.
        let mut rng = Pcg::seeded(21);
        let (nk_max, d) = (70, 8);
        let kf = Tensor::randn(&[nk_max, d], &mut rng);
        let vf = Tensor::randn(&[nk_max, d], &mut rng);
        let q = Tensor::randn(&[1, d], &mut rng);
        let cfg = AttnConfig { bq: 16, bk: 8, causal: false, scale: None, cw: 2, row_offset: 0 };
        let mut plan = SpanPlan::new();
        let mut ws = Workspace::default();
        for nk in 30..nk_max {
            let k = kf.rows(0, nk);
            let v = vf.rows(0, nk);
            let kernel = F32Kernel::new(&q, &k, &cfg);
            let mut out = vec![0f32; d];
            let st = run_tiled_splitkv_into(
                &q,
                &k,
                &v,
                &cfg,
                &kernel,
                &DenseFilter,
                Exec::Inline,
                2,
                &mut plan,
                &mut ws,
                &mut out,
            );
            let (fresh, st_fresh) =
                run_tiled_splitkv(&q, &k, &v, &cfg, &kernel, &DenseFilter, Exec::Inline, 2);
            assert_eq!(out.as_slice(), fresh.data(), "nk={nk}: reused plan diverged");
            assert_eq!(st, st_fresh, "nk={nk}: stats diverged");
            assert_eq!(plan.items(), cfg.n_kblocks(nk).div_ceil(2), "nk={nk}: plan geometry");
        }
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "overlapping fan-out slots")]
    fn overlapping_fanout_plan_trips_debug_checker() {
        // Slot stride 4 but slot length 6: items 0 and 1 overlap.
        debug_assert_disjoint_slots(2, |w| (w * 4, 6));
    }

    #[test]
    fn disjoint_and_empty_fanout_slots_pass_debug_checker() {
        debug_assert_disjoint_slots(3, |w| (w * 4, 4));
        debug_assert_disjoint_slots(3, |w| (w * 4, 0));
        debug_assert_disjoint_slots(0, |_| (0, 0));
    }

    #[test]
    fn miri_splitkv_sendptr_fanout_tiny() {
        // Tiny shape driven through real pool threads: the SendPtr
        // disjoint-slot arena writes — the path the Miri CI leg checks
        // for UB (the big numeric suites above are too slow under Miri).
        let pool = crate::util::threadpool::WorkerPool::new(2);
        let mut rng = Pcg::seeded(33);
        let (n, d) = (9, 4);
        let q = Tensor::randn(&[n, d], &mut rng);
        let k = Tensor::randn(&[n, d], &mut rng);
        let v = Tensor::randn(&[n, d], &mut rng);
        let cfg = AttnConfig { bq: 4, bk: 4, causal: true, scale: None, cw: 2, row_offset: 0 };
        let kernel = F32Kernel::new(&q, &k, &cfg);
        let (inline, si) =
            run_tiled_splitkv(&q, &k, &v, &cfg, &kernel, &DenseFilter, Exec::Inline, 1);
        let (pooled, sp) =
            run_tiled_splitkv(&q, &k, &v, &cfg, &kernel, &DenseFilter, Exec::Pool(&pool), 1);
        assert_eq!(inline, pooled, "pool fan-out must be bitwise vs inline");
        assert_eq!(si, sp);
    }

    #[test]
    fn mask_filter_skips_and_counts() {
        let mut rng = Pcg::seeded(15);
        let (n, d) = (32, 8);
        let q = Tensor::randn(&[n, d], &mut rng);
        let k = Tensor::randn(&[n, d], &mut rng);
        let v = Tensor::randn(&[n, d], &mut rng);
        let cfg = AttnConfig { bq: 8, bk: 8, causal: false, scale: None, cw: 2, row_offset: 0 };
        let mut mask = BlockMask::new_all(4, 4, true);
        mask.set(0, 3, false);
        mask.set(2, 1, false);
        let kernel = F32Kernel::new(&q, &k, &cfg);
        let filter = MaskFilter::new(&mask, None);
        let (_, stats) = run_tiled(&q, &k, &v, &cfg, &kernel, &filter, Exec::Inline);
        assert_eq!(stats.qk_total, 16);
        assert_eq!(stats.qk_skipped, 2);
        assert_eq!(stats.pv_skipped, 2);
    }
}
