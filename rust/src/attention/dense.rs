//! Naive O(N²·d) dense attention — the ground-truth oracle every other
//! engine in the repo is checked against.

use crate::tensor::{matmul, ops, Tensor};

use super::types::AttnConfig;

/// Full-matrix attention: O = softmax(QKᵀ·scale [+causal mask]) V.
///
/// Q, K, V are (N, d) single-head tensors. Materializes the N×N score
/// matrix, so only suitable as a reference for moderate N. Causal masking
/// honors the offset-aware contract: query row `i` sits at absolute
/// position `cfg.row_offset + i` and sees key rows `0..=row_offset + i`
/// (whole-sequence callers use offset 0 and need square scores).
pub fn attention_naive(q: &Tensor, k: &Tensor, v: &Tensor, cfg: &AttnConfig) -> Tensor {
    assert_eq!(q.ndim(), 2);
    assert_eq!(q.dim(1), k.dim(1), "q/k head dim");
    assert_eq!(k.dim(0), v.dim(0), "k/v length");
    let n = q.dim(0);
    let nk = k.dim(0);
    let scale = cfg.scale_for(q.dim(1));

    let mut s = matmul::matmul_nt(q, k);
    s.scale(scale);
    if cfg.causal {
        assert_eq!(cfg.row_offset + n, nk, "causal attention needs offset + q rows == k rows");
        for i in 0..n {
            for j in (cfg.row_offset + i + 1)..nk {
                *s.at2_mut(i, j) = f32::NEG_INFINITY;
            }
        }
    }
    let p = ops::softmax_rows(&s);
    matmul::matmul_nn(&p, v)
}

/// Multi-head wrapper over `attention_naive`: inputs are `h` stacked
/// (N, d) heads laid out as a Vec; returns per-head outputs.
pub fn attention_naive_heads(
    q: &[Tensor],
    k: &[Tensor],
    v: &[Tensor],
    cfg: &AttnConfig,
) -> Vec<Tensor> {
    assert_eq!(q.len(), k.len());
    assert_eq!(k.len(), v.len());
    q.iter().zip(k).zip(v).map(|((qh, kh), vh)| attention_naive(qh, kh, vh, cfg)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_allclose, Cases};
    use crate::util::rng::Pcg;

    #[test]
    fn uniform_scores_average_v() {
        // Q=0 ⇒ all scores equal ⇒ output is the mean of V rows.
        let mut rng = Pcg::seeded(1);
        let d = 8;
        let n = 16;
        let q = Tensor::zeros(&[n, d]);
        let k = Tensor::randn(&[n, d], &mut rng);
        let v = Tensor::randn(&[n, d], &mut rng);
        let o = attention_naive(&q, &k, &v, &AttnConfig::default());
        let mean = crate::tensor::ops::mean_axis0(&v);
        for i in 0..n {
            assert_allclose(o.row(i), &mean, 1e-5, 1e-5, "uniform").unwrap();
        }
    }

    #[test]
    fn causal_first_row_copies_v0() {
        let mut rng = Pcg::seeded(2);
        let (n, d) = (8, 4);
        let q = Tensor::randn(&[n, d], &mut rng);
        let k = Tensor::randn(&[n, d], &mut rng);
        let v = Tensor::randn(&[n, d], &mut rng);
        let o = attention_naive(&q, &k, &v, &AttnConfig::causal());
        assert_allclose(o.row(0), v.row(0), 1e-5, 1e-5, "causal row0").unwrap();
    }

    #[test]
    fn causal_offset_rows_match_full_run() {
        let mut rng = Pcg::seeded(4);
        let (n, d, c0) = (24, 8, 10);
        let q = Tensor::randn(&[n, d], &mut rng);
        let k = Tensor::randn(&[n, d], &mut rng);
        let v = Tensor::randn(&[n, d], &mut rng);
        let full = attention_naive(&q, &k, &v, &AttnConfig::causal());
        let chunk = attention_naive(&q.rows(c0, n), &k, &v, &AttnConfig::causal().at_offset(c0));
        assert_eq!(chunk.data(), &full.data()[c0 * d..], "offset oracle diverged");
    }

    #[test]
    fn one_hot_attention_selects_row() {
        // Huge scale makes softmax a hard argmax; K rows orthogonal.
        let d = 4;
        let k = Tensor::from_vec(&[4, d], {
            let mut eye = vec![0.0; 16];
            for i in 0..4 {
                eye[i * 4 + i] = 1.0;
            }
            eye
        });
        let q = k.clone();
        let mut v = Tensor::zeros(&[4, d]);
        for i in 0..4 {
            v.row_mut(i)[0] = i as f32;
        }
        let cfg = AttnConfig { scale: Some(100.0), ..Default::default() };
        let o = attention_naive(&q, &k, &v, &cfg);
        for i in 0..4 {
            assert!((o.at2(i, 0) - i as f32).abs() < 1e-3);
        }
    }

    #[test]
    fn rows_are_convex_combinations() {
        Cases::standard(401).check(|rng| {
            let n = rng.range(2, 20);
            let d = rng.range(1, 16);
            let q = Tensor::randn(&[n, d], rng);
            let k = Tensor::randn(&[n, d], rng);
            let v = Tensor::full(&[n, d], 1.0); // constant V ⇒ output must be 1
            let o = attention_naive(&q, &k, &v, &AttnConfig::default());
            for &x in o.data() {
                if (x - 1.0).abs() > 1e-4 {
                    return Err(format!("convexity violated: {x}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn heads_wrapper_matches_single() {
        let mut rng = Pcg::seeded(3);
        let mk = |rng: &mut Pcg| Tensor::randn(&[12, 8], rng);
        let (q0, k0, v0) = (mk(&mut rng), mk(&mut rng), mk(&mut rng));
        let (q1, k1, v1) = (mk(&mut rng), mk(&mut rng), mk(&mut rng));
        let cfg = AttnConfig::default();
        let outs = attention_naive_heads(
            &[q0.clone(), q1.clone()],
            &[k0.clone(), k1.clone()],
            &[v0.clone(), v1.clone()],
            &cfg,
        );
        assert_eq!(outs[0], attention_naive(&q0, &k0, &v0, &cfg));
        assert_eq!(outs[1], attention_naive(&q1, &k1, &v1, &cfg));
    }
}
