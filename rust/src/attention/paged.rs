//! Paged KV cache: fixed-size frames, copy-on-write prefix sharing, and
//! spill/restore eviction — the memory system under thousands of
//! resident sessions.
//!
//! The monolithic [`super::engine::AttnSession`] owns its KV cache as two
//! contiguous tensors, so N sessions cost N private growth curves and an
//! idle session pins its whole cache forever. This module replaces that
//! ownership with **frames**: a [`PageAllocator`] carves one up-front
//! reservation into fixed slots of exactly `b_k` rows each, recycled
//! through a free list, and a [`PagedAttnSession`] holds only a *page
//! table* (`Vec` of frame ids, one per `b_k` block of its sequence). The
//! tiled drivers never see the difference — [`PagedKv`] implements the
//! [`KvSource`] seam, resolving each `b_k`-aligned block request to
//! exactly one frame — and every per-block quantity the engines cache
//! pages along with K/V:
//!
//! - the V rows (the `P̃·V` side of [`KvSource::v_block`]),
//! - the K rows (resolved by the paged [`ScoreKernel`]s),
//! - the stage-1 pooled state (per-frame column sums + self-similarity,
//!   maintained with the same fixed-order microkernel chains as
//!   [`KPool`] — so predicted masks match the monolithic session bit for
//!   bit),
//! - and, under INT8, the per-frame [`QuantBlock`] payload of the
//!   smoothed K block (pre-reserved to `b_k × d` at construction so
//!   tail-block requantizes stay in place).
//!
//! So all three policies (dense / predicted / external) × both
//! precisions page identically — one page table serves every
//! composition.
//!
//! ## Contracts
//!
//! **Bitwise parity.** For f32 engines with λ off, a paged session's
//! prefill chunks and decode steps are *bitwise-identical* (outputs and
//! [`SkipStats`]) to the monolithic session under every `Exec` mode,
//! every pool size, and both split-KV settings: driver routing is the
//! same shape-pure [`AttnEngine::kv_span`] decision, the paged f32
//! kernel shares [`score_block_slices`] with [`F32Kernel`] (same score
//! bits from the same K bits), and frame-resident pooled state
//! reproduces [`KPool`]'s accumulation chains exactly. INT8 payloads are
//! byte-identical per block (blocks quantize independently), so the
//! quant path matches the monolithic cache kernel too.
//! `tests/paged_kv.rs` pins the full matrix.
//!
//! **Zero-alloc warmed decode.** A warmed [`PagedAttnSession::decode_into`]
//! step performs no heap allocation: frame claims pop a preallocated
//! free list, pooled updates write preallocated per-frame arrays, the
//! page table and staged sims are pre-sized to the stream's worst-case
//! block count ([`PagedAttnSession::reserve_rows`] — so even a decode
//! step that opens a new `b_k` block stays allocation-free), and all
//! per-step scratch comes from the session's [`Workspace`]/
//! [`SpanPlan`] arenas (`tests/alloc_regression.rs`).
//!
//! **Exhaustion is a value.** [`PageAllocator::claim`] returns `None`
//! when the pool is dry; session append paths *check first and decline*
//! (`false`/`None`) without touching any state, so admission control can
//! defer work instead of the allocator OOMing or panicking mid-append.
//!
//! ## Copy-on-write prefix sharing
//!
//! Two sessions opened from the same prompt map the *same* frames:
//! [`PagedAttnSession::prefill_shared`] hashes the prompt's Q/K/V bits
//! (Q included — the prefill output a borrower adopts is a function of
//! its query rows, not just the cache), and on a [`PrefixRegistry`] hit
//! — a hash match *confirmed by byte comparison* of the stored query
//! rows and the frame-resident K/V rows against the incoming prompt, so
//! a 64-bit hash collision degrades to a registry miss instead of
//! silent cross-request adoption — retains the lender's frames
//! (refcounts), adopts the cached prefill output rows (bitwise — they
//! were computed from the very same prompt bits), and skips the prefill
//! compute entirely. Frames stay shared until a writer must touch a
//! *partially filled* tail frame: the first divergent append CoW-splits
//! just that frame ([`PageAllocator::cow`]); full shared frames are
//! never written again and stay shared for the sessions' lifetimes.
//!
//! ## Eviction and re-page-in
//!
//! An idle session can be evicted ([`PagedAttnSession::evict`]): its
//! frame contents spill verbatim into a session-owned buffer, every
//! refcount is released, and the frames recycle to other sessions. The
//! next decode transparently re-pages-in ([`PagedAttnSession::ensure_resident`]):
//! fresh frames are claimed, K/V/pooled state restored bit-for-bit, and
//! INT8 payloads requantized from the restored rows (byte-identical —
//! quantization is deterministic per block). Decode after re-page-in is
//! therefore bitwise-equal to never having been evicted.
//!
//! ## Preemption: suspend / resume through an offload tier
//!
//! [`PagedAttnSession::suspend`] is eviction whose checkpoint leaves the
//! session: the spilled payload (a [`FrameCheckpoint`], including the
//! INT8 payload bytes verbatim) is handed to an [`OffloadTier`] — in
//! memory or checksummed on disk, see [`super::offload`] — under the
//! caller's key, so a preempted stream holds *zero* frames and no
//! payload buffer while parked. [`PagedAttnSession::resume`] loads the
//! checkpoint back and re-pages-in: a stream suspended mid-decode and
//! later resumed decodes bitwise-identically to one that was never
//! preempted (pinned by `tests/paged_kv.rs` across every exec mode and
//! pool size). A tier that lost or corrupted the checkpoint surfaces as
//! an [`OffloadError`] **value** — the session stays suspended and the
//! serving loop quarantines that one stream; nothing panics.

use crate::sparge::kernel::quant_score_block;
use crate::sparge::predict::{cos_sim_with_backend, predict_decode_row_into, predict_pooled};
use crate::tensor::microkernel::Backend;
use crate::tensor::quant::{self, QuantBlock};
use crate::tensor::Tensor;
use crate::util::threadpool::Workspace;

use super::engine::{AttnEngine, AttnOutput, OffsetMaskFilter, Precision, RowMaskFilter, SparsityPolicy};
use super::offload::{FrameCheckpoint, OffloadError, OffloadTier};
use super::pipeline::{
    run_tiled_into_kv, run_tiled_splitkv_into_kv, score_block_slices, BlockFilter, DenseFilter,
    Exec, KvSource, MaskFilter, ScoreKernel, ScoreScratch, SpanPlan,
};
use super::types::{AttnConfig, BlockMask, SkipStats};

#[cfg(doc)]
use super::pipeline::F32Kernel;
#[cfg(doc)]
use crate::sparge::predict::KPool;

/// Counter snapshot of a [`PageAllocator`] — the serving loop's memory
/// telemetry (`benches/table8_serving.rs` reports these per scale point).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PageStats {
    /// Total frames the pool was built with.
    pub frames: usize,
    /// Frames currently claimed by at least one holder.
    pub frames_in_use: usize,
    /// High-water mark of `frames_in_use`.
    pub peak_frames: usize,
    /// Successful frame claims over the pool's lifetime.
    pub claims: u64,
    /// Copy-on-write splits of shared frames.
    pub cow_splits: u64,
    /// Prompt-prefix registry hits (prefills skipped entirely).
    pub prefix_hits: u64,
    /// Session evictions (spill-to-buffer events).
    pub evictions: u64,
    /// Admissions deferred because the free list could not cover them.
    pub load_sheds: u64,
    /// Bytes of payload one frame carries (K + V + pooled state + INT8)
    /// — `peak_frames * frame_bytes` is the pool's high-water resident
    /// footprint.
    pub frame_bytes: usize,
}

/// A pool of fixed `b_k`-row KV frames recycled through a free list.
///
/// All storage — K rows, V rows, per-frame pooled sums/similarity, and
/// (for INT8 engines) per-frame quantized payloads — is allocated once
/// at construction as parallel per-frame arrays; nothing on the claim /
/// release / append path allocates. Frames are refcounted so prompt
/// prefixes can be shared; see the module docs for the CoW discipline.
pub struct PageAllocator {
    bk: usize,
    d: usize,
    dv: usize,
    quant: bool,
    /// K rows, `frames × bk × d`.
    k: Vec<f32>,
    /// V rows, `frames × bk × dv`.
    v: Vec<f32>,
    /// Per-frame pooled column sums (`frames × d`) — the paged
    /// equivalent of `KPool`'s per-block sums, same accumulation chains.
    psum: Vec<f32>,
    /// Rows currently held per frame (0..=bk).
    prow: Vec<usize>,
    /// Per-frame self-similarity (stage-1 `sim_k`).
    sim: Vec<f32>,
    /// Per-frame INT8 payload of the smoothed K block; empty unless the
    /// pool was built `with_quant` (payloads pre-reserved to `bk × d`).
    qk: Vec<QuantBlock>,
    /// Per-frame refcount; 0 = on the free list.
    rc: Vec<u32>,
    /// Free frame ids; preallocated to full capacity so `release` never
    /// allocates.
    free: Vec<usize>,
    frames_in_use: usize,
    peak_frames: usize,
    claims: u64,
    cow_splits: u64,
    prefix_hits: u64,
    evictions: u64,
    load_sheds: u64,
    /// Remaining artificial claim denials (fault injection): while
    /// nonzero, `claim` reports exhaustion and decrements. Always 0
    /// outside injected-fault runs — one compare on the claim path.
    deny_claims: u64,
}

impl PageAllocator {
    /// Build a pool of `frames` frames of `bk` rows each (K width `d`,
    /// V width `dv`). Everything is allocated here, once.
    pub fn new(frames: usize, bk: usize, d: usize, dv: usize) -> PageAllocator {
        assert!(frames > 0 && bk > 0 && d > 0 && dv > 0, "PageAllocator needs positive geometry");
        PageAllocator {
            bk,
            d,
            dv,
            quant: false,
            k: vec![0.0; frames * bk * d],
            v: vec![0.0; frames * bk * dv],
            psum: vec![0.0; frames * d],
            prow: vec![0; frames],
            sim: vec![1.0; frames],
            qk: Vec::new(),
            rc: vec![0; frames],
            // claim pops from the back: seed in reverse so frames hand
            // out in ascending id order (deterministic, debuggable)
            free: (0..frames).rev().collect(),
            frames_in_use: 0,
            peak_frames: 0,
            claims: 0,
            cow_splits: 0,
            prefix_hits: 0,
            evictions: 0,
            load_sheds: 0,
            deny_claims: 0,
        }
    }

    /// Add per-frame INT8 payload storage (required before serving an
    /// `Precision::Int8` engine). Payloads are pre-reserved to the full
    /// `bk × d` so in-place tail requantizes never grow them.
    pub fn with_quant(mut self) -> PageAllocator {
        let frames = self.prow.len();
        self.qk = (0..frames)
            .map(|_| QuantBlock {
                data: Vec::with_capacity(self.bk * self.d),
                rows: 0,
                d: self.d,
                scale: 1.0,
            })
            .collect();
        self.quant = true;
        self
    }

    /// Frame geometry: rows per frame (`b_k`).
    pub fn block_rows(&self) -> usize {
        self.bk
    }

    /// Frame geometry: K head dim and V dim the pool was built with
    /// (admission control screens stream shapes against these).
    pub fn head_dims(&self) -> (usize, usize) {
        (self.d, self.dv)
    }

    /// Total frames in the pool.
    pub fn capacity(&self) -> usize {
        self.prow.len()
    }

    /// Frames currently on the free list.
    pub fn free_frames(&self) -> usize {
        self.free.len()
    }

    /// Bytes of payload one frame carries (K + V + pooled state + INT8).
    pub fn frame_bytes(&self) -> usize {
        let f32s = self.bk * self.d + self.bk * self.dv + self.d + 1;
        let i8s = if self.quant { self.bk * self.d } else { 0 };
        f32s * std::mem::size_of::<f32>() + i8s
    }

    /// High-water resident bytes (peak frames × frame bytes).
    pub fn peak_bytes(&self) -> usize {
        self.peak_frames * self.frame_bytes()
    }

    /// Counter snapshot (see [`PageStats`]).
    pub fn stats(&self) -> PageStats {
        PageStats {
            frames: self.capacity(),
            frames_in_use: self.frames_in_use,
            peak_frames: self.peak_frames,
            claims: self.claims,
            cow_splits: self.cow_splits,
            prefix_hits: self.prefix_hits,
            evictions: self.evictions,
            load_sheds: self.load_sheds,
            frame_bytes: self.frame_bytes(),
        }
    }

    /// Record one load-shed (deferred admission) event. Kept on the
    /// allocator so memory pressure telemetry lives in one place.
    pub fn note_load_shed(&mut self) {
        self.load_sheds += 1;
    }

    /// Fault injection: deny the next `n` claim attempts as if the pool
    /// were exhausted — a [`PageAllocator::covers`] check fails (the
    /// caller defers/evicts exactly as a dry pool forces) and a direct
    /// [`PageAllocator::claim`] returns `None`. Cumulative; each denial
    /// is consumed by whichever of the two sees it first.
    pub fn inject_exhaustion(&mut self, n: u64) {
        self.deny_claims += n;
    }

    /// Artificial denials still pending (nonzero only mid-injection).
    pub fn pending_denials(&self) -> u64 {
        self.deny_claims
    }

    /// Admission check for a sequence of `frames` claims: true when the
    /// free list covers them all, so the session paths may
    /// check-then-claim without re-testing each claim. A pending
    /// injected denial is consumed *here* and fails the check — the
    /// caller takes the identical defer/evict path a really-dry pool
    /// forces, and the claims behind a passed check always succeed
    /// (which is what the `expect`s on those claims assert). Zero-frame
    /// requests pass without consuming anything: no claim will follow.
    pub fn covers(&mut self, frames: usize) -> bool {
        if frames == 0 {
            return true;
        }
        if self.deny_claims > 0 {
            self.deny_claims -= 1;
            return false;
        }
        self.free.len() >= frames
    }

    /// Frame-leak check for tests and drain: every frame must be back on
    /// the free list with refcount 0. Any leaked frame (or a
    /// `PrefixRegistry` still holding a refcount) fails loudly with the
    /// offending frame ids.
    pub fn assert_all_free(&self) {
        assert_eq!(
            self.frames_in_use, 0,
            "frame leak: {} frames still in use of {}",
            self.frames_in_use,
            self.capacity()
        );
        assert_eq!(
            self.free.len(),
            self.capacity(),
            "frame leak: free list holds {} of {} frames",
            self.free.len(),
            self.capacity()
        );
        let held: Vec<usize> =
            (0..self.rc.len()).filter(|&f| self.rc[f] != 0).collect();
        assert!(held.is_empty(), "frame leak: frames {held:?} still refcounted");
    }

    /// Claim one free frame (refcount 1, zeroed pooled state), or `None`
    /// when the pool is dry — exhaustion is a value, never a panic. Pops
    /// the preallocated free list: no allocation.
    pub fn claim(&mut self) -> Option<usize> {
        if self.deny_claims > 0 {
            // injected exhaustion: report a dry pool through the normal
            // value path, so recovery machinery sees exactly what a real
            // exhaustion produces
            self.deny_claims -= 1;
            return None;
        }
        let f = self.free.pop()?;
        self.rc[f] = 1;
        self.prow[f] = 0;
        self.psum[f * self.d..(f + 1) * self.d].fill(0.0);
        self.sim[f] = 1.0;
        self.claims += 1;
        self.frames_in_use += 1;
        self.peak_frames = self.peak_frames.max(self.frames_in_use);
        Some(f)
    }

    /// Add one reference to a claimed frame (prefix sharing).
    pub fn retain(&mut self, f: usize) {
        debug_assert!(self.rc[f] > 0, "retain of a free frame");
        self.rc[f] += 1;
    }

    /// Drop one reference; the frame recycles to the free list when the
    /// last holder releases (push into preallocated capacity — no
    /// allocation).
    pub fn release(&mut self, f: usize) {
        debug_assert!(self.rc[f] > 0, "release of a free frame");
        self.rc[f] -= 1;
        if self.rc[f] == 0 {
            self.free.push(f);
            self.frames_in_use -= 1;
        }
    }

    /// Whether `f` has more than one holder (writes require CoW).
    pub fn shared(&self, f: usize) -> bool {
        self.rc[f] > 1
    }

    /// Copy-on-write: return a frame the caller may write. Exclusive
    /// frames come back unchanged; shared frames are split — a fresh
    /// frame is claimed, the full contents (K, V, pooled state, INT8
    /// payload) copied over, and the caller's reference moved to the
    /// copy. `None` if a split was needed and the pool is dry (caller
    /// state untouched).
    pub fn cow(&mut self, f: usize) -> Option<usize> {
        if self.rc[f] == 1 {
            return Some(f);
        }
        let g = self.claim()?;
        let (bk, d, dv) = (self.bk, self.d, self.dv);
        self.k.copy_within(f * bk * d..(f + 1) * bk * d, g * bk * d);
        self.v.copy_within(f * bk * dv..(f + 1) * bk * dv, g * bk * dv);
        self.psum.copy_within(f * d..(f + 1) * d, g * d);
        self.prow[g] = self.prow[f];
        self.sim[g] = self.sim[f];
        if self.quant {
            // two disjoint references into qk: split at the larger index
            let (lo, hi) = if f < g { (f, g) } else { (g, f) };
            let (a, b) = self.qk.split_at_mut(hi);
            let (src, dst): (&QuantBlock, &mut QuantBlock) =
                if f < g { (&a[lo], &mut b[0]) } else { (&b[0], &mut a[lo]) };
            dst.data.clear();
            dst.data.extend_from_slice(&src.data);
            dst.rows = src.rows;
            dst.d = src.d;
            dst.scale = src.scale;
        }
        // move our reference: the shared original keeps its other holders
        self.rc[f] -= 1;
        self.cow_splits += 1;
        Some(g)
    }

    /// Append `rows` K/V rows into frame `f` (which must have room),
    /// maintaining the pooled column sums with the same fixed-order
    /// [`Backend::sum_rows_acc`] chain as [`KPool`] — bitwise parity by
    /// construction.
    fn push_rows(&mut self, f: usize, krows: &[f32], vrows: &[f32], rows: usize, mk: Backend) {
        let (bk, d, dv) = (self.bk, self.d, self.dv);
        let r = self.prow[f];
        debug_assert!(r + rows <= bk, "frame overflow");
        debug_assert_eq!(krows.len(), rows * d);
        debug_assert_eq!(vrows.len(), rows * dv);
        self.k[f * bk * d + r * d..f * bk * d + (r + rows) * d].copy_from_slice(krows);
        self.v[f * bk * dv + r * dv..f * bk * dv + (r + rows) * dv].copy_from_slice(vrows);
        mk.sum_rows_acc(krows, &mut self.psum[f * d..(f + 1) * d], rows, d);
        self.prow[f] = r + rows;
    }

    /// Recompute frame `f`'s self-similarity from its own K rows —
    /// exactly [`KPool::append_row`]'s tail recompute (same function,
    /// same slice bits). `scratch` is the session's normalization buffer.
    fn refresh_sim(&mut self, f: usize, mk: Backend, scratch: &mut Vec<f32>) {
        let (bk, d) = (self.bk, self.d);
        let rows = self.prow[f];
        let s = cos_sim_with_backend(mk, &self.k[f * bk * d..f * bk * d + rows * d], rows, d, scratch);
        self.sim[f] = s;
    }

    /// (Re)quantize frame `f`'s K rows with the session's frozen
    /// smoothing mean, in place into the pre-reserved payload — the
    /// paged equivalent of the monolithic tail-block requantize, with
    /// byte-identical payloads (blocks quantize independently).
    fn requantize_frame(&mut self, f: usize, kmean: &[f32], stage: &mut Vec<f32>) {
        debug_assert!(self.quant, "requantize on a pool built without with_quant()");
        let (bk, d) = (self.bk, self.d);
        let rows = self.prow[f];
        stage.clear();
        stage.extend_from_slice(&self.k[f * bk * d..f * bk * d + rows * d]);
        for row in stage.chunks_mut(d) {
            for (x, &m) in row.iter_mut().zip(kmean) {
                *x -= m;
            }
        }
        self.qk[f].requantize(stage, rows, d);
    }
}

/// A paged [`KvSource`]: the tiled drivers' view of one session's page
/// table. Each `b_k`-aligned block request resolves to exactly one
/// frame (the page-table lookup is one index per visited block).
pub struct PagedKv<'a> {
    alloc: &'a PageAllocator,
    frames: &'a [usize],
    rows: usize,
}

impl KvSource for PagedKv<'_> {
    fn rows(&self) -> usize {
        self.rows
    }

    fn dv(&self) -> usize {
        self.alloc.dv
    }

    fn v_block(&self, k0: usize, k1: usize) -> &[f32] {
        let (bk, dv) = (self.alloc.bk, self.alloc.dv);
        debug_assert_eq!(k0 % bk, 0, "KvSource callers request b_k-aligned blocks");
        debug_assert!(k1 - k0 <= bk);
        let f = self.frames[k0 / bk];
        let base = f * bk * dv;
        &self.alloc.v[base..base + (k1 - k0) * dv]
    }
}

/// f32 score kernel over paged K frames: shares [`score_block_slices`]
/// with [`F32Kernel`], so paged scores are bitwise-identical to the
/// monolithic cache (the K bits are the same rows, frame-resident).
struct PagedF32Kernel<'a> {
    q: &'a Tensor,
    alloc: &'a PageAllocator,
    frames: &'a [usize],
    scale: f32,
    causal: bool,
    row_offset: usize,
    mk: Backend,
}

impl ScoreKernel for PagedF32Kernel<'_> {
    fn score_block(
        &self,
        q0: usize,
        q1: usize,
        k0: usize,
        k1: usize,
        out: &mut [f32],
        _scratch: &mut ScoreScratch<'_>,
    ) {
        let (bk, d) = (self.alloc.bk, self.alloc.d);
        let f = self.frames[k0 / bk];
        let ks = &self.alloc.k[f * bk * d..f * bk * d + (k1 - k0) * d];
        score_block_slices(
            self.mk,
            &self.q.data()[q0 * d..q1 * d],
            ks,
            q1 - q0,
            k1 - k0,
            d,
            self.row_offset + q0,
            k0,
            self.scale,
            self.causal,
            out,
        );
    }

    fn microkernel(&self) -> Backend {
        self.mk
    }
}

/// INT8 score kernel over paged K frames: Q comes from the session's
/// staged blocks, K from each frame's cached payload — the paged twin of
/// the monolithic session's cache kernel, sharing `quant_score_block`.
struct PagedQuantKernel<'a> {
    qb: &'a [QuantBlock],
    alloc: &'a PageAllocator,
    frames: &'a [usize],
    scale: f32,
    causal: bool,
    row_offset: usize,
    bq: usize,
    mk: Backend,
}

impl ScoreKernel for PagedQuantKernel<'_> {
    fn score_block(
        &self,
        q0: usize,
        _q1: usize,
        k0: usize,
        _k1: usize,
        out: &mut [f32],
        scratch: &mut ScoreScratch<'_>,
    ) {
        let qblk = &self.qb[q0 / self.bq];
        let kblk = &self.alloc.qk[self.frames[k0 / self.alloc.bk]];
        quant_score_block(
            self.mk,
            qblk,
            kblk,
            self.row_offset + q0,
            k0,
            self.scale,
            self.causal,
            out,
            scratch.acc_i32,
        );
    }

    fn microkernel(&self) -> Backend {
        self.mk
    }
}

/// FNV-1a 64 over a prompt's Q/K/V bits (dims folded in) — the
/// [`PrefixRegistry`] key. Q participates because a registry hit adopts
/// the cached prefill *output*, which is a function of the query rows,
/// not just of the K/V cache. Exact bit equality, no float tolerance —
/// and the hash is only a fast filter: a hit is confirmed by byte
/// comparison before any sharing (see [`PrefixRegistry`]).
pub fn prefix_hash(q: &Tensor, k: &Tensor, v: &Tensor) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    let mut mix = |w: u64| {
        h ^= w;
        h = h.wrapping_mul(PRIME);
    };
    mix(k.dim(0) as u64);
    mix(q.dim(1) as u64);
    mix(k.dim(1) as u64);
    mix(v.dim(1) as u64);
    for &x in q.data() {
        mix(x.to_bits() as u64);
    }
    for &x in k.data() {
        mix(x.to_bits() as u64);
    }
    for &x in v.data() {
        mix(x.to_bits() as u64);
    }
    h
}

/// Exact bit equality of two f32 slices (NaN-safe: compared as bits, so
/// a NaN payload mismatch is a mismatch, never a spurious match).
fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// One registered shared prompt prefix: the frames (the registry holds
/// one refcount on each), the cached prefill result, and the session
/// state a borrower must adopt to stay bitwise-consistent.
struct PrefixEntry {
    hash: u64,
    rows: usize,
    frames: Vec<usize>,
    /// The lender's query rows, verbatim: the cached `out` below is a
    /// function of Q, so a borrower must present bit-identical query
    /// rows — the K/V side is verified against the frames themselves.
    q: Tensor,
    /// Frozen K-smoothing mean the lender quantized the shared frames
    /// with (INT8 engines); borrowers adopt it so the shared payloads
    /// stay consistent with their own later appends.
    kmean: Option<Vec<f32>>,
    out: Tensor,
    stats: SkipStats,
    mask: Option<BlockMask>,
    hits: u64,
}

/// Registry of shared prompt prefixes, keyed on [`prefix_hash`]. A hash
/// hit is never trusted on its own: the candidate's stored query rows
/// and frame-resident K/V rows are byte-compared against the incoming
/// prompt before sharing, so a 64-bit collision maps nothing — it just
/// misses and recomputes. The registry retains its own reference on
/// every registered frame, so a prefix outlives the session that
/// created it until [`PrefixRegistry::clear`] releases it.
#[derive(Default)]
pub struct PrefixRegistry {
    entries: Vec<PrefixEntry>,
}

impl PrefixRegistry {
    pub fn new() -> PrefixRegistry {
        PrefixRegistry { entries: Vec::new() }
    }

    /// Registered prefixes.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Total lookup hits across all entries.
    pub fn hits(&self) -> u64 {
        self.entries.iter().map(|e| e.hits).sum()
    }

    fn find(&self, alloc: &PageAllocator, hash: u64, q: &Tensor, k: &Tensor, v: &Tensor) -> Option<usize> {
        self.entries.iter().position(|e| {
            e.hash == hash
                && e.rows == k.dim(0)
                && bits_eq(e.q.data(), q.data())
                && Self::frames_match(alloc, &e.frames, k, v)
        })
    }

    /// Byte-verify a candidate entry: every frame's resident K/V rows
    /// must equal the incoming prompt's bit for bit. Shared frames are
    /// never written in place (full frames are read-only by the CoW
    /// discipline, and the registry's own reference forces a CoW split
    /// on any tail write), so the frames still hold the exact bits the
    /// entry was registered with.
    fn frames_match(alloc: &PageAllocator, frames: &[usize], k: &Tensor, v: &Tensor) -> bool {
        let (bk, d, dv) = (alloc.bk, alloc.d, alloc.dv);
        let rows = k.dim(0);
        if k.dim(1) != d || v.dim(1) != dv || frames.len() != rows.div_ceil(bk) {
            return false;
        }
        frames.iter().enumerate().all(|(b, &f)| {
            let r0 = b * bk;
            let r = alloc.prow[f];
            r == (rows - r0).min(bk)
                && bits_eq(&alloc.k[f * bk * d..f * bk * d + r * d], &k.data()[r0 * d..(r0 + r) * d])
                && bits_eq(&alloc.v[f * bk * dv..f * bk * dv + r * dv], &v.data()[r0 * dv..(r0 + r) * dv])
        })
    }

    /// Reclaim one registered prefix under memory pressure: drop the
    /// least-hit entry whose frames no live session references anymore
    /// (every refcount is the registry's own), releasing its frames to
    /// the free list. `false` when every entry is still shared with a
    /// session — those frames are not the registry's to give back.
    pub fn shed(&mut self, alloc: &mut PageAllocator) -> bool {
        let mut best: Option<usize> = None;
        for (i, e) in self.entries.iter().enumerate() {
            if e.frames.iter().any(|&f| alloc.shared(f)) {
                continue;
            }
            if best.map_or(true, |b| e.hits < self.entries[b].hits) {
                best = Some(i);
            }
        }
        let Some(i) = best else { return false };
        let e = self.entries.remove(i);
        for &f in &e.frames {
            alloc.release(f);
        }
        true
    }

    /// Release every registry-held frame reference and forget all
    /// entries (frames shared with live sessions stay resident through
    /// the sessions' own references).
    pub fn clear(&mut self, alloc: &mut PageAllocator) {
        for e in &self.entries {
            for &f in &e.frames {
                alloc.release(f);
            }
        }
        self.entries.clear();
    }
}

/// Per-sequence state over a shared [`AttnEngine`] whose KV cache lives
/// in [`PageAllocator`] frames instead of session-owned tensors. Append
/// paths take `&mut PageAllocator` (they claim/write frames); compute
/// paths take `&PageAllocator` — so a serving tick appends serially and
/// then fans the compute of many sessions over one shared `&alloc`.
/// See the module docs for the parity / zero-alloc / exhaustion
/// contracts.
pub struct PagedAttnSession<'e> {
    engine: &'e AttnEngine,
    d: usize,
    dv: usize,
    rows: usize,
    /// The page table: frame id of each `b_k` block, in sequence order.
    frames: Vec<usize>,
    /// Frozen K-smoothing channel mean (INT8 only; see the monolithic
    /// session — adopted from the registry on a prefix hit).
    kmean: Option<Vec<f32>>,
    /// Reusable Q-side quantization staging (INT8).
    qstage: Vec<QuantBlock>,
    /// Session-owned decode mask (`Predicted` policy), rebuilt in place.
    pred_mask: BlockMask,
    /// Staged per-frame sims for the predictor (means stage through the
    /// workspace arena) — refilled per step within capacity.
    pred_sims: Vec<f32>,
    /// Normalization scratch for the per-frame sim recompute (the paged
    /// twin of `KPool::scratch`).
    pool_scratch: Vec<f32>,
    ws: Workspace,
    plan: SpanPlan,
    steps: usize,
    evicted: bool,
    /// Whether the checkpoint was handed to an [`OffloadTier`]
    /// ([`PagedAttnSession::suspend`]) — resume must load it back before
    /// re-page-in can run.
    suspended: bool,
    /// Spilled frame payload while evicted (the old session-private
    /// `Spill` buffer, now the tier currency — see [`FrameCheckpoint`]).
    /// Empty whenever the payload is parked in a tier instead.
    ckpt: FrameCheckpoint,
}

impl<'e> PagedAttnSession<'e> {
    /// Open a paged session over `engine`. Frame geometry is checked
    /// against the allocator at first append.
    pub fn new(engine: &'e AttnEngine) -> PagedAttnSession<'e> {
        assert_eq!(
            engine.config().row_offset,
            0,
            "sessions manage row_offset; build the engine with offset 0"
        );
        PagedAttnSession {
            engine,
            d: 0,
            dv: 0,
            rows: 0,
            frames: Vec::new(),
            kmean: None,
            qstage: Vec::new(),
            pred_mask: BlockMask::new_all(0, 0, false),
            pred_sims: Vec::new(),
            pool_scratch: Vec::new(),
            ws: Workspace::default(),
            plan: SpanPlan::new(),
            steps: 0,
            evicted: false,
            suspended: false,
            ckpt: FrameCheckpoint::default(),
        }
    }

    /// Cached sequence length (rows of K/V seen so far).
    pub fn len(&self) -> usize {
        self.rows
    }

    pub fn is_empty(&self) -> bool {
        self.rows == 0
    }

    /// Decode steps taken so far.
    pub fn steps(&self) -> usize {
        self.steps
    }

    /// Frames this session currently references (0 while evicted).
    pub fn frames_held(&self) -> usize {
        self.frames.len()
    }

    /// Whether the session's frames are spilled (re-page-in needed
    /// before the next append/compute).
    pub fn is_evicted(&self) -> bool {
        self.evicted
    }

    /// Whether the session's checkpoint is parked in an offload tier —
    /// [`PagedAttnSession::resume`] must load it back before the session
    /// can become resident again.
    pub fn is_suspended(&self) -> bool {
        self.suspended
    }

    /// Frames a sequence of `rows` rows occupies under this allocator
    /// geometry (the admission-control unit).
    pub fn frames_for_rows(rows: usize, bk: usize) -> usize {
        rows.div_ceil(bk)
    }

    /// Pre-size the page table and the predictor's staged sims for a
    /// stream of `rows` total K/V rows, so no later frame claim grows
    /// them — this is what makes a warmed decode step that opens a new
    /// `b_k` block allocation-free, the paged twin of the monolithic
    /// session's `reserve_rows` amortization. The serving manager calls
    /// this at admission with the stream's full length; standalone
    /// sessions that skip it fall back to `Vec`'s amortized doubling.
    pub fn reserve_rows(&mut self, alloc: &PageAllocator, rows: usize) {
        let blocks = Self::frames_for_rows(rows, alloc.bk);
        self.frames.reserve(blocks.saturating_sub(self.frames.len()));
        self.pred_sims.reserve(blocks.saturating_sub(self.pred_sims.len()));
    }

    fn pooled(&self) -> bool {
        matches!(self.engine.policy(), SparsityPolicy::Predicted { .. })
    }

    fn init_dims(&mut self, alloc: &PageAllocator, k: &Tensor, v: &Tensor) {
        self.d = k.dim(1);
        self.dv = v.dim(1);
        assert_eq!(alloc.bk, self.engine.config().bk, "allocator frame rows must equal the engine's b_k");
        assert_eq!(alloc.d, self.d, "allocator K width");
        assert_eq!(alloc.dv, self.dv, "allocator V width");
        if self.engine.precision() == Precision::Int8 {
            assert!(alloc.quant, "INT8 engines need a PageAllocator built with_quant()");
        }
    }

    /// Frames an append of `new_rows` rows needs *now*: fresh frames for
    /// new blocks, plus one transient frame when the partially-filled
    /// shared tail must CoW-split first.
    fn frames_needed(&self, alloc: &PageAllocator, new_rows: usize) -> usize {
        let bk = alloc.bk;
        let blocks_after = (self.rows + new_rows).div_ceil(bk);
        let mut needed = blocks_after - self.frames.len();
        if self.rows % bk != 0 && alloc.shared(self.frames[self.frames.len() - 1]) {
            needed += 1;
        }
        needed
    }

    /// Prefill an empty session in one shot (a single chunk from empty).
    pub fn prefill(&mut self, alloc: &mut PageAllocator, q: &Tensor, k: &Tensor, v: &Tensor) -> Option<AttnOutput> {
        assert_eq!(self.rows, 0, "prefill on a non-empty session; use prefill_chunk()/decode()");
        self.prefill_chunk(alloc, q, k, v)
    }

    /// Append one prompt chunk and run its query rows against the whole
    /// paged cache, offset-aware — the paged twin of the monolithic
    /// `prefill_chunk`, bitwise-identical to it policy for policy (see
    /// module docs). Returns `None` — with **no state touched** — when
    /// the free list cannot cover the chunk's frames; the caller defers
    /// and retries after frames free up.
    pub fn prefill_chunk(
        &mut self,
        alloc: &mut PageAllocator,
        q: &Tensor,
        k: &Tensor,
        v: &Tensor,
    ) -> Option<AttnOutput> {
        assert_eq!(q.dim(0), k.dim(0), "prefill chunk q/k rows");
        assert_eq!(k.dim(0), v.dim(0), "k/v rows");
        assert!(k.dim(0) > 0, "empty prefill chunk");
        if !self.ensure_resident(alloc) {
            return None;
        }
        let row0 = self.rows;
        assert!(
            row0 == 0 || self.engine.config().causal,
            "multi-chunk prefill needs a causal engine (later rows are not cached yet)"
        );
        if row0 == 0 {
            self.init_dims(alloc, k, v);
            if self.engine.precision() == Precision::Int8 {
                self.kmean = Some(quant::channel_mean(k));
            }
        }
        assert_eq!(q.dim(1), self.d, "q head dim");
        assert_eq!(k.dim(1), self.d, "k head dim");
        assert_eq!(v.dim(1), self.dv, "v dim");

        if !alloc.covers(self.frames_needed(alloc, k.dim(0))) {
            return None;
        }
        self.append_rows(alloc, k, v, row0);
        if self.engine.precision() == Precision::Int8 {
            self.requantize_from(alloc, row0);
            quant::quantize_blocks_into(q, self.engine.config().bq, &mut self.qstage);
        }

        let cfg = self.engine.config().at_offset(row0);
        let mut out = Tensor::zeros(&[q.dim(0), self.dv]);
        let mut ws = std::mem::take(&mut self.ws);
        let mut plan = std::mem::take(&mut self.plan);
        let exec = self.engine.exec();
        let (stats, mask) = match self.engine.policy() {
            SparsityPolicy::Dense => {
                let st = self.run_paged(alloc, q, &cfg, &DenseFilter, exec, &mut plan, &mut ws, out.data_mut());
                (st, None)
            }
            SparsityPolicy::Predicted { params, lambda } => {
                // pooled K side straight off the frames — bitwise equal
                // to the monolithic KPool means/sims (same chains)
                let kt = self.frame_means(alloc);
                self.stage_sims(alloc);
                let pred = predict_pooled(q, &kt, &self.pred_sims, &cfg, params);
                let st = {
                    let filter = MaskFilter::new(&pred.mask, *lambda);
                    self.run_paged(alloc, q, &cfg, &filter, exec, &mut plan, &mut ws, out.data_mut())
                };
                (st, Some(pred.mask))
            }
            SparsityPolicy::External { mask, lambda } => {
                let cfg_bq = cfg.bq;
                assert_eq!(
                    row0 % cfg_bq,
                    0,
                    "chunked prefill under an external mask must start at a b_q boundary"
                );
                let row0_blocks = row0 / cfg_bq;
                assert!(
                    mask.rows >= row0_blocks + cfg.n_qblocks(q.dim(0)),
                    "external mask has {} block rows; chunk needs {}",
                    mask.rows,
                    row0_blocks + cfg.n_qblocks(q.dim(0))
                );
                assert!(
                    mask.cols >= cfg.n_kblocks(self.rows),
                    "external mask has {} block cols; cache needs {}",
                    mask.cols,
                    cfg.n_kblocks(self.rows)
                );
                let filter = OffsetMaskFilter { mask, row0: row0_blocks, lambda: *lambda };
                let st = self.run_paged(alloc, q, &cfg, &filter, exec, &mut plan, &mut ws, out.data_mut());
                (st, None)
            }
        };
        self.ws = ws;
        self.plan = plan;
        Some(AttnOutput { out, stats, mask })
    }

    /// Prefill through the shared-prefix registry: on a hit (hash match
    /// byte-verified against the stored query rows and frame contents)
    /// the session maps the lender's frames (refcounted, zero new frames
    /// for the prefix), adopts the cached prefill rows bitwise, and
    /// skips the compute; on a miss — including a prompt whose K/V match
    /// a registered entry but whose Q differs, since the cached output
    /// depends on Q — it prefills normally and registers the result.
    /// `None` on frame exhaustion (miss path only), session untouched.
    pub fn prefill_shared(
        &mut self,
        alloc: &mut PageAllocator,
        registry: &mut PrefixRegistry,
        q: &Tensor,
        k: &Tensor,
        v: &Tensor,
    ) -> Option<AttnOutput> {
        assert_eq!(self.rows, 0, "prefill_shared opens a session");
        assert_eq!(q.dim(0), k.dim(0), "prefill chunk q/k rows");
        let h = prefix_hash(q, k, v);
        if let Some(i) = registry.find(alloc, h, q, k, v) {
            let entry = &mut registry.entries[i];
            entry.hits += 1;
            alloc.prefix_hits += 1;
            for &f in &entry.frames {
                alloc.retain(f);
            }
            self.init_dims(alloc, k, v);
            self.rows = entry.rows;
            self.frames.extend_from_slice(&entry.frames);
            self.kmean = entry.kmean.clone();
            return Some(AttnOutput {
                out: entry.out.clone(),
                stats: entry.stats,
                mask: entry.mask.clone(),
            });
        }
        let r = self.prefill_chunk(alloc, q, k, v)?;
        for &f in &self.frames {
            alloc.retain(f);
        }
        registry.entries.push(PrefixEntry {
            hash: h,
            rows: self.rows,
            frames: self.frames.clone(),
            q: q.clone(),
            kmean: self.kmean.clone(),
            out: r.out.clone(),
            stats: r.stats,
            mask: r.mask.clone(),
            hits: 0,
        });
        Some(r)
    }

    /// The append half of a decode step: claim/CoW the tail frame, write
    /// the K/V row, maintain pooled state, requantize the tail payload
    /// (INT8). Returns `false` — session untouched — when the free list
    /// cannot cover the claim; the serving tick skips the session and
    /// retries next tick. Allocation-free once warm.
    pub fn append_token(&mut self, alloc: &mut PageAllocator, q: &Tensor, k: &Tensor, v: &Tensor) -> bool {
        assert_eq!(q.dim(0), 1, "decode takes a single query row");
        assert_eq!(k.dim(0), 1, "decode takes a single key row");
        assert_eq!(v.dim(0), 1, "decode takes a single value row");
        debug_assert!(!self.evicted, "ensure_resident before appending");
        if self.rows == 0 {
            self.init_dims(alloc, k, v);
            if self.engine.precision() == Precision::Int8 {
                // Init-on-empty: runs once on the first appended token,
                // before the session is warm. sparge-lint: allow(hot-path-no-alloc)
                self.kmean = Some(vec![0.0; self.d]);
            }
        }
        assert_eq!(q.dim(1), self.d, "q head dim");
        assert_eq!(k.dim(1), self.d, "k head dim");
        assert_eq!(v.dim(1), self.dv, "v dim");
        if !alloc.covers(self.frames_needed(alloc, 1)) {
            return false;
        }
        let bk = alloc.bk;
        let mk = self.engine.microkernel();
        let f = if self.rows % bk == 0 {
            let g = alloc.claim().expect("free-frame check covers the claim");
            self.frames.push(g);
            g
        } else {
            let tail = self.frames[self.frames.len() - 1];
            let g = alloc.cow(tail).expect("free-frame check covers the CoW claim");
            let last = self.frames.len() - 1;
            self.frames[last] = g;
            g
        };
        alloc.push_rows(f, k.row(0), v.row(0), 1, mk);
        if self.pooled() {
            alloc.refresh_sim(f, mk, &mut self.pool_scratch);
        }
        self.rows += 1;
        if self.engine.precision() == Precision::Int8 {
            let mean = self.kmean.as_deref().expect("kmean frozen at first append");
            alloc.requantize_frame(f, mean, &mut self.ws.quant_f32);
            quant::quantize_blocks_into(q, self.engine.config().bq, &mut self.qstage);
        }
        true
    }

    /// The compute half of a decode step: run the 1-row call over the
    /// paged cache under `exec`, writing the output row into `out`.
    /// Takes the allocator by shared reference so a serving tick can fan
    /// many sessions' steps over one `&alloc`. The bool is true when the
    /// step refreshed [`PagedAttnSession::pred_mask`] (`Predicted`
    /// policy).
    pub fn decode_step(
        &mut self,
        alloc: &PageAllocator,
        q: &Tensor,
        exec: Exec<'_>,
        out: &mut [f32],
    ) -> (SkipStats, bool) {
        debug_assert!(!self.evicted, "ensure_resident before computing");
        let step_cfg = AttnConfig { causal: false, ..*self.engine.config() };
        let scale = step_cfg.scale_for(self.d);
        let mut ws = std::mem::take(&mut self.ws);
        let mut plan = std::mem::take(&mut self.plan);
        let res = match self.engine.policy() {
            SparsityPolicy::Dense => {
                let st = self.run_paged(alloc, q, &step_cfg, &DenseFilter, exec, &mut plan, &mut ws, out);
                (st, false)
            }
            SparsityPolicy::Predicted { params, lambda } => {
                self.stage_means(alloc, &mut ws.pred_means);
                self.stage_sims(alloc);
                predict_decode_row_into(
                    q.row(0),
                    &ws.pred_means,
                    &self.pred_sims,
                    scale,
                    params,
                    &mut self.pred_mask,
                    &mut ws.pred_scores,
                    &mut ws.pred_probs,
                    &mut ws.pred_idx,
                );
                let st = {
                    let filter = MaskFilter::new(&self.pred_mask, *lambda);
                    self.run_paged(alloc, q, &step_cfg, &filter, exec, &mut plan, &mut ws, out)
                };
                (st, true)
            }
            SparsityPolicy::External { mask, lambda } => {
                let bi = (self.rows - 1) / step_cfg.bq;
                assert!(bi < mask.rows, "external mask has {} block rows; decode is at row {bi}", mask.rows);
                assert!(
                    step_cfg.n_kblocks(self.rows) <= mask.cols,
                    "external mask has {} block cols; cache needs {}",
                    mask.cols,
                    step_cfg.n_kblocks(self.rows)
                );
                let filter = RowMaskFilter { mask, row: bi, lambda: *lambda };
                let st = self.run_paged(alloc, q, &step_cfg, &filter, exec, &mut plan, &mut ws, out);
                (st, false)
            }
        };
        self.ws = ws;
        self.plan = plan;
        self.steps += 1;
        res
    }

    /// Decode one token into `out` (length dv): transparent re-page-in
    /// if evicted, then append + compute under the engine's executor.
    /// `None` — session untouched — when frames cannot cover the
    /// re-page-in or the append. Bitwise-identical to the monolithic
    /// `decode_into` for f32/λ-off engines.
    pub fn decode_into(
        &mut self,
        alloc: &mut PageAllocator,
        q: &Tensor,
        k: &Tensor,
        v: &Tensor,
        out: &mut [f32],
    ) -> Option<(SkipStats, Option<&BlockMask>)> {
        assert_eq!(out.len(), v.dim(1), "decode_into output buffer must hold one dv row");
        if !self.ensure_resident(alloc) {
            return None;
        }
        if !self.append_token(alloc, q, k, v) {
            return None;
        }
        let (stats, predicted) = self.decode_step(alloc, q, self.engine.exec(), out);
        Some((stats, predicted.then_some(&self.pred_mask)))
    }

    /// [`PagedAttnSession::decode_into`] allocating its output row.
    pub fn decode(
        &mut self,
        alloc: &mut PageAllocator,
        q: &Tensor,
        k: &Tensor,
        v: &Tensor,
    ) -> Option<AttnOutput> {
        if !self.ensure_resident(alloc) || !self.append_token(alloc, q, k, v) {
            return None;
        }
        let mut out = Tensor::zeros(&[1, self.dv]);
        let (stats, predicted) = self.decode_step(alloc, q, self.engine.exec(), out.data_mut());
        let mask = predicted.then(|| self.pred_mask.clone());
        Some(AttnOutput { out, stats, mask })
    }

    /// Spill this session's frame contents verbatim into its own buffer
    /// and release every frame reference — idle sessions hand their
    /// memory back without losing any state. No-op if already evicted.
    pub fn evict(&mut self, alloc: &mut PageAllocator) {
        if self.evicted || self.frames.is_empty() {
            return;
        }
        let (bk, d, dv) = (alloc.bk, alloc.d, alloc.dv);
        self.ckpt.clear();
        self.ckpt.d = d;
        self.ckpt.dv = dv;
        for &f in &self.frames {
            let rows = alloc.prow[f];
            self.ckpt.k.extend_from_slice(&alloc.k[f * bk * d..f * bk * d + rows * d]);
            self.ckpt.v.extend_from_slice(&alloc.v[f * bk * dv..f * bk * dv + rows * dv]);
            self.ckpt.psum.extend_from_slice(&alloc.psum[f * d..(f + 1) * d]);
            self.ckpt.prow.push(rows);
            self.ckpt.sim.push(alloc.sim[f]);
            if alloc.quant {
                // carry the INT8 payload verbatim, so a checkpoint that
                // round-trips an offload tier restores bit-for-bit
                // without consulting the smoothing mean
                self.ckpt.qscale.push(alloc.qk[f].scale);
                self.ckpt.qdata.extend_from_slice(&alloc.qk[f].data);
            }
        }
        for &f in &self.frames {
            alloc.release(f);
        }
        self.frames.clear();
        self.evicted = true;
        alloc.evictions += 1;
    }

    /// Re-page-in after an eviction: claim fresh frames and restore the
    /// checkpointed contents bit-for-bit (INT8 payloads restore from the
    /// checkpoint's own payload bytes; checkpoints captured without them
    /// requantize from the restored rows — byte-identical either way,
    /// quantization is deterministic). `false` — nothing claimed — when
    /// the free list cannot cover it, or when the checkpoint is parked
    /// in an offload tier ([`PagedAttnSession::resume`] loads it back).
    /// Resident sessions return `true` immediately.
    pub fn ensure_resident(&mut self, alloc: &mut PageAllocator) -> bool {
        if !self.evicted {
            return true;
        }
        if self.suspended {
            return false;
        }
        let nframes = self.ckpt.prow.len();
        if !alloc.covers(nframes) {
            return false;
        }
        let (bk, d, dv) = (alloc.bk, alloc.d, alloc.dv);
        let restore_quant = alloc.quant && self.ckpt.qscale.len() == nframes;
        let (mut ok, mut ov) = (0, 0);
        for b in 0..nframes {
            let f = alloc.claim().expect("free-frame check covers re-page-in claims");
            let rows = self.ckpt.prow[b];
            alloc.k[f * bk * d..f * bk * d + rows * d].copy_from_slice(&self.ckpt.k[ok..ok + rows * d]);
            alloc.v[f * bk * dv..f * bk * dv + rows * dv].copy_from_slice(&self.ckpt.v[ov..ov + rows * dv]);
            alloc.psum[f * d..(f + 1) * d].copy_from_slice(&self.ckpt.psum[b * d..(b + 1) * d]);
            alloc.prow[f] = rows;
            alloc.sim[f] = self.ckpt.sim[b];
            if restore_quant {
                // the checkpoint carries the INT8 payload verbatim
                // (qdata frames are rows×d, so `ok` indexes both)
                let qb = &mut alloc.qk[f];
                qb.data.clear();
                qb.data.extend_from_slice(&self.ckpt.qdata[ok..ok + rows * d]);
                qb.rows = rows;
                qb.d = d;
                qb.scale = self.ckpt.qscale[b];
            } else if alloc.quant {
                let mean = self.kmean.as_deref().expect("kmean frozen at first append");
                alloc.requantize_frame(f, mean, &mut self.ws.quant_f32);
            }
            self.frames.push(f);
            ok += rows * d;
            ov += rows * dv;
        }
        self.evicted = false;
        true
    }

    /// Preempt this session: evict (if still resident) and hand the
    /// checkpoint to `tier` under `key` — the swap-out half of
    /// priority-aware preemption. On `true` the payload lives in the
    /// tier and the session holds zero frames and zero payload bytes
    /// until [`PagedAttnSession::resume`]. On `false` the tier refused
    /// (e.g. disk IO failure) or the session had nothing to spill: the
    /// payload — if any — stays session-local, exactly a plain
    /// [`PagedAttnSession::evict`], so the normal re-page-in machinery
    /// still heals the stream.
    pub fn suspend(&mut self, alloc: &mut PageAllocator, key: u64, tier: &mut dyn OffloadTier) -> bool {
        self.evict(alloc);
        if !self.evicted || self.suspended {
            return false;
        }
        match tier.store(key, &mut self.ckpt) {
            Ok(()) => {
                self.suspended = true;
                true
            }
            Err(_) => false,
        }
    }

    /// Bring a suspended session back: load the checkpoint from `tier`
    /// (when suspend parked it there) and re-page-in. `Ok(true)` — the
    /// session is resident and decodes bitwise-identically to one that
    /// was never preempted. `Ok(false)` — the payload is back
    /// session-local but the free list cannot cover its frames yet; the
    /// normal [`PagedAttnSession::ensure_resident`] path heals it on a
    /// later tick. `Err` — the tier lost or corrupted the checkpoint;
    /// the session stays suspended (permanently unservable) and the
    /// caller should quarantine the stream. Bad tier bytes are values
    /// here, never panics.
    pub fn resume(
        &mut self,
        alloc: &mut PageAllocator,
        key: u64,
        tier: &mut dyn OffloadTier,
    ) -> Result<bool, OffloadError> {
        if self.suspended {
            tier.load(key, &mut self.ckpt)?;
            if !(self.ckpt.consistent(alloc.bk)
                && self.ckpt.rows() == self.rows
                && self.ckpt.d == self.d
                && self.ckpt.dv == self.dv)
            {
                // a checkpoint that passed the tier's own verification
                // but does not describe *this* session is still corrupt
                return Err(OffloadError::Corrupt);
            }
            self.suspended = false;
        }
        Ok(self.ensure_resident(alloc))
    }

    /// Release every frame reference (session retirement). The local
    /// checkpoint buffer is dropped with the session; a tier-resident
    /// checkpoint is the caller's to discard under the same key.
    pub fn release(&mut self, alloc: &mut PageAllocator) {
        for &f in &self.frames {
            alloc.release(f);
        }
        self.frames.clear();
        self.evicted = false;
        self.suspended = false;
    }

    /// Append a multi-row chunk frame by frame: top up the partial tail
    /// (CoW-splitting it first if shared), then claim fresh frames —
    /// pooled sums/sims maintained per touched frame with the exact
    /// `KPool::extend` chains. Caller has already verified the free-list
    /// budget.
    fn append_rows(&mut self, alloc: &mut PageAllocator, k: &Tensor, v: &Tensor, row0: usize) {
        let bk = alloc.bk;
        let (d, dv) = (self.d, self.dv);
        let mk = self.engine.microkernel();
        if row0 % bk != 0 {
            let last = self.frames.len() - 1;
            let g = alloc.cow(self.frames[last]).expect("free-frame check covers the CoW claim");
            self.frames[last] = g;
        }
        let new = k.dim(0);
        let mut r = 0;
        while r < new {
            let abs = row0 + r;
            let f = if abs % bk == 0 {
                let g = alloc.claim().expect("free-frame check covers fresh-frame claims");
                self.frames.push(g);
                g
            } else {
                self.frames[self.frames.len() - 1]
            };
            let take = (bk - abs % bk).min(new - r);
            alloc.push_rows(f, &k.data()[r * d..(r + take) * d], &v.data()[r * dv..(r + take) * dv], take, mk);
            if self.pooled() {
                alloc.refresh_sim(f, mk, &mut self.pool_scratch);
            }
            r += take;
        }
        self.rows += new;
    }

    /// Requantize every frame from the block containing `rows_before`
    /// through the tail (the monolithic `requantize_from`, per frame).
    fn requantize_from(&mut self, alloc: &mut PageAllocator, rows_before: usize) {
        let mean = self.kmean.as_deref().expect("kmean frozen at first append");
        let first = rows_before / alloc.bk;
        for b in first..self.frames.len() {
            alloc.requantize_frame(self.frames[b], mean, &mut self.ws.quant_f32);
        }
    }

    /// Per-frame pooled means as an (n_blocks × d) tensor (prefill-shape
    /// prediction; allocates — the decode path uses
    /// [`PagedAttnSession::stage_means`]).
    fn frame_means(&self, alloc: &PageAllocator) -> Tensor {
        let mut flat = Vec::new();
        self.stage_means(alloc, &mut flat);
        Tensor::from_vec(&[self.frames.len(), self.d], flat)
    }

    /// Stage per-frame pooled means into `out` — same `sum × (1/rows)`
    /// bits as `KPool::means_into`.
    fn stage_means(&self, alloc: &PageAllocator, out: &mut Vec<f32>) {
        let d = self.d;
        out.clear();
        out.resize(self.frames.len() * d, 0.0);
        for (b, &f) in self.frames.iter().enumerate() {
            let inv = 1.0 / alloc.prow[f] as f32;
            for (o, &s) in out[b * d..(b + 1) * d].iter_mut().zip(&alloc.psum[f * d..(f + 1) * d]) {
                *o = s * inv;
            }
        }
    }

    /// Stage per-frame sims into the session buffer (contiguous slice
    /// for the predictor), within capacity once warm.
    fn stage_sims(&mut self, alloc: &PageAllocator) {
        self.pred_sims.clear();
        self.pred_sims.extend(self.frames.iter().map(|&f| alloc.sim[f]));
    }

    /// Run one call through the driver the engine's `kv_split` policy
    /// selects — the same shape-pure routing as the monolithic
    /// `dispatch_into`, over the paged [`KvSource`].
    #[allow(clippy::too_many_arguments)]
    fn run_paged(
        &self,
        alloc: &PageAllocator,
        q: &Tensor,
        cfg: &AttnConfig,
        filter: &impl BlockFilter,
        exec: Exec<'_>,
        plan: &mut SpanPlan,
        ws: &mut Workspace,
        out: &mut [f32],
    ) -> SkipStats {
        let kv = PagedKv { alloc, frames: &self.frames, rows: self.rows };
        let span = self.engine.kv_span(cfg.n_qblocks(q.dim(0)), cfg.n_kblocks(self.rows));
        match self.engine.precision() {
            Precision::F32 => {
                let kernel = PagedF32Kernel {
                    q,
                    alloc,
                    frames: &self.frames,
                    scale: cfg.scale_for(self.d),
                    causal: cfg.causal,
                    row_offset: cfg.row_offset,
                    mk: self.engine.microkernel(),
                };
                match span {
                    Some(s) => {
                        run_tiled_splitkv_into_kv(q, &kv, cfg, &kernel, filter, exec, s, plan, ws, out)
                    }
                    None => run_tiled_into_kv(q, &kv, cfg, &kernel, filter, exec, ws, out),
                }
            }
            Precision::Int8 => {
                let kernel = PagedQuantKernel {
                    qb: &self.qstage,
                    alloc,
                    frames: &self.frames,
                    scale: cfg.scale_for(self.d),
                    causal: cfg.causal,
                    row_offset: cfg.row_offset,
                    bq: cfg.bq,
                    mk: self.engine.microkernel(),
                };
                match span {
                    Some(s) => {
                        run_tiled_splitkv_into_kv(q, &kv, cfg, &kernel, filter, exec, s, plan, ws, out)
                    }
                    None => run_tiled_into_kv(q, &kv, cfg, &kernel, filter, exec, ws, out),
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn prompt(n: usize, d: usize, seed: u64) -> (Tensor, Tensor, Tensor) {
        let mut rng = Pcg::seeded(seed);
        (
            Tensor::randn(&[n, d], &mut rng),
            Tensor::randn(&[n, d], &mut rng),
            Tensor::randn(&[n, d], &mut rng),
        )
    }

    #[test]
    fn registry_hit_is_byte_verified_never_hash_trusted() {
        // A 64-bit hash match alone must not map another prompt's frames
        // or output into a session: `find` byte-compares the stored query
        // rows and the frame-resident K/V rows, so a forged (colliding)
        // hash degrades to a miss — a recompute, never silent
        // cross-request KV/output adoption.
        let d = 8;
        let cfg = AttnConfig { bq: 8, bk: 8, causal: true, scale: None, cw: 2, row_offset: 0 };
        let engine = AttnEngine::builder().config(cfg).build();
        let mut alloc = PageAllocator::new(8, 8, d, d);
        let mut reg = PrefixRegistry::new();
        let (qa, ka, va) = prompt(12, d, 7001);
        let mut lender = engine.paged_session();
        lender.prefill_shared(&mut alloc, &mut reg, &qa, &ka, &va).expect("frames");
        assert_eq!(reg.len(), 1);

        // a different prompt whose hash is forged onto the entry: the
        // stored frames still hold prompt A's bytes, so lookup must miss
        let (qb, kb, vb) = prompt(12, d, 7002);
        let forged = prefix_hash(&qb, &kb, &vb);
        reg.entries[0].hash = forged;
        assert!(
            reg.find(&alloc, forged, &qb, &kb, &vb).is_none(),
            "colliding hash with mismatched K/V bytes must miss"
        );

        // same K/V, different Q, hash forged to collide: the K/V frames
        // match byte for byte, but the stored query rows differ — still
        // a miss, because the cached output is a function of Q
        let forged_q = prefix_hash(&qb, &ka, &va);
        reg.entries[0].hash = forged_q;
        assert!(
            reg.find(&alloc, forged_q, &qb, &ka, &va).is_none(),
            "colliding hash with mismatched Q bytes must miss"
        );

        // the genuine prompt (hash restored) still hits
        let real = prefix_hash(&qa, &ka, &va);
        reg.entries[0].hash = real;
        assert_eq!(reg.find(&alloc, real, &qa, &ka, &va), Some(0));

        lender.release(&mut alloc);
        reg.clear(&mut alloc);
        assert_eq!(alloc.stats().frames_in_use, 0);
    }
}
