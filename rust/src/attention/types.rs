//! Shared attention types: configuration, skip accounting, block masks.

/// Attention engine configuration.
#[derive(Clone, Copy, Debug)]
pub struct AttnConfig {
    /// Query block rows (paper default 128).
    pub bq: usize,
    /// Key/value block rows (paper default 64).
    pub bk: usize,
    /// Causal (decoder) masking.
    pub causal: bool,
    /// Softmax scale; `None` means 1/√d.
    pub scale: Option<f32>,
    /// Row groups per query tile — the paper's `c_w` GPU warps (§3.4).
    pub cw: usize,
    /// Global position of query row 0: under `causal`, query row `i` sits
    /// at absolute position `row_offset + i` while key rows stay absolute.
    /// 0 for whole-sequence calls; a chunked prefill sets it to the number
    /// of rows already cached so causal masking keeps referring to
    /// absolute positions (see the contract in `attention::pipeline`).
    pub row_offset: usize,
}

impl Default for AttnConfig {
    fn default() -> Self {
        AttnConfig { bq: 128, bk: 64, causal: false, scale: None, cw: 4, row_offset: 0 }
    }
}

impl AttnConfig {
    pub fn causal() -> Self {
        AttnConfig { causal: true, ..Default::default() }
    }

    /// This config with query row 0 placed at absolute position `row_offset`.
    pub fn at_offset(self, row_offset: usize) -> Self {
        AttnConfig { row_offset, ..self }
    }

    /// Effective softmax scale for head dimension `d`.
    pub fn scale_for(&self, d: usize) -> f32 {
        self.scale.unwrap_or(1.0 / (d as f32).sqrt())
    }

    /// Number of query blocks for sequence length n.
    pub fn n_qblocks(&self, n: usize) -> usize {
        n.div_ceil(self.bq)
    }

    /// Number of key blocks for sequence length n.
    pub fn n_kblocks(&self, n: usize) -> usize {
        n.div_ceil(self.bk)
    }
}

/// Span size (in k-blocks) used by [`KvSplit::Auto`]: with the paper's
/// default `b_k = 64` a span covers 256 cached keys, enough work to
/// amortize one partial-state merge while still exposing one span per
/// worker on KV caches past ~1K tokens.
pub const KV_SPLIT_AUTO_BLOCKS: usize = 4;

/// How an engine splits the KV domain of decode-shaped (single query
/// tile) calls across workers — the Flash-Decoding lever for the serving
/// hot path, where `run_tiled`'s row parallelism has only one row to
/// hand out.
///
/// The span count is always derived from the *cache length* (`S =
/// ceil(n_kblocks / span)`), **never** from the worker count, so outputs
/// and merged [`SkipStats`] are bitwise-identical across
/// `Exec::Inline`/`Threads`/`Pool` and any pool size (see the split-KV
/// contract in `attention::pipeline`). Because the geometry is a pure
/// function of `(cache_len, kend, span_blocks)`, sessions cache it: an
/// `AttnSession` keeps a `SpanPlan` (work-list + partial-state arenas)
/// that revalidates in O(1) per decode step and rebuilds only when the
/// cache grows into a new `b_k` block — plan reuse can never change a
/// bit, only skip redundant planning work and allocation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvSplit {
    /// Never split. Decode steps reduce their KV domain serially within
    /// one tile, which keeps decode **bitwise-identical** to the same
    /// rows of a one-shot prefill (the PR-2 parity contract). This is
    /// the builder default.
    Off,
    /// Split single-tile calls — decode steps and sub-`b_q` prefill
    /// chunks — into spans of [`KV_SPLIT_AUTO_BLOCKS`] k-blocks. Their
    /// output becomes allclose (not bitwise) to the serial path — the
    /// reduction tree changes — but stays bitwise deterministic across
    /// execution modes and pool sizes, with λ-off skip counters exactly
    /// equal.
    Auto,
    /// Split single-tile calls into spans of `n` k-blocks each.
    Blocks(usize),
}

impl KvSplit {
    /// Span size in k-blocks, if splitting is enabled.
    pub fn span_blocks(&self) -> Option<usize> {
        match self {
            KvSplit::Off => None,
            KvSplit::Auto => Some(KV_SPLIT_AUTO_BLOCKS),
            KvSplit::Blocks(n) => Some((*n).max(1)),
        }
    }
}

/// A binary block mask of shape (n_qblocks, n_kblocks) — `M_g` in the paper.
#[derive(Clone, Debug, PartialEq)]
pub struct BlockMask {
    pub rows: usize,
    pub cols: usize,
    bits: Vec<bool>,
}

impl BlockMask {
    pub fn new_all(rows: usize, cols: usize, value: bool) -> BlockMask {
        BlockMask { rows, cols, bits: vec![value; rows * cols] }
    }

    /// Reshape and refill in place — equal (`==`) to
    /// `new_all(rows, cols, value)` but reusing the bit storage, so a
    /// per-step mask rebuild allocates nothing once the buffer has
    /// reached its high-water size (the predicted decode hot path).
    pub fn reset(&mut self, rows: usize, cols: usize, value: bool) {
        self.rows = rows;
        self.cols = cols;
        self.bits.clear();
        self.bits.resize(rows * cols, value);
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> bool {
        self.bits[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: bool) {
        self.bits[i * self.cols + j] = v;
    }

    /// Set an entire row.
    pub fn set_row(&mut self, i: usize, v: bool) {
        for j in 0..self.cols {
            self.set(i, j, v);
        }
    }

    /// Set an entire column.
    pub fn set_col(&mut self, j: usize, v: bool) {
        for i in 0..self.rows {
            self.set(i, j, v);
        }
    }

    /// Count of `true` entries.
    pub fn count_active(&self) -> usize {
        self.bits.iter().filter(|&&b| b).count()
    }

    /// Fraction of `false` (skipped) entries.
    pub fn sparsity(&self) -> f64 {
        1.0 - self.count_active() as f64 / self.bits.len() as f64
    }

    /// Logical-or with another mask of identical shape.
    pub fn union(&self, other: &BlockMask) -> BlockMask {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let bits = self.bits.iter().zip(&other.bits).map(|(&a, &b)| a || b).collect();
        BlockMask { rows: self.rows, cols: self.cols, bits }
    }
}

/// Counters for skipped vs executed block matmuls.
///
/// The paper defines **Sparsity** as the proportion of `Q_iK_jᵀ` plus
/// `P̃_ijV_j` products skipped relative to the total a full attention needs
/// (§4.1). Both stage-1 (`M_g`) and stage-2 (λ filter) skips are counted.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SkipStats {
    /// Block QKᵀ products a dense attention would execute.
    pub qk_total: usize,
    /// Block QKᵀ products skipped (stage 1).
    pub qk_skipped: usize,
    /// Block P̃V products a dense attention would execute.
    pub pv_total: usize,
    /// Block P̃V products skipped at full blocks (stage 1).
    pub pv_skipped: usize,
    /// Row groups per query tile (c_w); carried for merge validation.
    pub cw: usize,
    /// Stage-2 λ skips, in *block* units: each skipped row group adds
    /// `(group rows) / (tile rows)`, so ragged tiles and decode-shaped
    /// steps (1 query row < b_q) are counted exactly — a 1-row tile that
    /// skips its only group counts one full block, not 1/c_w of one.
    /// Accumulation and merge order are deterministic (row order), so the
    /// value is identical across thread counts.
    pub pv_skipped_frac: f64,
}

impl SkipStats {
    /// Paper sparsity: skipped matmuls / total matmuls, QK and PV pooled.
    pub fn sparsity(&self) -> f64 {
        let total = (self.qk_total + self.pv_total) as f64;
        if total == 0.0 {
            return 0.0;
        }
        ((self.qk_skipped + self.pv_skipped) as f64 + self.pv_skipped_frac) / total
    }

    /// Sparsity from stage-1 only (`only M_g` row of Table 6).
    pub fn sparsity_stage1(&self) -> f64 {
        let total = (self.qk_total + self.pv_total) as f64;
        if total == 0.0 {
            return 0.0;
        }
        (self.qk_skipped + self.pv_skipped) as f64 / total
    }

    /// Merge counters from another run (e.g. other heads, other query-tile
    /// rows). Hard-errors (also in release builds) when both sides carry a
    /// nonzero, *different* c_w: pooling group-fraction accounting across
    /// configurations would silently corrupt the sparsity metric.
    pub fn merge(&mut self, other: &SkipStats) {
        assert!(
            self.cw == 0 || other.cw == 0 || other.cw == self.cw,
            "merging SkipStats with mismatched c_w: {} vs {}",
            self.cw,
            other.cw
        );
        self.qk_total += other.qk_total;
        self.qk_skipped += other.qk_skipped;
        self.pv_total += other.pv_total;
        self.pv_skipped += other.pv_skipped;
        self.pv_skipped_frac += other.pv_skipped_frac;
        if self.cw == 0 {
            self.cw = other.cw;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_defaults() {
        let c = AttnConfig::default();
        assert_eq!(c.bq, 128);
        assert_eq!(c.bk, 64);
        assert!((c.scale_for(64) - 0.125).abs() < 1e-7);
        assert_eq!(c.n_qblocks(300), 3);
        assert_eq!(c.n_kblocks(300), 5);
        assert_eq!(c.row_offset, 0);
        assert_eq!(c.at_offset(256).row_offset, 256);
        assert_eq!(c.at_offset(256).bq, 128);
    }

    #[test]
    fn mask_ops() {
        let mut m = BlockMask::new_all(3, 4, false);
        assert_eq!(m.count_active(), 0);
        m.set(1, 2, true);
        m.set_row(0, true);
        m.set_col(3, true);
        assert!(m.get(1, 2) && m.get(0, 0) && m.get(2, 3));
        assert_eq!(m.count_active(), 4 + 1 + 2);
        let u = m.union(&BlockMask::new_all(3, 4, true));
        assert_eq!(u.count_active(), 12);
        assert_eq!(u.sparsity(), 0.0);
    }

    #[test]
    fn skipstats_sparsity() {
        let s = SkipStats {
            qk_total: 100,
            qk_skipped: 50,
            pv_total: 100,
            pv_skipped: 50,
            cw: 4,
            pv_skipped_frac: 10.0,
        };
        // (50 + 50 + 10) / 200 = 110/200
        assert!((s.sparsity() - 0.55).abs() < 1e-12);
        assert!((s.sparsity_stage1() - 0.5).abs() < 1e-12);
        assert_eq!(SkipStats::default().sparsity(), 0.0);
    }

    #[test]
    fn skipstats_merge() {
        let mut a = SkipStats {
            qk_total: 10,
            qk_skipped: 5,
            pv_total: 10,
            pv_skipped: 5,
            cw: 4,
            pv_skipped_frac: 0.5,
        };
        let b = a;
        a.merge(&b);
        assert_eq!(a.qk_total, 20);
        assert_eq!(a.pv_skipped_frac, 1.0);
        assert_eq!(a.cw, 4);
        // merging with a cw-less (e.g. default) side adopts the nonzero cw
        let mut c = SkipStats::default();
        c.merge(&a);
        assert_eq!(c.cw, 4);
    }

    #[test]
    #[should_panic(expected = "mismatched c_w")]
    fn skipstats_merge_rejects_mismatched_cw() {
        let mut a = SkipStats { cw: 4, ..Default::default() };
        let b = SkipStats { cw: 2, ..Default::default() };
        a.merge(&b);
    }
}
