//! Attention engines, organized around **one** tiled loop.
//!
//! [`pipeline`] owns the single q-block × k-block driver ([`run_tiled`])
//! and the two seams every engine composes from: [`ScoreKernel`] (how a
//! score block is produced — f32 matmul vs. INT8 dequant) and
//! [`BlockFilter`] (which blocks run — dense, stage-1 mask, stage-2 λ,
//! causal bound). [`flash`] is the dense composition, [`dense`] the naive
//! softmax oracle used by tests, and `crate::sparge::kernel` the sparse +
//! quantized compositions. Adding an engine means adding a kernel or
//! filter impl — never another loop.

pub mod dense;
pub mod flash;
pub mod pipeline;
pub mod types;

pub use dense::attention_naive;
pub use flash::{attention_flash, attention_flash_stats, attention_flash_stats_threads};
pub use pipeline::{run_tiled, score_block, BlockFilter, DenseFilter, F32Kernel, FlashTile, MaskFilter, ScoreKernel};
pub use types::{AttnConfig, BlockMask, SkipStats};
