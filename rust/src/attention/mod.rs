//! Attention engines: the naive dense oracle and the blockwise
//! FlashAttention implementation the SpargeAttn kernel builds on.

pub mod dense;
pub mod flash;
pub mod types;

pub use dense::attention_naive;
pub use flash::{attention_flash, attention_flash_stats, FlashTile};
pub use types::{AttnConfig, BlockMask, SkipStats};
