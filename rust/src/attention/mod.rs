//! Attention engines, organized around **one** tiled loop and **one**
//! public composition API — built to be *served from*, not just called.
//!
//! [`engine`] is the front door: [`AttnEngine::builder`] composes
//! precision ([`Precision`]) × sparsity policy ([`SparsityPolicy`]) ×
//! execution ([`Execution`], including a persistent worker pool) into a
//! reusable `Send + Sync` engine; [`AttnEngine::session`] adds
//! per-sequence state (KV cache, incremental stage-1 pooling, cached K
//! quantization). One engine serves many concurrent sessions — the
//! coordinator's continuous-batching loop
//! (`crate::coordinator::session_manager`) holds N live sessions over a
//! single engine/pool and interleaves their work per tick:
//!
//! ```text
//! admit ──► chunked prefill ──► decode ticks ──► retire
//!           session.prefill_chunk(..)   session.decode(..)
//!           bounded, b_q-aligned,       one row per tick,
//!           offset-aware causal         per-step SkipStats
//! ```
//!
//! Chunked prefill runs each prompt slice against the whole cache with
//! an absolute-position causal mask (`AttnConfig::row_offset`; contract
//! in [`pipeline`]), bitwise-faithful to one-shot prefill for f32/λ-off
//! — so a long prompt never monopolizes the engine, which is what caps
//! time-to-first-token under mixed traffic.
//!
//! [`pipeline`] owns the single q-block × k-block loop and its **two
//! drivers**: [`run_tiled`] (parallel over query-block rows — the
//! prefill shape) and [`run_tiled_splitkv`] (additionally parallel along
//! the KV axis, Flash-Decoding style — the decode shape, where one query
//! row would otherwise leave the whole pool idle). Both compose the same
//! seams: [`ScoreKernel`] (how a score block is produced — f32 matmul
//! vs. INT8 dequant), [`BlockFilter`] (which blocks run — dense, stage-1
//! mask, stage-2 λ, causal bound), and [`Exec`] (inline / scoped threads
//! / persistent pool, shareable across engines via
//! `AttnEngineBuilder::shared_pool`). The engine picks the driver from
//! its [`KvSplit`] policy and the call *shape* alone — span count from
//! the cache length, **never** the worker count — so every composition
//! stays bitwise-deterministic across execution modes and pool sizes;
//! see the split-KV contract in [`pipeline`]. [`flash`] keeps the
//! deprecated dense free-function shims, [`dense`] the naive softmax
//! oracle used by tests, and `crate::sparge::kernel` the sparse +
//! quantized compositions. Adding an engine means adding a kernel or
//! filter impl — never another loop.
//!
//! ## KV ownership: monolithic sessions and paged frames
//!
//! A session's KV cache has two ownership models. The monolithic
//! [`AttnSession`] owns contiguous K/V tensors (amortized growth,
//! simplest possible lifetime). The paged [`PagedAttnSession`] holds
//! only a *page table* into a shared [`PageAllocator`] — a pool of
//! fixed `b_k`-row **frames** recycled through a free list, where K, V,
//! the stage-1 pooled state, and the INT8 payload of each block page
//! together. Frames are refcounted: identical prompts share their
//! prefix frames copy-on-write ([`PagedAttnSession::prefill_shared`]),
//! idle sessions spill and release ([`PagedAttnSession::evict`]) and
//! transparently re-page-in on their next decode, preempted sessions
//! checkpoint through an [`offload`] tier
//! ([`PagedAttnSession::suspend`]/[`PagedAttnSession::resume`] — in
//! memory or checksummed on disk, byte-identical round-trips), and the
//! serving loop admits work against the free-frame count instead of
//! OOMing. The
//! drivers are indifferent: both consume any [`KvSource`], and each
//! `b_k`-aligned block request resolves to exactly one frame, so the
//! paged path is bitwise-identical to the monolithic one for f32/λ-off
//! under every execution mode (`tests/paged_kv.rs`). See [`paged`] for
//! the full frame/CoW/eviction contracts.
//!
//! ## Workspace ownership and the determinism contract
//!
//! The steady-state serving hot path is **allocation-free**: all scratch
//! lives in [`Workspace`] arenas — one per pool worker (persistent, in
//! `util::threadpool`), one per [`AttnSession`] for inline work — and
//! the session additionally caches its split-KV [`SpanPlan`]
//! (work-list + partial-state arenas, revalidated in O(1) per decode
//! step). A warmed-up λ-off f32 [`AttnSession::decode_into`] step
//! performs zero heap allocations (`tests/alloc_regression.rs`).
//! Workspace reuse is bitwise-neutral and the pool hands out work by
//! chunked self-scheduling with the submitter participating, so:
//! **scheduling order may vary, merge order may not** — outputs and
//! [`SkipStats`] are identical for every execution mode, pool size, and
//! timing, because results are collected per index and merged in
//! index/span order, which is a pure function of the call's shape.
//!
//! Below the seams sits the **microkernel tier**
//! (`crate::tensor::microkernel::Backend`): every [`ScoreKernel`]
//! routes its flop-dominant inner loops — f32 QKᵀ, the m=1 decode GEMV,
//! the INT8 i8×i8→i32 dot, the P̃·V accumulate — through a
//! runtime-dispatched backend (portable lane-by-lane, or AVX2+FMA under
//! `--features simd` on capable x86-64). Backend choice extends the
//! contract above per kernel: the QKᵀ/GEMV/dot/INT8 kernels are in the
//! *fixed-order* tier (bitwise-identical on every backend, so every
//! bitwise guarantee in this module — across exec modes, pool sizes,
//! chunked vs one-shot prefill — also holds across backends), while
//! P̃·V is in the *oracle* tier (same summation order, fused rounding;
//! allclose to portable, bitwise-deterministic *within* a backend).
//! The per-kernel tier table lives in [`pipeline`]'s module docs next
//! to the merge-order rule; the engine pins a backend at `build()`
//! (`AttnEngineBuilder::microkernel`) so one run never mixes tiers.
//!
//! ## Migration (old free functions → builder API)
//!
//! | Deprecated call | Replacement |
//! |---|---|
//! | `attention_flash(q,k,v,cfg)` | `AttnEngine::dense(cfg).attention(q,k,v).out` |
//! | `attention_flash_stats(q,k,v,cfg)` | `AttnEngine::dense(cfg).attention(q,k,v)` |
//! | `attention_flash_stats_threads(..,t)` | `..builder().config(cfg).execution(Execution::Threads(t)).build()` |
//! | `sparge_attention(q,k,v,cfg,p)` | `AttnEngine::sparge(cfg, p).attention(q,k,v)` |
//! | `sparge_attention_threads(..,t)` | `..builder().config(cfg).sparge(p).execution(Execution::Threads(t)).build()` |
//! | `sparse_flash(q,k,v,mask,cfg,p)` | `..policy(SparsityPolicy::External { mask, lambda }) + .precision(..)` |
//! | `sparse_flash_threads(..,t)` | as above plus `.execution(Execution::Threads(t))` |
//! | per-call scoped threads | `.execution(Execution::Pool(n))` — pool spawned once at `build()` |
//! | KV-cache decode (new) | `engine.session()` → `session.prefill(..)` / `session.decode(..)` |
//! | chunked prefill (new) | `session.prefill_chunk(..)` per prompt slice — offset-aware causal |
//! | split-KV decode (new) | `.kv_split(KvSplit::Auto)` — decode steps fan KV spans across the pool |
//! | pool sharing (new) | `.shared_pool(pool)` — several engines over one `Arc<WorkerPool>` |
//! | zero-alloc decode (new) | `session.decode_into(q, k, v, &mut row)` — writes into a caller buffer |
//! | paged KV cache (new) | `engine.paged_session()` over a shared [`PageAllocator`] — frames, CoW prefix sharing, eviction |

pub mod dense;
pub mod engine;
pub mod flash;
pub mod offload;
pub mod paged;
pub mod pipeline;
pub mod types;

pub use dense::attention_naive;
pub use engine::{
    AttnEngine, AttnEngineBuilder, AttnOutput, AttnSession, Execution, Precision, PredictorCounters,
    SparsityPolicy,
};
#[allow(deprecated)]
pub use flash::{attention_flash, attention_flash_stats, attention_flash_stats_threads};
pub use offload::{DiskTier, FrameCheckpoint, MemTier, OffloadError, OffloadTier};
pub use paged::{prefix_hash, PageAllocator, PageStats, PagedAttnSession, PagedKv, PrefixRegistry};
pub use pipeline::{
    run_tiled, run_tiled_into, run_tiled_into_kv, run_tiled_splitkv, run_tiled_splitkv_into,
    run_tiled_splitkv_into_kv, score_block, score_block_slices, BlockFilter, DenseFilter, Exec,
    F32Kernel, FlashTile, KvSource, MaskFilter, ScoreKernel, ScoreScratch, SpanPlan, TensorKv,
};
pub use types::{AttnConfig, BlockMask, KvSplit, SkipStats, KV_SPLIT_AUTO_BLOCKS};
// Re-exported so engine users can hold scratch arenas without reaching
// into `util`.
pub use crate::util::threadpool::Workspace;
