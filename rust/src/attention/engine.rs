//! `AttnEngine` + `AttnSession`: the composable attention API.
//!
//! An engine is built once from three orthogonal choices and then reused
//! for any number of calls and sessions:
//!
//! - **precision** ([`Precision`]): f32 scoring ([`F32Kernel`]) or the
//!   SageAttention INT8 path ([`crate::sparge::QuantScoreKernel`], §3.5);
//! - **sparsity policy** ([`SparsityPolicy`]): dense, SpargeAttn stage-1
//!   prediction + stage-2 λ (§3.2–3.4), or an external [`BlockMask`];
//! - **execution** ([`Execution`]): inline, scoped threads per call, or a
//!   persistent [`WorkerPool`] created once at `build()` — the hot path
//!   then never spawns a thread.
//!
//! [`AttnEngine::attention`] is the one-shot (prefill-shaped) call and is
//! bitwise-identical to the deprecated free functions it replaces
//! (`attention_flash*`, `sparse_flash*`, `sparge_attention*`).
//!
//! [`AttnEngine::session`] opens per-sequence state for the serving path:
//! a growing KV cache, incrementally maintained stage-1 pooling under the
//! `Predicted` policy ([`KPool`]: block means + self-similarities, updated
//! per appended row or chunk — never a full `compress_blocks` recompute),
//! and cached per-block K quantization (quantized once, only the tail
//! block requantized per decoded token). The session lifecycle is the
//! serving loop's unit of work:
//!
//! ```text
//! engine.session() ── prefill_chunk(q,k,v) ··· prefill_chunk ──► decode ─┐
//!      (open)           (bounded chunks, offset-aware causal)    ▲       │ per token
//!                                                                └───────┘
//! ```
//!
//! ## Workspace ownership: the allocation-free decode step
//!
//! A session owns every piece of mutable scratch its hot loop needs, all
//! sized to their high-water mark and reused:
//!
//! - a [`Workspace`] arena (tile state, score blocks, quant staging) for
//!   work that runs on the calling thread — pool workers bring their own
//!   arenas for fanned-out work;
//! - a [`SpanPlan`] caching the split-KV work-list and partial-state
//!   arenas, revalidated in O(1) per step and rebuilt only when the
//!   cache grows into a new `b_k` block;
//! - the KV cache itself (amortized `b_k`-block doubling via
//!   [`AttnSession::reserve_rows`]) and, under INT8, the cached K block
//!   quantization plus a reusable per-call Q staging buffer.
//!
//! The result: a warmed-up [`AttnSession::decode_into`] step under the
//! dense or external-mask policy (f32, λ on or off) performs **zero**
//! heap allocations — regression-tested with a counting allocator in
//! `tests/alloc_regression.rs`. [`AttnSession::decode`] adds exactly the
//! output tensor it returns; the `Predicted` policy adds its per-step
//! mask. Workspace reuse is bitwise-neutral (same float evaluation
//! order; truncated, re-initialized views), so none of this changes any
//! output or stat.
//!
//! [`AttnSession::prefill_chunk`] appends one prompt chunk to the cache
//! and runs its query rows against the *whole* cache with
//! `row_offset = rows already cached` (the offset-aware causal contract
//! in [`crate::attention::pipeline`]), so a long prompt can be fed in
//! bounded slices between decode ticks of other sessions.
//! [`AttnSession::prefill`] is the one-shot convenience (a single chunk
//! from empty); [`AttnSession::decode`] runs a decode-shaped (one query
//! row) step. All of them run through the same pipeline seams; the
//! *driver* is picked per call from the engine's [`KvSplit`] policy and
//! the call shape — tall calls take the row-parallel `run_tiled`,
//! single-tile calls under `kv_split` take `run_tiled_splitkv`, which
//! fans contiguous KV spans of the cache across the worker pool
//! (Flash-Decoding). Span count derives from the cache length, never the
//! worker count, so either driver is bitwise-deterministic across
//! execution modes and pool sizes (scheduling order may vary, merge
//! order may not).
//!
//! ## Chunked-prefill / decode / prefill parity
//!
//! For f32 precision with `lambda: None` (dense or external-mask policy;
//! golden-tested in `tests/session_decode.rs`), under the default
//! [`KvSplit::Off`]:
//!
//! - N tokens fed through [`AttnSession::decode`] produce bit-identical
//!   rows to one causal [`AttnSession::prefill`] of the full sequence;
//! - a multi-chunk prefill produces bit-identical rows to the one-shot
//!   [`AttnSession::prefill`], for *any* chunk edges: every per-row
//!   quantity in the tiled pipeline is independent of its tile-mates,
//!   each query row sees the same visible key set either way, and
//!   fully-masked tail entries of ragged cache blocks are exact float
//!   no-ops. When chunk edges are multiples of `b_q` the chunk tiling
//!   coincides with the one-shot tiling, so the summed [`SkipStats`]
//!   match exactly too (and stage-2 λ group decisions, being per-tile,
//!   also coincide — λ-on parity needs aligned edges).
//!
//! The predicted policy pools the query side at `b_q` granularity and
//! pools K over the rows cached *so far*, so its chunked mask matches the
//! one-shot mask exactly when chunk edges are multiples of both `b_q`
//! and `b_k`; Int8 additionally freezes the K-smoothing mean at the first
//! chunk (one-shot parity holds for a single chunk, multi-chunk stays
//! within the INT8 error budget). As on GPU, those compositions trade
//! exact parity for sparsity/precision — decode kernels run their own
//! tiling there too.
//!
//! Turning split-KV on ([`KvSplit::Auto`]/`Blocks`) makes the same trade
//! along the execution axis: single-tile calls — decode steps *and*
//! sub-`b_q` prefill chunks — change their reduction *tree* (partial
//! online-softmax states merged per span), so their output is allclose
//! to — no longer bitwise with — the one-shot rows, while remaining
//! bitwise-identical across exec modes and pool sizes and keeping λ-off
//! [`SkipStats`] exactly equal (golden-tested in
//! `tests/splitkv_decode.rs`). Serving opts in; the default stays `Off`.

use std::sync::Arc;

use crate::sparge::kernel::{quant_score_block, QuantScoreKernel, SpargeParams};
use crate::sparge::predict::{
    compress_blocks, predict_decode_row_into, predict_pooled, KPool, PredictParams,
};
use crate::tensor::microkernel::Backend;
use crate::tensor::quant::{self, QuantBlock};
use crate::tensor::Tensor;
use crate::util::threadpool::{WorkerPool, Workspace};

use super::pipeline::{
    run_tiled_into, run_tiled_splitkv_into, BlockFilter, DenseFilter, Exec, F32Kernel, MaskFilter,
    ScoreKernel, ScoreScratch, SpanPlan,
};
use super::types::{AttnConfig, BlockMask, KvSplit, SkipStats};

/// Score-path precision of an engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    /// Plain f32 scoring (the FlashAttention-2 path).
    F32,
    /// SageAttention per-block INT8 scoring with K smoothing (§3.5).
    Int8,
}

/// Which blocks run: the engine's sparsity policy.
#[derive(Clone, Debug)]
pub enum SparsityPolicy {
    /// Every in-domain block is computed.
    Dense,
    /// SpargeAttn: predict the stage-1 mask `M_g` from the inputs
    /// (§3.2–3.3), then apply the stage-2 online-softmax λ filter (§3.4).
    Predicted { params: PredictParams, lambda: Option<f32> },
    /// An externally-constructed block mask (baseline mask policies,
    /// precomputed masks), plus optional stage-2 λ.
    External { mask: BlockMask, lambda: Option<f32> },
}

/// How the tiled driver distributes query-block rows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Execution {
    /// Serial on the calling thread.
    Inline,
    /// Scoped threads spawned per call (legacy; prefer `Pool`).
    Threads(usize),
    /// A persistent worker pool of the given size, created once at
    /// `build()` and reused across calls and sessions.
    Pool(usize),
}

/// Builder for [`AttnEngine`]. Defaults: dense f32, inline execution,
/// [`AttnConfig::default`], split-KV off.
pub struct AttnEngineBuilder {
    cfg: AttnConfig,
    precision: Precision,
    policy: SparsityPolicy,
    execution: Execution,
    kv_split: KvSplit,
    shared_pool: Option<Arc<WorkerPool>>,
    microkernel: Backend,
}

impl AttnEngineBuilder {
    pub fn config(mut self, cfg: AttnConfig) -> Self {
        self.cfg = cfg;
        self
    }

    pub fn precision(mut self, p: Precision) -> Self {
        self.precision = p;
        self
    }

    pub fn policy(mut self, p: SparsityPolicy) -> Self {
        self.policy = p;
        self
    }

    pub fn execution(mut self, e: Execution) -> Self {
        self.execution = e;
        self
    }

    /// Split-KV (Flash-Decoding) policy for decode-shaped calls. The
    /// default, [`KvSplit::Off`], keeps decode bitwise-identical to
    /// prefill rows; serving paths opt into [`KvSplit::Auto`] so 1-row
    /// steps parallelize along the KV axis (see the contract on
    /// [`KvSplit`]).
    pub fn kv_split(mut self, s: KvSplit) -> Self {
        self.kv_split = s;
        self
    }

    /// Run this engine over an existing shared [`WorkerPool`] instead of
    /// spawning its own — so multiple engine compositions (e.g. a dense
    /// and a sparge engine serving mixed-mode traffic) time-share one set
    /// of workers. Overrides [`AttnEngineBuilder::execution`]; the built
    /// engine reports `Execution::Pool(pool.size())`.
    pub fn shared_pool(mut self, pool: Arc<WorkerPool>) -> Self {
        self.shared_pool = Some(pool);
        self
    }

    /// Pin every score/P̃·V kernel under this engine to one explicit
    /// microkernel backend instead of the process-selected default
    /// ([`Backend::select`]) — for A/B benchmarking (the fig10
    /// microkernel scoreboard) and tests. The QKᵀ and INT8 kernels are
    /// bitwise-identical across backends; P̃·V is allclose (see
    /// [`crate::tensor::microkernel`]).
    pub fn microkernel(mut self, mk: Backend) -> Self {
        self.microkernel = mk;
        self
    }

    /// Map a [`SpargeParams`] bundle onto precision + predicted policy:
    /// `quant` selects INT8, (τ, θ) feed stage 1, λ feeds stage 2.
    pub fn sparge(mut self, params: &SpargeParams) -> Self {
        self.precision = if params.quant { Precision::Int8 } else { Precision::F32 };
        self.policy = SparsityPolicy::Predicted { params: params.predict_params(), lambda: params.lambda };
        self
    }

    /// Build the engine; `Execution::Pool(n)` spawns its workers here,
    /// once — unless a [`AttnEngineBuilder::shared_pool`] was supplied,
    /// in which case the engine joins that pool instead of owning one.
    pub fn build(self) -> AttnEngine {
        let (execution, pool) = match self.shared_pool {
            Some(p) => (Execution::Pool(p.size()), Some(p)),
            None => match self.execution {
                Execution::Pool(n) => (self.execution, Some(WorkerPool::shared(n))),
                e => (e, None),
            },
        };
        AttnEngine {
            cfg: self.cfg,
            precision: self.precision,
            policy: self.policy,
            pool,
            execution,
            kv_split: self.kv_split,
            microkernel: self.microkernel,
        }
    }
}

/// A reusable, `Send + Sync` attention engine: one composition of
/// precision × sparsity policy × execution (see module docs).
pub struct AttnEngine {
    cfg: AttnConfig,
    precision: Precision,
    policy: SparsityPolicy,
    execution: Execution,
    /// `Arc` so several engine compositions can time-share one pool
    /// (built privately, or joined via `shared_pool`).
    pool: Option<Arc<WorkerPool>>,
    kv_split: KvSplit,
    microkernel: Backend,
}

/// Result of an engine call (one-shot, prefill, or one decode step).
#[derive(Clone, Debug)]
pub struct AttnOutput {
    pub out: Tensor,
    pub stats: SkipStats,
    /// The stage-1 mask the call computed, when the policy produced one
    /// (`Predicted` one-shot / prefill / decode step).
    pub mask: Option<BlockMask>,
}

impl AttnEngine {
    pub fn builder() -> AttnEngineBuilder {
        AttnEngineBuilder {
            cfg: AttnConfig::default(),
            precision: Precision::F32,
            policy: SparsityPolicy::Dense,
            execution: Execution::Inline,
            kv_split: KvSplit::Off,
            shared_pool: None,
            microkernel: Backend::select(),
        }
    }

    /// Dense f32 engine (the FlashAttention-2 composition), inline.
    pub fn dense(cfg: AttnConfig) -> AttnEngine {
        AttnEngine::builder().config(cfg).build()
    }

    /// Full SpargeAttn engine from a [`SpargeParams`] bundle, inline.
    pub fn sparge(cfg: AttnConfig, params: &SpargeParams) -> AttnEngine {
        AttnEngine::builder().config(cfg).sparge(params).build()
    }

    pub fn config(&self) -> &AttnConfig {
        &self.cfg
    }

    pub fn precision(&self) -> Precision {
        self.precision
    }

    pub fn policy(&self) -> &SparsityPolicy {
        &self.policy
    }

    pub fn execution(&self) -> Execution {
        self.execution
    }

    pub fn kv_split(&self) -> KvSplit {
        self.kv_split
    }

    /// The microkernel backend every kernel under this engine runs on.
    pub fn microkernel(&self) -> Backend {
        self.microkernel
    }

    /// The engine's worker pool, when it runs one — shareable: pass a
    /// clone to [`AttnEngineBuilder::shared_pool`] so another engine
    /// composition reuses the same workers.
    pub fn pool(&self) -> Option<&Arc<WorkerPool>> {
        self.pool.as_ref()
    }

    /// The [`Exec`] seam this engine drives the tiled pipeline with.
    /// Public so batch schedulers (the serving tick) can fan *sessions*
    /// across the same workers the pipeline would use.
    pub fn exec(&self) -> Exec<'_> {
        match (&self.execution, &self.pool) {
            (Execution::Inline, _) => Exec::Inline,
            (Execution::Threads(t), _) => Exec::Threads(*t),
            (Execution::Pool(_), Some(p)) => Exec::Pool(p.as_ref()),
            // unreachable by construction (build() always spawns the pool)
            (Execution::Pool(_), None) => Exec::Inline,
        }
    }

    /// Split-KV span size (k-blocks) for a call of `tm` query tiles over
    /// `tn` cached k-blocks, or `None` to run the row-parallel driver.
    /// Pure in the call *shape*: taller calls (`tm > 1`) already
    /// parallelize over rows, and a domain of at most one span gains
    /// nothing — worker count never enters the decision, so routing (and
    /// therefore output bits) is identical for every execution mode.
    pub(crate) fn kv_span(&self, tm: usize, tn: usize) -> Option<usize> {
        let span = self.kv_split.span_blocks()?;
        if tm == 1 && tn > span {
            Some(span)
        } else {
            None
        }
    }

    /// Run one call through the driver the engine's `kv_split` policy and
    /// the call shape select, writing into `out` (n × dv, fully
    /// overwritten). All scratch comes from `plan`/`ws` (plus each pool
    /// worker's own arena), so a warmed-up single-tile call allocates
    /// nothing.
    #[allow(clippy::too_many_arguments)]
    fn dispatch_into(
        &self,
        q: &Tensor,
        k: &Tensor,
        v: &Tensor,
        cfg: &AttnConfig,
        kernel: &impl ScoreKernel,
        filter: &impl BlockFilter,
        exec: Exec<'_>,
        plan: &mut SpanPlan,
        ws: &mut Workspace,
        out: &mut [f32],
    ) -> SkipStats {
        match self.kv_span(cfg.n_qblocks(q.dim(0)), cfg.n_kblocks(k.dim(0))) {
            Some(span) => {
                run_tiled_splitkv_into(q, k, v, cfg, kernel, filter, exec, span, plan, ws, out)
            }
            None => run_tiled_into(q, k, v, cfg, kernel, filter, exec, ws, out),
        }
    }

    /// One-shot attention of `q` against `k`/`v` under the engine's
    /// composition (the prefill shape). Under the default
    /// [`KvSplit::Off`], bitwise-identical to the deprecated free
    /// functions this API replaces (with split-KV on, a single-tile call
    /// — `q` no taller than `b_q` — takes the split driver and is
    /// allclose instead).
    pub fn attention(&self, q: &Tensor, k: &Tensor, v: &Tensor) -> AttnOutput {
        match &self.policy {
            SparsityPolicy::Dense => {
                let (out, stats) = self.run(q, k, v, &self.cfg, &DenseFilter);
                AttnOutput { out, stats, mask: None }
            }
            SparsityPolicy::Predicted { params, lambda } => {
                let (kt, sim_k) = compress_blocks(k, self.cfg.bk);
                let pred = predict_pooled(q, &kt, &sim_k, &self.cfg, params);
                let (out, stats) = {
                    let filter = MaskFilter::new(&pred.mask, *lambda);
                    self.run(q, k, v, &self.cfg, &filter)
                };
                AttnOutput { out, stats, mask: Some(pred.mask) }
            }
            SparsityPolicy::External { mask, lambda } => {
                assert_eq!(mask.rows, self.cfg.n_qblocks(q.dim(0)), "external mask rows");
                assert_eq!(mask.cols, self.cfg.n_kblocks(k.dim(0)), "external mask cols");
                let filter = MaskFilter::new(mask, *lambda);
                let (out, stats) = self.run(q, k, v, &self.cfg, &filter);
                AttnOutput { out, stats, mask: None }
            }
        }
    }

    /// Open a paged per-sequence session whose KV cache lives in
    /// [`super::paged::PageAllocator`] frames instead of session-owned
    /// tensors — same engine semantics (bitwise for f32/λ-off), shared
    /// memory pool. See [`super::paged`] for the frame/CoW/eviction
    /// contracts.
    pub fn paged_session(&self) -> super::paged::PagedAttnSession<'_> {
        super::paged::PagedAttnSession::new(self)
    }

    /// Open a stateful per-sequence session (KV cache, incremental
    /// predictor pooling, cached K quantization, and the session-owned
    /// workspace + span plan that make warmed-up decode steps
    /// allocation-free) over this engine.
    pub fn session(&self) -> AttnSession<'_> {
        // chunked prefill sets the offset per call from the cache length
        assert_eq!(self.cfg.row_offset, 0, "sessions manage row_offset; build the engine with offset 0");
        AttnSession {
            engine: self,
            d: 0,
            dv: 0,
            rows: 0,
            k_cache: Tensor::zeros(&[0, 0]),
            v_cache: Tensor::zeros(&[0, 0]),
            kpool: None,
            kmean: None,
            kq: Vec::new(),
            qstage: Vec::new(),
            pred_mask: BlockMask::new_all(0, 0, false),
            ws: Workspace::default(),
            plan: SpanPlan::new(),
            steps: 0,
            cache_cap_rows: 0,
            cache_reallocs: 0,
        }
    }

    fn run(
        &self,
        q: &Tensor,
        k: &Tensor,
        v: &Tensor,
        cfg: &AttnConfig,
        filter: &impl BlockFilter,
    ) -> (Tensor, SkipStats) {
        let mut out = Tensor::zeros(&[q.dim(0), v.dim(1)]);
        let mut plan = SpanPlan::new();
        let mut ws = Workspace::default();
        let exec = self.exec();
        let stats = match self.precision {
            Precision::F32 => {
                let kernel = F32Kernel::new(q, k, cfg).with_microkernel(self.microkernel);
                self.dispatch_into(q, k, v, cfg, &kernel, filter, exec, &mut plan, &mut ws, out.data_mut())
            }
            Precision::Int8 => {
                let kernel = QuantScoreKernel::new(q, k, cfg).with_microkernel(self.microkernel);
                self.dispatch_into(q, k, v, cfg, &kernel, filter, exec, &mut plan, &mut ws, out.data_mut())
            }
        };
        (out, stats)
    }
}

// The whole point of the builder: engines are shared across serving
// threads. Compile-time proof of `Send + Sync`.
#[allow(dead_code)]
fn _assert_send_sync<T: Send + Sync>() {}
#[allow(dead_code)]
fn _engine_is_send_sync() {
    _assert_send_sync::<AttnEngine>();
}

/// How the session's stage-1 predictor maintained its pooled state (see
/// [`KPool`]); exposed so callers can assert the update discipline.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PredictorCounters {
    /// Full scans over the whole K cache (the prefill bulk build — the
    /// first chunk of a chunked prefill counts here too).
    pub full_recomputes: usize,
    /// Per-row incremental updates (decode appends).
    pub incremental_updates: usize,
    /// Blockwise multi-row extensions (prefill chunks after the first);
    /// each scans only the new rows plus the boundary block.
    pub chunk_extends: usize,
}

/// Mutable per-sequence state over a shared [`AttnEngine`]: a growing KV
/// cache, incrementally updated stage-1 pooling, (for INT8 engines)
/// cached per-block K quantization with reusable Q staging, and the
/// session-owned [`Workspace`] + [`SpanPlan`] scratch that make a
/// warmed-up decode step allocation-free. See the module docs for the
/// decode/prefill parity contract.
pub struct AttnSession<'e> {
    engine: &'e AttnEngine,
    d: usize,
    dv: usize,
    rows: usize,
    /// Cached keys as a live (rows × d) tensor: rows are appended in
    /// place ([`Tensor::append_rows`]) under the amortized capacity
    /// policy of [`AttnSession::reserve_rows`] — the hot loop never
    /// re-wraps or copies the cache.
    k_cache: Tensor,
    v_cache: Tensor,
    /// Stage-1 pooling state — maintained only under the `Predicted`
    /// policy (the single consumer); dense/external sessions skip the
    /// per-token pooling cost entirely.
    kpool: Option<KPool>,
    /// Frozen K-smoothing channel mean (INT8 only): fixed at the first
    /// append so every cached block shares one shift and softmax's
    /// shift-invariance holds exactly across the growing cache. A session
    /// that decodes from empty freezes it at zero (no smoothing).
    kmean: Option<Vec<f32>>,
    /// Cached INT8 quantization of the smoothed K cache; only the tail
    /// block is requantized — in place, reusing its payload — per
    /// decoded token.
    kq: Vec<QuantBlock>,
    /// Reusable Q-side quantization staging (INT8): the per-call Q blocks
    /// are requantized into these, reusing their payload allocations.
    qstage: Vec<QuantBlock>,
    /// Session-owned decode mask for the `Predicted` policy: each decode
    /// step rebuilds it **in place** ([`predict_decode_row_into`]) so the
    /// predicted hot path allocates nothing once warm. Other policies
    /// leave it empty.
    pred_mask: BlockMask,
    /// The session's scratch arena for inline pipeline work (pool workers
    /// bring their own).
    ws: Workspace,
    /// Cached split-KV plan + partial-state arenas (see [`SpanPlan`]).
    plan: SpanPlan,
    steps: usize,
    /// Rows the K/V cache (and the predictor pool) currently has capacity
    /// for — always a `b_k` multiple; see [`AttnSession::reserve_rows`].
    cache_cap_rows: usize,
    /// Capacity-growth events (both buffers grow together, counted once).
    cache_reallocs: usize,
}

impl AttnSession<'_> {
    /// Cached sequence length (rows of K/V seen so far).
    pub fn len(&self) -> usize {
        self.rows
    }

    pub fn is_empty(&self) -> bool {
        self.rows == 0
    }

    /// Decode steps taken so far.
    pub fn steps(&self) -> usize {
        self.steps
    }

    /// Capacity-growth events on the KV cache so far. Growth is amortized
    /// ([`AttnSession::reserve_rows`]): capacity at least doubles per
    /// event and is always a `b_k`-block multiple, so a decode loop of
    /// `T` tokens reallocates O(log T) times instead of leaving growth
    /// policy to the allocator on every appended token.
    pub fn cache_reallocs(&self) -> usize {
        self.cache_reallocs
    }

    /// Predictor maintenance counters; all-zero for non-`Predicted`
    /// policies (no pooled state is kept for them).
    pub fn predictor_counters(&self) -> PredictorCounters {
        match &self.kpool {
            Some(p) => PredictorCounters {
                full_recomputes: p.full_recomputes,
                incremental_updates: p.incremental_updates,
                chunk_extends: p.chunk_extends,
            },
            None => PredictorCounters::default(),
        }
    }

    /// Prefill an empty session in one shot — a single
    /// [`AttnSession::prefill_chunk`] from empty; the result is
    /// bitwise-identical to `engine.attention(q, k, v)`.
    pub fn prefill(&mut self, q: &Tensor, k: &Tensor, v: &Tensor) -> AttnOutput {
        assert_eq!(self.rows, 0, "prefill on a non-empty session; use prefill_chunk()/decode()");
        self.prefill_chunk(q, k, v)
    }

    /// Append one prompt chunk (`m` rows of q/k/v) to the session and run
    /// the chunk's query rows against the **whole** cache, offset-aware:
    /// query row `i` of the chunk sits at absolute position
    /// `cached rows + i`, so causal masking and the causal-domain block
    /// bound keep referring to absolute positions (see the `row_offset`
    /// contract in [`crate::attention::pipeline`]). The predictor pooling
    /// is extended blockwise over just the new rows ([`KPool::extend`])
    /// and, under INT8, only the boundary block is requantized and fresh
    /// blocks quantized — earlier cached state is reused untouched.
    ///
    /// Parity: for f32/λ-off (dense or external mask), any sequence of
    /// chunks is bitwise-identical row-for-row to the one-shot
    /// [`AttnSession::prefill`]; chunk edges on `b_q` boundaries
    /// additionally reproduce its summed [`SkipStats`] (and λ-on / the
    /// predicted policy — see the parity notes in the module docs).
    /// Chunks after the first require a causal engine: later positions
    /// are not cached yet, so a non-causal chunk could not see them.
    pub fn prefill_chunk(&mut self, q: &Tensor, k: &Tensor, v: &Tensor) -> AttnOutput {
        assert_eq!(q.dim(0), k.dim(0), "prefill chunk q/k rows");
        assert_eq!(k.dim(0), v.dim(0), "k/v rows");
        assert!(k.dim(0) > 0, "empty prefill chunk");
        let row0 = self.rows;
        assert!(
            row0 == 0 || self.engine.cfg.causal,
            "multi-chunk prefill needs a causal engine (later rows are not cached yet)"
        );
        if row0 == 0 {
            self.init_dims(k, v);
            if self.engine.precision == Precision::Int8 {
                // freeze the smoothing mean on the first chunk: every
                // cached block must share one shift for softmax's
                // shift-invariance to hold across the growing cache (a
                // single chunk reproduces the one-shot global mean exactly)
                self.kmean = Some(quant::channel_mean(k));
            }
        }
        assert_eq!(q.dim(1), self.d, "q head dim");
        assert_eq!(k.dim(1), self.d, "k head dim");
        assert_eq!(v.dim(1), self.dv, "v dim");

        self.reserve_rows(self.rows + k.dim(0));
        self.k_cache.append_rows(k.data());
        self.v_cache.append_rows(v.data());
        self.rows += k.dim(0);
        if let Some(pool) = self.kpool.as_mut() {
            pool.extend(row0, self.k_cache.data());
        }
        if self.engine.precision == Precision::Int8 {
            self.requantize_from(row0);
            self.stage_q(q);
        }

        let cfg = self.engine.cfg.at_offset(row0);
        let mut out = Tensor::zeros(&[q.dim(0), self.dv]);
        let mut ws = std::mem::take(&mut self.ws);
        let mut plan = std::mem::take(&mut self.plan);
        let exec = self.engine.exec();
        let (stats, mask) = match &self.engine.policy {
            SparsityPolicy::Dense => {
                let st = self.run_cache(q, &cfg, &DenseFilter, exec, &mut plan, &mut ws, out.data_mut());
                (st, None)
            }
            SparsityPolicy::Predicted { params, lambda } => {
                // reuse the incrementally-pooled K side; for a one-shot
                // prefill this is bitwise-identical to predict()
                let pool = self.kpool.as_ref().unwrap();
                let pred = predict_pooled(q, &pool.means(), pool.sims(), &cfg, params);
                let st = {
                    let filter = MaskFilter::new(&pred.mask, *lambda);
                    self.run_cache(q, &cfg, &filter, exec, &mut plan, &mut ws, out.data_mut())
                };
                (st, Some(pred.mask))
            }
            SparsityPolicy::External { mask, lambda } => {
                // the external mask is indexed by *global* block rows, so
                // a chunk must start on a query-block boundary to map
                // onto it; a decode-ready mask may already cover positions
                // past the chunk — require coverage, not exact geometry
                assert_eq!(
                    row0 % cfg.bq,
                    0,
                    "chunked prefill under an external mask must start at a b_q boundary"
                );
                let row0_blocks = row0 / cfg.bq;
                assert!(
                    mask.rows >= row0_blocks + cfg.n_qblocks(q.dim(0)),
                    "external mask has {} block rows; chunk needs {}",
                    mask.rows,
                    row0_blocks + cfg.n_qblocks(q.dim(0))
                );
                assert!(
                    mask.cols >= cfg.n_kblocks(self.rows),
                    "external mask has {} block cols; cache needs {}",
                    mask.cols,
                    cfg.n_kblocks(self.rows)
                );
                let filter = OffsetMaskFilter { mask, row0: row0_blocks, lambda: *lambda };
                let st = self.run_cache(q, &cfg, &filter, exec, &mut plan, &mut ws, out.data_mut());
                (st, None)
            }
        };
        self.ws = ws;
        self.plan = plan;
        AttnOutput { out, stats, mask }
    }

    /// First-append initialization: record dims and shape the caches.
    fn init_dims(&mut self, k: &Tensor, v: &Tensor) {
        self.d = k.dim(1);
        self.dv = v.dim(1);
        self.k_cache = Tensor::from_vec(&[0, self.d], Vec::new());
        self.v_cache = Tensor::from_vec(&[0, self.dv], Vec::new());
        if matches!(self.engine.policy, SparsityPolicy::Predicted { .. }) {
            self.kpool = Some(KPool::new(self.engine.cfg.bk, self.d).with_microkernel(self.engine.microkernel));
        }
    }

    /// Run `q` against the cached K/V under `cfg` (which carries the
    /// chunk's `row_offset` and, for decode steps, `causal: false`),
    /// writing the output rows into `out`. One code path serves one-shot
    /// prefill, prefill chunks, and decode steps; the INT8 side reuses
    /// the session's cached K quantization and pre-staged Q blocks
    /// instead of re-smoothing and re-quantizing (the per-block payloads
    /// are identical: blocks are quantized independently and the
    /// smoothing mean is shared either way). The driver — row-parallel
    /// or split-KV — is chosen by the engine's `kv_split` policy and the
    /// call *shape* alone, so the result does not depend on `exec`.
    #[allow(clippy::too_many_arguments)]
    fn run_cache(
        &self,
        q: &Tensor,
        cfg: &AttnConfig,
        filter: &impl BlockFilter,
        exec: Exec<'_>,
        plan: &mut SpanPlan,
        ws: &mut Workspace,
        out: &mut [f32],
    ) -> SkipStats {
        let (kc, vc) = (&self.k_cache, &self.v_cache);
        match self.engine.precision {
            Precision::F32 => {
                let kernel = F32Kernel::new(q, kc, cfg).with_microkernel(self.engine.microkernel);
                self.engine.dispatch_into(q, kc, vc, cfg, &kernel, filter, exec, plan, ws, out)
            }
            Precision::Int8 => {
                let kernel = QuantCacheKernel {
                    qb: &self.qstage,
                    kb: &self.kq,
                    scale: cfg.scale_for(q.dim(1)),
                    causal: cfg.causal,
                    row_offset: cfg.row_offset,
                    bq: cfg.bq,
                    bk: cfg.bk,
                    mk: self.engine.microkernel,
                };
                self.engine.dispatch_into(q, kc, vc, cfg, &kernel, filter, exec, plan, ws, out)
            }
        }
    }

    /// Decode one token: append the (1 × d) key/value rows to the cache,
    /// update the predictor pooling incrementally (and requantize only the
    /// tail K block under INT8), then run the 1-row step through the
    /// driver the engine's `kv_split` policy selects (split-KV when on:
    /// the single-tile step fans its KV spans across the pool). Returns
    /// the (1 × dv) output row with per-step [`SkipStats`] (exact
    /// fractional accounting — see `SkipStats::pv_skipped_frac`).
    ///
    /// Allocation note: this convenience allocates the returned tensor;
    /// the serving loop uses [`AttnSession::decode_into`], which writes
    /// into a caller buffer and is zero-allocation once warm.
    pub fn decode(&mut self, q: &Tensor, k: &Tensor, v: &Tensor) -> AttnOutput {
        self.decode_with_exec(q, k, v, self.engine.exec())
    }

    /// [`AttnSession::decode`] writing the output row directly into
    /// `out` (length dv) — no allocation on a warmed-up session under
    /// **every** policy: the `Predicted` step rebuilds the session-owned
    /// mask in place and returns a borrow of it instead of an owned
    /// clone. Stats and bits are identical to [`AttnSession::decode`].
    pub fn decode_into(
        &mut self,
        q: &Tensor,
        k: &Tensor,
        v: &Tensor,
        out: &mut [f32],
    ) -> (SkipStats, Option<&BlockMask>) {
        self.decode_into_with_exec(q, k, v, out, self.engine.exec())
    }

    /// [`AttnSession::decode`] with an explicit [`Exec`]: the serving
    /// tick advances many sessions in one pool map and runs each step
    /// `Exec::Inline` *inside* a pool worker (nesting the pool would
    /// deadlock). Both drivers are bitwise-deterministic across exec
    /// modes, so the step's output does not depend on this choice.
    pub(crate) fn decode_with_exec(
        &mut self,
        q: &Tensor,
        k: &Tensor,
        v: &Tensor,
        exec: Exec<'_>,
    ) -> AttnOutput {
        self.append_token(q, k, v);
        let mut out = Tensor::zeros(&[1, self.dv]);
        let (stats, predicted) = self.decode_step(q, exec, out.data_mut());
        let mask = predicted.then(|| self.pred_mask.clone());
        AttnOutput { out, stats, mask }
    }

    /// [`AttnSession::decode_into`] with an explicit [`Exec`] (see
    /// [`AttnSession::decode_with_exec`]).
    pub(crate) fn decode_into_with_exec(
        &mut self,
        q: &Tensor,
        k: &Tensor,
        v: &Tensor,
        out: &mut [f32],
        exec: Exec<'_>,
    ) -> (SkipStats, Option<&BlockMask>) {
        // validate before touching session state: a bad buffer must not
        // leave a half-applied token in the cache
        assert_eq!(out.len(), v.dim(1), "decode_into output buffer must hold one dv row");
        self.append_token(q, k, v);
        let (stats, predicted) = self.decode_step(q, exec, out);
        (stats, predicted.then_some(&self.pred_mask))
    }

    /// The append half of a decode step: init-on-empty, amortized
    /// capacity, KV append, incremental predictor pooling, INT8 tail
    /// requantize + Q staging. Allocation-free once warm.
    fn append_token(&mut self, q: &Tensor, k: &Tensor, v: &Tensor) {
        assert_eq!(q.dim(0), 1, "decode takes a single query row");
        assert_eq!(k.dim(0), 1, "decode takes a single key row");
        assert_eq!(v.dim(0), 1, "decode takes a single value row");
        if self.rows == 0 {
            self.init_dims(k, v);
            if self.engine.precision == Precision::Int8 {
                // Init-on-empty: runs once on the first appended token,
                // before the session is warm. sparge-lint: allow(hot-path-no-alloc)
                self.kmean = Some(vec![0.0; self.d]);
            }
        }
        assert_eq!(q.dim(1), self.d, "q head dim");
        assert_eq!(k.dim(1), self.d, "k head dim");
        assert_eq!(v.dim(1), self.dv, "v dim");

        // append (block-amortized capacity) + incremental predictor
        // update (tail block only)
        self.reserve_rows(self.rows + 1);
        self.k_cache.append_rows(k.data());
        self.v_cache.append_rows(v.data());
        self.rows += 1;
        let bk = self.engine.cfg.bk;
        let tail_start = ((self.rows - 1) / bk) * bk;
        if let Some(pool) = self.kpool.as_mut() {
            let tail = &self.k_cache.data()[tail_start * self.d..self.rows * self.d];
            pool.append_row(k.row(0), tail);
        }
        if self.engine.precision == Precision::Int8 {
            self.requantize_from(self.rows - 1);
            self.stage_q(q);
        }
    }

    /// The compute half of a decode step: run the 1-row call over the
    /// cache and write the output row into `out`. The bool is true when
    /// the step refreshed the session's [`AttnSession::pred_mask`]
    /// (`Predicted` policy only).
    fn decode_step(&mut self, q: &Tensor, exec: Exec<'_>, out: &mut [f32]) -> (SkipStats, bool) {
        // the decode step sees exactly the visible prefix, so it runs
        // non-causal over the cache; scale/bk/cw carry over from the engine
        let step_cfg = AttnConfig { causal: false, ..self.engine.cfg };
        let scale = step_cfg.scale_for(self.d);
        let mut ws = std::mem::take(&mut self.ws);
        let mut plan = std::mem::take(&mut self.plan);
        let res = match &self.engine.policy {
            SparsityPolicy::Dense => {
                let st = self.run_cache(q, &step_cfg, &DenseFilter, exec, &mut plan, &mut ws, out);
                (st, false)
            }
            SparsityPolicy::Predicted { params, lambda } => {
                // rebuild the session-owned mask in place from pooled
                // state staged through the workspace — value-identical to
                // the allocating predict_decode_row, and allocation-free
                // once the arenas have reached their high-water sizes
                {
                    let pool = self.kpool.as_ref().unwrap();
                    pool.means_into(&mut ws.pred_means);
                    predict_decode_row_into(
                        q.row(0),
                        &ws.pred_means,
                        pool.sims(),
                        scale,
                        params,
                        &mut self.pred_mask,
                        &mut ws.pred_scores,
                        &mut ws.pred_probs,
                        &mut ws.pred_idx,
                    );
                }
                let st = {
                    let filter = MaskFilter::new(&self.pred_mask, *lambda);
                    self.run_cache(q, &step_cfg, &filter, exec, &mut plan, &mut ws, out)
                };
                (st, true)
            }
            SparsityPolicy::External { mask, lambda } => {
                let bi = (self.rows - 1) / self.engine.cfg.bq;
                assert!(bi < mask.rows, "external mask has {} block rows; decode is at row {bi}", mask.rows);
                assert!(
                    step_cfg.n_kblocks(self.rows) <= mask.cols,
                    "external mask has {} block cols; cache needs {}",
                    mask.cols,
                    step_cfg.n_kblocks(self.rows)
                );
                let filter = RowMaskFilter { mask, row: bi, lambda: *lambda };
                let st = self.run_cache(q, &step_cfg, &filter, exec, &mut plan, &mut ws, out);
                (st, false)
            }
        };
        self.ws = ws;
        self.plan = plan;
        self.steps += 1;
        res
    }

    /// Grow the KV cache's reserved capacity to hold `new_rows` rows.
    /// Amortized: capacity targets `max(new_rows, 2 × current)` rounded
    /// up to a whole `b_k` block, so appends — per-token decode pushes
    /// included — trigger O(log n) reallocations, counted in
    /// [`AttnSession::cache_reallocs`]. The predictor pool reserves its
    /// per-block state for the same horizon.
    fn reserve_rows(&mut self, new_rows: usize) {
        if new_rows <= self.cache_cap_rows {
            return;
        }
        let bk = self.engine.cfg.bk;
        let target = new_rows.max(self.cache_cap_rows * 2).next_multiple_of(bk);
        self.k_cache.reserve_rows(target);
        self.v_cache.reserve_rows(target);
        if let Some(pool) = self.kpool.as_mut() {
            pool.reserve_rows(target);
        }
        self.cache_cap_rows = target;
        self.cache_reallocs += 1;
    }

    /// (Re)quantize the K cache from the block containing row
    /// `rows_before` through the cache end, with the frozen smoothing
    /// mean: a decode step touches only the tail block, a prefill chunk
    /// additionally quantizes the fresh blocks it appended; every earlier
    /// cached block is reused as-is, and touched blocks requantize **in
    /// place** into their existing payloads (smoothing staged through the
    /// workspace) — allocation-free once warm. Blocks are quantized
    /// independently, so the surviving prefix is bit-identical to a
    /// from-scratch `quantize_blocks` of the smoothed cache.
    fn requantize_from(&mut self, rows_before: usize) {
        let mean = self.kmean.as_ref().expect("kmean frozen at first append");
        let bk = self.engine.cfg.bk;
        let d = self.d;
        let first = rows_before / bk;
        let kd = self.k_cache.data();
        let stage = &mut self.ws.quant_f32;
        let mut b = first;
        let mut r0 = first * bk;
        while r0 < self.rows {
            let r1 = (r0 + bk).min(self.rows);
            stage.clear();
            stage.extend_from_slice(&kd[r0 * d..r1 * d]);
            for row in stage.chunks_mut(d) {
                for (x, &m) in row.iter_mut().zip(mean) {
                    *x -= m;
                }
            }
            if b < self.kq.len() {
                self.kq[b].requantize(stage, r1 - r0, d);
            } else {
                self.kq.push(QuantBlock::quantize(stage, r1 - r0, d));
            }
            // a partial tail block refills row by row across decode
            // steps; holding full-block payload capacity from the start
            // keeps those in-place requantizes allocation-free
            let blk = &mut self.kq[b];
            blk.data.reserve_exact(bk * d - blk.data.len());
            b += 1;
            r0 = r1;
        }
        self.kq.truncate(b);
    }

    /// Quantize the call's Q rows into the session's reusable staging
    /// blocks (INT8 engines; payload values identical to a fresh
    /// `quantize_blocks`).
    fn stage_q(&mut self, q: &Tensor) {
        quant::quantize_blocks_into(q, self.engine.cfg.bq, &mut self.qstage);
    }
}

/// INT8 kernel over the session's cached K blocks: Q is staged per call
/// (all blocks of a prefill chunk, one row per decode step — requantized
/// into reusable session buffers); K blocks are borrowed from the cache
/// so they are quantized exactly once each. `row_offset` places the
/// chunk's query rows at absolute positions for causal masking.
struct QuantCacheKernel<'a> {
    qb: &'a [QuantBlock],
    kb: &'a [QuantBlock],
    scale: f32,
    causal: bool,
    row_offset: usize,
    bq: usize,
    bk: usize,
    mk: Backend,
}

impl ScoreKernel for QuantCacheKernel<'_> {
    fn score_block(
        &self,
        q0: usize,
        _q1: usize,
        k0: usize,
        _k1: usize,
        out: &mut [f32],
        scratch: &mut ScoreScratch<'_>,
    ) {
        let qblk = &self.qb[q0 / self.bq];
        let kblk = &self.kb[k0 / self.bk];
        let q0_abs = self.row_offset + q0;
        quant_score_block(self.mk, qblk, kblk, q0_abs, k0, self.scale, self.causal, out, scratch.acc_i32);
    }

    fn microkernel(&self) -> Backend {
        self.mk
    }
}

/// Filter for one prefill chunk under an external full-sequence mask:
/// block-row lookups are shifted by the chunk's starting block row, so
/// local tile `bi` reads global mask row `row0 + bi`.
pub(crate) struct OffsetMaskFilter<'a> {
    pub(crate) mask: &'a BlockMask,
    pub(crate) row0: usize,
    pub(crate) lambda: Option<f32>,
}

impl BlockFilter for OffsetMaskFilter<'_> {
    fn keep(&self, bi: usize, bj: usize) -> bool {
        self.mask.get(self.row0 + bi, bj)
    }

    fn lambda(&self) -> Option<f32> {
        self.lambda
    }
}

/// Filter for one decode step under an external full-sequence mask: block
/// decisions come from the mask row the decoded position belongs to.
pub(crate) struct RowMaskFilter<'a> {
    pub(crate) mask: &'a BlockMask,
    pub(crate) row: usize,
    pub(crate) lambda: Option<f32>,
}

impl BlockFilter for RowMaskFilter<'_> {
    fn keep(&self, _bi: usize, bj: usize) -> bool {
        self.mask.get(self.row, bj)
    }

    fn lambda(&self) -> Option<f32> {
        self.lambda
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::dense::attention_naive;
    use crate::util::prop::{assert_allclose, rel_l1};
    use crate::util::rng::Pcg;

    fn qkv(n: usize, d: usize, seed: u64) -> (Tensor, Tensor, Tensor) {
        let mut rng = Pcg::seeded(seed);
        (Tensor::randn(&[n, d], &mut rng), Tensor::randn(&[n, d], &mut rng), Tensor::randn(&[n, d], &mut rng))
    }

    #[test]
    fn builder_composes_and_matches_oracle() {
        let (q, k, v) = qkv(48, 8, 71);
        let cfg = AttnConfig { bq: 16, bk: 8, causal: false, scale: None, cw: 2, row_offset: 0 };
        let engine = AttnEngine::dense(cfg);
        let r = engine.attention(&q, &k, &v);
        let oracle = attention_naive(&q, &k, &v, &cfg);
        assert_allclose(r.out.data(), oracle.data(), 1e-4, 1e-3, "engine-dense").unwrap();
        assert_eq!(r.stats.sparsity(), 0.0);
        assert!(r.mask.is_none());
    }

    #[test]
    fn execution_modes_are_bitwise_identical() {
        let (q, k, v) = qkv(96, 16, 72);
        let cfg = AttnConfig { bq: 16, bk: 16, causal: true, scale: None, cw: 2, row_offset: 0 };
        let params = SpargeParams { tau: 0.9, theta: 0.3, lambda: Some(-6.0), quant: false };
        let base = AttnEngine::sparge(cfg, &params).attention(&q, &k, &v);
        for exec in [Execution::Threads(4), Execution::Pool(2), Execution::Pool(8)] {
            let engine = AttnEngine::builder().config(cfg).sparge(&params).execution(exec).build();
            let r = engine.attention(&q, &k, &v);
            assert_eq!(r.out, base.out, "{exec:?}");
            assert_eq!(r.stats, base.stats, "{exec:?}");
            assert_eq!(r.mask, base.mask, "{exec:?}");
        }
    }

    #[test]
    fn engine_is_reusable_and_shared_across_threads() {
        let (q, k, v) = qkv(64, 8, 73);
        let cfg = AttnConfig { bq: 16, bk: 16, causal: false, scale: None, cw: 2, row_offset: 0 };
        let engine = AttnEngine::builder()
            .config(cfg)
            .sparge(&SpargeParams::default())
            .execution(Execution::Pool(2))
            .build();
        let first = engine.attention(&q, &k, &v);
        let outs: Vec<Tensor> = std::thread::scope(|scope| {
            let handles: Vec<_> =
                (0..4).map(|_| scope.spawn(|| engine.attention(&q, &k, &v).out)).collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for o in outs {
            assert_eq!(o, first.out);
        }
    }

    #[test]
    fn external_policy_checks_geometry() {
        let (q, k, v) = qkv(32, 8, 74);
        let cfg = AttnConfig { bq: 8, bk: 8, causal: false, scale: None, cw: 2, row_offset: 0 };
        let mask = BlockMask::new_all(4, 4, true);
        let engine = AttnEngine::builder()
            .config(cfg)
            .policy(SparsityPolicy::External { mask, lambda: None })
            .build();
        let r = engine.attention(&q, &k, &v);
        assert_eq!(r.stats.sparsity(), 0.0);
    }

    #[test]
    fn shared_pool_serves_multiple_engine_compositions() {
        // The ROADMAP follow-up: dense + sparge engines time-sharing one
        // worker pool, with outputs identical to privately-pooled engines.
        let (q, k, v) = qkv(64, 8, 76);
        let cfg = AttnConfig { bq: 16, bk: 16, causal: false, scale: None, cw: 2, row_offset: 0 };
        let pool = WorkerPool::shared(3);
        let params = SpargeParams { tau: 0.9, theta: 0.3, lambda: Some(-6.0), quant: false };
        let dense = AttnEngine::builder().config(cfg).shared_pool(Arc::clone(&pool)).build();
        let sparge =
            AttnEngine::builder().config(cfg).sparge(&params).shared_pool(Arc::clone(&pool)).build();
        assert_eq!(dense.execution(), Execution::Pool(3));
        assert_eq!(Arc::strong_count(&pool), 3, "two engines joined the shared pool");
        let d_ref = AttnEngine::builder().config(cfg).execution(Execution::Pool(2)).build();
        let s_ref =
            AttnEngine::builder().config(cfg).sparge(&params).execution(Execution::Pool(2)).build();
        assert_eq!(dense.attention(&q, &k, &v).out, d_ref.attention(&q, &k, &v).out);
        let (a, b) = (sparge.attention(&q, &k, &v), s_ref.attention(&q, &k, &v));
        assert_eq!(a.out, b.out);
        assert_eq!(a.stats, b.stats);
    }

    #[test]
    fn cache_growth_is_block_amortized_and_counted() {
        // b_k = 16: prefilling 32 rows reserves once (to 32); decoding to
        // 128 rows doubles twice (33→64, 65→128). Per-token pushes must
        // never trigger a growth event of their own.
        let (q, k, v) = qkv(128, 8, 77);
        let cfg = AttnConfig { bq: 16, bk: 16, causal: true, scale: None, cw: 2, row_offset: 0 };
        let engine = AttnEngine::dense(cfg);
        let mut session = engine.session();
        session.prefill(&q.rows(0, 32), &k.rows(0, 32), &v.rows(0, 32));
        assert_eq!(session.cache_reallocs(), 1);
        for t in 32..64 {
            session.decode(&q.rows(t, t + 1), &k.rows(t, t + 1), &v.rows(t, t + 1));
        }
        assert_eq!(session.cache_reallocs(), 2, "one doubling covers rows 33..=64");
        for t in 64..128 {
            session.decode(&q.rows(t, t + 1), &k.rows(t, t + 1), &v.rows(t, t + 1));
        }
        assert_eq!(session.cache_reallocs(), 3, "one more doubling covers rows 65..=128");
    }

    #[test]
    fn decode_into_matches_decode_bitwise() {
        // The zero-allocation entry point must be a pure repackaging of
        // decode(): same bits, same stats, for dense and predicted, both
        // drivers.
        let (q, k, v) = qkv(96, 8, 79);
        for split in [KvSplit::Off, KvSplit::Blocks(2)] {
            let cfg = AttnConfig { bq: 16, bk: 8, causal: true, scale: None, cw: 2, row_offset: 0 };
            let params = SpargeParams { tau: 0.9, theta: 0.3, lambda: Some(-6.0), quant: false };
            let mk = |sparge: bool| {
                let b = AttnEngine::builder().config(cfg).kv_split(split);
                if sparge { b.sparge(&params).build() } else { b.build() }
            };
            for sparge in [false, true] {
                let engine_a = mk(sparge);
                let engine_b = mk(sparge);
                let mut sa = engine_a.session();
                let mut sb = engine_b.session();
                sa.prefill(&q.rows(0, 64), &k.rows(0, 64), &v.rows(0, 64));
                sb.prefill(&q.rows(0, 64), &k.rows(0, 64), &v.rows(0, 64));
                let mut row = vec![0f32; 8];
                for t in 64..96 {
                    let r = sa.decode(&q.rows(t, t + 1), &k.rows(t, t + 1), &v.rows(t, t + 1));
                    let (st, mask) =
                        sb.decode_into(&q.rows(t, t + 1), &k.rows(t, t + 1), &v.rows(t, t + 1), &mut row);
                    assert_eq!(row.as_slice(), r.out.data(), "sparge={sparge} split={split:?} row {t}");
                    assert_eq!(st, r.stats);
                    assert_eq!(mask.cloned(), r.mask);
                }
            }
        }
    }

    #[test]
    fn kv_split_decode_is_allclose_to_serial_and_stats_exact() {
        let (q, k, v) = qkv(96, 8, 78);
        let cfg = AttnConfig { bq: 16, bk: 8, causal: true, scale: None, cw: 2, row_offset: 0 };
        let serial = AttnEngine::dense(cfg);
        let split = AttnEngine::builder().config(cfg).kv_split(KvSplit::Blocks(2)).build();
        let mut s0 = serial.session();
        let mut s1 = split.session();
        s0.prefill(&q.rows(0, 64), &k.rows(0, 64), &v.rows(0, 64));
        s1.prefill(&q.rows(0, 64), &k.rows(0, 64), &v.rows(0, 64));
        for t in 64..96 {
            let r0 = s0.decode(&q.rows(t, t + 1), &k.rows(t, t + 1), &v.rows(t, t + 1));
            let r1 = s1.decode(&q.rows(t, t + 1), &k.rows(t, t + 1), &v.rows(t, t + 1));
            crate::util::prop::assert_allclose(
                r1.out.data(),
                r0.out.data(),
                1e-4,
                1e-3,
                &format!("splitkv decode row {t}"),
            )
            .unwrap();
            assert_eq!(r1.stats, r0.stats, "λ-off stats must merge exactly (row {t})");
        }
    }

    #[test]
    fn int8_session_decode_tracks_dense_reference() {
        // quant decode is approximate (frozen smoothing mean, per-step row
        // quantization) but must stay within the INT8 budget of the f32
        // dense oracle.
        let (q, k, v) = qkv(72, 16, 75);
        let cfg = AttnConfig { bq: 16, bk: 16, causal: true, scale: None, cw: 2, row_offset: 0 };
        let engine = AttnEngine::builder().config(cfg).precision(Precision::Int8).build();
        let mut session = engine.session();
        let n0 = 48;
        let pre = session.prefill(&q.rows(0, n0), &k.rows(0, n0), &v.rows(0, n0));
        // the cached-K-quantization prefill path must equal the one-shot
        let oneshot = engine.attention(&q.rows(0, n0), &k.rows(0, n0), &v.rows(0, n0));
        assert_eq!(pre.out, oneshot.out);
        assert_eq!(pre.stats, oneshot.stats);
        let oracle = attention_naive(&q, &k, &v, &cfg);
        for t in n0..72 {
            let r = session.decode(&q.rows(t, t + 1), &k.rows(t, t + 1), &v.rows(t, t + 1));
            let err = rel_l1(r.out.data(), oracle.row(t));
            assert!(err < 0.1, "int8 decode row {t} rel-L1 {err}");
        }
        assert_eq!(session.len(), 72);
        assert_eq!(session.steps(), 24);
    }
}
