//! # SpargeAttn — training-free sparse + quantized attention (reproduction)
//!
//! Rust + JAX + Pallas three-layer reproduction of *SpargeAttention:
//! Accurate and Training-free Sparse Attention Accelerating Any Model
//! Inference* (Zhang et al., ICML 2025).
//!
//! Layers:
//! - **L1** (`python/compile/kernels/`): Pallas sparse-attention kernel,
//!   interpret-mode, validated against a pure-jnp oracle.
//! - **L2** (`python/compile/model.py`): JAX transformer (text LM + DiT
//!   proxy) whose attention dispatches to the kernel; AOT-lowered to HLO
//!   text artifacts by `python/compile/aot.py`.
//! - **L3** (this crate): the serving coordinator plus the block-sparse
//!   attention engine with *real* skipping (wall-clock measurements).
//!
//! The attention public API is the [`attention::AttnEngine`] builder:
//! precision ([`attention::Precision`]: f32 / SageAttention INT8) ×
//! sparsity policy ([`attention::SparsityPolicy`]: dense / predicted
//!  stage-1+2 / external mask) × execution ([`attention::Execution`]:
//! inline / scoped threads / persistent worker pool) compose into a
//! reusable `Send + Sync` engine. `engine.attention(q, k, v)` is the
//! one-shot (prefill) call; `engine.session()` opens stateful
//! per-sequence serving: a growing KV cache, incremental stage-1
//! predictor pooling, cached K quantization,
//! [`attention::AttnSession::prefill_chunk`] offset-aware chunked prefill,
//! and [`attention::AttnSession::decode`] steps — both bitwise-identical
//! to a one-shot full-sequence prefill (f32, λ off).
//! `engine.paged_session()` is the serving-scale variant: the KV cache
//! (plus the pooled stage-1 means and INT8 payloads, so Predicted and
//! Int8 page too) lives in fixed `b_k`-row frames rented from a shared
//! [`attention::PageAllocator`] — copy-on-write prompt-prefix sharing
//! across sessions ([`attention::PrefixRegistry`]), LRU eviction with
//! spill/re-page-in, and frame exhaustion surfaced as values, never
//! panics — while decoding bitwise-identically to the monolithic
//! session (`tests/paged_kv.rs`). The coordinator
//! serves many sessions at once: its continuous-batching scheduler
//! ([`coordinator::SessionManager`] + the token-level worker loop)
//! interleaves bounded prefill chunks and per-tick decode steps over one
//! shared engine/pool, reporting TTFT/TPOT and per-session sparsity —
//! and, with `SessionManager::new_paged`, admits streams by free-frame
//! reservation against the page pool, shedding load instead of
//! oversubscribing it. The
//! old free functions (`attention_flash*`, `sparse_flash*`,
//! `sparge_attention*`) remain as deprecated shims — see the migration
//! table in [`attention`].
//!
//! Underneath, every composition runs through **one** tiled
//! q-block × k-block loop with two drivers:
//! [`attention::pipeline::run_tiled`] (parallel over query-block rows —
//! the prefill shape) and [`attention::pipeline::run_tiled_splitkv`]
//! (Flash-Decoding: a decode step's KV domain is cut into contiguous
//! spans reduced in parallel and merged deterministically — the serving
//! hot path, opt-in via [`attention::KvSplit`]). Both share the seams:
//! [`attention::pipeline::ScoreKernel`] (how a score block is produced),
//! [`attention::pipeline::BlockFilter`] (stage-1 mask lookup, stage-2 λ,
//! causal-domain bound), and [`attention::pipeline::Exec`] (who runs the
//! work — inline, scoped threads, or a persistent pool shareable across
//! engines, handing out items by chunked self-scheduling with the
//! submitter participating). The flop-dominant inner loops of every
//! score kernel — f32 QKᵀ, the m=1 decode GEMV, the INT8 dot, the P̃·V
//! accumulate — bottom out in the **microkernel tier**
//! ([`tensor::microkernel::Backend`]): runtime CPU dispatch between
//! portable lane-by-lane kernels and AVX2+FMA ones (`--features simd`),
//! with a per-kernel determinism tier — fixed-order kernels are
//! bitwise-identical across backends, the P̃·V accumulate is
//! allclose-vs-oracle — documented next to the merge-order contract in
//! [`attention::pipeline`]. The steady-state decode step is
//! **allocation-free**: scratch lives in per-worker/per-session
//! [`attention::Workspace`] arenas and the session's cached
//! [`attention::SpanPlan`] and predicted-mask buffers, all
//! bitwise-neutral (counting-allocator regression suite in
//! `tests/alloc_regression.rs`, covering dense, external-mask, INT8,
//! and predicted decode plus whole `SessionManager` ticks — paged
//! decode steps and paged serving ticks included). Around it:
//! the mask-prediction pipeline, baselines (each just a mask
//! constructor), workloads, tuner, cost model, and the PJRT runtime
//! that loads and executes the artifacts. Python never runs on the
//! request path.
//!
//! The serving loop also carries a **graceful-degradation tier**
//! ([`coordinator::fault`]): faults degrade one request, never the
//! loop. A worker-job panic or poisoned (NaN/Inf) decode input
//! quarantines only its own stream (frames released through the normal
//! eviction path, terminal error recorded); per-request deadlines and
//! token budgets cancel or truncate at tick boundaries;
//! `SessionManager::drain` finishes or sheds every resident and hands
//! the frame pool back whole, wired into the TCP front end's shutdown
//! along with per-connection read/write timeouts. A seeded
//! [`coordinator::FaultPlan`] injects panics, frame exhaustion,
//! stalls, and poisoned inputs on schedule; with no plan installed the
//! recovery machinery costs one branch per tick and zero allocations.
//! `tests/chaos_serving.rs` property-tests the whole tier over seeded
//! random fault schedules.
//!
//! These contracts are machine-checked: `cargo run -p xtask -- lint`
//! runs the repo-contract static-analysis pass (unsafe hygiene,
//! fixed-order/no-FMA, hot-path/no-alloc, thread-spawn and serving-panic
//! confinement), and CI backs it with Miri, ThreadSanitizer, and loom
//! model checks over the unsafe concurrency core. See CONTRIBUTING.md
//! ("Correctness contracts and how they're enforced") for the full
//! contract → static rule → runtime suite map and local run commands.

// Every unsafe operation must sit in an explicit `unsafe {}` block with
// its own `// SAFETY:` comment, even inside `unsafe fn` — enforced
// together with the sparge-lint `unsafe-needs-safety` rule.
#![deny(unsafe_op_in_unsafe_fn)]
// Style lints we deliberately keep off (clippy runs with -D warnings in
// CI): index-based loops mirror the kernel math they implement, and the
// wide seam signatures (q/k/v/dims/scale...) are the documented API.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::uninlined_format_args)]
#![allow(clippy::too_many_arguments)]

pub mod attention;
pub mod baselines;
pub mod coordinator;
pub mod costmodel;
pub mod experiments;
pub mod models;
pub mod runtime;
pub mod sparge;
pub mod tensor;
pub mod util;
pub mod workloads;
