//! # SpargeAttn — training-free sparse + quantized attention (reproduction)
//!
//! Rust + JAX + Pallas three-layer reproduction of *SpargeAttention:
//! Accurate and Training-free Sparse Attention Accelerating Any Model
//! Inference* (Zhang et al., ICML 2025).
//!
//! Layers:
//! - **L1** (`python/compile/kernels/`): Pallas sparse-attention kernel,
//!   interpret-mode, validated against a pure-jnp oracle.
//! - **L2** (`python/compile/model.py`): JAX transformer (text LM + DiT
//!   proxy) whose attention dispatches to the kernel; AOT-lowered to HLO
//!   text artifacts by `python/compile/aot.py`.
//! - **L3** (this crate): the serving coordinator plus the block-sparse
//!   attention engine with *real* skipping (wall-clock measurements). All
//!   attention — dense flash, SpargeAttn f32, SageAttention INT8, and every
//!   baseline mask policy — runs through **one** tiled q-block × k-block
//!   driver, [`attention::pipeline::run_tiled`], parallel over query-block
//!   rows, with two pluggable seams: [`attention::pipeline::ScoreKernel`]
//!   (how a score block is produced) and
//!   [`attention::pipeline::BlockFilter`] (stage-1 mask lookup, stage-2 λ,
//!   causal-domain bound). Around it: the mask-prediction pipeline,
//!   baselines (each just a mask constructor), workloads, tuner, cost
//!   model, and the PJRT runtime that loads and executes the artifacts.
//!   Python never runs on the request path.

pub mod attention;
pub mod baselines;
pub mod coordinator;
pub mod costmodel;
pub mod experiments;
pub mod models;
pub mod runtime;
pub mod sparge;
pub mod tensor;
pub mod util;
pub mod workloads;
