//! `sparge` — CLI for the SpargeAttn reproduction.
//!
//! Subcommands:
//!   serve       start the TCP serving coordinator over the artifacts
//!   train       train the tiny byte-LM through the lm_train_step artifact
//!   generate    one-shot generation through the engine (dense|sparge)
//!   tune        per-layer (τ, θ, λ) grid search on a workload
//!   analyze     pattern/sparsity dumps (Fig. 2 / Fig. 4 / golden orders)
//!   selfcheck   end-to-end smoke: artifacts load, kernels agree

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use sparge::attention::types::AttnConfig;
use sparge::attention::AttnEngine;
use sparge::coordinator::{AttnMode, BatchPolicy, Coordinator, EngineHandle};
use sparge::runtime::{Manifest, Runtime, Value};
use sparge::sparge::SpargeParams;
use sparge::util::cli::Args;
use sparge::util::rng::Pcg;
use sparge::util::table::{fnum, pct, Table};
use sparge::workloads::{self, text};
use sparge::{log_info, tensor::Tensor};

fn main() {
    let args = Args::from_env();
    if args.flag("debug") {
        sparge::util::log::set_level(sparge::util::log::Level::Debug);
    }
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let result = match cmd {
        "serve" => cmd_serve(&args),
        "train" => cmd_train(&args),
        "generate" => cmd_generate(&args),
        "tune" => cmd_tune(&args),
        "analyze" => cmd_analyze(&args),
        "selfcheck" => cmd_selfcheck(&args),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "sparge — SpargeAttn (ICML 2025) reproduction\n\n\
         USAGE: sparge <command> [--options]\n\n\
         COMMANDS:\n  \
         serve      --addr 127.0.0.1:7071 --artifacts artifacts [--weights w.spg]\n  \
         train      --steps 200 --out artifacts/lm_trained.spg [--log-every 10]\n  \
         generate   --prompt 'text' --max-new 32 --mode sparge [--weights w.spg]\n  \
         tune       --model Mochi-proxy --scale 8 [--out tuned.json]\n  \
         analyze    --patterns | --qk | --hilbert-golden\n  \
         selfcheck  [--artifacts artifacts]\n"
    );
}

fn artifact_dir(args: &Args) -> PathBuf {
    args.get("artifacts").map(PathBuf::from).unwrap_or_else(Manifest::default_dir)
}

fn engine_with_weights(args: &Args) -> Result<EngineHandle> {
    let engine = EngineHandle::spawn(&artifact_dir(args))?;
    if let Some(w) = args.get("weights") {
        let t = workloads::trace::load(std::path::Path::new(w))?;
        let params = t.into_iter().next().context("weights file empty")?.into_vec();
        engine.load_params(params)?;
        log_info!("loaded weights from {w}");
    }
    Ok(engine)
}

// ----------------------------------------------------------------------

fn cmd_serve(args: &Args) -> Result<()> {
    let addr = args.get_or("addr", "127.0.0.1:7071");
    let engine = engine_with_weights(args)?;
    let coordinator = Arc::new(Coordinator::start(
        engine,
        BatchPolicy {
            max_batch: args.get_usize("max-batch", 8),
            max_wait: std::time::Duration::from_millis(args.get_usize("max-wait-ms", 20) as u64),
            capacity: args.get_usize("capacity", 1024),
            ..Default::default()
        },
    ));
    sparge::coordinator::server::serve(coordinator, addr)
}

fn cmd_train(args: &Args) -> Result<()> {
    use sparge::coordinator::engine::{TRAIN_B, TRAIN_T};
    let steps = args.get_usize("steps", 200);
    let log_every = args.get_usize("log-every", 10);
    let out = args.get_or("out", "artifacts/lm_trained.spg").to_string();
    let engine = engine_with_weights(args)?;

    let mut rng = Pcg::seeded(args.get_usize("seed", 42) as u64);
    let corpus = text::corpus_with_kv(1 << 20, &mut rng);
    log_info!("training byte-LM: {steps} steps of {TRAIN_B}x{TRAIN_T}");
    let t0 = std::time::Instant::now();
    let mut losses = Vec::new();
    for step in 0..steps {
        let mut batch = Vec::with_capacity(TRAIN_B * TRAIN_T);
        for _ in 0..TRAIN_B {
            let start = rng.range(0, corpus.len() - TRAIN_T - 1);
            batch.extend(corpus[start..start + TRAIN_T].iter().map(|&b| b as i32));
        }
        let loss = engine.train_step(batch)?;
        losses.push(loss);
        if step % log_every == 0 || step + 1 == steps {
            let dt = t0.elapsed().as_secs_f64();
            println!("step {step:4}  loss {loss:.4}  ppl {:.2}  ({dt:.1}s)", loss.exp());
        }
    }
    let params = engine.get_params()?;
    workloads::trace::save(std::path::Path::new(&out), &[Tensor::from_vec(&[params.len()], params)])?;
    println!("saved weights to {out}");
    println!("loss: {:.4} -> {:.4}", losses[0], losses[losses.len() - 1]);
    Ok(())
}

fn cmd_generate(args: &Args) -> Result<()> {
    let prompt = args.get_or("prompt", "the attention is ");
    let max_new = args.get_usize("max-new", 32);
    let mode = AttnMode::parse(args.get_or("mode", "sparge")).context("bad --mode")?;
    let engine = engine_with_weights(args)?;
    let t0 = std::time::Instant::now();
    let out = engine.generate(prompt.as_bytes(), max_new, mode)?;
    let dt = t0.elapsed().as_secs_f64();
    println!("{}{}", prompt, String::from_utf8_lossy(&out));
    let tps = out.len() as f64 / dt;
    println!("[{} tokens in {dt:.2}s, {tps:.1} tok/s, mode={}]", out.len(), mode.name());
    Ok(())
}

fn cmd_tune(args: &Args) -> Result<()> {
    use sparge::models::{suite, Workload};
    use sparge::sparge::tune::{tune_layer, CalibSample, TuneOptions};

    let scale = args.get_usize("scale", 8);
    let model_name = args.get_or("model", "Mochi-proxy");
    let cards = suite(scale);
    let card = cards.iter().find(|c| c.name == model_name).with_context(|| {
        format!("unknown model '{model_name}'; have: {:?}", cards.iter().map(|c| c.name).collect::<Vec<_>>())
    })?;

    let cfg = card.attn_config();
    let mut samples = Vec::new();
    for i in 0..args.get_usize("samples", 3) {
        let mut rng = Pcg::new(7, i as u64 + 1);
        let s = match card.workload {
            Workload::Lm(spec) => workloads::synthetic::generate(&spec, &mut rng),
            Workload::Grid(spec) => workloads::video::generate_grid(&spec, &mut rng),
        };
        samples.push(CalibSample { q: s.q, k: s.k, v: s.v });
    }
    let opts = TuneOptions { l1: card.l1, l2: card.l2, ..Default::default() };
    log_info!("tuning {model_name} (N={}, l1={}, l2={})", card.seq_len(), card.l1, card.l2);
    let res = tune_layer(&samples, &cfg, &opts);
    println!(
        "tuned {model_name}: tau={} theta={} lambda={:?}  sparsity={} L1={:.4} ({} grid points)",
        res.params.tau,
        res.params.theta,
        res.params.lambda,
        pct(res.sparsity),
        res.l1_error,
        res.evaluated
    );
    if let Some(out) = args.get("out") {
        let cfg_out =
            sparge::sparge::ModelSpargeConfig::uniform(model_name, card.layers, res.params, card.l1, card.l2);
        cfg_out.save(std::path::Path::new(out))?;
        println!("saved config to {out}");
    }
    Ok(())
}

fn cmd_analyze(args: &Args) -> Result<()> {
    if args.flag("hilbert-golden") {
        use sparge::sparge::hilbert::{token_order, Permutation};
        let order = token_order(Permutation::HilbertCurve, 2, 4, 4, 0);
        println!("{order:?}");
        return Ok(());
    }
    if args.flag("patterns") {
        return analyze_patterns(args);
    }
    if args.flag("qk") {
        return analyze_qk(args);
    }
    bail!("analyze needs one of --patterns | --qk | --hilbert-golden");
}

/// Fig. 2 reproduction: compressed attention-map patterns per proxy model.
fn analyze_patterns(args: &Args) -> Result<()> {
    use sparge::models::{suite, Workload};
    use sparge::sparge::predict::{predict, PredictParams};

    let scale = args.get_usize("scale", 16);
    for card in suite(scale) {
        let mut rng = Pcg::seeded(1);
        let s = match card.workload {
            Workload::Lm(spec) => workloads::synthetic::generate(&spec, &mut rng),
            Workload::Grid(spec) => workloads::video::generate_grid(&spec, &mut rng),
        };
        let cfg = card.attn_config();
        let pred = predict(&s.q, &s.k, &cfg, &PredictParams::default());
        println!("\n== {} (N={}) — compressed P-hat, '#'=high '.'=low ==", card.name, card.seq_len());
        let (tm, tn) = (pred.p_hat.dim(0), pred.p_hat.dim(1));
        let show = 32.min(tm);
        for i in 0..show {
            let row: String = (0..tn.min(64))
                .map(|j| {
                    let v = pred.p_hat.at2(i, j);
                    if v > 0.1 { '#' } else if v > 0.01 { '+' } else if v > 0.001 { ':' } else { '.' }
                })
                .collect();
            println!("{row}");
        }
    }
    Ok(())
}

/// Fig. 4 reproduction: Q/K block self-similarity per proxy model.
fn analyze_qk(args: &Args) -> Result<()> {
    use sparge::models::{suite, Workload};
    use sparge::sparge::metrics::avg_block_similarity;

    let scale = args.get_usize("scale", 16);
    let mut table = Table::new("Fig. 4 — average block self-similarity", &["model", "N", "Sim-q", "Sim-k"]);
    for card in suite(scale) {
        let mut rng = Pcg::seeded(1);
        let s = match card.workload {
            Workload::Lm(spec) => workloads::synthetic::generate(&spec, &mut rng),
            Workload::Grid(spec) => workloads::video::generate_grid(&spec, &mut rng),
        };
        let cfg = card.attn_config();
        table.row(&[
            card.name.to_string(),
            card.seq_len().to_string(),
            fnum(avg_block_similarity(&s.q, cfg.bq), 3),
            fnum(avg_block_similarity(&s.k, cfg.bk), 3),
        ]);
    }
    table.print();
    Ok(())
}

fn cmd_selfcheck(args: &Args) -> Result<()> {
    // 1. Rust engine invariant
    let mut rng = Pcg::seeded(3);
    let n = 256;
    let q = Tensor::randn(&[n, 64], &mut rng);
    let k = Tensor::randn(&[n, 64], &mut rng);
    let v = Tensor::randn(&[n, 64], &mut rng);
    let cfg = AttnConfig { bq: 64, bk: 64, causal: false, scale: None, cw: 4, row_offset: 0 };
    let params = SpargeParams { tau: 1.0, theta: -1.0, lambda: None, quant: false };
    let res = AttnEngine::sparge(cfg, &params).attention(&q, &k, &v);
    let dense = AttnEngine::dense(cfg).attention(&q, &k, &v).out;
    let err = sparge::sparge::metrics::rel_l1(&res.out, &dense);
    anyhow::ensure!(err < 1e-5, "engine selfcheck: rel-L1 {err}");
    println!("[1/3] rust engine: sparge(tau=1) == dense  (rel-L1 {err:.2e})");

    // 2. runtime loads + runs an artifact, matches the Rust engine
    let rt = Runtime::new(&artifact_dir(args))?;
    let name = "attn_dense_1024";
    let mut rng = Pcg::seeded(4);
    let (nq, d) = (1024, 64);
    let q = Tensor::randn(&[nq, d], &mut rng);
    let k = Tensor::randn(&[nq, d], &mut rng);
    let v = Tensor::randn(&[nq, d], &mut rng);
    let out = rt.run(name, &[Value::from_tensor(&q), Value::from_tensor(&k), Value::from_tensor(&v)])?;
    let hlo_out = out[0].to_tensor()?;
    let rust_out = sparge::attention::attention_naive(&q, &k, &v, &AttnConfig::default());
    let err = sparge::sparge::metrics::rel_l1(&hlo_out, &rust_out);
    anyhow::ensure!(err < 1e-4, "artifact-vs-engine rel-L1 {err}");
    println!("[2/3] runtime: {name} matches rust engine (rel-L1 {err:.2e})");

    // 3. sparge artifact runs and reports plausible density
    let inputs = [Value::from_tensor(&q), Value::from_tensor(&k), Value::from_tensor(&v)];
    let out = rt.run("attn_sparge_1024", &inputs)?;
    let density = out[1].scalar()?;
    let err = sparge::sparge::metrics::rel_l1(&out[0].to_tensor()?, &rust_out);
    anyhow::ensure!((0.0..=1.0).contains(&density), "bad density {density}");
    anyhow::ensure!(err < 0.15, "sparge artifact rel-L1 {err}");
    println!("[3/3] runtime: attn_sparge_1024 ok (mask density {density:.2}, rel-L1 {err:.3})");
    println!("selfcheck OK");
    Ok(())
}
