//! Counting global allocator for allocation-regression tests and
//! allocations-per-token bench columns.
//!
//! [`CountingAlloc`] wraps the system allocator and counts every
//! `alloc`/`realloc` twice: into a process-global counter and into a
//! per-thread counter. The hot-path contract this instruments: a
//! warmed-up λ-off f32 decode step performs **zero** heap allocations
//! (worker/session [`crate::util::threadpool::Workspace`] arenas and the
//! session's cached span plan absorb all scratch).
//!
//! Usage — a binary (test or bench) opts in at its root:
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: sparge::util::alloc::CountingAlloc = sparge::util::alloc::CountingAlloc;
//! ```
//!
//! then brackets a region with [`thread_allocations`] (immune to
//! allocations from other threads — the right probe for `Exec::Inline`
//! hot paths) or [`global_allocations`] (covers pool workers too; other
//! live threads can inject noise, so assert on the minimum over a few
//! rounds or keep the process quiet).

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};

static GLOBAL_ALLOCS: AtomicU64 = AtomicU64::new(0);

thread_local! {
    static THREAD_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

/// System allocator with global + per-thread allocation counting.
pub struct CountingAlloc;

impl CountingAlloc {
    #[inline]
    fn count() {
        GLOBAL_ALLOCS.fetch_add(1, Ordering::Relaxed);
        // try_with: TLS may be unavailable during thread teardown; those
        // allocations still land in the global counter.
        let _ = THREAD_ALLOCS.try_with(|c| c.set(c.get() + 1));
    }
}

// SAFETY: every method delegates verbatim to `System` after bumping the
// counters; layout/pointer obligations pass through unchanged, and the
// counter bumps (Relaxed atomic + TLS cell) never allocate or unwind.
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: the caller upholds `GlobalAlloc::alloc`'s contract for `layout`.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        CountingAlloc::count();
        // SAFETY: same `layout` the caller passed us.
        unsafe { System.alloc(layout) }
    }

    // SAFETY: the caller upholds `GlobalAlloc::alloc_zeroed`'s contract.
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        CountingAlloc::count();
        // SAFETY: same `layout` the caller passed us.
        unsafe { System.alloc_zeroed(layout) }
    }

    // SAFETY: the caller upholds `GlobalAlloc::realloc`'s contract for
    // `ptr`/`layout`/`new_size`.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        CountingAlloc::count();
        // SAFETY: same arguments the caller passed us; `ptr` came from
        // this allocator, which is `System` underneath.
        unsafe { System.realloc(ptr, layout, new_size) }
    }

    // SAFETY: the caller upholds `GlobalAlloc::dealloc`'s contract.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        // SAFETY: `ptr` was allocated by `System` (every alloc path above
        // delegates there) with this `layout`.
        unsafe { System.dealloc(ptr, layout) }
    }
}

/// Allocations (alloc + alloc_zeroed + realloc) since process start,
/// across all threads. 0 when [`CountingAlloc`] is not installed.
pub fn global_allocations() -> u64 {
    GLOBAL_ALLOCS.load(Ordering::Relaxed)
}

/// Allocations performed by the *calling thread* since it started. 0 when
/// [`CountingAlloc`] is not installed.
pub fn thread_allocations() -> u64 {
    THREAD_ALLOCS.with(|c| c.get())
}
