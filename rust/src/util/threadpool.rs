//! Fixed-size thread pool + scoped parallel-for (tokio/rayon substitute).
//!
//! Three executors live here:
//! - [`ThreadPool`]: fire-and-forget `'static` jobs (the coordinator's
//!   connection handling);
//! - [`parallel_map`] / [`parallel_for`]: scoped data-parallel loops that
//!   spawn threads per call (`std::thread::scope`);
//! - [`WorkerPool`]: a *persistent* pool for scoped data-parallel jobs —
//!   workers are spawned once (e.g. by an `AttnEngine` at build time) and
//!   reused across calls, so the hot decode/prefill path pays no per-call
//!   thread-spawn cost.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed-size pool of worker threads consuming a shared job queue.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    pending: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Spawn `n` workers (n >= 1).
    pub fn new(n: usize) -> ThreadPool {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new(AtomicUsize::new(0));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let pending = Arc::clone(&pending);
                thread::Builder::new()
                    .name(format!("sparge-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => {
                                job();
                                pending.fetch_sub(1, Ordering::SeqCst);
                            }
                            Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, pending }
    }

    /// Pool sized to the machine (cores, capped at 16).
    pub fn default_size() -> ThreadPool {
        let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16);
        ThreadPool::new(n)
    }

    /// Submit a job.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        self.pending.fetch_add(1, Ordering::SeqCst);
        self.tx.as_ref().expect("pool shut down").send(Box::new(job)).expect("send job");
    }

    /// Number of jobs submitted but not yet finished.
    pub fn pending(&self) -> usize {
        self.pending.load(Ordering::SeqCst)
    }

    /// Busy-wait (with yield) until all submitted jobs finish.
    pub fn wait_idle(&self) {
        while self.pending() > 0 {
            thread::yield_now();
        }
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// A persistent pool of workers for *scoped* data-parallel jobs.
///
/// Unlike [`ThreadPool`], jobs may borrow from the caller's stack: the
/// submitting call blocks until every index has been processed, so the
/// borrow outlives all worker accesses. Unlike [`parallel_map`], workers
/// are spawned once and reused — an attention engine creates the pool at
/// build time and every subsequent prefill/decode call is spawn-free.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    workers: Vec<thread::JoinHandle<()>>,
}

struct PoolShared {
    state: Mutex<PoolState>,
    /// Workers wait here for a new job (or shutdown).
    work: Condvar,
    /// Submitters wait here for job completion (and for the job slot).
    done: Condvar,
}

#[derive(Default)]
struct PoolState {
    /// Epoch of the most recently installed job.
    epoch: u64,
    /// Most recent fully-completed epoch.
    completed: u64,
    job: Option<JobPtr>,
    /// Next index to claim for the current job.
    next: usize,
    /// Indices finished for the current job.
    finished: usize,
    /// An index of the *current* job panicked; latched into
    /// `panicked_epochs` when the job completes.
    panicked: bool,
    /// Epochs of completed jobs that had a panicking index, each awaiting
    /// pickup by its own submitter. A *set* keyed by epoch — not a plain
    /// flag — so that with concurrent submitters neither a queued
    /// submitter installing the next job nor a second panicking job
    /// completing first can erase a panic before the panicked job's own
    /// submitter observes (and removes) its entry. Bounded by the number
    /// of in-flight submitters: every installed epoch is awaited by
    /// exactly one `run`, which consumes its entry. This propagates
    /// worker panics like `std::thread::scope`'s join would, instead of
    /// deadlocking the pool.
    panicked_epochs: Vec<u64>,
    shutdown: bool,
}

/// Lifetime-erased pointer to the submitter's closure. Sound because
/// [`WorkerPool::run`] does not return until `finished == n`, after which
/// no worker can dereference the pointer again (index claims fail once
/// `next >= n`, and a new job can only be installed by a new `run`).
#[derive(Clone, Copy)]
struct JobPtr {
    f: *const (dyn Fn(usize) + Sync),
    n: usize,
}

unsafe impl Send for JobPtr {}

impl WorkerPool {
    /// Spawn a pool of `n` persistent workers behind an `Arc`, for
    /// sharing across engine compositions: several `AttnEngine`s (dense +
    /// sparge, serving + probes) can time-share one set of workers via
    /// `AttnEngineBuilder::shared_pool` instead of each spawning their
    /// own. Concurrent submitters serialize on the single job slot (see
    /// [`WorkerPool::run`]), so sharing is safe — just queued.
    pub fn shared(n: usize) -> Arc<WorkerPool> {
        Arc::new(WorkerPool::new(n))
    }

    /// Spawn a pool of `n` persistent workers (n >= 1).
    pub fn new(n: usize) -> WorkerPool {
        let n = n.max(1);
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState::default()),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let workers = (0..n)
            .map(|i| {
                let shared = Arc::clone(&shared);
                thread::Builder::new()
                    .name(format!("sparge-pool-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool { shared, workers }
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Run `f(0..n)` across the pool, blocking until every index has been
    /// processed. Concurrent `run` calls from other threads serialize:
    /// later jobs wait for the slot. Which worker runs which index is
    /// nondeterministic; callers that need determinism collect per-index
    /// results (see [`WorkerPool::map`]).
    pub fn run(&self, n: usize, f: &(dyn Fn(usize) + Sync)) {
        if n == 0 {
            return;
        }
        // Erase the borrow lifetime; `run` does not return until all
        // workers are done with the pointer (see [`JobPtr`]).
        let ptr: *const (dyn Fn(usize) + Sync + '_) = f;
        #[allow(clippy::missing_transmute_annotations)]
        let job = JobPtr { f: unsafe { std::mem::transmute(ptr) }, n };
        let mut st = self.shared.state.lock().unwrap();
        while st.job.is_some() {
            st = self.shared.done.wait(st).unwrap();
        }
        st.epoch += 1;
        let epoch = st.epoch;
        st.job = Some(job);
        st.next = 0;
        st.finished = 0;
        st.panicked = false;
        self.shared.work.notify_all();
        while st.completed < epoch {
            st = self.shared.done.wait(st).unwrap();
        }
        // per-epoch latch: immune to a queued submitter having already
        // installed the *next* job — or a later job having also panicked
        // — by the time this submitter wakes
        let panicked = match st.panicked_epochs.iter().position(|&e| e == epoch) {
            Some(pos) => {
                st.panicked_epochs.swap_remove(pos);
                true
            }
            None => false,
        };
        drop(st);
        assert!(!panicked, "WorkerPool job panicked on a worker thread");
    }

    /// Deterministic scoped map over the pool: results are collected per
    /// index, so the output (and any caller-side merge in index order) is
    /// identical for every pool size. `n <= 1` runs inline on the caller —
    /// the decode-shaped fast path never crosses a thread.
    pub fn map<T: Send>(&self, n: usize, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
        if n == 0 {
            return Vec::new();
        }
        if n == 1 {
            return vec![f(0)];
        }
        let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
        let fill = |i: usize| {
            *slots[i].lock().unwrap() = Some(f(i));
        };
        self.run(n, &fill);
        slots.into_iter().map(|s| s.into_inner().unwrap().expect("pool filled slot")).collect()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shared.state.lock().unwrap().shutdown = true;
        self.shared.work.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: &PoolShared) {
    loop {
        // Claim an index (or sleep until there is work).
        let (job, i) = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if let Some(job) = st.job {
                    if st.next < job.n {
                        let i = st.next;
                        st.next += 1;
                        break (job, i);
                    }
                }
                st = shared.work.wait(st).unwrap();
            }
        };
        // Run outside the lock; catch panics so a failing job reports to
        // the submitter instead of wedging `finished` below `n` forever.
        let func = unsafe { &*job.f };
        let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| func(i))).is_ok();
        let mut st = shared.state.lock().unwrap();
        if !ok {
            st.panicked = true;
        }
        st.finished += 1;
        if st.finished == job.n {
            if st.panicked {
                st.panicked_epochs.push(st.epoch);
                st.panicked = false;
            }
            st.completed = st.epoch;
            st.job = None;
            shared.done.notify_all();
        }
    }
}

/// Scoped data-parallel map: runs `f(i)` for i in 0..n across up to
/// `threads` OS threads and returns results in index order. Uses
/// `std::thread::scope`, so `f` may borrow from the caller.
pub fn parallel_map<T: Send, F: Fn(usize) -> T + Sync>(n: usize, threads: usize, f: F) -> Vec<T> {
    let threads = threads.clamp(1, n.max(1));
    if n == 0 {
        return Vec::new();
    }
    if threads == 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let slots = Mutex::new(&mut out);
    thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::SeqCst);
                if i >= n {
                    break;
                }
                let v = f(i);
                let mut guard = slots.lock().unwrap();
                guard[i] = Some(v);
            });
        }
    });
    out.into_iter().map(|v| v.expect("worker filled slot")).collect()
}

/// Scoped parallel-for without result collection.
pub fn parallel_for<F: Fn(usize) + Sync>(n: usize, threads: usize, f: F) {
    let threads = threads.clamp(1, n.max(1));
    if n == 0 {
        return;
    }
    if threads == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::SeqCst);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Default worker count for compute kernels.
pub fn default_threads() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn pool_drop_joins() {
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = ThreadPool::new(2);
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.submit(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
        } // drop waits for all workers
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn parallel_map_ordered() {
        let out = parallel_map(100, 8, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_empty_and_single() {
        assert!(parallel_map(0, 4, |i| i).is_empty());
        assert_eq!(parallel_map(1, 4, |i| i + 1), vec![1]);
    }

    #[test]
    fn parallel_for_covers_all() {
        let hits: Vec<AtomicUsize> = (0..64).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(64, 8, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn parallel_map_borrows_environment() {
        let data: Vec<f64> = (0..32).map(|i| i as f64).collect();
        let out = parallel_map(32, 4, |i| data[i] * 2.0);
        assert_eq!(out[31], 62.0);
    }

    #[test]
    fn worker_pool_map_ordered_and_borrowing() {
        let pool = WorkerPool::new(4);
        let data: Vec<u64> = (0..100).collect();
        let out = pool.map(100, |i| data[i] * data[i]);
        assert_eq!(out, (0..100u64).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn worker_pool_reusable_across_jobs() {
        let pool = WorkerPool::new(3);
        for round in 0..20u64 {
            let out = pool.map(17, |i| i as u64 + round);
            assert_eq!(out, (0..17u64).map(|i| i + round).collect::<Vec<_>>());
        }
    }

    #[test]
    fn worker_pool_size_invariant_results() {
        let data: Vec<u64> = (0..64).collect();
        let mut outs = Vec::new();
        for size in [1, 2, 8] {
            let pool = WorkerPool::new(size);
            assert_eq!(pool.size(), size);
            outs.push(pool.map(64, |i| data[i] * 3));
        }
        assert_eq!(outs[0], outs[1]);
        assert_eq!(outs[1], outs[2]);
    }

    #[test]
    fn worker_pool_empty_single_and_drop() {
        let pool = WorkerPool::new(2);
        assert!(pool.map(0, |i| i).is_empty());
        assert_eq!(pool.map(1, |i| i + 1), vec![1]);
        drop(pool); // must join cleanly
    }

    #[test]
    fn worker_pool_propagates_job_panics_and_stays_usable() {
        let pool = WorkerPool::new(2);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(4, &|i| {
                if i == 2 {
                    panic!("boom");
                }
            });
        }));
        assert!(result.is_err(), "worker panic must propagate to the submitter");
        // the job slot was released; the pool keeps working
        assert_eq!(pool.map(3, |i| i), vec![0, 1, 2]);
    }

    #[test]
    fn worker_pool_panic_lands_on_the_panicking_submitter_only() {
        // The per-epoch panic latch: with submitters interleaving on one
        // shared pool (the serving + probe composition), a panic in one
        // submitter's job must surface on *that* submitter every time,
        // and never on the innocent one. Two panickers make consecutive
        // panicking epochs likely — a single last-panic slot would lose
        // the earlier one; the clean submitter catches misattribution.
        let pool = Arc::new(WorkerPool::new(2));
        let rounds = 25;
        thread::scope(|scope| {
            let panickers: Vec<_> = (0..2)
                .map(|_| {
                    let p = Arc::clone(&pool);
                    scope.spawn(move || {
                        let mut caught = 0;
                        for _ in 0..rounds {
                            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                                p.run(3, &|i| {
                                    if i == 1 {
                                        panic!("boom");
                                    }
                                });
                            }));
                            if r.is_err() {
                                caught += 1;
                            }
                        }
                        caught
                    })
                })
                .collect();
            let p = Arc::clone(&pool);
            let clean = scope.spawn(move || {
                for round in 0..rounds as u64 {
                    let out = p.map(5, |i| i as u64 + round);
                    assert_eq!(out, (0..5u64).map(|i| i + round).collect::<Vec<_>>());
                }
            });
            for h in panickers {
                assert_eq!(h.join().unwrap(), rounds, "every panicking job must report");
            }
            clean.join().expect("clean submitter must never see a foreign panic");
        });
    }

    #[test]
    fn worker_pool_concurrent_submitters_serialize() {
        let pool = Arc::new(WorkerPool::new(4));
        let hits = Arc::new(AtomicU64::new(0));
        thread::scope(|scope| {
            for _ in 0..4 {
                let pool = Arc::clone(&pool);
                let hits = Arc::clone(&hits);
                scope.spawn(move || {
                    for _ in 0..8 {
                        pool.run(10, &|_i| {
                            hits.fetch_add(1, Ordering::SeqCst);
                        });
                    }
                });
            }
        });
        assert_eq!(hits.load(Ordering::SeqCst), 4 * 8 * 10);
    }
}
