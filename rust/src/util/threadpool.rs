//! Fixed-size thread pool + scoped parallel-for (tokio/rayon substitute),
//! plus the per-worker [`Workspace`] scratch arena the attention hot path
//! runs in.
//!
//! Three executors live here:
//! - [`ThreadPool`]: fire-and-forget `'static` jobs (the coordinator's
//!   connection handling);
//! - [`parallel_map`] / [`parallel_for`]: scoped data-parallel loops that
//!   spawn threads per call (`std::thread::scope`);
//! - [`WorkerPool`]: a *persistent* pool for scoped data-parallel jobs —
//!   workers are spawned once (e.g. by an `AttnEngine` at build time) and
//!   reused, so the hot decode/prefill path pays no per-call
//!   thread-spawn cost.
//!
//! ## Workspaces
//!
//! Every [`WorkerPool`] worker owns one [`Workspace`] for its whole
//! lifetime and passes it to each job index it runs, so scratch buffers
//! (attention tile state, score blocks, quantization staging) are
//! allocated once per worker, grow to their high-water mark, and are then
//! reused forever — a warmed-up decode step allocates nothing. Callers
//! that run work inline supply their own workspace (a session owns one);
//! the `*_ws` entry points thread it through, and the legacy entry points
//! wrap them with a throwaway workspace.
//!
//! ## Scheduling
//!
//! [`WorkerPool::run_ws`] distributes indices by **chunked
//! self-scheduling**: idle workers (and the submitting thread itself,
//! which joins as an extra worker instead of blocking) repeatedly claim
//! the next chunk of indices under the pool lock, with the chunk sized to
//! the remaining work (guided self-scheduling) so the tail of a job is
//! handed out in single indices and one slow item cannot strand a batch
//! behind a static partition. Which thread runs which index is
//! nondeterministic; *results are not* — callers collect per-index
//! results and merge in index order, so outputs are identical for every
//! pool size (scheduling order may vary, merge order may not).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

/// Sync primitives behind the loom seam: under `--cfg loom` the
/// [`WorkerPool`]'s lock, condvars, and worker threads come from loom so
/// the chunked-claim and panic-latch protocols can be model-checked
/// (`RUSTFLAGS="--cfg loom" cargo test --release --test loom_pool`);
/// normal builds re-export std. `Arc` stays `std::sync::Arc` in both
/// builds — refcounting is not part of the protocols under test, and
/// engine handles hold `std::sync::Arc<WorkerPool>`. [`ThreadPool`] and
/// the scoped helpers keep plain std primitives: they are not modeled.
#[cfg(loom)]
pub(crate) mod sync {
    pub(crate) use loom::sync::{Condvar, Mutex};
    pub(crate) use loom::thread;
}
#[cfg(not(loom))]
pub(crate) mod sync {
    pub(crate) use std::sync::{Condvar, Mutex};
    pub(crate) use std::thread;
}

/// Per-thread scratch arena for the attention hot path: reusable buffers
/// that grow to their high-water mark and are never shrunk, so a
/// warmed-up hot loop performs zero heap allocations.
///
/// Ownership discipline: one `Workspace` per thread of execution — each
/// [`WorkerPool`] worker owns one for its lifetime, each `AttnSession`
/// owns one for inline work, and scoped-thread helpers create one per
/// spawned thread. Buffers carry no semantic state between uses: every
/// consumer truncates/overwrites the region it reads (bitwise-neutral
/// reuse — the same float evaluation order as freshly-zeroed buffers).
#[derive(Default)]
pub struct Workspace {
    /// FlashTile running row maxima `m` (tile rows).
    pub tile_m: Vec<f32>,
    /// FlashTile partition sums `l` (tile rows).
    pub tile_l: Vec<f32>,
    /// FlashTile per-block local maxima scratch (tile rows).
    pub tile_m_local: Vec<f32>,
    /// FlashTile unnormalized output `O` (tile rows × d).
    pub tile_o: Vec<f32>,
    /// FlashTile P̃ scratch (tile rows × b_k).
    pub tile_p: Vec<f32>,
    /// Score-block staging (tile rows × b_k).
    pub scores: Vec<f32>,
    /// Quantization staging: smoothed f32 rows before requantization
    /// (the session's tail-block requantize path).
    pub quant_f32: Vec<f32>,
    /// Quantization staging: i32 QKᵀ accumulator for the INT8 score path
    /// (threaded to kernels as `ScoreScratch`).
    pub quant_i32: Vec<i32>,
    /// Predicted-decode staging: pooled K block means (n_kblocks × d).
    pub pred_means: Vec<f32>,
    /// Predicted-decode staging: compressed scores Ŝ (n_kblocks).
    pub pred_scores: Vec<f32>,
    /// Predicted-decode staging: compressed probabilities P̂ (n_kblocks).
    pub pred_probs: Vec<f32>,
    /// Predicted-decode staging: TopCdf sort-order indices (n_kblocks).
    pub pred_idx: Vec<usize>,
}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed-size pool of worker threads consuming a shared job queue.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    pending: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Spawn `n` workers (n >= 1).
    pub fn new(n: usize) -> ThreadPool {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new(AtomicUsize::new(0));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let pending = Arc::clone(&pending);
                thread::Builder::new()
                    .name(format!("sparge-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => {
                                job();
                                pending.fetch_sub(1, Ordering::SeqCst);
                            }
                            Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, pending }
    }

    /// Pool sized to the machine (cores, capped at 16).
    pub fn default_size() -> ThreadPool {
        let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16);
        ThreadPool::new(n)
    }

    /// Submit a job.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        self.pending.fetch_add(1, Ordering::SeqCst);
        self.tx.as_ref().expect("pool shut down").send(Box::new(job)).expect("send job");
    }

    /// Number of jobs submitted but not yet finished.
    pub fn pending(&self) -> usize {
        self.pending.load(Ordering::SeqCst)
    }

    /// Busy-wait (with yield) until all submitted jobs finish.
    pub fn wait_idle(&self) {
        while self.pending() > 0 {
            thread::yield_now();
        }
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// A persistent pool of workers for *scoped* data-parallel jobs.
///
/// Unlike [`ThreadPool`], jobs may borrow from the caller's stack: the
/// submitting call blocks until every index has been processed, so the
/// borrow outlives all worker accesses. Unlike [`parallel_map`], workers
/// are spawned once and reused — an attention engine creates the pool at
/// build time and every subsequent prefill/decode call is spawn-free —
/// and each worker carries a persistent [`Workspace`], so hot-path calls
/// are allocation-free too once the buffers reach their high-water mark.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    workers: Vec<sync::thread::JoinHandle<()>>,
}

struct PoolShared {
    state: sync::Mutex<PoolState>,
    /// Worker count (for chunk sizing; never affects results).
    size: usize,
    /// Workers wait here for a new job (or shutdown).
    work: sync::Condvar,
    /// Submitters wait here for job completion (and for the job slot).
    done: sync::Condvar,
}

#[derive(Default)]
struct PoolState {
    /// Epoch of the most recently installed job.
    epoch: u64,
    /// Most recent fully-completed epoch.
    completed: u64,
    job: Option<JobPtr>,
    /// Next index to claim for the current job.
    next: usize,
    /// Indices finished for the current job.
    finished: usize,
    /// Indices of the *current* job that panicked; latched into
    /// `panicked_epochs` when the job completes. Empty on the clean path
    /// (an empty `Vec` never allocates), so the zero-alloc decode
    /// contract holds with no faults in flight.
    panicked_idx: Vec<usize>,
    /// Completed jobs that had panicking indices — `(epoch, indices)` —
    /// each awaiting pickup by its own submitter. A *set* keyed by epoch
    /// — not a plain flag — so that with concurrent submitters neither a
    /// queued submitter installing the next job nor a second panicking
    /// job completing first can erase a panic before the panicked job's
    /// own submitter observes (and removes) its entry. Bounded by the
    /// number of in-flight submitters: every installed epoch is awaited
    /// by exactly one `run`, which consumes its entry. Carrying the
    /// *indices* (not just the fact of a panic) lets a fault-owning
    /// submitter quarantine exactly the failed sessions instead of
    /// re-raising; `run_ws` still re-raises for callers without a fault
    /// domain. This propagates worker panics like `std::thread::scope`'s
    /// join would, instead of deadlocking the pool.
    panicked_epochs: Vec<(u64, Vec<usize>)>,
    shutdown: bool,
}

/// Lifetime-erased pointer to the submitter's closure. Sound because
/// [`WorkerPool::run_ws`] does not return until `finished == n`, after
/// which no worker can dereference the pointer again (chunk claims fail
/// once `next >= n`, claims happen under the state lock together with the
/// job lookup, and a new job can only be installed by a new submitter
/// after the slot is cleared).
#[derive(Clone, Copy)]
struct JobPtr {
    f: *const (dyn Fn(usize, &mut Workspace) + Sync),
    n: usize,
}

// SAFETY: the raw closure pointer crosses to pool workers, but every
// dereference happens between job installation and `finished == n` —
// a window during which the submitting `run_ws` frame (which owns the
// borrow behind the pointer) is still blocked. See [`JobPtr`].
unsafe impl Send for JobPtr {}

/// Chunk size for guided self-scheduling: proportional to the work left
/// per participant, so early claims are large (few lock round-trips) and
/// the tail is handed out in single indices (no straggler holds more than
/// one item's worth of unstarted work). Purely a scheduling choice —
/// results are collected per index, so outputs never depend on it.
fn claim_chunk(remaining: usize, participants: usize) -> usize {
    (remaining / (2 * participants.max(1))).clamp(1, 64)
}

impl WorkerPool {
    /// Spawn a pool of `n` persistent workers behind an `Arc`, for
    /// sharing across engine compositions: several `AttnEngine`s (dense +
    /// sparge, serving + probes) can time-share one set of workers via
    /// `AttnEngineBuilder::shared_pool` instead of each spawning their
    /// own. Concurrent submitters serialize on the single job slot (see
    /// [`WorkerPool::run_ws`]), so sharing is safe — just queued.
    pub fn shared(n: usize) -> Arc<WorkerPool> {
        Arc::new(WorkerPool::new(n))
    }

    /// Spawn a pool of `n` persistent workers (n >= 1).
    pub fn new(n: usize) -> WorkerPool {
        let n = n.max(1);
        let shared = Arc::new(PoolShared {
            state: sync::Mutex::new(PoolState::default()),
            size: n,
            work: sync::Condvar::new(),
            done: sync::Condvar::new(),
        });
        let workers = (0..n).map(|i| spawn_worker(i, Arc::clone(&shared))).collect();
        WorkerPool { shared, workers }
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Run `f(0..n)` across the pool, blocking until every index has been
    /// processed. See [`WorkerPool::run_ws`]; the closure gets a
    /// throwaway workspace reference it can ignore.
    pub fn run(&self, n: usize, f: &(dyn Fn(usize) + Sync)) {
        let mut ws = Workspace::default();
        self.run_ws(n, &mut ws, &|i, _ws| f(i));
    }

    /// Run `f(0..n)` across the pool, blocking until every index has been
    /// processed. Each pool worker passes its own persistent
    /// [`Workspace`]; the submitting thread **participates** — it claims
    /// chunks alongside the workers using `ws` instead of sleeping — so a
    /// job is never slower than running it inline, and one slow index
    /// cannot straggle behind an idle submitter. Concurrent `run_ws`
    /// calls from other threads serialize: later jobs wait for the slot.
    /// Which thread runs which index is nondeterministic; callers that
    /// need determinism collect per-index results (see
    /// [`WorkerPool::map_ws`]). `n <= 1` runs inline on the caller.
    pub fn run_ws(&self, n: usize, ws: &mut Workspace, f: &(dyn Fn(usize, &mut Workspace) + Sync)) {
        if n == 0 {
            return;
        }
        if n == 1 {
            // decode-shaped fast path: no locking, caller workspace
            f(0, ws);
            return;
        }
        let panicked = self.run_ws_protocol(n, ws, f);
        assert!(panicked.is_empty(), "WorkerPool job panicked on a worker thread");
    }

    /// [`WorkerPool::run_ws`] for callers that own a fault domain: worker
    /// panics are *attributed*, not re-raised. Returns the sorted indices
    /// whose closure invocation panicked (empty on a clean run — and an
    /// empty `Vec` never allocates, so the fault-free path stays
    /// zero-alloc). Every index is still visited exactly once; a panic at
    /// index `i` never prevents other indices from running, and the
    /// per-epoch latch guarantees the indices land on *this* submitter
    /// even with concurrent submitters interleaving on the shared pool
    /// (see `PoolState::panicked_epochs`).
    pub fn run_ws_caught(
        &self,
        n: usize,
        ws: &mut Workspace,
        f: &(dyn Fn(usize, &mut Workspace) + Sync),
    ) -> Vec<usize> {
        if n == 0 {
            // sparge-lint: allow(hot-path-no-alloc) — empty, never allocates
            return Vec::new();
        }
        if n == 1 {
            // decode-shaped fast path: no locking, caller workspace
            return match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(0, ws))) {
                // sparge-lint: allow(hot-path-no-alloc) — empty, never allocates
                Ok(()) => Vec::new(),
                // sparge-lint: allow(hot-path-no-alloc) — fault path only
                Err(_) => vec![0],
            };
        }
        self.run_ws_protocol(n, ws, f)
    }

    /// The shared submit/participate/await protocol behind [`run_ws`]
    /// (which re-raises on any panicked index) and [`run_ws_caught`]
    /// (which returns them). `n >= 2`.
    fn run_ws_protocol(
        &self,
        n: usize,
        ws: &mut Workspace,
        f: &(dyn Fn(usize, &mut Workspace) + Sync),
    ) -> Vec<usize> {
        // Erase the borrow lifetime; this frame does not return until all
        // workers are done with the pointer (see [`JobPtr`]).
        let ptr: *const (dyn Fn(usize, &mut Workspace) + Sync + '_) = f;
        // SAFETY: the transmute only erases the borrow lifetime. Workers
        // can dereference the pointer only while the job is installed,
        // and this frame does not return before `finished == n`, so the
        // borrow outlives every dereference (see [`JobPtr`]).
        #[allow(clippy::missing_transmute_annotations)]
        let job = JobPtr { f: unsafe { std::mem::transmute(ptr) }, n };
        let mut st = self.shared.state.lock().unwrap();
        while st.job.is_some() {
            st = self.shared.done.wait(st).unwrap();
        }
        st.epoch += 1;
        let epoch = st.epoch;
        st.job = Some(job);
        st.next = 0;
        st.finished = 0;
        st.panicked_idx.clear();
        self.shared.work.notify_all();
        // Participate: claim chunks like a worker until the job's indices
        // are exhausted (or the job completed under our feet).
        loop {
            if st.completed >= epoch || st.epoch != epoch || st.job.is_none() || st.next >= n {
                break;
            }
            let i0 = st.next;
            let i1 = (i0 + claim_chunk(n - i0, self.shared.size + 1)).min(n);
            st.next = i1;
            drop(st);
            let mut bad = Vec::new();
            for i in i0..i1 {
                if std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(i, ws))).is_err() {
                    bad.push(i);
                }
            }
            st = self.shared.state.lock().unwrap();
            st.panicked_idx.extend_from_slice(&bad);
            st.finished += i1 - i0;
            if st.finished == n {
                if !st.panicked_idx.is_empty() {
                    let idx = std::mem::take(&mut st.panicked_idx);
                    st.panicked_epochs.push((epoch, idx));
                }
                st.completed = epoch;
                st.job = None;
                self.shared.done.notify_all();
            }
        }
        while st.completed < epoch {
            st = self.shared.done.wait(st).unwrap();
        }
        // per-epoch latch: immune to a queued submitter having already
        // installed the *next* job — or a later job having also panicked
        // — by the time this submitter wakes
        let mut panicked = match st.panicked_epochs.iter().position(|(e, _)| *e == epoch) {
            Some(pos) => st.panicked_epochs.swap_remove(pos).1,
            None => Vec::new(),
        };
        drop(st);
        // scheduling decides recording order; the caller-visible order
        // must not depend on it
        panicked.sort_unstable();
        panicked
    }

    /// Deterministic scoped map over the pool: results are collected per
    /// index, so the output (and any caller-side merge in index order) is
    /// identical for every pool size and scheduling order. The closure
    /// gets a throwaway workspace reference it can ignore.
    pub fn map<T: Send>(&self, n: usize, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
        let mut ws = Workspace::default();
        self.map_ws(n, &mut ws, |i, _ws| f(i))
    }

    /// [`WorkerPool::map`] with workspace plumbing: pool workers pass
    /// their persistent [`Workspace`], the participating submitter passes
    /// `ws`. `n <= 1` runs inline on the caller — the decode-shaped fast
    /// path never crosses a thread.
    pub fn map_ws<T: Send>(
        &self,
        n: usize,
        ws: &mut Workspace,
        f: impl Fn(usize, &mut Workspace) -> T + Sync,
    ) -> Vec<T> {
        if n == 0 {
            return Vec::new();
        }
        if n == 1 {
            return vec![f(0, ws)];
        }
        let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
        let fill = |i: usize, ws: &mut Workspace| {
            *slots[i].lock().unwrap() = Some(f(i, ws));
        };
        self.run_ws(n, ws, &fill);
        slots.into_iter().map(|s| s.into_inner().unwrap().expect("pool filled slot")).collect()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shared.state.lock().unwrap().shutdown = true;
        self.shared.work.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Spawn one pool worker. Normal builds use a named `std::thread`;
/// under `--cfg loom` workers are plain loom threads (no Builder there).
#[cfg(not(loom))]
fn spawn_worker(i: usize, shared: Arc<PoolShared>) -> sync::thread::JoinHandle<()> {
    sync::thread::Builder::new()
        .name(format!("sparge-pool-{i}"))
        .spawn(move || worker_loop(&shared))
        .expect("spawn pool worker")
}

#[cfg(loom)]
fn spawn_worker(_i: usize, shared: Arc<PoolShared>) -> sync::thread::JoinHandle<()> {
    sync::thread::spawn(move || worker_loop(&shared))
}

fn worker_loop(shared: &PoolShared) {
    // The worker's scratch arena, alive for the pool's lifetime: sized by
    // the largest job it has run, then reused allocation-free.
    let mut ws = Workspace::default();
    let mut st = shared.state.lock().unwrap();
    loop {
        if st.shutdown {
            return;
        }
        // Claim a chunk of indices (or sleep until there is work). The
        // claim happens under the same lock as the job lookup, so a claim
        // can never land on a later job's index range.
        let (job, i0, i1) = match st.job {
            Some(job) if st.next < job.n => {
                let i0 = st.next;
                let i1 = (i0 + claim_chunk(job.n - i0, shared.size + 1)).min(job.n);
                st.next = i1;
                (job, i0, i1)
            }
            _ => {
                st = shared.work.wait(st).unwrap();
                continue;
            }
        };
        drop(st);
        // Run outside the lock; catch panics so a failing index reports
        // to the submitter instead of wedging `finished` below `n`.
        // SAFETY: the chunk claim above happened under the state lock
        // against the installed job, whose submitter is still blocked in
        // `run_ws` (it cannot return before `finished == n`), so the
        // closure behind `job.f` is alive for this whole chunk.
        let func = unsafe { &*job.f };
        let mut bad = Vec::new();
        for i in i0..i1 {
            if std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| func(i, &mut ws))).is_err() {
                bad.push(i);
            }
        }
        st = shared.state.lock().unwrap();
        st.panicked_idx.extend_from_slice(&bad);
        st.finished += i1 - i0;
        if st.finished == job.n {
            if !st.panicked_idx.is_empty() {
                let epoch = st.epoch;
                let idx = std::mem::take(&mut st.panicked_idx);
                st.panicked_epochs.push((epoch, idx));
            }
            st.completed = st.epoch;
            st.job = None;
            shared.done.notify_all();
        }
    }
}

/// Scoped data-parallel map: runs `f(i)` for i in 0..n across up to
/// `threads` OS threads and returns results in index order. Uses
/// `std::thread::scope`, so `f` may borrow from the caller.
pub fn parallel_map<T: Send, F: Fn(usize) -> T + Sync>(n: usize, threads: usize, f: F) -> Vec<T> {
    parallel_map_ws(n, threads, |i, _ws| f(i))
}

/// [`parallel_map`] with workspace plumbing: each spawned thread creates
/// its own [`Workspace`] (scoped threads cannot persist scratch across
/// calls — prefer a [`WorkerPool`] on hot paths).
pub fn parallel_map_ws<T: Send, F: Fn(usize, &mut Workspace) -> T + Sync>(
    n: usize,
    threads: usize,
    f: F,
) -> Vec<T> {
    let threads = threads.clamp(1, n.max(1));
    if n == 0 {
        return Vec::new();
    }
    if threads == 1 {
        let mut ws = Workspace::default();
        return (0..n).map(|i| f(i, &mut ws)).collect();
    }
    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let slots = Mutex::new(&mut out);
    thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| {
                let mut ws = Workspace::default();
                loop {
                    let i = next.fetch_add(1, Ordering::SeqCst);
                    if i >= n {
                        break;
                    }
                    let v = f(i, &mut ws);
                    let mut guard = slots.lock().unwrap();
                    guard[i] = Some(v);
                }
            });
        }
    });
    out.into_iter().map(|v| v.expect("worker filled slot")).collect()
}

/// Scoped parallel-for without result collection.
pub fn parallel_for<F: Fn(usize) + Sync>(n: usize, threads: usize, f: F) {
    parallel_for_ws(n, threads, |i, _ws| f(i));
}

/// [`parallel_for`] with workspace plumbing (one fresh [`Workspace`] per
/// spawned thread).
pub fn parallel_for_ws<F: Fn(usize, &mut Workspace) + Sync>(n: usize, threads: usize, f: F) {
    let threads = threads.clamp(1, n.max(1));
    if n == 0 {
        return;
    }
    if threads == 1 {
        let mut ws = Workspace::default();
        for i in 0..n {
            f(i, &mut ws);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| {
                let mut ws = Workspace::default();
                loop {
                    let i = next.fetch_add(1, Ordering::SeqCst);
                    if i >= n {
                        break;
                    }
                    f(i, &mut ws);
                }
            });
        }
    });
}

/// Default worker count for compute kernels.
pub fn default_threads() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;
    use std::time::Duration;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn pool_drop_joins() {
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = ThreadPool::new(2);
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.submit(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
        } // drop waits for all workers
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn parallel_map_ordered() {
        let out = parallel_map(100, 8, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_empty_and_single() {
        assert!(parallel_map(0, 4, |i| i).is_empty());
        assert_eq!(parallel_map(1, 4, |i| i + 1), vec![1]);
    }

    #[test]
    fn parallel_for_covers_all() {
        let hits: Vec<AtomicUsize> = (0..64).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(64, 8, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn parallel_map_borrows_environment() {
        let data: Vec<f64> = (0..32).map(|i| i as f64).collect();
        let out = parallel_map(32, 4, |i| data[i] * 2.0);
        assert_eq!(out[31], 62.0);
    }

    #[test]
    fn claim_chunk_covers_range_and_shrinks_to_tail() {
        assert_eq!(claim_chunk(1, 4), 1);
        assert_eq!(claim_chunk(7, 4), 1);
        assert!(claim_chunk(1000, 4) > 1);
        assert!(claim_chunk(1_000_000, 1) <= 64, "chunks are bounded");
        // walking a range with guided chunks terminates and covers it
        let (mut next, n) = (0usize, 997);
        while next < n {
            next += claim_chunk(n - next, 5);
        }
        assert_eq!(next.min(n), n);
    }

    #[test]
    fn worker_pool_map_ordered_and_borrowing() {
        let pool = WorkerPool::new(4);
        let data: Vec<u64> = (0..100).collect();
        let out = pool.map(100, |i| data[i] * data[i]);
        assert_eq!(out, (0..100u64).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn worker_pool_reusable_across_jobs() {
        let pool = WorkerPool::new(3);
        let rounds = if cfg!(miri) { 5 } else { 20 };
        for round in 0..rounds as u64 {
            let out = pool.map(17, |i| i as u64 + round);
            assert_eq!(out, (0..17u64).map(|i| i + round).collect::<Vec<_>>());
        }
    }

    #[test]
    fn worker_pool_size_invariant_results() {
        let data: Vec<u64> = (0..64).collect();
        let mut outs = Vec::new();
        for size in [1, 2, 8] {
            let pool = WorkerPool::new(size);
            assert_eq!(pool.size(), size);
            outs.push(pool.map(64, |i| data[i] * 3));
        }
        assert_eq!(outs[0], outs[1]);
        assert_eq!(outs[1], outs[2]);
    }

    #[test]
    fn worker_pool_empty_single_and_drop() {
        let pool = WorkerPool::new(2);
        assert!(pool.map(0, |i| i).is_empty());
        assert_eq!(pool.map(1, |i| i + 1), vec![1]);
        drop(pool); // must join cleanly
    }

    #[test]
    fn worker_pool_workspaces_persist_across_jobs() {
        // A Workspace handed to a job must be a persistent arena, not a
        // fresh one per index: warm whatever arenas round 1 touches,
        // then require round 2 to observe retained capacity. (Which
        // participant claims which index is timing-dependent, but the
        // submitting thread always claims the first chunk — it installs
        // the job and claims under one lock hold — so at least its
        // caller-owned arena is deterministically warm.)
        let pool = WorkerPool::new(1);
        let mut ws = Workspace::default();
        pool.run_ws(4, &mut ws, &|_i, ws| {
            if ws.scores.capacity() < 4096 {
                ws.scores.reserve_exact(4096 - ws.scores.len());
            }
        });
        let warm_hits = AtomicUsize::new(0);
        pool.run_ws(4, &mut ws, &|_i, ws| {
            if ws.scores.capacity() >= 4096 {
                warm_hits.fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(warm_hits.load(Ordering::SeqCst) > 0, "no index saw a persistent arena");
        assert!(ws.scores.capacity() >= 4096, "the caller's arena must persist across jobs");
    }

    #[test]
    fn chunked_scheduling_is_deterministic_under_worker_skew() {
        // The determinism contract: shuffled per-index delays (simulating
        // slow workers / ragged items) must never change map results —
        // scheduling order may vary, merge order may not.
        let pool = WorkerPool::new(4);
        let want: Vec<u64> = (0..37u64).map(|i| i * 3 + 1).collect();
        let rounds = if cfg!(miri) { 2 } else { 8 };
        for round in 0..rounds as u64 {
            let out = pool.map(37, |i| {
                if (i as u64 * 7 + round) % 5 == 0 {
                    thread::sleep(Duration::from_micros(200));
                }
                i as u64 * 3 + 1
            });
            assert_eq!(out, want, "round {round}");
        }
    }

    #[test]
    fn submitter_participates_in_its_own_job() {
        // With a pool of 1 whose worker is held busy by the first index,
        // the remaining indices can only finish promptly if the submitter
        // claims chunks too. All indices must complete either way; at
        // least one must run on the submitting thread.
        let pool = WorkerPool::new(1);
        let submitter = thread::current().id();
        let on_submitter = AtomicUsize::new(0);
        pool.run(8, &|i| {
            if i == 0 {
                thread::sleep(Duration::from_millis(20));
            }
            if thread::current().id() == submitter {
                on_submitter.fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(on_submitter.load(Ordering::SeqCst) > 0, "submitter never claimed a chunk");
    }

    #[test]
    fn worker_pool_propagates_job_panics_and_stays_usable() {
        let pool = WorkerPool::new(2);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(4, &|i| {
                if i == 2 {
                    panic!("boom");
                }
            });
        }));
        assert!(result.is_err(), "worker panic must propagate to the submitter");
        // the job slot was released; the pool keeps working
        assert_eq!(pool.map(3, |i| i), vec![0, 1, 2]);
    }

    #[test]
    fn run_ws_caught_attributes_indices_without_reraising() {
        let pool = WorkerPool::new(2);
        let mut ws = Workspace::default();
        // clean run: empty attribution, nothing raised
        assert!(pool.run_ws_caught(8, &mut ws, &|_i, _ws| {}).is_empty());
        // two failing indices out of 8: exactly those, sorted, and the
        // remaining indices all still ran
        let hits: Vec<AtomicUsize> = (0..8).map(|_| AtomicUsize::new(0)).collect();
        let bad = pool.run_ws_caught(8, &mut ws, &|i, _ws| {
            hits[i].fetch_add(1, Ordering::SeqCst);
            if i == 2 || i == 5 {
                panic!("boom");
            }
        });
        assert_eq!(bad, vec![2, 5]);
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1), "an index was skipped");
        // the pool survives and the n == 1 inline fast path attributes too
        let bad = pool.run_ws_caught(1, &mut ws, &|_i, _ws| panic!("boom"));
        assert_eq!(bad, vec![0]);
        assert_eq!(pool.map(3, |i| i), vec![0, 1, 2]);
    }

    #[test]
    fn worker_pool_panic_lands_on_the_panicking_submitter_only() {
        // The per-epoch panic latch: with submitters interleaving on one
        // shared pool (the serving + probe composition), a panic in one
        // submitter's job must surface on *that* submitter every time,
        // and never on the innocent one. Two panickers make consecutive
        // panicking epochs likely — a single last-panic slot would lose
        // the earlier one; the clean submitter catches misattribution.
        let pool = Arc::new(WorkerPool::new(2));
        let rounds = if cfg!(miri) { 4 } else { 25 };
        thread::scope(|scope| {
            let panickers: Vec<_> = (0..2)
                .map(|_| {
                    let p = Arc::clone(&pool);
                    scope.spawn(move || {
                        let mut caught = 0;
                        for _ in 0..rounds {
                            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                                p.run(3, &|i| {
                                    if i == 1 {
                                        panic!("boom");
                                    }
                                });
                            }));
                            if r.is_err() {
                                caught += 1;
                            }
                        }
                        caught
                    })
                })
                .collect();
            let p = Arc::clone(&pool);
            let clean = scope.spawn(move || {
                for round in 0..rounds as u64 {
                    let out = p.map(5, |i| i as u64 + round);
                    assert_eq!(out, (0..5u64).map(|i| i + round).collect::<Vec<_>>());
                }
            });
            for h in panickers {
                assert_eq!(h.join().unwrap(), rounds, "every panicking job must report");
            }
            clean.join().expect("clean submitter must never see a foreign panic");
        });
    }

    #[test]
    fn worker_pool_concurrent_submitters_serialize() {
        let pool = Arc::new(WorkerPool::new(4));
        let hits = Arc::new(AtomicU64::new(0));
        let rounds: u64 = if cfg!(miri) { 2 } else { 8 };
        thread::scope(|scope| {
            for _ in 0..4 {
                let pool = Arc::clone(&pool);
                let hits = Arc::clone(&hits);
                scope.spawn(move || {
                    for _ in 0..rounds {
                        pool.run(10, &|_i| {
                            hits.fetch_add(1, Ordering::SeqCst);
                        });
                    }
                });
            }
        });
        assert_eq!(hits.load(Ordering::SeqCst), 4 * rounds * 10);
    }
}
