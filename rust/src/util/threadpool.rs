//! Fixed-size thread pool + scoped parallel-for (tokio/rayon substitute).
//!
//! The coordinator uses `ThreadPool` for request handling; the attention
//! engines use `parallel_for` to fan head-level work across cores.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed-size pool of worker threads consuming a shared job queue.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    pending: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Spawn `n` workers (n >= 1).
    pub fn new(n: usize) -> ThreadPool {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new(AtomicUsize::new(0));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let pending = Arc::clone(&pending);
                thread::Builder::new()
                    .name(format!("sparge-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => {
                                job();
                                pending.fetch_sub(1, Ordering::SeqCst);
                            }
                            Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, pending }
    }

    /// Pool sized to the machine (cores, capped at 16).
    pub fn default_size() -> ThreadPool {
        let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16);
        ThreadPool::new(n)
    }

    /// Submit a job.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        self.pending.fetch_add(1, Ordering::SeqCst);
        self.tx.as_ref().expect("pool shut down").send(Box::new(job)).expect("send job");
    }

    /// Number of jobs submitted but not yet finished.
    pub fn pending(&self) -> usize {
        self.pending.load(Ordering::SeqCst)
    }

    /// Busy-wait (with yield) until all submitted jobs finish.
    pub fn wait_idle(&self) {
        while self.pending() > 0 {
            thread::yield_now();
        }
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Scoped data-parallel map: runs `f(i)` for i in 0..n across up to
/// `threads` OS threads and returns results in index order. Uses
/// `std::thread::scope`, so `f` may borrow from the caller.
pub fn parallel_map<T: Send, F: Fn(usize) -> T + Sync>(n: usize, threads: usize, f: F) -> Vec<T> {
    let threads = threads.clamp(1, n.max(1));
    if n == 0 {
        return Vec::new();
    }
    if threads == 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let slots = Mutex::new(&mut out);
    thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::SeqCst);
                if i >= n {
                    break;
                }
                let v = f(i);
                let mut guard = slots.lock().unwrap();
                guard[i] = Some(v);
            });
        }
    });
    out.into_iter().map(|v| v.expect("worker filled slot")).collect()
}

/// Scoped parallel-for without result collection.
pub fn parallel_for<F: Fn(usize) + Sync>(n: usize, threads: usize, f: F) {
    let threads = threads.clamp(1, n.max(1));
    if n == 0 {
        return;
    }
    if threads == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::SeqCst);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Default worker count for compute kernels.
pub fn default_threads() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn pool_drop_joins() {
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = ThreadPool::new(2);
            for _ in 0..10 {
                let c = Arc::clone(&counter);
                pool.submit(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
        } // drop waits for all workers
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn parallel_map_ordered() {
        let out = parallel_map(100, 8, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_empty_and_single() {
        assert!(parallel_map(0, 4, |i| i).is_empty());
        assert_eq!(parallel_map(1, 4, |i| i + 1), vec![1]);
    }

    #[test]
    fn parallel_for_covers_all() {
        let hits: Vec<AtomicUsize> = (0..64).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(64, 8, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn parallel_map_borrows_environment() {
        let data: Vec<f64> = (0..32).map(|i| i as f64).collect();
        let out = parallel_map(32, 4, |i| data[i] * 2.0);
        assert_eq!(out[31], 62.0);
    }
}
