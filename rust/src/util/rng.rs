//! Deterministic PRNG substrate (no external crates available offline).
//!
//! PCG-XSH-RR 64/32 — small, fast, statistically solid; plus helpers for
//! Gaussians (Box–Muller), permutations (Fisher–Yates) and ranges. Every
//! workload generator and property test in the repo seeds one of these so
//! that all experiments are exactly reproducible.

/// PCG-XSH-RR 64/32 generator.
#[derive(Clone, Debug)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg { state: 0, inc: (stream << 1) | 1 };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Convenience: seed-only constructor (stream 0xda3e39cb94b95bdb).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    /// Next u32.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next u64.
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, bound) without modulo bias (Lemire).
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0) is meaningless");
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul_u64(x, bound);
            if lo >= bound || lo >= x.wrapping_neg() % bound {
                return hi;
            }
        }
    }

    /// Uniform usize in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range");
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn gauss(&mut self) -> f32 {
        loop {
            let u1 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
        }
    }

    /// Vector of standard normals.
    pub fn gauss_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.gauss()).collect()
    }

    /// Uniform vector in [lo, hi).
    pub fn uniform_vec(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| lo + (hi - lo) * self.f32()).collect()
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Bernoulli draw.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }
}

#[inline]
fn mul_u64(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Pcg::seeded(7);
        let mut b = Pcg::seeded(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_streams_differ() {
        let mut a = Pcg::new(7, 1);
        let mut b = Pcg::new(7, 2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Pcg::seeded(1);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Pcg::seeded(3);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as i64 - 10_000).abs() < 600, "count {c} too far from 10000");
        }
    }

    #[test]
    fn gauss_moments() {
        let mut r = Pcg::seeded(11);
        let xs = r.gauss_vec(50_000);
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn permutation_is_bijection() {
        let mut r = Pcg::seeded(5);
        let p = r.permutation(257);
        let mut seen = vec![false; 257];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_empty_and_single() {
        let mut r = Pcg::seeded(5);
        let mut e: [u8; 0] = [];
        r.shuffle(&mut e);
        let mut one = [42];
        r.shuffle(&mut one);
        assert_eq!(one, [42]);
    }

    #[test]
    fn range_bounds() {
        let mut r = Pcg::seeded(9);
        for _ in 0..1000 {
            let x = r.range(10, 20);
            assert!((10..20).contains(&x));
        }
    }
}
