//! Minimal JSON substrate (serde is not in the offline vendor set).
//!
//! Supports the full JSON grammar needed by this repo: artifact manifests,
//! per-layer hyper-parameter configs, and the coordinator's JSON-lines wire
//! protocol. Numbers are stored as f64; object key order is preserved.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    /// Member lookup on an object.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| if x >= 0.0 && x.fract() == 0.0 { Some(x as usize) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Object members as a map view (copies keys).
    pub fn as_map(&self) -> Option<BTreeMap<String, Json>> {
        match self {
            Json::Obj(pairs) => Some(pairs.iter().cloned().collect()),
            _ => None,
        }
    }

    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    /// Serialize (compact).
    pub fn dump(&self) -> String {
        let mut s = String::new();
        write_value(self, &mut s);
        s
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.dump())
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal, expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(pairs)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}' in object"));
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']' in array"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pair handling
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            out.push(char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?);
                        } else if (0xDC00..0xE000).contains(&cp) {
                            return Err(self.err("unexpected low surrogate"));
                        } else {
                            out.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control character in string")),
                Some(c) => {
                    // Re-decode UTF-8 multibyte sequences from the raw bytes.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("eof in \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

fn write_value(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(true) => out.push_str("true"),
        Json::Bool(false) => out.push_str("false"),
        Json::Num(x) => {
            if x.fract() == 0.0 && x.abs() < 1e15 {
                out.push_str(&format!("{}", *x as i64));
            } else {
                out.push_str(&format!("{x}"));
            }
        }
        Json::Str(s) => write_string(s, out),
        Json::Arr(items) => {
            out.push('[');
            for (i, it) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(it, out);
            }
            out.push(']');
        }
        Json::Obj(pairs) => {
            out.push('{');
            for (i, (k, val)) in pairs.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_string(k, out);
                out.push(':');
                write_value(val, out);
            }
            out.push('}');
        }
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::str("hi"));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\n\"y\""}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\n\"y\"");
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"sparge","tau":0.9,"layers":[{"theta":0.5},{"theta":-0.1}],"ok":true,"none":null}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
        // multibyte utf-8 passthrough
        let v = Json::parse("\"héllo 世界\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo 世界");
    }

    #[test]
    fn errors() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn preserves_key_order() {
        let v = Json::parse(r#"{"z":1,"a":2}"#).unwrap();
        assert_eq!(v.dump(), r#"{"z":1,"a":2}"#);
    }

    #[test]
    fn integers_dump_without_decimal() {
        assert_eq!(Json::Num(5.0).dump(), "5");
        assert_eq!(Json::Num(5.5).dump(), "5.5");
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 3, "s": "x", "b": false, "a": [1]}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("b").unwrap().as_bool(), Some(false));
        assert!(v.get("missing").is_none());
        assert_eq!(v.as_map().unwrap().len(), 4);
        assert_eq!(Json::Num(-1.0).as_usize(), None);
        assert_eq!(Json::Num(1.5).as_usize(), None);
    }
}
