//! Substrate utilities built from scratch for the offline environment
//! (only `xla` and `anyhow` are vendored): RNG, JSON, CLI parsing, thread
//! pool, statistics, ASCII tables, timing, logging, and a property-test
//! driver.

pub mod alloc;
pub mod cli;
pub mod json;
pub mod log;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
pub mod threadpool;
pub mod timer;
