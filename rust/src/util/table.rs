//! ASCII table rendering for paper-shaped benchmark output.
//!
//! Every bench in `rust/benches/` prints its rows through this so that the
//! regenerated tables line up with the paper's layout.

/// A simple column-aligned table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with a title and column headers.
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row; panics if the arity mismatches the header.
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity != header arity");
        self.rows.push(cells.to_vec());
        self
    }

    /// Append a row of &str.
    pub fn row_str(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True if no data rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render to a string.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for i in 0..ncol {
                s.push(' ');
                s.push_str(&cells[i]);
                s.push_str(&" ".repeat(widths[i] - cells[i].len() + 1));
                s.push('|');
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    /// Render and print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// Render as GitHub-flavored markdown (for EXPERIMENTS.md).
    pub fn render_markdown(&self) -> String {
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("**{}**\n\n", self.title));
        }
        out.push_str(&format!("| {} |\n", self.header.join(" | ")));
        out.push_str(&format!("|{}\n", "---|".repeat(self.header.len())));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        out
    }
}

/// Format a float with `prec` decimals.
pub fn fnum(x: f64, prec: usize) -> String {
    format!("{:.*}", prec, x)
}

/// Format a fraction as a percentage with one decimal.
pub fn pct(x: f64) -> String {
    format!("{:.1}%", 100.0 * x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["a", "long_column"]);
        t.row_str(&["1", "2"]);
        t.row_str(&["hello", "world"]);
        let r = t.render();
        assert!(r.contains("demo"));
        assert!(r.contains("| hello | world       |"));
        let lines: Vec<&str> = r.lines().collect();
        // all separator lines identical
        assert_eq!(lines[1], lines[3]);
        assert_eq!(lines[1], *lines.last().unwrap());
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row_str(&["only-one"]);
    }

    #[test]
    fn markdown_shape() {
        let mut t = Table::new("m", &["c1", "c2"]);
        t.row_str(&["x", "y"]);
        let md = t.render_markdown();
        assert!(md.contains("| c1 | c2 |"));
        assert!(md.contains("|---|---|"));
        assert!(md.contains("| x | y |"));
    }

    #[test]
    fn pct_and_fnum() {
        assert_eq!(pct(0.54), "54.0%");
        assert_eq!(fnum(3.14159, 2), "3.14");
    }
}
