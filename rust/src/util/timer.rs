//! Wall-clock timing + a minimal benchmark loop (criterion substitute —
//! the offline vendor set has no external bench crate).

use std::time::{Duration, Instant};

use super::stats::Summary;

/// Time a single invocation, returning (result, seconds).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Options for `bench`.
#[derive(Clone, Copy, Debug)]
pub struct BenchOpts {
    /// Warmup iterations (not measured).
    pub warmup: usize,
    /// Measured iterations.
    pub iters: usize,
    /// Hard cap on total measured wall-clock; the loop stops early once
    /// exceeded (at least one sample is always taken).
    pub max_total: Duration,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts { warmup: 2, iters: 10, max_total: Duration::from_secs(20) }
    }
}

impl BenchOpts {
    /// Quick preset for cheap operations.
    pub fn quick() -> Self {
        BenchOpts { warmup: 1, iters: 5, max_total: Duration::from_secs(5) }
    }
}

/// Run `f` repeatedly and summarize per-iteration seconds.
pub fn bench<T>(opts: BenchOpts, mut f: impl FnMut() -> T) -> Summary {
    for _ in 0..opts.warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(opts.iters);
    let start = Instant::now();
    for i in 0..opts.iters.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
        if i > 0 && start.elapsed() > opts.max_total {
            break;
        }
    }
    Summary::from(&samples)
}

/// A stopwatch accumulating named segments (used to split prediction time
/// from attention time for Table 3).
#[derive(Default, Debug)]
pub struct Stopwatch {
    segments: Vec<(String, f64)>,
}

impl Stopwatch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure and record it under `name`.
    pub fn measure<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let (out, secs) = time_once(f);
        self.segments.push((name.to_string(), secs));
        out
    }

    /// Total seconds recorded under `name`.
    pub fn total(&self, name: &str) -> f64 {
        self.segments.iter().filter(|(n, _)| n == name).map(|(_, s)| s).sum()
    }

    /// Total of all segments.
    pub fn grand_total(&self) -> f64 {
        self.segments.iter().map(|(_, s)| s).sum()
    }

    /// All recorded (name, seconds) pairs.
    pub fn segments(&self) -> &[(String, f64)] {
        &self.segments
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_once_measures_positive() {
        let (v, secs) = time_once(|| (0..1000).sum::<u64>());
        assert_eq!(v, 499500);
        assert!(secs >= 0.0);
    }

    #[test]
    fn bench_returns_requested_samples() {
        let s = bench(BenchOpts { warmup: 0, iters: 4, max_total: Duration::from_secs(60) }, || 1 + 1);
        assert_eq!(s.n, 4);
    }

    #[test]
    fn stopwatch_accumulates() {
        let mut sw = Stopwatch::new();
        sw.measure("a", || {});
        sw.measure("a", || {});
        sw.measure("b", || {});
        assert_eq!(sw.segments().len(), 3);
        assert!(sw.total("a") >= 0.0);
        assert!(sw.grand_total() >= sw.total("a"));
        assert_eq!(sw.total("missing"), 0.0);
    }
}
