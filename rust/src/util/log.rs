//! Leveled stderr logging with a process-global verbosity switch.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

/// Log levels in increasing verbosity.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Set the global level.
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::SeqCst);
}

/// Current global level.
pub fn level() -> Level {
    match LEVEL.load(Ordering::SeqCst) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        _ => Level::Debug,
    }
}

/// Whether a message at `l` would be emitted.
pub fn enabled(l: Level) -> bool {
    (l as u8) <= LEVEL.load(Ordering::SeqCst)
}

/// Emit a log line (used via the macros below).
pub fn emit(l: Level, module: &str, msg: &str) {
    if !enabled(l) {
        return;
    }
    let t = SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default();
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
    };
    eprintln!("[{}.{:03} {} {}] {}", t.as_secs(), t.subsec_millis(), tag, module, msg);
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::log::emit($crate::util::log::Level::Info, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::log::emit($crate::util::log::Level::Warn, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::log::emit($crate::util::log::Level::Error, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::log::emit($crate::util::log::Level::Debug, module_path!(), &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Debug);
        assert!(enabled(Level::Debug));
        set_level(Level::Info); // restore default for other tests
    }
}
