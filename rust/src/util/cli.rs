//! Tiny CLI argument parser (clap is not in the offline vendor set).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = iter.next().unwrap();
                    out.options.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process's own args.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects a number, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn get_f32(&self, name: &str, default: f32) -> f32 {
        self.get_f64(name, default as f64) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()))
    }

    #[test]
    fn positional_and_options() {
        let a = parse(&["serve", "--port", "8080", "--model=lm", "--verbose"]);
        assert_eq!(a.positional, vec!["serve"]);
        assert_eq!(a.get("port"), Some("8080"));
        assert_eq!(a.get("model"), Some("lm"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn typed_getters() {
        let a = parse(&["--n", "42", "--tau", "0.9"]);
        assert_eq!(a.get_usize("n", 0), 42);
        assert!((a.get_f64("tau", 0.0) - 0.9).abs() < 1e-12);
        assert_eq!(a.get_usize("missing", 7), 7);
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["--fast"]);
        assert!(a.flag("fast"));
        assert!(a.options.is_empty());
    }

    #[test]
    #[should_panic]
    fn bad_int_panics() {
        let a = parse(&["--n", "xyz"]);
        a.get_usize("n", 0);
    }
}
