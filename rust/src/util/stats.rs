//! Summary statistics for benchmark timing and experiment reporting.

/// Summary of a sample of measurements.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    /// Compute a summary from raw samples. Panics on an empty slice.
    pub fn from(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::from on empty samples");
        let mut xs = samples.to_vec();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: xs[0],
            p50: percentile_sorted(&xs, 0.50),
            p90: percentile_sorted(&xs, 0.90),
            p99: percentile_sorted(&xs, 0.99),
            max: xs[n - 1],
        }
    }
}

/// Linear-interpolated percentile of an already-sorted slice, q in [0,1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let q = q.clamp(0.0, 1.0);
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() { 0.0 } else { xs.iter().sum::<f64>() / xs.len() as f64 }
}

/// Mean of f32 slice as f64.
pub fn mean_f32(xs: &[f32]) -> f64 {
    if xs.is_empty() { 0.0 } else { xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64 }
}

/// Median (by sorting a copy).
pub fn median(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, 0.5)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::from(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile_sorted(&xs, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&xs, 0.0), 0.0);
        assert_eq!(percentile_sorted(&xs, 1.0), 10.0);
    }

    #[test]
    fn percentile_single() {
        assert_eq!(percentile_sorted(&[7.0], 0.9), 7.0);
    }

    #[test]
    fn median_odd_even() {
        assert!((median(&[3.0, 1.0, 2.0]) - 2.0).abs() < 1e-12);
        assert!((median(&[4.0, 1.0, 2.0, 3.0]) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn std_of_constant_is_zero() {
        let s = Summary::from(&[2.0; 10]);
        assert_eq!(s.std, 0.0);
    }
}
