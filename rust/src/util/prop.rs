//! Minimal property-testing harness (proptest substitute).
//!
//! `Cases` drives a closure over many PCG-seeded random cases; on failure it
//! reports the failing case index + seed so the case is exactly replayable
//! with `Cases::replay(seed, idx)`.

use super::rng::Pcg;

/// Property-test driver.
pub struct Cases {
    seed: u64,
    n: usize,
}

impl Cases {
    /// `n` cases derived from `seed`.
    pub fn new(seed: u64, n: usize) -> Cases {
        Cases { seed, n }
    }

    /// Standard size for module-level property tests.
    pub fn standard(seed: u64) -> Cases {
        // Allow override so CI can crank coverage: SPARGE_PROP_CASES=500.
        // Under Miri every case costs ~100x native, so default far lower
        // there; the env override still wins if set.
        let fallback = if cfg!(miri) { 6 } else { 40 };
        let n =
            std::env::var("SPARGE_PROP_CASES").ok().and_then(|v| v.parse().ok()).unwrap_or(fallback);
        Cases::new(seed, n)
    }

    /// Run `f(case_rng)` for each case; each case gets an independent
    /// deterministic RNG stream. Returns an error message naming the failing
    /// case on the first panic-free `Err`.
    pub fn check<F>(&self, mut f: F)
    where
        F: FnMut(&mut Pcg) -> Result<(), String>,
    {
        for idx in 0..self.n {
            let mut rng = Pcg::new(self.seed, idx as u64 + 1);
            if let Err(msg) = f(&mut rng) {
                panic!("property failed at case {idx} (seed {seed}): {msg}", seed = self.seed);
            }
        }
    }

    /// Re-create the RNG of a specific failing case for debugging.
    pub fn replay(seed: u64, idx: usize) -> Pcg {
        Pcg::new(seed, idx as u64 + 1)
    }
}

/// Assert two f32 slices are elementwise close.
pub fn assert_allclose(a: &[f32], b: &[f32], atol: f32, rtol: f32, what: &str) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("{what}: length mismatch {} vs {}", a.len(), b.len()));
    }
    let mut worst = 0f32;
    let mut worst_i = 0usize;
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs();
        let err = (x - y).abs();
        if err > tol && err - tol > worst {
            worst = err - tol;
            worst_i = i;
        }
    }
    if worst > 0.0 {
        return Err(format!(
            "{what}: mismatch at [{worst_i}]: {} vs {} (excess {worst:.3e}, atol {atol}, rtol {rtol})",
            a[worst_i], b[worst_i]
        ));
    }
    Ok(())
}

/// Relative L1 distance Σ|a−b| / Σ|b| — the paper's accuracy metric (§3.6).
pub fn rel_l1(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let num: f64 = a.iter().zip(b).map(|(&x, &y)| (x - y).abs() as f64).sum();
    let den: f64 = b.iter().map(|&y| y.abs() as f64).sum();
    if den == 0.0 {
        if num == 0.0 { 0.0 } else { f64::INFINITY }
    } else {
        num / den
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cases_run_and_pass() {
        let mut count = 0;
        Cases::new(1, 10).check(|rng| {
            count += 1;
            let x = rng.f32();
            if (0.0..1.0).contains(&x) { Ok(()) } else { Err("out of range".into()) }
        });
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "property failed at case")]
    fn cases_report_failure() {
        Cases::new(2, 5).check(|_| Err("boom".into()));
    }

    #[test]
    fn replay_matches_case_stream() {
        let mut seen = Vec::new();
        Cases::new(3, 4).check(|rng| {
            seen.push(rng.next_u64());
            Ok(())
        });
        let mut replayed = Cases::replay(3, 2);
        assert_eq!(replayed.next_u64(), seen[2]);
    }

    #[test]
    fn allclose_and_rel_l1() {
        assert!(assert_allclose(&[1.0, 2.0], &[1.0, 2.0 + 1e-6], 1e-5, 0.0, "t").is_ok());
        assert!(assert_allclose(&[1.0], &[2.0], 1e-5, 0.0, "t").is_err());
        assert!(assert_allclose(&[1.0], &[1.0, 2.0], 1e-5, 0.0, "t").is_err());
        assert!((rel_l1(&[1.0, 1.0], &[1.0, 2.0]) - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(rel_l1(&[0.0], &[0.0]), 0.0);
        assert_eq!(rel_l1(&[1.0], &[0.0]), f64::INFINITY);
    }
}
