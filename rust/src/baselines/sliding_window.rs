//! StreamingLLM-style pattern baseline (Xiao et al., ICLR 2024):
//! attention sinks (first blocks) + a sliding local window. This is the
//! "pattern-required" family of §2 — input-independent, so it is cheap but
//! cannot adapt to content (the universality limitation L1 the paper
//! motivates with).

use crate::attention::types::{AttnConfig, BlockMask};

/// Sink + sliding-window block mask for an (n_q, n_k) token problem:
/// every query block attends to the first `sink_blocks` key blocks and to
/// the `window_blocks` key blocks nearest its own diagonal position.
pub fn sliding_window_mask(
    n_q: usize,
    n_k: usize,
    cfg: &AttnConfig,
    sink_blocks: usize,
    window_blocks: usize,
) -> BlockMask {
    let tm = cfg.n_qblocks(n_q);
    let tn = cfg.n_kblocks(n_k);
    let mut mask = BlockMask::new_all(tm, tn, false);
    for i in 0..tm {
        // causal upper limit for this query block
        let q_last = ((i + 1) * cfg.bq).min(n_q) - 1;
        let j_max = if cfg.causal { (q_last / cfg.bk).min(tn - 1) } else { tn - 1 };
        for j in 0..sink_blocks.min(j_max + 1) {
            mask.set(i, j, true);
        }
        // window centred at the diagonal position of this q block
        let jd = ((i * cfg.bq) / cfg.bk).min(j_max);
        let lo = jd.saturating_sub(window_blocks / 2);
        let hi = (jd + window_blocks.div_ceil(2)).min(j_max + 1);
        for j in lo..hi.max(lo + 1).min(tn) {
            mask.set(i, j, true);
        }
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(bq: usize, bk: usize, causal: bool) -> AttnConfig {
        AttnConfig { bq, bk, causal, scale: None, cw: 2, row_offset: 0 }
    }

    #[test]
    fn sink_and_window_present() {
        let c = cfg(16, 16, true);
        let m = sliding_window_mask(128, 128, &c, 1, 2);
        for i in 0..m.rows {
            assert!(m.get(i, 0), "sink missing at row {i}");
            assert!(m.get(i, i), "diagonal missing at row {i}");
        }
    }

    #[test]
    fn causal_never_exceeds_diagonal() {
        let c = cfg(16, 16, true);
        let m = sliding_window_mask(128, 128, &c, 2, 4);
        for i in 0..m.rows {
            for j in (i + 1)..m.cols {
                assert!(!m.get(i, j), "violation ({i},{j})");
            }
        }
    }

    #[test]
    fn long_sequences_are_sparse() {
        let c = cfg(16, 16, false);
        let m = sliding_window_mask(1024, 1024, &c, 1, 4);
        assert!(m.sparsity() > 0.8, "sparsity {}", m.sparsity());
    }

    #[test]
    fn window_larger_than_grid_is_dense() {
        let c = cfg(16, 16, false);
        let m = sliding_window_mask(64, 64, &c, 4, 100);
        assert_eq!(m.sparsity(), 0.0);
    }

    #[test]
    fn every_row_nonempty() {
        let c = cfg(32, 16, true);
        let m = sliding_window_mask(320, 320, &c, 0, 1);
        for i in 0..m.rows {
            assert!((0..m.cols).any(|j| m.get(i, j)), "row {i} empty");
        }
    }
}
