//! FlexPrefill baseline (Lai et al., ICLR 2025).
//!
//! FlexPrefill selects, per head, the minimal set of key blocks whose
//! estimated attention mass reaches a *global* cumulative threshold γ
//! (query-aware block selection). The key differences from SpargeAttn:
//! the γ-budget is applied over the whole compressed map rather than per
//! query row with a self-similarity judge, so heads with diffuse attention
//! over-prune rows whose mass is spread out — the failure mode behind its
//! diffusion-model collapse in Table 1.

use crate::attention::types::{AttnConfig, BlockMask};
use crate::sparge::predict::compress_blocks;
use crate::tensor::{matmul, ops, Tensor};

/// Construct a FlexPrefill-style mask: keep the smallest set of (i,j)
/// blocks whose compressed-map mass reaches `gamma` of the total
/// (γ ∈ (0,1]; the paper uses γ = 0.95 and 0.99).
pub fn flexprefill_mask(q: &Tensor, k: &Tensor, cfg: &AttnConfig, gamma: f64) -> BlockMask {
    assert!(gamma > 0.0 && gamma <= 1.0, "gamma in (0,1]");
    let (qt, _) = compress_blocks(q, cfg.bq);
    let (kt, _) = compress_blocks(k, cfg.bk);
    let tm = qt.dim(0);
    let tn = kt.dim(0);
    let scale = cfg.scale_for(q.dim(1));

    let mut s_hat = matmul::matmul_nt(&qt, &kt);
    s_hat.scale(scale);
    if cfg.causal {
        for i in 0..tm {
            let q_last = ((i + 1) * cfg.bq).min(q.dim(0)) - 1;
            for j in 0..tn {
                if j * cfg.bk > q_last {
                    *s_hat.at2_mut(i, j) = f32::NEG_INFINITY;
                }
            }
        }
    }
    let p_hat = ops::softmax_rows(&s_hat);

    // Global selection: sort all in-domain blocks by mass, take the minimal
    // prefix reaching gamma of the total.
    let mut entries: Vec<(f32, usize, usize)> = Vec::with_capacity(tm * tn);
    let mut total = 0f64;
    for i in 0..tm {
        for j in 0..tn {
            let v = p_hat.at2(i, j);
            if v > 0.0 || !cfg.causal {
                entries.push((v, i, j));
                total += v as f64;
            }
        }
    }
    entries.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal));
    let mut mask = BlockMask::new_all(tm, tn, false);
    let budget = gamma * total;
    let mut cum = 0f64;
    for &(v, i, j) in &entries {
        mask.set(i, j, true);
        cum += v as f64;
        if cum >= budget {
            break;
        }
    }
    // FlexPrefill guarantees the diagonal (local) blocks are present.
    for i in 0..tm {
        let jd = ((i * cfg.bq) / cfg.bk).min(tn - 1);
        mask.set(i, jd, true);
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Cases;
    use crate::util::rng::Pcg;

    fn cfg(bq: usize, bk: usize, causal: bool) -> AttnConfig {
        AttnConfig { bq, bk, causal, scale: None, cw: 2, row_offset: 0 }
    }

    #[test]
    fn gamma_one_keeps_everything_noncausal() {
        let mut rng = Pcg::seeded(61);
        let q = Tensor::randn(&[64, 8], &mut rng);
        let k = Tensor::randn(&[64, 8], &mut rng);
        let m = flexprefill_mask(&q, &k, &cfg(16, 16, false), 1.0);
        assert_eq!(m.count_active(), 16);
    }

    #[test]
    fn smaller_gamma_is_sparser() {
        Cases::standard(902).check(|rng| {
            let n = rng.range(32, 128);
            let q = Tensor::randn(&[n, 8], rng);
            let k = Tensor::randn(&[n, 8], rng);
            let c = cfg(16, 16, false);
            let dense = flexprefill_mask(&q, &k, &c, 0.99);
            let sparse = flexprefill_mask(&q, &k, &c, 0.5);
            if sparse.count_active() > dense.count_active() {
                return Err("gamma monotonicity violated".into());
            }
            Ok(())
        });
    }

    #[test]
    fn diagonal_blocks_always_present() {
        let mut rng = Pcg::seeded(62);
        let q = Tensor::randn(&[128, 8], &mut rng);
        let k = Tensor::randn(&[128, 8], &mut rng);
        let c = cfg(16, 16, false);
        let m = flexprefill_mask(&q, &k, &c, 0.3);
        for i in 0..m.rows {
            assert!(m.get(i, i));
        }
    }

    #[test]
    fn concentrated_mass_prunes_diffuse_rows() {
        // Rows 0..1 blocks dominate; with a small gamma, far-off blocks of
        // other rows get dropped (the over-pruning failure mode).
        let n = 64;
        let d = 8;
        let mut q = Tensor::zeros(&[n, d]);
        let mut k = Tensor::zeros(&[n, d]);
        for i in 0..16 {
            q.row_mut(i)[0] = 6.0;
            k.row_mut(i)[0] = 6.0;
        }
        let c = cfg(16, 16, false);
        let m = flexprefill_mask(&q, &k, &c, 0.5);
        assert!(m.sparsity() > 0.4, "sparsity {}", m.sparsity());
    }
}
