//! Baseline sparse-attention mask constructors the paper compares against
//! (§4.1): block-sparse MInference and FlexPrefill, plus a
//! StreamingLLM-style sink+window pattern baseline.
//!
//! All baselines produce a [`BlockMask`] that is executed through the
//! *identical* sparse kernel (an `AttnEngine` with
//! `SparsityPolicy::External`), isolating the mask-construction policy as
//! the only experimental variable.

pub mod flexprefill;
pub mod minference;
pub mod sliding_window;

pub use flexprefill::flexprefill_mask;
pub use minference::minference_mask;
pub use sliding_window::sliding_window_mask;
