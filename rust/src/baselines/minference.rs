//! Block-sparse MInference baseline (Jiang et al., NeurIPS 2024).
//!
//! MInference's block-sparse branch estimates block importance from a
//! *mean-pooled* attention approximation and keeps a fixed top-k budget of
//! key blocks per query block. Unlike SpargeAttn it has no self-similarity
//! judge (every block is compressed regardless of coherence) and a fixed
//! budget rather than a CDF target — the two design deltas the paper's
//! Table 1/5 ablate.

use crate::attention::types::{AttnConfig, BlockMask};
use crate::sparge::predict::compress_blocks;
use crate::tensor::{matmul, ops, Tensor};

/// Construct a block mask keeping the top-`budget` fraction of key blocks
/// per query row (budget ∈ (0,1]; e.g. 0.5 and 0.7 reproduce the paper's
/// "MInference (0.5)" and "(0.3)" rows, where the figure in parentheses is
/// the resulting *sparsity* = 1 − budget).
pub fn minference_mask(q: &Tensor, k: &Tensor, cfg: &AttnConfig, budget: f64) -> BlockMask {
    assert!(budget > 0.0 && budget <= 1.0, "budget in (0,1]");
    let (qt, _) = compress_blocks(q, cfg.bq);
    let (kt, _) = compress_blocks(k, cfg.bk);
    let tm = qt.dim(0);
    let tn = kt.dim(0);
    let scale = cfg.scale_for(q.dim(1));

    let mut s_hat = matmul::matmul_nt(&qt, &kt);
    s_hat.scale(scale);
    if cfg.causal {
        for i in 0..tm {
            let q_last = ((i + 1) * cfg.bq).min(q.dim(0)) - 1;
            for j in 0..tn {
                if j * cfg.bk > q_last {
                    *s_hat.at2_mut(i, j) = f32::NEG_INFINITY;
                }
            }
        }
    }
    let p_hat = ops::softmax_rows(&s_hat);

    let mut mask = BlockMask::new_all(tm, tn, false);
    for i in 0..tm {
        let row = p_hat.row(i);
        // candidate blocks = those inside the causal domain
        let mut cand: Vec<usize> = (0..tn).filter(|&j| row[j] > 0.0 || !cfg.causal).collect();
        if cand.is_empty() {
            cand.push(0);
        }
        let keep = ((cand.len() as f64 * budget).ceil() as usize).clamp(1, cand.len());
        cand.sort_by(|&a, &b| row[b].partial_cmp(&row[a]).unwrap_or(std::cmp::Ordering::Equal));
        for &j in cand.iter().take(keep) {
            mask.set(i, j, true);
        }
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Cases;
    use crate::util::rng::Pcg;

    fn cfg(bq: usize, bk: usize, causal: bool) -> AttnConfig {
        AttnConfig { bq, bk, causal, scale: None, cw: 2, row_offset: 0 }
    }

    #[test]
    fn budget_controls_density() {
        let mut rng = Pcg::seeded(51);
        let q = Tensor::randn(&[128, 16], &mut rng);
        let k = Tensor::randn(&[128, 16], &mut rng);
        let c = cfg(16, 16, false);
        let half = minference_mask(&q, &k, &c, 0.5);
        let full = minference_mask(&q, &k, &c, 1.0);
        assert_eq!(full.count_active(), 64);
        assert_eq!(half.count_active(), 32);
        assert!((half.sparsity() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn every_row_keeps_at_least_one() {
        Cases::standard(901).check(|rng| {
            let n = rng.range(16, 100);
            let q = Tensor::randn(&[n, 8], rng);
            let k = Tensor::randn(&[n, 8], rng);
            let c = cfg(rng.range(4, 20), rng.range(4, 20), rng.chance(0.5));
            let m = minference_mask(&q, &k, &c, 0.1);
            for i in 0..m.rows {
                if (0..m.cols).all(|j| !m.get(i, j)) {
                    return Err(format!("row {i} empty"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn causal_mask_is_lower_triangular() {
        let mut rng = Pcg::seeded(52);
        let q = Tensor::randn(&[64, 8], &mut rng);
        let k = Tensor::randn(&[64, 8], &mut rng);
        let c = cfg(16, 16, true);
        let m = minference_mask(&q, &k, &c, 1.0);
        for i in 0..m.rows {
            for j in 0..m.cols {
                if j > i {
                    assert!(!m.get(i, j), "causal violation ({i},{j})");
                }
            }
        }
        // diagonal present
        for i in 0..m.rows {
            assert!(m.get(i, i));
        }
    }

    #[test]
    fn picks_dominant_blocks() {
        // One key block is made to dominate all queries; budget 1 block/row
        // must select it.
        let n = 64;
        let d = 8;
        let mut q = Tensor::zeros(&[n, d]);
        let mut k = Tensor::zeros(&[n, d]);
        for i in 0..n {
            q.row_mut(i)[0] = 3.0;
            k.row_mut(i)[0] = if (16..32).contains(&i) { 5.0 } else { -1.0 };
        }
        let c = cfg(16, 16, false);
        let m = minference_mask(&q, &k, &c, 0.25);
        for i in 0..m.rows {
            assert!(m.get(i, 1), "row {i} missed dominant block");
        }
    }
}
