//! Workload generators and traces: correlated synthetic attention inputs,
//! text corpora + Needle-in-a-Haystack, video latent grids, and the binary
//! tensor-trace interchange format.

pub mod synthetic;
pub mod text;
pub mod trace;
pub mod video;

pub use synthetic::{generate, generate_heads, QkvSample, SyntheticSpec};
pub use video::VideoSpec;
